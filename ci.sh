#!/usr/bin/env sh
# CI gate for the lovelock crate. No network, no external dependencies:
# everything builds from the repo with the stock Rust toolchain.
#
#   ./ci.sh            full gate (build, tests, docs-with-denied-warnings)
#   ./ci.sh quick      skip the release build (debug tests + docs only)

set -eu

cd "$(dirname "$0")"

if [ "${1:-}" != "quick" ]; then
    echo "==> cargo build --release"
    cargo build --release
    echo "==> cargo bench --no-run (compile bench targets)"
    cargo bench --no-run
fi

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI gate passed."

#!/usr/bin/env sh
# CI gate for the lovelock crate. No network, no external dependencies:
# everything builds from the repo with the stock Rust toolchain.
#
#   ./ci.sh            full gate (lint, build, tests, docs-with-denied-warnings)
#   ./ci.sh quick      skip the release build (debug tests + docs only)

set -eu

cd "$(dirname "$0")"

# Lint stage: rustfmt and clippy are rustup components that may be
# absent from a minimal toolchain image — detect before demanding.
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --all -- --check
else
    echo "==> cargo fmt unavailable; skipping format check"
fi
if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy (warnings denied)"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi

if [ "${1:-}" != "quick" ]; then
    echo "==> cargo build --release"
    cargo build --release
    echo "==> cargo bench --no-run (compile bench targets)"
    cargo bench --no-run
fi

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI gate passed."

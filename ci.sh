#!/usr/bin/env sh
# CI gate for the lovelock crate. No network, no external dependencies:
# everything builds from the repo with the stock Rust toolchain.
#
#   ./ci.sh            full gate (lint, build, tests, docs-with-denied-warnings)
#   ./ci.sh quick      skip the release build (debug tests + docs only)

set -eu

cd "$(dirname "$0")"

# Lint stage: rustfmt and clippy are rustup components that may be
# absent from a minimal toolchain image — detect before demanding.
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --all -- --check
else
    echo "==> cargo fmt unavailable; skipping format check"
fi
if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy (warnings denied)"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi

# Invariant lint: the zero-dependency in-repo checker (rule table in
# DESIGN.md §3h). Hard-fail: any lock-order / hot-path-alloc /
# wire-tag / no-panic-worker finding without a reasoned allow (or a
# `// bound:` proof for codec indexing) stops the gate here. Pass
# `--json` when a machine needs the findings.
echo "==> lovelock lint (invariant checker, hard fail)"
cargo run -q -- lint rust/src

if [ "${1:-}" != "quick" ]; then
    echo "==> cargo build --release"
    cargo build --release
    echo "==> cargo bench --no-run (compile bench targets)"
    cargo bench --no-run
fi

echo "==> cargo test -q"
cargo test -q

# Wire-format stability gate: decode and RUN the golden encoded plan
# fixture (rust/tests/fixtures/q6_plan.bin). `cargo test` above already
# ran it; this explicit stage keeps the gate visible and names the fix:
# an intentional codec change regenerates the fixture with
# LOVELOCK_BLESS=1 and commits it alongside.
echo "==> golden plan fixture (LogicalPlan wire format pinned)"
cargo test -q --test plan_fixture

# Chaos gate: the deterministic fault-injection suite (seeded drops /
# duplicates / delays + worker kills mid-map and mid-reduce; fixed
# seeds 0xC0FFEE and 0x5EED inside rust/tests/chaos.rs, so a failure
# here replays locally with the same schedule). `cargo test -q` above
# already ran it in debug; the full gate re-runs it in release, where
# different timing widens the interleavings the monitor races against.
echo "==> chaos suite (fault-injected QueryService, debug)"
cargo test -q --test chaos
if [ "${1:-}" != "quick" ]; then
    echo "==> chaos suite (release)"
    cargo test --release -q --test chaos
fi

# Alloc-count gate: a per-row allocation sneaking back into the batch
# kernels must fail CI, not wait for someone to read bench output. The
# `cargo test -q` above already ran the alloc_regression test in debug
# (quick mode's coverage); the full gate re-runs it in release, where
# the optimized code that ships is what gets measured.
if [ "${1:-}" != "quick" ]; then
    echo "==> alloc-count regression (release)"
    cargo test --release -q --test alloc_regression
fi

# Overload gate: admission control, per-query deadlines, and fair
# scheduling composed with chaos (DESIGN.md §3g). The acceptance test
# drives a 10x closed loop through a mid-map worker kill and requires
# explicit shedding, a held buffered-bytes watermark, and serial-
# identical rows (or typed timeouts) for everything admitted. The churn
# test is its own binary on purpose: a process-wide live-byte allocator
# pins the heap high-water mark across thousands of fresh-session
# submit/wait/retire cycles.
echo "==> overload suite (admission / deadlines / fairness + kill)"
cargo test -q --test overload
echo "==> session-churn heap high-water gate"
cargo test -q --test service_churn

# Zone-map pruning gate: pruned and unpruned compilations of randomized
# window predicates must produce bit-identical partials (the property
# test), and all three execution paths must agree on every registry
# query over chunked, zone-mapped storage. `cargo test -q` above already
# ran these; this stage keeps the invariant visible by name.
echo "==> zone-map pruning equality (pruned == unpruned, all paths agree)"
cargo test -q --test properties -- prop_zone_pruning_is_invisible_in_results \
    three_paths_agree_for_every_registry_query \
    distributed_q6_and_q19_prune_morsels

# Streaming-generation gate: a full lineitem pass through the chunk
# stream must hold only one reused buffer — the peak-tracking allocator
# in rust/tests/gen_stream.rs asserts the high-water mark stays a small
# constant far below a materialized table (SF-bounded-memory smoke).
echo "==> streaming generator bounded-memory smoke"
cargo test -q --test gen_stream

# SQL front-end gate: every registry query expressed as SQL must plan
# through parse -> bind -> optimize and return the registry's rows on
# all three execution paths; the parser must never panic on hostile
# text; the optimizer must never change results; and fixtures/q6.sql
# must land on the exact frozen q6 wire bytes. Then an `explain` smoke
# through the real CLI: plan tree + derived prune intervals + cost rows
# must render for an ad-hoc query (a front-end regression that only
# bites the binary fails here, not in a user's hands).
echo "==> sql front-end (registry equivalence, robustness, golden q6.sql)"
cargo test -q --test sql_queries
echo "==> explain smoke (CLI)"
cargo run -q -- explain "SELECT l_returnflag, COUNT(*) FROM lineitem \
 WHERE l_shipdate < DATE '1994-06-01' AND l_quantity < 30 \
 GROUP BY l_returnflag" >/dev/null

if [ "${1:-}" != "quick" ]; then
    # Bench smoke: run every bench once with the short measurement loop
    # (LOVELOCK_BENCH_QUICK), so a bench that panics (or drifts from a
    # changed API) fails CI — timings themselves are not checked. The SF
    # overrides apply to hotpath (the only bench that generates large
    # data); JSON artifacts are redirected so the smoke run's tiny-SF
    # rows never clobber a real BENCH_hotpath.json / BENCH_service.json
    # measurement (loadgen honors LOVELOCK_BENCH_QUICK with short
    # windows of its own).
    for bench in table1 fig3 fig4 table2 cost gnn rpc hotpath loadgen; do
        echo "==> bench smoke: $bench"
        LOVELOCK_BENCH_QUICK=1 LOVELOCK_BENCH_SF=0.004 LOVELOCK_BENCH_SF_BIG=0.01 \
            LOVELOCK_BENCH_JSON=/tmp/BENCH_hotpath_smoke.json \
            cargo bench --bench "$bench" >/dev/null
    done
fi

# Sanitizer stages: both need optional components (miri; a nightly
# toolchain with rust-src for -Zbuild-std), so detect before demanding
# and skip LOUDLY — a silent skip reads as coverage that isn't there.
if cargo miri --version >/dev/null 2>&1; then
    # Miri over the wire-codec and scheduler unit tests: the codecs do
    # the crate's only offset arithmetic over untrusted bytes, exactly
    # where UB would hide.
    echo "==> miri (wire codec + scheduler unit tests)"
    cargo miri test -q --lib wirefmt:: coordinator::protocol:: coordinator::scheduler::
else
    echo "==> SKIPPED: cargo miri not installed (rustup component add miri) — no UB coverage this run"
fi
if cargo +nightly --version >/dev/null 2>&1; then
    # ThreadSanitizer build of the two most interleaving-heavy suites.
    # Building (not running) is the gate: TSan instrumentation itself
    # requires -Zbuild-std, and a build catches bitrot in the config.
    echo "==> TSan build (chaos + overload test binaries, nightly)"
    RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test --no-run -q \
        -Zbuild-std --target x86_64-unknown-linux-gnu \
        --test chaos --test overload
else
    echo "==> SKIPPED: nightly toolchain not installed — no TSan build this run"
fi

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI gate passed."

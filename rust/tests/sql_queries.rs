//! SQL front-end integration: the whole Figure-3 registry expressed as
//! SQL text, planned through `parse → bind → optimize`, and executed on
//! all three paths (serial, morsel-parallel, distributed) against the
//! registry constructors' rows.
//!
//! Also here: the golden `fixtures/q6.sql` → wire-bytes pin (the SQL
//! front door must land on the exact bytes `plan_fixture.rs` freezes
//! for the registry's q6), parser robustness under hostile and mutated
//! text, optimizer result-preservation on randomized queries and on
//! every registry plan, and the IN-set hull pruning oracle.

use lovelock::analytics::engine::{self, plan, LogicalPlan, PlanParams};
use lovelock::analytics::queries::{self, Value};
use lovelock::analytics::sql::{optimize, plan_sql, plan_sql_unoptimized};
use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{QueryService, ServiceConfig};
use lovelock::platform::n2d_milan;
use lovelock::proptest_mini::*;
use std::sync::Arc;

/// Every registry query as SQL. The texts mirror the TPC-H statements
/// the IR constructors hand-compile (`rust/src/analytics/queries/`),
/// with the constructors' default parameters inlined.
const REGISTRY_SQL: [(&str, &str); 9] = [
    (
        "q1",
        "SELECT l_returnflag, l_linestatus, SUM(l_quantity), SUM(l_extendedprice), \
         SUM(l_extendedprice * (1 - l_discount)), \
         SUM(l_extendedprice * (1 - l_discount) * (1 + l_tax)), \
         AVG(l_quantity), AVG(l_extendedprice), AVG(l_discount), COUNT(*) \
         FROM lineitem WHERE l_shipdate <= DATE '1998-09-02' \
         GROUP BY l_returnflag, l_linestatus ORDER BY l_returnflag, l_linestatus",
    ),
    (
        "q3",
        "SELECT l_orderkey, SUM(l_extendedprice * (1 - l_discount)) AS revenue, o_orderdate \
         FROM lineitem \
         JOIN customer ON c_custkey = o_custkey \
         JOIN orders ON o_orderkey = l_orderkey \
         WHERE c_mktsegment = 'BUILDING' AND o_orderdate < DATE '1995-03-15' \
         AND l_shipdate > DATE '1995-03-15' \
         GROUP BY l_orderkey, o_orderdate ORDER BY revenue DESC, l_orderkey LIMIT 10",
    ),
    (
        "q5",
        "SELECT nation_name(c_nationkey), \
         SUM(l_extendedprice * (1 - l_discount)) AS revenue \
         FROM lineitem \
         JOIN customer ON c_custkey = o_custkey \
         JOIN orders ON o_orderkey = l_orderkey \
         JOIN supplier ON s_suppkey = l_suppkey \
         WHERE region_of(c_nationkey) = 'ASIA' \
         AND o_orderdate >= DATE '1994-01-01' AND o_orderdate < DATE '1995-01-01' \
         AND c_nationkey = s_nationkey \
         GROUP BY nation_name(c_nationkey) ORDER BY revenue DESC",
    ),
    (
        "q6",
        "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
         WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
         AND l_discount >= 0.045 AND l_discount < 0.075 AND l_quantity < 24",
    ),
    (
        "q9",
        "SELECT nation_name(s_nationkey), year(o_orderdate), \
         SUM(l_extendedprice * (1 - l_discount) - ps_supplycost * l_quantity) \
         FROM lineitem \
         JOIN part ON p_partkey = l_partkey \
         JOIN partsupp ON ps_partkey = l_partkey AND ps_suppkey = l_suppkey \
         JOIN supplier ON s_suppkey = l_suppkey \
         JOIN orders ON o_orderkey = l_orderkey \
         WHERE p_name LIKE '%green%' \
         GROUP BY nation_name(s_nationkey), year(o_orderdate) ORDER BY 1, 2 DESC",
    ),
    (
        "q12",
        "SELECT l_shipmode, \
         SUM(CASE WHEN o_orderpriority IN ('1-URGENT', '2-HIGH') THEN 1 ELSE 0 END), \
         SUM(CASE WHEN o_orderpriority IN ('1-URGENT', '2-HIGH') THEN 0 ELSE 1 END) \
         FROM lineitem JOIN orders ON o_orderkey = l_orderkey \
         WHERE l_shipmode IN ('MAIL', 'SHIP') \
         AND l_receiptdate >= DATE '1994-01-01' AND l_receiptdate < DATE '1995-01-01' \
         AND l_commitdate < l_receiptdate AND l_shipdate < l_commitdate \
         GROUP BY l_shipmode ORDER BY l_shipmode",
    ),
    (
        "q14",
        "SELECT 100 * SUM(CASE WHEN p_type LIKE 'PROMO%' \
         THEN l_extendedprice * (1 - l_discount) ELSE 0 END) \
         / SUM(l_extendedprice * (1 - l_discount)) \
         FROM lineitem JOIN part ON p_partkey = l_partkey \
         WHERE l_shipdate >= DATE '1995-09-01' AND l_shipdate < DATE '1995-10-01'",
    ),
    (
        "q18",
        "SELECT o_custkey, l_orderkey, o_orderdate, o_totalprice, SUM(l_quantity) \
         FROM lineitem JOIN orders ON o_orderkey = l_orderkey \
         GROUP BY o_custkey, l_orderkey, o_orderdate, o_totalprice \
         HAVING SUM(l_quantity) > 300 \
         ORDER BY o_totalprice DESC, l_orderkey LIMIT 100",
    ),
    (
        "q19",
        "SELECT SUM(l_extendedprice * (1 - l_discount)) FROM lineitem \
         JOIN part ON p_partkey = l_partkey \
         WHERE l_shipmode IN ('AIR', 'REG AIR') AND l_shipinstruct = 'DELIVER IN PERSON' AND \
         ((p_brand = 'Brand#12' AND p_container IN ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG') \
           AND p_size BETWEEN 1 AND 5 AND l_quantity BETWEEN 1 AND 11) \
          OR (p_brand = 'Brand#23' AND p_container IN ('MED BAG', 'MED BOX') \
           AND p_size BETWEEN 1 AND 10 AND l_quantity BETWEEN 10 AND 20) \
          OR (p_brand = 'Brand#34' AND p_container IN ('LG CASE', 'LG BOX') \
           AND p_size BETWEEN 1 AND 15 AND l_quantity BETWEEN 20 AND 30))",
    ),
];

const SQL_FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/q6.sql");
const PLAN_FIXTURE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/q6_plan.bin");

fn sql_for(name: &str) -> &'static str {
    REGISTRY_SQL
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, s)| *s)
        .unwrap_or_else(|| panic!("no SQL text for {name}"))
}

#[test]
fn registry_queries_as_sql_match_on_all_three_paths() {
    let db = Arc::new(TpchDb::generate(TpchConfig::new(0.01, 777)));
    let svc = QueryService::with_config(
        ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute),
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    for (name, sql) in REGISTRY_SQL {
        let reference = queries::run_query(&db, name).unwrap();
        let p = plan_sql(sql).unwrap_or_else(|e| panic!("{name}: {e}"));
        let serial = engine::try_run_serial(&db, &p).unwrap();
        assert!(reference.approx_eq_rows(&serial.rows), "{name}: serial SQL rows diverged");
        let morsel = engine::try_run_parallel(&db, &p, 4, 8192).unwrap();
        assert!(reference.approx_eq_rows(&morsel.rows), "{name}: morsel SQL rows diverged");
        let id = svc.submit_plan(&db, &p).unwrap();
        let (rows, _) = svc.wait(id).unwrap();
        assert!(reference.approx_eq_rows(&rows), "{name}: distributed SQL rows diverged");
    }
}

#[test]
fn most_registry_plans_are_reproduced_exactly_from_sql() {
    // For everything but q5/q9 the optimized SQL plan is structurally
    // identical to the hand-built constructor — same predicate tree,
    // same join shapes, same finalize — not merely row-equal. (q5/q9
    // come out row-equal under a different join order; see below.)
    for (name, sql) in REGISTRY_SQL {
        if name == "q5" || name == "q9" {
            continue;
        }
        let mut p = plan_sql(sql).unwrap_or_else(|e| panic!("{name}: {e}"));
        p.name = name.into();
        let reg = queries::build(name, &PlanParams::default()).unwrap();
        assert_eq!(p, reg, "{name}: SQL plan diverged from the registry constructor");
    }
}

#[test]
fn reordered_plans_cover_the_same_join_tables() {
    // q5 and q9 legitimately differ from the constructors: the binder
    // lowers supplier/part as dense probes and the cost model reorders
    // the builds cheapest-first. The table set must still agree (rows
    // are compared in the all-paths test above).
    for name in ["q5", "q9"] {
        let p = plan_sql(sql_for(name)).unwrap();
        let reg = queries::build(name, &PlanParams::default()).unwrap();
        let mut a: Vec<&str> = p.joins.iter().map(|j| j.table.name()).collect();
        let mut b: Vec<&str> = reg.joins.iter().map(|j| j.table.name()).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "{name}: join table sets diverged");
    }
}

#[test]
fn golden_q6_sql_lands_on_the_frozen_plan_bytes() {
    // The end-to-end pin: SQL text on disk → lex → parse → bind →
    // optimize → encode must reproduce the exact wire bytes frozen for
    // the registry's q6. Intentional wire-format changes regenerate the
    // .bin via `LOVELOCK_BLESS=1 cargo test --test plan_fixture`
    // (the fixture is shared; this test only ever reads it).
    let text = std::fs::read_to_string(SQL_FIXTURE)
        .unwrap_or_else(|e| panic!("missing SQL fixture {SQL_FIXTURE}: {e}"));
    let mut p = plan_sql(&text).expect("fixture SQL must plan");
    p.name = "q6".into();
    let bytes = std::fs::read(PLAN_FIXTURE)
        .unwrap_or_else(|e| panic!("missing golden fixture {PLAN_FIXTURE}: {e}"));
    assert_eq!(
        p.encode(),
        bytes,
        "SQL-born q6 drifted from the frozen wire bytes; if the binder or format \
         change is intentional, re-bless via plan_fixture and revisit q6.sql"
    );
    let golden = LogicalPlan::decode(&bytes).expect("frozen bytes decode");
    assert_eq!(p, golden, "decoded golden plan differs from the SQL-born plan");
}

#[test]
fn prop_parser_never_panics_on_byte_soup() {
    let strat = vec_of(int_range(0, 255), 0, 120);
    check("sql_no_panic_bytes", &strat, |bytes| {
        let s: String = bytes.iter().map(|b| *b as u8 as char).collect();
        let _ = plan_sql(&s); // Ok or Err both fine; a panic fails the property.
        Ok(())
    });
}

#[test]
fn prop_parser_never_panics_on_fragment_splices() {
    // Random splices of real grammar fragments reach far deeper into
    // the parser and binder than byte soup does.
    const FRAGMENTS: [&str; 36] = [
        "SELECT", "FROM", "WHERE", "GROUP BY", "ORDER BY", "HAVING", "LIMIT", "JOIN", "ON",
        "AND", "OR", "NOT", "IN", "BETWEEN", "LIKE", "AS", "CASE WHEN", "THEN", "ELSE", "END",
        "SUM(", "AVG(", "COUNT(*)", "(", ")", ",", "*", "+", "-", "=", "<", ">=",
        "lineitem", "l_shipdate", "DATE '1994-01-01'", "0.05",
    ];
    let strat = vec_of(int_range(0, FRAGMENTS.len() as i64 - 1), 0, 40);
    check("sql_no_panic_fragments", &strat, |idxs| {
        let s: Vec<&str> = idxs.iter().map(|i| FRAGMENTS[*i as usize]).collect();
        let _ = plan_sql(&s.join(" "));
        Ok(())
    });
}

#[test]
fn prop_optimizer_preserves_rows_on_random_queries() {
    let db = TpchDb::generate(TpchConfig::new(0.002, 99));
    let strat = pair_of(
        pair_of(int_range(1992, 1997), int_range(1, 12)),
        pair_of(int_range(30, 400), int_range(1, 50)),
    );
    check("sql_optimizer_preserves_rows", &strat, |((y, m), (span, q))| {
        // Folded date arithmetic + float bound + a char group key: the
        // optimizer rewrites all of it (fold, push, merge); rows must
        // not move, raw vs optimized, serial vs morsel.
        let sql = format!(
            "SELECT l_returnflag, COUNT(*), SUM(l_extendedprice) FROM lineitem \
             WHERE l_shipdate >= DATE '{y:04}-{m:02}-01' \
             AND l_shipdate < DATE '{y:04}-{m:02}-01' + {span} \
             AND l_quantity < {q} \
             GROUP BY l_returnflag ORDER BY l_returnflag"
        );
        let raw = plan_sql_unoptimized(&sql).map_err(|e| e.to_string())?;
        let opt = optimize::optimize(&raw);
        opt.check_wire_bounds().map_err(|e| e.to_string())?;
        let a = engine::try_run_serial(&db, &raw).map_err(|e| e.to_string())?;
        let b = engine::try_run_serial(&db, &opt).map_err(|e| e.to_string())?;
        if !a.approx_eq_rows(&b.rows) {
            return Err("optimized rows diverged from raw".into());
        }
        let c = engine::try_run_parallel(&db, &opt, 3, 2048).map_err(|e| e.to_string())?;
        if !a.approx_eq_rows(&c.rows) {
            return Err("morsel rows diverged from serial".into());
        }
        Ok(())
    });
}

#[test]
fn optimizing_registry_plans_never_changes_rows() {
    // The optimizer takes LogicalPlan, not SQL, so the hand-built
    // registry plans must survive it too — bit-for-bit legal, row-equal.
    let db = TpchDb::generate(TpchConfig::new(0.005, 5));
    for (name, _) in REGISTRY_SQL {
        let reg = queries::build(name, &PlanParams::default()).unwrap();
        let opt = optimize::optimize(&reg);
        opt.check_wire_bounds().unwrap_or_else(|e| panic!("{name}: {e}"));
        let a = queries::run_query(&db, name).unwrap();
        let b = engine::try_run_serial(&db, &opt).unwrap();
        assert!(a.approx_eq_rows(&b.rows), "{name}: optimizer changed rows");
    }
}

#[test]
fn prop_in_set_hull_pruning_matches_brute_force() {
    // IN-set predicates prune through a conservative [min, max] hull;
    // the count must equal a row-at-a-time scan no matter how the set
    // clusters against the zone boundaries.
    let db = TpchDb::generate(TpchConfig::new(0.005, 4242));
    let ship = db.lineitem.col("l_shipdate").as_i32();
    let lo = *ship.iter().min().unwrap() as i64;
    let hi = *ship.iter().max().unwrap() as i64;
    let strat = vec_of(int_range(lo, hi), 1, 8);
    check("sql_in_set_prune_brute_force", &strat, |days| {
        let list = days.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
        let sql = format!("SELECT COUNT(*) FROM lineitem WHERE l_shipdate IN ({list})");
        let p = plan_sql(&sql).map_err(|e| e.to_string())?;
        if plan::derived_intervals(&p).is_empty() {
            return Err("IN-set hull must derive a prune interval".into());
        }
        let out = engine::try_run_serial(&db, &p).map_err(|e| e.to_string())?;
        let expect = ship.iter().filter(|v| days.contains(&(**v as i64))).count() as i64;
        let got = match out.rows.first().and_then(|r| r.first()) {
            Some(Value::Int(n)) => *n,
            other => return Err(format!("expected an integer count, got {other:?}")),
        };
        if got != expect {
            return Err(format!("IN-set count {got} != brute force {expect}"));
        }
        let par = engine::try_run_parallel(&db, &p, 3, 1024).map_err(|e| e.to_string())?;
        if !out.approx_eq_rows(&par.rows) {
            return Err("morsel IN-set rows diverged from serial".into());
        }
        Ok(())
    });
}

#[test]
fn adhoc_sql_runs_everywhere_and_pushdown_unlocks_pruning() {
    let db = Arc::new(TpchDb::generate(TpchConfig::new(0.01, 42)));
    let svc = QueryService::with_config(
        ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute),
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    let adhoc = [
        "SELECT l_returnflag, COUNT(*), AVG(l_extendedprice) FROM lineitem \
         WHERE l_quantity BETWEEN 10 AND 20 AND l_shipmode IN ('MAIL', 'AIR') \
         GROUP BY l_returnflag ORDER BY l_returnflag",
        "SELECT l_shipmode, SUM(l_extendedprice * (1 - l_discount)) AS revenue \
         FROM lineitem JOIN part ON p_partkey = l_partkey \
         WHERE p_size < 15 AND l_shipdate >= DATE '1996-01-01' \
         GROUP BY l_shipmode ORDER BY revenue DESC",
        "SELECT COUNT(*), SUM(l_extendedprice) FROM lineitem \
         WHERE l_shipdate < DATE '1993-01-01' + 90",
    ];
    for sql in adhoc {
        let p = plan_sql(sql).unwrap_or_else(|e| panic!("{sql}: {e}"));
        let serial = engine::try_run_serial(&db, &p).unwrap();
        let morsel = engine::try_run_parallel(&db, &p, 4, 4096).unwrap();
        assert!(serial.approx_eq_rows(&morsel.rows), "{sql}: morsel diverged");
        let id = svc.submit_sql(&db, sql).unwrap();
        let (rows, _) = svc.wait(id).unwrap();
        assert!(serial.approx_eq_rows(&rows), "{sql}: distributed diverged");
    }
    // The measurability case: unoptimized, `DATE '..' + 90` stays a
    // post-scan compare — no derived intervals, nothing prunes. The
    // optimizer folds the constant, pushes the compare into the scan
    // predicate, and the zone maps over the date-sorted lineitem skip
    // whole chunks.
    let sql = adhoc[2];
    let raw = plan_sql_unoptimized(sql).unwrap();
    let opt = plan_sql(sql).unwrap();
    assert!(plan::derived_intervals(&raw).is_empty(), "raw plan should derive nothing");
    assert!(!plan::derived_intervals(&opt).is_empty(), "optimized plan should derive a range");
    let a = engine::try_run_serial(&db, &raw).unwrap();
    let b = engine::try_run_serial(&db, &opt).unwrap();
    assert!(a.approx_eq_rows(&b.rows), "optimization changed the rows");
    assert_eq!(a.stats.morsels_pruned, 0, "no intervals -> nothing to prune");
    assert!(b.stats.morsels_pruned > 0, "pushdown must unlock zone-map pruning");
    assert!(
        b.stats.bytes_scanned < a.stats.bytes_scanned,
        "pruned run must touch fewer bytes ({} vs {})",
        b.stats.bytes_scanned,
        a.stats.bytes_scanned
    );
}

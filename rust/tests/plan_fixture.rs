//! Golden wire-format fixture: pins the [`LogicalPlan`] codec across
//! PRs.
//!
//! `fixtures/q6_plan.bin` holds the encoded default-parameter Q6 plan as
//! some past commit produced it. This test decodes it, checks it is
//! byte- and structure-identical to what the registry builds today, and
//! **runs** it against the serial reference — so an accidental codec
//! change (reordered field, changed tag, new mandatory byte) fails CI
//! before it strands a mixed-version cluster whose leader and workers
//! disagree on the wire layout.
//!
//! Intentional format migrations regenerate the fixture with
//! `LOVELOCK_BLESS=1 cargo test --test plan_fixture` and commit the new
//! bytes alongside the codec change.

use lovelock::analytics::engine::{self, LogicalPlan, PlanParams};
use lovelock::analytics::{queries, TpchConfig, TpchDb};

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/q6_plan.bin");

#[test]
fn golden_q6_plan_decodes_and_runs() {
    let current = queries::build("q6", &PlanParams::default()).unwrap();
    if std::env::var("LOVELOCK_BLESS").is_ok() {
        std::fs::write(FIXTURE, current.encode()).expect("bless: cannot write fixture");
    }
    let bytes = std::fs::read(FIXTURE)
        .unwrap_or_else(|e| panic!("missing golden fixture {FIXTURE}: {e}"));

    // Stability gate 1: the frozen bytes still decode.
    let golden = LogicalPlan::decode(&bytes)
        .expect("golden plan no longer decodes — the wire format broke");

    // Stability gate 2: they decode to exactly today's q6, and today's
    // q6 encodes to exactly the frozen bytes (exact-inverse both ways).
    assert_eq!(
        golden, current,
        "fixture decodes to a different q6 than the registry builds; if the format \
         change is intentional, regenerate with LOVELOCK_BLESS=1 and commit the fixture"
    );
    assert_eq!(current.encode(), bytes, "encoder drifted from the frozen bytes");

    // Stability gate 3: decode-and-RUN — the golden plan executes and
    // reproduces the reference rows.
    let db = TpchDb::generate(TpchConfig::new(0.002, 2026));
    let out = engine::try_run_serial(&db, &golden).expect("golden plan failed to compile");
    let reference = queries::run_query(&db, "q6").unwrap();
    assert!(
        reference.approx_eq_rows(&out.rows),
        "golden plan rows diverged from the registry's q6"
    );
}

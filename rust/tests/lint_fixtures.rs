//! Integration tests for `lovelock lint`: every rule runs over a
//! committed good/bad fixture pair — the bad fixture must produce the
//! exact RULE-ID (including the seeded PR 3 endpoint-teardown deadlock
//! shape), the good fixture must be clean — plus a whole-tree smoke
//! test asserting the repo's own `rust/src` lints clean.
//!
//! Fixtures live in `rust/tests/fixtures/lint/` and are never
//! compiled; they are fed to [`lint_sources`] as text under virtual
//! paths chosen to land in each rule's file scope.

use lovelock::lint::{lint_sources, load_paths, Diag};

fn lint_fixture(virtual_path: &str, src: &str) -> Vec<Diag> {
    lint_sources(&[(virtual_path.to_string(), src.to_string())])
}

#[test]
fn lock_order_bad_detects_inversion_cycle_and_leaf_violation() {
    let diags = lint_fixture(
        "rust/src/coordinator/fixture_teardown.rs",
        include_str!("fixtures/lint/lock_order_bad.rs"),
    );
    assert!(!diags.is_empty());
    assert!(diags.iter().all(|d| d.rule == "lock-order"), "{diags:?}");
    // The PR 3 shape: sched held while a callee re-locks queries.
    assert!(diags.iter().any(|d| d.msg.contains("canonical order")), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("lock cycle")), "{diags:?}");
    // The monitor shape: last_heard held across dead.
    assert!(diags.iter().any(|d| d.msg.contains("leaf-only")), "{diags:?}");
}

#[test]
fn lock_order_good_is_clean() {
    let diags = lint_fixture(
        "rust/src/coordinator/fixture_teardown.rs",
        include_str!("fixtures/lint/lock_order_good.rs"),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn hot_path_bad_flags_direct_and_transitive_allocs() {
    let diags = lint_fixture(
        "rust/src/analytics/engine/mod.rs",
        include_str!("fixtures/lint/hot_path_bad.rs"),
    );
    assert_eq!(diags.len(), 2, "{diags:?}");
    assert!(diags.iter().all(|d| d.rule == "hot-path-alloc"), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("`.collect()`")), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("`.to_vec()`")), "{diags:?}");
    // Provenance names the root kernel in both cases.
    assert!(diags.iter().all(|d| d.msg.contains("root `fold_range`")), "{diags:?}");
}

#[test]
fn hot_path_good_is_clean() {
    let diags = lint_fixture(
        "rust/src/analytics/engine/mod.rs",
        include_str!("fixtures/lint/hot_path_good.rs"),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn wire_tags_bad_flags_dup_ghost_and_missing_default() {
    let diags = lint_fixture(
        "rust/src/coordinator/protocol.rs",
        include_str!("fixtures/lint/wire_tags_bad.rs"),
    );
    assert!(diags.iter().all(|d| d.rule == "wire-tag"), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("duplicate wire tag")), "{diags:?}");
    assert!(
        diags.iter().any(|d| d.msg.contains("METHOD_GHOST") && d.msg.contains("never matched")),
        "{diags:?}"
    );
    assert!(diags.iter().any(|d| d.msg.contains("no default arm")), "{diags:?}");
}

#[test]
fn wire_tags_good_is_clean() {
    let diags = lint_fixture(
        "rust/src/coordinator/protocol.rs",
        include_str!("fixtures/lint/wire_tags_good.rs"),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn no_panic_bad_flags_unwrap_panic_and_unproven_index() {
    let diags = lint_fixture(
        "rust/src/coordinator/service.rs",
        include_str!("fixtures/lint/no_panic_bad.rs"),
    );
    assert_eq!(diags.len(), 3, "{diags:?}");
    assert!(diags.iter().all(|d| d.rule == "no-panic-worker"), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("`.unwrap()`")), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("`panic!`")), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("unchecked slice index")), "{diags:?}");
}

#[test]
fn no_panic_good_is_clean() {
    let diags = lint_fixture(
        "rust/src/coordinator/service.rs",
        include_str!("fixtures/lint/no_panic_good.rs"),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn allow_without_reason_fails_the_meta_rule() {
    let src = "impl WorkerShared {\n    fn on_x(&self) -> u32 {\n        \
               // lint: allow(no-panic-worker)\n        self.v.get().expect(\"wired\")\n    }\n}\n";
    let diags = lint_fixture("rust/src/coordinator/service.rs", src);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].rule, "lint-allow");
}

/// The acceptance gate: the repo's own tree must lint clean — every
/// remaining unwrap/alloc/tag/lock finding is either fixed or carries
/// a reasoned allow / `// bound:` proof.
#[test]
fn repo_tree_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
    let sources = load_paths(&[root.to_string_lossy().into_owned()]).expect("read rust/src");
    assert!(sources.len() > 30, "suspiciously small tree: {} files", sources.len());
    let diags = lint_sources(&sources);
    assert!(
        diags.is_empty(),
        "repo tree must lint clean:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}

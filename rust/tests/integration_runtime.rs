//! Integration: PJRT runtime × AOT artifacts (requires `make artifacts`).
//!
//! These tests exercise the full L1→L2→L3 composition: Pallas kernels and
//! the JAX model, lowered to HLO text by python, loaded and executed from
//! Rust with Python out of the loop.

use lovelock::analytics::queries::q6;
use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::runtime::*;
use lovelock::training::driver::TrainDriver;

fn need_artifacts() -> bool {
    if artifacts_available() {
        true
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        false
    }
}

#[test]
fn engine_loads_and_reports_platform() {
    if !need_artifacts() {
        return;
    }
    let eng = Engine::cpu().unwrap();
    assert!(eng.platform().to_lowercase().contains("cpu"));
}

#[test]
fn matmul_artifact_matches_cpu() {
    if !need_artifacts() {
        return;
    }
    let eng = Engine::cpu().unwrap();
    let module = eng.load_module(artifact_path("matmul.hlo.txt")).unwrap();
    // a: 256x512, b: 512x384 (the shapes aot.py lowered).
    let a: Vec<f32> = (0..256 * 512).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
    let b: Vec<f32> = (0..512 * 384).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let out = module
        .execute(&[
            literal_f32(&a, &[256, 512]).unwrap(),
            literal_f32(&b, &[512, 384]).unwrap(),
        ])
        .unwrap();
    let got = to_f32(&out[0]).unwrap();
    assert_eq!(got.len(), 256 * 384);
    // Spot-check a few entries against a host matmul.
    for &(i, j) in &[(0usize, 0usize), (7, 11), (255, 383), (100, 200)] {
        let mut want = 0.0f64;
        for k in 0..512 {
            want += a[i * 512 + k] as f64 * b[k * 384 + j] as f64;
        }
        let g = got[i * 384 + j] as f64;
        assert!(
            (g - want).abs() <= 1e-3 * want.abs().max(1.0),
            "({i},{j}): {g} vs {want}"
        );
    }
}

#[test]
fn q6_artifact_matches_engine() {
    if !need_artifacts() {
        return;
    }
    // Real TPC-H data through the PJRT Q6 kernel vs the native engine.
    let db = TpchDb::generate(TpchConfig::new(0.01, 42));
    let native = q6::run(&db).rows[0][0].as_f64();

    let (ship, disc, qty, price) = q6::kernel_inputs(&db);
    let eng = Engine::cpu().unwrap();
    let module = eng.load_module(artifact_path("q6_scan.hlo.txt")).unwrap();
    const CHUNK: usize = 65536;
    let p = q6::Q6Params::default();
    let bounds = [
        p.date_lo as f32,
        p.date_hi as f32,
        p.disc_lo as f32,
        p.disc_hi as f32,
        p.qty_lt as f32,
    ];
    let mut total = 0f64;
    let n = ship.len();
    let mut off = 0;
    while off < n {
        let take = CHUNK.min(n - off);
        let mut s = vec![3.0e38f32; CHUNK]; // pad fails the date filter
        let mut d = vec![0f32; CHUNK];
        let mut q = vec![0f32; CHUNK];
        let mut x = vec![0f32; CHUNK];
        for i in 0..take {
            s[i] = ship[off + i] as f32;
            d[i] = disc[off + i] as f32;
            q[i] = qty[off + i] as f32;
            x[i] = price[off + i] as f32;
        }
        let out = module
            .execute(&[
                literal_f32(&s, &[CHUNK as i64]).unwrap(),
                literal_f32(&d, &[CHUNK as i64]).unwrap(),
                literal_f32(&q, &[CHUNK as i64]).unwrap(),
                literal_f32(&x, &[CHUNK as i64]).unwrap(),
                literal_f32(&bounds, &[5]).unwrap(),
            ])
            .unwrap();
        total += to_f32(&out[0]).unwrap()[0] as f64;
        off += take;
    }
    // f32 accumulation over ~100k rows: allow 0.1% relative error.
    let rel = (total - native).abs() / native.abs().max(1.0);
    assert!(rel < 1e-3, "pjrt {total} vs native {native} (rel {rel})");
}

#[test]
fn attention_artifact_runs() {
    if !need_artifacts() {
        return;
    }
    let eng = Engine::cpu().unwrap();
    let module = eng.load_module(artifact_path("attention.hlo.txt")).unwrap();
    let (b, h, s, d) = (2usize, 4usize, 128usize, 64usize);
    let n = b * h * s * d;
    let q: Vec<f32> = (0..n).map(|i| ((i % 23) as f32 - 11.0) * 0.05).collect();
    let out = module
        .execute(&[
            literal_f32(&q, &[b as i64, h as i64, s as i64, d as i64]).unwrap(),
            literal_f32(&q, &[b as i64, h as i64, s as i64, d as i64]).unwrap(),
            literal_f32(&q, &[b as i64, h as i64, s as i64, d as i64]).unwrap(),
        ])
        .unwrap();
    let got = to_f32(&out[0]).unwrap();
    assert_eq!(got.len(), n);
    // Causal row 0 attends only to itself → output row 0 == v row 0.
    for j in 0..d {
        assert!((got[j] - q[j]).abs() < 1e-4, "j={j}: {} vs {}", got[j], q[j]);
    }
    assert!(got.iter().all(|x| x.is_finite()));
}

#[test]
fn train_driver_loss_decreases() {
    if !need_artifacts() {
        return;
    }
    let mut driver = TrainDriver::load("tiny", 7).unwrap();
    driver.init(7).unwrap();
    driver.run(40, 10).unwrap();
    assert_eq!(driver.loss_log.len(), 4);
    let first = driver.loss_log[0].1;
    let last = driver.loss_log.last().unwrap().1;
    assert!(
        last < first - 0.3,
        "loss did not decrease: {first} -> {last}"
    );
    assert!(driver.accounting.steps == 40);
    // The §5.3 shape: host does almost nothing vs device compute.
    assert!(driver.accounting.host_cpu_frac() < 0.25);
}

#[test]
fn train_driver_deterministic_given_seed() {
    if !need_artifacts() {
        return;
    }
    let run = |seed: u64| {
        let mut d = TrainDriver::load("tiny", seed).unwrap();
        d.init(seed as i32).unwrap();
        d.run(10, 10).unwrap();
        d.loss_log.last().unwrap().1
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn checkpoint_roundtrip_and_chunking() {
    if !need_artifacts() {
        return;
    }
    let mut driver = TrainDriver::load("tiny", 5).unwrap();
    driver.init(5).unwrap();
    driver.run(3, 0).unwrap();
    let dir = std::env::temp_dir().join("lovelock-ckpt-test");
    std::fs::create_dir_all(&dir).unwrap();
    let mono = dir.join("mono.bin");
    let chunked = dir.join("chunked.bin");
    let b1 = driver.checkpoint(&mono, false).unwrap();
    let b2 = driver.checkpoint(&chunked, true).unwrap();
    assert_eq!(b1, b2);
    // Both policies must produce byte-identical snapshots.
    let a = std::fs::read(&mono).unwrap();
    let b = std::fs::read(&chunked).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len() as u64, b1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifact_is_clean_error() {
    let eng = Engine::cpu().unwrap();
    assert!(eng.load_module("artifacts/no-such-module.hlo.txt").is_err());
}

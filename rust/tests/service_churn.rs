//! Session-churn boundedness gate for the long-lived QueryService.
//!
//! An overload-hardened service is only as good as its steady state: a
//! leader that leaks a few hundred bytes per session — a retained map
//! entry, a growing trace, an unretired DRR session — dies not under
//! the storm but a week after it. This file installs a live-byte
//! allocator (same pattern as `gen_stream.rs`) and drives thousands of
//! complete submit → wait → retire cycles, each under a **fresh
//! session key**, then pins the heap high-water mark measured after
//! warmup: the remaining thousands of cycles must not raise it by more
//! than a small slack.
//!
//! Like the other allocator-instrumented gates this file keeps to a
//! single measured test: the allocator is process-wide and concurrent
//! sibling tests would pollute the peak.

use lovelock::analytics::{queries, TpchConfig, TpchDb};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{QueryService, ServiceConfig, SubmitOpts};
use lovelock::platform::n2d_milan;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// System allocator wrapper tracking live bytes and their peak.
struct PeakAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn on_grow(grew: usize) {
    let live = LIVE.fetch_add(grew, Ordering::Relaxed) + grew;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

// SAFETY: delegates verbatim to `System`; the additions are relaxed
// atomic arithmetic, which allocates nothing and cannot unwind.
unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                on_grow(new_size - layout.size());
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

#[test]
fn thousands_of_session_cycles_hold_the_heap_high_water() {
    const WARMUP: u64 = 64;
    const CYCLES: u64 = 2048;
    // Generous slack over the post-warmup peak: absorbs allocator noise,
    // hash-map resizes, and thread-pool scratch — but a per-cycle leak
    // of even ~4 KB across ~2000 cycles blows through it.
    const SLACK: usize = 8 << 20;

    let db = Arc::new(TpchDb::generate(TpchConfig::new(0.001, 321)));
    let svc = QueryService::with_config(
        cluster(2),
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    let serial = queries::run_query(&db, "q6").unwrap();
    let cycle = |session: u64| {
        let id = svc
            .submit_opts(&db, "q6", SubmitOpts { session, ..Default::default() })
            .unwrap();
        let (rows, _) = svc.wait(id).unwrap();
        assert!(serial.approx_eq_rows(&rows), "cycle {session} diverged");
        assert!(svc.retire(id), "cycle {session} could not retire");
    };
    // Warmup: fill pools, caches, and lazily-built state.
    for s in 0..WARMUP {
        cycle(s);
    }
    let baseline = PEAK.load(Ordering::Relaxed);
    for s in WARMUP..CYCLES {
        cycle(s);
    }
    let peak = PEAK.load(Ordering::Relaxed);
    assert!(
        peak <= baseline + SLACK,
        "heap high-water grew {} KB over {} post-warmup session cycles \
         (baseline {} KB, peak {} KB) — something retains per-session state",
        (peak - baseline) / 1024,
        CYCLES - WARMUP,
        baseline / 1024,
        peak / 1024,
    );
    assert_eq!(svc.live_queries(), 0);
    assert_eq!(svc.credits_in_flight(), 0);
}

fn cluster(n: usize) -> ClusterSpec {
    ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
}

//! Bounded-memory regression gate for the streaming TPC-H generator.
//!
//! The worker shard path never materializes a table: a full pass over
//! lineitem through [`for_each_lineitem_chunk`] must hold only one
//! reused chunk buffer plus O(1) walk state, whatever the scale factor.
//! This file installs a live-byte-tracking allocator and pins the
//! high-water mark of a streaming pass to a small constant — the
//! property that lets a memory-wimpy smart NIC generate (and scan) an
//! SF10 shard it could never hold as columns.
//!
//! Like `alloc_regression.rs`, this file keeps to a single measured
//! test: the allocator is process-wide, and concurrent sibling tests
//! would pollute the peak. (The SF1 variant is `#[ignore]`d — minutes
//! in debug builds — and measures the same way when run alone.)

use lovelock::analytics::tpch::{for_each_lineitem_chunk, lineitem_rows, TpchConfig};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// System allocator wrapper tracking live bytes and their peak.
struct PeakAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn on_grow(grew: usize) {
    let live = LIVE.fetch_add(grew, Ordering::Relaxed) + grew;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

// SAFETY: delegates verbatim to `System`; the additions are relaxed
// atomic arithmetic, which allocates nothing and cannot unwind.
unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                on_grow(new_size - layout.size());
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

/// Peak live-heap growth (bytes above entry level) across one full
/// 4096-row-chunk streaming pass at `sf`; also returns the row count.
fn streaming_peak_delta(sf: f64) -> (usize, usize) {
    let cfg = TpchConfig::new(sf, 77);
    let total = lineitem_rows(&cfg);
    let entry = LIVE.load(Ordering::Relaxed);
    PEAK.store(entry, Ordering::Relaxed);
    let mut rows = 0usize;
    for_each_lineitem_chunk(&cfg, 0, total, 4096, |c| rows += c.len());
    assert_eq!(rows, total, "stream dropped rows at sf {sf}");
    let peak = PEAK.load(Ordering::Relaxed);
    (peak.saturating_sub(entry), total)
}

#[test]
fn streaming_generation_stays_in_bounded_memory() {
    // SF 0.05 ≈ 300k lineitem rows — tens of MB as materialized
    // columns. The stream must stay within a budget that is both a
    // small absolute constant and far below the materialized footprint.
    let (delta, rows) = streaming_peak_delta(0.05);
    let materialized = rows * 100; // ~100 B/row across 15 columns
    let budget = 8 << 20;
    assert!(
        delta < budget,
        "streaming peak grew {delta} B over an {budget} B budget ({rows} rows)"
    );
    assert!(
        delta * 4 < materialized,
        "streaming peak {delta} B is not clearly below the ~{materialized} B a \
         materialized table would hold"
    );
}

#[test]
#[ignore = "SF 1 streams ~6M rows; minutes in debug — run with --ignored in release"]
fn sf1_streaming_generation_stays_in_bounded_memory() {
    // The same constant budget at SF 1: bounded memory means the peak
    // does not scale with the row count.
    let (delta, rows) = streaming_peak_delta(1.0);
    assert!(rows > 5_000_000, "SF1 should stream millions of rows, got {rows}");
    assert!(delta < 8 << 20, "SF1 streaming peak grew {delta} B, exceeding 8 MiB");
}

//! Overload acceptance suite for the hardened QueryService (DESIGN.md
//! §3g): admission control, per-query deadlines, fair scheduling, and
//! graceful degradation — composed with the chaos machinery.
//!
//! The headline test is the ISSUE's acceptance bar: a 10x closed-loop
//! overload *with a mid-map worker kill*, during which the service must
//!
//! * shed the excess **explicitly** (typed [`Submission::Shed`], ids
//!   polling as `Rejected`) — never buffer it;
//! * keep the leader's buffered-bytes peak under the configured
//!   watermark;
//! * return, for every *accepted* query, either serial-identical rows
//!   or a typed `Failed(Timeout)` — nothing else;
//! * balance the backpressure credit gate to zero afterwards.
//!
//! The satellite tests cover queued-deadline expiry and cross-session
//! fairness under sustained overload — behaviors the in-module unit
//! tests pin only in isolation.

use lovelock::analytics::{queries, TpchConfig, TpchDb};
use lovelock::coordinator::{
    AdmissionConfig, ChaosConfig, FailCause, KillPhase, QueryService, QueryStatus, ServiceConfig,
    SubmitOpts, Submission,
};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::platform::n2d_milan;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn db(sf: f64, seed: u64) -> Arc<TpchDb> {
    Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)))
}

fn cluster(n: usize) -> ClusterSpec {
    ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
}

/// The acceptance bar (see module docs). Dispatch capacity is 4; the
/// closed loop keeps 40 outstanding submissions — 10x — while chaos
/// kills worker 2 at its first mid-map frame, so admission, fair
/// queueing, deadline budgets, and lease/repair all run at once.
#[test]
fn ten_x_overload_with_a_mid_map_kill_degrades_gracefully() {
    let db = db(0.002, 4311);
    let watermark: u64 = 32 << 20;
    let svc = QueryService::with_config(
        cluster(4),
        ServiceConfig {
            threads: 2,
            heartbeat_ms: 10,
            lease_ms: 300,
            chaos: Some(ChaosConfig { seed: 0xBEEF, kill: Some((2, KillPhase::MidMap)) }),
            max_dispatched: 4,
            admission: AdmissionConfig {
                max_in_flight: 8,
                max_buffered_bytes: watermark,
                ..Default::default()
            },
            // Generous: repairs are meant to win; the deadline is the
            // typed escape hatch, not the expected outcome.
            default_deadline_ms: 60_000,
            ..ServiceConfig::default()
        },
    );
    // Serial ground truth per mix entry, computed once.
    let mix = ["q6", "q1", "q12"];
    let serial: HashMap<&str, _> =
        mix.iter().map(|q| (*q, queries::run_query(&db, q).unwrap())).collect();
    let offered_target = 120u32; // 10x the ~12 the capacity serves comfortably
    let concurrency = 40usize;
    let mut offered = 0u32;
    let mut shed = 0u32;
    let mut done = 0u32;
    let mut timeouts = 0u32;
    let mut inflight: Vec<(lovelock::coordinator::QueryId, &str)> = Vec::new();
    let hard_stop = Instant::now() + Duration::from_secs(120);
    while (offered < offered_target || !inflight.is_empty()) && Instant::now() < hard_stop {
        // Refill the closed loop.
        while offered < offered_target && inflight.len() < concurrency {
            let q = mix[offered as usize % mix.len()];
            let plan = lovelock::analytics::engine::spec(q).unwrap();
            offered += 1;
            let opts = SubmitOpts { session: offered as u64 % 7, ..Default::default() };
            match svc.try_submit_plan(&db, &plan, opts).unwrap() {
                Submission::Admitted(id) => inflight.push((id, q)),
                Submission::Shed { id, reason } => {
                    shed += 1;
                    // Shedding is explicit and typed, and sheds hold
                    // nothing: the id polls Rejected out of a bounded
                    // ring, and the reason names the gate.
                    assert_eq!(svc.poll(id), QueryStatus::Rejected);
                    assert!(reason.to_string().starts_with("overloaded:"), "{reason}");
                    assert!(svc.retire(id));
                    break; // gates closed — drain a little before refilling
                }
            }
        }
        // Sweep completions; every accepted query must end in exactly
        // serial rows or a typed timeout.
        let mut i = 0;
        while i < inflight.len() {
            let (id, q) = inflight[i];
            match svc.poll(id) {
                QueryStatus::Done => {
                    let (rows, _) = svc.wait(id).unwrap();
                    assert!(
                        serial[q].approx_eq_rows(&rows),
                        "{q} ({id}) diverged from serial rows under overload + kill"
                    );
                    done += 1;
                    svc.retire(id);
                    inflight.swap_remove(i);
                }
                QueryStatus::Failed(FailCause::Timeout) => {
                    timeouts += 1;
                    svc.retire(id);
                    inflight.swap_remove(i);
                }
                QueryStatus::Failed(FailCause::Error(e)) => {
                    panic!("{q} ({id}) failed untyped under overload: {e}")
                }
                QueryStatus::Cancelled | QueryStatus::Rejected | QueryStatus::Unknown => {
                    panic!("{q} ({id}) reached an impossible state")
                }
                QueryStatus::Queued
                | QueryStatus::Mapping { .. }
                | QueryStatus::Reducing { .. } => i += 1,
            }
        }
        // The memory watermark holds *while* overloaded, not just at
        // the end.
        assert!(
            svc.peak_buffered_bytes() <= watermark,
            "leader buffering {} exceeded the {} watermark",
            svc.peak_buffered_bytes(),
            watermark
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(inflight.is_empty(), "overload run hit the 120s hard stop with work stuck");
    assert_eq!(offered, offered_target);
    assert_eq!(done + timeouts + shed, offered, "every submission must be accounted");
    assert!(done > 0, "overload shed everything — no goodput at all");
    assert!(shed > 0, "40 outstanding vs 8 in-flight slots never tripped admission");
    assert_eq!(shed as u64, svc.shed_queries());
    // The kill really happened and was ridden out.
    assert!(svc.dead_workers() >= 1, "the mid-map kill never landed");
    // Nothing leaked: credits balanced, gauges drained.
    assert_eq!(svc.credits_in_flight(), 0, "overload + kill leaked a credit");
    assert_eq!(svc.live_queries(), 0);
    assert_eq!(svc.queued_queries(), 0);
    assert_eq!(svc.buffered_bytes(), 0);
    // And the service still serves cleanly afterwards.
    let id = svc.submit(&db, "q6").unwrap();
    let (rows, _) = svc.wait(id).unwrap();
    assert!(serial["q6"].approx_eq_rows(&rows), "service unusable after the storm");
}

/// A deadline must fire while a query is still *queued* — the fair
/// queue unlinks it, it never dispatches, and the slot math stays
/// intact.
#[test]
fn queued_queries_expire_to_typed_timeouts() {
    let db = db(0.005, 4313);
    let svc = QueryService::with_config(
        cluster(2),
        ServiceConfig {
            threads: 2,
            max_dispatched: 1,
            // Per-row morsels keep the front query folding long enough
            // that the one behind it is still queued when its deadline
            // lapses.
            morsel_rows: 1,
            ..ServiceConfig::default()
        },
    );
    let front = svc.submit(&db, "q18").unwrap();
    let doomed = svc
        .submit_with_deadline(&db, "q6", Duration::from_millis(1))
        .unwrap();
    // No monitor on this service: the lazy checks in poll/wait must
    // expire it.
    std::thread::sleep(Duration::from_millis(5));
    assert_eq!(svc.poll(doomed), QueryStatus::Failed(FailCause::Timeout));
    let err = svc.wait(doomed).unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");
    // The front query is untouched by its neighbor's expiry.
    let serial = queries::run_query(&db, "q18").unwrap();
    let (rows, _) = svc.wait(front).unwrap();
    assert!(serial.approx_eq_rows(&rows));
    assert_eq!(svc.queued_queries(), 0);
    assert_eq!(svc.live_queries(), 0);
    assert_eq!(svc.credits_in_flight(), 0);
}

/// Fairness under sustained overload: a tenant flooding the queue
/// cannot starve a light tenant — the light tenant's single query
/// dispatches within its first DRR turn, not after the flood.
#[test]
fn light_tenant_is_served_through_a_heavy_tenant_flood() {
    let db = db(0.005, 4317);
    let svc = QueryService::with_config(
        cluster(2),
        ServiceConfig {
            threads: 2,
            max_dispatched: 1,
            morsel_rows: 8,
            ..ServiceConfig::default()
        },
    );
    let heavy: Vec<_> = (0..8)
        .map(|_| {
            svc.submit_opts(&db, "q18", SubmitOpts { session: 1, ..Default::default() }).unwrap()
        })
        .collect();
    let light = svc
        .submit_opts(&db, "q6", SubmitOpts { session: 2, ..Default::default() })
        .unwrap();
    let serial_light = queries::run_query(&db, "q6").unwrap();
    let (rows, _) = svc.wait(light).unwrap();
    assert!(serial_light.approx_eq_rows(&rows));
    let light_seq = svc.dispatch_sequence(light).expect("light query must dispatch");
    assert!(
        light_seq <= 3,
        "light tenant starved behind the flood: dispatched #{light_seq} of 9"
    );
    let serial_heavy = queries::run_query(&db, "q18").unwrap();
    for id in heavy {
        let (rows, _) = svc.wait(id).unwrap();
        assert!(serial_heavy.approx_eq_rows(&rows), "heavy tenant lost work to fairness");
    }
    assert_eq!(svc.live_queries(), 0);
    assert_eq!(svc.credits_in_flight(), 0);
}

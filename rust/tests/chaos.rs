//! Chaos: deterministic fault-injection suite for the query service.
//!
//! Every test here is replayable: faults come from a seeded
//! [`FaultPlan`](lovelock::rpc::FaultPlan) (drop/duplicate/delay of the
//! Nth frame per method, per endpoint) plus explicit worker kills at a
//! named phase — no timing randomness decides *which* frames are
//! faulted. The invariants under test (DESIGN.md §3d):
//!
//! * **Correctness across kills** — killing a worker mid-map or
//!   mid-reduce, every registry query still returns serial-identical
//!   rows after re-execution on survivors.
//! * **Liveness** — random fault schedules never hang `wait()`: the
//!   query terminates Done or Failed within the repair bound.
//! * **No leaks** — backpressure credits balance to zero on every exit
//!   path (done, failed, cancelled, repaired).
//! * **Cancel vs. failure** — a cancel racing an in-flight repair
//!   settles to exactly one terminal state and the service stays
//!   usable.
//!
//! Seeds are fixed (0xC0FFEE for the acceptance runs, proptest_mini's
//! name-derived seed for the property) so CI failures reproduce locally
//! with a plain `cargo test --test chaos`.

use lovelock::analytics::{queries, TpchConfig, TpchDb, QUERY_NAMES};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{ChaosConfig, KillPhase, QueryService, QueryStatus, ServiceConfig};
use lovelock::platform::n2d_milan;
use lovelock::proptest_mini::{check_with_seed, int_range, PropResult};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn db(sf: f64, seed: u64) -> Arc<TpchDb> {
    Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)))
}

fn cluster(n: usize) -> ClusterSpec {
    ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
}

/// Chaos-run config: a generous lease so a fold (or a loaded CI
/// machine) can never outlive it and livelock the epoch counter, and a
/// fast heartbeat so kill detection stays cheap relative to the suite.
fn chaos_config(chaos: ChaosConfig) -> ServiceConfig {
    ServiceConfig {
        threads: 2,
        heartbeat_ms: 25,
        lease_ms: 600,
        chaos: Some(chaos),
        ..ServiceConfig::default()
    }
}

/// The acceptance bar: one service, worker 1 killed at `phase` by the
/// first triggering frame it receives, all nine registry queries run
/// through the survivors — each must reproduce its serial rows, and no
/// query may leak a backpressure credit.
///
/// All queries share the service on purpose: the kill lands during the
/// first query that sends the trigger frame, and every later query must
/// still run correctly against a cluster that *starts* with a dead
/// member (the `touches_dead` repair path, not just the stall path).
fn kill_survival_suite(phase: KillPhase) {
    let db = db(0.002, 4242);
    let svc = QueryService::with_config(
        cluster(4),
        chaos_config(ChaosConfig { seed: 0xC0FFEE, kill: Some((1, phase)) }),
    );
    let mut total_repairs = 0u32;
    for q in QUERY_NAMES {
        let serial = queries::run_query(&db, q).unwrap();
        let id = svc.submit(&db, q).unwrap();
        let (rows, report) = svc
            .wait(id)
            .unwrap_or_else(|e| panic!("{q} did not survive the {phase:?} kill: {e}"));
        assert!(
            serial.approx_eq_rows(&rows),
            "{q} diverged from serial rows across a {phase:?} kill"
        );
        total_repairs += report.repairs;
        assert_eq!(svc.credits_in_flight(), 0, "{q} leaked a backpressure credit");
    }
    assert!(total_repairs > 0, "the {phase:?} kill never forced a repair round");
    assert!(svc.dead_workers() >= 1, "the killed endpoint was never declared dead");
}

#[test]
fn all_queries_survive_a_mid_map_kill() {
    kill_survival_suite(KillPhase::MidMap);
}

#[test]
fn all_queries_survive_a_mid_reduce_kill() {
    kill_survival_suite(KillPhase::MidReduce);
}

/// Liveness property: for random chaos seeds (drops, duplicates, and
/// delays on every data-plane method of every endpoint, leader
/// included — no kill), `wait()` always terminates within the repair
/// bound: Done with serial-identical rows, or Failed. Afterward the
/// credit gate must be balanced. Polls with a wall-clock deadline far
/// above MAX_REPAIRS × lease so a hang is reported as a property
/// failure (with the shrunk seed), not a test timeout.
#[test]
fn prop_random_fault_schedules_never_hang_wait() {
    let db = db(0.001, 999);
    let serial = queries::run_query(&db, "q6").unwrap();
    // Each case spins a full service and may ride out several
    // lease-long stalls; cap the case count well below the
    // framework-default 128 (LOVELOCK_PROP_CASES still raises it).
    let cases = lovelock::proptest_mini::default_cases().clamp(4, 12);
    let result = check_with_seed(0x5EED, cases, &int_range(1, 1 << 48), |&seed| {
        let svc = QueryService::with_config(
            cluster(3),
            ServiceConfig {
                threads: 2,
                heartbeat_ms: 10,
                lease_ms: 150,
                chaos: Some(ChaosConfig { seed: seed as u64, kill: None }),
                ..ServiceConfig::default()
            },
        );
        let id = svc.submit(&db, "q6").map_err(|e| e.to_string())?;
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            match svc.poll(id) {
                QueryStatus::Done => {
                    let (rows, _) = svc.wait(id).map_err(|e| e.to_string())?;
                    if !serial.approx_eq_rows(&rows) {
                        return Err(format!("seed {seed}: rows diverged from serial"));
                    }
                    break;
                }
                // An unrecoverable schedule may legitimately fail after
                // MAX_REPAIRS rounds; the property is that it *settles*.
                QueryStatus::Failed(_) => break,
                QueryStatus::Unknown | QueryStatus::Cancelled | QueryStatus::Rejected => {
                    return Err(format!("seed {seed}: impossible status"));
                }
                QueryStatus::Queued
                | QueryStatus::Mapping { .. }
                | QueryStatus::Reducing { .. } => {
                    if Instant::now() > deadline {
                        return Err(format!("seed {seed}: wait() hung past the repair bound"));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        if svc.credits_in_flight() != 0 {
            return Err(format!("seed {seed}: backpressure credits leaked"));
        }
        Ok(())
    });
    if let PropResult::Failed { original, shrunk, message } = result {
        panic!(
            "chaos liveness failed: {message}\n  original seed: {original:?}\n  \
             shrunk seed: {shrunk:?}"
        );
    }
}

/// Cancel racing an in-flight re-execution: a worker is killed mid-map,
/// and while the monitor is detecting/repairing we cancel. Whichever
/// side wins, the query settles to exactly one terminal state, `wait()`
/// returns promptly, no credit leaks, and the service keeps serving.
#[test]
fn cancel_during_reexecution_settles_cleanly() {
    let db = db(0.002, 555);
    let svc = QueryService::with_config(
        cluster(3),
        ServiceConfig {
            threads: 2,
            heartbeat_ms: 10,
            lease_ms: 120,
            chaos: Some(ChaosConfig { seed: 0, kill: Some((1, KillPhase::MidMap)) }),
            ..ServiceConfig::default()
        },
    );
    let id = svc.submit(&db, "q1").unwrap();
    // Sleep past the lease so the kill has been detected and the repair
    // is (likely) in flight when the cancel lands. Both race outcomes
    // are legal; each is asserted below.
    std::thread::sleep(Duration::from_millis(160));
    let cancelled = svc.cancel(id);
    let res = svc.wait(id);
    if cancelled {
        assert!(res.is_err(), "cancelled query returned rows");
        assert_eq!(svc.poll(id), QueryStatus::Cancelled);
        // A second cancel of a terminal query is a no-op, not a
        // double-finalize.
        assert!(!svc.cancel(id));
    } else {
        // The repair finished (or failed) before the cancel: terminal
        // either way, and stays terminal.
        assert!(matches!(svc.poll(id), QueryStatus::Done | QueryStatus::Failed(_)));
    }
    assert_eq!(svc.credits_in_flight(), 0, "cancel/failure race leaked a credit");
    // The service survives the race: a fresh query on the remaining
    // live workers still reproduces serial rows.
    let serial = queries::run_query(&db, "q6").unwrap();
    let id2 = svc.submit(&db, "q6").unwrap();
    let (rows, _) = svc.wait(id2).unwrap();
    assert!(serial.approx_eq_rows(&rows), "service unusable after cancel/failure race");
}

/// Regression guard for the clean path: a default-config service (no
/// chaos, no lease tuning) must not engage any fault-tolerance
/// machinery — no monitor, no repairs, no dead endpoints, no "repair"
/// lines in the conversation trace.
#[test]
fn default_config_runs_without_fault_machinery() {
    let db = db(0.002, 777);
    let svc = QueryService::with_config(cluster(3), ServiceConfig::default());
    let id = svc.submit(&db, "q6").unwrap();
    let (rows, report) = svc.wait(id).unwrap();
    let serial = queries::run_query(&db, "q6").unwrap();
    assert!(serial.approx_eq_rows(&rows));
    assert_eq!(report.repairs, 0);
    assert_eq!(svc.dead_workers(), 0);
    assert!(
        svc.conversation(id).iter().all(|l| !l.contains("repair")),
        "clean run traced a repair"
    );
}

/// Lease monitor without chaos: heartbeats keep every worker's lease
/// fresh, so a clean query under an armed monitor completes with zero
/// repairs and zero dead endpoints (the stall repair is chaos-gated so
/// a slow CI box can't fail a healthy query).
#[test]
fn heartbeats_keep_live_workers_out_of_the_dead_set() {
    let db = db(0.002, 888);
    let svc = QueryService::with_config(
        cluster(3),
        ServiceConfig { threads: 2, heartbeat_ms: 10, lease_ms: 100, ..ServiceConfig::default() },
    );
    // Outlive several leases so expiry would have fired if heartbeats
    // were not refreshing `last_heard`.
    std::thread::sleep(Duration::from_millis(350));
    let id = svc.submit(&db, "q1").unwrap();
    let (rows, report) = svc.wait(id).unwrap();
    let serial = queries::run_query(&db, "q1").unwrap();
    assert!(serial.approx_eq_rows(&rows));
    assert_eq!(report.repairs, 0, "a healthy cluster repaired");
    assert_eq!(svc.dead_workers(), 0, "a heartbeating worker was declared dead");
}

/// Livelock regression: a fold that outlives the lease. A worker's
/// single dispatch core cannot answer pings mid-fold — they queue
/// behind the ExecuteRange — so before mid-fold Progress beats existed,
/// any fold longer than the lease got its endpoint declared dead and
/// its fragment endlessly re-executed (each re-execution also outliving
/// the lease): a livelock that burned every repair round and failed the
/// query. With beats at morsel boundaries the lease stays fresh for as
/// long as the fold genuinely makes progress.
///
/// Per-row morsels inflate a q18 fold far past the tiny lease on any
/// machine; should some future engine make even that fast, the test
/// degrades to trivially-true rather than flaky.
#[test]
fn long_folds_outliving_the_lease_are_not_livelocked() {
    let db = db(0.01, 779);
    let svc = QueryService::with_config(
        cluster(2),
        ServiceConfig {
            threads: 2,
            heartbeat_ms: 5,
            lease_ms: 100,
            morsel_rows: 1,
            ..ServiceConfig::default()
        },
    );
    let serial = queries::run_query(&db, "q18").unwrap();
    let id = svc.submit(&db, "q18").unwrap();
    let (rows, report) = svc.wait(id).unwrap_or_else(|e| {
        panic!("fold outliving the lease livelocked (re-execution storm): {e}")
    });
    assert!(serial.approx_eq_rows(&rows), "q18 diverged from serial rows");
    assert_eq!(report.repairs, 0, "progress beats must keep a folding worker leased");
    assert_eq!(svc.dead_workers(), 0, "a folding worker was declared dead");
    assert_eq!(svc.credits_in_flight(), 0);
}

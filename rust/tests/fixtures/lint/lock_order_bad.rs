//! Seeded reproduction of the PR 3 endpoint-teardown deadlock shape:
//! `submit` takes `queries` then `sched`; `teardown_endpoint` holds
//! `sched` while calling `retire_sessions`, which takes `queries` —
//! a cross-function inversion and a two-lock cycle. `beat` re-creates
//! the monitor-loop leaf-only violation (`last_heard` held across
//! `dead`). Never compiled: linted as text by `lint_fixtures.rs`
//! under the virtual path `rust/src/coordinator/fixture_teardown.rs`.

struct Leader {
    queries: Mutex<u32>,
    sched: Mutex<u32>,
    last_heard: Mutex<u32>,
    dead: Mutex<u32>,
}

impl Leader {
    fn submit(&self) {
        let q = self.queries.lock().unwrap();
        let s = self.sched.lock().unwrap();
        drop(s);
        drop(q);
    }

    fn teardown_endpoint(&self) {
        let s = self.sched.lock().unwrap();
        self.retire_sessions();
        drop(s);
    }

    fn retire_sessions(&self) {
        let q = self.queries.lock().unwrap();
        drop(q);
    }

    fn beat(&self) {
        let heard = self.last_heard.lock().unwrap();
        let dead = self.dead.lock().unwrap();
        drop(dead);
        drop(heard);
    }
}

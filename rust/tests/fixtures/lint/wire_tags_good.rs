//! The healthy protocol shape: unique tag values, every constant both
//! matched (decoded) and sent, and the dispatch ends in a rejecting
//! default. Never compiled: linted as text under the virtual path
//! `rust/src/coordinator/protocol.rs`.

pub const METHOD_PING: u32 = 1;
pub const METHOD_CAST: u32 = 2;

pub fn dispatch(m: u32) -> crate::Result<u32> {
    match m {
        METHOD_PING => Ok(1),
        METHOD_CAST => Ok(2),
        t => crate::bail!("unknown method tag {t:#x}"),
    }
}

pub fn send_all(out: &mut Vec<u32>) {
    out.push(METHOD_PING);
    out.push(METHOD_CAST);
}

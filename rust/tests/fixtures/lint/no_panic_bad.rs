//! Panics on a worker frame path: a `WorkerShared` handler that
//! unwraps and panics, calling a codec fn that indexes without a
//! proven bound. Never compiled: linted as text under the virtual
//! path `rust/src/coordinator/service.rs`, where `WorkerShared`
//! methods are no-panic roots.

impl WorkerShared {
    fn on_frame(&self, body: &[u8]) -> u32 {
        let first = decode(body);
        if first == 0 {
            panic!("zero tag");
        }
        self.slot.get().unwrap()
    }
}

fn decode(body: &[u8]) -> u32 {
    body[0] as u32
}

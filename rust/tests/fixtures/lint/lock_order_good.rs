//! The corrected teardown shape: every acquisition follows the
//! canonical order, the held guard is dropped before the call that
//! re-locks, and the leaf-only lock is snapshotted instead of held.
//! Never compiled: linted as text by `lint_fixtures.rs`.

struct Leader {
    queries: Mutex<u32>,
    sched: Mutex<u32>,
    last_heard: Mutex<u32>,
    dead: Mutex<u32>,
}

impl Leader {
    fn submit(&self) {
        let q = self.queries.lock().unwrap();
        let s = self.sched.lock().unwrap();
        drop(s);
        drop(q);
    }

    fn teardown_endpoint(&self) {
        let s = self.sched.lock().unwrap();
        drop(s);
        self.retire_sessions();
    }

    fn retire_sessions(&self) {
        let q = self.queries.lock().unwrap();
        drop(q);
    }

    fn beat(&self) {
        let heard = self.last_heard.lock().unwrap().clone();
        let dead = self.dead.lock().unwrap();
        drop(dead);
        drop(heard);
    }
}

//! The sanctioned kernel shape: write into a caller-provided buffer,
//! no owned storage constructed per call. Never compiled: linted as
//! text under the virtual path `rust/src/analytics/engine/mod.rs`.

pub fn fold_range(lo: usize, hi: usize, out: &mut [u32]) -> usize {
    let mut k = 0;
    for i in lo..hi {
        out[k] = i as u32;
        k += helper(i);
    }
    k
}

fn helper(i: usize) -> usize {
    (i & 1 == 0) as usize
}

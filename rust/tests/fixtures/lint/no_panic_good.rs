//! The panic-free worker shape: mutex poisoning propagates via
//! `.lock().unwrap()` (exempt by policy), hostile input returns a
//! typed error, and codec indexing carries a `// bound:` proof.
//! Never compiled: linted as text under the virtual path
//! `rust/src/coordinator/service.rs`.

impl WorkerShared {
    fn on_frame(&self, body: &[u8]) -> crate::Result<u32> {
        let g = self.state.lock().unwrap();
        let first = decode(body)?;
        Ok(first + *g)
    }
}

fn decode(body: &[u8]) -> crate::Result<u32> {
    crate::ensure!(!body.is_empty(), "empty frame");
    // bound: the ensure! above proves body is non-empty
    Ok(body[0] as u32)
}

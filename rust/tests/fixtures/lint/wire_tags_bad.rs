//! Wire-tag pathologies: `METHOD_DUP` collides with `METHOD_CAST`,
//! `METHOD_GHOST` is sent but never matched by any decoder, and the
//! dispatch match has no rejecting default arm. Never compiled:
//! linted as text under the virtual path
//! `rust/src/coordinator/protocol.rs`.

pub const METHOD_PING: u32 = 1;
pub const METHOD_CAST: u32 = 2;
pub const METHOD_DUP: u32 = 2;
pub const METHOD_GHOST: u32 = 9;

pub fn dispatch(m: u32) -> u32 {
    match m {
        METHOD_PING => 1,
        METHOD_CAST => 2,
    }
}

pub fn send_all(out: &mut Vec<u32>) {
    out.push(METHOD_PING);
    out.push(METHOD_CAST);
    out.push(METHOD_DUP);
    out.push(METHOD_GHOST);
}

//! A morsel kernel that allocates per call, directly (`.collect()`)
//! and through a helper it calls (`.to_vec()`). Never compiled:
//! linted as text under the virtual path
//! `rust/src/analytics/engine/mod.rs`, where `fold_range` is a
//! hot-path root.

pub fn fold_range(lo: usize, hi: usize, out: &mut Vec<f64>) -> f64 {
    let ids: Vec<usize> = (lo..hi).collect();
    let mut acc = 0.0;
    for i in ids {
        acc += helper(i, out);
    }
    acc
}

fn helper(i: usize, out: &mut Vec<f64>) -> f64 {
    let copy = out.to_vec();
    copy.get(i).copied().unwrap_or(0.0)
}

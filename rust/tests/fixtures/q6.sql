SELECT SUM(l_extendedprice * l_discount)
FROM lineitem
WHERE l_shipdate >= DATE '1994-01-01'
  AND l_shipdate < DATE '1995-01-01'
  AND l_discount >= 0.045
  AND l_discount < 0.075
  AND l_quantity < 24

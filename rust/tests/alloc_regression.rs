//! Allocation regression gate for the engine's hot path.
//!
//! The zero-allocation contract of the batch kernels: after warm-up
//! (scratch buffers at their high-water size, every group discovered),
//! folding a morsel through predicate evaluation ([`SelScratch`]
//! ping-pong), batched evaluation ([`EvalBatch`] columns), and the
//! batched `HashAgg::update_sel` performs **zero** heap allocations.
//! This is the property that lets wimpy smart-NIC cores spend their
//! cycles on column data instead of the allocator — and it is exactly
//! what a stray `Vec::new()` in a kernel would silently regress, so CI
//! runs this file in quick mode too (see `ci.sh`). The evaluators under
//! test are the ones [`lovelock::analytics::engine::plan::compile`]
//! generates from the serializable IR — the zero-allocation contract
//! holds for *plans as data*, not just hand-written closures.
//!
//! This file deliberately contains a single `#[test]`: the counting
//! allocator is process-wide, and a sibling test allocating concurrently
//! would make the measured window noisy. Cargo gives each integration
//! test file its own process, so the single-test-per-file rule is what
//! guarantees a quiet measurement.

use lovelock::analytics::engine::{self, TaskScratch};
use lovelock::analytics::ops::ExecStats;
use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::benchkit::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const MORSEL_ROWS: usize = 4096;

/// Fold `[0, n)` morsel-by-morsel into `agg`, returning rows folded.
fn fold_all(
    c: &engine::Compiled<'_>,
    width: usize,
    n: usize,
    agg: &mut engine::HashAgg,
    scr: &mut TaskScratch,
) -> ExecStats {
    let mut stats = ExecStats::default();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + MORSEL_ROWS).min(n);
        engine::fold_range(c, width, lo, hi, agg, scr, &mut stats);
        lo = hi;
    }
    stats
}

#[test]
fn steady_state_fold_allocates_nothing_per_morsel() {
    let db = TpchDb::generate(TpchConfig::new(0.01, 5));
    let n = db.lineitem.len();
    assert!(n > 4 * MORSEL_ROWS, "need several morsels for a meaningful steady state");

    // q6: selective three-conjunct predicate cascade, single group.
    // q1: near-full scan, 5 accumulator columns, 4 groups.
    for q in ["q6", "q1"] {
        let plan = engine::spec(q).unwrap();
        let (c, _prep) = engine::plan::compile(&db, &plan).unwrap();
        let width = plan.width();
        let mut agg = engine::agg_for(&c, width, n);
        let mut scr = TaskScratch::new();

        // Warm-up pass: sizes every scratch buffer to its high-water
        // mark and discovers every group this data set produces.
        let warm = fold_all(&c, width, n, &mut agg, &mut scr);
        assert!(warm.rows_in > 0, "{q}: warm-up folded nothing");

        // Measured pass over the same rows: the same morsels, the same
        // groups — by the zero-allocation contract, not one allocation.
        let before = CountingAlloc::allocations();
        let stats = fold_all(&c, width, n, &mut agg, &mut scr);
        let allocs = CountingAlloc::allocations() - before;
        let morsels = n.div_ceil(MORSEL_ROWS);
        assert_eq!(
            allocs, 0,
            "{q}: steady-state fold allocated {allocs} times over {morsels} morsels \
             ({} rows in)",
            stats.rows_in
        );

        // The fold still did real work (both passes folded every row).
        assert_eq!(stats.rows_in, warm.rows_in, "{q}: measured pass degenerated");
        let p = engine::finish_fold(agg, stats);
        assert!(!p.is_empty(), "{q}: fold produced no groups");
    }
}

//! Integration: coordinator × cluster × simnet × analytics — distributed
//! queries on simulated traditional vs Lovelock clusters, validating the
//! §5.2 argument inside the repo (not just the Fig. 4 arithmetic), plus
//! the message-native `QueryService` session API under concurrency.

use lovelock::analytics::engine::{self, LogicalPlan, PlanParams};
use lovelock::analytics::{queries, TpchConfig, TpchDb};
use lovelock::bigquery::{project, Breakdown};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{
    Backpressure, DistributedQuery, QueryService, QueryStatus, Scheduler, ServiceConfig, Task,
    TaskKind,
};
use lovelock::platform::{ipu_e2000, n2d_milan};
use lovelock::rpc::Dispatch;
use std::sync::Arc;

fn db() -> Arc<TpchDb> {
    Arc::new(TpchDb::generate(TpchConfig::new(0.01, 777)))
}

fn traditional(n: usize) -> ClusterSpec {
    ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
}

#[test]
fn distributed_results_match_local_across_clusters() {
    // Every query in the Figure-3 set, on traditional and Lovelock
    // clusters, must reproduce the single-node rows.
    let db = db();
    for (name, cluster) in [
        ("traditional", traditional(8)),
        ("lovelock-phi2", ClusterSpec::lovelock_e2000(&traditional(8), 2)),
        ("lovelock-phi3", ClusterSpec::lovelock_e2000(&traditional(8), 3)),
    ] {
        for q in lovelock::analytics::QUERY_NAMES {
            let local = queries::run_query(&db, q).unwrap();
            let dist = DistributedQuery::new(cluster.clone()).run(&db, q).unwrap();
            assert!(
                local.approx_eq_rows(&dist.rows),
                "{q} on {name} diverged from local execution"
            );
        }
    }
}

#[test]
fn morsel_path_matches_distributed_path() {
    // The local morsel executor and the distributed executor share the
    // same kernels; both must agree with each other (and the reference).
    let db = db();
    for q in lovelock::analytics::QUERY_NAMES {
        let local = lovelock::analytics::run_query_morsel(&db, q, 4, 8192).unwrap();
        let dist = DistributedQuery::new(traditional(4)).run(&db, q).unwrap();
        assert!(
            local.approx_eq_rows(&dist.rows),
            "{q}: morsel path diverged from distributed path"
        );
    }
}

#[test]
fn concurrent_sessions_match_serial_regardless_of_wait_order() {
    // The acceptance bar of the QueryService redesign: ≥4 simultaneous
    // TPC-H queries interleaving over one service's shared scheduler,
    // credits, and endpoints, each reproducing its serial rows no matter
    // the completion order.
    let db = db();
    let svc = QueryService::with_config(
        traditional(4),
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    let names = ["q1", "q6", "q18", "q5", "q12", "q14"];
    let ids: Vec<_> = names.iter().map(|q| svc.submit(&db, q).unwrap()).collect();
    // Interrogate the lifecycle while queries are in flight.
    for id in &ids {
        match svc.poll(*id) {
            QueryStatus::Mapping { .. }
            | QueryStatus::Reducing { .. }
            | QueryStatus::Done => {}
            other => panic!("{id}: unexpected status {other:?}"),
        }
    }
    // Wait in reverse submit order.
    for (q, id) in names.iter().zip(ids.iter()).rev() {
        let (rows, report) = svc.wait(*id).unwrap();
        let serial = queries::run_query(&db, q).unwrap();
        assert!(serial.approx_eq_rows(&rows), "{q} ({id}) diverged under concurrency");
        assert_eq!(report.workers, 4);
        assert!(report.control_bytes > 0, "{q}: no control frames charged");
    }
}

#[test]
fn service_reuse_across_batches_is_deterministic() {
    // The same service object serves successive batches; a query's rows
    // do not depend on what ran before it.
    let db = db();
    let svc = QueryService::new(traditional(3));
    let first = {
        let id = svc.submit(&db, "q3").unwrap();
        svc.wait(id).unwrap().0
    };
    for _ in 0..3 {
        let noise = svc.submit(&db, "q18").unwrap();
        let again = svc.submit(&db, "q3").unwrap();
        let rows = svc.wait(again).unwrap().0;
        let serial = queries::run_query(&db, "q3").unwrap();
        assert!(serial.approx_eq_rows(&rows));
        assert_eq!(rows.len(), first.len());
        svc.wait(noise).unwrap();
    }
}

#[test]
fn parameterized_ir_plans_match_serial_across_the_wire() {
    // The acceptance bar of the plans-as-data redesign, parameterized:
    // a LogicalPlan built at the leader with NON-default parameters,
    // encoded into the PlanFragment, decoded and compiled by workers
    // that never consult the registry, produces rows equal (within
    // approx_eq_rows) to the serial run of the same plan — for every
    // parameterized query.
    let db = db();
    let svc = QueryService::new(traditional(3));
    let overrides: &[(&str, &[(&str, &str)])] = &[
        ("q1", &[("cutoff", "1995-06-01")]),
        ("q3", &[("segment", "MACHINERY"), ("top", "5")]),
        ("q5", &[("region", "EUROPE"), ("date-lo", "1995-01-01"), ("date-hi", "1996-01-01")]),
        ("q6", &[("date-lo", "1995-01-01"), ("date-hi", "1996-01-01"), ("qty-lt", "30")]),
        ("q9", &[("color", "azure")]),
        ("q12", &[("modes", "AIR,RAIL")]),
        ("q14", &[("date-lo", "1994-03-01"), ("date-hi", "1994-04-01")]),
        ("q18", &[("qty-threshold", "250"), ("top", "50")]),
        ("q19", &[("modes", "AIR,REG AIR,TRUCK")]),
    ];
    assert_eq!(overrides.len(), lovelock::analytics::QUERY_NAMES.len());
    for (q, kvs) in overrides {
        let mut bag = PlanParams::new();
        for (k, v) in *kvs {
            bag.set(k, v);
        }
        let plan = queries::build(q, &bag).unwrap();
        let serial = engine::try_run_serial(&db, &plan).unwrap();
        let id = svc.submit_plan(&db, &plan).unwrap();
        let (rows, _) = svc.wait(id).unwrap();
        assert!(serial.approx_eq_rows(&rows), "{q}: parameterized wire plan diverged");
    }
}

#[test]
fn default_ir_plans_cross_path_equal() {
    // serial == morsel == distributed, all three driven from the same
    // encode→decode'd IR (the bytes that cross the fabric), for every
    // registered query.
    let db = db();
    let svc = QueryService::new(traditional(4));
    for q in lovelock::analytics::QUERY_NAMES {
        let plan = engine::spec(q).unwrap();
        let wire = LogicalPlan::decode(&plan.encode()).unwrap();
        assert_eq!(wire, plan, "{q}: codec not an exact inverse");
        let serial = engine::try_run_serial(&db, &wire).unwrap();
        let morsel = engine::try_run_parallel(&db, &wire, 4, 8192).unwrap();
        assert!(morsel.approx_eq_rows(&serial.rows), "{q}: morsel-from-IR diverged");
        let id = svc.submit_plan(&db, &wire).unwrap();
        let (rows, _) = svc.wait(id).unwrap();
        assert!(serial.approx_eq_rows(&rows), "{q}: dist-from-IR diverged");
    }
}

#[test]
fn lovelock_phi_reduces_network_phase() {
    // The §5.2 mechanism observed end-to-end: with φ=2 E2000s per Milan
    // server (200G vs 100G ports and twice the nodes), the simulated
    // shuffle+IO time of the same query drops by ≈4x.
    let db = db();
    let trad = traditional(8);
    let love2 = ClusterSpec::lovelock_e2000(&trad, 2);
    let rt = DistributedQuery::new(trad).run(&db, "q18").unwrap();
    let rl = DistributedQuery::new(love2).run(&db, "q18").unwrap();
    let net_t = rt.io_secs + rt.shuffle_secs;
    let net_l = rl.io_secs + rl.shuffle_secs;
    let gain = net_t / net_l;
    assert!(gain > 2.0, "network phase gain {gain:.2} < 2 (t={net_t:.4}s l={net_l:.4}s)");
}

#[test]
fn breakdown_feeds_fig4_model() {
    // Wire the measured distributed breakdown into the Fig. 4 projection:
    // a network-heavy workload must cross μ<1 somewhere in φ∈[2,6].
    let db = db();
    let r = DistributedQuery::new(traditional(8)).run(&db, "q18").unwrap();
    let (cpu, shuffle, io) = r.breakdown();
    let b = Breakdown { cpu, shuffle, storage_io: io };
    let mu6 = project(&b, 6.0, 4.7).mu();
    assert!(mu6 < 1.0, "even φ=6 does not win (breakdown cpu={cpu:.2})");
}

#[test]
fn scheduler_with_backpressure_executes_all_tasks() {
    // Leader/worker control plane over the real RPC endpoint with a
    // credit gate: all tasks complete, concurrency never exceeds credits.
    let ep = Dispatch::new()
        .on(1, |m: &lovelock::rpc::Message| {
            // Worker: "execute" the task by echoing its id.
            Ok(m.payload.clone())
        })
        .serve();
    let bp = Arc::new(Backpressure::new(4));
    let cluster = traditional(4);
    let mut sched = Scheduler::new(&cluster);
    let tasks: Vec<Task> = (0..64)
        .map(|id| Task { id, kind: TaskKind::Compute, est_secs: 0.01 })
        .collect();
    let placements = sched.place_all(&tasks).unwrap();
    let threads: Vec<_> = placements
        .into_iter()
        .map(|p| {
            let client = ep.client();
            let bp = bp.clone();
            std::thread::spawn(move || {
                assert!(bp.acquire());
                let resp = client.call(1, p.task_id.to_le_bytes().to_vec()).unwrap();
                bp.release();
                u64::from_le_bytes(resp[..8].try_into().unwrap())
            })
        })
        .collect();
    let mut ids: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..64).collect::<Vec<u64>>());
    assert!(bp.max_in_flight() <= 4);
}

#[test]
fn lovelock_cluster_cost_accounting_consistent_with_eq1() {
    // ClusterSpec's bottom-up cost sum reproduces Eq. 1 for bare nodes.
    let trad = traditional(16);
    for phi in [1u32, 2, 3] {
        let love = ClusterSpec::lovelock_e2000(&trad, phi);
        let ratio = trad.relative_cost(0.0) / love.relative_cost(0.0);
        let eq1 = 7.0 / phi as f64;
        assert!((ratio - eq1).abs() < 1e-9, "phi={phi}: {ratio} vs {eq1}");
    }
}

#[test]
fn e2000_cluster_has_more_aggregate_bandwidth_fewer_cores() {
    let trad = traditional(8);
    let love = ClusterSpec::lovelock_e2000(&trad, 3);
    assert!(love.aggregate_nic_gbps() > trad.aggregate_nic_gbps() * 5.9);
    assert!(love.total_vcpus() < trad.total_vcpus());
    assert_eq!(love.nodes[0].platform.name, ipu_e2000().name);
}

//! Integration: the analytics engine end to end — dbgen → queries →
//! profiles → contention model, i.e. the full Figure-3 pipeline.

use lovelock::analytics::profile::{profile_all, profile_query};
use lovelock::analytics::queries::{self, run_query, QUERY_NAMES};
use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::memsim::{full_occupancy, system_ratio};
use lovelock::platform::{ipu_e2000, n2d_milan, skylake_fig3};

fn db() -> TpchDb {
    // Large enough that per-query wall times dominate timer/alloc noise.
    TpchDb::generate(TpchConfig::new(0.01, 2026))
}

#[test]
fn every_query_matches_its_oracle_on_one_db() {
    // One shared database, all queries vs their independent naive oracles
    // — the strongest single correctness statement about the engine.
    let db = db();
    let checks: Vec<(&str, Vec<queries::Row>)> = vec![
        ("q1", queries::q1::naive(&db)),
        ("q3", queries::q3::naive(&db)),
        ("q5", queries::q5::naive(&db)),
        ("q6", queries::q6::naive(&db)),
        ("q9", queries::q9::naive(&db)),
        ("q12", queries::q12::naive(&db)),
        ("q14", queries::q14::naive(&db)),
        ("q18", queries::q18::naive(&db)),
        ("q19", queries::q19::naive(&db)),
    ];
    for (name, oracle) in checks {
        let out = run_query(&db, name).unwrap();
        assert!(
            out.approx_eq_rows(&oracle),
            "{name}: vectorized ({} rows) != oracle ({} rows)",
            out.rows.len(),
            oracle.len()
        );
    }
}

#[test]
fn figure3_pipeline_shape() {
    // Profiles → per-platform degradation. The paper's claims:
    //  * E2000 per-core slowdown is mild (8-26%);
    //  * x86 slowdown is severe (39-88%);
    //  * whole-system: Milan 1.9-9.2x of E2000, Skylake 2.1-4.5x.
    // Our engine + model won't match the absolute numbers of a
    // proprietary engine, but the ordering must hold per query and the
    // medians must land in plausible bands.
    let db = db();
    let profiles = profile_all(&db, 1.0);
    assert_eq!(profiles.len(), QUERY_NAMES.len());
    let e2000 = ipu_e2000();
    let milan = n2d_milan();
    let skylake = skylake_fig3();
    let mut milan_ratios = Vec::new();
    for p in &profiles {
        let w = p.workload();
        let drop_nic = full_occupancy(&e2000, &w).slowdown_frac;
        let drop_milan = full_occupancy(&milan, &w).slowdown_frac;
        let drop_sky = full_occupancy(&skylake, &w).slowdown_frac;
        assert!(
            drop_milan >= drop_nic,
            "{}: milan {drop_milan:.2} < nic {drop_nic:.2}",
            p.name
        );
        assert!(
            drop_sky >= drop_nic,
            "{}: skylake {drop_sky:.2} < nic {drop_nic:.2}",
            p.name
        );
        assert!(drop_nic < 0.45, "{}: nic drop {drop_nic:.2} too large", p.name);
        milan_ratios.push(system_ratio(&milan, &e2000, &w));
    }
    milan_ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = milan_ratios[milan_ratios.len() / 2];
    // The pure-CPU-bound ceiling is 224·1.55·0.65/16 ≈ 14.1; the median
    // must sit strictly below it (memory throttling visible) and above
    // parity. Debug builds inflate cpu_secs (unoptimized engine), pushing
    // ratios toward the ceiling — the calibrated release numbers are
    // produced by `cargo bench --bench fig3` (median ≈ 8, paper: 4.7).
    assert!(
        median > 1.5 && median < 14.05,
        "milan/e2000 median system ratio {median:.2} out of band"
    );
}

#[test]
fn query_times_scale_with_sf() {
    let small = TpchDb::generate(TpchConfig::new(0.002, 5));
    let big = TpchDb::generate(TpchConfig::new(0.008, 5));
    let t_small = run_query(&small, "q1").unwrap().stats.bytes_scanned;
    let t_big = run_query(&big, "q1").unwrap().stats.bytes_scanned;
    let ratio = t_big as f64 / t_small as f64;
    assert!(ratio > 3.0 && ratio < 5.0, "bytes ratio {ratio}");
}

#[test]
fn profile_bytes_exceed_table_scan_for_joins() {
    let db = db();
    let q5 = profile_query(&db, "q5", 1.0).unwrap();
    let q6 = profile_query(&db, "q6", 1.0).unwrap();
    // Join queries move more bytes and hold bigger working sets.
    assert!(q5.working_set_bytes > q6.working_set_bytes);
}

#[test]
fn q6_is_lowest_intensity_scan() {
    // The paper's Q6 exception: a compute-bound scan. In our engine it
    // must have the smallest bytes-per-run of the full-scan queries.
    let db = db();
    let q1 = profile_query(&db, "q1", 1.0).unwrap();
    let q6 = profile_query(&db, "q6", 1.0).unwrap();
    let q18 = profile_query(&db, "q18", 1.0).unwrap();
    assert!(q6.dram_bytes < q1.dram_bytes);
    assert!(q6.dram_bytes < q18.dram_bytes);
}

//! Property-based tests (via `proptest_mini`) on coordinator, simulator,
//! and model invariants.

use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{DistributedQuery, Scheduler, Task, TaskKind};
use lovelock::costmodel::CostModel;
use lovelock::memsim::{simulate, WorkloadProfile};
use lovelock::platform::{ipu_e2000, n2d_milan};
use lovelock::proptest_mini::*;
use lovelock::simnet::{Simulation, Topology};

#[test]
fn prop_maxmin_rates_never_exceed_link_capacity() {
    // Any flow set: per-flow goodput ≤ host line rate, and the sum into
    // any receiver ≤ its down-link.
    let strat = vec_of(
        pair_of(int_range(0, 7), pair_of(int_range(0, 7), int_range(1, 200))),
        1,
        24,
    );
    check("maxmin_capacity", &strat, |flows| {
        let mut sim = Simulation::new(Topology::flat(8, 100.0));
        for (src, (dst, mb)) in flows {
            sim.add_flow(*src as usize, *dst as usize, *mb as f64 * 1e6, 0.0);
        }
        let done = sim.run();
        for d in &done {
            if d.duration() > 1e-9 && d.bytes > 0.0 {
                let gbps = d.gbps();
                if gbps > 100.0 + 1e-6 {
                    return Err(format!("flow exceeded line rate: {gbps}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flow_conservation() {
    // Every queued flow completes exactly once, with finish ≥ start.
    let strat = vec_of(
        pair_of(int_range(0, 5), pair_of(int_range(0, 5), int_range(0, 100))),
        1,
        20,
    );
    check("flow_conservation", &strat, |flows| {
        let mut sim = Simulation::new(Topology::new(2, 3, 100.0, 150.0));
        let mut ids = Vec::new();
        for (i, (src, (dst, mb))) in flows.iter().enumerate() {
            ids.push(sim.add_flow(
                *src as usize,
                *dst as usize,
                *mb as f64 * 1e6,
                (i % 3) as f64 * 0.1,
            ));
        }
        let done = sim.run();
        if done.len() != ids.len() {
            return Err(format!("{} queued, {} completed", ids.len(), done.len()));
        }
        for d in &done {
            if d.finish < d.start - 1e-9 {
                return Err(format!("flow {} finished before start", d.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_memsim_slowdown_monotone_in_occupancy() {
    let strat = pair_of(float_range(0.1, 4.0), float_range(0.5, 16.0));
    check("memsim_monotone", &strat, |(cpu_secs, gb)| {
        let w = WorkloadProfile {
            cpu_secs: *cpu_secs,
            dram_bytes: gb * 1e9,
            working_set_bytes: 32e6,
        };
        for p in [ipu_e2000(), n2d_milan()] {
            let mut last = f64::INFINITY;
            for k in [1u32, 2, 4, 8, p.vcpus / 2, p.vcpus] {
                let r = simulate(&p, &w, k.max(1));
                if r.per_core_rate > last + 1e-9 {
                    return Err(format!("{}: rate increased at k={k}", p.name));
                }
                last = r.per_core_rate;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_bounds() {
    // 0 < ratios < c_s+c_p for any sane (φ, μ); monotone decreasing in φ.
    let strat = pair_of(float_range(0.5, 8.0), float_range(0.3, 3.0));
    check("cost_bounds", &strat, |(phi, mu)| {
        let m = CostModel::host_only().with_pcie_share(0.6);
        let c = m.cost_ratio(*phi);
        let p = m.power_ratio(*phi, *mu);
        if !(c > 0.0 && c.is_finite() && p > 0.0 && p.is_finite()) {
            return Err(format!("bad ratios c={c} p={p}"));
        }
        let c2 = m.cost_ratio(*phi + 0.5);
        if c2 >= c {
            return Err("cost not decreasing in phi".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_conserves_tasks_and_respects_roles() {
    let strat = vec_of(int_range(0, 2), 1, 60);
    check("scheduler_roles", &strat, |kinds| {
        let mut cluster = ClusterSpec::traditional(6, n2d_milan(), Role::LiteCompute);
        cluster.nodes[0].role = Role::Storage { devices: 2 };
        cluster.nodes[1].role = Role::Accelerator { count: 1 };
        let mut sched = Scheduler::new(&cluster);
        let tasks: Vec<Task> = kinds
            .iter()
            .enumerate()
            .map(|(id, k)| Task {
                id,
                kind: match k {
                    0 => TaskKind::Compute,
                    1 => TaskKind::StorageIo,
                    _ => TaskKind::AccelDispatch,
                },
                est_secs: 1.0,
            })
            .collect();
        let placements = sched.place_all(&tasks).ok_or("placement failed")?;
        if placements.len() != tasks.len() {
            return Err("task lost".into());
        }
        for (t, p) in tasks.iter().zip(&placements) {
            match t.kind {
                TaskKind::StorageIo if p.node_id != 0 => {
                    return Err(format!("storage task on node {}", p.node_id));
                }
                TaskKind::AccelDispatch if p.node_id != 1 => {
                    return Err(format!("accel task on node {}", p.node_id));
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dbgen_deterministic_and_fk_closed() {
    let strat = pair_of(int_range(1, 1000), int_range(1, 8));
    check("dbgen_fk", &strat, |(seed, scale)| {
        let sf = *scale as f64 * 0.0005;
        let a = TpchDb::generate(TpchConfig::new(sf, *seed as u64));
        let b = TpchDb::generate(TpchConfig::new(sf, *seed as u64));
        if a.lineitem.len() != b.lineitem.len() {
            return Err("nondeterministic lineitem count".into());
        }
        let n_orders = a.orders.len() as i64;
        for &ok in a.lineitem.col("l_orderkey").as_i64().iter().take(500) {
            if ok < 1 || ok > n_orders {
                return Err(format!("dangling orderkey {ok}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_q6_invariant_to_worker_count() {
    // Routing/partitioning invariance: any worker count gives the same
    // answer (the shuffle-conservation property).
    let db = TpchDb::generate(TpchConfig::new(0.002, 99));
    let reference = lovelock::analytics::run_query(&db, "q6").unwrap();
    let strat = int_range(1, 12);
    check("dist_q6_workers", &strat, |w| {
        let cluster = ClusterSpec::traditional(*w as usize, n2d_milan(), Role::LiteCompute);
        let r = DistributedQuery::new(cluster)
            .run(&db, "q6")
            .map_err(|e| e.to_string())?;
        if !reference.approx_eq_rows(&r.rows) {
            return Err(format!("diverged at {w} workers"));
        }
        Ok(())
    });
}

#[test]
fn prop_groupby_total_count_conserved() {
    use lovelock::analytics::ops::GroupBy;
    let strat = vec_of(int_range(-50, 50), 0, 400);
    check("groupby_conservation", &strat, |keys| {
        let mut g: GroupBy<1> = GroupBy::with_capacity(8);
        for &k in keys {
            g.update(k, [1.0]);
        }
        let total: u64 = g.groups.iter().map(|(_, _, c)| c).sum();
        if total != keys.len() as u64 {
            return Err(format!("{total} != {}", keys.len()));
        }
        let sum: f64 = g.groups.iter().map(|(_, s, _)| s[0]).sum();
        if (sum - keys.len() as f64).abs() > 1e-9 {
            return Err("sum mismatch".into());
        }
        Ok(())
    });
}

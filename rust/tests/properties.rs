//! Property-based tests (via `proptest_mini`) on coordinator, simulator,
//! and model invariants — including the exact-inverse property of every
//! frame codec in the leader↔worker wire protocol.

use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{DistributedQuery, Scheduler, Task, TaskKind};
use lovelock::costmodel::CostModel;
use lovelock::memsim::{simulate, WorkloadProfile};
use lovelock::platform::{ipu_e2000, n2d_milan};
use lovelock::proptest_mini::*;
use lovelock::simnet::{Simulation, Topology};

#[test]
fn prop_maxmin_rates_never_exceed_link_capacity() {
    // Any flow set: per-flow goodput ≤ host line rate, and the sum into
    // any receiver ≤ its down-link.
    let strat = vec_of(
        pair_of(int_range(0, 7), pair_of(int_range(0, 7), int_range(1, 200))),
        1,
        24,
    );
    check("maxmin_capacity", &strat, |flows| {
        let mut sim = Simulation::new(Topology::flat(8, 100.0));
        for (src, (dst, mb)) in flows {
            sim.add_flow(*src as usize, *dst as usize, *mb as f64 * 1e6, 0.0);
        }
        let done = sim.run();
        for d in &done {
            if d.duration() > 1e-9 && d.bytes > 0.0 {
                let gbps = d.gbps();
                if gbps > 100.0 + 1e-6 {
                    return Err(format!("flow exceeded line rate: {gbps}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flow_conservation() {
    // Every queued flow completes exactly once, with finish ≥ start.
    let strat = vec_of(
        pair_of(int_range(0, 5), pair_of(int_range(0, 5), int_range(0, 100))),
        1,
        20,
    );
    check("flow_conservation", &strat, |flows| {
        let mut sim = Simulation::new(Topology::new(2, 3, 100.0, 150.0));
        let mut ids = Vec::new();
        for (i, (src, (dst, mb))) in flows.iter().enumerate() {
            ids.push(sim.add_flow(
                *src as usize,
                *dst as usize,
                *mb as f64 * 1e6,
                (i % 3) as f64 * 0.1,
            ));
        }
        let done = sim.run();
        if done.len() != ids.len() {
            return Err(format!("{} queued, {} completed", ids.len(), done.len()));
        }
        for d in &done {
            if d.finish < d.start - 1e-9 {
                return Err(format!("flow {} finished before start", d.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_memsim_slowdown_monotone_in_occupancy() {
    let strat = pair_of(float_range(0.1, 4.0), float_range(0.5, 16.0));
    check("memsim_monotone", &strat, |(cpu_secs, gb)| {
        let w = WorkloadProfile {
            cpu_secs: *cpu_secs,
            dram_bytes: gb * 1e9,
            working_set_bytes: 32e6,
        };
        for p in [ipu_e2000(), n2d_milan()] {
            let mut last = f64::INFINITY;
            for k in [1u32, 2, 4, 8, p.vcpus / 2, p.vcpus] {
                let r = simulate(&p, &w, k.max(1));
                if r.per_core_rate > last + 1e-9 {
                    return Err(format!("{}: rate increased at k={k}", p.name));
                }
                last = r.per_core_rate;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_bounds() {
    // 0 < ratios < c_s+c_p for any sane (φ, μ); monotone decreasing in φ.
    let strat = pair_of(float_range(0.5, 8.0), float_range(0.3, 3.0));
    check("cost_bounds", &strat, |(phi, mu)| {
        let m = CostModel::host_only().with_pcie_share(0.6);
        let c = m.cost_ratio(*phi);
        let p = m.power_ratio(*phi, *mu);
        if !(c > 0.0 && c.is_finite() && p > 0.0 && p.is_finite()) {
            return Err(format!("bad ratios c={c} p={p}"));
        }
        let c2 = m.cost_ratio(*phi + 0.5);
        if c2 >= c {
            return Err("cost not decreasing in phi".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_conserves_tasks_and_respects_roles() {
    let strat = vec_of(int_range(0, 2), 1, 60);
    check("scheduler_roles", &strat, |kinds| {
        let mut cluster = ClusterSpec::traditional(6, n2d_milan(), Role::LiteCompute);
        cluster.nodes[0].role = Role::Storage { devices: 2 };
        cluster.nodes[1].role = Role::Accelerator { count: 1 };
        let mut sched = Scheduler::new(&cluster);
        let tasks: Vec<Task> = kinds
            .iter()
            .enumerate()
            .map(|(id, k)| Task {
                id,
                kind: match k {
                    0 => TaskKind::Compute,
                    1 => TaskKind::StorageIo,
                    _ => TaskKind::AccelDispatch,
                },
                est_secs: 1.0,
            })
            .collect();
        let placements = sched.place_all(&tasks).ok_or("placement failed")?;
        if placements.len() != tasks.len() {
            return Err("task lost".into());
        }
        for (t, p) in tasks.iter().zip(&placements) {
            match t.kind {
                TaskKind::StorageIo if p.node_id != 0 => {
                    return Err(format!("storage task on node {}", p.node_id));
                }
                TaskKind::AccelDispatch if p.node_id != 1 => {
                    return Err(format!("accel task on node {}", p.node_id));
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dbgen_deterministic_and_fk_closed() {
    let strat = pair_of(int_range(1, 1000), int_range(1, 8));
    check("dbgen_fk", &strat, |(seed, scale)| {
        let sf = *scale as f64 * 0.0005;
        let a = TpchDb::generate(TpchConfig::new(sf, *seed as u64));
        let b = TpchDb::generate(TpchConfig::new(sf, *seed as u64));
        if a.lineitem.len() != b.lineitem.len() {
            return Err("nondeterministic lineitem count".into());
        }
        let n_orders = a.orders.len() as i64;
        for &ok in a.lineitem.col("l_orderkey").as_i64().iter().take(500) {
            if ok < 1 || ok > n_orders {
                return Err(format!("dangling orderkey {ok}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_q6_invariant_to_worker_count() {
    // Routing/partitioning invariance: any worker count gives the same
    // answer (the shuffle-conservation property).
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(0.002, 99)));
    let reference = lovelock::analytics::run_query(&db, "q6").unwrap();
    let strat = int_range(1, 12);
    check("dist_q6_workers", &strat, |w| {
        let cluster = ClusterSpec::traditional(*w as usize, n2d_milan(), Role::LiteCompute);
        let r = DistributedQuery::new(cluster)
            .run(&db, "q6")
            .map_err(|e| e.to_string())?;
        if !reference.approx_eq_rows(&r.rows) {
            return Err(format!("diverged at {w} workers"));
        }
        Ok(())
    });
}

#[test]
fn prop_hashagg_total_count_conserved() {
    use lovelock::analytics::engine::HashAgg;
    let strat = vec_of(int_range(-50, 50), 0, 400);
    check("hashagg_conservation", &strat, |keys| {
        let mut g = HashAgg::with_capacity(1, 8);
        for &k in keys {
            g.update(k, &[1.0]);
        }
        let p = g.into_partial();
        let total: u64 = p.counts.iter().sum();
        if total != keys.len() as u64 {
            return Err(format!("{total} != {}", keys.len()));
        }
        let sum: f64 = p.accs.iter().sum();
        if (sum - keys.len() as f64).abs() > 1e-9 {
            return Err("sum mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_partial_codec_roundtrip() {
    // The shuffle wire codec: encode→decode is the identity on every
    // (width, groups) shape the engine can produce.
    use lovelock::analytics::engine::{HashAgg, Partial};
    let strat = pair_of(
        int_range(1, 5),
        vec_of(pair_of(int_range(-1000, 1000), float_range(-1e6, 1e6)), 0, 64),
    );
    check("partial_codec_roundtrip", &strat, |(width, rows)| {
        let w = *width as usize;
        let mut g = HashAgg::with_capacity(w, 8);
        for (k, v) in rows {
            let vals: Vec<f64> = (0..w).map(|j| v + j as f64).collect();
            g.update(*k, &vals);
        }
        let p = g.into_partial();
        let d = Partial::decode(&p.encode()).map_err(|e| e.to_string())?;
        if d.width != p.width || d.keys != p.keys || d.accs != p.accs || d.counts != p.counts {
            return Err(format!("roundtrip mismatch at width {w}, {} groups", p.len()));
        }
        Ok(())
    });
}

/// Build a short printable string from a generated integer (the
/// mini-framework has no string strategy; shrinking the int shrinks the
/// string toward empty).
fn int_to_name(v: i64) -> String {
    let n = (v.unsigned_abs() % 1000) as usize;
    format!("q{n}")
}

#[test]
fn prop_protocol_frame_codecs_roundtrip() {
    // Every frame codec of the query-service wire protocol is an exact
    // inverse: encode → decode is the identity on any field values, and
    // decode rejects one-byte truncations of any encoding.
    use lovelock::coordinator::protocol::{
        Ack, CancelQuery, ExecuteRange, Heartbeat, PartialFrame, Ping, PlanFragment, Progress,
        QueryId, ReduceCmd, ReleaseQuery, ResendPartition,
    };
    let strat = pair_of(
        pair_of(int_range(0, i64::MAX / 2), int_range(0, 5000)),
        vec_of(int_range(0, 1 << 30), 0, 24),
    );
    check("protocol_codecs", &strat, |((qid, small), list)| {
        let qid = QueryId(*qid as u64);
        let small_u = *small as u32;
        let u64s: Vec<u64> = list.iter().map(|&v| v as u64).collect();
        let u32s: Vec<u32> = list.iter().map(|&v| (v % (1 << 20)) as u32).collect();
        let bytes: Vec<u8> = list.iter().map(|&v| (v % 256) as u8).collect();

        let plan = PlanFragment {
            query_id: qid,
            name: int_to_name(*small),
            plan: bytes.clone(),
            workers: small_u % 128,
            morsel_rows: *small as u64,
            deadline_ms: *small as u64 * 11,
        };
        let exec = ExecuteRange {
            query_id: qid,
            worker: small_u,
            lo: u64s.first().copied().unwrap_or(0),
            hi: u64s.last().copied().unwrap_or(0),
            epoch: small_u % 97,
            route: u32s.clone(),
        };
        let ack = Ack {
            query_id: qid,
            worker: small_u,
            epoch: small_u % 89,
            map_ns: *small as u64 * 7,
            ht_bytes: *small as u64 * 31,
            morsels_pruned: *small as u64 * 3,
            part_bytes: u64s.clone(),
            error: if small % 2 == 0 { String::new() } else { int_to_name(*small) },
        };
        // The reduce expectation carries (sender, epoch) pairs — the
        // reducer's dedup key against re-executed duplicates.
        let expect: Vec<(u32, u32)> = u32s.iter().map(|&w| (w, w % 53)).collect();
        let red = ReduceCmd { query_id: qid, partition: small_u, expect };
        let part = PartialFrame {
            query_id: qid,
            partition: small_u,
            from_worker: small_u / 2,
            epoch: small_u % 61,
            reduce_ns: *small as u64,
            body: bytes,
        };
        let cancel = CancelQuery { query_id: qid };
        let ping = Ping { nonce: *small as u64 * 13 };
        let hb = Heartbeat { worker: small_u % 128, nonce: *small as u64 * 17 };
        let resend = ResendPartition {
            query_id: qid,
            worker: small_u % 128,
            partition: small_u % 127,
            to: small_u % 125,
        };
        let release = ReleaseQuery { query_id: qid };
        let progress = Progress {
            query_id: qid,
            endpoint: small_u % 128,
            worker: small_u % 127,
            epoch: small_u % 43,
        };

        macro_rules! roundtrip {
            ($ty:ident, $v:expr) => {{
                let enc = $v.encode();
                let dec = $ty::decode(&enc).map_err(|e| format!("{}: {e}", stringify!($ty)))?;
                if dec != $v {
                    return Err(format!("{} roundtrip mismatch", stringify!($ty)));
                }
                if !enc.is_empty() && $ty::decode(&enc[..enc.len() - 1]).is_ok() {
                    return Err(format!("{} accepted truncated frame", stringify!($ty)));
                }
            }};
        }
        roundtrip!(PlanFragment, plan);
        roundtrip!(ExecuteRange, exec);
        roundtrip!(Ack, ack);
        roundtrip!(ReduceCmd, red);
        roundtrip!(PartialFrame, part);
        roundtrip!(CancelQuery, cancel);
        roundtrip!(Ping, ping);
        roundtrip!(Heartbeat, hb);
        roundtrip!(ResendPartition, resend);
        roundtrip!(ReleaseQuery, release);
        roundtrip!(Progress, progress);
        Ok(())
    });
}

#[test]
fn prop_partition_then_merge_equals_merge_all() {
    // The distributed exchange invariant: partitioning every worker
    // partial by key, pre-merging per partition (worker order), and
    // merging the partition results must equal merging the raw partials
    // directly — bit-for-bit, since each key's contributions meet in the
    // same order on both routes.
    use lovelock::analytics::engine::{HashAgg, Merger, Partial};
    use std::collections::BTreeMap;
    let strat = pair_of(
        int_range(1, 8),
        vec_of(pair_of(int_range(-40, 40), float_range(0.0, 100.0)), 0, 80),
    );
    check("partition_then_merge", &strat, |(parts, rows)| {
        let p_count = *parts as usize;
        // One "worker" partial per 10 rows.
        let mut partials: Vec<Partial> = Vec::new();
        for chunk in rows.chunks(10) {
            let mut g = HashAgg::with_capacity(2, 8);
            for (k, v) in chunk {
                g.update(*k, &[*v, 1.0]);
            }
            partials.push(g.into_partial());
        }
        // Route A: leader merges every raw partial.
        let mut direct = Merger::new(2);
        for p in &partials {
            direct.absorb(p).map_err(|e| e.to_string())?;
        }
        let direct = direct.into_partial();
        // Route B: hash-partition each partial, pre-merge per partition
        // in worker order, then merge the partition results.
        let mut per_part: Vec<Merger> = (0..p_count).map(|_| Merger::new(2)).collect();
        for p in &partials {
            for (pi, part) in p.partition_by_key(p_count).iter().enumerate() {
                per_part[pi].absorb(part).map_err(|e| e.to_string())?;
            }
        }
        let mut leader = Merger::new(2);
        for m in per_part {
            leader.absorb(&m.into_partial()).map_err(|e| e.to_string())?;
        }
        let exchanged = leader.into_partial();
        // Compare as key → (accs, count) maps (group order differs by
        // construction; contents must be exactly equal).
        let as_map = |p: &Partial| -> BTreeMap<i64, (Vec<u64>, u64)> {
            (0..p.len())
                .map(|i| {
                    let bits: Vec<u64> = p.acc(i).iter().map(|a| a.to_bits()).collect();
                    (p.keys[i], (bits, p.counts[i]))
                })
                .collect()
        };
        if as_map(&direct) != as_map(&exchanged) {
            return Err(format!(
                "exchange diverged: {} direct vs {} exchanged groups",
                direct.len(),
                exchanged.len()
            ));
        }
        Ok(())
    });
}

// --------------------------------------------------- logical-plan codec

/// Build a structurally rich [`LogicalPlan`] from a generated integer
/// vector: every predicate leaf kind, 0–3 joins (hash with link +
/// payloads, dense with cases), packed/year/payload keys, all compare
/// ops and output columns rotate in as the ints vary. The plan need not
/// *compile* — this drives the codec, whose domain is structure.
fn arb_plan(ints: &[i64]) -> lovelock::analytics::engine::LogicalPlan {
    use lovelock::analytics::engine::plan::*;
    let get = |i: usize| ints.get(i).copied().unwrap_or(0);
    let name = |i: usize| format!("c{}", get(i).unsigned_abs() % 40);
    let leaf = |k: i64, salt: i64| -> PredExpr {
        match k.rem_euclid(8) {
            0 => PredExpr::True,
            1 => i32_range("l_shipdate", salt as i32, salt as i32 ^ 77),
            2 => i32_col_lt("l_commitdate", "l_receiptdate"),
            3 => f64_range("l_discount", salt as f64 * 0.5, salt as f64),
            4 => f64_lt("l_quantity", salt as f64),
            5 => str_eq("l_shipmode", "MAIL"),
            6 => i32_in("c_nationkey", vec![salt as i32, 1, 2]),
            _ => por(vec![
                str_prefix("p_type", "PROMO"),
                str_contains("p_name", "gre"),
                str_in("p_container", &["SM BOX".to_string(), "LG BOX".to_string()]),
            ]),
        }
    };
    let width = (get(0).unsigned_abs() as usize % 5) + 1;
    let n_joins = get(1).unsigned_abs() as usize % 4;
    let joins: Vec<JoinStep> = (0..n_joins)
        .map(|j| {
            let salt = get(10 + j);
            let dense = salt.rem_euclid(3) == 0;
            JoinStep {
                table: match salt.rem_euclid(5) {
                    0 => TableRef::Orders,
                    1 => TableRef::Customer,
                    2 => TableRef::Supplier,
                    3 => TableRef::Part,
                    _ => TableRef::Partsupp,
                },
                dense,
                build_key: if dense {
                    None
                } else if salt.rem_euclid(2) == 0 {
                    Some(KeyCols::Col(name(11 + j)))
                } else {
                    Some(KeyCols::Packed {
                        a: name(11 + j),
                        shift: (salt.unsigned_abs() % 40) as u8,
                        b: name(12 + j),
                    })
                },
                probe_key: if dense || salt.rem_euclid(4) != 1 {
                    Some(KeyCols::Col("l_orderkey".into()))
                } else {
                    None
                },
                filter: leaf(salt, salt ^ 13),
                link: if !dense && j > 0 && salt.rem_euclid(5) == 2 {
                    Some(LinkRef { step: (j - 1) as u8, via: name(13 + j) })
                } else {
                    None
                },
                payloads: match salt.rem_euclid(4) {
                    0 => vec![],
                    1 => vec![Payload::Col(name(14 + j))],
                    2 => vec![
                        Payload::Flag { col: name(14 + j), m: StrMatch::Eq("X".into()) },
                        Payload::CaseConst {
                            cases: vec![(leaf(salt ^ 3, salt), salt as f64)],
                        },
                    ],
                    _ => vec![Payload::FromLink((salt.unsigned_abs() % 3) as u8)],
                },
            }
        })
        .collect();
    let v = |i: usize| -> ValExpr {
        match get(i).rem_euclid(4) {
            0 => vconst(get(i) as f64 * 0.25),
            1 => vcol("l_extendedprice"),
            2 => vpay((get(i).unsigned_abs() % 4) as u8, (get(i).unsigned_abs() % 3) as u8),
            _ => vmul(vcol("l_quantity"), vsub(vconst(1.0), vcol("l_discount"))),
        }
    };
    let ops = [CmpOp::Eq, CmpOp::Lt, CmpOp::Le, CmpOp::Ge, CmpOp::Gt];
    let cmps: Vec<CmpExpr> = (0..get(2).unsigned_abs() as usize % 3)
        .map(|i| cmp(v(20 + i), ops[(get(3).unsigned_abs() as usize + i) % 5], v(23 + i)))
        .collect();
    let key = match get(4).rem_euclid(4) {
        0 => kconst(get(4)),
        1 => kcol("l_orderkey"),
        2 => kyear(kpay(0, 0)),
        _ => kpack(kcol("l_returnflag"), (get(4).unsigned_abs() % 30) as u8, kcol("l_linestatus")),
    };
    let outcols = [
        OutCol::KeyInt { shift: 3, bits: 16 },
        OutCol::KeyChar { shift: 8 },
        OutCol::KeyNation { shift: 16, bits: 0 },
        OutCol::KeyDict { table: TableRef::Lineitem, col: "l_shipmode".into() },
        OutCol::Acc(0),
        OutCol::AccInt(0),
        OutCol::Count,
        OutCol::AccOverCount(0),
        OutCol::AccRatioPct(0, 0),
        OutCol::DimInt { table: TableRef::Orders, col: "o_custkey".into() },
        OutCol::DimFloat { table: TableRef::Orders, col: "o_totalprice".into() },
    ];
    let start = get(5).unsigned_abs() as usize % outcols.len();
    let ncols = get(6).unsigned_abs() as usize % 4 + 1;
    lovelock::analytics::engine::LogicalPlan {
        name: name(7),
        scan: TableRef::Lineitem,
        pred: pand(vec![leaf(get(8), get(8) ^ 5), leaf(get(9), get(9))]),
        joins,
        cmps,
        key,
        slots: (0..width).map(|i| v(30 + i)).collect(),
        groups_hint: if get(7).rem_euclid(2) == 0 {
            GroupsHint::Const(get(7).unsigned_abs() as u32)
        } else {
            GroupsHint::TableRows(TableRef::Orders)
        },
        finalize: FinalizeSpec {
            scalar: get(0).rem_euclid(2) == 0,
            columns: (0..ncols).map(|i| outcols[(start + i) % outcols.len()].clone()).collect(),
            having_gt: if get(1).rem_euclid(2) == 0 { None } else { Some((0, get(1) as f64)) },
            sort: vec![(0, if get(2).rem_euclid(2) == 0 { SortDir::Asc } else { SortDir::Desc })],
            limit: get(3).unsigned_abs() as u32 % 1000,
        },
    }
}

#[test]
fn prop_logical_plan_codec_roundtrip() {
    // The plans-as-data codec is an exact inverse over the randomized IR
    // space (all predicate leaves, 0–3 joins, widths 1..=MAX_ACCS), and
    // decode rejects every one-byte truncation.
    use lovelock::analytics::engine::LogicalPlan;
    let strat = vec_of(int_range(i64::MIN / 2, i64::MAX / 2), 0, 40);
    check("logical_plan_codec", &strat, |ints| {
        let plan = arb_plan(ints);
        let enc = plan.encode();
        let dec = LogicalPlan::decode(&enc).map_err(|e| e.to_string())?;
        if dec != plan {
            return Err("roundtrip mismatch".into());
        }
        if LogicalPlan::decode(&enc[..enc.len() - 1]).is_ok() {
            return Err("accepted truncated plan".into());
        }
        let mut padded = enc.clone();
        padded.push(0);
        if LogicalPlan::decode(&padded).is_ok() {
            return Err("accepted trailing garbage".into());
        }
        Ok(())
    });
}

#[test]
fn prop_plan_decode_never_panics_on_garbage() {
    // Hostile frames: whatever bytes arrive, decode returns (Ok or Err),
    // never panics, never recurses unboundedly.
    use lovelock::analytics::engine::LogicalPlan;
    let strat = vec_of(int_range(0, 255), 0, 200);
    check("plan_decode_garbage", &strat, |bytes| {
        let buf: Vec<u8> = bytes.iter().map(|&b| b as u8).collect();
        let _ = LogicalPlan::decode(&buf);
        Ok(())
    });
}

// ------------------------------------------------------ zone-map pruning

#[test]
fn prop_zone_pruning_is_invisible_in_results() {
    // Chunk pruning must be a pure optimization: for any conjunctive
    // window predicate over lineitem, the pruned and unpruned
    // compilations fold the same qualifying rows in the same order, so
    // the partials are *bit*-identical — and the pruned run never
    // charges more scan bytes.
    use lovelock::analytics::engine::{self, plan::*};
    let db = TpchDb::generate(TpchConfig::new(0.01, 23));
    let strat = pair_of(
        pair_of(int_range(8035, 10591), int_range(1, 2200)),
        pair_of(int_range(1, 55), int_range(0, 10)),
    );
    check("zone_pruning_equality", &strat, |((d0, span), (qhi, dhi))| {
        let plan = LogicalPlan {
            name: "prune-prop".into(),
            scan: TableRef::Lineitem,
            pred: pand(vec![
                i32_range("l_shipdate", *d0 as i32, (*d0 + *span) as i32),
                f64_lt("l_quantity", *qhi as f64),
                f64_range("l_discount", 0.0, *dhi as f64 * 0.01),
            ]),
            joins: vec![],
            cmps: vec![],
            key: kcol("l_returnflag"),
            slots: vec![vcol("l_extendedprice")],
            groups_hint: GroupsHint::Const(4),
            finalize: FinalizeSpec {
                scalar: false,
                columns: vec![OutCol::KeyChar { shift: 0 }, OutCol::Acc(0)],
                having_gt: None,
                sort: vec![(0, SortDir::Asc)],
                limit: 0,
            },
        };
        let (cp, _) = compile(&db, &plan).map_err(|e| e.to_string())?;
        let (cu, _) = compile_unpruned(&db, &plan).map_err(|e| e.to_string())?;
        if !cp.prune.is_active() {
            return Err("generated lineitem carries zones; pruning must arm".into());
        }
        if cu.prune.is_active() {
            return Err("compile_unpruned armed a prune plan".into());
        }
        let n = db.lineitem.len();
        let w = plan.width();
        let pp = engine::run_range(&cp, w, 0, n);
        let pu = engine::run_range(&cu, w, 0, n);
        if pp.keys != pu.keys || pp.counts != pu.counts {
            return Err(format!(
                "groups diverged: {} pruned vs {} unpruned",
                pp.len(),
                pu.len()
            ));
        }
        let bits = |p: &engine::Partial| -> Vec<u64> { p.accs.iter().map(|a| a.to_bits()).collect() };
        if bits(&pp) != bits(&pu) {
            return Err("accumulators diverged bitwise".into());
        }
        if pp.stats.bytes_scanned > pu.stats.bytes_scanned {
            return Err("pruned run charged more scan bytes than unpruned".into());
        }
        Ok(())
    });
}

#[test]
fn three_paths_agree_for_every_registry_query() {
    // Serial, morsel-parallel, and distributed (workers generating
    // their lineitem shards in place, zone maps armed) must return the
    // same rows for the whole registry.
    use lovelock::analytics::{run_query, run_query_morsel, QUERY_NAMES};
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(0.005, 5)));
    for q in QUERY_NAMES {
        let serial = run_query(&db, q).unwrap();
        let par = run_query_morsel(&db, q, 3, 1024).unwrap();
        assert!(par.approx_eq_rows(&serial.rows), "{q}: morsel diverged from serial");
        let cluster = ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
        let dist = DistributedQuery::new(cluster).run(&db, q).unwrap();
        assert!(serial.approx_eq_rows(&dist.rows), "{q}: distributed diverged from serial");
    }
}

#[test]
fn distributed_q6_and_q19_prune_morsels() {
    // The paper-default parameters carry real pruning power: Q6's date
    // window and Q19's derived quantity hull each rule out whole chunks
    // of the generator's date-sorted lineitem, and the workers' acks
    // surface the skip count through the report.
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(0.01, 42)));
    for q in ["q6", "q19"] {
        let cluster = ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
        let r = DistributedQuery::new(cluster).run(&db, q).unwrap();
        assert!(r.morsels_pruned > 0, "{q}: expected pruned chunks, report says 0");
    }
}

//! Bench `fig4` — regenerates Figure 4: BigQuery execution-time
//! projection under Lovelock, two ways:
//!
//! 1. the paper's arithmetic ([19] breakdown × Fig. 3 CPU ratio), and
//! 2. an end-to-end validation: the distributed q18 shuffle job measured
//!    on simulated traditional vs Lovelock clusters.

use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::benchkit::Bench;
use lovelock::bigquery::{cost_energy_for, figure4, project, Breakdown};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::DistributedQuery;
use lovelock::platform::n2d_milan;

fn main() {
    let mut b = Bench::new("Figure 4 — BigQuery projection (normalized to baseline = 1.0)");
    let br = Breakdown::isca23();
    for p in figure4(&br, &[2.0, 3.0], 4.7) {
        let label = if p.phi == 0.0 {
            "baseline".to_string()
        } else {
            format!("lovelock phi={}", p.phi)
        };
        let paper = if p.phi == 2.0 {
            " | paper mu=1.22"
        } else if p.phi == 3.0 {
            " | paper mu=0.81"
        } else {
            " | paper 1.00"
        };
        b.row(
            &label,
            format!("{:.2}", p.mu()),
            format!(
                "cpu {:.2} + shuffle {:.2} + io {:.2}{paper}",
                p.cpu, p.shuffle, p.storage_io
            ),
        );
    }
    for (phi, paper_cost, paper_energy) in [(2.0, 3.5, 4.58), (3.0, 2.33, 4.58)] {
        let mu = project(&br, phi, 4.7).mu();
        let (c, e) = cost_energy_for(phi, mu);
        b.row(
            &format!("cost/energy phi={phi}"),
            format!("{c:.2}x / {e:.2}x"),
            format!("paper {paper_cost:.2}x / {paper_energy:.2}x"),
        );
    }

    // End-to-end validation on the simulated clusters.
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(0.02, 4242)));
    let trad = ClusterSpec::traditional(8, n2d_milan(), Role::LiteCompute);
    let rt = DistributedQuery::new(trad.clone()).run(&db, "q18").unwrap();
    let base = rt.total_secs();
    let (cpu, shuffle, io) = rt.breakdown();
    b.row(
        "e2e q18 traditional",
        "1.00".to_string(),
        format!(
            "cpu {:.0}% shuffle {:.0}% io {:.0}%",
            cpu * 100.0,
            shuffle * 100.0,
            io * 100.0
        ),
    );
    for phi in [1u32, 2, 3] {
        let love = ClusterSpec::lovelock_e2000(&trad, phi);
        let rl = DistributedQuery::new(love).run(&db, "q18").unwrap();
        b.row(
            &format!("e2e q18 lovelock phi={phi}"),
            format!("{:.2}", rl.total_secs() / base),
            format!(
                "cpu {:.3}s net {:.3}s (trad net {:.3}s)",
                rl.compute_secs,
                rl.shuffle_secs + rl.io_secs,
                rt.shuffle_secs + rt.io_secs
            ),
        );
    }

    // Per-query shuffle intensity across the whole Figure-3 set: every
    // query now has a distributed plan; the shuffle-byte spread is what
    // makes q18 the Fig. 4 stress case.
    for q in lovelock::analytics::QUERY_NAMES {
        // q18 was already executed above for the baseline row.
        let r = if q == "q18" {
            rt.clone()
        } else {
            DistributedQuery::new(trad.clone()).run(&db, q).unwrap()
        };
        let (cpu, shuffle, io) = r.breakdown();
        b.row(
            &format!("dist {q} shuffle"),
            format!("{} KB", r.shuffle_bytes / 1000),
            format!(
                "cpu {:.0}% shuffle {:.0}% io {:.0}% ({} workers)",
                cpu * 100.0,
                shuffle * 100.0,
                io * 100.0,
                r.workers
            ),
        );
    }
    b.finish();
}

//! Bench `cost` — regenerates every §4/§5.2/§5.3 cost & energy scenario,
//! the fabric-cost extension, and a φ×μ sweep of Eq. 1/2.

use lovelock::benchkit::Bench;
use lovelock::costmodel::{sweep, CostModel, Scenario};

fn main() {
    let mut b = Bench::new("Cost & energy model — paper scenarios");
    let bare = CostModel::bare_bluefield();
    let lite = CostModel::host_only();
    let pcie = CostModel::host_only().with_pcie_share(0.75);
    let s53 = CostModel { c_s: 7.0, p_s: 11.2, c_p: 21.0, p_p: 33.2 };

    b.row(
        "bare phi=3 mu=1.2",
        format!("{:.2}x / {:.2}x", bare.cost_ratio(3.0), bare.power_ratio(3.0, 1.2)),
        "paper: 2.3x cheaper, 3.1x less energy (§4)",
    );
    b.row(
        "pcie phi=1 mu=1.0",
        format!("{:.2}x / {:.2}x", pcie.cost_ratio(1.0), pcie.power_ratio(1.0, 1.0)),
        "paper: 1.27x / 1.3x (§4)",
    );
    b.row(
        "pcie phi=2 mu=0.9",
        format!("{:.2}x / {:.2}x", pcie.cost_ratio(2.0), pcie.power_ratio(2.0, 0.9)),
        "paper: 1.22x / 1.4x (§4)",
    );
    b.row(
        "bigquery phi=2 mu=1.22",
        format!("{:.2}x / {:.2}x", lite.cost_ratio(2.0), lite.power_ratio(2.0, 1.22)),
        "paper: 3.5x / 4.58x (§5.2)",
    );
    b.row(
        "bigquery phi=3 mu=0.81",
        format!("{:.2}x / {:.2}x", lite.cost_ratio(3.0), lite.power_ratio(3.0, 0.81)),
        "paper: 2.33x / 4.58x (§5.2)",
    );
    b.row(
        "fabric c_f=0.7 phi=2",
        format!("{:.2}x", lite.cost_ratio_with_fabric(2.0, 0.7)),
        "paper: 2.26x (§5.2)",
    );
    b.row(
        "fabric c_f=0.7 phi=3",
        format!("{:.2}x", lite.cost_ratio_with_fabric(3.0, 0.7)),
        "paper: 1.51x (§5.2)",
    );
    b.row(
        "fabric speed @ mu=1.22",
        format!("{:.2}x", lite.required_fabric_speed(1.22)),
        "paper: fabric may be ~19% slower (§5.2)",
    );
    b.row(
        "fabric speed @ mu=0.81",
        format!("{:.2}x", lite.required_fabric_speed(0.81)),
        "paper: fabric must be ~23% faster (§5.2)",
    );
    b.row(
        "llm training phi=1",
        format!("{:.2}x / {:.2}x", s53.cost_ratio(1.0), s53.power_ratio(1.0, 1.0)),
        "paper: 1.27x / 1.30x (§5.3)",
    );
    b.row(
        "gnn phi=2 mu=0.9",
        format!("{:.2}x / {:.2}x", pcie.cost_ratio(2.0), pcie.power_ratio(2.0, 0.9)),
        "paper: 1.22x / 1.4x (§5.3)",
    );

    // φ × μ sweep (the design space the knobs expose).
    let scenarios: Vec<Scenario> = [1.0, 2.0, 3.0, 4.0]
        .iter()
        .flat_map(|&phi| [0.8, 1.0, 1.2].iter().map(move |&mu| Scenario { phi, mu }))
        .collect();
    for (s, c, p) in sweep(&bare, &scenarios) {
        b.row(
            &format!("sweep phi={} mu={}", s.phi, s.mu),
            format!("{c:.2}x / {p:.2}x"),
            "bare cluster Eq.1 / Eq.2",
        );
    }
    b.finish();
}

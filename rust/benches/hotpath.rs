//! Bench `hotpath` — microbenchmarks of the engine and coordinator hot
//! paths, used by the §Perf optimization loop (EXPERIMENTS.md §Perf).
//!
//! Emits `BENCH_hotpath.json` next to the working directory so the
//! speedup tables in EXPERIMENTS.md can be regenerated mechanically.
//!
//! The bench binary installs [`CountingAlloc`] as its global allocator
//! and reports **allocations per morsel** for the steady-state fold of
//! q6 and q1 — the zero-allocation contract of the batch kernels,
//! measured, not asserted (the `alloc_regression` test asserts it).

use lovelock::analytics::engine::{
    self, BatchEval, Compiled, EvalBatch, HashAgg, HashJoinTable, Merger, Predicate, PrunePlan,
    Sel, TaskScratch,
};
use lovelock::analytics::morsel::run_query_morsel;
use lovelock::analytics::tpch::{for_each_lineitem_chunk, lineitem_rows};
use lovelock::analytics::ops::{
    all_rows, filter_i32_range, hash_join, par_filter_i32_range, ExecStats,
};
use lovelock::analytics::{run_query, TpchConfig, TpchDb, QUERY_NAMES};
use lovelock::benchkit::{black_box, Bench, CountingAlloc};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::{
    ChaosConfig, DistributedQuery, KillPhase, QueryService, ServiceConfig,
};
use lovelock::platform::n2d_milan;
use lovelock::prng::Pcg64;
use lovelock::simnet::{Simulation, Topology};
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Scale-factor override for CI smoke runs (`LOVELOCK_BENCH_SF`,
/// `LOVELOCK_BENCH_SF_BIG`).
fn env_sf(var: &str, default: f64) -> f64 {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Steady-state allocations per morsel of a query's fold: warm one full
/// pass (scratch + groups reach high water), then count allocation
/// events across a second identical pass.
fn allocs_per_morsel(db: &TpchDb, q: &str, morsel_rows: usize) -> (f64, usize) {
    let plan = engine::spec(q).unwrap();
    let (c, _prep) = engine::plan::compile(db, &plan).unwrap();
    let width = plan.width();
    let n = db.lineitem.len();
    let mut agg = engine::agg_for(&c, width, n);
    let mut scr = TaskScratch::new();
    let mut fold = |agg: &mut HashAgg, scr: &mut TaskScratch| {
        let mut stats = ExecStats::default();
        let mut lo = 0;
        while lo < n {
            let hi = (lo + morsel_rows).min(n);
            engine::fold_range(&c, width, lo, hi, agg, scr, &mut stats);
            lo = hi;
        }
        stats.rows_in
    };
    fold(&mut agg, &mut scr); // warm-up pass
    let before = CountingAlloc::allocations();
    fold(&mut agg, &mut scr); // measured pass
    let allocs = CountingAlloc::allocations() - before;
    let morsels = n.div_ceil(morsel_rows).max(1);
    (allocs as f64 / morsels as f64, morsels)
}

fn main() {
    let mut b = Bench::new("hot paths");
    let db = Arc::new(TpchDb::generate(TpchConfig::new(env_sf("LOVELOCK_BENCH_SF", 0.02), 9)));
    let li_rows = db.lineitem.len() as u64;

    // Allocations per morsel, steady state (the tentpole metric of the
    // zero-allocation kernels; 0.00 is the contract).
    for q in ["q6", "q1", "q18"] {
        let (apm, morsels) = allocs_per_morsel(&db, q, 16_384);
        b.row(
            &format!("{q} allocs/morsel steady-state"),
            format!("{apm:.2}"),
            format!("counting allocator over {morsels} warm morsels"),
        );
    }

    // Full single-node queries (engine end to end), keeping each
    // query's scanned-bytes figure for the morsel rows below.
    let mut query_bytes = Vec::with_capacity(QUERY_NAMES.len());
    for q in QUERY_NAMES {
        let bytes = run_query(&db, q).unwrap().stats.bytes_scanned;
        query_bytes.push((q, bytes));
        b.measure_throughput(&format!("query {q}"), bytes, || {
            black_box(run_query(&db, q).unwrap());
        });
    }

    // Per-query morsel throughput at the default morsel size — the
    // batched-kernel rows the perf loop tracks query by query.
    for &(q, bytes) in &query_bytes {
        b.measure_throughput(&format!("{q} morsel x4"), bytes, || {
            black_box(run_query_morsel(&db, q, 4, 16_384).unwrap());
        });
    }

    // Morsel-driven vs single-threaded engine at SF 0.1 — the speedup
    // rows EXPERIMENTS.md §Morsel records. The morsel path must beat the
    // serial path at ≥4 threads.
    let big = TpchDb::generate(TpchConfig::new(env_sf("LOVELOCK_BENCH_SF_BIG", 0.1), 9));
    for q in ["q1", "q6", "q18"] {
        let bytes = run_query(&big, q).unwrap().stats.bytes_scanned;
        b.measure_throughput(&format!("{q} sf0.1 serial"), bytes, || {
            black_box(run_query(&big, q).unwrap());
        });
        for threads in [2usize, 4, 8] {
            b.measure_throughput(&format!("{q} sf0.1 morsel x{threads}"), bytes, || {
                black_box(run_query_morsel(&big, q, threads, 16_384).unwrap());
            });
        }
    }

    // Engine kernels: predicate eval (ping-pong scratch, branchless
    // leaves), compile+kernel, partition exchange.
    let q6 = engine::spec("q6").unwrap();
    let (c6, _) = engine::plan::compile(&db, &q6).unwrap();
    let mut scr6 = engine::SelScratch::new();
    b.measure_throughput("q6 eval_predicate", li_rows * 4, || {
        let mut st = ExecStats::default();
        black_box(c6.pred.eval_into(0, db.lineitem.len(), &mut scr6, &mut st).len());
    });
    let q18 = engine::spec("q18").unwrap();
    let (c18, _) = engine::plan::compile(&db, &q18).unwrap();
    let mut scr18 = TaskScratch::new();
    b.measure_throughput("q18 kernel (full range)", li_rows * 16, || {
        black_box(engine::run_range_scratch(&c18, q18.width(), 0, db.lineitem.len(), &mut scr18));
    });

    // Zone-map pruning: the same q6 fold with chunk skipping armed
    // (generated lineitem carries per-chunk min-max zones; q6's date
    // window rules most chunks out wholesale) vs the unpruned baseline.
    let (c6u, _) = engine::plan::compile_unpruned(&db, &q6).unwrap();
    {
        let mut scr = TaskScratch::new();
        let n = db.lineitem.len();
        let pruned = engine::run_range_scratch(&c6, q6.width(), 0, n, &mut scr);
        b.row(
            "q6 chunks pruned",
            format!(
                "{}/{}",
                pruned.stats.morsels_pruned,
                n.div_ceil(lovelock::analytics::CHUNK_ROWS)
            ),
            format!("{} scan bytes charged after pruning", pruned.stats.bytes_scanned),
        );
        b.measure("q6 scan pruned (zone maps)", || {
            black_box(engine::run_range_scratch(&c6, q6.width(), 0, n, &mut scr));
        });
        b.measure("q6 scan unpruned baseline", || {
            black_box(engine::run_range_scratch(&c6u, q6.width(), 0, n, &mut scr));
        });
    }

    // Streaming generator: lineitem rows/s through the bounded-memory
    // chunk stream (the worker shard path — no table materialization).
    {
        let total = lineitem_rows(&db.config);
        let mut rows = 0usize;
        b.measure("gen lineitem streaming (full pass)", || {
            rows = 0;
            for_each_lineitem_chunk(&db.config, 0, total, 4096, |c| rows += c.len());
            black_box(rows);
        });
        b.row(
            "gen lineitem streamed rows",
            format!("{rows}"),
            "4096-row chunks, one reused buffer".to_string(),
        );
    }

    // Plan-IR overhead: the IR-generated BatchEval vs a hand-written
    // closure over the same predicate + kernel (the pre-IR shape of
    // q6/q1) — the rows EXPERIMENTS.md §Morsel tracks to pin "plans as
    // data" at closure-speed. Only the evaluator differs: predicate,
    // fold, and aggregation are shared engine code on both sides.
    {
        let li = &db.lineitem;
        let n = li.len();
        let ship = li.col("l_shipdate").as_i32();
        let disc = li.col("l_discount").as_f64();
        let qty = li.col("l_quantity").as_f64();
        let price = li.col("l_extendedprice").as_f64();
        let q6p = lovelock::analytics::queries::q6::Q6Params::default();
        let pred = Predicate::and(vec![
            Predicate::i32_range(ship, q6p.date_lo, q6p.date_hi),
            Predicate::f64_range(disc, q6p.disc_lo, q6p.disc_hi),
            Predicate::f64_lt(qty, q6p.qty_lt),
        ]);
        let eval: BatchEval<'_> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
            rows.for_each(|i| {
                out.keys.push(0);
                out.cols[0].push(price[i] * disc[i]);
            });
        });
        let hand6 =
            Compiled { pred, payload_bytes: 8, eval, groups_hint: 1, prune: PrunePlan::none() };
        let bytes6 = run_query(&db, "q6").unwrap().stats.bytes_scanned;
        let mut scr = TaskScratch::new();
        b.measure_throughput("q6 fold hand-written", bytes6, || {
            black_box(engine::run_range_scratch(&hand6, 1, 0, n, &mut scr));
        });
        // Unpruned on both sides: this row pins IR overhead against the
        // hand-written closure, not the zone-map win measured above.
        b.measure_throughput("q6 fold plan-ir", bytes6, || {
            black_box(engine::run_range_scratch(&c6u, 1, 0, n, &mut scr));
        });

        let tax = li.col("l_tax").as_f64();
        let rf = li.col("l_returnflag").as_u8();
        let ls = li.col("l_linestatus").as_u8();
        let cutoff = lovelock::analytics::column::date_to_days(1998, 12, 1) - 90;
        let pred1 = Predicate::i32_range(ship, i32::MIN, cutoff + 1);
        let eval1: BatchEval<'_> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
            rows.for_each(|i| {
                let dp = price[i] * (1.0 - disc[i]);
                out.keys.push(((rf[i] as i64) << 8) | ls[i] as i64);
                out.cols[0].push(qty[i]);
                out.cols[1].push(price[i]);
                out.cols[2].push(dp);
                out.cols[3].push(dp * (1.0 + tax[i]));
                out.cols[4].push(disc[i]);
            });
        });
        let hand1 = Compiled {
            pred: pred1,
            payload_bytes: 8 * 4 + 2,
            eval: eval1,
            groups_hint: 8,
            prune: PrunePlan::none(),
        };
        let q1 = engine::spec("q1").unwrap();
        let (c1, _) = engine::plan::compile_unpruned(&db, &q1).unwrap();
        let bytes1 = run_query(&db, "q1").unwrap().stats.bytes_scanned;
        b.measure_throughput("q1 fold hand-written", bytes1, || {
            black_box(engine::run_range_scratch(&hand1, 5, 0, n, &mut scr));
        });
        b.measure_throughput("q1 fold plan-ir", bytes1, || {
            black_box(engine::run_range_scratch(&c1, 5, 0, n, &mut scr));
        });
    }

    // SQL front door: parse + bind + optimize latency for the q6 text —
    // the per-query planning cost an ad-hoc `sql`/`explain` invocation
    // pays before the engine ever sees a LogicalPlan. Planning is pure
    // string/IR work (no db), so this row is scale-factor independent.
    {
        let q6_sql = "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
             AND l_discount >= 0.045 AND l_discount < 0.075 AND l_quantity < 24";
        b.measure("sql parse+bind+optimize q6", || {
            black_box(lovelock::analytics::sql::plan_sql(q6_sql).unwrap());
        });
    }

    let p18 = engine::run_range(&c18, q18.width(), 0, db.lineitem.len());
    b.measure("q18 partition_by_key x8", || {
        black_box(p18.partition_by_key(8));
    });
    b.measure("q18 partition+merge x8", || {
        let parts = p18.partition_by_key(8);
        let mut m = Merger::new(q18.width());
        for p in &parts {
            m.absorb(p).unwrap();
        }
        black_box(m.into_partial().len());
    });

    // Operator microbenches.
    let ship = db.lineitem.col("l_shipdate").as_i32().to_vec();
    let sel = all_rows(ship.len());
    b.measure_throughput("filter_i32_range", li_rows * 4, || {
        black_box(filter_i32_range(&sel, &ship, 8766, 9131));
    });
    b.measure_throughput("par_filter_i32_range x4", li_rows * 4, || {
        black_box(par_filter_i32_range(&ship, 8766, 9131, 4, 16_384));
    });

    let mut rng = Pcg64::seed_from_u64(5);
    let build_keys: Vec<i64> = (0..200_000).map(|_| rng.gen_range_i64(0, 1 << 20)).collect();
    let probe_keys: Vec<i64> = (0..400_000).map(|_| rng.gen_range_i64(0, 1 << 20)).collect();
    let bsel = all_rows(build_keys.len());
    let psel = all_rows(probe_keys.len());
    b.measure_throughput("join build 200k", (build_keys.len() * 8) as u64, || {
        black_box(HashJoinTable::build(&build_keys, &bsel));
    });
    b.measure_throughput(
        "hash_join 200k/400k",
        ((build_keys.len() + probe_keys.len()) * 8) as u64,
        || {
            let mut stats = ExecStats::default();
            black_box(hash_join(&build_keys, &bsel, &probe_keys, &psel, &mut stats));
        },
    );

    // Row-at-a-time vs batched aggregation over the same key stream.
    let agg_keys: Vec<i64> = (0..500_000).map(|_| rng.gen_range_i64(0, 4096)).collect();
    let agg_c0: Vec<f64> = vec![1.0; agg_keys.len()];
    let agg_c1: Vec<f64> = vec![2.0; agg_keys.len()];
    b.measure_throughput("hashagg 500k/4096g row-at-a-time", (agg_keys.len() * 8) as u64, || {
        let mut g = HashAgg::with_capacity(2, 4096);
        for &k in &agg_keys {
            g.update(k, &[1.0, 2.0]);
        }
        black_box(g.len());
    });
    let mut gids = Vec::new();
    b.measure_throughput("hashagg 500k/4096g update_sel", (agg_keys.len() * 8) as u64, || {
        let mut g = HashAgg::with_capacity(2, 4096);
        let cols = [agg_c0.as_slice(), agg_c1.as_slice()];
        g.update_sel(&agg_keys, Sel::Range(0, agg_keys.len()), &cols, &mut gids);
        black_box(g.len());
    });

    // Fabric simulator: a 64-node all-to-all shuffle.
    b.measure("simnet 64-node all-to-all", || {
        let mut sim = Simulation::new(Topology::new(4, 16, 100.0, 800.0));
        for s in 0..64usize {
            for d in 0..64usize {
                if s != d {
                    sim.add_flow(s, d, 1e7, 0.0);
                }
            }
        }
        black_box(sim.run_makespan());
    });

    // Distributed query end to end (compute + codec + exchange + sim).
    let cluster = ClusterSpec::traditional(8, n2d_milan(), Role::LiteCompute);
    b.measure("distributed q1 (8 workers)", || {
        black_box(DistributedQuery::new(cluster.clone()).run(&db, "q1").unwrap());
    });
    b.measure("distributed q18 (8 workers)", || {
        black_box(DistributedQuery::new(cluster.clone()).run(&db, "q18").unwrap());
    });

    // QueryService session throughput: N simultaneous q6 submissions on
    // one long-lived service — the concurrency datapoint EXPERIMENTS.md
    // records (queries/s at --concurrency {1,4,8}).
    let svc = QueryService::with_config(cluster.clone(), ServiceConfig::default());
    for conc in [1usize, 4, 8] {
        let st = b.measure(&format!("service q6 x{conc} concurrent"), || {
            let ids: Vec<_> = (0..conc).map(|_| svc.submit(&db, "q6").unwrap()).collect();
            for id in ids {
                black_box(svc.wait(id).unwrap());
                svc.retire(id);
            }
        });
        b.row(
            &format!("service q6 x{conc} queries/s"),
            format!("{:.1}", conc as f64 / (st.median_ns / 1e9)),
            format!("median batch {:.2} ms", st.median_ns / 1e6),
        );
    }

    // Fault-tolerance recovery: q6 on a fresh 4-worker service whose
    // worker 1 is killed by its first ExecuteRange. The measured time
    // is lease expiry + repair + re-execution on a survivor — the
    // §Failure re-execution-overhead row of EXPERIMENTS.md (compare
    // against the clean distributed rows above). A tight lease keeps
    // the row about repair cost, not detection patience.
    let chaos_cluster = ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
    let st = b.measure("q6 recover after mid-map kill", || {
        let svc = QueryService::with_config(
            chaos_cluster.clone(),
            ServiceConfig {
                threads: 2,
                heartbeat_ms: 5,
                lease_ms: 60,
                chaos: Some(ChaosConfig { seed: 0, kill: Some((1, KillPhase::MidMap)) }),
                ..ServiceConfig::default()
            },
        );
        let id = svc.submit(&db, "q6").unwrap();
        let (rows, report) = svc.wait(id).unwrap();
        assert!(report.repairs > 0, "kill bench ran clean");
        black_box(rows.len());
    });
    b.row(
        "q6 mid-map kill detect+repair ms",
        format!("{:.1}", st.median_ns / 1e6),
        "fresh 4-worker service per run; 60 ms lease; includes re-execution".to_string(),
    );

    // dbgen throughput.
    b.measure("dbgen sf=0.01", || {
        black_box(TpchDb::generate(TpchConfig::new(0.01, 1)));
    });
    // CI smoke runs redirect the artifact so tiny-SF rows never clobber
    // a real measurement of BENCH_hotpath.json.
    let json_path = std::env::var("LOVELOCK_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    b.finish_json(&json_path);
}

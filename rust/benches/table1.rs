//! Bench `table1` — regenerates Table 1: network and DRAM bandwidth per
//! core across cloud hosts and smart NICs, from the platform catalog.

use lovelock::benchkit::Bench;
use lovelock::platform::table1_platforms;

fn main() {
    let mut b = Bench::new("Table 1 — bandwidth per vCPU (paper values in parentheses)");
    let paper_nic = [0.13, 0.06, 0.20, 0.14, 0.13, 1.56, 3.13];
    let paper_dram = [2.67, 1.83, 3.20, 3.49, 2.40, 6.40, 5.60];
    for (i, p) in table1_platforms().iter().enumerate() {
        b.row(
            &format!("{} nic/core", p.name),
            format!("{:.2} GB/s", p.nic_gbs_per_core()),
            format!("paper {:.2} GB/s | {} vcpus, {:.0}G NIC", paper_nic[i], p.vcpus, p.nic_gbps),
        );
        b.row(
            &format!("{} dram/core", p.name),
            format!("{:.2} GB/s", p.dram_gbs_per_core()),
            format!(
                "paper {:.2} GB/s | {}ch x {:.0} MT/s",
                paper_dram[i], p.mem_channels, p.mem_mtps
            ),
        );
    }
    // The §6 BlueField observation.
    let bf = lovelock::platform::bluefield_v3();
    b.row(
        "bluefield dram/nic ratio",
        format!("{:.2}x", bf.dram_gbs() / bf.nic_gbs()),
        "paper: ~1.8x (cannot process at line rate)",
    );
    b.finish();
}

//! Bench `loadgen` — the QueryService under offered overload (DESIGN.md
//! §3g). Three scenarios on the same 4-worker cluster:
//!
//! 1. closed loop at a sane multiprogramming level (the baseline the
//!    overload rows are read against);
//! 2. closed loop at 10x that level with the admission gates armed —
//!    the service must shed explicitly and keep p99 for what it admits;
//! 3. an open-loop Poisson stream with admission + per-query deadlines —
//!    overload shows up as shed rate and bounded leader buffering,
//!    never as queue growth.
//!
//! Writes `BENCH_service.json` (redirect with `LOVELOCK_BENCH_JSON`;
//! `LOVELOCK_BENCH_QUICK=1` shrinks scale factor and windows for CI
//! smoke runs). Numbers here are host-wall measurements of the real
//! message-driven service, not simulator projections.

use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::benchkit::Bench;
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::loadgen::{run_load, LoadMode, LoadSpec};
use lovelock::coordinator::{AdmissionConfig, QueryService, ServiceConfig};
use lovelock::platform::n2d_milan;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let quick = std::env::var("LOVELOCK_BENCH_QUICK").is_ok();
    let mut b = Bench::new("QueryService under overload (§3g load driver)");
    let sf = if quick { 0.001 } else { 0.01 };
    let window = Duration::from_millis(if quick { 300 } else { 2000 });
    let db = Arc::new(TpchDb::generate(TpchConfig::new(sf, 42)));
    let cluster = || ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
    let base_conc = 4;

    // 1. Baseline: closed loop the service comfortably sustains.
    let svc = QueryService::with_config(
        cluster(),
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    let rep = run_load(
        &svc,
        &db,
        &LoadSpec {
            mode: LoadMode::Closed { concurrency: base_conc },
            duration: window,
            ..LoadSpec::default()
        },
    )
    .expect("baseline load run");
    println!("baseline: {}", rep.summary());
    b.row("closed 1x qps", format!("{:.1}", rep.qps), rep.summary());
    b.row(
        "closed 1x p50/p99",
        format!("{:.2}/{:.2} ms", rep.p50_ms, rep.p99_ms),
        format!("{} completed, {} sessions", rep.completed, 1000),
    );
    let base_qps = rep.qps;

    // 2. 10x closed-loop overload, admission armed: in-flight gate a
    // little over the baseline level, so most of the extra offered load
    // is shed at the door instead of queued.
    let svc = QueryService::with_config(
        cluster(),
        ServiceConfig {
            threads: 2,
            max_dispatched: base_conc,
            admission: AdmissionConfig {
                max_in_flight: base_conc * 2,
                max_buffered_bytes: 64 << 20,
                ..Default::default()
            },
            ..ServiceConfig::default()
        },
    );
    let rep = run_load(
        &svc,
        &db,
        &LoadSpec {
            mode: LoadMode::Closed { concurrency: base_conc * 10 },
            duration: window,
            ..LoadSpec::default()
        },
    )
    .expect("10x overload run");
    println!("closed 10x: {}", rep.summary());
    b.row(
        "closed 10x qps",
        format!("{:.1}", rep.qps),
        format!("vs {base_qps:.1} baseline — goodput must not collapse"),
    );
    b.row("closed 10x p99", format!("{:.2} ms", rep.p99_ms), "of admitted queries");
    b.row(
        "closed 10x shed rate",
        format!("{:.1}%", rep.shed_rate * 100.0),
        format!("{} shed of {} offered, all explicit", rep.shed, rep.submitted),
    );
    b.row(
        "closed 10x peak leader buffer",
        format!("{} KB", rep.peak_buffered_bytes / 1000),
        "bounded by the buffered-bytes admission gate",
    );

    // 3. Open-loop Poisson stream at ~3x the baseline completion rate,
    // with deadlines: arrivals don't slow down for the service, so the
    // gap between offered and sustained shows up as shed + timeouts.
    let svc = QueryService::with_config(
        cluster(),
        ServiceConfig {
            threads: 2,
            max_dispatched: base_conc,
            admission: AdmissionConfig {
                max_in_flight: base_conc * 2,
                max_buffered_bytes: 64 << 20,
                ..Default::default()
            },
            ..ServiceConfig::default()
        },
    );
    let rep = run_load(
        &svc,
        &db,
        &LoadSpec {
            mode: LoadMode::Open { qps: (base_qps * 3.0).max(20.0) },
            duration: window,
            deadline: Some(Duration::from_secs(5)),
            ..LoadSpec::default()
        },
    )
    .expect("open-loop run");
    println!("open 3x: {}", rep.summary());
    b.row(
        "open 3x shed rate",
        format!("{:.1}%", rep.shed_rate * 100.0),
        format!("{} shed, {} timeout of {} offered", rep.shed, rep.timeouts, rep.submitted),
    );
    b.row("open 3x p99", format!("{:.2} ms", rep.p99_ms), "of admitted queries");
    b.row(
        "open 3x peak leader buffer",
        format!("{} KB", rep.peak_buffered_bytes / 1000),
        "open-loop overload must not grow leader memory",
    );

    let json_path = std::env::var("LOVELOCK_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_service.json".to_string());
    b.finish_json(&json_path);
}

//! Bench `table2` — regenerates Table 2: host CPU and DRAM use during
//! distributed LLM training (GLaM 1B–39B on 8 hosts × 4 accelerators),
//! plus the §5.3 checkpoint-chunking ablation and, when built with the
//! `xla` feature and artifacts, a *measured* row from the real PJRT
//! training driver.

use lovelock::benchkit::Bench;
use lovelock::training::hostmodel::{CheckpointPolicy, GlamModel, TrainSetup};

fn main() {
    let mut b = Bench::new("Table 2 — host CPU/DRAM during training (8 hosts x 4 accels)");
    let setup = TrainSetup::default();
    let paper = [
        ("GLaM1B", 4.8, 8.9, 0.2, 0.8, 3.4, 5.0),
        ("GLaM4B", 3.8, 6.2, 0.4, 1.8, 3.8, 6.5),
        ("GLaM17B", 3.4, 10.2, 2.0, 8.1, 4.2, 17.8),
        ("GLaM39B", 2.1, 13.3, 4.5, 18.2, 4.7, 35.7),
    ];
    for (m, p) in GlamModel::table2_models().iter().zip(paper.iter()) {
        let u = setup.host_usage(m);
        b.row(
            &format!("{} cpu mean/peak", m.name),
            format!("{:.1}% / {:.1}%", u.mean_cpu_frac * 100.0, u.peak_cpu_frac * 100.0),
            format!("paper {:.1}% / {:.1}%", p.1, p.2),
        );
        b.row(
            &format!("{} state accel/host", m.name),
            format!("{:.1} / {:.1} GB", u.state_per_accel / 1e9, u.state_per_host / 1e9),
            format!("paper {:.1} / {:.1} GB", p.3, p.4),
        );
        b.row(
            &format!("{} mem mean/max", m.name),
            format!("{:.1} / {:.1} GB", u.mean_mem / 1e9, u.max_mem / 1e9),
            format!("paper {:.1} / {:.1} GB", p.5, p.6),
        );
    }

    // §5.3 ablation: chunked-stream checkpointing caps the peak.
    let chunked = TrainSetup {
        policy: CheckpointPolicy::ChunkedStream { chunk_bytes: 256 << 20 },
        ..setup
    };
    for m in [GlamModel::glam_17b(), GlamModel::glam_39b()] {
        let mono = setup.host_usage(&m).max_mem / 1e9;
        let chk = chunked.host_usage(&m).max_mem / 1e9;
        b.row(
            &format!("{} max mem, chunked ckpt", m.name),
            format!("{chk:.1} GB"),
            format!("monolithic {mono:.1} GB — paper's §5.3 proposal"),
        );
        b.row(
            &format!("{} accels per E2000 (48GB)", m.name),
            format!("{}", chunked.accels_per_e2000(&m, 48e9)),
            "paper: each E2000 can drive 2-4 accelerators",
        );
    }

    // Measured: the real AOT training loop's host-vs-device split
    // (needs the xla feature and built artifacts).
    measured_driver_row(&mut b);
    b.finish();
}

#[cfg(feature = "xla")]
fn measured_driver_row(b: &mut Bench) {
    use lovelock::training::driver::TrainDriver;
    if !lovelock::runtime::artifacts_available() {
        return;
    }
    if let Ok(mut driver) = TrainDriver::load("tiny", 11) {
        driver.init(11).unwrap();
        driver.run(30, 0).unwrap();
        let acc = driver.accounting;
        b.row(
            "measured tiny driver host-cpu",
            format!("{:.1}%", acc.host_cpu_frac() * 100.0),
            format!(
                "host {:.3}s vs device {:.3}s over {} steps (PJRT)",
                acc.host_secs, acc.device_secs, acc.steps
            ),
        );
    }
}

#[cfg(not(feature = "xla"))]
fn measured_driver_row(_b: &mut Bench) {}

//! Bench `gnn` — §5.3's GNN input-pipeline analysis: the BGL numbers
//! (8xV100 compute 400 mb/s, 100 Gbps feeds ~60), Lovelock φ sweeps, the
//! cache ablation, and the generic stall-amortization claim.

use lovelock::benchkit::Bench;
use lovelock::gnn::{bandwidth_speedup, GnnHost, LovelockGnn};

fn main() {
    let mut b = Bench::new("GNN input pipeline (BGL workload, §5.3)");
    let base = GnnHost::bgl_server();
    b.row(
        "server compute ceiling",
        format!("{:.0} mb/s", base.compute_rate()),
        "paper: 8 V100 compute 400 mini-batches/s",
    );
    b.row(
        "server network ceiling",
        format!("{:.1} mb/s", base.network_rate()),
        "paper: shared 100 Gbps allows only ~60",
    );
    b.row(
        "server GPU stall",
        format!("{:.0}%", base.stall_fraction() * 100.0),
        "accelerators idle waiting on fetches",
    );
    for phi in [1u32, 2, 4, 8] {
        let l = LovelockGnn { phi, nic_gbps_each: 200.0, base };
        b.row(
            &format!("lovelock phi={phi} (200G each)"),
            format!("{:.0} mb/s", l.achieved_rate()),
            format!("{:.1}x vs server", l.speedup_vs_server()),
        );
    }
    for hit in [0.0, 0.5, 0.8] {
        let mut h = base;
        h.cache_hit = hit;
        b.row(
            &format!("feature cache hit={hit}"),
            format!("{:.0} mb/s", h.achieved_rate()),
            format!("stall {:.0}%", h.stall_fraction() * 100.0),
        );
    }
    b.row(
        "2x bw @ 20% stalls",
        format!("{:.3}x", bandwidth_speedup(0.20, 2.0)),
        "paper: 'providing 2x of bandwidth can easily bring 10% speedup'",
    );
    b.finish();
}

//! Bench `rpc` — §6's networking/RPC claims: eRPC calibration points, the
//! E2000 single-ARM-core model, and *measured* per-core message rate and
//! large-message goodput of our in-process RPC transport.

use lovelock::benchkit::{black_box, Bench};
use lovelock::rpc::{Dispatch, RpcModel};

fn main() {
    let mut b = Bench::new("RPC per-core throughput (§6)");

    // Model rows.
    let x86 = RpcModel::erpc_x86();
    let arm = RpcModel::e2000_arm();
    b.row(
        "erpc x86 small msgs",
        format!("{:.1} M/s", x86.msgs_per_sec(32.0) / 1e6),
        "paper/eRPC: ~10M small RPCs per second per core",
    );
    b.row(
        "erpc x86 1MB goodput",
        format!("{:.0} Gbps", x86.gbps(1e6)),
        "paper/eRPC: ~75 Gbps with large messages",
    );
    b.row(
        "e2000 arm 1MB goodput",
        format!("{:.0} Gbps", arm.gbps(1e6)),
        "paper: single ARM core sustains over 25 Gbps",
    );
    for size in [64.0, 4096.0, 65536.0, 1e6] {
        b.row(
            &format!("e2000 arm @ {size:.0}B"),
            format!("{:.2} Gbps", arm.gbps(size)),
            format!("{:.2} M msgs/s", arm.msgs_per_sec(size) / 1e6),
        );
    }
    b.row(
        "arm cores for 200G line rate",
        format!("{:.1}", arm.cores_for(200.0, 1e6)),
        "of the E2000's 16 cores, at 1MB messages",
    );

    // Measured rows: our in-process transport (single dispatch core).
    let ep = Dispatch::new()
        .on(1, |m: &lovelock::rpc::Message| Ok(m.payload[..8.min(m.payload.len())].to_vec()))
        .serve();
    let client = ep.client();

    let small = vec![7u8; 32];
    b.measure("measured small rpc", || {
        black_box(client.call(1, small.clone()).unwrap());
    });
    // One-way casts: batch + closing call, so the unbounded queue drains
    // every iteration instead of outrunning the single dispatch core.
    b.measure("measured 64 casts + flush", || {
        for _ in 0..64 {
            black_box(client.cast(1, small.clone()).unwrap());
        }
        black_box(client.call(1, small.clone()).unwrap());
    });
    let big = vec![7u8; 1 << 20];
    let bytes = big.len() as u64;
    b.measure_throughput("measured 1MB rpc goodput", bytes, || {
        black_box(client.call(1, big.clone()).unwrap());
    });
    b.finish();
}

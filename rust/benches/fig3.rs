//! Bench `fig3` — regenerates Figure 3: per-core TPC-H performance when
//! every hardware thread runs an independent query, on IPU E2000 vs AMD
//! Milan vs Intel Skylake.
//!
//! Pipeline: generate TPC-H data → run each query on the real engine
//! (timed, warm) → feed the measured demand profile into the
//! memory-contention model per platform. Prints, per query: normalized
//! per-core performance (1-core and all-core, E2000-1-core = 1.0) plus
//! the whole-system ratios the paper quotes.

use lovelock::analytics::profile::profile_query_warm;
use lovelock::analytics::{TpchConfig, TpchDb, QUERY_NAMES};
use lovelock::benchkit::Bench;
use lovelock::memsim::{full_occupancy, simulate, system_ratio};
use lovelock::platform::{ipu_e2000, n2d_milan, skylake_fig3};

fn main() {
    let sf = std::env::var("LOVELOCK_FIG3_SF")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    let db = TpchDb::generate(TpchConfig::new(sf, 2026));
    let e2000 = ipu_e2000();
    let milan = n2d_milan();
    let sky = skylake_fig3();

    let mut b = Bench::new(&format!(
        "Figure 3 — per-core perf under full occupancy (profiled at SF {sf}, scaled to SF 1)"
    ));
    let mut milan_ratios = Vec::new();
    let mut sky_ratios = Vec::new();
    for q in QUERY_NAMES {
        let p = profile_query_warm(&db, q, 1.0, 3).unwrap();
        let w = p.workload();
        // Normalized per-core performance (E2000 single-core = 1).
        let base = simulate(&e2000, &w, 1).per_core_rate;
        let rows = [
            ("e2000", full_occupancy(&e2000, &w)),
            ("milan", full_occupancy(&milan, &w)),
            ("skylake", full_occupancy(&sky, &w)),
        ];
        for (name, r) in rows {
            b.row(
                &format!("{q}/{name}"),
                format!("{:.2}", r.per_core_rate / base),
                format!(
                    "drop {:.0}% {}",
                    r.slowdown_frac * 100.0,
                    if r.memory_bound { "(mem-bound)" } else { "(cpu-bound)" }
                ),
            );
        }
        milan_ratios.push(system_ratio(&milan, &e2000, &w));
        sky_ratios.push(system_ratio(&sky, &e2000, &w));
    }
    let summary = |xs: &mut Vec<f64>| {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (xs[0], xs[xs.len() - 1], xs[xs.len() / 2])
    };
    let (mlo, mhi, mmed) = summary(&mut milan_ratios);
    b.row(
        "milan whole-system ratio",
        format!("{mlo:.1}-{mhi:.1}x (median {mmed:.1})"),
        "paper: 1.9-9.2x (median 4.7)",
    );
    let (slo, shi, smed) = summary(&mut sky_ratios);
    b.row(
        "skylake whole-system ratio",
        format!("{slo:.1}-{shi:.1}x (median {smed:.1})"),
        "paper: 2.1-4.5x (median 3.6)",
    );

    // Sanity anchor for the morsel path: the parallel engine reproduces
    // the serial rows on this host (Fig. 3 profiles stay single-threaded
    // by methodology; the shuffle executor uses the morsel kernels).
    let q1_serial = lovelock::analytics::run_query(&db, "q1").unwrap();
    let q1_morsel = lovelock::analytics::run_query_morsel(&db, "q1", 0, 16_384).unwrap();
    b.row(
        "morsel path agrees with serial",
        format!("{}", q1_morsel.approx_eq_rows(&q1_serial.rows)),
        "q1 rows, all cores vs 1 thread",
    );
    b.finish();
}

//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! from the Rust request path — Python is never in the loop.
//!
//! Artifacts are HLO **text** (`artifacts/*.hlo.txt`), produced once by
//! `python/compile/aot.py`. Text is the interchange format because jax ≥
//! 0.5 emits HloModuleProtos with 64-bit instruction ids that the
//! xla_extension 0.5.1 backing the `xla` crate rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! [`Engine`] wraps the PJRT CPU client; [`Module`] is one compiled
//! executable. For iterated execution (the training loop) use the
//! buffer-to-buffer path ([`Module::execute_buffers`]) so parameters stay
//! resident and no literal round-trips happen per step.

use crate::error::{Context, Result};
use crate::err;
use std::path::Path;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// The PJRT engine (CPU plugin).
pub struct Engine {
    client: PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_module<P: AsRef<Path>>(&self, path: P) -> Result<Module> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Module { exe, name: path.display().to_string() })
    }

    /// Copy a host literal into a device buffer.
    pub fn to_device(&self, lit: &Literal) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| err!("host->device: {e}"))
    }
}

/// One compiled executable.
pub struct Module {
    exe: PjRtLoadedExecutable,
    name: String,
}

impl Module {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the outputs as literals.
    ///
    /// Single-output modules (`return_tuple=False` in aot.py) yield one
    /// array literal; tuple-rooted modules are decomposed into their
    /// elements.
    pub fn execute(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let outs = self.exe.execute::<Literal>(inputs).map_err(|e| err!("execute: {e}"))?;
        let lit = outs[0][0].to_literal_sync().map_err(|e| err!("d2h: {e}"))?;
        let is_tuple = lit.shape().map(|s| s.is_tuple()).unwrap_or(false);
        if is_tuple {
            Ok(lit.to_tuple().map_err(|e| err!("untuple: {e}"))?)
        } else {
            Ok(vec![lit])
        }
    }

    /// Execute buffer-to-buffer (no host round trip). Returns the raw
    /// output buffers of the first (only) device.
    ///
    /// CAUTION: the CPU PJRT client executes asynchronously; callers must
    /// keep the input buffers alive until the outputs have been observed
    /// (see `TrainDriver`, which retires inputs one generation late).
    pub fn execute_buffers<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<PjRtBuffer>> {
        let mut outs =
            self.exe.execute_b(inputs).map_err(|e| err!("execute_b: {e}"))?;
        Ok(outs.swap_remove(0))
    }
}

/// Blocking partial read of `n` f32 elements at `offset` from a device
/// buffer. Doubles as a synchronization point: PJRT CPU executes
/// asynchronously, and this returns only after the producing computation
/// finished — after which its input buffers may safely be dropped.
pub fn read_f32_at(buf: &PjRtBuffer, offset: usize, n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0f32; n];
    buf.copy_raw_to_host_sync(&mut out, offset)
        .map_err(|e| err!("copy_raw_to_host_sync: {e}"))?;
    Ok(out)
}

/// f32 vector → rank-N literal.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Literal::vec1(data).reshape(dims).map_err(|e| err!("reshape: {e}"))
}

/// i32 vector → rank-N literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Literal::vec1(data).reshape(dims).map_err(|e| err!("reshape: {e}"))
}

/// Literal → f32 vec.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| err!("to_vec: {e}"))
}

/// Scalar f32 from a literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v = to_f32(lit)?;
    crate::ensure!(!v.is_empty(), "empty literal");
    Ok(v[0])
}

/// Path to an artifact, honouring LOVELOCK_ARTIFACTS.
pub fn artifact_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::var("LOVELOCK_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    std::path::Path::new(&dir).join(name)
}

/// True if the artifact directory has been built.
pub fn artifacts_available() -> bool {
    artifact_path("q6_scan.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full engine tests (needing artifacts) live in
    // rust/tests/integration_runtime.rs; these cover the helpers.

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let l = literal_f32(&[42.5], &[1]).unwrap();
        assert_eq!(scalar_f32(&l).unwrap(), 42.5);
    }

    #[test]
    fn artifact_path_respects_env() {
        std::env::set_var("LOVELOCK_ARTIFACTS", "/tmp/lovelock-test-artifacts");
        assert_eq!(
            artifact_path("x.hlo.txt"),
            std::path::PathBuf::from("/tmp/lovelock-test-artifacts/x.hlo.txt")
        );
        std::env::remove_var("LOVELOCK_ARTIFACTS");
    }
}

//! Load driver for the overload-hardened [`QueryService`] — the
//! measurement half of DESIGN.md §3g's overload model.
//!
//! Two canonical load shapes, both driven from one pacing loop:
//!
//! * **closed loop** — a fixed multiprogramming level: up to
//!   `concurrency` requests outstanding, each completion (or shed)
//!   immediately refilled. Models a pool of synchronous clients; the
//!   offered load self-throttles to what the service sustains, so the
//!   interesting numbers are qps and the latency percentiles.
//! * **open loop** — arrivals are a seeded Poisson process at `qps`
//!   regardless of completions. Models the internet: the service does
//!   *not* get to slow the clients down, so overload shows up as
//!   explicit shedding (never as unbounded buffering) and the
//!   interesting numbers are the shed rate and the peak of the leader's
//!   buffered-bytes gauge.
//!
//! The query mix is Zipf-ranked over [`plan_mix`] — a few parameterized
//! `q6` variants at the hot head (cheap, high-rate point lookups in
//! spirit) with the full TPC-H registry in the tail (q18 and friends as
//! the heavy stragglers) — and every submission carries a session key
//! drawn from `sessions` distinct tenants, exercising the service's
//! deficit-round-robin fairness at realistic tenant counts.
//!
//! One driver thread paces thousands of outstanding queries: `submit`
//! is a non-blocking cast and `poll` a non-blocking snapshot, so the
//! loop interleaves submission with a completion sweep and never holds
//! a thread per in-flight query. Determinism: everything random (mix
//! rank, session key, interarrival gap) comes from one seeded
//! [`Pcg64`], so a run is replayable from `(spec, seed)`.

use crate::analytics::engine::PlanParams;
use crate::analytics::{queries, TpchDb, QUERY_NAMES};
use crate::analytics::engine::LogicalPlan;
use crate::coordinator::protocol::QueryId;
use crate::coordinator::service::{
    FailCause, QueryService, QueryStatus, SubmitOpts, Submission,
};
use crate::error::Result;
use crate::prng::Pcg64;
use std::time::{Duration, Instant};

/// How load is offered (see the module docs for the two shapes).
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// Fixed multiprogramming level: refill to `concurrency` outstanding.
    Closed { concurrency: usize },
    /// Seeded Poisson arrivals at `qps`, independent of completions.
    Open { qps: f64 },
}

/// One load-run recipe. `Default` is a 1-second closed loop at
/// concurrency 8 over 1000 sessions with mild Zipf skew.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub mode: LoadMode,
    /// Submission window. After it closes the driver stops offering
    /// load and drains what is outstanding (bounded by `drain`).
    pub duration: Duration,
    /// Hard cap on the post-window drain before outstanding queries are
    /// cancelled (counted separately, not as errors).
    pub drain: Duration,
    /// Distinct session keys the submissions are spread over.
    pub sessions: u64,
    /// Zipf skew of the query mix (0 = uniform).
    pub zipf_s: f64,
    /// Per-query deadline attached to every submission (None = none).
    pub deadline: Option<Duration>,
    /// PRNG seed: same spec + seed → same offered load.
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            mode: LoadMode::Closed { concurrency: 8 },
            duration: Duration::from_secs(1),
            drain: Duration::from_secs(30),
            sessions: 1000,
            zipf_s: 1.1,
            deadline: None,
            seed: 0x10AD,
        }
    }
}

/// What a load run observed. Counts partition `submitted` exactly:
/// `submitted = completed + shed + timeouts + errors + cancelled`.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub submitted: u64,
    pub completed: u64,
    /// Rejected at admission (explicit load shedding).
    pub shed: u64,
    /// Expired to `Failed(Timeout)` — a typed deadline, not an error.
    pub timeouts: u64,
    pub errors: u64,
    /// Still outstanding when the drain cap hit; cancelled by the driver.
    pub cancelled: u64,
    /// Completed-query throughput over the whole run (incl. drain).
    pub qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// shed / submitted.
    pub shed_rate: f64,
    /// High water of the leader's buffered partial bytes over the run.
    pub peak_buffered_bytes: u64,
    pub elapsed: Duration,
}

impl LoadReport {
    /// One-line human rendering (the CLI and bench both print this).
    pub fn summary(&self) -> String {
        format!(
            "{} submitted in {:.2}s: {} ok ({:.1} qps), {} shed ({:.1}%), \
             {} timeout, {} error, {} cancelled; p50 {:.2} ms p99 {:.2} ms; \
             peak leader buffer {} KB",
            self.submitted,
            self.elapsed.as_secs_f64(),
            self.completed,
            self.qps,
            self.shed,
            self.shed_rate * 100.0,
            self.timeouts,
            self.errors,
            self.cancelled,
            self.p50_ms,
            self.p99_ms,
            self.peak_buffered_bytes / 1000,
        )
    }
}

/// The Zipf-ranked plan mix: four parameterized `q6` variants (widening
/// quantity cuts — same plan shape, different selectivity) at the hot
/// head, then the whole registry at default parameters. Rank 0 is the
/// hottest; Zipf skew makes the cheap variants dominate and the heavy
/// registry tail (q18, q9, …) the stragglers — the shape that makes
/// fair scheduling and admission interesting.
pub fn plan_mix() -> Result<Vec<LogicalPlan>> {
    let mut plans = Vec::new();
    for (i, qty) in [24.0f64, 30.0, 36.0, 45.0].iter().enumerate() {
        let mut p = PlanParams::new();
        p.set("qty-lt", &format!("{qty}"));
        let mut plan = queries::build("q6", &p)?;
        // Distinct names keep traces and reports tellable apart; the
        // service treats them as ad-hoc IR either way.
        plan.name = format!("q6-load{i}");
        plans.push(plan);
    }
    for name in QUERY_NAMES {
        plans.push(queries::build(name, &PlanParams::new())?);
    }
    Ok(plans)
}

/// Sorted-percentile helper (same interpolation as benchkit's stats).
fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Drive `svc` with the offered load of `spec` and report what happened.
/// The driver never buffers on the service's behalf: a shed submission
/// is retired immediately, a completion is retired as soon as its
/// latency is recorded, so a long run holds O(outstanding) state.
pub fn run_load(
    svc: &QueryService,
    db: &std::sync::Arc<TpchDb>,
    spec: &LoadSpec,
) -> Result<LoadReport> {
    let plans = plan_mix()?;
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    let sessions = spec.sessions.max(1);
    let mut rep = LoadReport::default();
    let mut inflight: Vec<(QueryId, Instant)> = Vec::new();
    let mut lat_ms: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    let mut next_arrival = t0;
    loop {
        // 1. Offer load while the window is open.
        let offering = t0.elapsed() < spec.duration;
        if offering {
            match spec.mode {
                LoadMode::Closed { concurrency } => {
                    while inflight.len() < concurrency.max(1) {
                        let admitted = submit_one(
                            svc, db, &plans, &mut rng, sessions, spec, &mut rep, &mut inflight,
                        )?;
                        if !admitted {
                            break; // gates closed: retry next sweep, not in a hot loop
                        }
                    }
                }
                LoadMode::Open { qps } => {
                    let gap = 1.0 / qps.max(1e-3);
                    while Instant::now() >= next_arrival && t0.elapsed() < spec.duration {
                        // Admitted or shed, the arrival happened: open
                        // loops never retry, the next arrival is already
                        // scheduled.
                        let _ = submit_one(
                            svc, db, &plans, &mut rng, sessions, spec, &mut rep, &mut inflight,
                        )?;
                        next_arrival += Duration::from_secs_f64(rng.gen_exp(1.0 / gap));
                        // Don't let a stall turn into an unbounded
                        // catch-up burst: drop any backlog of virtual
                        // arrivals older than 50ms.
                        let behind = Instant::now().saturating_duration_since(next_arrival);
                        if behind > Duration::from_millis(50) {
                            next_arrival = Instant::now();
                        }
                    }
                }
            }
        }
        // 2. Completion sweep.
        let mut i = 0;
        while i < inflight.len() {
            let (id, submitted_at) = inflight[i];
            let terminal = match svc.poll(id) {
                QueryStatus::Done => {
                    lat_ms.push(submitted_at.elapsed().as_secs_f64() * 1e3);
                    rep.completed += 1;
                    true
                }
                QueryStatus::Failed(FailCause::Timeout) => {
                    rep.timeouts += 1;
                    true
                }
                QueryStatus::Failed(FailCause::Error(_)) => {
                    rep.errors += 1;
                    true
                }
                // The driver never cancels mid-run and ids are retired
                // only after this sweep saw them terminal — these are
                // "impossible", counted as errors rather than panicking
                // a long measurement.
                QueryStatus::Cancelled | QueryStatus::Rejected | QueryStatus::Unknown => {
                    rep.errors += 1;
                    true
                }
                QueryStatus::Queued
                | QueryStatus::Mapping { .. }
                | QueryStatus::Reducing { .. } => false,
            };
            if terminal {
                svc.retire(id);
                inflight.swap_remove(i);
            } else {
                i += 1;
            }
        }
        // 3. Exit: window closed and nothing outstanding — or the drain
        // cap hit, cancelling the stragglers.
        if !offering {
            if inflight.is_empty() {
                break;
            }
            if t0.elapsed() > spec.duration + spec.drain {
                for (id, _) in inflight.drain(..) {
                    svc.cancel(id);
                    svc.retire(id);
                    rep.cancelled += 1;
                }
                break;
            }
        }
        std::thread::sleep(Duration::from_micros(500));
    }
    rep.elapsed = t0.elapsed();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rep.p50_ms = percentile_ms(&lat_ms, 50.0);
    rep.p99_ms = percentile_ms(&lat_ms, 99.0);
    rep.qps = rep.completed as f64 / rep.elapsed.as_secs_f64().max(1e-9);
    rep.shed_rate = if rep.submitted > 0 { rep.shed as f64 / rep.submitted as f64 } else { 0.0 };
    rep.peak_buffered_bytes = svc.peak_buffered_bytes();
    Ok(rep)
}

/// One paced submission. Returns whether it was admitted (a shed or a
/// synchronous submit error closes the closed-loop refill for this
/// sweep). Shed ids are retired on the spot so the rejected ring never
/// accumulates driver garbage.
#[allow(clippy::too_many_arguments)]
fn submit_one(
    svc: &QueryService,
    db: &std::sync::Arc<TpchDb>,
    plans: &[LogicalPlan],
    rng: &mut Pcg64,
    sessions: u64,
    spec: &LoadSpec,
    rep: &mut LoadReport,
    inflight: &mut Vec<(QueryId, Instant)>,
) -> Result<bool> {
    let plan = &plans[rng.gen_zipf(plans.len() as u64, spec.zipf_s) as usize];
    let opts = SubmitOpts { session: rng.gen_range_u64(sessions), deadline: spec.deadline };
    rep.submitted += 1;
    match svc.try_submit_plan(db, plan, opts) {
        Ok(Submission::Admitted(id)) => {
            inflight.push((id, Instant::now()));
            Ok(true)
        }
        Ok(Submission::Shed { id, .. }) => {
            rep.shed += 1;
            svc.retire(id);
            Ok(false)
        }
        Err(e) => {
            // A submit error (e.g. a plan failing wire bounds) is a
            // driver bug, not load: surface it.
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::TpchConfig;
    use crate::cluster::{ClusterSpec, Role};
    use crate::coordinator::service::{AdmissionConfig, ServiceConfig};
    use crate::platform::n2d_milan;
    use std::sync::Arc;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    fn db() -> Arc<TpchDb> {
        Arc::new(TpchDb::generate(TpchConfig::new(0.001, 12)))
    }

    #[test]
    fn percentiles_interpolate_and_degrade() {
        assert_eq!(percentile_ms(&[], 99.0), 0.0);
        assert_eq!(percentile_ms(&[7.0], 50.0), 7.0);
        let v = [0.0, 10.0];
        assert!((percentile_ms(&v, 50.0) - 5.0).abs() < 1e-9);
        assert!((percentile_ms(&v, 99.0) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn plan_mix_builds_and_leads_with_parameterized_q6() {
        let plans = plan_mix().unwrap();
        assert_eq!(plans.len(), 4 + QUERY_NAMES.len());
        assert!(plans[0].name.starts_with("q6-load"));
        // Every plan must survive the wire-bounds check the service
        // applies at submit.
        for p in &plans {
            p.check_wire_bounds().unwrap();
        }
    }

    #[test]
    fn closed_loop_smoke_completes_and_balances() {
        let db = db();
        let svc = QueryService::with_config(
            cluster(2),
            ServiceConfig { threads: 2, ..ServiceConfig::default() },
        );
        let spec = LoadSpec {
            mode: LoadMode::Closed { concurrency: 4 },
            duration: Duration::from_millis(200),
            sessions: 50,
            ..LoadSpec::default()
        };
        let rep = run_load(&svc, &db, &spec).unwrap();
        assert!(rep.completed > 0, "no queries completed: {rep:?}");
        assert_eq!(rep.errors, 0, "{rep:?}");
        assert_eq!(
            rep.submitted,
            rep.completed + rep.shed + rep.timeouts + rep.errors + rep.cancelled,
            "outcome counts must partition submissions: {rep:?}"
        );
        assert!(rep.p50_ms > 0.0 && rep.p99_ms >= rep.p50_ms, "{rep:?}");
        assert_eq!(svc.credits_in_flight(), 0);
        assert_eq!(svc.live_queries(), 0, "driver must drain the service");
    }

    #[test]
    fn open_loop_sheds_explicitly_when_admission_gates_close() {
        let db = db();
        let svc = QueryService::with_config(
            cluster(2),
            ServiceConfig {
                threads: 2,
                // One query at a time, one more queued: a 200/s open
                // stream must mostly shed.
                max_dispatched: 1,
                admission: AdmissionConfig { max_in_flight: 2, ..Default::default() },
                ..ServiceConfig::default()
            },
        );
        let spec = LoadSpec {
            mode: LoadMode::Open { qps: 200.0 },
            duration: Duration::from_millis(300),
            sessions: 500,
            ..LoadSpec::default()
        };
        let rep = run_load(&svc, &db, &spec).unwrap();
        assert!(rep.shed > 0, "admission never engaged: {rep:?}");
        assert!(rep.shed_rate > 0.0 && rep.shed_rate <= 1.0);
        assert!(rep.completed > 0, "gates must still admit some load: {rep:?}");
        assert_eq!(rep.errors, 0, "{rep:?}");
        assert_eq!(svc.live_queries(), 0);
        assert_eq!(svc.credits_in_flight(), 0);
    }
}

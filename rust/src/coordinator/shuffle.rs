//! Distributed query execution: scatter partitions, compute real partial
//! aggregates, shuffle partials over the simulated fabric, merge.
//!
//! This is the BigQuery-shaped workload of §5.2 run end to end *inside*
//! the repository: the data is real (TPC-H partitions), the per-worker
//! compute is real (the vectorized engine on a thread pool), the partial
//! results cross a real wire format ([`crate::rpc::Message`]), and the
//! network/storage time comes from the flow-level fabric simulator for
//! whichever [`ClusterSpec`] is being evaluated. The resulting
//! CPU/shuffle/IO breakdown is directly comparable to Figure 4.

use crate::analytics::column::Table;
use crate::analytics::ops::{top_k_desc, GroupBy};
use crate::analytics::queries::{Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::cluster::ClusterSpec;
use crate::exec::parallel_map;
use crate::memsim::{simulate, WorkloadProfile};
use crate::rpc::Message;
use crate::simnet::Simulation;
use anyhow::{bail, Result};
use std::time::Instant;

/// Distributed execution report: result rows + the simulated breakdown.
#[derive(Clone, Debug)]
pub struct DistQueryReport {
    pub query: String,
    pub rows: Vec<Row>,
    pub workers: usize,
    /// Simulated seconds of per-worker compute (max across workers).
    pub compute_secs: f64,
    /// Simulated seconds for the partial-result shuffle.
    pub shuffle_secs: f64,
    /// Simulated seconds for reading input from disaggregated storage.
    pub io_secs: f64,
    /// Bytes shuffled leader-ward.
    pub shuffle_bytes: u64,
    /// Bytes read from storage.
    pub input_bytes: u64,
    /// Wall seconds this process actually spent computing partials.
    pub host_compute_secs: f64,
}

impl DistQueryReport {
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.shuffle_secs + self.io_secs
    }

    /// Normalized breakdown (cpu, shuffle, io).
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let t = self.total_secs().max(1e-12);
        (self.compute_secs / t, self.shuffle_secs / t, self.io_secs / t)
    }
}

/// Distributed query executor over a cluster spec.
pub struct DistributedQuery {
    pub cluster: ClusterSpec,
    /// Worker nodes to use (≤ cluster nodes; 0 = all).
    pub workers: usize,
    /// Local thread parallelism for computing the real partials.
    pub threads: usize,
}

/// RPC method ids for the shuffle wire protocol.
pub const METHOD_PARTIAL: u32 = 0x51;

impl DistributedQuery {
    pub fn new(cluster: ClusterSpec) -> Self {
        Self { cluster, workers: 0, threads: 0 }
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    fn n_workers(&self) -> usize {
        let n = self.cluster.num_nodes();
        if self.workers == 0 {
            n
        } else {
            self.workers.min(n)
        }
    }

    /// Run a supported distributed query ("q1", "q6", "q18").
    pub fn run(&self, db: &TpchDb, query: &str) -> Result<DistQueryReport> {
        match query {
            "q1" => self.run_q1(db),
            "q6" => self.run_q6(db),
            "q18" => self.run_q18(db),
            other => bail!("query {other} has no distributed plan"),
        }
    }

    /// Contiguous row ranges of `len` over `w` workers.
    fn ranges(len: usize, w: usize) -> Vec<(usize, usize)> {
        let chunk = len.div_ceil(w.max(1));
        (0..w)
            .map(|i| ((i * chunk).min(len), ((i + 1) * chunk).min(len)))
            .collect()
    }

    fn partition_lineitem(db: &TpchDb, w: usize) -> Vec<Table> {
        Self::ranges(db.lineitem.len(), w)
            .into_iter()
            .map(|(s, e)| db.lineitem.take(&(s as u32..e as u32).collect::<Vec<_>>()))
            .collect()
    }

    /// Simulate the network phases and worker compute for a run where
    /// each worker scanned `input_bytes_each` and shipped
    /// `partial_bytes_each` to the leader, with local per-worker compute
    /// measured at `host_secs_each` on this host.
    fn simulate_phases(
        &self,
        query: &str,
        input_bytes_each: u64,
        partial_bytes_each: Vec<u64>,
        host_secs_each: Vec<f64>,
        ht_bytes_each: u64,
    ) -> (f64, f64, f64) {
        let w = partial_bytes_each.len();
        let topo = self.cluster.topology();
        let n = topo.num_nodes();

        // Phase 1 — storage read: worker i pulls its partition from a
        // storage replica on a different node (disaggregated storage).
        let mut io_sim = Simulation::new(topo.clone());
        for i in 0..w {
            let src = (i + n / 2) % n;
            if src != i {
                io_sim.add_flow(src, i, input_bytes_each as f64, 0.0);
            }
        }
        let io_secs = io_sim.run_makespan();

        // Phase 2 — compute: each worker node runs its partition across
        // all its cores; memsim gives the contention-adjusted speedup.
        let platform = &self.cluster.nodes[0].platform;
        let profile = WorkloadProfile {
            cpu_secs: 1.0, // shape only: we scale measured time below
            dram_bytes: (input_bytes_each as f64).max(1.0),
            working_set_bytes: (ht_bytes_each as f64).max(4e6),
        };
        let k = platform.vcpus;
        let r = simulate(platform, &profile, k);
        // Effective parallel speedup on the node vs one uncontended core.
        let single = simulate(platform, &profile, 1).per_core_rate;
        let speedup = (r.system_rate / single).max(1e-9);
        let host_to_platform = crate::analytics::profile::host_speed() / platform.st_speed;
        let compute_secs = host_secs_each
            .iter()
            .map(|h| h * host_to_platform / speedup)
            .fold(0.0, f64::max);
        let _ = query;

        // Phase 3 — shuffle partials to the leader (node 0).
        let mut sh_sim = Simulation::new(topo);
        for (i, &b) in partial_bytes_each.iter().enumerate() {
            if i != 0 && b > 0 {
                sh_sim.add_flow(i, 0, b as f64, 0.0);
            }
        }
        let shuffle_secs = sh_sim.run_makespan();
        (compute_secs, shuffle_secs, io_secs)
    }

    // -------------------------------------------------------------- Q1

    fn run_q1(&self, db: &TpchDb) -> Result<DistQueryReport> {
        let w = self.n_workers();
        let parts = Self::partition_lineitem(db, w);
        let input_bytes_each = parts.first().map(|p| p.bytes()).unwrap_or(0);

        let t0 = Instant::now();
        let partials: Vec<(Vec<u8>, f64)> = parallel_map(parts, self.threads, |p| {
            let t = Instant::now();
            let sub = q1_partial(&p);
            let frame = Message { method: METHOD_PARTIAL, id: 0, payload: encode_q1(&sub) }.encode();
            (frame, t.elapsed().as_secs_f64())
        });
        let host_compute_secs = t0.elapsed().as_secs_f64();

        // Leader: decode frames and merge.
        let mut merged: GroupBy<5> = GroupBy::with_capacity(8);
        let mut partial_bytes = Vec::with_capacity(w);
        let mut host_secs = Vec::with_capacity(w);
        for (frame, secs) in &partials {
            partial_bytes.push(frame.len() as u64);
            host_secs.push(*secs);
            let msg = Message::decode(frame).map_err(anyhow::Error::msg)?;
            for (key, sums, cnt) in decode_q1(&msg.payload)? {
                let gi = merged.group_index(key);
                for (a, v) in merged.groups[gi].1.iter_mut().zip(sums.iter()) {
                    *a += v;
                }
                merged.groups[gi].2 += cnt;
            }
        }
        let rows = q1_rows(&merged);
        let shuffle_bytes: u64 = partial_bytes.iter().sum();
        let (compute_secs, shuffle_secs, io_secs) = self.simulate_phases(
            "q1",
            input_bytes_each,
            partial_bytes,
            host_secs,
            1 << 16,
        );
        Ok(DistQueryReport {
            query: "q1".into(),
            rows,
            workers: w,
            compute_secs,
            shuffle_secs,
            io_secs,
            shuffle_bytes,
            input_bytes: input_bytes_each * w as u64,
            host_compute_secs,
        })
    }

    // -------------------------------------------------------------- Q6

    fn run_q6(&self, db: &TpchDb) -> Result<DistQueryReport> {
        let w = self.n_workers();
        let parts = Self::partition_lineitem(db, w);
        let input_bytes_each = parts.first().map(|p| p.bytes()).unwrap_or(0);

        let t0 = Instant::now();
        let partials: Vec<(Vec<u8>, f64)> = parallel_map(parts, self.threads, |p| {
            let t = Instant::now();
            let rev = q6_partial(&p);
            let frame =
                Message { method: METHOD_PARTIAL, id: 0, payload: rev.to_le_bytes().to_vec() }
                    .encode();
            (frame, t.elapsed().as_secs_f64())
        });
        let host_compute_secs = t0.elapsed().as_secs_f64();

        let mut revenue = 0.0;
        let mut partial_bytes = Vec::new();
        let mut host_secs = Vec::new();
        for (frame, secs) in &partials {
            partial_bytes.push(frame.len() as u64);
            host_secs.push(*secs);
            let msg = Message::decode(frame).map_err(anyhow::Error::msg)?;
            revenue += f64::from_le_bytes(msg.payload[..8].try_into()?);
        }
        let shuffle_bytes: u64 = partial_bytes.iter().sum();
        let (compute_secs, shuffle_secs, io_secs) =
            self.simulate_phases("q6", input_bytes_each, partial_bytes, host_secs, 4096);
        Ok(DistQueryReport {
            query: "q6".into(),
            rows: vec![vec![Value::Float(revenue)]],
            workers: w,
            compute_secs,
            shuffle_secs,
            io_secs,
            shuffle_bytes,
            input_bytes: input_bytes_each * w as u64,
            host_compute_secs,
        })
    }

    // -------------------------------------------------------------- Q18

    fn run_q18(&self, db: &TpchDb) -> Result<DistQueryReport> {
        let w = self.n_workers();
        let parts = Self::partition_lineitem(db, w);
        let input_bytes_each = parts.first().map(|p| p.bytes()).unwrap_or(0);

        let t0 = Instant::now();
        let partials: Vec<(Vec<u8>, f64)> = parallel_map(parts, self.threads, |p| {
            let t = Instant::now();
            let sums = q18_partial(&p);
            let frame =
                Message { method: METHOD_PARTIAL, id: 0, payload: encode_q18(&sums) }.encode();
            (frame, t.elapsed().as_secs_f64())
        });
        let host_compute_secs = t0.elapsed().as_secs_f64();

        // The q18 shuffle is the heavy one: per-order partial sums.
        let mut merged: GroupBy<1> = GroupBy::with_capacity(db.orders.len());
        let mut partial_bytes = Vec::new();
        let mut host_secs = Vec::new();
        for (frame, secs) in &partials {
            partial_bytes.push(frame.len() as u64);
            host_secs.push(*secs);
            let msg = Message::decode(frame).map_err(anyhow::Error::msg)?;
            for (key, qty) in decode_q18(&msg.payload)? {
                merged.update(key, [qty]);
            }
        }
        let ototal = db.orders.col("o_totalprice").as_f64();
        let ocust = db.orders.col("o_custkey").as_i64();
        let odate = db.orders.col("o_orderdate").as_i32();
        let mut big: Vec<(i64, f64)> = merged
            .groups
            .iter()
            .filter(|(_, s, _)| s[0] > 300.0)
            .map(|(k, _, _)| (*k, ototal[(*k - 1) as usize]))
            .collect();
        top_k_desc(&mut big, 100);
        let qty_of: std::collections::HashMap<i64, f64> =
            merged.groups.iter().map(|(k, s, _)| (*k, s[0])).collect();
        let rows: Vec<Row> = big
            .into_iter()
            .map(|(ok, total)| {
                let orow = (ok - 1) as usize;
                vec![
                    Value::Int(ocust[orow]),
                    Value::Int(ok),
                    Value::Int(odate[orow] as i64),
                    Value::Float(total),
                    Value::Float(qty_of[&ok]),
                ]
            })
            .collect();

        let shuffle_bytes: u64 = partial_bytes.iter().sum();
        let (compute_secs, shuffle_secs, io_secs) = self.simulate_phases(
            "q18",
            input_bytes_each,
            partial_bytes,
            host_secs,
            (db.orders.len() * 24) as u64,
        );
        Ok(DistQueryReport {
            query: "q18".into(),
            rows,
            workers: w,
            compute_secs,
            shuffle_secs,
            io_secs,
            shuffle_bytes,
            input_bytes: input_bytes_each * w as u64,
            host_compute_secs,
        })
    }
}

// ------------------------------------------------------------ partials

fn q1_partial(part: &Table) -> GroupBy<5> {
    use crate::analytics::column::date_to_days;
    let cutoff = date_to_days(1998, 12, 1) - 90;
    let ship = part.col("l_shipdate").as_i32();
    let qty = part.col("l_quantity").as_f64();
    let price = part.col("l_extendedprice").as_f64();
    let disc = part.col("l_discount").as_f64();
    let tax = part.col("l_tax").as_f64();
    let rf = part.col("l_returnflag").as_u8();
    let ls = part.col("l_linestatus").as_u8();
    let mut g: GroupBy<5> = GroupBy::with_capacity(8);
    for i in 0..part.len() {
        if ship[i] > cutoff {
            continue;
        }
        let dp = price[i] * (1.0 - disc[i]);
        let key = ((rf[i] as i64) << 8) | ls[i] as i64;
        g.update(key, [qty[i], price[i], dp, dp * (1.0 + tax[i]), disc[i]]);
    }
    g
}

fn q1_rows(g: &GroupBy<5>) -> Vec<Row> {
    let mut rows: Vec<Row> = g
        .groups
        .iter()
        .map(|(key, s, cnt)| {
            let c = *cnt as f64;
            vec![
                Value::Str(((key >> 8) as u8 as char).to_string()),
                Value::Str(((key & 0xff) as u8 as char).to_string()),
                Value::Float(s[0]),
                Value::Float(s[1]),
                Value::Float(s[2]),
                Value::Float(s[3]),
                Value::Float(s[0] / c),
                Value::Float(s[1] / c),
                Value::Float(s[4] / c),
                Value::Int(*cnt as i64),
            ]
        })
        .collect();
    rows.sort_by(|a, b| {
        let sa = (fmt(&a[0]), fmt(&a[1]));
        let sb = (fmt(&b[0]), fmt(&b[1]));
        sa.cmp(&sb)
    });
    rows
}

fn fmt(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        _ => unreachable!(),
    }
}

fn q6_partial(part: &Table) -> f64 {
    use crate::analytics::column::date_to_days;
    let lo = date_to_days(1994, 1, 1);
    let hi = date_to_days(1995, 1, 1);
    let ship = part.col("l_shipdate").as_i32();
    let disc = part.col("l_discount").as_f64();
    let qty = part.col("l_quantity").as_f64();
    let price = part.col("l_extendedprice").as_f64();
    let mut rev = 0.0;
    for i in 0..part.len() {
        if ship[i] >= lo
            && ship[i] < hi
            && disc[i] >= 0.045
            && disc[i] < 0.075
            && qty[i] < 24.0
        {
            rev += price[i] * disc[i];
        }
    }
    rev
}

fn q18_partial(part: &Table) -> Vec<(i64, f64)> {
    let lok = part.col("l_orderkey").as_i64();
    let qty = part.col("l_quantity").as_f64();
    let mut g: GroupBy<1> = GroupBy::with_capacity(part.len() / 4 + 16);
    for i in 0..part.len() {
        g.update(lok[i], [qty[i]]);
    }
    g.groups.iter().map(|(k, s, _)| (*k, s[0])).collect()
}

// ------------------------------------------------------------ encoding

fn encode_q1(g: &GroupBy<5>) -> Vec<u8> {
    let mut out = Vec::with_capacity(g.groups.len() * 56);
    for (k, sums, cnt) in &g.groups {
        out.extend_from_slice(&k.to_le_bytes());
        for s in sums {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&cnt.to_le_bytes());
    }
    out
}

type Q1Partial = Vec<(i64, [f64; 5], u64)>;

fn decode_q1(buf: &[u8]) -> Result<Q1Partial> {
    if buf.len() % 56 != 0 {
        bail!("bad q1 partial length {}", buf.len());
    }
    let mut out = Vec::with_capacity(buf.len() / 56);
    for chunk in buf.chunks_exact(56) {
        let key = i64::from_le_bytes(chunk[0..8].try_into()?);
        let mut sums = [0.0; 5];
        for (i, s) in sums.iter_mut().enumerate() {
            *s = f64::from_le_bytes(chunk[8 + i * 8..16 + i * 8].try_into()?);
        }
        let cnt = u64::from_le_bytes(chunk[48..56].try_into()?);
        out.push((key, sums, cnt));
    }
    Ok(out)
}

fn encode_q18(sums: &[(i64, f64)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(sums.len() * 16);
    for (k, q) in sums {
        out.extend_from_slice(&k.to_le_bytes());
        out.extend_from_slice(&q.to_le_bytes());
    }
    out
}

fn decode_q18(buf: &[u8]) -> Result<Vec<(i64, f64)>> {
    if buf.len() % 16 != 0 {
        bail!("bad q18 partial length {}", buf.len());
    }
    Ok(buf
        .chunks_exact(16)
        .map(|c| {
            (
                i64::from_le_bytes(c[0..8].try_into().unwrap()),
                f64::from_le_bytes(c[8..16].try_into().unwrap()),
            )
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries;
    use crate::analytics::tpch::TpchConfig;
    use crate::cluster::Role;
    use crate::platform::n2d_milan;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    #[test]
    fn distributed_q1_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 101));
        let single = queries::q1::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "distributed q1 diverged");
        assert!(dist.shuffle_bytes > 0);
        assert!(dist.compute_secs > 0.0);
    }

    #[test]
    fn distributed_q6_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 103));
        let single = queries::q6::run(&db);
        let dist = DistributedQuery::new(cluster(8)).run(&db, "q6").unwrap();
        assert!(single.approx_eq_rows(&dist.rows));
    }

    #[test]
    fn distributed_q18_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 107));
        let single = queries::q18::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q18").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "q18 diverged");
        // q18 shuffles per-order sums: orders of magnitude more bytes
        // than q1's 4-group partials.
        let q1 = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(dist.shuffle_bytes > 100 * q1.shuffle_bytes);
    }

    #[test]
    fn unsupported_query_errors() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 109));
        assert!(DistributedQuery::new(cluster(2)).run(&db, "q3").is_err());
    }

    #[test]
    fn worker_count_caps_at_cluster() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 113));
        let r = DistributedQuery::new(cluster(3)).with_workers(64).run(&db, "q6").unwrap();
        assert_eq!(r.workers, 3);
    }

    #[test]
    fn lovelock_reduces_network_time() {
        // Same bytes, Lovelock φ=2 with 200G NICs vs servers with 100G:
        // shuffle+io time must shrink.
        let db = TpchDb::generate(TpchConfig::new(0.005, 127));
        let trad = cluster(4);
        let love = ClusterSpec::lovelock_e2000(&trad, 2);
        let rt = DistributedQuery::new(trad).run(&db, "q18").unwrap();
        let rl = DistributedQuery::new(love).run(&db, "q18").unwrap();
        assert!(rl.io_secs < rt.io_secs, "lovelock io {} vs trad {}", rl.io_secs, rt.io_secs);
        assert_eq!(rl.rows.len(), rt.rows.len());
    }

    #[test]
    fn ranges_cover_exactly() {
        let r = DistributedQuery::ranges(103, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 103);
        let total: usize = r.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, 103);
    }

    #[test]
    fn codec_roundtrip() {
        let mut g: GroupBy<5> = GroupBy::with_capacity(4);
        g.update(7, [1.0, 2.0, 3.0, 4.0, 5.0]);
        g.update(9, [9.0, 8.0, 7.0, 6.0, 5.0]);
        let enc = encode_q1(&g);
        let dec = decode_q1(&enc).unwrap();
        assert_eq!(dec.len(), 2);
        assert_eq!(dec[0].0, 7);
        assert_eq!(dec[1].1[0], 9.0);
        assert!(decode_q1(&enc[..10]).is_err());

        let sums = vec![(1i64, 2.5f64), (3, 4.5)];
        assert_eq!(decode_q18(&encode_q18(&sums)).unwrap(), sums);
    }
}

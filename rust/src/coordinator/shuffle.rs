//! Compatibility wrapper over the message-native query service.
//!
//! [`DistributedQuery`] used to *be* the distributed executor — a
//! synchronous in-process function that shared the leader's address
//! space. The executor now lives in [`super::service`]: leader and
//! workers are RPC endpoints exchanging the typed frames of
//! [`super::protocol`], and queries are submitted, polled, and awaited.
//! This type remains as the one-shot face of that service:
//! [`DistributedQuery::run`] is exactly `submit` + `wait` on a service
//! scoped to the call. Use [`super::service::QueryService`] directly to
//! interleave queries.

use crate::analytics::morsel::DEFAULT_MORSEL_ROWS;
use crate::analytics::tpch::TpchDb;
use crate::cluster::ClusterSpec;
use crate::coordinator::service::{ChaosConfig, QueryService, ServiceConfig};
use crate::error::Result;
use std::sync::Arc;

pub use crate::coordinator::protocol::METHOD_PARTIAL;
pub use crate::coordinator::service::DistQueryReport;

/// One-shot distributed query executor over a cluster spec (a thin
/// wrapper over [`QueryService`]).
pub struct DistributedQuery {
    pub cluster: ClusterSpec,
    /// Worker nodes to use (≤ cluster nodes; 0 = all).
    pub workers: usize,
    /// Leader decode-pool threads (0 = all cores).
    pub threads: usize,
    /// Rows per morsel inside each worker's partition.
    pub morsel_rows: usize,
    /// Deterministic fault injection for this run (also enables the
    /// lease monitor — see [`ServiceConfig::chaos`]).
    pub chaos: Option<ChaosConfig>,
}

impl DistributedQuery {
    pub fn new(cluster: ClusterSpec) -> Self {
        Self { cluster, workers: 0, threads: 0, morsel_rows: DEFAULT_MORSEL_ROWS, chaos: None }
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_morsel_rows(mut self, rows: usize) -> Self {
        self.morsel_rows = rows;
        self
    }

    pub fn with_chaos(mut self, chaos: ChaosConfig) -> Self {
        self.chaos = Some(chaos);
        self
    }

    /// Run any query from the Figure-3 set distributed across the
    /// cluster's workers: `submit` + `wait` on a call-scoped
    /// [`QueryService`]. Result rows `approx_eq_rows` the single-node
    /// reference of [`crate::analytics::run_query`].
    pub fn run(&self, db: &Arc<TpchDb>, query: &str) -> Result<DistQueryReport> {
        let svc = QueryService::with_config(
            self.cluster.clone(),
            ServiceConfig {
                workers: self.workers,
                threads: self.threads,
                morsel_rows: self.morsel_rows,
                chaos: self.chaos,
                ..ServiceConfig::default()
            },
        );
        let id = svc.submit(db, query)?;
        let (_rows, report) = svc.wait(id)?;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::{self, QUERY_NAMES};
    use crate::analytics::tpch::TpchConfig;
    use crate::cluster::Role;
    use crate::platform::n2d_milan;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    fn db(sf: f64, seed: u64) -> Arc<TpchDb> {
        Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)))
    }

    #[test]
    fn every_query_matches_single_node() {
        let db = db(0.005, 101);
        for q in QUERY_NAMES {
            let single = queries::run_query(&db, q).unwrap();
            let dist = DistributedQuery::new(cluster(4)).run(&db, q).unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "distributed {q} diverged ({} vs {} rows)",
                dist.rows.len(),
                single.rows.len()
            );
            // Empty partitions ship nothing, so leader-ward bytes are
            // only guaranteed when the query produced groups at all.
            if single.stats.rows_out > 0 {
                assert!(dist.shuffle_bytes > 0, "{q} shuffled nothing");
            }
            assert!(dist.compute_secs > 0.0, "{q} reported no compute");
            assert!(dist.control_bytes > 0, "{q} charged no control frames");
        }
    }

    #[test]
    fn distributed_q1_matches_single_node() {
        let db = db(0.002, 101);
        let single = queries::q1::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "distributed q1 diverged");
        assert!(dist.shuffle_bytes > 0);
        assert!(dist.compute_secs > 0.0);
    }

    #[test]
    fn distributed_q6_matches_single_node() {
        let db = db(0.002, 103);
        let single = queries::q6::run(&db);
        let dist = DistributedQuery::new(cluster(8)).run(&db, "q6").unwrap();
        assert!(single.approx_eq_rows(&dist.rows));
    }

    #[test]
    fn distributed_q18_matches_single_node() {
        let db = db(0.01, 107);
        let single = queries::q18::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q18").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "q18 diverged");
        // q18 shuffles per-order sums: orders of magnitude more bytes
        // than q1's 4-group partials.
        let q1 = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(dist.shuffle_bytes > 100 * q1.shuffle_bytes);
    }

    #[test]
    fn pre_merge_deduplicates_leaderward_bytes() {
        // Q1 has ~4 groups replicated in every worker's partial. After
        // the partition exchange the leader must receive each group
        // once, not once per worker — leader-ward bytes stay near one
        // partial's worth no matter how many workers ran.
        let db = db(0.002, 131);
        let r2 = DistributedQuery::new(cluster(2)).run(&db, "q1").unwrap();
        let r8 = DistributedQuery::new(cluster(8)).run(&db, "q1").unwrap();
        // Fixed per-frame overhead grows with w; group payload must not
        // multiply. 8 workers would ship ≥4× the groups of 2 workers
        // without pre-merge.
        assert!(
            r8.shuffle_bytes < 2 * r2.shuffle_bytes + 8 * 64,
            "leaderward bytes scale with workers: {} (8w) vs {} (2w)",
            r8.shuffle_bytes,
            r2.shuffle_bytes
        );
        // The exchange, by contrast, does grow with worker count.
        assert!(r8.exchange_bytes > r2.exchange_bytes);
    }

    #[test]
    fn morsel_size_does_not_change_results() {
        let db = db(0.002, 211);
        let single = queries::q5::run(&db);
        for rows in [128, 4096, 1 << 22] {
            let dist = DistributedQuery::new(cluster(3))
                .with_morsel_rows(rows)
                .run(&db, "q5")
                .unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "q5 diverged at morsel_rows={rows}"
            );
        }
    }

    #[test]
    fn one_shot_run_survives_a_seeded_kill() {
        use crate::coordinator::service::KillPhase;
        let db = db(0.001, 137);
        let single = queries::q6::run(&db);
        let dist = DistributedQuery::new(cluster(3))
            .with_chaos(ChaosConfig { seed: 0, kill: Some((1, KillPhase::MidMap)) })
            .run(&db, "q6")
            .unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "q6 diverged across a worker kill");
        assert!(dist.repairs > 0, "the kill must have forced a repair round");
    }

    #[test]
    fn unsupported_query_errors() {
        let db = db(0.001, 109);
        assert!(DistributedQuery::new(cluster(2)).run(&db, "q99").is_err());
    }

    #[test]
    fn worker_count_caps_at_cluster() {
        let db = db(0.001, 113);
        let r = DistributedQuery::new(cluster(3)).with_workers(64).run(&db, "q6").unwrap();
        assert_eq!(r.workers, 3);
    }

    #[test]
    fn lovelock_reduces_network_time() {
        // Same bytes, Lovelock φ=2 with 200G NICs vs servers with 100G:
        // shuffle+io time must shrink.
        let db = db(0.005, 127);
        let trad = cluster(4);
        let love = ClusterSpec::lovelock_e2000(&trad, 2);
        let rt = DistributedQuery::new(trad).run(&db, "q18").unwrap();
        let rl = DistributedQuery::new(love).run(&db, "q18").unwrap();
        assert!(rl.io_secs < rt.io_secs, "lovelock io {} vs trad {}", rl.io_secs, rt.io_secs);
        assert_eq!(rl.rows.len(), rt.rows.len());
    }
}

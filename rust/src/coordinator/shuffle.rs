//! Distributed query execution: scatter partitions, compute real partial
//! aggregates morsel by morsel, shuffle partials over the simulated
//! fabric, merge.
//!
//! This is the BigQuery-shaped workload of §5.2 run end to end *inside*
//! the repository: the data is real (TPC-H partitions read in place — no
//! copies), the per-worker compute is real (the morsel kernels of
//! [`crate::analytics::morsel`] on scoped worker threads), the partial
//! results cross a real wire format ([`crate::rpc::Message`] carrying an
//! encoded [`Partial`]), the leader decodes them on the coordinator
//! [`ThreadPool`] with a [`Backpressure`] credit held per partial until
//! it is merged (bounding decoded-partial buffering), worker tasks
//! are placed on cluster nodes by the [`Scheduler`], and the
//! network/storage time comes from the flow-level fabric simulator for
//! whichever [`ClusterSpec`] is being evaluated. The resulting
//! CPU/shuffle/IO breakdown is directly comparable to Figure 4.
//!
//! Every query in [`crate::analytics::queries::QUERY_NAMES`] has a
//! distributed plan: dimension tables are broadcast (each worker builds
//! its own hash maps from them), `lineitem` is range-partitioned, and the
//! per-query [`crate::analytics::morsel::MorselPlan`] supplies the
//! partial kernel and the leader-side finalizer.

use crate::analytics::morsel::{self, Merger, Partial, DEFAULT_MORSEL_ROWS};
use crate::analytics::queries::Row;
use crate::analytics::tpch::TpchDb;
use crate::cluster::ClusterSpec;
use crate::coordinator::backpressure::Backpressure;
use crate::coordinator::scheduler::{Scheduler, Task, TaskKind};
use crate::error::{Error, Result};
use crate::exec::{parallel_map, ThreadPool};
use crate::memsim::{simulate, WorkloadProfile};
use crate::rpc::Message;
use crate::simnet::Simulation;
use std::time::Instant;

/// Distributed execution report: result rows + the simulated breakdown.
#[derive(Clone, Debug)]
pub struct DistQueryReport {
    pub query: String,
    pub rows: Vec<Row>,
    pub workers: usize,
    /// Simulated seconds of per-worker compute (max across workers).
    pub compute_secs: f64,
    /// Simulated seconds for the partial-result shuffle.
    pub shuffle_secs: f64,
    /// Simulated seconds for reading input from disaggregated storage.
    pub io_secs: f64,
    /// Bytes shuffled leader-ward.
    pub shuffle_bytes: u64,
    /// Bytes read from storage.
    pub input_bytes: u64,
    /// Wall seconds this process actually spent computing partials.
    pub host_compute_secs: f64,
}

impl DistQueryReport {
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.shuffle_secs + self.io_secs
    }

    /// Normalized breakdown (cpu, shuffle, io).
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let t = self.total_secs().max(1e-12);
        (self.compute_secs / t, self.shuffle_secs / t, self.io_secs / t)
    }
}

/// Distributed query executor over a cluster spec.
pub struct DistributedQuery {
    pub cluster: ClusterSpec,
    /// Worker nodes to use (≤ cluster nodes; 0 = all).
    pub workers: usize,
    /// Local thread parallelism for computing the real partials
    /// (0 = all cores).
    pub threads: usize,
    /// Rows per morsel inside each worker's partition.
    pub morsel_rows: usize,
}

/// RPC method id for the shuffle wire protocol.
pub const METHOD_PARTIAL: u32 = 0x51;

impl DistributedQuery {
    pub fn new(cluster: ClusterSpec) -> Self {
        Self { cluster, workers: 0, threads: 0, morsel_rows: DEFAULT_MORSEL_ROWS }
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_morsel_rows(mut self, rows: usize) -> Self {
        self.morsel_rows = rows;
        self
    }

    fn n_workers(&self) -> usize {
        let n = self.cluster.num_nodes();
        if self.workers == 0 {
            n
        } else {
            self.workers.min(n)
        }
    }

    /// Contiguous row ranges of `len` over `w` workers.
    fn ranges(len: usize, w: usize) -> Vec<(usize, usize)> {
        let chunk = len.div_ceil(w.max(1));
        (0..w)
            .map(|i| ((i * chunk).min(len), ((i + 1) * chunk).min(len)))
            .collect()
    }

    /// Run any query from the Figure-3 set distributed across the
    /// cluster's workers. Result rows `approx_eq_rows` the single-node
    /// reference of [`crate::analytics::run_query`].
    pub fn run(&self, db: &TpchDb, query: &str) -> Result<DistQueryReport> {
        let plan = morsel::plan(query)
            .ok_or_else(|| crate::err!("query {query} has no distributed plan"))?;
        let w = self.n_workers();
        crate::ensure!(w >= 1, "cluster has no nodes");
        let n = db.lineitem.len();
        let ranges = Self::ranges(n, w);
        let rows_each = ranges.first().map(|(s, e)| e - s).unwrap_or(0);
        let input_bytes_each = if n == 0 {
            0
        } else {
            (db.lineitem.bytes() as f64 * rows_each as f64 / n as f64) as u64
        };

        // Worker phase: each simulated NIC worker builds its broadcast
        // context (dimension tables are replicated to every node), folds
        // its partition morsel by morsel, and encodes the merged partial
        // as an RPC frame.
        let morsel_rows = self.morsel_rows.max(1);
        let t0 = Instant::now();
        let worker_out: Vec<Result<(Vec<u8>, f64, u64)>> =
            parallel_map(ranges, self.threads, |(lo, hi)| {
                let t = Instant::now();
                let (kernel, _prep_stats) = (plan.prepare)(db);
                let mut merger = Merger::new(plan.width);
                let mut morsel_ht_peak = 0u64;
                let mut s = lo;
                while s < hi {
                    let e = (s + morsel_rows).min(hi);
                    let p = kernel(s, e);
                    // Morsels run sequentially within a worker, so the
                    // live working set is one morsel's hash table plus
                    // the accumulated merge state — not the sum of every
                    // transient table (which stats.ht_bytes records).
                    morsel_ht_peak = morsel_ht_peak.max(p.stats.ht_bytes);
                    merger.absorb(&p)?;
                    s = e;
                }
                let partial = merger.into_partial();
                let group_bytes = (8 + 8 * plan.width + 8) as u64;
                let ht_bytes = morsel_ht_peak + partial.len() as u64 * group_bytes;
                let frame =
                    Message { method: METHOD_PARTIAL, id: lo as u64, payload: partial.encode() }
                        .encode();
                Ok((frame, t.elapsed().as_secs_f64(), ht_bytes))
            });
        let host_compute_secs = t0.elapsed().as_secs_f64();
        let mut frames = Vec::with_capacity(w);
        for r in worker_out {
            frames.push(r?);
        }

        let partial_bytes: Vec<u64> = frames.iter().map(|(f, _, _)| f.len() as u64).collect();
        let host_secs: Vec<f64> = frames.iter().map(|(_, s, _)| *s).collect();
        let ht_bytes_each = frames.iter().map(|(_, _, h)| *h).max().unwrap_or(0);
        let shuffle_bytes: u64 = partial_bytes.iter().sum();

        // Leader phase: decode the partial frames on the coordinator
        // thread pool and merge in worker order so the result is
        // deterministic. A backpressure credit is held per admitted
        // frame from submission until its decoded partial has been
        // merged, so at most `credits` decoded-but-unmerged partials
        // ever buffer at the leader (q18 partials are large).
        let pool = ThreadPool::new(self.threads);
        let credits = Backpressure::new(pool.threads().max(1));
        let mut pending: std::collections::VecDeque<crate::exec::JoinHandle<Result<Partial>>> =
            std::collections::VecDeque::new();
        let mut merger = Merger::new(plan.width);
        for (frame, _, _) in frames {
            while !credits.try_acquire() {
                // Admission full: retire the oldest in-flight partial
                // (merge order stays worker order) to free a credit.
                let h = pending.pop_front().expect("credits exhausted with nothing pending");
                merger.absorb(&h.join()?)?;
                credits.release();
            }
            pending.push_back(pool.submit(move || {
                Message::decode(&frame)
                    .map_err(Error::msg)
                    .and_then(|msg| Partial::decode(&msg.payload))
            }));
        }
        while let Some(h) = pending.pop_front() {
            merger.absorb(&h.join()?)?;
            credits.release();
        }
        let merged = merger.into_partial();
        let rows: Vec<Row> = (plan.finalize)(db, &merged);

        // Place the worker tasks on cluster nodes (role-aware, balanced
        // by the measured per-worker seconds) so the simulated network
        // phases charge flows to the nodes that actually ran them.
        let mut sched = Scheduler::new(&self.cluster);
        let tasks: Vec<Task> = host_secs
            .iter()
            .enumerate()
            .map(|(id, &est)| Task { id, kind: TaskKind::Compute, est_secs: est.max(1e-9) })
            .collect();
        let placements = sched
            .place_all(&tasks)
            .ok_or_else(|| crate::err!("no eligible compute node for worker tasks"))?;
        let worker_nodes: Vec<usize> = placements.iter().map(|p| p.node_id).collect();

        let (compute_secs, shuffle_secs, io_secs) = self.simulate_phases(
            input_bytes_each,
            &partial_bytes,
            &host_secs,
            ht_bytes_each,
            &worker_nodes,
        );
        Ok(DistQueryReport {
            query: query.to_string(),
            rows,
            workers: w,
            compute_secs,
            shuffle_secs,
            io_secs,
            shuffle_bytes,
            input_bytes: input_bytes_each * w as u64,
            host_compute_secs,
        })
    }

    /// Simulate the network phases and worker compute for a run where
    /// the worker on `worker_nodes[i]` scanned `input_bytes_each`,
    /// shipped `partial_bytes[i]` to the leader (node 0), and its local
    /// compute was measured at `host_secs_each[i]` on this host.
    fn simulate_phases(
        &self,
        input_bytes_each: u64,
        partial_bytes: &[u64],
        host_secs_each: &[f64],
        ht_bytes_each: u64,
        worker_nodes: &[usize],
    ) -> (f64, f64, f64) {
        let topo = self.cluster.topology();
        let n = topo.num_nodes();

        // Phase 1 — storage read: each worker node pulls its partition
        // from a storage replica on a different node (disaggregated
        // storage).
        let mut io_sim = Simulation::new(topo.clone());
        for &node in worker_nodes {
            let src = (node + n / 2) % n;
            if src != node && input_bytes_each > 0 {
                io_sim.add_flow(src, node, input_bytes_each as f64, 0.0);
            }
        }
        let io_secs = io_sim.run_makespan();

        // Phase 2 — compute: each worker node runs its partition across
        // all its cores; memsim gives the contention-adjusted speedup.
        let platform = self.cluster.platform();
        let profile = WorkloadProfile {
            cpu_secs: 1.0, // shape only: we scale measured time below
            dram_bytes: (input_bytes_each as f64).max(1.0),
            working_set_bytes: (ht_bytes_each as f64).max(4e6),
        };
        let k = platform.vcpus;
        let r = simulate(platform, &profile, k);
        // Effective parallel speedup on the node vs one uncontended core.
        let single = simulate(platform, &profile, 1).per_core_rate;
        let speedup = (r.system_rate / single).max(1e-9);
        let host_to_platform = crate::analytics::profile::host_speed() / platform.st_speed;
        let compute_secs = host_secs_each
            .iter()
            .map(|h| h * host_to_platform / speedup)
            .fold(0.0, f64::max);

        // Phase 3 — shuffle partials to the leader (node 0).
        let mut sh_sim = Simulation::new(topo);
        for (i, &b) in partial_bytes.iter().enumerate() {
            let node = worker_nodes[i];
            if node != 0 && b > 0 {
                sh_sim.add_flow(node, 0, b as f64, 0.0);
            }
        }
        let shuffle_secs = sh_sim.run_makespan();
        (compute_secs, shuffle_secs, io_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::{self, QUERY_NAMES};
    use crate::analytics::tpch::TpchConfig;
    use crate::cluster::Role;
    use crate::platform::n2d_milan;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    #[test]
    fn every_query_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.005, 101));
        for q in QUERY_NAMES {
            let single = queries::run_query(&db, q).unwrap();
            let dist = DistributedQuery::new(cluster(4)).run(&db, q).unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "distributed {q} diverged ({} vs {} rows)",
                dist.rows.len(),
                single.rows.len()
            );
            assert!(dist.shuffle_bytes > 0, "{q} shuffled nothing");
            assert!(dist.compute_secs > 0.0, "{q} reported no compute");
        }
    }

    #[test]
    fn distributed_q1_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 101));
        let single = queries::q1::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "distributed q1 diverged");
        assert!(dist.shuffle_bytes > 0);
        assert!(dist.compute_secs > 0.0);
    }

    #[test]
    fn distributed_q6_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 103));
        let single = queries::q6::run(&db);
        let dist = DistributedQuery::new(cluster(8)).run(&db, "q6").unwrap();
        assert!(single.approx_eq_rows(&dist.rows));
    }

    #[test]
    fn distributed_q18_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 107));
        let single = queries::q18::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q18").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "q18 diverged");
        // q18 shuffles per-order sums: orders of magnitude more bytes
        // than q1's 4-group partials.
        let q1 = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(dist.shuffle_bytes > 100 * q1.shuffle_bytes);
    }

    #[test]
    fn morsel_size_does_not_change_results() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 211));
        let single = queries::q5::run(&db);
        for rows in [128, 4096, 1 << 22] {
            let dist = DistributedQuery::new(cluster(3))
                .with_morsel_rows(rows)
                .run(&db, "q5")
                .unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "q5 diverged at morsel_rows={rows}"
            );
        }
    }

    #[test]
    fn unsupported_query_errors() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 109));
        assert!(DistributedQuery::new(cluster(2)).run(&db, "q99").is_err());
    }

    #[test]
    fn worker_count_caps_at_cluster() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 113));
        let r = DistributedQuery::new(cluster(3)).with_workers(64).run(&db, "q6").unwrap();
        assert_eq!(r.workers, 3);
    }

    #[test]
    fn lovelock_reduces_network_time() {
        // Same bytes, Lovelock φ=2 with 200G NICs vs servers with 100G:
        // shuffle+io time must shrink.
        let db = TpchDb::generate(TpchConfig::new(0.005, 127));
        let trad = cluster(4);
        let love = ClusterSpec::lovelock_e2000(&trad, 2);
        let rt = DistributedQuery::new(trad).run(&db, "q18").unwrap();
        let rl = DistributedQuery::new(love).run(&db, "q18").unwrap();
        assert!(rl.io_secs < rt.io_secs, "lovelock io {} vs trad {}", rl.io_secs, rt.io_secs);
        assert_eq!(rl.rows.len(), rt.rows.len());
    }

    #[test]
    fn ranges_cover_exactly() {
        let r = DistributedQuery::ranges(103, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 103);
        let total: usize = r.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, 103);
    }
}

//! Distributed query execution: scatter partitions, compute real partial
//! aggregates morsel by morsel, exchange hash-partitioned partials over
//! the simulated fabric, reduce, merge.
//!
//! This is the BigQuery-shaped workload of §5.2 run end to end *inside*
//! the repository: the data is real (TPC-H partitions read in place — no
//! copies), the per-worker compute is real (the unified engine kernel of
//! [`crate::analytics::engine`] on scoped worker threads), the partial
//! results cross a real wire format ([`crate::rpc::Message`] carrying an
//! encoded [`Partial`]), worker tasks are placed on cluster nodes by the
//! [`Scheduler`], and the network/storage time comes from the flow-level
//! fabric simulator for whichever [`ClusterSpec`] is being evaluated.
//! The resulting CPU/shuffle/IO breakdown is directly comparable to
//! Figure 4.
//!
//! The shuffle is a **hash-partitioned partial exchange**: each worker
//! splits its merged partial into `w` key-disjoint partitions
//! ([`Partial::partition_by_key`]); partition `p` of every worker goes
//! to the reducer co-located with worker `p`, which pre-merges them
//! (worker order — deterministic) and ships one *already-merged,
//! key-deduplicated* partial to the leader. Empty partitions are never
//! encoded or shipped, so single-group queries exchange `O(w)` frames,
//! not `O(w²)`. The leader then decodes `w`
//! key-disjoint frames on the coordinator [`ThreadPool`] — a
//! [`Backpressure`] credit held per frame from submission until merge
//! bounds decoded-partial buffering — instead of merging every raw
//! worker partial itself. For low-cardinality aggregates (Q1's four
//! groups) this cuts leader-ward bytes by ~w×; for all queries it moves
//! the merge CPU off the leader onto the workers.
//!
//! Every query in [`crate::analytics::queries::QUERY_NAMES`] has a
//! distributed plan: dimension tables are broadcast (each worker
//! compiles its own [`crate::analytics::engine::PlanSpec`] context),
//! `lineitem` is range-partitioned, and the plan supplies the kernel and
//! the leader-side finalizer.

use crate::analytics::engine::{self, Merger, Partial};
use crate::analytics::morsel::DEFAULT_MORSEL_ROWS;
use crate::analytics::queries::Row;
use crate::analytics::tpch::TpchDb;
use crate::cluster::ClusterSpec;
use crate::coordinator::backpressure::Backpressure;
use crate::coordinator::scheduler::{Scheduler, Task, TaskKind};
use crate::error::{Error, Result};
use crate::exec::{parallel_map, JoinHandle, ThreadPool};
use crate::memsim::{simulate, WorkloadProfile};
use crate::rpc::Message;
use crate::simnet::Simulation;
use std::collections::VecDeque;
use std::time::Instant;

/// Distributed execution report: result rows + the simulated breakdown.
#[derive(Clone, Debug)]
pub struct DistQueryReport {
    pub query: String,
    pub rows: Vec<Row>,
    pub workers: usize,
    /// Simulated seconds of per-worker compute (map + reduce makespans).
    pub compute_secs: f64,
    /// Simulated seconds for the two shuffle phases (partition exchange
    /// + pre-merged partials to the leader).
    pub shuffle_secs: f64,
    /// Simulated seconds for reading input from disaggregated storage.
    pub io_secs: f64,
    /// Bytes crossing the fabric in the worker↔worker partition exchange
    /// (a worker's own partition stays local and is not counted).
    pub exchange_bytes: u64,
    /// Bytes shuffled leader-ward: the pre-merged reducer partials.
    pub shuffle_bytes: u64,
    /// Bytes read from storage.
    pub input_bytes: u64,
    /// Wall seconds this process actually spent computing partials
    /// (map + reduce phases).
    pub host_compute_secs: f64,
}

impl DistQueryReport {
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.shuffle_secs + self.io_secs
    }

    /// Normalized breakdown (cpu, shuffle, io).
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let t = self.total_secs().max(1e-12);
        (self.compute_secs / t, self.shuffle_secs / t, self.io_secs / t)
    }
}

/// Distributed query executor over a cluster spec.
pub struct DistributedQuery {
    pub cluster: ClusterSpec,
    /// Worker nodes to use (≤ cluster nodes; 0 = all).
    pub workers: usize,
    /// Local thread parallelism for computing the real partials
    /// (0 = all cores).
    pub threads: usize,
    /// Rows per morsel inside each worker's partition.
    pub morsel_rows: usize,
}

/// RPC method id for the shuffle wire protocol.
pub const METHOD_PARTIAL: u32 = 0x51;

/// Decode partial frames on `pool` and absorb them into `merger` in
/// frame order. A backpressure credit is held per admitted frame from
/// submission until its decoded partial has been merged, bounding
/// decoded-but-unmerged buffering. Credits are released on *every* path
/// — a decode or merge failure must not leak the credit out of a
/// long-lived gate (the leak regression test below drives this).
fn decode_and_merge(
    pool: &ThreadPool,
    credits: &Backpressure,
    frames: Vec<Vec<u8>>,
    merger: &mut Merger,
) -> Result<()> {
    let mut pending: VecDeque<JoinHandle<Result<Partial>>> = VecDeque::new();
    let mut result: Result<()> = Ok(());
    for frame in frames {
        // Admission: retire the oldest in-flight partial (merge order
        // stays frame order) until a credit frees up.
        while result.is_ok() && !credits.try_acquire() {
            let h = pending.pop_front().expect("credits exhausted with nothing pending");
            let r = h.join().and_then(|p| merger.absorb(&p));
            credits.release();
            result = result.and(r);
        }
        if result.is_err() {
            break;
        }
        pending.push_back(pool.submit(move || {
            Message::decode(&frame)
                .map_err(Error::msg)
                .and_then(|msg| Partial::decode(&msg.payload))
        }));
    }
    // Drain: release every remaining credit even after a failure.
    while let Some(h) = pending.pop_front() {
        let r = h.join().and_then(|p| merger.absorb(&p));
        credits.release();
        result = result.and(r);
    }
    result
}

/// Per-run inputs to the phase simulation.
struct PhaseInputs<'a> {
    input_bytes_each: u64,
    /// `[worker][reducer]` frame bytes of the partition exchange.
    exchange_pair_bytes: &'a [Vec<u64>],
    /// Per-reducer pre-merged frame bytes shipped to the leader.
    leader_bytes: &'a [u64],
    /// Measured host seconds per worker (map) and per reducer (reduce).
    worker_secs: &'a [f64],
    reduce_secs: &'a [f64],
    ht_bytes_each: u64,
    worker_nodes: &'a [usize],
}

impl DistributedQuery {
    pub fn new(cluster: ClusterSpec) -> Self {
        Self { cluster, workers: 0, threads: 0, morsel_rows: DEFAULT_MORSEL_ROWS }
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_morsel_rows(mut self, rows: usize) -> Self {
        self.morsel_rows = rows;
        self
    }

    fn n_workers(&self) -> usize {
        let n = self.cluster.num_nodes();
        if self.workers == 0 {
            n
        } else {
            self.workers.min(n)
        }
    }

    /// Contiguous row ranges of `len` over `w` workers.
    fn ranges(len: usize, w: usize) -> Vec<(usize, usize)> {
        let chunk = len.div_ceil(w.max(1));
        (0..w)
            .map(|i| ((i * chunk).min(len), ((i + 1) * chunk).min(len)))
            .collect()
    }

    /// Run any query from the Figure-3 set distributed across the
    /// cluster's workers. Result rows `approx_eq_rows` the single-node
    /// reference of [`crate::analytics::run_query`].
    pub fn run(&self, db: &TpchDb, query: &str) -> Result<DistQueryReport> {
        let spec = engine::spec(query)
            .ok_or_else(|| crate::err!("query {query} has no distributed plan"))?;
        let w = self.n_workers();
        crate::ensure!(w >= 1, "cluster has no nodes");
        let n = db.lineitem.len();
        let ranges = Self::ranges(n, w);
        let rows_each = ranges.first().map(|(s, e)| e - s).unwrap_or(0);
        let input_bytes_each = if n == 0 {
            0
        } else {
            (db.lineitem.bytes() as f64 * rows_each as f64 / n as f64) as u64
        };

        // Map phase: each simulated NIC worker compiles its broadcast
        // context (dimension tables are replicated to every node), folds
        // its partition morsel by morsel through the shared engine
        // kernel, and hash-partitions the merged result into `w`
        // key-disjoint RPC frames, one per reducer.
        let morsel_rows = self.morsel_rows.max(1);
        let t0 = Instant::now();
        let indexed: Vec<(usize, (usize, usize))> = ranges.into_iter().enumerate().collect();
        let worker_out: Vec<Result<(Vec<(usize, Vec<u8>)>, f64, u64)>> =
            parallel_map(indexed, self.threads, |(wi, (lo, hi))| {
                let t = Instant::now();
                let (c, _prep) = (spec.compile)(db);
                let mut merger = Merger::new(spec.width);
                let mut morsel_ht_peak = 0u64;
                let mut s = lo;
                while s < hi {
                    let e = (s + morsel_rows).min(hi);
                    let p = engine::run_range(&c, spec.width, s, e);
                    // Morsels run sequentially within a worker, so the
                    // live working set is one morsel's hash table plus
                    // the accumulated merge state — not the sum of every
                    // transient table (which stats.ht_bytes records).
                    morsel_ht_peak = morsel_ht_peak.max(p.stats.ht_bytes);
                    merger.absorb(&p)?;
                    s = e;
                }
                let partial = merger.into_partial();
                let ht_bytes = morsel_ht_peak
                    + partial.len() as u64 * Partial::group_bytes(spec.width) as u64;
                // Empty partitions (single-group queries leave w-1 of
                // them) are not encoded or shipped — no real system
                // sends header-only frames.
                let frames: Vec<(usize, Vec<u8>)> = partial
                    .partition_by_key(w)
                    .iter()
                    .enumerate()
                    .filter(|(_, part)| !part.is_empty())
                    .map(|(p_idx, part)| {
                        let frame = Message {
                            method: METHOD_PARTIAL,
                            id: ((wi as u64) << 32) | p_idx as u64,
                            payload: part.encode(),
                        }
                        .encode();
                        (p_idx, frame)
                    })
                    .collect();
                Ok((frames, t.elapsed().as_secs_f64(), ht_bytes))
            });
        let host_map_secs = t0.elapsed().as_secs_f64();
        let mut frames_by_worker = Vec::with_capacity(w);
        let mut host_secs = Vec::with_capacity(w);
        let mut ht_bytes_each = 0u64;
        for r in worker_out {
            let (frames, secs, ht) = r?;
            ht_bytes_each = ht_bytes_each.max(ht);
            host_secs.push(secs);
            frames_by_worker.push(frames);
        }

        // Exchange: partition p of every worker goes to reducer p
        // (co-located with worker p). Frames regroup by reducer in
        // worker order, so every reducer's merge is deterministic.
        let mut exchange_pair_bytes = vec![vec![0u64; w]; w];
        let mut by_reducer: Vec<Vec<Vec<u8>>> = (0..w).map(|_| Vec::with_capacity(w)).collect();
        for (wi, frames) in frames_by_worker.into_iter().enumerate() {
            for (p_idx, f) in frames {
                exchange_pair_bytes[wi][p_idx] = f.len() as u64;
                by_reducer[p_idx].push(f);
            }
        }
        let exchange_bytes: u64 = exchange_pair_bytes
            .iter()
            .enumerate()
            .map(|(wi, row)| {
                row.iter()
                    .enumerate()
                    .filter(|(p, _)| *p != wi)
                    .map(|(_, b)| *b)
                    .sum::<u64>()
            })
            .sum();

        // Reduce: each reducer decodes its w partition frames and
        // pre-merges them into one key-deduplicated partial for the
        // leader. This is the merge work the leader no longer does.
        let t1 = Instant::now();
        let reducer_in: Vec<(usize, Vec<Vec<u8>>)> = by_reducer.into_iter().enumerate().collect();
        let reducer_out: Vec<Result<(Option<Vec<u8>>, f64)>> =
            parallel_map(reducer_in, self.threads, |(p_idx, frames)| {
                let t = Instant::now();
                let mut m = Merger::new(spec.width);
                for f in &frames {
                    let msg = Message::decode(f).map_err(Error::msg)?;
                    m.absorb(&Partial::decode(&msg.payload)?)?;
                }
                let merged = m.into_partial();
                // A reducer whose partition is empty ships nothing.
                let frame = if merged.is_empty() {
                    None
                } else {
                    Some(
                        Message {
                            method: METHOD_PARTIAL,
                            id: p_idx as u64,
                            payload: merged.encode(),
                        }
                        .encode(),
                    )
                };
                Ok((frame, t.elapsed().as_secs_f64()))
            });
        let host_reduce_secs = t1.elapsed().as_secs_f64();
        let mut leader_bytes = vec![0u64; w];
        let mut leader_frames: Vec<Vec<u8>> = Vec::with_capacity(w);
        let mut reduce_secs = Vec::with_capacity(w);
        for (p_idx, r) in reducer_out.into_iter().enumerate() {
            let (f, s) = r?;
            reduce_secs.push(s);
            if let Some(f) = f {
                leader_bytes[p_idx] = f.len() as u64;
                leader_frames.push(f);
            }
        }
        let shuffle_bytes: u64 = leader_bytes.iter().sum();

        // Leader phase: decode the pre-merged, key-disjoint reducer
        // frames on the coordinator thread pool and merge in partition
        // order so the result is deterministic.
        let pool = ThreadPool::new(self.threads);
        let credits = Backpressure::new(pool.threads().max(1));
        let mut merger = Merger::new(spec.width);
        decode_and_merge(&pool, &credits, leader_frames, &mut merger)?;
        let merged = merger.into_partial();
        let rows: Vec<Row> = (spec.finalize)(db, &merged);

        // Place the worker tasks on cluster nodes (role-aware, balanced
        // by the measured per-worker seconds) so the simulated network
        // phases charge flows to the nodes that actually ran them.
        let mut sched = Scheduler::new(&self.cluster);
        let tasks: Vec<Task> = host_secs
            .iter()
            .enumerate()
            .map(|(id, &est)| Task { id, kind: TaskKind::Compute, est_secs: est.max(1e-9) })
            .collect();
        let placements = sched
            .place_all(&tasks)
            .ok_or_else(|| crate::err!("no eligible compute node for worker tasks"))?;
        let worker_nodes: Vec<usize> = placements.iter().map(|p| p.node_id).collect();

        let (compute_secs, shuffle_secs, io_secs) = self.simulate_phases(&PhaseInputs {
            input_bytes_each,
            exchange_pair_bytes: &exchange_pair_bytes,
            leader_bytes: &leader_bytes,
            worker_secs: &host_secs,
            reduce_secs: &reduce_secs,
            ht_bytes_each,
            worker_nodes: &worker_nodes,
        });
        Ok(DistQueryReport {
            query: query.to_string(),
            rows,
            workers: w,
            compute_secs,
            shuffle_secs,
            io_secs,
            exchange_bytes,
            shuffle_bytes,
            input_bytes: input_bytes_each * w as u64,
            host_compute_secs: host_map_secs + host_reduce_secs,
        })
    }

    /// Simulate the network phases and worker compute for a run where
    /// the worker on `worker_nodes[i]` scanned `input_bytes_each`,
    /// exchanged `exchange_pair_bytes[i][p]` with the reducer on
    /// `worker_nodes[p]`, and the reducers shipped `leader_bytes[p]` to
    /// the leader (node 0).
    fn simulate_phases(&self, ph: &PhaseInputs<'_>) -> (f64, f64, f64) {
        let topo = self.cluster.topology();
        let n = topo.num_nodes();

        // Phase 1 — storage read: each worker node pulls its partition
        // from a storage replica on a different node (disaggregated
        // storage).
        let mut io_sim = Simulation::new(topo.clone());
        for &node in ph.worker_nodes {
            let src = (node + n / 2) % n;
            if src != node && ph.input_bytes_each > 0 {
                io_sim.add_flow(src, node, ph.input_bytes_each as f64, 0.0);
            }
        }
        let io_secs = io_sim.run_makespan();

        // Phase 2 — compute: each worker node runs its partition across
        // all its cores; memsim gives the contention-adjusted speedup.
        // Map and reduce are sequential phases, so their scaled
        // makespans add.
        let platform = self.cluster.platform();
        let profile = WorkloadProfile {
            cpu_secs: 1.0, // shape only: we scale measured time below
            dram_bytes: (ph.input_bytes_each as f64).max(1.0),
            working_set_bytes: (ph.ht_bytes_each as f64).max(4e6),
        };
        let k = platform.vcpus;
        let r = simulate(platform, &profile, k);
        // Effective parallel speedup on the node vs one uncontended core.
        let single = simulate(platform, &profile, 1).per_core_rate;
        let speedup = (r.system_rate / single).max(1e-9);
        let host_to_platform = crate::analytics::profile::host_speed() / platform.st_speed;
        let scale = |h: &f64| h * host_to_platform / speedup;
        let map_secs = ph.worker_secs.iter().map(scale).fold(0.0, f64::max);
        let red_secs = ph.reduce_secs.iter().map(scale).fold(0.0, f64::max);
        let compute_secs = map_secs + red_secs;

        // Phase 3 — partition exchange: worker i → reducer p. A worker's
        // own partition stays on-node and adds no flow.
        let mut ex_sim = Simulation::new(topo.clone());
        for (wi, row) in ph.exchange_pair_bytes.iter().enumerate() {
            for (p, &b) in row.iter().enumerate() {
                let (src, dst) = (ph.worker_nodes[wi], ph.worker_nodes[p]);
                if src != dst && b > 0 {
                    ex_sim.add_flow(src, dst, b as f64, 0.0);
                }
            }
        }
        let exchange_secs = ex_sim.run_makespan();

        // Phase 4 — pre-merged reducer partials to the leader (node 0).
        let mut sh_sim = Simulation::new(topo);
        for (p, &b) in ph.leader_bytes.iter().enumerate() {
            let node = ph.worker_nodes[p];
            if node != 0 && b > 0 {
                sh_sim.add_flow(node, 0, b as f64, 0.0);
            }
        }
        let shuffle_secs = exchange_secs + sh_sim.run_makespan();
        (compute_secs, shuffle_secs, io_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::{self, QUERY_NAMES};
    use crate::analytics::tpch::TpchConfig;
    use crate::cluster::Role;
    use crate::platform::n2d_milan;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    #[test]
    fn every_query_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.005, 101));
        for q in QUERY_NAMES {
            let single = queries::run_query(&db, q).unwrap();
            let dist = DistributedQuery::new(cluster(4)).run(&db, q).unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "distributed {q} diverged ({} vs {} rows)",
                dist.rows.len(),
                single.rows.len()
            );
            // Empty partitions ship nothing, so leader-ward bytes are
            // only guaranteed when the query produced groups at all.
            if single.stats.rows_out > 0 {
                assert!(dist.shuffle_bytes > 0, "{q} shuffled nothing");
            }
            assert!(dist.compute_secs > 0.0, "{q} reported no compute");
        }
    }

    #[test]
    fn distributed_q1_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 101));
        let single = queries::q1::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "distributed q1 diverged");
        assert!(dist.shuffle_bytes > 0);
        assert!(dist.compute_secs > 0.0);
    }

    #[test]
    fn distributed_q6_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 103));
        let single = queries::q6::run(&db);
        let dist = DistributedQuery::new(cluster(8)).run(&db, "q6").unwrap();
        assert!(single.approx_eq_rows(&dist.rows));
    }

    #[test]
    fn distributed_q18_matches_single_node() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 107));
        let single = queries::q18::run(&db);
        let dist = DistributedQuery::new(cluster(4)).run(&db, "q18").unwrap();
        assert!(single.approx_eq_rows(&dist.rows), "q18 diverged");
        // q18 shuffles per-order sums: orders of magnitude more bytes
        // than q1's 4-group partials.
        let q1 = DistributedQuery::new(cluster(4)).run(&db, "q1").unwrap();
        assert!(dist.shuffle_bytes > 100 * q1.shuffle_bytes);
    }

    #[test]
    fn pre_merge_deduplicates_leaderward_bytes() {
        // Q1 has ~4 groups replicated in every worker's partial. After
        // the partition exchange the leader must receive each group
        // once, not once per worker — leader-ward bytes stay near one
        // partial's worth no matter how many workers ran.
        let db = TpchDb::generate(TpchConfig::new(0.002, 131));
        let r2 = DistributedQuery::new(cluster(2)).run(&db, "q1").unwrap();
        let r8 = DistributedQuery::new(cluster(8)).run(&db, "q1").unwrap();
        // Fixed per-frame overhead grows with w; group payload must not
        // multiply. 8 workers would ship ≥4× the groups of 2 workers
        // without pre-merge.
        assert!(
            r8.shuffle_bytes < 2 * r2.shuffle_bytes + 8 * 64,
            "leaderward bytes scale with workers: {} (8w) vs {} (2w)",
            r8.shuffle_bytes,
            r2.shuffle_bytes
        );
        // The exchange, by contrast, does grow with worker count.
        assert!(r8.exchange_bytes > r2.exchange_bytes);
    }

    #[test]
    fn morsel_size_does_not_change_results() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 211));
        let single = queries::q5::run(&db);
        for rows in [128, 4096, 1 << 22] {
            let dist = DistributedQuery::new(cluster(3))
                .with_morsel_rows(rows)
                .run(&db, "q5")
                .unwrap();
            assert!(
                single.approx_eq_rows(&dist.rows),
                "q5 diverged at morsel_rows={rows}"
            );
        }
    }

    #[test]
    fn unsupported_query_errors() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 109));
        assert!(DistributedQuery::new(cluster(2)).run(&db, "q99").is_err());
    }

    #[test]
    fn worker_count_caps_at_cluster() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 113));
        let r = DistributedQuery::new(cluster(3)).with_workers(64).run(&db, "q6").unwrap();
        assert_eq!(r.workers, 3);
    }

    #[test]
    fn lovelock_reduces_network_time() {
        // Same bytes, Lovelock φ=2 with 200G NICs vs servers with 100G:
        // shuffle+io time must shrink.
        let db = TpchDb::generate(TpchConfig::new(0.005, 127));
        let trad = cluster(4);
        let love = ClusterSpec::lovelock_e2000(&trad, 2);
        let rt = DistributedQuery::new(trad).run(&db, "q18").unwrap();
        let rl = DistributedQuery::new(love).run(&db, "q18").unwrap();
        assert!(rl.io_secs < rt.io_secs, "lovelock io {} vs trad {}", rl.io_secs, rt.io_secs);
        assert_eq!(rl.rows.len(), rt.rows.len());
    }

    #[test]
    fn ranges_cover_exactly() {
        let r = DistributedQuery::ranges(103, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 103);
        let total: usize = r.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, 103);
    }

    // ------------------------------------------- credit-leak regression

    fn frame_of(p: &Partial) -> Vec<u8> {
        Message { method: METHOD_PARTIAL, id: 0, payload: p.encode() }.encode()
    }

    #[test]
    fn decode_and_merge_absorbs_all_frames() {
        use crate::analytics::ops::ExecStats;
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(2);
        let frames: Vec<Vec<u8>> = (0..6)
            .map(|i| frame_of(&Partial::single(i, &[1.0], 1, ExecStats::default())))
            .collect();
        let mut merger = Merger::new(1);
        decode_and_merge(&pool, &credits, frames, &mut merger).unwrap();
        assert_eq!(credits.in_flight(), 0);
        let p = merger.into_partial();
        assert_eq!(p.len(), 6);
        assert_eq!(p.keys, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn decoder_error_releases_credits() {
        // Regression: a corrupt frame mid-stream used to leak the
        // credits of every in-flight partial (the error return skipped
        // `release`). The gate must read zero in-flight afterwards and
        // still admit new work.
        use crate::analytics::ops::ExecStats;
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(1); // capacity 1 forces retirement
        let good = |k: i64| frame_of(&Partial::single(k, &[1.0], 1, ExecStats::default()));
        let mut corrupt = good(99);
        // Truncate the payload: Message::decode succeeds (length prefix
        // rewritten) is avoided by cutting inside the frame instead.
        corrupt.truncate(corrupt.len() - 3);
        let frames = vec![good(1), corrupt, good(2), good(3)];
        let mut merger = Merger::new(1);
        let err = decode_and_merge(&pool, &credits, frames, &mut merger);
        assert!(err.is_err(), "corrupt frame must surface an error");
        assert_eq!(credits.in_flight(), 0, "error path leaked a credit");
        assert!(credits.try_acquire(), "gate must still admit work");
        credits.release();
    }

    #[test]
    fn merge_width_error_releases_credits() {
        use crate::analytics::ops::ExecStats;
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(2);
        // Width-2 partial into a width-1 merger: absorb fails.
        let bad = frame_of(&Partial::single(7, &[1.0, 2.0], 1, ExecStats::default()));
        let good = frame_of(&Partial::single(1, &[1.0], 1, ExecStats::default()));
        let mut merger = Merger::new(1);
        let err = decode_and_merge(&pool, &credits, vec![good, bad], &mut merger);
        assert!(err.is_err());
        assert_eq!(credits.in_flight(), 0, "merge error leaked a credit");
    }
}

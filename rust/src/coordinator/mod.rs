//! The Lovelock coordinator — the paper's system contribution at L3.
//!
//! A Lovelock cluster has no servers, so cluster-level coordination runs
//! *on* the smart NICs. This module implements the leader/worker runtime:
//!
//! * [`backpressure`] — credit-based admission so lite-compute nodes with
//!   16 cores and 48 GB are never overrun;
//! * [`scheduler`] — task placement over the node roles of a
//!   [`crate::cluster::ClusterSpec`];
//! * [`shuffle`] — the distributed query executor: partial aggregation on
//!   real data partitions (executed on a thread pool standing in for the
//!   worker fleet), wire-format partial results over the RPC substrate,
//!   and a shuffle/storage overlay on the fabric simulator that yields the
//!   Fig. 4-style time breakdown for any cluster spec.

pub mod backpressure;
pub mod scheduler;
pub mod shuffle;

pub use backpressure::Backpressure;
pub use scheduler::{Placement, Scheduler, Task, TaskKind};
pub use shuffle::{DistQueryReport, DistributedQuery};

//! The Lovelock coordinator — the paper's system contribution at L3.
//!
//! A Lovelock cluster has no servers, so cluster-level coordination runs
//! *on* the smart NICs. This module implements the leader/worker runtime:
//!
//! * [`backpressure`] — credit-based admission so lite-compute nodes with
//!   16 cores and 48 GB are never overrun (the distributed executor gates
//!   leader-side partial decoding on it);
//! * [`scheduler`] — task placement over the node roles of a
//!   [`crate::cluster::ClusterSpec`] (the distributed executor places its
//!   worker partitions through it);
//! * [`shuffle`] — the distributed query executor: morsel-driven partial
//!   aggregation on real data partitions (worker threads standing in for
//!   the NIC fleet), wire-format partial results over the RPC substrate,
//!   and a shuffle/storage overlay on the fabric simulator that yields the
//!   Fig. 4-style time breakdown for any cluster spec.
//!
//! Every TPC-H query runs distributed and produces the same rows as the
//! single-node engine:
//!
//! ```
//! use lovelock::analytics::{run_query, TpchConfig, TpchDb};
//! use lovelock::cluster::{ClusterSpec, Role};
//! use lovelock::coordinator::DistributedQuery;
//! use lovelock::platform::n2d_milan;
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 9));
//! let cluster = ClusterSpec::traditional(2, n2d_milan(), Role::LiteCompute);
//! let report = DistributedQuery::new(cluster).run(&db, "q6").unwrap();
//! let local = run_query(&db, "q6").unwrap();
//! assert_eq!(report.workers, 2);
//! assert!(local.approx_eq_rows(&report.rows));
//! ```

pub mod backpressure;
pub mod scheduler;
pub mod shuffle;

pub use backpressure::Backpressure;
pub use scheduler::{Placement, Scheduler, Task, TaskKind};
pub use shuffle::{DistQueryReport, DistributedQuery};

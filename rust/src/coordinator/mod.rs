//! The Lovelock coordinator — the paper's system contribution at L3.
//!
//! A Lovelock cluster has no servers, so cluster-level coordination runs
//! *on* the smart NICs — which means leader and workers can only talk
//! through messages on the fabric. This module implements that runtime:
//!
//! * [`protocol`] — the typed leader↔worker wire frames (`PlanFragment`,
//!   `ExecuteRange`, `PartialFrame`, `Ack`, `ReduceCmd`, `CancelQuery`)
//!   with exact-inverse codecs layered on [`crate::rpc::Message`];
//! * [`service`] — [`QueryService`]: submit/poll/wait/cancel sessions
//!   under which every byte crossing the leader/worker boundary is a
//!   real encoded message dispatched through [`crate::rpc::Endpoint`]
//!   handlers, and any number of queries interleave over the shared
//!   scheduler, backpressure credits, and decode pool;
//! * fault tolerance — the service survives worker death and packet
//!   loss: a lease monitor pings workers, declares silent ones dead,
//!   and re-executes their fragments on survivors under a bumped epoch
//!   (deterministic folds make re-execution idempotent; reducers dedup
//!   frames on `(query, worker, partition, epoch)`). Chaos runs are
//!   replayable: [`ChaosConfig`] seeds a [`crate::rpc::FaultPlan`] on
//!   every endpoint. See DESIGN.md §3d for the failure model;
//! * [`backpressure`] — credit-based admission so lite-compute nodes with
//!   16 cores and 48 GB are never overrun (the leader gates partial
//!   decoding on it);
//! * [`scheduler`] — task placement over the node roles of a
//!   [`crate::cluster::ClusterSpec`] (worker tasks of concurrent queries
//!   spread over its least-loaded nodes);
//! * [`shuffle`] — the one-shot compatibility wrapper:
//!   [`DistributedQuery::run`] = `submit` + `wait`.
//!
//! Every TPC-H query runs distributed and produces the same rows as the
//! single-node engine:
//!
//! ```
//! use lovelock::analytics::{run_query, TpchConfig, TpchDb};
//! use lovelock::cluster::{ClusterSpec, Role};
//! use lovelock::coordinator::DistributedQuery;
//! use lovelock::platform::n2d_milan;
//! use std::sync::Arc;
//!
//! let db = Arc::new(TpchDb::generate(TpchConfig::new(0.001, 9)));
//! let cluster = ClusterSpec::traditional(2, n2d_milan(), Role::LiteCompute);
//! let report = DistributedQuery::new(cluster).run(&db, "q6").unwrap();
//! let local = run_query(&db, "q6").unwrap();
//! assert_eq!(report.workers, 2);
//! assert!(local.approx_eq_rows(&report.rows));
//! ```

pub mod backpressure;
pub mod loadgen;
pub mod protocol;
pub mod scheduler;
pub mod service;
pub mod shuffle;

pub use backpressure::Backpressure;
pub use loadgen::{run_load, LoadMode, LoadReport, LoadSpec};
pub use protocol::QueryId;
pub use scheduler::{DrrQueue, Placement, Scheduler, Task, TaskKind};
pub use service::{
    AdmissionConfig, ChaosConfig, DistQueryReport, FailCause, KillPhase, QueryService,
    QueryStatus, ServiceConfig, ShedReason, Submission, SubmitOpts,
};
pub use shuffle::DistributedQuery;

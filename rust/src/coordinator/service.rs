//! `QueryService` — the message-native distributed query service.
//!
//! The paper's premise is that every "server" is a headless smart NIC:
//! the leader can only reach a worker with a message on the fabric. This
//! module is the coordinator's L3 rebuilt on that constraint. Leader and
//! workers are [`crate::rpc::Endpoint`]s (one single-threaded dispatch
//! core each, like the §6 measurement) that communicate **exclusively**
//! through the typed frames of [`super::protocol`]; every partial
//! aggregate that crosses the leader/worker or worker/worker boundary is
//! a real encoded [`crate::rpc::Message`], and the observed frame bytes
//! are what the fabric simulator charges. The *computation itself* is
//! data too: a [`PlanFragment`] carries an encoded
//! [`LogicalPlan`] and the worker compiles whatever IR arrives —
//! [`QueryService::submit_plan`] runs a plan no registry has ever heard
//! of exactly like a TPC-H classic.
//!
//! The API is submit/poll/wait/cancel rather than one blocking call, so
//! any number of queries interleave over the shared [`Scheduler`],
//! [`Backpressure`] credits, and decode [`ThreadPool`]:
//!
//! ```
//! use lovelock::analytics::{run_query, TpchConfig, TpchDb};
//! use lovelock::cluster::{ClusterSpec, Role};
//! use lovelock::coordinator::QueryService;
//! use lovelock::platform::n2d_milan;
//! use std::sync::Arc;
//!
//! let db = Arc::new(TpchDb::generate(TpchConfig::new(0.001, 9)));
//! let cluster = ClusterSpec::traditional(2, n2d_milan(), Role::LiteCompute);
//! let svc = QueryService::new(cluster);
//! let a = svc.submit(&db, "q6").unwrap();
//! let b = svc.submit(&db, "q1").unwrap();
//! let (rows_b, _) = svc.wait(b).unwrap();
//! let (rows_a, _) = svc.wait(a).unwrap();
//! assert!(run_query(&db, "q6").unwrap().approx_eq_rows(&rows_a));
//! assert!(run_query(&db, "q1").unwrap().approx_eq_rows(&rows_b));
//! ```
//!
//! **State machines.** Worker `i` (per query): `Idle → Planned
//! (PlanFragment) → Mapped (ExecuteRange: fold the range morsel by
//! morsel, hash-partition, cast PartialFrames to reducers, cast Ack to
//! leader)`; as reducer `i`: `Collecting (buffer PartialFrames) →
//! Reduced (ReduceCmd names the expected workers; pre-merge in worker
//! order, cast the deduplicated partial to the leader)`. Leader (per
//! query): `Mapping (await w Acks) → Reducing (await one PartialFrame
//! per non-empty partition) → Done (decode behind backpressure credits,
//! merge in partition order, finalize, simulate the phase network)`.
//! Cancellation takes effect at frame boundaries — the granularity a
//! single-dispatch-core NIC actually has.
//!
//! The input tables are *not* messaged: workers read their range of the
//! shared, immutably attached [`TpchDb`] in place (the disaggregated
//! storage attach of §5.2, whose read cost is charged by the IO phase of
//! the simulation). Everything derived from the data crosses as frames.

use crate::analytics::engine::plan::{self as planir, FinalizeSpec};
use crate::analytics::engine::{self, LogicalPlan, Merger, Partial, TaskScratch};
use crate::analytics::morsel::DEFAULT_MORSEL_ROWS;
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::Row;
use crate::analytics::tpch::{gen as tpchgen, TpchDb};
use crate::cluster::ClusterSpec;
use crate::coordinator::backpressure::Backpressure;
use crate::coordinator::protocol::{
    Ack, CancelQuery, ExecuteRange, Heartbeat, PartialFrame, Ping, PlanFragment, Progress,
    QueryId, ReduceCmd, ReleaseQuery, ResendPartition, CHAOS_METHODS, METHOD_ACK, METHOD_CANCEL,
    METHOD_EXECUTE, METHOD_HEARTBEAT, METHOD_PARTIAL, METHOD_PING, METHOD_PLAN, METHOD_PROGRESS,
    METHOD_REDUCE, METHOD_RELEASE, METHOD_RESEND,
};
use crate::coordinator::scheduler::{DrrQueue, Scheduler, Task, TaskKind};
use crate::error::Result;
use crate::exec::{JoinHandle, ThreadPool};
use crate::memsim::{simulate, WorkloadProfile};
use crate::rpc::{BufPool, Client, Dispatch, Endpoint, FaultPlan, KillSpec};
use crate::simnet::Simulation;
use std::collections::{HashMap, HashSet, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Distributed execution report: result rows + the simulated breakdown.
#[derive(Clone, Debug)]
pub struct DistQueryReport {
    pub query: String,
    pub rows: Vec<Row>,
    pub workers: usize,
    /// Simulated seconds of per-worker compute (map + reduce makespans).
    pub compute_secs: f64,
    /// Simulated seconds for the two shuffle phases (partition exchange
    /// + pre-merged partials to the leader, control frames included).
    pub shuffle_secs: f64,
    /// Simulated seconds for reading input from disaggregated storage.
    pub io_secs: f64,
    /// Bytes crossing the fabric in the worker↔worker partition exchange
    /// (a worker's own partition stays local and is not counted).
    pub exchange_bytes: u64,
    /// Bytes shuffled leader-ward: the pre-merged reducer partials.
    pub shuffle_bytes: u64,
    /// Control-plane frame bytes (PlanFragment, ExecuteRange, ReduceCmd,
    /// Ack, CancelQuery) between leader and workers, both directions.
    pub control_bytes: u64,
    /// Bytes read from storage.
    pub input_bytes: u64,
    /// Host seconds spent computing partials: slowest map + slowest
    /// reduce, i.e. the critical path through this process's fold work.
    pub host_compute_secs: f64,
    /// Repair rounds the leader ran to finish this query (0 = clean
    /// run; each round bumps the execution epoch and re-executes the
    /// fragments whose valid ack is missing).
    pub repairs: u32,
    /// Scan chunks skipped wholesale across all workers via zone-map
    /// pruning (summed from the map acks).
    pub morsels_pruned: u64,
}

impl DistQueryReport {
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.shuffle_secs + self.io_secs
    }

    /// Normalized breakdown (cpu, shuffle, io).
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let t = self.total_secs().max(1e-12);
        (self.compute_secs / t, self.shuffle_secs / t, self.io_secs / t)
    }
}

/// Why a terminal query failed. `wait()` renders this into its error;
/// callers that need to distinguish a deadline expiry from a real
/// execution error match on [`QueryStatus::Failed`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FailCause {
    /// The query's deadline passed before it finished (see
    /// [`SubmitOpts::deadline`] / [`ServiceConfig::default_deadline_ms`]).
    Timeout,
    /// A worker or leader-side execution error.
    Error(String),
}

impl fmt::Display for FailCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FailCause::Timeout => write!(f, "timed out (deadline exceeded)"),
            FailCause::Error(e) => write!(f, "{e}"),
        }
    }
}

/// Lifecycle snapshot of one submitted query (see [`QueryService::poll`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueryStatus {
    /// The id was never issued by this service (or predates it).
    Unknown,
    /// Admitted but not yet dispatched: waiting for a dispatch slot in
    /// the fair (deficit-round-robin) queue.
    Queued,
    /// Map phase: `acked` of `workers` map reports are in.
    Mapping { acked: usize, workers: usize },
    /// Exchange/reduce phase: `received` of `expected` pre-merged
    /// partition frames have reached the leader.
    Reducing { received: usize, expected: usize },
    Done,
    Failed(FailCause),
    Cancelled,
    /// Shed by the admission controller — the query never ran and holds
    /// no resources. Remembered in a bounded ring; very old shed ids
    /// eventually read as `Unknown` again.
    Rejected,
}

/// Why the admission controller shed a submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Live (queued + executing) queries at the configured ceiling.
    InFlight { live: usize, max: usize },
    /// Leader-side buffered partial bytes over the watermark.
    BufferedBytes { bytes: u64, max: u64 },
    /// Decode-gate credits below the floor: the leader is saturated.
    Credits { free: usize, min: usize },
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShedReason::InFlight { live, max } => {
                write!(f, "overloaded: {live} queries in flight (max {max})")
            }
            ShedReason::BufferedBytes { bytes, max } => {
                write!(f, "overloaded: {bytes} buffered bytes (max {max})")
            }
            ShedReason::Credits { free, min } => {
                write!(f, "overloaded: {free} decode credits free (min {min})")
            }
        }
    }
}

/// Outcome of a submission under admission control (see
/// [`QueryService::try_submit_plan`]). Shedding is **explicit and
/// load-bounded**: a shed query was never buffered, placed, or cast —
/// the service holds nothing for it beyond a slot in a bounded
/// rejected-id ring so `poll` can answer [`QueryStatus::Rejected`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Submission {
    Admitted(QueryId),
    Shed { id: QueryId, reason: ShedReason },
}

impl Submission {
    /// The id either way (shed ids are real ids: they poll as Rejected).
    pub fn id(&self) -> QueryId {
        match self {
            Submission::Admitted(id) => *id,
            Submission::Shed { id, .. } => *id,
        }
    }
}

/// Admission-control thresholds. Each gate is independent and `0`
/// disables it, so the zero default admits everything (the pre-overload
/// behavior). Gates are checked at submit time, under the leader state
/// lock — admission is serialized with completion, so the counts it
/// reads are exact, not racy snapshots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Max live (queued + executing) queries (0 = unlimited).
    pub max_in_flight: usize,
    /// Max leader-side buffered partial bytes (0 = unlimited).
    pub max_buffered_bytes: u64,
    /// Min free decode credits required to admit (0 = don't check).
    pub min_free_credits: usize,
}

/// Per-submission options (see [`QueryService::submit_opts`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// Fair-scheduling key: dispatch slots are shared deficit-round-
    /// robin across sessions, so one heavy session cannot starve the
    /// rest. Sessions are caller-defined (0 is a perfectly good default
    /// for single-tenant use).
    pub session: u64,
    /// Per-query deadline, overriding
    /// [`ServiceConfig::default_deadline_ms`]. Expires the query to
    /// [`FailCause::Timeout`] with full cleanup wherever it is in its
    /// lifecycle — queued, mapping, or reducing.
    pub deadline: Option<Duration>,
}

/// Service tuning (all fields have sensible zero-ish defaults).
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker endpoints to spin up (0 = one per cluster node).
    pub workers: usize,
    /// Leader decode-pool threads (0 = all cores).
    pub threads: usize,
    /// Rows per morsel inside each worker's fold.
    pub morsel_rows: usize,
    /// Monitor ping interval in milliseconds (0 = 20ms). The lease
    /// monitor only runs at all when `chaos` is set or one of
    /// `heartbeat_ms`/`lease_ms` is non-zero, so a default-config
    /// service behaves byte-for-byte as before.
    pub heartbeat_ms: u64,
    /// A worker that has not been heard from for this long is declared
    /// dead and its fragments re-executed (0 = 8 × heartbeat).
    pub lease_ms: u64,
    /// Deterministic fault injection (see [`ChaosConfig`]); also turns
    /// on the lease monitor and worker-side partition-body retention.
    pub chaos: Option<ChaosConfig>,
    /// Load-shedding thresholds (all-zero default = admit everything).
    pub admission: AdmissionConfig,
    /// Deadline applied to every query that doesn't carry its own via
    /// [`SubmitOpts`] (0 = none). A non-zero value arms the monitor
    /// thread in deadline-only mode even without chaos/lease config.
    pub default_deadline_ms: u64,
    /// Max queries dispatched to the fabric at once; further admitted
    /// queries wait in the fair queue (0 = dispatch immediately on
    /// submit, the pre-overload behavior).
    pub max_dispatched: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            threads: 0,
            morsel_rows: DEFAULT_MORSEL_ROWS,
            heartbeat_ms: 0,
            lease_ms: 0,
            chaos: None,
            admission: AdmissionConfig::default(),
            default_deadline_ms: 0,
            max_dispatched: 0,
        }
    }
}

/// Where a chaos kill fires inside a worker's per-query state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillPhase {
    /// The endpoint dies on its first `ExecuteRange` — before the map
    /// fold runs, so neither partials nor the ack ever leave it.
    MidMap,
    /// The endpoint dies on its first `ReduceCmd` — after it acked its
    /// map, so the leader must invalidate a *successful* ack and
    /// re-home the partition.
    MidReduce,
}

/// Deterministic chaos: every run with the same seed and kill spec
/// replays the same fault schedule (see [`crate::rpc::FaultPlan`]).
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Seeds a random drop/duplicate/delay schedule on every endpoint
    /// (each derives its own stream). 0 = no random faults (kill only).
    pub seed: u64,
    /// Kill worker `.0`'s endpoint at the given phase.
    pub kill: Option<(u32, KillPhase)>,
}

// --------------------------------------------------------------- worker

/// Marker a worker puts in its error ack when it abandons a fold whose
/// dispatched deadline passed. The leader maps errors carrying it to
/// [`FailCause::Timeout`] so the caller sees the same typed cause no
/// matter which side noticed the expiry first.
const DEADLINE_MSG: &str = "deadline exceeded";

/// Per-query state a worker holds between PlanFragment and ExecuteRange:
/// the **decoded logical plan** — computation that arrived over the
/// fabric, not code baked into the worker.
struct PlanState {
    plan: LogicalPlan,
    morsel_rows: usize,
    workers: usize,
    /// Remaining time budget the leader computed at dispatch (0 = no
    /// deadline). Checked at morsel boundaries so an expired query
    /// stops burning this worker's single dispatch core mid-fold.
    deadline_ms: u64,
    db: Arc<TpchDb>,
}

/// Per-partition state a worker holds in its reducer role. Keyed by
/// `(QueryId, partition)` — after a repair re-homes partitions, one
/// endpoint can reduce several of them.
struct ReduceState {
    /// `(worker, epoch)` pairs to await (set by ReduceCmd; None until
    /// it arrives). A repair round's ReduceCmd overwrites this with the
    /// substitute senders' epochs.
    expect: Option<Vec<(u32, u32)>>,
    /// Buffered partition bodies keyed by `(sending worker, epoch)`:
    /// the idempotence point of the failure model. Duplicate frames
    /// (chaos, resends) land on the same key; superseded attempts land
    /// on keys no expectation names.
    got: HashMap<(u32, u32), Vec<u8>>,
}

/// A finished map execution a worker retains: the epoch dedups repeated
/// `ExecuteRange`s, and (fault-tolerant services only) the encoded
/// partition bodies let [`ResendPartition`] re-route the exchange to a
/// substitute reducer without re-running the fold.
struct Executed {
    epoch: u32,
    /// Indexed by partition; empty when retention is off.
    part_bodies: Vec<Vec<u8>>,
}

/// One worker node's endpoint state — everything its handlers touch.
struct WorkerShared {
    wi: u32,
    /// Query → attached input tables (the storage layer; see module docs).
    catalog: Arc<Mutex<HashMap<QueryId, Arc<TpchDb>>>>,
    plans: Mutex<HashMap<QueryId, PlanState>>,
    reduces: Mutex<HashMap<(QueryId, u32), ReduceState>>,
    /// Completed map executions by `(query, logical fragment)`, bounded
    /// FIFO (same eviction discipline as `cancelled`).
    executed: Mutex<(HashMap<(QueryId, u32), Executed>, VecDeque<(QueryId, u32)>)>,
    /// Retain partition bodies in `executed` for resend. Off for
    /// default-config services, preserving the allocation-free map
    /// steady state.
    retain: bool,
    /// Mid-fold progress-beat interval in ms (0 = off; set to the
    /// monitor's heartbeat on fault-tolerant services). A fold is the
    /// one place a worker's single dispatch core goes silent for longer
    /// than a lease — pings queue behind it unanswered — so the fold
    /// itself casts [`Progress`] beats at morsel boundaries to renew
    /// the lease and the query's stall clock. Without this, any fold
    /// longer than the lease is declared dead, re-executed, and expires
    /// again: a livelock that burns every repair round.
    progress_ms: u64,
    /// Cancelled/released ids (set + insertion order, oldest evicted
    /// first so the bound never wipes a *recently* closed id whose
    /// frames are still in flight).
    cancelled: Mutex<(HashSet<QueryId>, VecDeque<QueryId>)>,
    /// Clients to every worker endpoint (self included), leader-wired
    /// after all endpoints exist.
    peers: OnceLock<Vec<Client>>,
    leader: OnceLock<Client>,
    /// Body-buffer free list: partial encodings are built in recycled
    /// buffers before being framed into the destination endpoint's own
    /// pool, so a worker serving a query stream stops allocating
    /// exchange bodies after warm-up.
    bufs: BufPool,
}

impl WorkerShared {
    fn leader(&self) -> &Client {
        // lint: allow(no-panic-worker) wired once at startup, before the endpoint serves frames
        self.leader.get().expect("leader client not wired")
    }

    fn peers(&self) -> &[Client] {
        // lint: allow(no-panic-worker) wired once at startup, before the endpoint serves frames
        self.peers.get().expect("peer clients not wired")
    }

    fn is_cancelled(&self, qid: QueryId) -> bool {
        self.cancelled.lock().unwrap().0.contains(&qid)
    }

    /// Report a worker-side failure to the leader as an error Ack
    /// (epoch 0: the leader fails the query on *any* error ack while it
    /// is in flight — worker-side errors are deterministic, so a stale
    /// epoch would fail identically re-executed).
    fn ack_error(&self, qid: QueryId, msg: String) {
        let ack = Ack {
            query_id: qid,
            worker: self.wi,
            epoch: 0,
            map_ns: 0,
            ht_bytes: 0,
            morsels_pruned: 0,
            part_bytes: Vec::new(),
            error: msg,
        };
        let _ = self.leader().cast_frame(METHOD_ACK, |out| ack.encode_into(out));
    }

    fn on_plan(&self, pf: PlanFragment) {
        if self.is_cancelled(pf.query_id) {
            return;
        }
        let db = match self.catalog.lock().unwrap().get(&pf.query_id) {
            Some(db) => Arc::clone(db),
            None => {
                self.ack_error(pf.query_id, format!("{}: no storage attached", pf.query_id));
                return;
            }
        };
        // Decode the wire IR here, at frame-arrival time: a malformed
        // plan is an error Ack, never a worker panic.
        let plan = match LogicalPlan::decode(&pf.plan) {
            Ok(p) => p,
            Err(e) => {
                self.ack_error(pf.query_id, format!("{}: bad plan frame: {e}", pf.query_id));
                return;
            }
        };
        self.plans.lock().unwrap().insert(
            pf.query_id,
            PlanState {
                plan,
                morsel_rows: (pf.morsel_rows as usize).max(1),
                workers: pf.workers as usize,
                deadline_ms: pf.deadline_ms,
                db,
            },
        );
    }

    fn on_execute(&self, ex: ExecuteRange) {
        let qid = ex.query_id;
        if self.is_cancelled(qid) {
            return;
        }
        {
            // Idempotence: the leader bumps the epoch on every repair,
            // so an ExecuteRange at an epoch we already ran is a
            // duplicate (chaos) or a superseded re-send — drop it.
            let g = self.executed.lock().unwrap();
            if g.0.get(&(qid, ex.worker)).is_some_and(|d| d.epoch >= ex.epoch) {
                return;
            }
        }
        // Holding `plans` across the fold is safe: every handler of this
        // endpoint runs on its single serve thread, so the lock is
        // uncontended and the plan stays put for repeat executions.
        let plans = self.plans.lock().unwrap();
        let Some(plan) = plans.get(&qid) else {
            // The PlanFragment was lost in flight (chaos): stay silent —
            // the leader's lease repair re-sends plan + range together.
            return;
        };
        match self.map_fold(plan, &ex) {
            Ok((ack, done)) => {
                drop(plans);
                {
                    let mut g = self.executed.lock().unwrap();
                    let (map, order) = &mut *g;
                    if map.insert((qid, ex.worker), done).is_none() {
                        order.push_back((qid, ex.worker));
                    }
                    while order.len() > 1024 {
                        if let Some(old) = order.pop_front() {
                            map.remove(&old);
                        }
                    }
                }
                let _ = self.leader().cast_frame(METHOD_ACK, |out| ack.encode_into(out));
            }
            Err(e) => {
                drop(plans);
                self.ack_error(qid, e.to_string());
            }
        }
    }

    /// The map phase: fold the assigned range morsel by morsel through
    /// the shared engine kernel into ONE long-lived aggregation table
    /// (no per-morsel table + merge — the allocation-free steady state
    /// the counting-allocator regression test pins down), hash-partition
    /// the result, cast the non-empty partitions to their reducers from
    /// pooled frame buffers, and report to the leader (partition frame
    /// bytes, map time, table footprint).
    fn map_fold(&self, plan: &PlanState, ex: &ExecuteRange) -> Result<(Ack, Executed)> {
        let qid = ex.query_id;
        let (lo, hi) = (ex.lo as usize, ex.hi as usize);
        let t = Instant::now();
        // Lease renewal while this core is occupied (see `progress_ms`).
        // One beat up front covers shard generation + compile, the rest
        // fire at morsel boundaries.
        let beat = || {
            let pr = Progress {
                query_id: qid,
                endpoint: self.wi,
                worker: ex.worker,
                epoch: ex.epoch,
            };
            let _ = self.leader().cast_frame(METHOD_PROGRESS, |out| pr.encode_into(out));
        };
        if self.progress_ms > 0 {
            beat();
        }
        // Compile whatever IR arrived — the worker has no query registry
        // to consult, exactly as a headless NIC receiving its program
        // over the fabric. A plan the leader invented five seconds ago
        // runs the same as a TPC-H classic.
        //
        // Lineitem scans never receive table bytes: the worker *streams
        // its own shard into existence* from the deterministic
        // per-shard generator (bitwise-identical to the same rows of a
        // full generation) and folds it locally, zone maps included.
        // Dimension builds still resolve against the attached catalog.
        let shard;
        let (c, fold_lo, fold_hi) = if plan.plan.scan == planir::TableRef::Lineitem {
            shard = tpchgen::lineitem_shard(&plan.db.config, lo, hi);
            let (c, _prep) = planir::compile_scan(&plan.db, &plan.plan, &shard, true)?;
            (c, 0, shard.len())
        } else {
            let (c, _prep) = planir::compile(&plan.db, &plan.plan)?;
            (c, lo, hi)
        };
        let width = plan.plan.width();
        let mut agg = engine::agg_for(&c, width, fold_hi - fold_lo);
        let mut scr = TaskScratch::new();
        let mut stats = ExecStats::default();
        let mut s = fold_lo;
        let mut last_beat = Instant::now();
        while s < fold_hi {
            let e = (s + plan.morsel_rows).min(fold_hi);
            engine::fold_range(&c, width, s, e, &mut agg, &mut scr, &mut stats);
            s = e;
            if s < fold_hi {
                // The morsel boundary is the granularity a fold can
                // react at: enforce the dispatched deadline (don't burn
                // the core for a query the leader will discard) and
                // renew the lease.
                let elapsed_ms = t.elapsed().as_millis() as u64;
                if plan.deadline_ms > 0 && elapsed_ms > plan.deadline_ms {
                    crate::bail!("{DEADLINE_MSG} mid-fold after {} rows", s - fold_lo);
                }
                if self.progress_ms > 0
                    && last_beat.elapsed().as_millis() as u64 >= self.progress_ms
                {
                    last_beat = Instant::now();
                    beat();
                }
            }
        }
        let partial = engine::finish_fold(agg, stats);
        // One live table for the whole fold: its footprint IS the
        // worker's aggregation working set.
        let ht_bytes = partial.stats.ht_bytes;
        // Empty partitions (single-group queries leave w-1 of them) are
        // never encoded or shipped — no real system sends header-only
        // frames. The Ack's zero tells the leader not to expect them.
        let w = plan.workers;
        let mut part_bytes = vec![0u64; w];
        let mut part_bodies = vec![Vec::new(); if self.retain { w } else { 0 }];
        let mut body = self.bufs.get(0);
        for (p_idx, part) in partial.partition_by_key(w).iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            body.clear();
            part.encode_into(&mut body);
            // The leader's routing table sends partition p to its
            // (possibly re-homed) reducer endpoint; frames carry the
            // *logical* fragment index + epoch so reducers match them
            // against the leader's expectations wherever they execute.
            let dest = ex.route.get(p_idx).map(|&d| d as usize).unwrap_or(p_idx);
            let peers = self.peers();
            crate::ensure!(dest < peers.len(), "partition {p_idx} routed to unknown w{dest}");
            part_bytes[p_idx] = peers[dest].cast_frame(METHOD_PARTIAL, |out| {
                PartialFrame::encode_parts_into(qid, p_idx as u32, ex.worker, ex.epoch, 0, &body, out);
            })? as u64;
            if self.retain {
                part_bodies[p_idx] = body.clone();
            }
        }
        self.bufs.put(body);
        Ok((
            Ack {
                query_id: qid,
                worker: ex.worker,
                epoch: ex.epoch,
                // Clamped ≥ 1 ns: a measured phase never reports zero, so
                // the simulated compute share cannot vanish on fast hosts.
                map_ns: (t.elapsed().as_nanos() as u64).max(1),
                ht_bytes,
                morsels_pruned: partial.stats.morsels_pruned,
                part_bytes,
                error: String::new(),
            },
            Executed { epoch: ex.epoch, part_bodies },
        ))
    }

    fn on_partial(&self, pf: PartialFrame) {
        let qid = pf.query_id;
        if self.is_cancelled(qid) {
            return;
        }
        let key = (qid, pf.partition);
        {
            let mut g = self.reduces.lock().unwrap();
            let st = g
                .entry(key)
                .or_insert_with(|| ReduceState { expect: None, got: HashMap::new() });
            st.got.insert((pf.from_worker, pf.epoch), pf.body);
        }
        self.try_reduce(key);
    }

    fn on_reduce(&self, rc: ReduceCmd) {
        let qid = rc.query_id;
        if self.is_cancelled(qid) {
            return;
        }
        let key = (qid, rc.partition);
        {
            let mut g = self.reduces.lock().unwrap();
            let st = g
                .entry(key)
                .or_insert_with(|| ReduceState { expect: None, got: HashMap::new() });
            st.expect = Some(rc.expect);
        }
        self.try_reduce(key);
    }

    /// A repair re-routes the exchange: re-ship the retained body of one
    /// partition to a substitute reducer. A worker that never executed
    /// the fragment (or retained nothing) stays silent — the leader's
    /// next repair round escalates to re-execution.
    fn on_resend(&self, rs: ResendPartition) {
        if self.is_cancelled(rs.query_id) {
            return;
        }
        let (body, epoch) = {
            let g = self.executed.lock().unwrap();
            match g.0.get(&(rs.query_id, rs.worker)) {
                Some(done) => match done.part_bodies.get(rs.partition as usize) {
                    Some(b) if !b.is_empty() => (b.clone(), done.epoch),
                    _ => return,
                },
                None => return,
            }
        };
        let Some(peer) = self.peers().get(rs.to as usize) else { return };
        let _ = peer.cast_frame(METHOD_PARTIAL, |out| {
            PartialFrame::encode_parts_into(rs.query_id, rs.partition, rs.worker, epoch, 0, &body, out);
        });
    }

    /// If every expected partition frame is buffered, pre-merge them in
    /// worker order (deterministic) and ship one key-deduplicated
    /// partial to the leader.
    fn try_reduce(&self, key: (QueryId, u32)) {
        let st = {
            let mut g = self.reduces.lock().unwrap();
            let complete = match g.get(&key) {
                Some(st) => match &st.expect {
                    Some(e) => e.iter().all(|k| st.got.contains_key(k)),
                    None => false,
                },
                None => false,
            };
            if !complete {
                return;
            }
            // `complete` above proved the entry exists; a racing second
            // delivery between checks would make this None, so treat a
            // lost race as already-reduced rather than panicking.
            let Some(st) = g.remove(&key) else { return };
            st
        };
        if let Err(e) = self.pre_merge(key.0, key.1, st) {
            self.ack_error(key.0, e.to_string());
        }
    }

    fn pre_merge(&self, qid: QueryId, partition: u32, st: ReduceState) -> Result<()> {
        let t = Instant::now();
        // try_reduce only forwards states whose expect-set arrived; a
        // frame slipping through without one is a protocol violation a
        // hostile peer could trigger, so error-Ack instead of panicking.
        let Some(mut expect) = st.expect else {
            return Err(crate::err!("reduce state for {qid:?} p{partition} has no expect set"));
        };
        expect.sort_unstable();
        let mut merger: Option<Merger> = None;
        for k in &expect {
            let bytes = st
                .got
                .get(k)
                .ok_or_else(|| crate::err!("missing partition frame from worker {k}"))?;
            let p = Partial::decode(bytes)?;
            merger.get_or_insert_with(|| Merger::new(p.width)).absorb(&p)?;
        }
        let merged = match merger {
            Some(m) => m.into_partial(),
            None => return Ok(()), // nothing expected: nothing to ship
        };
        let mut body = self.bufs.get(0);
        merged.encode_into(&mut body);
        let reduce_ns = (t.elapsed().as_nanos() as u64).max(1);
        self.leader().cast_frame(METHOD_PARTIAL, |out| {
            PartialFrame::encode_parts_into(qid, partition, self.wi, 0, reduce_ns, &body, out);
        })?;
        self.bufs.put(body);
        Ok(())
    }

    /// A ping from the leader's monitor: answer with a heartbeat. The
    /// answer rides the same single-threaded dispatch as real work, so
    /// a dead (or wedged) endpoint stops heartbeating — that silence IS
    /// the failure signal.
    fn on_ping(&self, p: Ping) {
        let hb = Heartbeat { worker: self.wi, nonce: p.nonce };
        let _ = self.leader().cast_frame(METHOD_HEARTBEAT, |out| hb.encode_into(out));
    }

    /// Drop every per-query thing this endpoint holds.
    fn close(&self, qid: QueryId) {
        self.plans.lock().unwrap().remove(&qid);
        self.reduces.lock().unwrap().retain(|(q, _), _| *q != qid);
        let mut g = self.executed.lock().unwrap();
        let (map, order) = &mut *g;
        map.retain(|(q, _), _| *q != qid);
        order.retain(|(q, _)| *q != qid);
    }

    /// Mark an id closed so its late frames are discarded. Bounded
    /// memory: evict the *oldest* ids only — their frames have long
    /// drained; a stray late frame for an evicted id would merely
    /// recreate a plans/reduces entry that the next close (or nothing)
    /// cleans, never corrupt a live query (ids are never reused).
    fn mark_closed(&self, qid: QueryId) {
        let mut cc = self.cancelled.lock().unwrap();
        let (set, order) = &mut *cc;
        if set.insert(qid) {
            order.push_back(qid);
        }
        while order.len() > 4096 {
            if let Some(old) = order.pop_front() {
                set.remove(&old);
            }
        }
    }

    fn on_cancel(&self, c: CancelQuery) {
        self.close(c.query_id);
        self.mark_closed(c.query_id);
    }

    /// The leader finished the query: retention and straggler frames
    /// (duplicates, delayed resends) are dead weight — drop them all.
    fn on_release(&self, r: ReleaseQuery) {
        self.close(r.query_id);
        self.mark_closed(r.query_id);
    }
}

// --------------------------------------------------------------- leader

enum Phase {
    /// Admitted, waiting in the fair queue for a dispatch slot.
    Queued,
    Mapping,
    Reducing,
    Done,
    Failed(FailCause),
    Cancelled,
}

impl Phase {
    /// Still consuming resources (storage attach, scheduler load, a
    /// live/dispatch count)?
    fn is_live(&self) -> bool {
        matches!(self, Phase::Queued | Phase::Mapping | Phase::Reducing)
    }
}

struct AckInfo {
    /// Epoch of the execution attempt this ack reports — reducers are
    /// told to expect frames carrying exactly this `(worker, epoch)`.
    epoch: u32,
    map_ns: u64,
    ht_bytes: u64,
    morsels_pruned: u64,
    part_bytes: Vec<u64>,
}

/// Repair rounds before the leader gives up on a query. Bounds every
/// `wait()` under arbitrary fault schedules: each round either finishes
/// the query or burns one of these.
const MAX_REPAIRS: u32 = 32;

/// Leader-side protocol state of one query.
struct QueryState {
    query: String,
    width: usize,
    finalize: FinalizeSpec,
    /// Dropped at completion so a long-lived service does not pin dbs.
    db: Option<Arc<TpchDb>>,
    phase: Phase,
    /// Fair-scheduling key this query was submitted under.
    session: u64,
    /// DRR cost: total estimated fold seconds across fragments.
    cost: f64,
    /// Absolute expiry instant (submit time + deadline), if any.
    deadline: Option<Instant>,
    /// Holds one of the `max_dispatched` slots (flipped by dispatch,
    /// cleared by the terminal transition).
    dispatched: bool,
    /// Monotone dispatch order, assigned when the query leaves the
    /// queue (observability; fairness tests assert on it).
    dispatch_seq: Option<u64>,
    /// Bytes of pre-merged partial bodies currently buffered for this
    /// query (counted into the service-wide gauge; drained on every
    /// terminal path).
    buf_bytes: u64,
    w: usize,
    worker_nodes: Vec<usize>,
    est_secs: Vec<f64>,
    input_bytes_each: u64,
    /// Current execution epoch: bumped on every repair round so stale
    /// acks and partials from superseded attempts are recognizable.
    epoch: u32,
    /// Physical endpoint currently executing logical fragment `l`
    /// (identity until a repair re-homes a dead worker's fragment).
    assign: Vec<usize>,
    /// Physical endpoint currently reducing partition `p` — the routing
    /// table shipped inside every ExecuteRange.
    red_assign: Vec<u32>,
    /// Epoch each fragment's next valid ack must carry.
    want_epoch: Vec<u32>,
    repairs: u32,
    /// Last ack/partial arrival (or repair) — the stall detector's clock.
    last_progress: Instant,
    /// Retained so repair can re-cast PlanFragment + ExecuteRange.
    plan_bytes: Vec<u8>,
    ranges: Vec<(u64, u64)>,
    morsel_rows: u64,
    acks: Vec<Option<AckInfo>>,
    acked: usize,
    expected_reducers: usize,
    reducer_got: usize,
    /// Per partition: (partial body, reduce ns, wire bytes).
    reducer_frames: Vec<Option<(Vec<u8>, u64, u64)>>,
    control_to: Vec<u64>,
    control_from: Vec<u64>,
    /// Leader's view of the conversation, in order (for tests/debugging).
    trace: Vec<String>,
    /// Set at completion (result rows live inside, once). The heavy
    /// per-phase buffers (`acks`, `reducer_frames`) are cleared then, so
    /// a finished query retains only its rows, report, and trace.
    result: Option<DistQueryReport>,
}

impl QueryState {
    fn status(&self) -> QueryStatus {
        match &self.phase {
            Phase::Queued => QueryStatus::Queued,
            Phase::Mapping => QueryStatus::Mapping { acked: self.acked, workers: self.w },
            Phase::Reducing => QueryStatus::Reducing {
                received: self.reducer_got,
                expected: self.expected_reducers,
            },
            Phase::Done => QueryStatus::Done,
            Phase::Failed(e) => QueryStatus::Failed(e.clone()),
            Phase::Cancelled => QueryStatus::Cancelled,
        }
    }
}

/// Bound on the rejected-id ring: shedding must not itself buffer
/// unboundedly, so only this many recently shed ids poll as `Rejected`
/// (older ones age back to `Unknown`). Same discipline as the workers'
/// cancelled-id ring.
const REJECTED_RING: usize = 4096;

/// Everything behind the leader's one state lock: the query table plus
/// the fair dispatch queue. One lock for both means admission, dispatch
/// and completion serialize — the gates read exact counts.
struct LeaderState {
    map: HashMap<QueryId, QueryState>,
    /// Admitted-but-undispatched ids, deficit-round-robin over sessions.
    queue: DrrQueue<QueryId>,
    /// Recently shed ids (set + insertion order, oldest evicted first).
    rejected: HashSet<QueryId>,
    rejected_order: VecDeque<QueryId>,
    /// Monotone dispatch counter (source of `QueryState::dispatch_seq`).
    next_dispatch_seq: u64,
}

impl LeaderState {
    fn note_rejected(&mut self, id: QueryId) {
        if self.rejected.insert(id) {
            self.rejected_order.push_back(id);
        }
        while self.rejected_order.len() > REJECTED_RING {
            if let Some(old) = self.rejected_order.pop_front() {
                self.rejected.remove(&old);
            }
        }
    }
}

/// Everything the leader endpoint's handlers touch.
///
/// Lock order (enforced by `lovelock lint`, rule `lock-order`):
/// `queries` < `dead` < `sched`, and `last_heard` is leaf-only — it is
/// stamped by every worker frame, so nothing may be acquired while it
/// is held. `catalog` is unordered: it is only ever taken alone.
struct LeaderShared {
    cluster: ClusterSpec,
    queries: Mutex<LeaderState>,
    cv: Condvar,
    pool: ThreadPool,
    credits: Backpressure,
    sched: Mutex<Scheduler>,
    catalog: Arc<Mutex<HashMap<QueryId, Arc<TpchDb>>>>,
    worker_clients: OnceLock<Vec<Client>>,
    /// Per-endpoint instant of the last heartbeat (index = worker).
    last_heard: Mutex<Vec<Instant>>,
    /// Endpoints whose lease expired. Monotone: a declared-dead
    /// endpoint never rejoins (rejoin is an elasticity problem, not a
    /// fault-tolerance one — see DESIGN §3d).
    dead: Mutex<HashSet<usize>>,
    admission: AdmissionConfig,
    /// Dispatch-slot ceiling (0 = unlimited).
    max_dispatched: usize,
    /// Gauges. Kept as atomics (not inside the state lock) because the
    /// terminal transitions (`fail`/`complete`/`cancel`) run with only
    /// a `&mut QueryState` in hand; all writers do hold the state lock,
    /// so reads under it are exact.
    live: AtomicUsize,
    dispatched: AtomicUsize,
    buffered: AtomicU64,
    peak_buffered: AtomicU64,
    shed: AtomicU64,
}

// Lock-order discipline (deadlock freedom): `queries` before `dead`
// before `sched`; `last_heard` is leaf-only. Casts are non-blocking
// sends, safe under any of them. `pump` (dispatch) runs under
// `queries` — every caller that retires a dispatch slot pumps before
// unlocking, so the queue drains without a dedicated thread.

/// Bounded exponential backoff for leader→worker control casts: 3
/// attempts, 1/2 ms between them. Casts fail only when the receiving
/// endpoint is gone; the short retry absorbs a transient (an endpoint
/// mid-drain under chaos) without stalling the dispatch path — total
/// worst-case sleep is 3 ms, after which the caller fails the query and
/// the lease/repair machinery owns the rest.
fn with_cast_backoff<T>(mut cast: impl FnMut() -> Result<T>) -> Result<T> {
    const ATTEMPTS: u32 = 3;
    let mut delay = Duration::from_millis(1);
    let mut attempt = 0;
    loop {
        match cast() {
            Ok(v) => return Ok(v),
            Err(e) => {
                attempt += 1;
                if attempt >= ATTEMPTS {
                    return Err(e);
                }
                std::thread::sleep(delay);
                delay *= 2;
            }
        }
    }
}

impl LeaderShared {
    /// Release the resources a live query holds (storage attach,
    /// scheduler load). Callers flip `phase` themselves.
    fn release(&self, qid: QueryId, st: &QueryState) {
        self.catalog.lock().unwrap().remove(&qid);
        let mut s = self.sched.lock().unwrap();
        for (node, est) in st.worker_nodes.iter().zip(&st.est_secs) {
            s.complete(*node, *est);
        }
    }

    /// Retire the query from the live/dispatched gauges. Every terminal
    /// transition (done, failed, cancelled) passes through exactly once:
    /// `fail` and `cancel` guard on a live phase, `complete` only runs
    /// from Reducing.
    fn note_terminal(&self, st: &mut QueryState) {
        self.live.fetch_sub(1, Ordering::SeqCst);
        if std::mem::take(&mut st.dispatched) {
            self.dispatched.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Return the query's buffered partial bytes to the service-wide
    /// gauge (idempotent: `buf_bytes` is taken).
    fn drain_buf(&self, st: &mut QueryState) {
        let b = std::mem::take(&mut st.buf_bytes);
        if b > 0 {
            self.buffered.fetch_sub(b, Ordering::SeqCst);
        }
    }

    /// The admission gates, in check order. `None` = admit. Called with
    /// the state lock held, so the gauges are exact.
    fn admission_check(&self) -> Option<ShedReason> {
        let a = &self.admission;
        if a.max_in_flight > 0 {
            let live = self.live.load(Ordering::SeqCst);
            if live >= a.max_in_flight {
                return Some(ShedReason::InFlight { live, max: a.max_in_flight });
            }
        }
        if a.max_buffered_bytes > 0 {
            let bytes = self.buffered.load(Ordering::SeqCst);
            if bytes >= a.max_buffered_bytes {
                return Some(ShedReason::BufferedBytes { bytes, max: a.max_buffered_bytes });
            }
        }
        if a.min_free_credits > 0 {
            let free = self.credits.free();
            if free < a.min_free_credits {
                return Some(ShedReason::Credits { free, min: a.min_free_credits });
            }
        }
        None
    }

    fn fail(&self, qid: QueryId, st: &mut QueryState, cause: FailCause) {
        if !st.phase.is_live() {
            return;
        }
        self.note_terminal(st);
        self.drain_buf(st);
        self.release(qid, st);
        st.db = None;
        st.acks = Vec::new();
        st.reducer_frames = Vec::new();
        // Clean the workers' per-query state (pending plans, buffered
        // exchange partials) so a failed query cannot leak buffers.
        if let Some(clients) = self.worker_clients.get() {
            let cq = CancelQuery { query_id: qid };
            for c in clients {
                let _ = c.cast_frame(METHOD_CANCEL, |out| cq.encode_into(out));
            }
        }
        st.trace.push(format!("failed: {cause}"));
        st.phase = Phase::Failed(cause);
    }

    /// Expire the query if it carries a deadline that has passed.
    /// Returns whether it fired (callers pump + notify). A queued query
    /// is unlinked from the fair queue first so the pump never
    /// dispatches a corpse.
    fn check_deadline(
        &self,
        qid: QueryId,
        st: &mut QueryState,
        queue: &mut DrrQueue<QueryId>,
        now: Instant,
    ) -> bool {
        let Some(dl) = st.deadline else { return false };
        if !st.phase.is_live() || now < dl {
            return false;
        }
        if matches!(st.phase, Phase::Queued) {
            queue.remove(st.session, |q| *q == qid);
        }
        self.fail(qid, st, FailCause::Timeout);
        true
    }

    /// Fill free dispatch slots from the fair queue. Runs under the
    /// state lock; called at submit and by everything that retires a
    /// slot (completion, failure, cancel, deadline sweep).
    fn pump(&self, g: &mut LeaderState) {
        loop {
            if self.max_dispatched > 0
                && self.dispatched.load(Ordering::SeqCst) >= self.max_dispatched
            {
                return;
            }
            let Some((_, qid)) = g.queue.pop() else { return };
            let seq = g.next_dispatch_seq;
            g.next_dispatch_seq += 1;
            let Some(st) = g.map.get_mut(&qid) else { continue };
            if !matches!(st.phase, Phase::Queued) {
                continue; // retired/cancelled while queued
            }
            self.dispatch(qid, st, seq);
        }
    }

    /// Move one query from Queued to Mapping: place its tasks on the
    /// least-loaded nodes **now** (a queued query holds no scheduler
    /// load) and cast plan + range to every worker.
    fn dispatch(&self, qid: QueryId, st: &mut QueryState, seq: u64) {
        let now = Instant::now();
        if let Some(dl) = st.deadline {
            if now >= dl {
                self.fail(qid, st, FailCause::Timeout);
                self.cv.notify_all();
                return;
            }
        }
        let tasks: Vec<Task> = st
            .est_secs
            .iter()
            .enumerate()
            .map(|(id, &est)| Task { id, kind: TaskKind::Compute, est_secs: est })
            .collect();
        let placed = {
            let mut s = self.sched.lock().unwrap();
            s.place_all(&tasks)
        };
        let Some(placed) = placed else {
            self.fail(qid, st, FailCause::Error("no eligible compute node".into()));
            self.cv.notify_all();
            return;
        };
        st.worker_nodes = placed.iter().map(|p| p.node_id).collect();
        st.dispatched = true;
        st.dispatch_seq = Some(seq);
        self.dispatched.fetch_add(1, Ordering::SeqCst);
        st.phase = Phase::Mapping;
        st.last_progress = now;
        // Remaining budget rides the fragment so the deadline takes
        // effect mid-fold on the workers (0 = none; clamped ≥ 1 since
        // the not-yet-expired case must not encode as "no deadline").
        let deadline_ms = st
            .deadline
            .map(|dl| (dl.saturating_duration_since(now).as_millis() as u64).max(1))
            .unwrap_or(0);
        let frag = PlanFragment {
            query_id: qid,
            name: st.query.clone(),
            plan: st.plan_bytes.clone(),
            workers: st.w as u32,
            morsel_rows: st.morsel_rows,
            deadline_ms,
        };
        let clients = self.worker_clients.get().expect("worker clients not wired");
        for wi in 0..st.w {
            let (lo, hi) = st.ranges[wi];
            st.trace.push(format!("send Plan w{wi}"));
            match with_cast_backoff(|| {
                clients[wi].cast_frame(METHOD_PLAN, |out| frag.encode_into(out))
            }) {
                Ok(b) => st.control_to[wi] += b as u64,
                Err(e) => {
                    self.fail(qid, st, FailCause::Error(format!("plan to w{wi}: {e}")));
                    self.cv.notify_all();
                    return;
                }
            }
            let ex = ExecuteRange {
                query_id: qid,
                worker: wi as u32,
                lo,
                hi,
                epoch: st.epoch,
                route: st.red_assign.clone(),
            };
            st.trace.push(format!("send Execute w{wi} rows={lo}..{hi}"));
            match with_cast_backoff(|| {
                clients[wi].cast_frame(METHOD_EXECUTE, |out| ex.encode_into(out))
            }) {
                Ok(b) => st.control_to[wi] += b as u64,
                Err(e) => {
                    self.fail(qid, st, FailCause::Error(format!("execute to w{wi}: {e}")));
                    self.cv.notify_all();
                    return;
                }
            }
        }
    }

    fn on_ack(&self, ack: Ack, wire_bytes: u64) {
        let mut g = self.queries.lock().unwrap();
        self.on_ack_locked(&mut g, ack, wire_bytes);
        // An error ack or a completion may have retired a dispatch slot.
        self.pump(&mut g);
    }

    fn on_ack_locked(&self, g: &mut LeaderState, ack: Ack, wire_bytes: u64) {
        let qid = ack.query_id;
        let Some(st) = g.map.get_mut(&qid) else { return };
        if !ack.error.is_empty() {
            if matches!(st.phase, Phase::Mapping | Phase::Reducing) {
                st.trace.push(format!("recv Ack w{} error", ack.worker));
                // A worker that abandoned its fold because the dispatched
                // deadline passed is a timeout, not an execution error —
                // same cause the leader-side sweep would assign.
                let cause = if ack.error.contains(DEADLINE_MSG) {
                    FailCause::Timeout
                } else {
                    FailCause::Error(ack.error)
                };
                self.fail(qid, st, cause);
                self.cv.notify_all();
            }
            return;
        }
        if !matches!(st.phase, Phase::Mapping) {
            return;
        }
        let wi = ack.worker as usize;
        if wi >= st.w || ack.epoch != st.want_epoch[wi] || st.acks[wi].is_some() {
            return; // stale epoch or duplicate: already superseded
        }
        if ack.part_bytes.len() != st.w {
            let msg = format!(
                "w{wi} reported {} partitions, expected {}",
                ack.part_bytes.len(),
                st.w
            );
            self.fail(qid, st, FailCause::Error(msg));
            self.cv.notify_all();
            return;
        }
        st.control_from[wi] += wire_bytes;
        st.trace.push(format!("recv Ack w{wi}"));
        st.acks[wi] = Some(AckInfo {
            epoch: ack.epoch,
            map_ns: ack.map_ns,
            ht_bytes: ack.ht_bytes,
            morsels_pruned: ack.morsels_pruned,
            part_bytes: ack.part_bytes,
        });
        st.acked += 1;
        st.last_progress = Instant::now();
        if st.acked == st.w {
            self.push_reduce(qid, st);
        }
        self.cv.notify_all();
    }

    /// All map acks are in: assemble the exchange expectations and
    /// command the engaged reducers. Safe to call again after a repair
    /// round: partitions whose pre-merged frame already arrived are
    /// skipped, and surviving senders are asked to re-cast their
    /// retained partition bodies to the (possibly re-homed) reducers —
    /// the originals may have been lost with a dead endpoint.
    fn push_reduce(&self, qid: QueryId, st: &mut QueryState) {
        let mut expect_per_p: Vec<Vec<(u32, u32)>> = vec![Vec::new(); st.w];
        for (wi, info) in st.acks.iter().enumerate() {
            let info = info.as_ref().expect("acked == w");
            for (p, &b) in info.part_bytes.iter().enumerate() {
                if b > 0 {
                    expect_per_p[p].push((wi as u32, info.epoch));
                }
            }
        }
        st.expected_reducers = expect_per_p.iter().filter(|e| !e.is_empty()).count();
        st.phase = Phase::Reducing;
        let resend = st.repairs > 0;
        let clients = self.worker_clients.get().expect("worker clients not wired");
        for (p, expect) in expect_per_p.into_iter().enumerate() {
            if expect.is_empty() || st.reducer_frames[p].is_some() {
                continue;
            }
            let dest = st.red_assign[p] as usize;
            st.trace.push(format!("send Reduce p{p} expect={}", expect.len()));
            if resend {
                for &(wi, _) in &expect {
                    let rs = ResendPartition {
                        query_id: qid,
                        worker: wi,
                        partition: p as u32,
                        to: st.red_assign[p],
                    };
                    let sender = st.assign[wi as usize];
                    if let Ok(b) =
                        clients[sender].cast_frame(METHOD_RESEND, |out| rs.encode_into(out))
                    {
                        st.control_to[sender] += b as u64;
                    }
                }
            }
            let cmd = ReduceCmd { query_id: qid, partition: p as u32, expect };
            match with_cast_backoff(|| {
                clients[dest].cast_frame(METHOD_REDUCE, |out| cmd.encode_into(out))
            }) {
                Ok(b) => st.control_to[dest] += b as u64,
                Err(e) => {
                    // An unreachable reducer would leave the query in
                    // Reducing forever (its frame can never arrive) and
                    // wait() blocked — fail it instead.
                    self.fail(qid, st, FailCause::Error(format!("reduce command to w{dest}: {e}")));
                    return;
                }
            }
        }
        if st.reducer_got >= st.expected_reducers {
            // Empty input (zero groups everywhere), or every engaged
            // partition already delivered before the repair: complete.
            self.complete(qid, st);
        }
    }

    fn on_partial(&self, pf: PartialFrame, wire_bytes: u64) {
        let mut g = self.queries.lock().unwrap();
        self.on_partial_locked(&mut g, pf, wire_bytes);
        // A completion (or completion-path failure) retires a slot.
        self.pump(&mut g);
    }

    fn on_partial_locked(&self, g: &mut LeaderState, pf: PartialFrame, wire_bytes: u64) {
        let qid = pf.query_id;
        let Some(st) = g.map.get_mut(&qid) else { return };
        if !matches!(st.phase, Phase::Reducing) {
            return;
        }
        let p = pf.partition as usize;
        if p >= st.w || st.reducer_frames[p].is_some() {
            return;
        }
        st.trace.push(format!("recv Partial p{p}"));
        // The buffered-bytes gauge: admission's memory gate and the
        // load driver's peak both read it. Charged here, drained on
        // every exit (complete consumes, fail/cancel drop).
        let body_bytes = pf.body.len() as u64;
        st.buf_bytes += body_bytes;
        let cur = self.buffered.fetch_add(body_bytes, Ordering::SeqCst) + body_bytes;
        self.peak_buffered.fetch_max(cur, Ordering::SeqCst);
        st.reducer_frames[p] = Some((pf.body, pf.reduce_ns, wire_bytes));
        st.reducer_got += 1;
        st.last_progress = Instant::now();
        if st.reducer_got == st.expected_reducers {
            self.complete(qid, st);
        }
        self.cv.notify_all();
    }

    fn on_heartbeat(&self, hb: Heartbeat) {
        if let Some(slot) = self.last_heard.lock().unwrap().get_mut(hb.worker as usize) {
            *slot = Instant::now();
        }
    }

    /// A worker's mid-fold progress beat: renew the endpoint's lease (a
    /// folding single-dispatch core cannot answer pings) and, when the
    /// beat reports the query's current epoch, its stall clock. Beats
    /// from superseded epochs still renew the lease — the endpoint is
    /// alive, just busy with work a repair already re-homed.
    fn on_progress(&self, pr: Progress) {
        if let Some(slot) = self.last_heard.lock().unwrap().get_mut(pr.endpoint as usize) {
            *slot = Instant::now();
        }
        let mut g = self.queries.lock().unwrap();
        let Some(st) = g.map.get_mut(&pr.query_id) else { return };
        if !matches!(st.phase, Phase::Mapping | Phase::Reducing) {
            return;
        }
        let l = pr.worker as usize;
        if l < st.w && st.want_epoch[l] == pr.epoch {
            st.last_progress = Instant::now();
        }
    }

    /// One repair round for a stuck or bereaved query: bump the epoch,
    /// re-home partitions off dead reducers, re-place and re-execute
    /// every fragment lacking a valid ack (dead executor, or frames
    /// lost in flight). Deterministic folds make this idempotent — a
    /// re-run fragment produces byte-identical partitions, so whatever
    /// frames the first attempt did deliver collapse with the re-sent
    /// ones at the reducers.
    fn repair(&self, qid: QueryId, st: &mut QueryState) {
        if !matches!(st.phase, Phase::Mapping | Phase::Reducing) {
            return;
        }
        if st.repairs >= MAX_REPAIRS {
            let msg = format!("unrecoverable after {MAX_REPAIRS} repair rounds");
            self.fail(qid, st, FailCause::Error(msg));
            self.cv.notify_all();
            return;
        }
        st.repairs += 1;
        st.epoch += 1;
        let dead = self.dead.lock().unwrap().clone();
        let live: Vec<usize> = (0..st.w).filter(|i| !dead.contains(i)).collect();
        if live.is_empty() {
            self.fail(qid, st, FailCause::Error("no live workers left".into()));
            self.cv.notify_all();
            return;
        }
        st.trace.push(format!("repair #{} epoch={}", st.repairs, st.epoch));
        for p in 0..st.w {
            if dead.contains(&(st.red_assign[p] as usize)) {
                st.red_assign[p] = live[p % live.len()] as u32;
            }
        }
        for l in 0..st.w {
            if !dead.contains(&st.assign[l]) {
                continue;
            }
            // The fragment's executor died: invalidate its ack (the
            // partials it casted may be lost with it) and re-place its
            // task — release the dead node's scheduler load, charge a
            // surviving one.
            if st.acks[l].take().is_some() {
                st.acked -= 1;
            }
            {
                let mut s = self.sched.lock().unwrap();
                let task = Task { id: l, kind: TaskKind::Compute, est_secs: st.est_secs[l] };
                if let Some(pl) = s.replace(st.worker_nodes[l], st.est_secs[l], &task) {
                    st.worker_nodes[l] = pl.node_id;
                }
            }
            st.assign[l] = live[l % live.len()];
        }
        // Re-cast plan + range for every fragment lacking a valid ack.
        let deadline_ms = st
            .deadline
            .map(|dl| (dl.saturating_duration_since(Instant::now()).as_millis() as u64).max(1))
            .unwrap_or(0);
        let clients = self.worker_clients.get().expect("worker clients not wired");
        for l in 0..st.w {
            if st.acks[l].is_some() {
                continue;
            }
            st.want_epoch[l] = st.epoch;
            let dest = st.assign[l];
            let frag = PlanFragment {
                query_id: qid,
                name: st.query.clone(),
                plan: st.plan_bytes.clone(),
                workers: st.w as u32,
                morsel_rows: st.morsel_rows,
                deadline_ms,
            };
            st.trace.push(format!("send Plan w{l} (repair)"));
            if let Ok(b) = with_cast_backoff(|| {
                clients[dest].cast_frame(METHOD_PLAN, |out| frag.encode_into(out))
            }) {
                st.control_to[dest] += b as u64;
            }
            let (lo, hi) = st.ranges[l];
            let ex = ExecuteRange {
                query_id: qid,
                worker: l as u32,
                lo,
                hi,
                epoch: st.epoch,
                route: st.red_assign.clone(),
            };
            st.trace.push(format!("send Execute w{l} rows={lo}..{hi} (repair)"));
            if let Ok(b) = clients[dest].cast_frame(METHOD_EXECUTE, |out| ex.encode_into(out)) {
                st.control_to[dest] += b as u64;
            }
        }
        st.last_progress = Instant::now();
        if st.acked == st.w {
            // Only reducers were lost (or frames past the map phase):
            // every ack is still valid — go straight to re-commanding
            // the reduce with resent exchange bodies.
            self.push_reduce(qid, st);
        } else {
            st.phase = Phase::Mapping;
        }
        self.cv.notify_all();
    }

    /// Every expected pre-merged partition is in: final merge (decode on
    /// the pool behind backpressure credits, partition order), finalize,
    /// charge the simulated phase network, release resources.
    ///
    /// Runs on the leader endpoint thread with the state lock held —
    /// completions serialize, which is the single-leader-core semantic
    /// this service models (the dominant cost, the map folds, runs on
    /// the worker endpoints without this lock).
    fn complete(&self, qid: QueryId, st: &mut QueryState) {
        // Take the per-phase buffers out of the state: the bodies move
        // straight into the decode (no copies of the shuffle payload),
        // and a finished query retains only rows, report, and trace.
        // Their bytes leave the buffered gauge here — consumed, whether
        // the decode below succeeds or fails.
        self.drain_buf(st);
        let frames = std::mem::take(&mut st.reducer_frames);
        let acks = std::mem::take(&mut st.acks);
        let mut reduce_secs = vec![0.0; st.w];
        let mut leader_bytes = vec![0u64; st.w];
        let mut bodies: Vec<Vec<u8>> = Vec::with_capacity(st.reducer_got);
        for (p, f) in frames.into_iter().enumerate() {
            if let Some((body, ns, bytes)) = f {
                reduce_secs[p] = ns as f64 * 1e-9;
                leader_bytes[p] = bytes;
                bodies.push(body);
            }
        }
        let mut merger = Merger::new(st.width);
        if let Err(e) = decode_and_merge(&self.pool, &self.credits, bodies, &mut merger) {
            self.fail(qid, st, FailCause::Error(e.to_string()));
            return;
        }
        let merged = merger.into_partial();
        let db = st.db.take().expect("completed twice");
        let rows: Vec<Row> = match planir::finalize(&db, &st.finalize, &merged) {
            Ok(rows) => rows,
            Err(e) => {
                self.fail(qid, st, FailCause::Error(format!("finalize: {e}")));
                return;
            }
        };
        self.release(qid, st);
        // Tell every worker the query is over: drop retained partition
        // bodies, buffered partials, plans — and suppress stragglers
        // (late duplicates of a finished query must not accrete state).
        if let Some(clients) = self.worker_clients.get() {
            let rq = ReleaseQuery { query_id: qid };
            for (i, c) in clients.iter().enumerate() {
                if let Ok(b) = c.cast_frame(METHOD_RELEASE, |out| rq.encode_into(out)) {
                    st.control_to[i] += b as u64;
                }
            }
        }

        let worker_secs: Vec<f64> = acks
            .iter()
            .map(|a| a.as_ref().map_or(0.0, |a| a.map_ns as f64 * 1e-9))
            .collect();
        let ht_bytes_each =
            acks.iter().map(|a| a.as_ref().map_or(0, |a| a.ht_bytes)).max().unwrap_or(0);
        let morsels_pruned: u64 =
            acks.iter().map(|a| a.as_ref().map_or(0, |a| a.morsels_pruned)).sum();
        let exchange_pair_bytes: Vec<Vec<u64>> = acks
            .into_iter()
            .map(|a| a.map_or_else(|| vec![0; st.w], |a| a.part_bytes))
            .collect();
        let exchange_bytes: u64 = exchange_pair_bytes
            .iter()
            .enumerate()
            .map(|(wi, row)| {
                row.iter().enumerate().filter(|(p, _)| *p != wi).map(|(_, b)| *b).sum::<u64>()
            })
            .sum();
        let shuffle_bytes: u64 = leader_bytes.iter().sum();
        let control_bytes: u64 =
            st.control_to.iter().sum::<u64>() + st.control_from.iter().sum::<u64>();
        let (compute_secs, shuffle_secs, io_secs) = simulate_phases(
            &self.cluster,
            &PhaseInputs {
                input_bytes_each: st.input_bytes_each,
                exchange_pair_bytes: &exchange_pair_bytes,
                leader_bytes: &leader_bytes,
                worker_secs: &worker_secs,
                reduce_secs: &reduce_secs,
                ht_bytes_each,
                worker_nodes: &st.worker_nodes,
                control_to: &st.control_to,
                control_from: &st.control_from,
            },
        );
        let max = |v: &[f64]| v.iter().fold(0.0f64, |a, &b| a.max(b));
        let report = DistQueryReport {
            query: st.query.clone(),
            rows,
            workers: st.w,
            compute_secs,
            shuffle_secs,
            io_secs,
            exchange_bytes,
            shuffle_bytes,
            control_bytes,
            input_bytes: st.input_bytes_each * st.w as u64,
            host_compute_secs: max(&worker_secs) + max(&reduce_secs),
            repairs: st.repairs,
            morsels_pruned,
        };
        st.trace.push(format!("done rows={}", report.rows.len()));
        st.result = Some(report);
        self.note_terminal(st);
        st.phase = Phase::Done;
        self.cv.notify_all();
    }
}

// -------------------------------------------------------------- service

/// The message-native distributed query service (see module docs).
pub struct QueryService {
    w: usize,
    morsel_rows: usize,
    /// Deadline stamped on submissions that don't carry their own.
    default_deadline: Option<Duration>,
    next_query: AtomicU64,
    catalog: Arc<Mutex<HashMap<QueryId, Arc<TpchDb>>>>,
    worker_clients: Vec<Client>,
    leader: Arc<LeaderShared>,
    /// Signals the monitor thread (if any) to exit; joined in Drop
    /// before the endpoints drain.
    stop: Arc<AtomicBool>,
    monitor: Option<std::thread::JoinHandle<()>>,
    // Declaration order is drop order: worker endpoints drain first
    // (their final casts still find the leader endpoint alive), the
    // leader endpoint drains last.
    _worker_eps: Vec<Endpoint>,
    _leader_ep: Endpoint,
}

impl Drop for QueryService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
    }
}

impl QueryService {
    /// Spin up the service with default tuning: one worker endpoint per
    /// cluster node, decode pool on all cores, default morsel size.
    pub fn new(cluster: ClusterSpec) -> Self {
        Self::with_config(cluster, ServiceConfig::default())
    }

    /// Spin up the service: `w` worker endpoints plus one leader
    /// endpoint, each a single-threaded [`Endpoint`] dispatch core.
    pub fn with_config(cluster: ClusterSpec, cfg: ServiceConfig) -> Self {
        let n = cluster.num_nodes();
        let w = if cfg.workers == 0 { n } else { cfg.workers.min(n) };
        // The lease monitor (and the worker-side body retention that
        // resend depends on) runs only when the caller opted into fault
        // tolerance; default-config services keep the exact pre-chaos
        // behavior and allocation profile.
        let fault_tolerant = cfg.chaos.is_some() || cfg.heartbeat_ms > 0 || cfg.lease_ms > 0;
        let heartbeat =
            Duration::from_millis(if cfg.heartbeat_ms == 0 { 20 } else { cfg.heartbeat_ms });
        let lease = if cfg.lease_ms == 0 {
            heartbeat * 8
        } else {
            Duration::from_millis(cfg.lease_ms)
        };
        // Deterministic per-endpoint fault schedule: each endpoint
        // derives its own stream from the one chaos seed, so a run is
        // replayable end to end from `(seed, kill)` alone.
        let fault_for = |wi: usize| -> FaultPlan {
            let Some(ch) = cfg.chaos else { return FaultPlan::none() };
            let mut plan = if ch.seed != 0 {
                let derived = ch.seed ^ (wi as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                FaultPlan::from_seed(derived, CHAOS_METHODS)
            } else {
                FaultPlan::none()
            };
            if let Some((kw, phase)) = ch.kill {
                if kw as usize == wi {
                    let method = match phase {
                        KillPhase::MidMap => METHOD_EXECUTE,
                        KillPhase::MidReduce => METHOD_REDUCE,
                    };
                    plan = plan.with_kill(Some(KillSpec { method: Some(method), nth: 1 }));
                }
            }
            plan
        };
        let catalog: Arc<Mutex<HashMap<QueryId, Arc<TpchDb>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let shareds: Vec<Arc<WorkerShared>> = (0..w)
            .map(|wi| {
                Arc::new(WorkerShared {
                    wi: wi as u32,
                    catalog: Arc::clone(&catalog),
                    plans: Mutex::new(HashMap::new()),
                    reduces: Mutex::new(HashMap::new()),
                    executed: Mutex::new((HashMap::new(), VecDeque::new())),
                    retain: fault_tolerant,
                    progress_ms: if fault_tolerant {
                        (heartbeat.as_millis() as u64).max(1)
                    } else {
                        0
                    },
                    cancelled: Mutex::new((HashSet::new(), VecDeque::new())),
                    peers: OnceLock::new(),
                    leader: OnceLock::new(),
                    bufs: BufPool::new(),
                })
            })
            .collect();
        let worker_eps: Vec<Endpoint> = shareds
            .iter()
            .enumerate()
            .map(|(wi, ws)| {
                let (p, e, x, r, c) =
                    (ws.clone(), ws.clone(), ws.clone(), ws.clone(), ws.clone());
                let (rs, pg, rl) = (ws.clone(), ws.clone(), ws.clone());
                Dispatch::new()
                    .on(METHOD_PLAN, move |m| {
                        p.on_plan(PlanFragment::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_EXECUTE, move |m| {
                        e.on_execute(ExecuteRange::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_PARTIAL, move |m| {
                        x.on_partial(PartialFrame::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_REDUCE, move |m| {
                        r.on_reduce(ReduceCmd::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_CANCEL, move |m| {
                        c.on_cancel(CancelQuery::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_RESEND, move |m| {
                        rs.on_resend(ResendPartition::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_PING, move |m| {
                        pg.on_ping(Ping::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .on(METHOD_RELEASE, move |m| {
                        rl.on_release(ReleaseQuery::decode(&m.payload)?);
                        Ok(Vec::new())
                    })
                    .serve_with_faults(fault_for(wi))
            })
            .collect();
        let worker_clients: Vec<Client> = worker_eps.iter().map(|e| e.client()).collect();
        let pool = ThreadPool::new(cfg.threads);
        let credits = Backpressure::new(pool.threads().max(1));
        let sched = Mutex::new(Scheduler::new(&cluster));
        let leader = Arc::new(LeaderShared {
            cluster,
            queries: Mutex::new(LeaderState {
                map: HashMap::new(),
                queue: DrrQueue::new(),
                rejected: HashSet::new(),
                rejected_order: VecDeque::new(),
                next_dispatch_seq: 0,
            }),
            cv: Condvar::new(),
            pool,
            credits,
            sched,
            catalog: Arc::clone(&catalog),
            worker_clients: OnceLock::new(),
            last_heard: Mutex::new(vec![Instant::now(); w]),
            dead: Mutex::new(HashSet::new()),
            admission: cfg.admission,
            max_dispatched: cfg.max_dispatched,
            live: AtomicUsize::new(0),
            dispatched: AtomicUsize::new(0),
            buffered: AtomicU64::new(0),
            peak_buffered: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        });
        let (la, lp, lh, lg) = (
            Arc::clone(&leader),
            Arc::clone(&leader),
            Arc::clone(&leader),
            Arc::clone(&leader),
        );
        // The leader endpoint gets its own fault stream (drops/delays of
        // acks and partials are recoverable via the stall repair) but
        // never a kill: leader death is explicitly out of scope.
        let leader_plan = match cfg.chaos {
            Some(ch) if ch.seed != 0 => {
                FaultPlan::from_seed(ch.seed ^ 0xD1B5_4A32_D192_ED03, CHAOS_METHODS)
            }
            _ => FaultPlan::none(),
        };
        let leader_ep = Dispatch::new()
            .on(METHOD_ACK, move |m| {
                la.on_ack(Ack::decode(&m.payload)?, 16 + m.payload.len() as u64);
                Ok(Vec::new())
            })
            .on(METHOD_PARTIAL, move |m| {
                lp.on_partial(PartialFrame::decode(&m.payload)?, 16 + m.payload.len() as u64);
                Ok(Vec::new())
            })
            .on(METHOD_HEARTBEAT, move |m| {
                lh.on_heartbeat(Heartbeat::decode(&m.payload)?);
                Ok(Vec::new())
            })
            .on(METHOD_PROGRESS, move |m| {
                lg.on_progress(Progress::decode(&m.payload)?);
                Ok(Vec::new())
            })
            .serve_with_faults(leader_plan);
        let leader_client = leader_ep.client();
        let _ = leader.worker_clients.set(worker_clients.clone());
        for ws in &shareds {
            let _ = ws.peers.set(worker_clients.clone());
            let _ = ws.leader.set(leader_client.clone());
        }
        let stop = Arc::new(AtomicBool::new(false));
        // The monitor also sweeps deadlines; a deadline-only service
        // (no chaos, no lease config) arms it in a reduced mode that
        // never pings, expires leases, or repairs.
        let monitored = fault_tolerant || cfg.default_deadline_ms > 0;
        let monitor = monitored.then(|| {
            let chaos_enabled = cfg.chaos.is_some();
            let leader = Arc::clone(&leader);
            let stop = Arc::clone(&stop);
            let clients = worker_clients.clone();
            std::thread::spawn(move || {
                Self::monitor_loop(
                    &leader,
                    &clients,
                    heartbeat,
                    lease,
                    fault_tolerant,
                    chaos_enabled,
                    &stop,
                )
            })
        });
        Self {
            w,
            morsel_rows: cfg.morsel_rows.max(1),
            default_deadline: (cfg.default_deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.default_deadline_ms)),
            next_query: AtomicU64::new(0),
            catalog,
            worker_clients,
            leader,
            stop,
            monitor,
            _worker_eps: worker_eps,
            _leader_ep: leader_ep,
        }
    }

    /// The leader's failure detector: ping every live endpoint, expire
    /// leases of silent ones, and run a repair pass over in-flight
    /// queries that either touch a dead endpoint or (chaos runs only —
    /// a loaded CI machine must not fail a merely-slow clean query)
    /// have made no progress for a full lease.
    fn monitor_loop(
        leader: &LeaderShared,
        clients: &[Client],
        heartbeat: Duration,
        lease: Duration,
        fault_tolerant: bool,
        chaos_enabled: bool,
        stop: &AtomicBool,
    ) {
        let mut nonce = 0u64;
        while !stop.load(Ordering::Relaxed) {
            if fault_tolerant {
                nonce += 1;
                let ping = Ping { nonce };
                {
                    let dead = leader.dead.lock().unwrap().clone();
                    for (i, c) in clients.iter().enumerate() {
                        if !dead.contains(&i) {
                            let _ = c.cast_frame(METHOD_PING, |out| ping.encode_into(out));
                        }
                    }
                }
                let now = Instant::now();
                // Snapshot `last_heard` instead of holding it: it is
                // leaf-only in the lock order (workers stamp it on every
                // frame), so it must never be held across `dead`.
                let heard: Vec<Instant> = leader.last_heard.lock().unwrap().clone();
                let mut dead = leader.dead.lock().unwrap();
                for (i, t) in heard.iter().enumerate() {
                    if !dead.contains(&i) && now.duration_since(*t) > lease {
                        dead.insert(i);
                    }
                }
            }
            let now = Instant::now();
            {
                let mut g = leader.queries.lock().unwrap();
                let mut expired = false;
                {
                    let LeaderState { map, queue, .. } = &mut *g;
                    let qids: Vec<QueryId> = map.keys().copied().collect();
                    for qid in qids {
                        let Some(st) = map.get_mut(&qid) else { continue };
                        if !st.phase.is_live() {
                            continue;
                        }
                        // Deadlines first: an expired query must not be
                        // repaired, it must die (with full cleanup).
                        if leader.check_deadline(qid, st, queue, now) {
                            expired = true;
                            continue;
                        }
                        if !fault_tolerant || !matches!(st.phase, Phase::Mapping | Phase::Reducing)
                        {
                            continue;
                        }
                        let touches_dead = {
                            let dead = leader.dead.lock().unwrap();
                            st.assign.iter().any(|a| dead.contains(a))
                                || st.red_assign.iter().any(|r| dead.contains(&(*r as usize)))
                        };
                        let stalled =
                            chaos_enabled && now.duration_since(st.last_progress) > lease;
                        if touches_dead || stalled {
                            leader.repair(qid, st);
                        }
                    }
                }
                // Expiries and repair failures may have retired slots.
                leader.pump(&mut g);
                if expired {
                    leader.cv.notify_all();
                }
            }
            std::thread::sleep(heartbeat);
        }
    }

    /// Worker endpoints this service runs.
    pub fn workers(&self) -> usize {
        self.w
    }

    /// Backpressure credits currently held by in-flight decodes. Zero
    /// whenever no query is completing — the chaos suite asserts this
    /// after every fault schedule (failure paths must not leak).
    pub fn credits_in_flight(&self) -> usize {
        self.leader.credits.in_flight()
    }

    /// Endpoints the lease monitor has declared dead (0 without chaos
    /// or when every worker heartbeats within its lease).
    pub fn dead_workers(&self) -> usize {
        self.leader.dead.lock().unwrap().len()
    }

    /// Contiguous row ranges of `len` over `w` workers.
    fn ranges(len: usize, w: usize) -> Vec<(usize, usize)> {
        let chunk = len.div_ceil(w.max(1));
        (0..w)
            .map(|i| ((i * chunk).min(len), ((i + 1) * chunk).min(len)))
            .collect()
    }

    /// Submit a registered query by name: build its default-parameter
    /// plan and hand it to [`QueryService::submit_plan`].
    pub fn submit(&self, db: &Arc<TpchDb>, query: &str) -> Result<QueryId> {
        self.submit_opts(db, query, SubmitOpts::default())
    }

    /// [`QueryService::submit`] with a session key and/or deadline.
    pub fn submit_opts(&self, db: &Arc<TpchDb>, query: &str, opts: SubmitOpts) -> Result<QueryId> {
        let spec = engine::spec(query)
            .ok_or_else(|| crate::err!("query {query} has no distributed plan"))?;
        self.submit_plan_opts(db, &spec, opts)
    }

    /// [`QueryService::submit`] with a per-query deadline: the query
    /// expires to [`FailCause::Timeout`] — with full cleanup on leader
    /// and workers — if it has not finished within `deadline`.
    pub fn submit_with_deadline(
        &self,
        db: &Arc<TpchDb>,
        query: &str,
        deadline: Duration,
    ) -> Result<QueryId> {
        self.submit_opts(db, query, SubmitOpts { deadline: Some(deadline), ..Default::default() })
    }

    /// Submit an ad-hoc SQL query: parse, bind, and optimize it into a
    /// [`LogicalPlan`], then hand it to [`QueryService::submit_plan`].
    /// The workers see only the encoded IR — SQL never crosses the
    /// fabric.
    pub fn submit_sql(&self, db: &Arc<TpchDb>, sql: &str) -> Result<QueryId> {
        self.submit_sql_opts(db, sql, SubmitOpts::default())
    }

    /// [`QueryService::submit_sql`] with a session key and/or deadline.
    pub fn submit_sql_opts(
        &self,
        db: &Arc<TpchDb>,
        sql: &str,
        opts: SubmitOpts,
    ) -> Result<QueryId> {
        self.submit_plan_opts(db, &crate::analytics::sql::plan_sql(sql)?, opts)
    }

    /// Submit a logical plan (see [`QueryService::try_submit_plan`]).
    /// Returns immediately — the query runs on the endpoint threads. A
    /// submission shed by the admission controller comes back as an
    /// error here; use `try_submit_plan` to branch on it without
    /// string-matching.
    pub fn submit_plan(&self, db: &Arc<TpchDb>, plan: &LogicalPlan) -> Result<QueryId> {
        self.submit_plan_opts(db, plan, SubmitOpts::default())
    }

    /// [`QueryService::submit_plan`] with a session key and/or deadline.
    pub fn submit_plan_opts(
        &self,
        db: &Arc<TpchDb>,
        plan: &LogicalPlan,
        opts: SubmitOpts,
    ) -> Result<QueryId> {
        match self.try_submit_plan(db, plan, opts)? {
            Submission::Admitted(id) => Ok(id),
            Submission::Shed { id, reason } => Err(crate::err!("{id} shed: {reason}")),
        }
    }

    /// Submit a logical plan under admission control: attach the input
    /// tables and enqueue the query in the fair (deficit-round-robin
    /// over sessions) dispatch queue — or shed it, explicitly, if an
    /// admission gate is over threshold. Placement and the PlanFragment
    /// + ExecuteRange casts happen at *dispatch* (immediately, unless
    /// [`ServiceConfig::max_dispatched`] holds the query in the queue);
    /// the PlanFragment carries the **encoded plan** — workers compile
    /// it; no registry is consulted. The plan needs no name the service
    /// has ever heard of: ad-hoc IR runs exactly like the TPC-H set.
    pub fn try_submit_plan(
        &self,
        db: &Arc<TpchDb>,
        plan: &LogicalPlan,
        opts: SubmitOpts,
    ) -> Result<Submission> {
        // The encoder narrows collection counts; an out-of-bounds plan
        // would truncate silently on the wire and decode to a different
        // (or undecodable) plan on every worker — reject it here, at the
        // one place plans are put on the fabric.
        plan.check_wire_bounds()?;
        let width = plan.width();
        crate::ensure!(self.w >= 1, "cluster has no nodes");
        let scan = planir::table(db, plan.scan);
        let n = scan.len();
        let ranges = Self::ranges(n, self.w);
        let rows_each = ranges.first().map(|(s, e)| e - s).unwrap_or(0);
        let input_bytes_each = if n == 0 {
            0
        } else {
            (scan.bytes() as f64 * rows_each as f64 / n as f64) as u64
        };
        // Fold-cost estimate (rows at a nominal per-row rate — only
        // relative load matters): the scheduler's placement weight at
        // dispatch and the DRR cost in the fair queue.
        let est_secs: Vec<f64> =
            ranges.iter().map(|(s, e)| ((e - s) as f64 * 2e-8).max(1e-9)).collect();
        let cost: f64 = est_secs.iter().sum();
        let plan_bytes = plan.encode();
        let qid = QueryId(self.next_query.fetch_add(1, Ordering::SeqCst) + 1);
        let mut g = self.leader.queries.lock().unwrap();
        // Admission, under the state lock: the gauges are exact, and a
        // shed query was never buffered — the only trace it leaves is
        // its slot in the bounded rejected ring.
        if let Some(reason) = self.leader.admission_check() {
            g.note_rejected(qid);
            self.leader.shed.fetch_add(1, Ordering::SeqCst);
            return Ok(Submission::Shed { id: qid, reason });
        }
        self.catalog.lock().unwrap().insert(qid, Arc::clone(db));
        self.leader.live.fetch_add(1, Ordering::SeqCst);
        let deadline =
            opts.deadline.or(self.default_deadline).map(|d| Instant::now() + d);
        g.map.insert(
            qid,
            QueryState {
                query: plan.name.clone(),
                width,
                finalize: plan.finalize.clone(),
                db: Some(Arc::clone(db)),
                phase: Phase::Queued,
                session: opts.session,
                cost,
                deadline,
                dispatched: false,
                dispatch_seq: None,
                buf_bytes: 0,
                w: self.w,
                worker_nodes: Vec::new(),
                est_secs,
                input_bytes_each,
                epoch: 0,
                assign: (0..self.w).collect(),
                red_assign: (0..self.w as u32).collect(),
                want_epoch: vec![0; self.w],
                repairs: 0,
                last_progress: Instant::now(),
                plan_bytes,
                ranges: ranges.iter().map(|&(s, e)| (s as u64, e as u64)).collect(),
                morsel_rows: self.morsel_rows as u64,
                acks: (0..self.w).map(|_| None).collect(),
                acked: 0,
                expected_reducers: 0,
                reducer_got: 0,
                reducer_frames: (0..self.w).map(|_| None).collect(),
                control_to: vec![0; self.w],
                control_from: vec![0; self.w],
                trace: Vec::new(),
                result: None,
            },
        );
        g.queue.push(opts.session, qid, cost);
        // Dispatch under the same lock hold: with free slots the casts
        // go out before the insert is visible to any ack, and the trace
        // stays ordered (casts are non-blocking sends).
        self.leader.pump(&mut g);
        Ok(Submission::Admitted(qid))
    }

    /// Snapshot a query's lifecycle state (non-blocking). Also the lazy
    /// deadline check: polling an expired query expires it on the spot,
    /// so deadlines hold even on services without a monitor thread.
    pub fn poll(&self, id: QueryId) -> QueryStatus {
        let mut g = self.leader.queries.lock().unwrap();
        if g.rejected.contains(&id) {
            return QueryStatus::Rejected;
        }
        let fired = {
            let LeaderState { map, queue, .. } = &mut *g;
            match map.get_mut(&id) {
                Some(st) => self.leader.check_deadline(id, st, queue, Instant::now()),
                None => return QueryStatus::Unknown,
            }
        };
        if fired {
            self.leader.pump(&mut g);
            self.leader.cv.notify_all();
        }
        g.map.get(&id).map_or(QueryStatus::Unknown, |st| st.status())
    }

    /// Block until the query finishes; returns its rows and report.
    /// Waiting is idempotent — any number of callers get the result. A
    /// query with a deadline never blocks past it: the wait sleeps no
    /// longer than the time remaining and expires the query itself if
    /// the monitor hasn't — so `wait` is deadline-bounded even on
    /// services with no monitor thread at all.
    pub fn wait(&self, id: QueryId) -> Result<(Vec<Row>, DistQueryReport)> {
        let mut g = self.leader.queries.lock().unwrap();
        loop {
            match g.map.get(&id) {
                None if g.rejected.contains(&id) => {
                    crate::bail!("{id}: shed at admission")
                }
                None => crate::bail!("{id}: unknown query"),
                Some(st) => match &st.phase {
                    Phase::Done => {
                        let report = st.result.clone().expect("done without result");
                        return Ok((report.rows.clone(), report));
                    }
                    Phase::Failed(e) => crate::bail!("{id} failed: {e}"),
                    Phase::Cancelled => crate::bail!("{id} cancelled"),
                    Phase::Queued | Phase::Mapping | Phase::Reducing => {}
                },
            }
            let now = Instant::now();
            let (fired, deadline) = {
                let LeaderState { map, queue, .. } = &mut *g;
                let st = map.get_mut(&id).expect("matched Some above");
                let dl = st.deadline;
                (self.leader.check_deadline(id, st, queue, now), dl)
            };
            if fired {
                self.leader.pump(&mut g);
                self.leader.cv.notify_all();
                continue; // next iteration reports the Failed(Timeout)
            }
            g = match deadline {
                Some(dl) => {
                    let left = dl
                        .saturating_duration_since(now)
                        .max(Duration::from_millis(1));
                    self.leader.cv.wait_timeout(g, left).unwrap().0
                }
                None => self.leader.cv.wait(g).unwrap(),
            };
        }
    }

    /// Best-effort cancel: returns `true` if the query was still live
    /// (queued or in flight; its late frames will be discarded),
    /// `false` if it already finished, failed, or never existed.
    pub fn cancel(&self, id: QueryId) -> bool {
        let mut g = self.leader.queries.lock().unwrap();
        {
            let LeaderState { map, queue, .. } = &mut *g;
            let Some(st) = map.get_mut(&id) else { return false };
            if !st.phase.is_live() {
                return false;
            }
            if matches!(st.phase, Phase::Queued) {
                queue.remove(st.session, |q| *q == id);
            }
            self.leader.note_terminal(st);
            self.leader.drain_buf(st);
            self.leader.release(id, st);
            st.db = None;
            st.acks = Vec::new();
            st.reducer_frames = Vec::new();
            st.phase = Phase::Cancelled;
            st.trace.push("cancelled".to_string());
            let cq = CancelQuery { query_id: id };
            for (wi, c) in self.worker_clients.iter().enumerate() {
                if let Ok(b) = c.cast_frame(METHOD_CANCEL, |out| cq.encode_into(out)) {
                    st.control_to[wi] += b as u64;
                }
            }
        }
        // Cancelling a dispatched query freed its slot.
        self.leader.pump(&mut g);
        self.leader.cv.notify_all();
        true
    }

    /// Evict a finished (done, failed, cancelled, or shed) query's
    /// retained state — rows, report, trace. Returns `false` if the
    /// query is still live (or unknown); a long-lived service that
    /// serves an unbounded query stream should retire ids once their
    /// result has been consumed.
    pub fn retire(&self, id: QueryId) -> bool {
        let mut g = self.leader.queries.lock().unwrap();
        if g.rejected.remove(&id) {
            g.rejected_order.retain(|q| *q != id);
            return true;
        }
        let terminal = g.map.get(&id).is_some_and(|st| !st.phase.is_live());
        if terminal {
            g.map.remove(&id);
        }
        terminal
    }

    /// The leader's ordered view of a query's conversation — one line
    /// per frame sent or received (empty for unknown ids).
    pub fn conversation(&self, id: QueryId) -> Vec<String> {
        let g = self.leader.queries.lock().unwrap();
        g.map.get(&id).map_or_else(Vec::new, |st| st.trace.clone())
    }

    /// Live (queued + executing) queries.
    pub fn live_queries(&self) -> usize {
        self.leader.live.load(Ordering::SeqCst)
    }

    /// Admitted queries waiting in the fair queue for a dispatch slot.
    pub fn queued_queries(&self) -> usize {
        self.leader.queries.lock().unwrap().queue.len()
    }

    /// Submissions shed by the admission controller since startup.
    pub fn shed_queries(&self) -> u64 {
        self.leader.shed.load(Ordering::SeqCst)
    }

    /// Pre-merged partial bytes currently buffered on the leader.
    pub fn buffered_bytes(&self) -> u64 {
        self.leader.buffered.load(Ordering::SeqCst)
    }

    /// High-water mark of [`QueryService::buffered_bytes`] — the number
    /// the overload acceptance test holds against the memory watermark.
    pub fn peak_buffered_bytes(&self) -> u64 {
        self.leader.peak_buffered.load(Ordering::SeqCst)
    }

    /// The order this query left the fair queue (None while queued, or
    /// for ids that never dispatched). Fairness tests assert on it.
    pub fn dispatch_sequence(&self, id: QueryId) -> Option<u64> {
        self.leader.queries.lock().unwrap().map.get(&id).and_then(|st| st.dispatch_seq)
    }
}

// ------------------------------------------------- leader decode + sim

/// Decode partial bodies on `pool` and absorb them into `merger` in
/// order. A backpressure credit is held per admitted body from
/// submission until its decoded partial has been merged, bounding
/// decoded-but-unmerged buffering. Credits are released on *every* path
/// — a decode or merge failure must not leak the credit out of a
/// long-lived gate (the leak regression tests below drive this).
/// How long the decode path waits for a credit it cannot free itself
/// before declaring the gate wedged (a release lost elsewhere) and
/// failing the query with a typed error instead of blocking `wait()`
/// forever.
const LOST_CREDIT_WAIT: Duration = Duration::from_secs(2);

fn decode_and_merge(
    pool: &ThreadPool,
    credits: &Backpressure,
    bodies: Vec<Vec<u8>>,
    merger: &mut Merger,
) -> Result<()> {
    let mut pending: VecDeque<JoinHandle<Result<Partial>>> = VecDeque::new();
    let mut result: Result<()> = Ok(());
    for body in bodies {
        // Admission: retire the oldest in-flight partial (merge order
        // stays body order) until a credit frees up.
        while result.is_ok() && !credits.try_acquire() {
            let Some(h) = pending.pop_front() else {
                // No in-flight decode of ours to retire and no credit
                // free: every credit is held elsewhere (concurrent
                // completer, or a release lost to a bug). Wait bounded —
                // if the gate never recovers, the query fails with a
                // typed error rather than wedging forever.
                if credits.acquire_timeout(LOST_CREDIT_WAIT) {
                    break; // credit in hand, proceed to submit the body
                }
                result = Err(crate::err!(
                    "no backpressure credit after {LOST_CREDIT_WAIT:?} (lost release?)"
                ));
                break;
            };
            let r = h.join().and_then(|p| merger.absorb(&p));
            credits.release();
            result = result.and(r);
        }
        if result.is_err() {
            break;
        }
        pending.push_back(pool.submit(move || Partial::decode(&body)));
    }
    // Drain: release every remaining credit even after a failure.
    while let Some(h) = pending.pop_front() {
        let r = h.join().and_then(|p| merger.absorb(&p));
        credits.release();
        result = result.and(r);
    }
    result
}

/// Per-run inputs to the phase simulation.
struct PhaseInputs<'a> {
    input_bytes_each: u64,
    /// `[worker][reducer]` frame bytes of the partition exchange.
    exchange_pair_bytes: &'a [Vec<u64>],
    /// Per-reducer pre-merged frame bytes shipped to the leader.
    leader_bytes: &'a [u64],
    /// Measured host seconds per worker (map) and per reducer (reduce).
    worker_secs: &'a [f64],
    reduce_secs: &'a [f64],
    ht_bytes_each: u64,
    worker_nodes: &'a [usize],
    /// Control frame bytes leader → worker i / worker i → leader.
    control_to: &'a [u64],
    control_from: &'a [u64],
}

/// Simulate the network phases and worker compute for a run where the
/// worker on `worker_nodes[i]` scanned `input_bytes_each`, exchanged
/// `exchange_pair_bytes[i][p]` with the reducer on `worker_nodes[p]`,
/// and the reducers shipped `leader_bytes[p]` to the leader (node 0).
/// Control frames ride the leader-ward phase as concurrent tiny flows.
fn simulate_phases(cluster: &ClusterSpec, ph: &PhaseInputs<'_>) -> (f64, f64, f64) {
    let topo = cluster.topology();
    let n = topo.num_nodes();

    // Phase 1 — storage read: each worker node pulls its partition
    // from a storage replica on a different node (disaggregated
    // storage).
    let mut io_sim = Simulation::new(topo.clone());
    for &node in ph.worker_nodes {
        let src = (node + n / 2) % n;
        if src != node && ph.input_bytes_each > 0 {
            io_sim.add_flow(src, node, ph.input_bytes_each as f64, 0.0);
        }
    }
    let io_secs = io_sim.run_makespan();

    // Phase 2 — compute: each worker node runs its partition across
    // all its cores; memsim gives the contention-adjusted speedup.
    // Map and reduce are sequential phases, so their scaled
    // makespans add.
    let platform = cluster.platform();
    let profile = WorkloadProfile {
        cpu_secs: 1.0, // shape only: we scale measured time below
        dram_bytes: (ph.input_bytes_each as f64).max(1.0),
        working_set_bytes: (ph.ht_bytes_each as f64).max(4e6),
    };
    let k = platform.vcpus;
    let r = simulate(platform, &profile, k);
    // Effective parallel speedup on the node vs one uncontended core.
    let single = simulate(platform, &profile, 1).per_core_rate;
    let speedup = (r.system_rate / single).max(1e-9);
    let host_to_platform = crate::analytics::profile::host_speed() / platform.st_speed;
    let scale = |h: &f64| h * host_to_platform / speedup;
    let map_secs = ph.worker_secs.iter().map(scale).fold(0.0, f64::max);
    let red_secs = ph.reduce_secs.iter().map(scale).fold(0.0, f64::max);
    let compute_secs = map_secs + red_secs;

    // Phase 3 — partition exchange: worker i → reducer p. A worker's
    // own partition stays on-node and adds no flow.
    let mut ex_sim = Simulation::new(topo.clone());
    for (wi, row) in ph.exchange_pair_bytes.iter().enumerate() {
        for (p, &b) in row.iter().enumerate() {
            let (src, dst) = (ph.worker_nodes[wi], ph.worker_nodes[p]);
            if src != dst && b > 0 {
                ex_sim.add_flow(src, dst, b as f64, 0.0);
            }
        }
    }
    let exchange_secs = ex_sim.run_makespan();

    // Phase 4 — pre-merged reducer partials to the leader (node 0),
    // with the query's control frames as concurrent flows.
    let mut sh_sim = Simulation::new(topo);
    for (p, &b) in ph.leader_bytes.iter().enumerate() {
        let node = ph.worker_nodes[p];
        if node != 0 && b > 0 {
            sh_sim.add_flow(node, 0, b as f64, 0.0);
        }
    }
    for (wi, (&to, &from)) in ph.control_to.iter().zip(ph.control_from).enumerate() {
        let node = ph.worker_nodes[wi];
        if node != 0 {
            if to > 0 {
                sh_sim.add_flow(0, node, to as f64, 0.0);
            }
            if from > 0 {
                sh_sim.add_flow(node, 0, from as f64, 0.0);
            }
        }
    }
    let shuffle_secs = exchange_secs + sh_sim.run_makespan();
    (compute_secs, shuffle_secs, io_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::ops::ExecStats;
    use crate::analytics::queries;
    use crate::analytics::tpch::TpchConfig;
    use crate::cluster::Role;
    use crate::platform::n2d_milan;

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::traditional(n, n2d_milan(), Role::LiteCompute)
    }

    fn db(sf: f64, seed: u64) -> Arc<TpchDb> {
        Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)))
    }

    #[test]
    fn submit_wait_matches_serial() {
        let db = db(0.002, 41);
        let svc = QueryService::new(cluster(4));
        let id = svc.submit(&db, "q1").unwrap();
        let (rows, report) = svc.wait(id).unwrap();
        let single = queries::run_query(&db, "q1").unwrap();
        assert!(single.approx_eq_rows(&rows));
        assert!(single.approx_eq_rows(&report.rows));
        assert_eq!(report.workers, 4);
        assert!(report.shuffle_bytes > 0);
        assert!(report.control_bytes > 0, "control frames must be charged");
        assert_eq!(svc.poll(id), QueryStatus::Done);
        // wait is idempotent.
        let (rows2, _) = svc.wait(id).unwrap();
        assert!(single.approx_eq_rows(&rows2));
        // retire evicts the finished query's retained state.
        assert!(svc.retire(id));
        assert_eq!(svc.poll(id), QueryStatus::Unknown);
        assert!(!svc.retire(id), "retire is not idempotent on evicted ids");
    }

    #[test]
    fn interleaved_queries_each_match_serial() {
        let db = db(0.002, 43);
        let svc = QueryService::new(cluster(3));
        let names = ["q1", "q6", "q18", "q14", "q1", "q6"];
        let ids: Vec<QueryId> = names.iter().map(|q| svc.submit(&db, q).unwrap()).collect();
        // Wait in reverse submit order: completion order must not matter.
        for (q, id) in names.iter().zip(ids.iter()).rev() {
            let (rows, _) = svc.wait(*id).unwrap();
            let single = queries::run_query(&db, q).unwrap();
            assert!(single.approx_eq_rows(&rows), "{q} ({id}) diverged");
        }
    }

    #[test]
    fn unknown_query_is_rejected_at_submit() {
        let db = db(0.001, 7);
        let svc = QueryService::new(cluster(2));
        assert!(svc.submit(&db, "q99").is_err());
        assert_eq!(svc.poll(QueryId(999)), QueryStatus::Unknown);
        assert!(svc.wait(QueryId(999)).is_err());
    }

    #[test]
    fn ranges_cover_exactly() {
        let r = QueryService::ranges(103, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 103);
        let total: usize = r.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, 103);
    }

    #[test]
    fn single_worker_service_matches_serial() {
        let db = db(0.002, 11);
        let svc = QueryService::with_config(
            cluster(4),
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
        );
        assert_eq!(svc.workers(), 1);
        let id = svc.submit(&db, "q12").unwrap();
        let (rows, report) = svc.wait(id).unwrap();
        assert!(queries::run_query(&db, "q12").unwrap().approx_eq_rows(&rows));
        // One worker: the whole exchange is node-local.
        assert_eq!(report.exchange_bytes, 0);
        assert!(report.shuffle_bytes > 0);
    }

    #[test]
    fn conversation_trace_is_ordered() {
        let db = db(0.002, 47);
        let w = 3;
        let svc = QueryService::with_config(
            cluster(w),
            ServiceConfig { workers: w, ..ServiceConfig::default() },
        );
        let id = svc.submit(&db, "q1").unwrap();
        svc.wait(id).unwrap();
        let trace = svc.conversation(id);
        let count = |p: &str| trace.iter().filter(|l| l.starts_with(p)).count();
        // Leader sends exactly one plan + one range per worker, first.
        assert_eq!(count("send Plan"), w);
        assert_eq!(count("send Execute"), w);
        for (i, line) in trace.iter().take(2 * w).enumerate() {
            let wi = i / 2;
            let want = if i % 2 == 0 {
                format!("send Plan w{wi}")
            } else {
                format!("send Execute w{wi}")
            };
            assert!(line.starts_with(&want), "entry {i}: {line} !~ {want}");
        }
        // Every worker acks its map; reduce commands only after the last
        // ack; reducer partials only after the reduce commands; done last.
        assert_eq!(count("recv Ack"), w);
        let pos = |p: &str| trace.iter().position(|l| l.starts_with(p)).unwrap();
        let rpos = |p: &str| trace.iter().rposition(|l| l.starts_with(p)).unwrap();
        assert!(rpos("recv Ack") < pos("send Reduce"));
        assert!(rpos("send Reduce") < pos("recv Partial"));
        assert!(count("send Reduce") >= 1 && count("send Reduce") <= w);
        assert_eq!(count("recv Partial"), count("send Reduce"));
        assert!(trace.last().unwrap().starts_with("done"), "{:?}", trace.last());
    }

    #[test]
    fn cancel_is_best_effort_but_consistent() {
        let db = db(0.005, 53);
        let svc = QueryService::new(cluster(2));
        let id = svc.submit(&db, "q18").unwrap();
        let cancelled = svc.cancel(id);
        if cancelled {
            assert_eq!(svc.poll(id), QueryStatus::Cancelled);
            let err = svc.wait(id).unwrap_err();
            assert!(err.to_string().contains("cancelled"), "{err}");
            // A second cancel is a no-op.
            assert!(!svc.cancel(id));
        } else {
            // The query won the race; its result must still be correct.
            let (rows, _) = svc.wait(id).unwrap();
            assert!(queries::run_query(&db, "q18").unwrap().approx_eq_rows(&rows));
        }
        // The service stays usable either way.
        let id2 = svc.submit(&db, "q6").unwrap();
        let (rows, _) = svc.wait(id2).unwrap();
        assert!(queries::run_query(&db, "q6").unwrap().approx_eq_rows(&rows));
        assert!(!svc.cancel(QueryId(4242)), "unknown id is not cancellable");
    }

    #[test]
    fn poll_reports_progress_phases() {
        let db = db(0.002, 59);
        let svc = QueryService::new(cluster(2));
        let id = svc.submit(&db, "q6").unwrap();
        // Whatever instant we sample, the status is a valid lifecycle
        // state, and it reaches Done.
        loop {
            match svc.poll(id) {
                QueryStatus::Mapping { acked, workers } => assert!(acked <= workers),
                QueryStatus::Reducing { received, expected } => assert!(received <= expected),
                QueryStatus::Done => break,
                other => panic!("unexpected status {other:?}"),
            }
            std::thread::yield_now();
        }
    }

    #[test]
    fn adhoc_plan_executes_without_registry() {
        // The acceptance bar of the plans-as-data redesign: a plan built
        // at the leader, encoded into the PlanFragment, decoded and
        // compiled by workers that never consult engine::spec — under a
        // name the registry has never heard of.
        let db = db(0.002, 61);
        let svc = QueryService::new(cluster(3));
        let mut bag = engine::PlanParams::new();
        bag.set("date-lo", "1995-06-01");
        bag.set("date-hi", "1996-06-01");
        bag.set("qty-lt", "30");
        let mut plan = crate::analytics::queries::build("q6", &bag).unwrap();
        plan.name = "adhoc-revenue".into();
        assert!(engine::spec("adhoc-revenue").is_none(), "name must be unregistered");
        let id = svc.submit_plan(&db, &plan).unwrap();
        let (rows, report) = svc.wait(id).unwrap();
        assert_eq!(report.query, "adhoc-revenue");
        let serial = engine::try_run_serial(&db, &plan).unwrap();
        assert!(serial.approx_eq_rows(&rows), "ad-hoc wire plan diverged from serial");
        assert!(rows[0][0].as_f64() > 0.0, "shifted window should still find revenue");
    }

    #[test]
    fn malformed_wire_plan_fails_the_query_not_the_worker() {
        // A plan referencing a column no table has must come back as a
        // Failed query (worker acks the compile error); the service
        // stays usable afterwards.
        let db = db(0.001, 67);
        let svc = QueryService::new(cluster(2));
        let mut plan = engine::spec("q6").unwrap();
        plan.slots = vec![crate::analytics::engine::plan::vcol("no_such_column")];
        let id = svc.submit_plan(&db, &plan).unwrap();
        let err = svc.wait(id).unwrap_err();
        assert!(err.to_string().contains("no_such_column"), "{err}");
        let ok = svc.submit(&db, "q1").unwrap();
        let (rows, _) = svc.wait(ok).unwrap();
        assert!(queries::run_query(&db, "q1").unwrap().approx_eq_rows(&rows));
    }

    // ------------------------------------------- credit-leak regression

    #[test]
    fn decode_and_merge_absorbs_all_bodies() {
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(2);
        let bodies: Vec<Vec<u8>> = (0..6)
            .map(|i| Partial::single(i, &[1.0], 1, ExecStats::default()).encode())
            .collect();
        let mut merger = Merger::new(1);
        decode_and_merge(&pool, &credits, bodies, &mut merger).unwrap();
        assert_eq!(credits.in_flight(), 0);
        let p = merger.into_partial();
        assert_eq!(p.len(), 6);
        assert_eq!(p.keys, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn decoder_error_releases_credits() {
        // Regression: a corrupt body mid-stream used to leak the credits
        // of every in-flight partial (the error return skipped
        // `release`). The gate must read zero in-flight afterwards and
        // still admit new work.
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(1); // capacity 1 forces retirement
        let good = |k: i64| Partial::single(k, &[1.0], 1, ExecStats::default()).encode();
        let mut corrupt = good(99);
        corrupt.truncate(corrupt.len() - 3);
        let bodies = vec![good(1), corrupt, good(2), good(3)];
        let mut merger = Merger::new(1);
        let err = decode_and_merge(&pool, &credits, bodies, &mut merger);
        assert!(err.is_err(), "corrupt body must surface an error");
        assert_eq!(credits.in_flight(), 0, "error path leaked a credit");
        assert!(credits.try_acquire(), "gate must still admit work");
        credits.release();
    }

    // ------------------------------------------------ overload hardening

    #[test]
    fn cast_backoff_retries_then_succeeds() {
        let mut left = 2;
        let t = Instant::now();
        let r: Result<u32> = with_cast_backoff(|| {
            if left > 0 {
                left -= 1;
                crate::bail!("transient");
            }
            Ok(7)
        });
        assert_eq!(r.unwrap(), 7);
        // Two failures → 1ms + 2ms of backoff before the third attempt.
        assert!(t.elapsed() >= Duration::from_millis(3));
    }

    #[test]
    fn cast_backoff_gives_up_after_three_attempts() {
        let mut calls = 0;
        let r: Result<()> = with_cast_backoff(|| {
            calls += 1;
            crate::bail!("down")
        });
        assert!(r.is_err());
        assert_eq!(calls, 3);
    }

    #[test]
    fn zero_deadline_times_out_with_typed_cause() {
        let db = db(0.001, 71);
        let svc = QueryService::new(cluster(2));
        // An already-expired deadline dies at dispatch, deterministically
        // — and on a default-config service (no monitor thread), which
        // proves the lazy poll/wait enforcement alone suffices.
        let id = svc.submit_with_deadline(&db, "q6", Duration::ZERO).unwrap();
        assert_eq!(svc.poll(id), QueryStatus::Failed(FailCause::Timeout));
        let err = svc.wait(id).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        assert_eq!(svc.credits_in_flight(), 0);
        assert_eq!(svc.buffered_bytes(), 0, "expired query must drop its buffers");
        // The service is unharmed.
        let ok = svc.submit(&db, "q6").unwrap();
        let (rows, _) = svc.wait(ok).unwrap();
        assert!(queries::run_query(&db, "q6").unwrap().approx_eq_rows(&rows));
    }

    #[test]
    fn default_deadline_applies_and_is_overridable() {
        let db = db(0.005, 73);
        // morsel_rows: 1 makes the fold per-row, so q18 reliably takes
        // many ms — far past the 1ms default deadline — and the mid-fold
        // deadline check gets a boundary on every row.
        let svc = QueryService::with_config(
            cluster(2),
            ServiceConfig { default_deadline_ms: 1, morsel_rows: 1, ..ServiceConfig::default() },
        );
        // 1ms is far under q18's runtime at this scale: must time out
        // (monitor sweep or deadline-bounded wait, whichever first).
        let id = svc.submit(&db, "q18").unwrap();
        let err = svc.wait(id).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        assert_eq!(svc.poll(id), QueryStatus::Failed(FailCause::Timeout));
        // A generous explicit deadline overrides the default.
        let opts = SubmitOpts { deadline: Some(Duration::from_secs(60)), ..Default::default() };
        let ok = svc.submit_opts(&db, "q6", opts).unwrap();
        let (rows, _) = svc.wait(ok).unwrap();
        assert!(queries::run_query(&db, "q6").unwrap().approx_eq_rows(&rows));
        assert_eq!(svc.credits_in_flight(), 0);
        assert_eq!(svc.live_queries(), 0);
    }

    #[test]
    fn admission_sheds_explicitly_at_the_in_flight_gate() {
        let db = db(0.005, 79);
        let svc = QueryService::with_config(
            cluster(2),
            ServiceConfig {
                max_dispatched: 1,
                // Small morsels slow the dispatched query enough that
                // the submissions below happen while it is still live.
                morsel_rows: 8,
                admission: AdmissionConfig { max_in_flight: 2, ..Default::default() },
                ..ServiceConfig::default()
            },
        );
        let plan = engine::spec("q18").unwrap();
        let a = svc.submit_plan(&db, &plan).unwrap(); // dispatched
        let b = svc.submit_plan(&db, &plan).unwrap(); // queued (live = 2)
        let shed = svc.try_submit_plan(&db, &plan, SubmitOpts::default()).unwrap();
        let Submission::Shed { id: c, reason } = shed else {
            panic!("third submission must shed, got {shed:?}");
        };
        assert!(
            matches!(reason, ShedReason::InFlight { live: 2, max: 2 }),
            "unexpected reason {reason}"
        );
        assert_eq!(svc.poll(c), QueryStatus::Rejected);
        assert_eq!(svc.shed_queries(), 1);
        let err = svc.wait(c).unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
        // submit_plan surfaces the shed as a typed-reason error.
        let err = svc.submit_plan(&db, &plan).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "{err}");
        // Admitted queries are unaffected and still serial-identical.
        let single = queries::run_query(&db, "q18").unwrap();
        for id in [a, b] {
            let (rows, _) = svc.wait(id).unwrap();
            assert!(single.approx_eq_rows(&rows));
        }
        // With the overload drained, admission opens again.
        let d = svc.submit_plan(&db, &plan).unwrap();
        svc.wait(d).unwrap();
        // A shed id can be retired (drops it from the rejected ring).
        assert!(svc.retire(c));
        assert_eq!(svc.poll(c), QueryStatus::Unknown);
        assert_eq!(svc.credits_in_flight(), 0);
    }

    #[test]
    fn fair_queue_dispatches_across_sessions() {
        let db = db(0.005, 83);
        let svc = QueryService::with_config(
            cluster(2),
            ServiceConfig { max_dispatched: 1, morsel_rows: 8, ..ServiceConfig::default() },
        );
        // Session 1 floods; session 2 sends one query afterwards. With
        // FIFO dispatch the light query would run last; DRR must slot it
        // within the first few dispatches.
        let heavy: Vec<QueryId> = (0..4)
            .map(|_| {
                svc.submit_opts(&db, "q18", SubmitOpts { session: 1, ..Default::default() })
                    .unwrap()
            })
            .collect();
        let light = svc
            .submit_opts(&db, "q18", SubmitOpts { session: 2, ..Default::default() })
            .unwrap();
        for id in heavy.iter().chain([&light]) {
            svc.wait(*id).unwrap();
        }
        let light_seq = svc.dispatch_sequence(light).expect("light must dispatch");
        let last_heavy = heavy
            .iter()
            .map(|id| svc.dispatch_sequence(*id).expect("heavy must dispatch"))
            .max()
            .unwrap();
        assert!(
            light_seq <= 3,
            "light session starved: dispatched #{light_seq} of 5 (heavies up to #{last_heavy})"
        );
        assert_eq!(svc.queued_queries(), 0);
        assert_eq!(svc.live_queries(), 0);
    }

    #[test]
    fn decode_waits_out_a_briefly_held_gate() {
        // All credits held externally at entry: the decode path must
        // wait (bounded) and proceed once a credit comes back — not
        // panic, not wedge.
        let pool = ThreadPool::new(2);
        let credits = Arc::new(Backpressure::new(1));
        assert!(credits.acquire());
        let c2 = Arc::clone(&credits);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            c2.release();
        });
        let bodies = vec![Partial::single(1, &[1.0], 1, ExecStats::default()).encode()];
        let mut merger = Merger::new(1);
        decode_and_merge(&pool, &credits, bodies, &mut merger).unwrap();
        t.join().unwrap();
        assert_eq!(credits.in_flight(), 0);
        assert_eq!(merger.into_partial().len(), 1);
    }

    #[test]
    fn merge_width_error_releases_credits() {
        let pool = ThreadPool::new(2);
        let credits = Backpressure::new(2);
        // Width-2 partial into a width-1 merger: absorb fails.
        let bad = Partial::single(7, &[1.0, 2.0], 1, ExecStats::default()).encode();
        let good = Partial::single(1, &[1.0], 1, ExecStats::default()).encode();
        let mut merger = Merger::new(1);
        let err = decode_and_merge(&pool, &credits, vec![good, bad], &mut merger);
        assert!(err.is_err());
        assert_eq!(credits.in_flight(), 0, "merge error leaked a credit");
    }
}

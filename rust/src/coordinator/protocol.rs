//! The leader↔worker wire protocol of the distributed query service.
//!
//! Lovelock nodes are headless smart NICs: the only way the coordinator
//! can reach a worker is a message on the fabric. Every frame that
//! crosses the leader/worker (or worker/worker) boundary is one of the
//! typed structs below, encoded little-endian into the payload of an
//! [`crate::rpc::Message`] whose `method` is the frame's `METHOD_*` id,
//! and delivered through an [`crate::rpc::Endpoint`].
//!
//! One query's conversation (see `DESIGN.md §3b` for the state machines):
//!
//! ```text
//! leader → worker  : PlanFragment   announce query: the encoded LogicalPlan
//! leader → worker  : ExecuteRange   assign the lineitem row range
//! worker → worker  : PartialFrame   hash-partitioned partial, partition p
//!                                   goes to the reducer co-located with
//!                                   worker p (empty partitions not sent)
//! worker → leader  : Ack            map report: per-partition frame
//!                                   bytes, map time, table footprint
//! leader → reducer : ReduceCmd      which workers' partitions to expect
//! reducer → leader : PartialFrame   the pre-merged, key-deduplicated
//!                                   partition (reduce time piggybacked)
//! leader → worker  : CancelQuery    best-effort abort (frame-boundary
//!                                   granularity — a mid-map worker
//!                                   finishes and its output is dropped)
//! ```
//!
//! All codecs are exact inverses (`encode` then `decode` is identity),
//! property-tested in `rust/tests/properties.rs`.

use crate::error::Result;
use crate::wirefmt::{put_bytes, put_str, put_vec_u32, put_vec_u64, Reader};
use std::fmt;

/// Method id of [`PlanFragment`] frames.
pub const METHOD_PLAN: u32 = 0x50;
/// Method id of [`PartialFrame`] frames (kept from the pre-service
/// shuffle protocol).
pub const METHOD_PARTIAL: u32 = 0x51;
/// Method id of [`ExecuteRange`] frames.
pub const METHOD_EXECUTE: u32 = 0x52;
/// Method id of [`Ack`] frames.
pub const METHOD_ACK: u32 = 0x53;
/// Method id of [`ReduceCmd`] frames.
pub const METHOD_REDUCE: u32 = 0x54;
/// Method id of [`CancelQuery`] frames.
pub const METHOD_CANCEL: u32 = 0x55;
/// Method id of [`Ping`] frames (leader → worker lease probe).
pub const METHOD_PING: u32 = 0x56;
/// Method id of [`Heartbeat`] frames (worker → leader lease renewal).
pub const METHOD_HEARTBEAT: u32 = 0x57;
/// Method id of [`ResendPartition`] frames (repair: re-ship a retained
/// map output to a re-homed reducer).
pub const METHOD_RESEND: u32 = 0x58;
/// Method id of [`ReleaseQuery`] frames (leader → worker: the query is
/// finalized, drop its retained state).
pub const METHOD_RELEASE: u32 = 0x59;
/// Method id of [`Progress`] frames (worker → leader: a long map fold is
/// alive — sent from *inside* the fold at morsel boundaries, because the
/// single dispatch core cannot answer pings while folding).
pub const METHOD_PROGRESS: u32 = 0x5A;

/// Every query-protocol method a chaos [`crate::rpc::FaultPlan`] may
/// target. Lease traffic (`Ping`/`Heartbeat`/`Progress`) is deliberately
/// excluded: faulting the failure detector itself only changes *when* a
/// worker is declared dead, not whether the query recovers, and leaving
/// it clean keeps chaos schedules aligned with the query conversation.
pub const CHAOS_METHODS: &[u32] = &[
    METHOD_PLAN,
    METHOD_PARTIAL,
    METHOD_EXECUTE,
    METHOD_ACK,
    METHOD_REDUCE,
    METHOD_RESEND,
];

/// Identifier of one submitted query, unique within a
/// [`crate::coordinator::service::QueryService`]. Frames of concurrent
/// queries interleave on the shared endpoints; the id is what keys every
/// per-query state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QueryId(pub u64);

impl fmt::Display for QueryId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "q#{}", self.0)
    }
}

// ---------------------------------------------------------------- frames

/// Leader → worker: announce a query before any range executes. The
/// frame carries the **encoded
/// [`crate::analytics::engine::LogicalPlan`]** — the computation itself
/// crosses the fabric; the worker compiles whatever IR arrives and never
/// consults a query registry. The worker stores the fragment and
/// compiles its broadcast context (dimension hash tables) lazily when
/// the [`ExecuteRange`] arrives. `name` is display-only (reports,
/// traces).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanFragment {
    pub query_id: QueryId,
    /// Display name of the plan (not an executable reference).
    pub name: String,
    /// `LogicalPlan::encode` bytes — the query, as data.
    pub plan: Vec<u8>,
    /// Worker count `w` — the fan-out of the partition exchange.
    pub workers: u32,
    /// Rows per morsel inside the worker's fold.
    pub morsel_rows: u64,
    /// Milliseconds the worker may spend before abandoning the fold
    /// (0 = no deadline). Carried on the wire so a deadline takes effect
    /// *mid-fold* — a CancelQuery only lands at frame boundaries, and a
    /// worker grinding a fold for a query the leader already expired is
    /// exactly the overload behavior the admission controller exists to
    /// prevent.
    pub deadline_ms: u64,
}

impl PlanFragment {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.name.len() + self.plan.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        put_str(out, &self.name);
        put_bytes(out, &self.plan);
        out.extend_from_slice(&self.workers.to_le_bytes());
        out.extend_from_slice(&self.morsel_rows.to_le_bytes());
        out.extend_from_slice(&self.deadline_ms.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            name: r.str()?,
            plan: r.bytes()?,
            workers: r.u32()?,
            morsel_rows: r.u64()?,
            deadline_ms: r.u64()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: execute the query over lineitem rows `[lo, hi)`.
///
/// `worker` is the **logical** fragment index — under repair a fragment
/// may be re-executed on a different endpoint, but its partition hashing
/// and sender identity stay the logical index, so re-execution produces
/// byte-identical partials. `route[p]` names the endpoint currently
/// hosting reducer partition `p` (the identity map until a reducer is
/// re-homed). `epoch` counts repair rounds; every frame derived from
/// this execute carries it so stale deliveries are recognizable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecuteRange {
    pub query_id: QueryId,
    /// Logical fragment index (also its reducer partition).
    pub worker: u32,
    pub lo: u64,
    pub hi: u64,
    /// Repair epoch this assignment belongs to (0 = first attempt).
    pub epoch: u32,
    /// Partition → endpoint routing table, length `w`.
    pub route: Vec<u32>,
}

impl ExecuteRange {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(36 + 4 * self.route.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.lo.to_le_bytes());
        out.extend_from_slice(&self.hi.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        put_vec_u32(out, &self.route);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            worker: r.u32()?,
            lo: r.u64()?,
            hi: r.u64()?,
            epoch: r.u32()?,
            route: r.vec_u32()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Worker → leader: the map phase finished (or failed). `part_bytes[p]`
/// is the encoded [`PartialFrame`] wire bytes this worker cast to
/// reducer `p` (0 for empty partitions, which are never sent) — the
/// leader assembles the exchange matrix from these reports.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ack {
    pub query_id: QueryId,
    pub worker: u32,
    /// Repair epoch of the [`ExecuteRange`] being acknowledged — the
    /// leader discards acks from superseded epochs.
    pub epoch: u32,
    /// Nanoseconds of host compute the map fold took (≥ 1: a
    /// measured phase never reports zero).
    pub map_ns: u64,
    /// Peak live hash-table footprint of the fold (bytes).
    pub ht_bytes: u64,
    /// Scan chunks this worker skipped wholesale via zone-map pruning.
    pub morsels_pruned: u64,
    /// Exchange frame bytes per reducer partition (length `w`).
    pub part_bytes: Vec<u64>,
    /// Empty on success; a failed worker reports why here.
    pub error: String,
}

impl Ack {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40 + 8 * self.part_bytes.len() + self.error.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.map_ns.to_le_bytes());
        out.extend_from_slice(&self.ht_bytes.to_le_bytes());
        out.extend_from_slice(&self.morsels_pruned.to_le_bytes());
        put_vec_u64(out, &self.part_bytes);
        put_str(out, &self.error);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            worker: r.u32()?,
            epoch: r.u32()?,
            map_ns: r.u64()?,
            ht_bytes: r.u64()?,
            morsels_pruned: r.u64()?,
            part_bytes: r.vec_u64()?,
            error: r.str()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → reducer `partition`: every map ack is in; merge the
/// [`PartialFrame`]s from exactly the `(worker, epoch)` pairs in
/// `expect` (the workers whose partition was non-empty, each pinned to
/// the epoch whose ack the leader accepted) and ship the result to the
/// leader. Naming the epoch is what makes the reduce idempotent under
/// repair: a partial from a superseded execution attempt is simply never
/// in the expected set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReduceCmd {
    pub query_id: QueryId,
    pub partition: u32,
    /// `(logical worker, epoch)` pairs whose frames to await, ascending
    /// by worker.
    pub expect: Vec<(u32, u32)>,
}

impl ReduceCmd {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + 8 * self.expect.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.partition.to_le_bytes());
        let workers: Vec<u32> = self.expect.iter().map(|&(w, _)| w).collect();
        let epochs: Vec<u32> = self.expect.iter().map(|&(_, e)| e).collect();
        put_vec_u32(out, &workers);
        put_vec_u32(out, &epochs);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let query_id = QueryId(r.u64()?);
        let partition = r.u32()?;
        let workers = r.vec_u32()?;
        let epochs = r.vec_u32()?;
        r.finish()?;
        crate::ensure!(
            workers.len() == epochs.len(),
            "reduce expect: {} workers vs {} epochs",
            workers.len(),
            epochs.len()
        );
        Ok(Self {
            query_id,
            partition,
            expect: workers.into_iter().zip(epochs).collect(),
        })
    }
}

/// A partial aggregate on the wire: worker → reducer during the
/// exchange, reducer → leader after the pre-merge. `body` is
/// [`crate::analytics::engine::Partial::encode`] output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartialFrame {
    pub query_id: QueryId,
    /// Reducer partition this partial belongs to.
    pub partition: u32,
    /// Sender: logical worker index (exchange hop) or reducer partition
    /// (leader hop).
    pub from_worker: u32,
    /// Repair epoch of the execution attempt that produced this partial
    /// — reducers merge one frame per expected `(worker, epoch)` and
    /// drop the rest (duplicates, superseded attempts).
    pub epoch: u32,
    /// Reducer → leader only: nanoseconds the pre-merge took.
    pub reduce_ns: u64,
    /// Encoded [`crate::analytics::engine::Partial`].
    pub body: Vec<u8>,
}

impl PartialFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.body.len());
        Self::encode_parts_into(
            self.query_id,
            self.partition,
            self.from_worker,
            self.epoch,
            self.reduce_ns,
            &self.body,
            &mut out,
        );
        out
    }

    /// Append a frame's wire encoding built straight from its parts, the
    /// body supplied as a slice — the pooled-buffer path: the query
    /// service encodes exchange frames without ever materializing a
    /// `PartialFrame` struct (whose `body` field would force an owned
    /// copy of the partial bytes).
    pub fn encode_parts_into(
        query_id: QueryId,
        partition: u32,
        from_worker: u32,
        epoch: u32,
        reduce_ns: u64,
        body: &[u8],
        out: &mut Vec<u8>,
    ) {
        out.reserve(32 + body.len());
        out.extend_from_slice(&query_id.0.to_le_bytes());
        out.extend_from_slice(&partition.to_le_bytes());
        out.extend_from_slice(&from_worker.to_le_bytes());
        out.extend_from_slice(&epoch.to_le_bytes());
        out.extend_from_slice(&reduce_ns.to_le_bytes());
        put_bytes(out, body);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            partition: r.u32()?,
            from_worker: r.u32()?,
            epoch: r.u32()?,
            reduce_ns: r.u64()?,
            body: r.bytes()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: abort a query. Takes effect at frame boundaries
/// (an endpoint mid-map finishes its fold; the leader discards the
/// output) — exactly the granularity a single-dispatch-core NIC has.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CancelQuery {
    pub query_id: QueryId,
}

impl CancelQuery {
    pub fn encode(&self) -> Vec<u8> {
        self.query_id.0.to_le_bytes().to_vec()
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self { query_id: QueryId(r.u64()?) };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: lease probe. Carries only a nonce; the worker
/// answers with a [`Heartbeat`] echoing it. Ping/heartbeat traffic is
/// the failure detector's only signal — a worker whose heartbeats stop
/// arriving for a lease interval is declared dead (see DESIGN.md §3d).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ping {
    pub nonce: u64,
}

impl Ping {
    pub fn encode(&self) -> Vec<u8> {
        self.nonce.to_le_bytes().to_vec()
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.nonce.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self { nonce: r.u64()? };
        r.finish()?;
        Ok(v)
    }
}

/// Worker → leader: lease renewal, answering a [`Ping`]. `worker` is the
/// sender's endpoint index; `nonce` echoes the ping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Heartbeat {
    pub worker: u32,
    pub nonce: u64,
}

impl Heartbeat {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.nonce.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self { worker: r.u32()?, nonce: r.u64()? };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → surviving worker (repair): re-cast the retained map output
/// of logical fragment `worker` for reducer `partition` to endpoint
/// `to` — the reducer that partition was re-homed to. A worker that no
/// longer retains that output ignores the frame; the leader's stall
/// detector will then escalate to re-executing the fragment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResendPartition {
    pub query_id: QueryId,
    /// Logical fragment whose output to re-ship.
    pub worker: u32,
    /// Reducer partition wanted.
    pub partition: u32,
    /// Destination endpoint index.
    pub to: u32,
}

impl ResendPartition {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.partition.to_le_bytes());
        out.extend_from_slice(&self.to.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            worker: r.u32()?,
            partition: r.u32()?,
            to: r.u32()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Worker → leader: a map fold is *slow, not dead*. Cast from inside
/// [`ExecuteRange`] handling at morsel boundaries whenever the fold has
/// run longer than the progress interval. The endpoint's single dispatch
/// core cannot answer [`Ping`]s while it folds, so without this frame a
/// fold outliving the lease is indistinguishable from a dead worker: the
/// monitor expires the lease, re-executes the fragment at a bumped
/// epoch, the original ack arrives stale — and the cycle repeats
/// (livelock). A progress frame renews both the endpoint's lease and the
/// query's stall clock (when `epoch` is current).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Progress {
    pub query_id: QueryId,
    /// Physical endpoint index doing the folding (lease renewal key).
    pub endpoint: u32,
    /// Logical fragment index being folded.
    pub worker: u32,
    /// Repair epoch of the execution attempt — a superseded attempt's
    /// progress renews the endpoint lease but not the query stall clock.
    pub epoch: u32,
}

impl Progress {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.endpoint.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            endpoint: r.u32()?,
            worker: r.u32()?,
            epoch: r.u32()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: the query is finalized (done or abandoned); drop all
/// retained state for it (plan, materialized map outputs, reduce
/// buffers). What `CancelQuery` is to an in-flight query, this is to a
/// finished one — without it, state retained for repair would outlive
/// every query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReleaseQuery {
    pub query_id: QueryId,
}

impl ReleaseQuery {
    pub fn encode(&self) -> Vec<u8> {
        self.query_id.0.to_le_bytes().to_vec()
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self { query_id: QueryId(r.u64()?) };
        r.finish()?;
        Ok(v)
    }
}

/// Any protocol frame, decoded from a raw [`crate::rpc::Message`] by
/// method id — the tracing/debugging view of a conversation.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Plan(PlanFragment),
    Execute(ExecuteRange),
    Ack(Ack),
    Reduce(ReduceCmd),
    Partial(PartialFrame),
    Cancel(CancelQuery),
    Ping(Ping),
    Heartbeat(Heartbeat),
    Resend(ResendPartition),
    Release(ReleaseQuery),
    Progress(Progress),
}

impl Frame {
    pub fn decode(msg: &crate::rpc::Message) -> Result<Frame> {
        match msg.method {
            METHOD_PLAN => Ok(Frame::Plan(PlanFragment::decode(&msg.payload)?)),
            METHOD_EXECUTE => Ok(Frame::Execute(ExecuteRange::decode(&msg.payload)?)),
            METHOD_ACK => Ok(Frame::Ack(Ack::decode(&msg.payload)?)),
            METHOD_REDUCE => Ok(Frame::Reduce(ReduceCmd::decode(&msg.payload)?)),
            METHOD_PARTIAL => Ok(Frame::Partial(PartialFrame::decode(&msg.payload)?)),
            METHOD_CANCEL => Ok(Frame::Cancel(CancelQuery::decode(&msg.payload)?)),
            METHOD_PING => Ok(Frame::Ping(Ping::decode(&msg.payload)?)),
            METHOD_HEARTBEAT => Ok(Frame::Heartbeat(Heartbeat::decode(&msg.payload)?)),
            METHOD_RESEND => Ok(Frame::Resend(ResendPartition::decode(&msg.payload)?)),
            METHOD_RELEASE => Ok(Frame::Release(ReleaseQuery::decode(&msg.payload)?)),
            METHOD_PROGRESS => Ok(Frame::Progress(Progress::decode(&msg.payload)?)),
            m => crate::bail!("unknown protocol method {m:#x}"),
        }
    }

    /// The query this frame belongs to — `None` for lease traffic
    /// (ping/heartbeat), which is a property of the fabric, not of any
    /// one query.
    pub fn query_id(&self) -> Option<QueryId> {
        match self {
            Frame::Plan(f) => Some(f.query_id),
            Frame::Execute(f) => Some(f.query_id),
            Frame::Ack(f) => Some(f.query_id),
            Frame::Reduce(f) => Some(f.query_id),
            Frame::Partial(f) => Some(f.query_id),
            Frame::Cancel(f) => Some(f.query_id),
            Frame::Ping(_) | Frame::Heartbeat(_) => None,
            Frame::Resend(f) => Some(f.query_id),
            Frame::Release(f) => Some(f.query_id),
            Frame::Progress(f) => Some(f.query_id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::Message;

    #[test]
    fn plan_fragment_roundtrip() {
        let f = PlanFragment {
            query_id: QueryId(7),
            name: "q18".into(),
            plan: vec![9, 8, 7, 6],
            workers: 8,
            morsel_rows: 16_384,
            deadline_ms: 2_500,
        };
        assert_eq!(PlanFragment::decode(&f.encode()).unwrap(), f);
        let no_deadline = PlanFragment { deadline_ms: 0, ..f };
        assert_eq!(PlanFragment::decode(&no_deadline.encode()).unwrap(), no_deadline);
    }

    #[test]
    fn execute_range_roundtrip() {
        let f = ExecuteRange {
            query_id: QueryId(1),
            worker: 3,
            lo: 1000,
            hi: 2000,
            epoch: 2,
            route: vec![0, 1, 2, 3],
        };
        assert_eq!(ExecuteRange::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn ack_roundtrip_with_error_and_parts() {
        let f = Ack {
            query_id: QueryId(9),
            worker: 2,
            epoch: 1,
            map_ns: 12345,
            ht_bytes: 1 << 20,
            morsels_pruned: 7,
            part_bytes: vec![0, 64, 0, 1024],
            error: "".into(),
        };
        assert_eq!(Ack::decode(&f.encode()).unwrap(), f);
        let e = Ack { error: "no plan for q#9".into(), part_bytes: vec![], ..f };
        assert_eq!(Ack::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn reduce_cmd_roundtrip() {
        let f =
            ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![(0, 0), (2, 1), (5, 0)] };
        assert_eq!(ReduceCmd::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn partial_frame_roundtrip() {
        let f = PartialFrame {
            query_id: QueryId(2),
            partition: 5,
            from_worker: 1,
            epoch: 3,
            reduce_ns: 88,
            body: vec![1, 2, 3, 4, 5, 6, 7],
        };
        assert_eq!(PartialFrame::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn lease_and_repair_frames_roundtrip() {
        let p = Ping { nonce: 0xABCD };
        assert_eq!(Ping::decode(&p.encode()).unwrap(), p);
        let h = Heartbeat { worker: 3, nonce: 0xABCD };
        assert_eq!(Heartbeat::decode(&h.encode()).unwrap(), h);
        let rs = ResendPartition { query_id: QueryId(5), worker: 1, partition: 2, to: 3 };
        assert_eq!(ResendPartition::decode(&rs.encode()).unwrap(), rs);
        let rl = ReleaseQuery { query_id: QueryId(6) };
        assert_eq!(ReleaseQuery::decode(&rl.encode()).unwrap(), rl);
        let pr = Progress { query_id: QueryId(8), endpoint: 2, worker: 1, epoch: 4 };
        assert_eq!(Progress::decode(&pr.encode()).unwrap(), pr);
        // A mid-fold progress frame names its query (the stall clock it
        // renews), unlike ping/heartbeat.
        let msg = Message { method: METHOD_PROGRESS, id: 1, payload: pr.encode() };
        assert_eq!(Frame::decode(&msg).unwrap().query_id(), Some(QueryId(8)));
        // Lease frames carry no query id; repair frames do.
        let msg = Message { method: METHOD_PING, id: 1, payload: p.encode() };
        assert_eq!(Frame::decode(&msg).unwrap().query_id(), None);
        let msg = Message { method: METHOD_RESEND, id: 1, payload: rs.encode() };
        assert_eq!(Frame::decode(&msg).unwrap().query_id(), Some(QueryId(5)));
    }

    #[test]
    fn reduce_cmd_rejects_mismatched_expect_vectors() {
        // Hand-build a payload whose worker and epoch vectors disagree.
        let mut buf = Vec::new();
        buf.extend_from_slice(&4u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        put_vec_u32(&mut buf, &[0, 2]);
        put_vec_u32(&mut buf, &[0]);
        assert!(ReduceCmd::decode(&buf).is_err());
    }

    #[test]
    fn encode_into_appends_identically() {
        // The pooled-buffer forms must be byte-identical to `encode`,
        // and append (never clobber a partially written frame buffer).
        let pf = PartialFrame {
            query_id: QueryId(2),
            partition: 5,
            from_worker: 1,
            epoch: 2,
            reduce_ns: 88,
            body: vec![1, 2, 3],
        };
        let mut out = vec![0xAB];
        PartialFrame::encode_parts_into(
            pf.query_id,
            pf.partition,
            pf.from_worker,
            pf.epoch,
            pf.reduce_ns,
            &pf.body,
            &mut out,
        );
        assert_eq!(out[0], 0xAB);
        assert_eq!(&out[1..], pf.encode().as_slice());

        let ack = Ack {
            query_id: QueryId(9),
            worker: 2,
            epoch: 0,
            map_ns: 1,
            ht_bytes: 2,
            morsels_pruned: 3,
            part_bytes: vec![0, 64],
            error: "e".into(),
        };
        let mut out = Vec::new();
        ack.encode_into(&mut out);
        assert_eq!(out, ack.encode());
        let rc =
            ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![(0, 0), (2, 1), (5, 0)] };
        let mut out = Vec::new();
        rc.encode_into(&mut out);
        assert_eq!(out, rc.encode());
    }

    #[test]
    fn cancel_roundtrip() {
        let f = CancelQuery { query_id: QueryId(0xDEAD) };
        assert_eq!(CancelQuery::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn decode_rejects_truncation_and_trailing_garbage() {
        let enc =
            ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![(0, 0), (2, 0)] }.encode();
        assert!(ReduceCmd::decode(&enc[..enc.len() - 1]).is_err());
        let mut padded = enc.clone();
        padded.push(0);
        assert!(ReduceCmd::decode(&padded).is_err());
        assert!(PlanFragment::decode(&[1, 2, 3]).is_err());
    }

    #[test]
    fn frame_decodes_by_method() {
        let pf = PlanFragment {
            query_id: QueryId(3),
            name: "q1".into(),
            plan: vec![1, 2, 3],
            workers: 2,
            morsel_rows: 64,
            deadline_ms: 0,
        };
        let msg = Message { method: METHOD_PLAN, id: 1, payload: pf.encode() };
        match Frame::decode(&msg).unwrap() {
            Frame::Plan(got) => assert_eq!(got, pf),
            other => panic!("wrong variant: {other:?}"),
        }
        assert_eq!(Frame::decode(&msg).unwrap().query_id(), Some(QueryId(3)));
        let bad = Message { method: 0x99, id: 1, payload: vec![] };
        assert!(Frame::decode(&bad).is_err());
    }
}

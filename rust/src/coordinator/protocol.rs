//! The leader↔worker wire protocol of the distributed query service.
//!
//! Lovelock nodes are headless smart NICs: the only way the coordinator
//! can reach a worker is a message on the fabric. Every frame that
//! crosses the leader/worker (or worker/worker) boundary is one of the
//! typed structs below, encoded little-endian into the payload of an
//! [`crate::rpc::Message`] whose `method` is the frame's `METHOD_*` id,
//! and delivered through an [`crate::rpc::Endpoint`].
//!
//! One query's conversation (see `DESIGN.md §3b` for the state machines):
//!
//! ```text
//! leader → worker  : PlanFragment   announce query: the encoded LogicalPlan
//! leader → worker  : ExecuteRange   assign the lineitem row range
//! worker → worker  : PartialFrame   hash-partitioned partial, partition p
//!                                   goes to the reducer co-located with
//!                                   worker p (empty partitions not sent)
//! worker → leader  : Ack            map report: per-partition frame
//!                                   bytes, map time, table footprint
//! leader → reducer : ReduceCmd      which workers' partitions to expect
//! reducer → leader : PartialFrame   the pre-merged, key-deduplicated
//!                                   partition (reduce time piggybacked)
//! leader → worker  : CancelQuery    best-effort abort (frame-boundary
//!                                   granularity — a mid-map worker
//!                                   finishes and its output is dropped)
//! ```
//!
//! All codecs are exact inverses (`encode` then `decode` is identity),
//! property-tested in `rust/tests/properties.rs`.

use crate::error::Result;
use crate::wirefmt::{put_bytes, put_str, put_vec_u32, put_vec_u64, Reader};
use std::fmt;

/// Method id of [`PlanFragment`] frames.
pub const METHOD_PLAN: u32 = 0x50;
/// Method id of [`PartialFrame`] frames (kept from the pre-service
/// shuffle protocol).
pub const METHOD_PARTIAL: u32 = 0x51;
/// Method id of [`ExecuteRange`] frames.
pub const METHOD_EXECUTE: u32 = 0x52;
/// Method id of [`Ack`] frames.
pub const METHOD_ACK: u32 = 0x53;
/// Method id of [`ReduceCmd`] frames.
pub const METHOD_REDUCE: u32 = 0x54;
/// Method id of [`CancelQuery`] frames.
pub const METHOD_CANCEL: u32 = 0x55;

/// Identifier of one submitted query, unique within a
/// [`crate::coordinator::service::QueryService`]. Frames of concurrent
/// queries interleave on the shared endpoints; the id is what keys every
/// per-query state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QueryId(pub u64);

impl fmt::Display for QueryId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "q#{}", self.0)
    }
}

// ---------------------------------------------------------------- frames

/// Leader → worker: announce a query before any range executes. The
/// frame carries the **encoded
/// [`crate::analytics::engine::LogicalPlan`]** — the computation itself
/// crosses the fabric; the worker compiles whatever IR arrives and never
/// consults a query registry. The worker stores the fragment and
/// compiles its broadcast context (dimension hash tables) lazily when
/// the [`ExecuteRange`] arrives. `name` is display-only (reports,
/// traces).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanFragment {
    pub query_id: QueryId,
    /// Display name of the plan (not an executable reference).
    pub name: String,
    /// `LogicalPlan::encode` bytes — the query, as data.
    pub plan: Vec<u8>,
    /// Worker count `w` — the fan-out of the partition exchange.
    pub workers: u32,
    /// Rows per morsel inside the worker's fold.
    pub morsel_rows: u64,
}

impl PlanFragment {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.name.len() + self.plan.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        put_str(out, &self.name);
        put_bytes(out, &self.plan);
        out.extend_from_slice(&self.workers.to_le_bytes());
        out.extend_from_slice(&self.morsel_rows.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            name: r.str()?,
            plan: r.bytes()?,
            workers: r.u32()?,
            morsel_rows: r.u64()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: execute the query over lineitem rows `[lo, hi)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecuteRange {
    pub query_id: QueryId,
    /// Receiving worker's index (also its reducer partition).
    pub worker: u32,
    pub lo: u64,
    pub hi: u64,
}

impl ExecuteRange {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.lo.to_le_bytes());
        out.extend_from_slice(&self.hi.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            worker: r.u32()?,
            lo: r.u64()?,
            hi: r.u64()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Worker → leader: the map phase finished (or failed). `part_bytes[p]`
/// is the encoded [`PartialFrame`] wire bytes this worker cast to
/// reducer `p` (0 for empty partitions, which are never sent) — the
/// leader assembles the exchange matrix from these reports.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ack {
    pub query_id: QueryId,
    pub worker: u32,
    /// Nanoseconds of host compute the map fold took (≥ 1: a
    /// measured phase never reports zero).
    pub map_ns: u64,
    /// Peak live hash-table footprint of the fold (bytes).
    pub ht_bytes: u64,
    /// Exchange frame bytes per reducer partition (length `w`).
    pub part_bytes: Vec<u64>,
    /// Empty on success; a failed worker reports why here.
    pub error: String,
}

impl Ack {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40 + 8 * self.part_bytes.len() + self.error.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.map_ns.to_le_bytes());
        out.extend_from_slice(&self.ht_bytes.to_le_bytes());
        put_vec_u64(out, &self.part_bytes);
        put_str(out, &self.error);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            worker: r.u32()?,
            map_ns: r.u64()?,
            ht_bytes: r.u64()?,
            part_bytes: r.vec_u64()?,
            error: r.str()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → reducer `partition`: every map ack is in; merge the
/// [`PartialFrame`]s from exactly the workers in `expect` (the ones
/// whose partition was non-empty) and ship the result to the leader.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReduceCmd {
    pub query_id: QueryId,
    pub partition: u32,
    /// Worker indices whose partition frames to await, ascending.
    pub expect: Vec<u32>,
}

impl ReduceCmd {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + 4 * self.expect.len());
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
        out.extend_from_slice(&self.partition.to_le_bytes());
        put_vec_u32(out, &self.expect);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            partition: r.u32()?,
            expect: r.vec_u32()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// A partial aggregate on the wire: worker → reducer during the
/// exchange, reducer → leader after the pre-merge. `body` is
/// [`crate::analytics::engine::Partial::encode`] output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartialFrame {
    pub query_id: QueryId,
    /// Reducer partition this partial belongs to.
    pub partition: u32,
    /// Sender: worker index (exchange hop) or reducer index (leader hop).
    pub from_worker: u32,
    /// Reducer → leader only: nanoseconds the pre-merge took.
    pub reduce_ns: u64,
    /// Encoded [`crate::analytics::engine::Partial`].
    pub body: Vec<u8>,
}

impl PartialFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28 + self.body.len());
        Self::encode_parts_into(
            self.query_id,
            self.partition,
            self.from_worker,
            self.reduce_ns,
            &self.body,
            &mut out,
        );
        out
    }

    /// Append a frame's wire encoding built straight from its parts, the
    /// body supplied as a slice — the pooled-buffer path: the query
    /// service encodes exchange frames without ever materializing a
    /// `PartialFrame` struct (whose `body` field would force an owned
    /// copy of the partial bytes).
    pub fn encode_parts_into(
        query_id: QueryId,
        partition: u32,
        from_worker: u32,
        reduce_ns: u64,
        body: &[u8],
        out: &mut Vec<u8>,
    ) {
        out.reserve(28 + body.len());
        out.extend_from_slice(&query_id.0.to_le_bytes());
        out.extend_from_slice(&partition.to_le_bytes());
        out.extend_from_slice(&from_worker.to_le_bytes());
        out.extend_from_slice(&reduce_ns.to_le_bytes());
        put_bytes(out, body);
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self {
            query_id: QueryId(r.u64()?),
            partition: r.u32()?,
            from_worker: r.u32()?,
            reduce_ns: r.u64()?,
            body: r.bytes()?,
        };
        r.finish()?;
        Ok(v)
    }
}

/// Leader → worker: abort a query. Takes effect at frame boundaries
/// (an endpoint mid-map finishes its fold; the leader discards the
/// output) — exactly the granularity a single-dispatch-core NIC has.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CancelQuery {
    pub query_id: QueryId,
}

impl CancelQuery {
    pub fn encode(&self) -> Vec<u8> {
        self.query_id.0.to_le_bytes().to_vec()
    }

    /// Append the wire encoding to `out` (the pooled-buffer path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.query_id.0.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self { query_id: QueryId(r.u64()?) };
        r.finish()?;
        Ok(v)
    }
}

/// Any protocol frame, decoded from a raw [`crate::rpc::Message`] by
/// method id — the tracing/debugging view of a conversation.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Plan(PlanFragment),
    Execute(ExecuteRange),
    Ack(Ack),
    Reduce(ReduceCmd),
    Partial(PartialFrame),
    Cancel(CancelQuery),
}

impl Frame {
    pub fn decode(msg: &crate::rpc::Message) -> Result<Frame> {
        match msg.method {
            METHOD_PLAN => Ok(Frame::Plan(PlanFragment::decode(&msg.payload)?)),
            METHOD_EXECUTE => Ok(Frame::Execute(ExecuteRange::decode(&msg.payload)?)),
            METHOD_ACK => Ok(Frame::Ack(Ack::decode(&msg.payload)?)),
            METHOD_REDUCE => Ok(Frame::Reduce(ReduceCmd::decode(&msg.payload)?)),
            METHOD_PARTIAL => Ok(Frame::Partial(PartialFrame::decode(&msg.payload)?)),
            METHOD_CANCEL => Ok(Frame::Cancel(CancelQuery::decode(&msg.payload)?)),
            m => crate::bail!("unknown protocol method {m:#x}"),
        }
    }

    pub fn query_id(&self) -> QueryId {
        match self {
            Frame::Plan(f) => f.query_id,
            Frame::Execute(f) => f.query_id,
            Frame::Ack(f) => f.query_id,
            Frame::Reduce(f) => f.query_id,
            Frame::Partial(f) => f.query_id,
            Frame::Cancel(f) => f.query_id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::Message;

    #[test]
    fn plan_fragment_roundtrip() {
        let f = PlanFragment {
            query_id: QueryId(7),
            name: "q18".into(),
            plan: vec![9, 8, 7, 6],
            workers: 8,
            morsel_rows: 16_384,
        };
        assert_eq!(PlanFragment::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn execute_range_roundtrip() {
        let f = ExecuteRange { query_id: QueryId(1), worker: 3, lo: 1000, hi: 2000 };
        assert_eq!(ExecuteRange::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn ack_roundtrip_with_error_and_parts() {
        let f = Ack {
            query_id: QueryId(9),
            worker: 2,
            map_ns: 12345,
            ht_bytes: 1 << 20,
            part_bytes: vec![0, 64, 0, 1024],
            error: "".into(),
        };
        assert_eq!(Ack::decode(&f.encode()).unwrap(), f);
        let e = Ack { error: "no plan for q#9".into(), part_bytes: vec![], ..f };
        assert_eq!(Ack::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn reduce_cmd_roundtrip() {
        let f = ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![0, 2, 5] };
        assert_eq!(ReduceCmd::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn partial_frame_roundtrip() {
        let f = PartialFrame {
            query_id: QueryId(2),
            partition: 5,
            from_worker: 1,
            reduce_ns: 88,
            body: vec![1, 2, 3, 4, 5, 6, 7],
        };
        assert_eq!(PartialFrame::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn encode_into_appends_identically() {
        // The pooled-buffer forms must be byte-identical to `encode`,
        // and append (never clobber a partially written frame buffer).
        let pf = PartialFrame {
            query_id: QueryId(2),
            partition: 5,
            from_worker: 1,
            reduce_ns: 88,
            body: vec![1, 2, 3],
        };
        let mut out = vec![0xAB];
        PartialFrame::encode_parts_into(
            pf.query_id,
            pf.partition,
            pf.from_worker,
            pf.reduce_ns,
            &pf.body,
            &mut out,
        );
        assert_eq!(out[0], 0xAB);
        assert_eq!(&out[1..], pf.encode().as_slice());

        let ack = Ack {
            query_id: QueryId(9),
            worker: 2,
            map_ns: 1,
            ht_bytes: 2,
            part_bytes: vec![0, 64],
            error: "e".into(),
        };
        let mut out = Vec::new();
        ack.encode_into(&mut out);
        assert_eq!(out, ack.encode());
        let rc = ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![0, 2, 5] };
        let mut out = Vec::new();
        rc.encode_into(&mut out);
        assert_eq!(out, rc.encode());
    }

    #[test]
    fn cancel_roundtrip() {
        let f = CancelQuery { query_id: QueryId(0xDEAD) };
        assert_eq!(CancelQuery::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn decode_rejects_truncation_and_trailing_garbage() {
        let enc = ReduceCmd { query_id: QueryId(4), partition: 1, expect: vec![0, 2] }.encode();
        assert!(ReduceCmd::decode(&enc[..enc.len() - 1]).is_err());
        let mut padded = enc.clone();
        padded.push(0);
        assert!(ReduceCmd::decode(&padded).is_err());
        assert!(PlanFragment::decode(&[1, 2, 3]).is_err());
    }

    #[test]
    fn frame_decodes_by_method() {
        let pf = PlanFragment {
            query_id: QueryId(3),
            name: "q1".into(),
            plan: vec![1, 2, 3],
            workers: 2,
            morsel_rows: 64,
        };
        let msg = Message { method: METHOD_PLAN, id: 1, payload: pf.encode() };
        match Frame::decode(&msg).unwrap() {
            Frame::Plan(got) => assert_eq!(got, pf),
            other => panic!("wrong variant: {other:?}"),
        }
        assert_eq!(Frame::decode(&msg).unwrap().query_id(), QueryId(3));
        let bad = Message { method: 0x99, id: 1, payload: vec![] };
        assert!(Frame::decode(&bad).is_err());
    }
}

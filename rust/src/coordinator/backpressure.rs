//! Credit-based backpressure.
//!
//! Lovelock nodes are small (16 cores, 48 GB); the coordinator bounds
//! in-flight work per node with a credit gate. `acquire` blocks until a
//! credit is free (or the gate is closed), `release` returns one. The
//! distributed executor holds one credit per outstanding task per node.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct State {
    available: usize,
    closed: bool,
    /// High-water mark of concurrently held credits (for tests/metrics).
    max_in_flight: usize,
    capacity: usize,
}

/// A counting credit gate.
pub struct Backpressure {
    state: Mutex<State>,
    cv: Condvar,
}

impl Backpressure {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            state: Mutex::new(State {
                available: capacity,
                closed: false,
                max_in_flight: 0,
                capacity,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until a credit is available. Returns `false` if closed.
    pub fn acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.available > 0 {
                st.available -= 1;
                let in_flight = st.capacity - st.available;
                st.max_in_flight = st.max_in_flight.max(in_flight);
                return true;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Block until a credit is available, but never past `dur`. Returns
    /// `false` on timeout or if the gate closes while waiting. The
    /// leader's decode path uses this instead of an unbounded `acquire`:
    /// if a credit is ever lost (a `release` skipped by a bug or a
    /// poisoned path), the completing query surfaces a typed error after
    /// `dur` instead of wedging `wait()` forever.
    pub fn acquire_timeout(&self, dur: Duration) -> bool {
        let deadline = Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.available > 0 {
                st.available -= 1;
                let in_flight = st.capacity - st.available;
                st.max_in_flight = st.max_in_flight.max(in_flight);
                return true;
            }
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                return false;
            };
            let (g, timeout) = self.cv.wait_timeout(st, left).unwrap();
            st = g;
            if timeout.timed_out() && st.available == 0 {
                return false;
            }
        }
    }

    /// Non-blocking acquire.
    pub fn try_acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.available == 0 {
            return false;
        }
        st.available -= 1;
        let in_flight = st.capacity - st.available;
        st.max_in_flight = st.max_in_flight.max(in_flight);
        true
    }

    pub fn release(&self) {
        let mut st = self.state.lock().unwrap();
        assert!(st.available < st.capacity, "release without acquire");
        st.available += 1;
        drop(st);
        self.cv.notify_one();
    }

    /// Close the gate: pending and future acquires return `false`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn in_flight(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.capacity - st.available
    }

    pub fn max_in_flight(&self) -> usize {
        self.state.lock().unwrap().max_in_flight
    }

    /// Total credits this gate was built with.
    pub fn capacity(&self) -> usize {
        self.state.lock().unwrap().capacity
    }

    /// Credits currently free (capacity − in flight). The admission
    /// controller sheds when this drops under its floor — a saturated
    /// decode gate means the leader is already at its concurrency limit.
    pub fn free(&self) -> usize {
        self.state.lock().unwrap().available
    }

    /// True when every credit is back home — the invariant each query
    /// must restore on *every* exit path (done, failed, cancelled,
    /// repaired). The chaos suite asserts this after each fault
    /// schedule; a `false` here on an idle gate means a failure path
    /// leaked a credit.
    pub fn balanced(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.available == st.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn acquire_release_cycle() {
        let bp = Backpressure::new(2);
        assert!(bp.acquire());
        assert!(bp.acquire());
        assert!(!bp.try_acquire());
        assert_eq!(bp.in_flight(), 2);
        bp.release();
        assert!(bp.try_acquire());
        assert_eq!(bp.max_in_flight(), 2);
    }

    #[test]
    fn blocks_until_release() {
        let bp = Arc::new(Backpressure::new(1));
        assert!(bp.acquire());
        let bp2 = bp.clone();
        let t = std::thread::spawn(move || bp2.acquire());
        std::thread::sleep(std::time::Duration::from_millis(20));
        bp.release();
        assert!(t.join().unwrap());
    }

    #[test]
    fn close_unblocks_waiters() {
        let bp = Arc::new(Backpressure::new(1));
        assert!(bp.acquire());
        let bp2 = bp.clone();
        let t = std::thread::spawn(move || bp2.acquire());
        std::thread::sleep(std::time::Duration::from_millis(20));
        bp.close();
        assert!(!t.join().unwrap());
        assert!(!bp.try_acquire());
    }

    #[test]
    fn concurrency_never_exceeds_capacity() {
        let bp = Arc::new(Backpressure::new(4));
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..16)
            .map(|_| {
                let (bp, live, peak) = (bp.clone(), live.clone(), peak.clone());
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        assert!(bp.acquire());
                        let l = live.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(l, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_micros(50));
                        live.fetch_sub(1, Ordering::SeqCst);
                        bp.release();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 4);
        assert_eq!(bp.in_flight(), 0);
        assert!(bp.balanced());
    }

    #[test]
    fn balanced_tracks_outstanding_credits() {
        let bp = Backpressure::new(2);
        assert!(bp.balanced());
        assert!(bp.acquire());
        assert!(!bp.balanced());
        bp.release();
        assert!(bp.balanced());
    }

    #[test]
    #[should_panic]
    fn release_without_acquire_panics() {
        Backpressure::new(1).release();
    }

    #[test]
    fn acquire_timeout_succeeds_when_credit_free() {
        let bp = Backpressure::new(1);
        assert!(bp.acquire_timeout(std::time::Duration::from_millis(1)));
        assert_eq!(bp.in_flight(), 1);
        bp.release();
    }

    #[test]
    fn acquire_timeout_times_out_on_lost_release() {
        // Simulate a lost release: the only credit is held and never
        // returned. The bounded acquire must give up, not wedge.
        let bp = Backpressure::new(1);
        assert!(bp.acquire());
        let t = std::time::Instant::now();
        assert!(!bp.acquire_timeout(std::time::Duration::from_millis(30)));
        assert!(t.elapsed() >= std::time::Duration::from_millis(30));
        // The gate is unharmed: returning the credit re-admits work.
        bp.release();
        assert!(bp.acquire_timeout(std::time::Duration::from_millis(1)));
        bp.release();
        assert!(bp.balanced());
    }

    #[test]
    fn acquire_timeout_woken_by_release() {
        let bp = Arc::new(Backpressure::new(1));
        assert!(bp.acquire());
        let bp2 = bp.clone();
        let t = std::thread::spawn(move || bp2.acquire_timeout(std::time::Duration::from_secs(10)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        bp.release();
        assert!(t.join().unwrap(), "a release while waiting must hand over the credit");
        bp.release();
    }

    #[test]
    fn acquire_timeout_unblocked_by_close() {
        let bp = Arc::new(Backpressure::new(1));
        assert!(bp.acquire());
        let bp2 = bp.clone();
        let t = std::thread::spawn(move || bp2.acquire_timeout(std::time::Duration::from_secs(10)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        bp.close();
        assert!(!t.join().unwrap(), "close while waiting must return false, not time out");
    }

    #[test]
    fn capacity_and_free_track_the_gate() {
        let bp = Backpressure::new(3);
        assert_eq!(bp.capacity(), 3);
        assert_eq!(bp.free(), 3);
        assert!(bp.acquire());
        assert_eq!(bp.free(), 2);
        assert_eq!(bp.capacity(), 3);
        bp.release();
        assert_eq!(bp.free(), 3);
    }
}

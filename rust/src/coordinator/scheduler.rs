//! Task placement over a cluster's node roles.
//!
//! The coordinator assigns work to the node types of §3: scan/aggregate
//! tasks to lite-compute nodes (or any node with spare cores), storage
//! I/O to storage nodes, accelerator dispatch to accelerator nodes.
//! Placement is load-balanced by outstanding-task count with role
//! affinity, and the scheduler exposes the per-node queue depths the
//! backpressure layer gates on.

use crate::cluster::{ClusterSpec, Role};
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// What a task needs from its node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// CPU scan/aggregate/shuffle work — any node, prefers lite-compute.
    Compute,
    /// Reads/writes attached storage — storage nodes only.
    StorageIo,
    /// Dispatches work to an attached accelerator — accelerator nodes only.
    AccelDispatch,
}

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: usize,
    pub kind: TaskKind,
    /// Estimated work (seconds of node CPU) — used for balance checks.
    pub est_secs: f64,
}

/// Placement decision: task → node index in the cluster spec.
#[derive(Clone, Debug)]
pub struct Placement {
    pub task_id: usize,
    pub node_id: usize,
}

/// Greedy least-loaded scheduler with role affinity.
pub struct Scheduler {
    /// (load_secs, queue_depth) per node.
    load: Vec<(f64, usize)>,
    eligible_compute: Vec<usize>,
    eligible_storage: Vec<usize>,
    eligible_accel: Vec<usize>,
}

impl Scheduler {
    pub fn new(cluster: &ClusterSpec) -> Self {
        let mut eligible_compute = Vec::new();
        let mut eligible_storage = Vec::new();
        let mut eligible_accel = Vec::new();
        for n in &cluster.nodes {
            match n.role {
                Role::LiteCompute => eligible_compute.push(n.id),
                Role::Storage { .. } => {
                    eligible_storage.push(n.id);
                    eligible_compute.push(n.id); // storage nodes can compute too
                }
                Role::Accelerator { .. } => {
                    eligible_accel.push(n.id);
                    eligible_compute.push(n.id);
                }
            }
        }
        Self {
            load: vec![(0.0, 0); cluster.num_nodes()],
            eligible_compute,
            eligible_storage,
            eligible_accel,
        }
    }

    fn candidates(&self, kind: TaskKind) -> &[usize] {
        match kind {
            TaskKind::Compute => &self.eligible_compute,
            TaskKind::StorageIo => &self.eligible_storage,
            TaskKind::AccelDispatch => &self.eligible_accel,
        }
    }

    /// Place one task on the least-loaded eligible node.
    pub fn place(&mut self, task: &Task) -> Option<Placement> {
        let candidates = self.candidates(task.kind);
        let &node = candidates.iter().min_by(|&&a, &&b| {
            self.load[a]
                .0
                .partial_cmp(&self.load[b].0)
                .unwrap()
                .then(self.load[a].1.cmp(&self.load[b].1))
        })?;
        self.load[node].0 += task.est_secs;
        self.load[node].1 += 1;
        Some(Placement { task_id: task.id, node_id: node })
    }

    /// Place a batch; returns None if any task has no eligible node.
    pub fn place_all(&mut self, tasks: &[Task]) -> Option<Vec<Placement>> {
        tasks.iter().map(|t| self.place(t)).collect()
    }

    /// Mark a task complete, releasing its load.
    pub fn complete(&mut self, node_id: usize, est_secs: f64) {
        self.load[node_id].0 = (self.load[node_id].0 - est_secs).max(0.0);
        self.load[node_id].1 = self.load[node_id].1.saturating_sub(1);
    }

    /// The failure path in one step: release a dead (or abandoned)
    /// node's load for the task and place its substitute on the
    /// least-loaded eligible node — the re-placement half of fragment
    /// re-execution. Returns None if no node is eligible.
    pub fn replace(&mut self, node_id: usize, est_secs: f64, task: &Task) -> Option<Placement> {
        self.complete(node_id, est_secs);
        self.place(task)
    }

    pub fn queue_depth(&self, node_id: usize) -> usize {
        self.load[node_id].1
    }

    /// Outstanding estimated seconds on one node.
    pub fn load_secs(&self, node_id: usize) -> f64 {
        self.load[node_id].0
    }

    /// Max/min load ratio across nodes that got any work (balance metric).
    pub fn imbalance(&self) -> f64 {
        let loads: Vec<f64> = self.load.iter().map(|(s, _)| *s).filter(|s| *s > 0.0).collect();
        if loads.is_empty() {
            return 1.0;
        }
        let max = loads.iter().fold(0.0f64, |a, &b| a.max(b));
        let min = loads.iter().fold(f64::INFINITY, |a, &b| a.min(b));
        max / min
    }

    /// Simulated makespan if nodes drain their queues independently.
    pub fn makespan(&self) -> f64 {
        self.load.iter().map(|(s, _)| *s).fold(0.0, f64::max)
    }
}

// ------------------------------------------------ deficit round robin

/// One session's backlog inside a [`DrrQueue`].
struct SessionQ<T> {
    /// FIFO of `(item, cost)` — order within a session is preserved.
    q: VecDeque<(T, f64)>,
    /// Unspent service credit, in cost units (seconds here).
    deficit: f64,
}

/// Deficit-round-robin queue over sessions: the fair-dispatch policy in
/// front of the QueryService's worker fabric. Items carry a cost (the
/// query's estimated seconds); each session is served `quantum` worth of
/// cost per round, with unspent deficit carried over, so a session
/// drip-feeding thousands of queries gets the *same service rate* as one
/// submitting a single query — by cost, not by queue position. FIFO
/// order is preserved within a session.
///
/// The quantum auto-scales to the largest cost ever pushed, so every
/// session can always dispatch its head within one top-up (no starvation
/// and `pop` is O(sessions) worst case), while deficit carry-over keeps
/// the per-round service cost-proportional when items are uneven.
pub struct DrrQueue<T> {
    /// Sessions awaiting a turn (non-empty sessions live here or in
    /// `current`; stale ids are skipped lazily).
    ring: VecDeque<u64>,
    sessions: HashMap<u64, SessionQ<T>>,
    /// The session currently being served (spends its deficit across
    /// consecutive `pop`s before yielding the ring).
    current: Option<u64>,
    quantum: f64,
    len: usize,
}

impl<T> Default for DrrQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DrrQueue<T> {
    pub fn new() -> Self {
        Self {
            ring: VecDeque::new(),
            sessions: HashMap::new(),
            current: None,
            quantum: 1e-9,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue `item` for `session` at the given cost (clamped ≥ 0).
    pub fn push(&mut self, session: u64, item: T, cost: f64) {
        let cost = cost.max(0.0);
        self.quantum = self.quantum.max(cost);
        let s = self
            .sessions
            .entry(session)
            .or_insert_with(|| SessionQ { q: VecDeque::new(), deficit: 0.0 });
        let was_empty = s.q.is_empty();
        s.q.push_back((item, cost));
        self.len += 1;
        if was_empty && self.current != Some(session) && !self.ring.contains(&session) {
            self.ring.push_back(session);
        }
    }

    /// Dequeue the next item under the DRR policy. Returns the owning
    /// session with the item.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        if self.len == 0 {
            return None;
        }
        loop {
            if let Some(cur) = self.current {
                let s = self.sessions.get_mut(&cur).expect("current session exists");
                match s.q.front() {
                    // Tiny epsilon: deficits are sums/differences of the
                    // same costs, so exact comparison is off by rounding.
                    Some(&(_, cost)) if s.deficit + 1e-12 >= cost => {
                        let (item, cost) = s.q.pop_front().expect("front checked");
                        s.deficit -= cost;
                        self.len -= 1;
                        if s.q.is_empty() {
                            // Drained: drop the session's entry outright
                            // (no deficit hoarding across idle gaps, and
                            // a service seeing ever-fresh session keys
                            // must not grow this map without bound).
                            self.sessions.remove(&cur);
                            self.current = None;
                        }
                        return Some((cur, item));
                    }
                    Some(_) => {
                        // Deficit spent: yield the server, keep the rest.
                        self.ring.push_back(cur);
                        self.current = None;
                    }
                    None => {
                        self.sessions.remove(&cur);
                        self.current = None;
                    }
                }
            } else {
                let next = self.ring.pop_front()?;
                // Stale ring ids (session drained by pop/remove) have no
                // map entry anymore — skip them.
                let Some(s) = self.sessions.get_mut(&next) else { continue };
                if s.q.is_empty() {
                    self.sessions.remove(&next);
                    continue;
                }
                // One top-up per turn. quantum ≥ every cost ever pushed,
                // so the head is always dispatchable this turn.
                s.deficit += self.quantum;
                self.current = Some(next);
            }
        }
    }

    /// Remove the first queued item of `session` matching `pred`
    /// (cancel/deadline-expiry of a still-queued query). Returns it, or
    /// `None` if no queued item matches.
    pub fn remove(&mut self, session: u64, mut pred: impl FnMut(&T) -> bool) -> Option<T> {
        let s = self.sessions.get_mut(&session)?;
        let idx = s.q.iter().position(|(t, _)| pred(t))?;
        let (item, _cost) = s.q.remove(idx).expect("position checked");
        self.len -= 1;
        // Drop a drained session's entry (bounded map under session
        // churn) — unless it is the one `pop` is currently serving, whose
        // entry `pop` itself retires on its next call.
        if s.q.is_empty() && self.current != Some(session) {
            self.sessions.remove(&session);
        }
        Some(item)
    }
}

/// Priority-ordered work queue (longest-task-first improves balance).
pub fn ltf_order(tasks: &mut Vec<Task>) {
    let mut heap: BinaryHeap<(u64, usize)> = BinaryHeap::new();
    for (i, t) in tasks.iter().enumerate() {
        heap.push(((t.est_secs * 1e9) as u64, i));
    }
    let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|(_, i)| i)).collect();
    let mut out = Vec::with_capacity(tasks.len());
    for i in order {
        out.push(tasks[i].clone());
    }
    *tasks = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::platform::n2d_milan;

    fn mixed_cluster() -> ClusterSpec {
        let mut c = ClusterSpec::traditional(6, n2d_milan(), Role::LiteCompute);
        c.nodes[0].role = Role::Storage { devices: 4 };
        c.nodes[1].role = Role::Accelerator { count: 2 };
        c
    }

    #[test]
    fn compute_spreads_evenly() {
        let c = mixed_cluster();
        let mut s = Scheduler::new(&c);
        let tasks: Vec<Task> = (0..60)
            .map(|id| Task { id, kind: TaskKind::Compute, est_secs: 1.0 })
            .collect();
        let placements = s.place_all(&tasks).unwrap();
        assert_eq!(placements.len(), 60);
        // 6 eligible compute nodes → 10 tasks each.
        for n in 0..6 {
            assert_eq!(s.queue_depth(n), 10, "node {n}");
        }
        assert!((s.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn storage_tasks_only_on_storage_nodes() {
        let c = mixed_cluster();
        let mut s = Scheduler::new(&c);
        for id in 0..5 {
            let p = s.place(&Task { id, kind: TaskKind::StorageIo, est_secs: 1.0 }).unwrap();
            assert_eq!(p.node_id, 0);
        }
    }

    #[test]
    fn accel_tasks_only_on_accel_nodes() {
        let c = mixed_cluster();
        let mut s = Scheduler::new(&c);
        let p = s.place(&Task { id: 0, kind: TaskKind::AccelDispatch, est_secs: 1.0 }).unwrap();
        assert_eq!(p.node_id, 1);
    }

    #[test]
    fn no_eligible_node_is_none() {
        let c = ClusterSpec::traditional(2, n2d_milan(), Role::LiteCompute);
        let mut s = Scheduler::new(&c);
        assert!(s.place(&Task { id: 0, kind: TaskKind::StorageIo, est_secs: 1.0 }).is_none());
    }

    #[test]
    fn complete_releases_load() {
        let c = mixed_cluster();
        let mut s = Scheduler::new(&c);
        let p = s.place(&Task { id: 0, kind: TaskKind::Compute, est_secs: 2.0 }).unwrap();
        assert_eq!(s.queue_depth(p.node_id), 1);
        s.complete(p.node_id, 2.0);
        assert_eq!(s.queue_depth(p.node_id), 0);
        assert_eq!(s.makespan(), 0.0);
    }

    #[test]
    fn replace_moves_load_to_least_loaded_survivor() {
        let c = ClusterSpec::traditional(3, n2d_milan(), Role::LiteCompute);
        let mut s = Scheduler::new(&c);
        // Load node 0 with the task to be replaced, node 1 heavily.
        let t0 = Task { id: 0, kind: TaskKind::Compute, est_secs: 1.0 };
        let p0 = s.place(&t0).unwrap();
        s.place(&Task { id: 1, kind: TaskKind::Compute, est_secs: 5.0 }).unwrap();
        s.place(&Task { id: 2, kind: TaskKind::Compute, est_secs: 5.0 }).unwrap();
        let before = s.queue_depth(p0.node_id);
        let sub = s.replace(p0.node_id, t0.est_secs, &t0).unwrap();
        // The dead node's load was released...
        assert_eq!(
            s.queue_depth(p0.node_id) + if sub.node_id == p0.node_id { 0 } else { 1 },
            before,
            "replace must release the old placement's queue slot"
        );
        // ...and the substitute landed on the emptiest node.
        for n in 0..3 {
            assert!(
                s.load_secs(sub.node_id) <= s.load_secs(n) + 1e-9,
                "substitute on node {} (load {}) but node {n} has {}",
                sub.node_id,
                s.load_secs(sub.node_id),
                s.load_secs(n)
            );
        }
    }

    #[test]
    fn drr_is_fifo_within_one_session() {
        let mut q = DrrQueue::new();
        for i in 0..5 {
            q.push(7, i, 1.0);
        }
        assert_eq!(q.len(), 5);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn drr_heavy_session_cannot_starve_a_light_one() {
        // Session 1 floods 100 queries before session 2 submits one. A
        // FIFO queue would serve the newcomer 101st; DRR serves it on
        // the second turn.
        let mut q = DrrQueue::new();
        for i in 0..100 {
            q.push(1, ("heavy", i), 1.0);
        }
        q.push(2, ("light", 0), 1.0);
        let mut light_at = None;
        for n in 0..q.len() {
            let (s, _) = q.pop().unwrap();
            if s == 2 {
                light_at = Some(n);
                break;
            }
        }
        assert!(light_at.unwrap() <= 2, "light session served at {light_at:?}");
    }

    #[test]
    fn drr_shares_by_cost_not_queue_position() {
        // A's queries cost 1.0s, B's cost 0.25s: per round A dispatches
        // one and B four, so both receive the same service *rate*.
        let mut q = DrrQueue::new();
        for i in 0..10 {
            q.push(1, ("a", i), 1.0);
        }
        for i in 0..40 {
            q.push(2, ("b", i), 0.25);
        }
        let (mut a_cost, mut b_cost) = (0.0, 0.0);
        for _ in 0..10 {
            match q.pop().unwrap() {
                (1, _) => a_cost += 1.0,
                (2, _) => b_cost += 0.25,
                other => panic!("unknown session {other:?}"),
            }
        }
        assert!(
            (a_cost - b_cost).abs() <= 1.0 + 1e-9,
            "cost share diverged: a={a_cost} b={b_cost}"
        );
    }

    #[test]
    fn drr_remove_unqueues_and_skips_drained_sessions() {
        let mut q = DrrQueue::new();
        q.push(1, 10, 1.0);
        q.push(1, 11, 1.0);
        q.push(2, 20, 1.0);
        assert_eq!(q.remove(1, |&v| v == 10), Some(10));
        assert_eq!(q.remove(1, |&v| v == 99), None);
        assert_eq!(q.len(), 2);
        let mut got: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        got.sort_unstable();
        assert_eq!(got, vec![11, 20]);
        // Drain session 2 entirely via remove: its stale ring entry must
        // not wedge or duplicate later pops.
        q.push(2, 21, 1.0);
        assert_eq!(q.remove(2, |_| true), Some(21));
        assert!(q.pop().is_none());
        q.push(3, 30, 1.0);
        assert_eq!(q.pop(), Some((3, 30)));
    }

    #[test]
    fn drr_drops_drained_session_entries() {
        // A long-lived service sees ever-fresh session keys; the map
        // behind the queue must stay bounded by the *live* sessions, not
        // grow with every key ever seen.
        let mut q = DrrQueue::new();
        for s in 0..10_000u64 {
            q.push(s, s, 1.0);
            assert_eq!(q.pop(), Some((s, s)));
        }
        assert!(q.is_empty());
        assert!(q.sessions.is_empty(), "{} drained sessions retained", q.sessions.len());
        // Draining via remove() drops the entry too.
        q.push(1, 10, 1.0);
        assert_eq!(q.remove(1, |&v| v == 10), Some(10));
        assert!(q.sessions.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn uneven_tasks_balance_with_ltf() {
        let c = ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
        let mut tasks: Vec<Task> = (0..16)
            .map(|id| Task { id, kind: TaskKind::Compute, est_secs: (id % 4 + 1) as f64 })
            .collect();
        ltf_order(&mut tasks);
        assert!(tasks[0].est_secs >= tasks.last().unwrap().est_secs);
        let mut s = Scheduler::new(&c);
        s.place_all(&tasks).unwrap();
        assert!(s.imbalance() < 1.35, "imbalance={}", s.imbalance());
    }
}

//! # Lovelock — a smart-NIC-hosted cluster runtime and simulator
//!
//! Reproduction of *"Lovelock: Towards Smart NIC-hosted Clusters"*
//! (CS.DC 2023). Lovelock replaces every server in a cluster with one or
//! more headless smart NICs; this crate provides:
//!
//! * the **cluster model** ([`cluster`]) and the **Lovelock coordinator**
//!   ([`coordinator`]) — a message-native distributed query service
//!   (leader and workers converse only in typed RPC frames; submit/poll/
//!   wait/cancel sessions), role-aware scheduling, backpressure;
//! * every **substrate** the paper's evaluation rests on: a TPC-H analytics
//!   engine ([`analytics`]) with morsel-driven parallel execution
//!   ([`analytics::morsel`]), a flow-level fabric simulator ([`simnet`]), a
//!   memory-bandwidth contention model ([`memsim`]), a disaggregated storage
//!   layer ([`storage`]), an RPC stack ([`rpc`]), and a distributed-training
//!   coordinator ([`training`]);
//! * the paper's **analytical models**: cost/energy ([`costmodel`]), the
//!   BigQuery projection ([`bigquery`]), the GNN input pipeline ([`gnn`]),
//!   and the platform catalog of Table 1 ([`platform`]);
//! * behind the `xla` feature, a **PJRT runtime** (`runtime`) that loads
//!   AOT-compiled JAX/Pallas artifacts (HLO text under `artifacts/`) and
//!   executes them from the request path with Python never in the loop.
//!   The feature is off by default because the external `xla` crate is not
//!   in the offline registry.
//!
//! Infrastructure substrates written in-repo because the offline registry
//! is empty: [`error`] (error type, in lieu of anyhow), [`exec`] (thread
//! pool / parallel loops, in lieu of tokio/rayon), [`cli`] (argument
//! parsing, in lieu of clap), [`benchkit`] (measurement harness, in lieu
//! of criterion), [`proptest_mini`] (property testing, in lieu of
//! proptest), [`configfmt`] (TOML-subset + JSON, in lieu of serde),
//! [`wirefmt`] (little-endian wire codec primitives shared by the
//! protocol frames and the serializable logical plans).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

// The engine and its row-at-a-time oracles index many parallel column
// slices by one row id; rewriting those loops as iterators over a single
// slice (what this lint wants) would obscure the columnar access pattern.
#![allow(clippy::needless_range_loop)]

pub mod analytics;
pub mod benchkit;
pub mod bigquery;
pub mod cli;
pub mod cluster;
pub mod configfmt;
pub mod coordinator;
pub mod costmodel;
pub mod error;
pub mod exec;
pub mod gnn;
pub mod lint;
pub mod memsim;
pub mod metrics;
pub mod platform;
pub mod prng;
pub mod proptest_mini;
pub mod rpc;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod simnet;
pub mod storage;
pub mod training;
pub mod wirefmt;

pub use error::{Error, Result};

//! `lovelock` — CLI launcher for the Lovelock cluster runtime/simulator.
//!
//! Every paper experiment is reachable from here (the benches print the
//! same tables with measurement loops): `lovelock fig3`, `lovelock cost`,
//! `lovelock train --model tiny --steps 50`, …

use lovelock::analytics::engine::{self, PlanParams};
use lovelock::analytics::morsel::{run_query_morsel, DEFAULT_MORSEL_ROWS};
use lovelock::analytics::{profile, queries, run_query, TpchConfig, TpchDb, QUERY_NAMES};
use lovelock::bigquery::{self, Breakdown};
use lovelock::cli::Command;
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::loadgen::{run_load, LoadMode, LoadSpec};
use lovelock::coordinator::{AdmissionConfig, ChaosConfig, KillPhase, QueryService, ServiceConfig};
use lovelock::costmodel::CostModel;
use lovelock::gnn::{GnnHost, LovelockGnn};
use lovelock::memsim;
use lovelock::platform::{self, table1_platforms};
use lovelock::training::hostmodel::{CheckpointPolicy, GlamModel, TrainSetup};
use std::sync::Arc;

// The --morsel-rows help default below is a string literal; keep it in
// lockstep with the engine's constant.
const _: () = assert!(DEFAULT_MORSEL_ROWS == 16_384);

fn main() {
    let cmd = Command::new("lovelock", "smart-NIC-hosted cluster runtime (paper reproduction)")
        .sub("table1", "platform bandwidth-per-core catalog (Table 1)")
        .sub("fig3", "per-core TPC-H performance under contention (Fig. 3)")
        .sub("fig4", "BigQuery execution-time projection (Fig. 4)")
        .sub("table2", "host CPU/DRAM during LLM training (Table 2)")
        .sub("cost", "cost/energy model scenarios (§4, §5.2, §5.3)")
        .sub("gnn", "GNN input-pipeline stall analysis (§5.3)")
        .sub("tpch", "run TPC-H queries on the local engine")
        .sub("sql", "plan and run an ad-hoc SQL query (serial/morsel/dist)")
        .sub("explain", "show a SQL query's optimized plan, prune intervals, and costs")
        .sub("dist", "run a distributed query on a simulated cluster")
        .sub("load", "drive a QueryService with open/closed-loop overload")
        .sub("train", "real AOT-compiled training loop via PJRT")
        .sub("lint", "zero-dep invariant checker over rust/src (see DESIGN.md §3h)")
        .opt("sf", Some("0.01"), "TPC-H scale factor")
        .opt("seed", Some("42"), "experiment seed")
        .opt("phi", Some("2"), "smart NICs per replaced server")
        .opt("workers", Some("8"), "worker nodes for dist")
        .opt("threads", Some("0"), "local threads for parallel paths (0 = all cores)")
        .opt("morsel-rows", Some("16384"), "rows per morsel for parallel execution")
        .opt("model", Some("tiny"), "model artifact name (tiny|100m)")
        .opt("steps", Some("50"), "training steps")
        .opt("log-every", Some("10"), "loss log interval")
        .opt("query", Some("q1"), "query name for dist")
        .multi("param", "plan parameter key=value (repeatable; needs an explicit query)")
        .opt("concurrency", Some("1"), "simultaneous queries for dist (submit/poll/wait)")
        .opt("chaos-seed", None, "seed a deterministic fault schedule on every dist endpoint")
        .opt("kill-worker", None, "kill worker W at a phase: W, W@mid-map, or W@mid-reduce")
        .opt("duration-ms", Some("1000"), "load submission window in ms")
        .opt("qps", Some("0"), "open-loop arrival rate for load (0 = closed loop)")
        .opt("sessions", Some("1000"), "distinct session keys for load")
        .opt("zipf", Some("1.1"), "Zipf skew of the load query mix (0 = uniform)")
        .opt("deadline-ms", Some("0"), "per-query deadline for load (0 = none)")
        .opt("max-in-flight", Some("0"), "admission gate: max live queries (0 = off)")
        .opt("max-buffered-mb", Some("0"), "admission gate: max leader buffered MB (0 = off)")
        .opt("max-dispatched", Some("0"), "dispatch slots; extra queries queue fairly (0 = all)")
        .flag("lovelock", "use a Lovelock (E2000) cluster for dist")
        .flag("serial", "run tpch single-threaded instead of morsel-driven")
        .flag("dist", "run sql on a simulated cluster instead of locally")
        .flag("no-optimize", "run/show the bound plan without optimizer rewrites")
        .flag("chunked", "use chunked-stream checkpointing")
        .flag("json", "lint: emit diagnostics as a JSON array")
        .flag("fix-none", "lint: report diagnostics but exit 0 (dry run for tooling)");
    let args = match cmd.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("table1") => cmd_table1(),
        Some("fig3") => cmd_fig3(&args),
        Some("fig4") => cmd_fig4(),
        Some("table2") => cmd_table2(&args),
        Some("cost") => cmd_cost(),
        Some("gnn") => cmd_gnn(&args),
        Some("tpch") => cmd_tpch(&args),
        Some("sql") => cmd_sql(&args),
        Some("explain") => cmd_explain(&args),
        Some("dist") => cmd_dist(&args),
        Some("load") => cmd_load(&args),
        Some("train") => cmd_train(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!("{}", cmd.help_text());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Collect `--param key=value` occurrences into a plan parameter bag.
fn plan_params(args: &lovelock::cli::Args) -> lovelock::Result<PlanParams> {
    let mut p = PlanParams::new();
    for kv in args.get_all("param") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| lovelock::err!("--param expects key=value, got {kv:?}"))?;
        p.set(k, v);
    }
    Ok(p)
}

fn cmd_table1() -> lovelock::Result<()> {
    println!(
        "{:<26} {:>6} {:>9} {:>10} {:>12} {:>12}",
        "platform", "vcpus", "nic", "dram", "nic/core", "dram/core"
    );
    for p in table1_platforms() {
        println!(
            "{:<26} {:>6} {:>7.0}G {:>8.1}GB/s {:>10.2}GB/s {:>10.2}GB/s",
            p.name,
            p.vcpus,
            p.nic_gbps,
            p.dram_gbs(),
            p.nic_gbs_per_core(),
            p.dram_gbs_per_core()
        );
    }
    Ok(())
}

fn cmd_fig3(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let sf = args.get_f64("sf", 0.01);
    let seed = args.get_u64("seed", 42);
    let db = TpchDb::generate(TpchConfig::new(sf, seed));
    let plats = [platform::ipu_e2000(), platform::n2d_milan(), platform::skylake_fig3()];
    println!("{:<6} {:>14} {:>14} {:>14}", "query", "E2000 drop", "Milan drop", "Skylake drop");
    for q in QUERY_NAMES {
        let prof = profile::profile_query(&db, q, 1.0).unwrap();
        let w = prof.workload();
        let drops: Vec<f64> = plats
            .iter()
            .map(|p| memsim::full_occupancy(p, &w).slowdown_frac * 100.0)
            .collect();
        println!("{q:<6} {:>13.1}% {:>13.1}% {:>13.1}%", drops[0], drops[1], drops[2]);
    }
    Ok(())
}

fn cmd_fig4() -> lovelock::Result<()> {
    let b = Breakdown::isca23();
    println!("{:<12} {:>8} {:>8} {:>8} {:>8}", "config", "cpu", "shuffle", "io", "total");
    println!(
        "{:<12} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
        "baseline",
        b.cpu,
        b.shuffle,
        b.storage_io,
        b.total()
    );
    for phi in [2.0, 3.0] {
        let p = bigquery::project(&b, phi, 4.7);
        println!(
            "{:<12} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
            format!("lovelock x{phi}"),
            p.cpu,
            p.shuffle,
            p.storage_io,
            p.mu()
        );
    }
    Ok(())
}

fn cmd_table2(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let policy = if args.get_flag("chunked") {
        CheckpointPolicy::ChunkedStream { chunk_bytes: 256 << 20 }
    } else {
        CheckpointPolicy::Monolithic
    };
    let setup = TrainSetup { policy, ..TrainSetup::default() };
    println!(
        "{:<9} {:>9} {:>9} {:>11} {:>11} {:>9} {:>9}",
        "model", "meanCPU%", "peakCPU%", "GB/accel", "GB/host", "meanGB", "maxGB"
    );
    for m in GlamModel::table2_models() {
        let u = setup.host_usage(&m);
        println!(
            "{:<9} {:>8.1}% {:>8.1}% {:>11.1} {:>11.1} {:>9.1} {:>9.1}",
            m.name,
            u.mean_cpu_frac * 100.0,
            u.peak_cpu_frac * 100.0,
            u.state_per_accel / 1e9,
            u.state_per_host / 1e9,
            u.mean_mem / 1e9,
            u.max_mem / 1e9
        );
    }
    Ok(())
}

fn cmd_cost() -> lovelock::Result<()> {
    let bare = CostModel::bare_bluefield();
    let pcie = CostModel::host_only().with_pcie_share(0.75);
    let lite = CostModel::host_only();
    println!("scenario                         cost    energy");
    println!(
        "bare phi=3 mu=1.2            {:>7.2}x {:>8.2}x",
        bare.cost_ratio(3.0),
        bare.power_ratio(3.0, 1.2)
    );
    println!(
        "pcie phi=1 mu=1.0            {:>7.2}x {:>8.2}x",
        pcie.cost_ratio(1.0),
        pcie.power_ratio(1.0, 1.0)
    );
    println!(
        "pcie phi=2 mu=0.9            {:>7.2}x {:>8.2}x",
        pcie.cost_ratio(2.0),
        pcie.power_ratio(2.0, 0.9)
    );
    println!(
        "bigquery phi=2 mu=1.22       {:>7.2}x {:>8.2}x",
        lite.cost_ratio(2.0),
        lite.power_ratio(2.0, 1.22)
    );
    println!(
        "bigquery phi=3 mu=0.81       {:>7.2}x {:>8.2}x",
        lite.cost_ratio(3.0),
        lite.power_ratio(3.0, 0.81)
    );
    println!("fabric-adjusted phi=2        {:>7.2}x", lite.cost_ratio_with_fabric(2.0, 0.7));
    println!("fabric-adjusted phi=3        {:>7.2}x", lite.cost_ratio_with_fabric(3.0, 0.7));
    Ok(())
}

fn cmd_gnn(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let base = GnnHost::bgl_server();
    println!(
        "server: compute {:.0} mb/s, network {:.0} mb/s, achieved {:.0} mb/s, stall {:.0}%",
        base.compute_rate(),
        base.network_rate(),
        base.achieved_rate(),
        base.stall_fraction() * 100.0
    );
    let phi = args.get_u64("phi", 2) as u32;
    let l = LovelockGnn { phi, nic_gbps_each: 200.0, base };
    println!(
        "lovelock phi={phi}: achieved {:.0} mb/s ({:.1}x speedup)",
        l.achieved_rate(),
        l.speedup_vs_server()
    );
    Ok(())
}

fn cmd_tpch(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let sf = args.get_f64("sf", 0.01);
    let seed = args.get_u64("seed", 42);
    let serial = args.get_flag("serial");
    let threads = args.get_usize("threads", 0);
    let morsel_rows = args.get_usize("morsel-rows", DEFAULT_MORSEL_ROWS);
    let db = TpchDb::generate(TpchConfig::new(sf, seed));
    let params = plan_params(args)?;
    // Parameter keys are per-query knobs and unknown keys are rejected
    // per plan — an all-queries sweep would abort on the first query
    // that doesn't read them, so require naming the target query.
    if !params.is_empty() && args.positional.is_empty() {
        return Err(lovelock::err!(
            "--param needs an explicit query (e.g. `tpch q6 --param date-lo=1995-01-01`); \
             each query's keys are documented on its `logical` constructor"
        ));
    }
    let names: Vec<String> = if args.positional.is_empty() {
        QUERY_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for q in names {
        let t = std::time::Instant::now();
        // --param overrides flow through the query's IR constructor; a
        // fresh bag per query keeps used-key tracking per plan.
        let out = if params.is_empty() {
            if serial {
                run_query(&db, &q)
            } else {
                run_query_morsel(&db, &q, threads, morsel_rows)
            }
        } else {
            let plan = queries::build(&q, &params.clone())?;
            Some(if serial {
                engine::try_run_serial(&db, &plan)?
            } else {
                engine::try_run_parallel(&db, &plan, threads, morsel_rows)?
            })
        };
        match out {
            Some(out) => println!(
                "{q}: {} rows in {:.1} ms ({} MB scanned, {})",
                out.rows.len(),
                t.elapsed().as_secs_f64() * 1e3,
                out.stats.bytes_scanned / 1_000_000,
                if serial { "serial".to_string() } else { format!("morsels of {morsel_rows}") }
            ),
            None => println!("{q}: unknown query"),
        }
    }
    Ok(())
}

/// The SQL text of an `sql`/`explain` invocation: the positional
/// arguments joined, so both `sql "SELECT ..."` and unquoted
/// multi-token forms work.
fn sql_text(args: &lovelock::cli::Args) -> lovelock::Result<String> {
    let text = args.positional.join(" ");
    if text.trim().is_empty() {
        return Err(lovelock::err!("expected a SQL query, e.g. sql \"SELECT ... FROM lineitem\""));
    }
    Ok(text)
}

fn fmt_row(row: &[queries::Value]) -> String {
    row.iter()
        .map(|v| match v {
            queries::Value::Int(i) => i.to_string(),
            queries::Value::Float(f) => format!("{f:.4}"),
            queries::Value::Str(s) => s.clone(),
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

fn cmd_sql(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let text = sql_text(args)?;
    let plan = if args.get_flag("no-optimize") {
        lovelock::analytics::sql::plan_sql_unoptimized(&text)?
    } else {
        lovelock::analytics::sql::plan_sql(&text)?
    };
    let sf = args.get_f64("sf", 0.01);
    let seed = args.get_u64("seed", 42);
    let threads = args.get_usize("threads", 0);
    let morsel_rows = args.get_usize("morsel-rows", DEFAULT_MORSEL_ROWS);
    let t = std::time::Instant::now();
    if args.get_flag("dist") {
        let workers = args.get_usize("workers", 8);
        let db = Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)));
        let cluster =
            ClusterSpec::traditional(workers, platform::n2d_milan(), Role::LiteCompute);
        let svc = QueryService::with_config(
            cluster,
            ServiceConfig { workers: 0, threads, morsel_rows, ..ServiceConfig::default() },
        );
        let id = svc.submit_plan(&db, &plan)?;
        let (rows, r) = svc.wait(id)?;
        for row in &rows {
            println!("{}", fmt_row(row));
        }
        println!(
            "{} rows in {:.1} ms host wall (distributed over {workers} workers, {} morsels pruned)",
            rows.len(),
            t.elapsed().as_secs_f64() * 1e3,
            r.morsels_pruned
        );
        return Ok(());
    }
    let db = TpchDb::generate(TpchConfig::new(sf, seed));
    let out = if args.get_flag("serial") {
        engine::try_run_serial(&db, &plan)?
    } else {
        engine::try_run_parallel(&db, &plan, threads, morsel_rows)?
    };
    for row in &out.rows {
        println!("{}", fmt_row(row));
    }
    println!(
        "{} rows in {:.1} ms ({} MB scanned, {} morsels pruned, {})",
        out.rows.len(),
        t.elapsed().as_secs_f64() * 1e3,
        out.stats.bytes_scanned / 1_000_000,
        out.stats.morsels_pruned,
        if args.get_flag("serial") { "serial".to_string() } else { format!("morsels of {morsel_rows}") }
    );
    Ok(())
}

fn cmd_explain(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let text = sql_text(args)?;
    if args.get_flag("no-optimize") {
        let plan = lovelock::analytics::sql::plan_sql_unoptimized(&text)?;
        println!("{}", plan.pretty());
        return Ok(());
    }
    print!("{}", lovelock::analytics::sql::explain_report(&text)?);
    Ok(())
}

fn cmd_dist(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let sf = args.get_f64("sf", 0.01);
    let seed = args.get_u64("seed", 42);
    let workers = args.get_usize("workers", 8);
    let threads = args.get_usize("threads", 0);
    let morsel_rows = args.get_usize("morsel-rows", DEFAULT_MORSEL_ROWS);
    let query = args.get_str("query", "q1");
    let concurrency = args.get_usize("concurrency", 1).max(1);
    // --param overrides ride the encoded plan: every worker compiles
    // the parameterized IR the leader casts, never a registry entry.
    let plan = queries::build(&query, &plan_params(args)?)?;
    let db = Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)));
    let trad = ClusterSpec::traditional(workers, platform::n2d_milan(), Role::LiteCompute);
    let cluster = if args.get_flag("lovelock") {
        ClusterSpec::lovelock_e2000(&trad, args.get_u64("phi", 2) as u32)
    } else {
        trad
    };
    let name = cluster.name.clone();
    // --chaos-seed / --kill-worker wire a deterministic FaultPlan onto
    // every endpoint: the same flags replay the same drops, duplicates,
    // delays, and kill — and the repair rounds that survive them.
    let chaos_seed = args.get_u64("chaos-seed", 0);
    let kill = match args.get_str("kill-worker", "").as_str() {
        "" => None,
        spec => {
            let (w, phase) = match spec.split_once('@') {
                None => (spec, KillPhase::MidMap),
                Some((w, "mid-map")) => (w, KillPhase::MidMap),
                Some((w, "mid-reduce")) => (w, KillPhase::MidReduce),
                Some((_, p)) => {
                    return Err(lovelock::err!(
                        "--kill-worker phase {p:?} (want mid-map or mid-reduce)"
                    ))
                }
            };
            let w: u32 = w
                .parse()
                .map_err(|_| lovelock::err!("--kill-worker expects W or W@phase, got {spec:?}"))?;
            Some((w, phase))
        }
    };
    let chaos = (chaos_seed != 0 || kill.is_some())
        .then_some(ChaosConfig { seed: chaos_seed, kill });
    // workers sizes the traditional cluster; a Lovelock replacement uses
    // all φ·workers NIC nodes. The service hosts one worker endpoint per
    // node; --concurrency queries interleave over them.
    let svc = QueryService::with_config(
        cluster,
        ServiceConfig { workers: 0, threads, morsel_rows, chaos, ..ServiceConfig::default() },
    );
    let t0 = std::time::Instant::now();
    let ids: Vec<_> = (0..concurrency)
        .map(|_| svc.submit_plan(&db, &plan))
        .collect::<lovelock::Result<_>>()?;
    for id in &ids {
        let (_rows, r) = svc.wait(*id)?;
        let (c, s, i) = r.breakdown();
        println!(
            "{id} {query} on {name}: {} rows; sim total {:.3}s = cpu {:.0}% shuffle {:.0}% io {:.0}%; exchanged {} KB, {} KB to leader, {} B control",
            r.rows.len(),
            r.total_secs(),
            c * 100.0,
            s * 100.0,
            i * 100.0,
            r.exchange_bytes / 1000,
            r.shuffle_bytes / 1000,
            r.control_bytes
        );
        if chaos.is_some() {
            println!(
                "  chaos: {} repair round(s), {} endpoint(s) declared dead",
                r.repairs,
                svc.dead_workers()
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if concurrency > 1 {
        println!(
            "{concurrency} concurrent queries in {:.1} ms host wall ({:.1} queries/s)",
            wall * 1e3,
            concurrency as f64 / wall
        );
    }
    Ok(())
}

fn cmd_load(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let sf = args.get_f64("sf", 0.01);
    let seed = args.get_u64("seed", 42);
    let workers = args.get_usize("workers", 8);
    let threads = args.get_usize("threads", 0);
    let qps = args.get_f64("qps", 0.0);
    let concurrency = args.get_usize("concurrency", 1).max(1);
    let deadline_ms = args.get_u64("deadline-ms", 0);
    let db = Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)));
    let trad = ClusterSpec::traditional(workers, platform::n2d_milan(), Role::LiteCompute);
    let cluster = if args.get_flag("lovelock") {
        ClusterSpec::lovelock_e2000(&trad, args.get_u64("phi", 2) as u32)
    } else {
        trad
    };
    let name = cluster.name.clone();
    let svc = QueryService::with_config(
        cluster,
        ServiceConfig {
            workers: 0,
            threads,
            max_dispatched: args.get_usize("max-dispatched", 0),
            admission: AdmissionConfig {
                max_in_flight: args.get_usize("max-in-flight", 0),
                max_buffered_bytes: args.get_u64("max-buffered-mb", 0) << 20,
                min_free_credits: 0,
            },
            ..ServiceConfig::default()
        },
    );
    let spec = LoadSpec {
        mode: if qps > 0.0 {
            LoadMode::Open { qps }
        } else {
            LoadMode::Closed { concurrency }
        },
        duration: std::time::Duration::from_millis(args.get_u64("duration-ms", 1000)),
        sessions: args.get_u64("sessions", 1000),
        zipf_s: args.get_f64("zipf", 1.1),
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
        seed,
        ..LoadSpec::default()
    };
    let mode = match spec.mode {
        LoadMode::Open { qps } => format!("open loop @ {qps:.0}/s"),
        LoadMode::Closed { concurrency } => format!("closed loop x{concurrency}"),
    };
    println!("{mode} on {name} ({workers} workers), {} sessions", spec.sessions);
    let rep = run_load(&svc, &db, &spec)?;
    println!("{}", rep.summary());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_args: &lovelock::cli::Args) -> lovelock::Result<()> {
    Err(lovelock::err!(
        "the train subcommand needs the PJRT runtime; rebuild with `--features xla` \
         (requires vendoring the xla crate — see Cargo.toml)"
    ))
}

#[cfg(feature = "xla")]
fn cmd_train(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    use lovelock::training::driver::TrainDriver;
    let model = args.get_str("model", "tiny");
    let steps = args.get_u64("steps", 50) as u32;
    let log_every = args.get_u64("log-every", 10) as u32;
    let seed = args.get_u64("seed", 42);
    let mut driver = TrainDriver::load(&model, seed)?;
    driver.init(seed as i32)?;
    println!(
        "training {model}: {} params, batch {} x seq {}",
        driver.spec.params, driver.spec.batch, driver.spec.seq
    );
    driver.run(steps, log_every)?;
    for (step, loss) in &driver.loss_log {
        println!("step {step:>5}  loss {loss:.4}");
    }
    let acc = driver.accounting;
    println!(
        "host cpu fraction: {:.1}% (device {:.2}s, host {:.2}s, h2d {} KB, d2h {} KB)",
        acc.host_cpu_frac() * 100.0,
        acc.device_secs,
        acc.host_secs,
        acc.h2d_bytes / 1000,
        acc.d2h_bytes / 1000
    );
    Ok(())
}

/// `lovelock lint [--json] [--fix-none] [paths…]` — run the invariant
/// checker (DESIGN.md §3h). Default scope is the whole `rust/src` tree;
/// exits non-zero on any diagnostic unless `--fix-none`.
fn cmd_lint(args: &lovelock::cli::Args) -> lovelock::Result<()> {
    let paths: Vec<String> = if args.positional.is_empty() {
        vec!["rust/src".to_string()]
    } else {
        args.positional.clone()
    };
    let sources = lovelock::lint::load_paths(&paths)?;
    let diags = lovelock::lint::lint_sources(&sources);
    if args.get_flag("json") {
        println!("{}", lovelock::lint::render_json(&diags));
    } else {
        for d in &diags {
            println!("{d}");
        }
        if diags.is_empty() {
            println!("lint clean: {} files, 0 diagnostics", sources.len());
        }
    }
    if !diags.is_empty() && !args.get_flag("fix-none") {
        lovelock::bail!("lint: {} diagnostic(s)", diags.len());
    }
    Ok(())
}

//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! Provides generator combinators over a deterministic PRNG, automatic
//! counterexample shrinking, and a `check` entry point. Used by
//! `rust/tests/properties.rs` for coordinator invariants (routing
//! conservation, shuffle totals, fairness, cost-model monotonicity).
//!
//! Design: a [`Gen<T>`] draws a value from a PRNG. Shrinking is
//! value-based: each strategy also knows how to propose smaller variants
//! of a failing input, and [`check`] greedily descends until no proposed
//! shrink still fails.

use crate::prng::Pcg64;
use std::fmt::Debug;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Number of random cases per property (override with LOVELOCK_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("LOVELOCK_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// A generation + shrinking strategy for `T`.
pub trait Strategy: Clone {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Propose strictly "smaller" variants of `v` (may be empty).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value>;
}

/// Uniform integer range `[lo, hi]`, shrinking toward `lo`.
#[derive(Clone)]
pub struct IntRange {
    pub lo: i64,
    pub hi: i64,
}

pub fn int_range(lo: i64, hi: i64) -> IntRange {
    assert!(lo <= hi);
    IntRange { lo, hi }
}

impl Strategy for IntRange {
    type Value = i64;
    fn generate(&self, rng: &mut Pcg64) -> i64 {
        rng.gen_range_i64(self.lo, self.hi)
    }
    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if *v != self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != *v {
                out.push(mid);
            }
            if *v - 1 >= self.lo {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// Uniform float range `[lo, hi)`, shrinking toward `lo` and simple values.
#[derive(Clone)]
pub struct FloatRange {
    pub lo: f64,
    pub hi: f64,
}

pub fn float_range(lo: f64, hi: f64) -> FloatRange {
    assert!(lo < hi);
    FloatRange { lo, hi }
}

impl Strategy for FloatRange {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.gen_range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        for cand in [self.lo, 0.0, 1.0, (self.lo + *v) / 2.0] {
            if cand >= self.lo && cand < self.hi && cand != *v && (cand - *v).abs() > 1e-12 {
                out.push(cand);
            }
        }
        out
    }
}

/// Vector of values from an element strategy, shrinking by halving length
/// then shrinking elements.
#[derive(Clone)]
pub struct VecOf<S: Strategy> {
    pub elem: S,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn vec_of<S: Strategy>(elem: S, min_len: usize, max_len: usize) -> VecOf<S> {
    assert!(min_len <= max_len);
    VecOf { elem, min_len, max_len }
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<S::Value> {
        let span = (self.max_len - self.min_len + 1) as u64;
        let len = self.min_len + rng.gen_range_u64(span) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        // Halve the vector (front half, back half).
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            out.push(v[v.len() - half..].to_vec());
            // Drop one element.
            if v.len() - 1 >= self.min_len {
                let mut w = v.clone();
                w.pop();
                out.push(w);
            }
        }
        // Shrink the first shrinkable element.
        for (i, elem) in v.iter().enumerate().take(8) {
            for smaller in self.elem.shrink(elem).into_iter().take(2) {
                let mut w = v.clone();
                w[i] = smaller;
                out.push(w);
            }
        }
        out
    }
}

/// Pair of two strategies.
#[derive(Clone)]
pub struct PairOf<A: Strategy, B: Strategy> {
    pub a: A,
    pub b: B,
}

pub fn pair_of<A: Strategy, B: Strategy>(a: A, b: B) -> PairOf<A, B> {
    PairOf { a, b }
}

impl<A: Strategy, B: Strategy> Strategy for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .a
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.b.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Result of a property check.
#[derive(Debug)]
pub enum PropResult<T: Debug> {
    Ok { cases: usize },
    Failed { original: T, shrunk: T, message: String },
}

/// Run `prop` over `cases` random inputs from `strategy`; on failure,
/// shrink greedily and return the minimal counterexample found.
pub fn check_with_seed<S, F>(seed: u64, cases: usize, strategy: &S, prop: F) -> PropResult<S::Value>
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let mut rng = Pcg64::seed_from_u64(seed);
    for _ in 0..cases {
        let input = strategy.generate(&mut rng);
        if let Err(msg) = run_case(&prop, &input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in strategy.shrink(&best) {
                    if let Err(m) = run_case(&prop, &cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            return PropResult::Failed { original: input, shrunk: best, message: best_msg };
        }
    }
    PropResult::Ok { cases }
}

fn run_case<T: Clone + Debug, F>(prop: &F, input: &T) -> Result<(), String>
where
    F: Fn(&T) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let input2 = input.clone();
    match catch_unwind(AssertUnwindSafe(|| prop(&input2))) {
        Ok(r) => r,
        Err(p) => {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            Err(format!("panicked: {msg}"))
        }
    }
}

/// Assert-style wrapper: panics with the shrunk counterexample on failure.
pub fn check<S, F>(name: &str, strategy: &S, prop: F)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let seed = 0xC0FFEE ^ fnv(name);
    match check_with_seed(seed, default_cases(), strategy, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { original, shrunk, message } => {
            panic!(
                "property {name} failed: {message}\n  original: {original:?}\n  shrunk:   {shrunk:?}"
            );
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = check_with_seed(1, 64, &int_range(0, 100), |v| {
            if *v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert!(matches!(r, PropResult::Ok { cases: 64 }));
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        // Fails for v >= 50; minimal counterexample is 50.
        let r = check_with_seed(2, 256, &int_range(0, 1000), |v| {
            if *v < 50 {
                Ok(())
            } else {
                Err(format!("{v} too big"))
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => assert_eq!(shrunk, 50),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn vec_shrinks_toward_small() {
        // Fails when the vec contains any element >= 10.
        let strat = vec_of(int_range(0, 100), 0, 50);
        let r = check_with_seed(3, 256, &strat, |v| {
            if v.iter().all(|x| *x < 10) {
                Ok(())
            } else {
                Err("has big elem".into())
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => {
                assert!(shrunk.len() <= 2, "shrunk too big: {shrunk:?}");
                assert!(shrunk.iter().any(|x| *x >= 10));
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn panic_is_caught_as_failure() {
        let r = check_with_seed(4, 64, &int_range(0, 10), |v| {
            if *v > 8 {
                panic!("boom at {v}");
            }
            Ok(())
        });
        assert!(matches!(r, PropResult::Failed { .. }));
    }

    #[test]
    fn pair_strategy_generates_and_shrinks() {
        let strat = pair_of(int_range(0, 100), float_range(0.0, 1.0));
        let mut rng = Pcg64::seed_from_u64(5);
        let v = strat.generate(&mut rng);
        assert!((0..=100).contains(&v.0));
        assert!((0.0..1.0).contains(&v.1));
        let r = check_with_seed(6, 128, &strat, |(a, _b)| {
            if *a < 90 {
                Ok(())
            } else {
                Err("a big".into())
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => assert_eq!(shrunk.0, 90),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let f = |v: &i64| if *v < 5000 { Ok(()) } else { Err("x".into()) };
        let a = check_with_seed(7, 64, &int_range(0, 10_000), f);
        let b = check_with_seed(7, 64, &int_range(0, 10_000), f);
        match (a, b) {
            (PropResult::Failed { original: o1, .. }, PropResult::Failed { original: o2, .. }) => {
                assert_eq!(o1, o2)
            }
            (PropResult::Ok { .. }, PropResult::Ok { .. }) => {}
            _ => panic!("nondeterministic"),
        }
    }
}

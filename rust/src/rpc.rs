//! RPC substrate: message framing, an in-process transport, and the
//! eRPC-style per-core throughput model of §6.
//!
//! Two halves:
//!
//! 1. **A real transport** ([`Endpoint`]) — length-prefixed messages over
//!    in-process channels with a server dispatch loop. The coordinator's
//!    leader/worker protocol (see [`crate::coordinator::protocol`]) runs
//!    on it, and `bench rpc` measures its per-core message rate and
//!    large-message goodput (the §6 experiment: "a single ARM core can
//!    sustain over 25 Gbps with large message RPCs"; eRPC's 10 M small
//!    RPCs/s/core and ~75 Gbps large-message numbers are the calibration
//!    points). Clients speak two verbs: [`Client::call`] (synchronous
//!    request/response) and [`Client::cast`] (one-way fire-and-forget —
//!    what the query protocol's state machines use so that two busy
//!    endpoints can never deadlock waiting on each other's replies).
//! 2. **An analytic model** ([`RpcModel`]) mapping per-message CPU cost and
//!    per-byte cost to achievable Gbps per core on a given platform —
//!    used to scale measured x86 numbers to smart-NIC ARM cores.
//!
//! Failures carry the crate-wide [`crate::error::Error`] (frame framing
//! errors, closed endpoints, handler errors), never bare strings.

use crate::error::Result;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Method id reserved for error responses.
pub const METHOD_ERR: u32 = u32::MAX;

/// Wire format: 16-byte header (method, len, id) + payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub method: u32,
    pub id: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(16 + self.payload.len());
        buf.extend_from_slice(&self.method.to_le_bytes());
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        crate::ensure!(buf.len() >= 16, "short frame: {} bytes", buf.len());
        let method = u32::from_le_bytes(buf[0..4].try_into()?);
        let len = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let id = u64::from_le_bytes(buf[8..16].try_into()?);
        crate::ensure!(
            buf.len() == 16 + len,
            "bad frame length: header says {len}, have {}",
            buf.len() - 16
        );
        Ok(Self { method, id, payload: buf[16..].to_vec() })
    }
}

/// Handler: method → response payload (or a protocol error, which the
/// server encodes as a [`METHOD_ERR`] frame for `call`ers and drops for
/// `cast`s — one-way senders must report failures with their own frames).
pub type Handler = Arc<dyn Fn(&Message) -> Result<Vec<u8>> + Send + Sync>;

/// Builder for an endpoint's method table — the typed-dispatch face of
/// [`Endpoint::serve`].
///
/// ```
/// use lovelock::rpc::Dispatch;
/// let ep = Dispatch::new()
///     .on(1, |m| Ok(m.payload.to_vec()))
///     .serve();
/// assert_eq!(ep.client().call(1, vec![9]).unwrap(), vec![9]);
/// ```
#[derive(Default)]
pub struct Dispatch {
    handlers: HashMap<u32, Handler>,
}

impl Dispatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the handler for `method` (last registration wins).
    pub fn on<F>(mut self, method: u32, f: F) -> Self
    where
        F: Fn(&Message) -> Result<Vec<u8>> + Send + Sync + 'static,
    {
        self.handlers.insert(method, Arc::new(f));
        self
    }

    /// Spawn the endpoint serving this method table.
    pub fn serve(self) -> Endpoint {
        Endpoint::serve(self.handlers)
    }
}

/// One queued request: an encoded frame with an optional reply channel
/// (`None` marks a one-way `cast`), or the shutdown sentinel that
/// `Endpoint`'s `Drop` enqueues. The sentinel is what lets an endpoint
/// shut down even while other endpoints' handler state still holds
/// `Client` senders to it — without it, a mesh of endpoints whose
/// handlers hold clients to each other (the coordinator's topology)
/// could never disconnect and every drop would deadlock on the join.
enum Request {
    Frame(Vec<u8>, Option<Sender<Vec<u8>>>),
    Shutdown,
}

/// A served endpoint: spawn with handlers, then create [`Client`]s.
pub struct Endpoint {
    tx: Sender<Request>,
    server: Option<std::thread::JoinHandle<()>>,
}

impl Endpoint {
    /// Start a single-threaded server (one dispatch core — deliberately,
    /// to measure per-core capacity like the paper's experiment).
    pub fn serve(handlers: HashMap<u32, Handler>) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let server = std::thread::Builder::new()
            .name("rpc-server".into())
            .spawn(move || {
                // Exits on the shutdown sentinel or full disconnect,
                // after draining everything queued before it.
                while let Ok(Request::Frame(frame, reply_tx)) = rx.recv() {
                    let resp = match Message::decode(&frame) {
                        Ok(msg) => match handlers.get(&msg.method) {
                            Some(h) => match h(&msg) {
                                Ok(payload) => {
                                    Message { method: msg.method, id: msg.id, payload }.encode()
                                }
                                Err(e) => Message {
                                    method: METHOD_ERR,
                                    id: msg.id,
                                    payload: e.to_string().into_bytes(),
                                }
                                .encode(),
                            },
                            None => {
                                let payload = b"no such method".to_vec();
                                Message { method: METHOD_ERR, id: msg.id, payload }.encode()
                            }
                        },
                        Err(e) => Message {
                            method: METHOD_ERR,
                            id: 0,
                            payload: e.to_string().into_bytes(),
                        }
                        .encode(),
                    };
                    if let Some(reply_tx) = reply_tx {
                        let _ = reply_tx.send(resp);
                    }
                }
            })
            .expect("spawn rpc server");
        Self { tx, server: Some(server) }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone(), next_id: Arc::new(Mutex::new(0)) }
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        // Enqueue the shutdown sentinel, then join: the server drains
        // every frame queued before the sentinel and exits — even if
        // outstanding `Client` clones (possibly held by other endpoints'
        // handlers, possibly by this endpoint's own) never drop. Their
        // later sends fail with "endpoint closed".
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.server.take() {
            let _ = h.join();
        }
    }
}

/// Client handle (cheaply cloneable).
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    next_id: Arc<Mutex<u64>>,
}

impl Client {
    fn fresh_id(&self) -> u64 {
        let mut g = self.next_id.lock().unwrap();
        *g += 1;
        *g
    }

    /// Synchronous call; returns the response payload.
    pub fn call(&self, method: u32, payload: Vec<u8>) -> Result<Vec<u8>> {
        let id = self.fresh_id();
        let frame = Message { method, id, payload }.encode();
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Frame(frame, Some(rtx)))
            .map_err(|_| crate::err!("endpoint closed"))?;
        let resp = rrx.recv().map_err(|_| crate::err!("endpoint closed"))?;
        let msg = Message::decode(&resp)?;
        if msg.method == METHOD_ERR {
            crate::bail!("{}", String::from_utf8_lossy(&msg.payload));
        }
        crate::ensure!(msg.id == id, "response id mismatch: {} vs {}", msg.id, id);
        Ok(msg.payload)
    }

    /// One-way send: enqueue the frame and return immediately with the
    /// number of bytes that crossed the wire. The handler's return value
    /// is discarded; delivery is in-order per endpoint. This is the verb
    /// the coordinator's protocol state machines use — a handler may
    /// `cast` to a peer that is itself mid-handler without deadlock.
    pub fn cast(&self, method: u32, payload: Vec<u8>) -> Result<usize> {
        let id = self.fresh_id();
        let frame = Message { method, id, payload }.encode();
        let bytes = frame.len();
        self.tx
            .send(Request::Frame(frame, None))
            .map_err(|_| crate::err!("endpoint closed"))?;
        Ok(bytes)
    }
}

// ------------------------------------------------------------- perf model

/// Analytic per-core RPC throughput model (eRPC-style).
///
/// A core spends `per_msg_us` microseconds of fixed work per RPC plus
/// `per_byte_ns` nanoseconds per payload byte (copy + checksum at the
/// modeled stack efficiency). Throughput at message size `s` is
/// `1 / (per_msg + per_byte·s)` messages/s.
#[derive(Clone, Copy, Debug)]
pub struct RpcModel {
    pub per_msg_us: f64,
    pub per_byte_ns: f64,
    /// Core speed relative to the x86 core the constants were calibrated
    /// on (ARM N1 ≈ 0.77 of the calibration core in the paper's setting).
    pub core_speed: f64,
}

impl RpcModel {
    /// eRPC's published numbers on x86: ~10 M small RPCs/s/core
    /// (per_msg = 0.1 µs) and ~75 Gbps large-message goodput
    /// (per_byte ≈ 0.1067 ns/B).
    pub fn erpc_x86() -> Self {
        Self { per_msg_us: 0.1, per_byte_ns: 0.1067, core_speed: 1.0 }
    }

    /// The same stack on one IPU E2000 ARM N1 core. Calibrated against the
    /// paper's measurement: "a single ARM core can sustain over 25 Gbps
    /// with large message RPCs" — i.e. ≈ 1/3 of the x86 large-message
    /// goodput (ARM core is slower and LPDDR copies are costlier).
    pub fn e2000_arm() -> Self {
        Self { per_msg_us: 0.22, per_byte_ns: 0.30, core_speed: 0.77 }
    }

    /// Messages per second at payload size `bytes`, one core.
    pub fn msgs_per_sec(&self, bytes: f64) -> f64 {
        let us = self.per_msg_us + self.per_byte_ns * bytes / 1000.0;
        1e6 / us
    }

    /// Goodput in Gbit/s at payload size `bytes`, one core.
    pub fn gbps(&self, bytes: f64) -> f64 {
        self.msgs_per_sec(bytes) * bytes * 8.0 / 1e9
    }

    /// Asymptotic large-message goodput, Gbit/s.
    pub fn peak_gbps(&self) -> f64 {
        8.0 / self.per_byte_ns
    }

    /// Cores needed to sustain `gbps` at message size `bytes`.
    pub fn cores_for(&self, gbps: f64, bytes: f64) -> f64 {
        gbps / self.gbps(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn message_roundtrip() {
        let m = Message { method: 7, id: 99, payload: vec![1, 2, 3, 4, 5] };
        let buf = m.encode();
        assert_eq!(Message::decode(&buf).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[1, 2, 3]).is_err());
        let mut buf = Message { method: 1, id: 1, payload: vec![0; 8] }.encode();
        buf.pop(); // truncate
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn endpoint_dispatches() {
        let ep = Dispatch::new()
            .on(1, |m: &Message| {
                let mut v = m.payload.clone();
                v.reverse();
                Ok(v)
            })
            .on(2, |_m: &Message| Ok(b"pong".to_vec()))
            .serve();
        let c = ep.client();
        assert_eq!(c.call(1, vec![1, 2, 3]).unwrap(), vec![3, 2, 1]);
        assert_eq!(c.call(2, vec![]).unwrap(), b"pong".to_vec());
    }

    #[test]
    fn unknown_method_errors() {
        let ep = Endpoint::serve(HashMap::new());
        let c = ep.client();
        let err = c.call(42, vec![]).unwrap_err();
        assert!(err.to_string().contains("no such method"));
    }

    #[test]
    fn handler_error_reaches_caller_as_error() {
        let ep = Dispatch::new()
            .on(3, |_m: &Message| Err(crate::err!("handler exploded")))
            .serve();
        let err = ep.client().call(3, vec![]).unwrap_err();
        assert!(err.to_string().contains("handler exploded"), "{err}");
    }

    #[test]
    fn cast_is_one_way_and_ordered() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let ep = Dispatch::new()
            .on(1, move |m: &Message| {
                log2.lock().unwrap().push(m.payload[0]);
                Ok(vec![])
            })
            .serve();
        let c = ep.client();
        for i in 0..10u8 {
            let bytes = c.cast(1, vec![i]).unwrap();
            assert_eq!(bytes, 17, "16B header + 1B payload");
        }
        // A closing call flushes the queue (the server is in-order).
        c.call(1, vec![99]).unwrap();
        let seen = log.lock().unwrap().clone();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 99]);
    }

    #[test]
    fn cast_errors_are_dropped_not_fatal() {
        let hits = Arc::new(AtomicUsize::new(0));
        let hits2 = hits.clone();
        let ep = Dispatch::new()
            .on(1, move |_m: &Message| {
                hits2.fetch_add(1, Ordering::SeqCst);
                Err(crate::err!("boom"))
            })
            .on(2, |_m| Ok(vec![]))
            .serve();
        let c = ep.client();
        c.cast(1, vec![]).unwrap(); // handler errors, nothing to report to
        c.call(2, vec![]).unwrap(); // endpoint still serves
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn concurrent_clients() {
        let ep = Dispatch::new().on(1, |m: &Message| Ok(m.payload.clone())).serve();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let c = ep.client();
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        let payload = vec![(t * 100 + i) as u8; 16];
                        assert_eq!(c.call(1, payload.clone()).unwrap(), payload);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    /// eRPC calibration: ~10M msgs/s at tiny payloads, ~75 Gbps at 1 MB.
    #[test]
    fn erpc_calibration_points() {
        let m = RpcModel::erpc_x86();
        assert!(close(m.msgs_per_sec(0.0) / 1e6, 10.0, 0.01));
        assert!(m.gbps(1e6) > 70.0 && m.gbps(1e6) < 76.0, "gbps={}", m.gbps(1e6));
    }

    /// §6: one E2000 ARM core sustains > 25 Gbps with large messages.
    #[test]
    fn e2000_arm_exceeds_25gbps_large() {
        let m = RpcModel::e2000_arm();
        assert!(m.gbps(1e6) > 25.0, "gbps={}", m.gbps(1e6));
        assert!(m.peak_gbps() > 25.0);
        // But it should be well below the x86 core (slower core).
        assert!(m.gbps(1e6) < RpcModel::erpc_x86().gbps(1e6));
    }

    #[test]
    fn throughput_monotone_in_size() {
        let m = RpcModel::e2000_arm();
        let mut last = 0.0;
        for s in [64.0, 1024.0, 65536.0, 1e6] {
            let g = m.gbps(s);
            assert!(g > last);
            last = g;
        }
    }

    #[test]
    fn cores_for_line_rate() {
        // How many ARM cores to drive a 200 Gbps NIC with 1 MB messages?
        let m = RpcModel::e2000_arm();
        let n = m.cores_for(200.0, 1e6);
        assert!(n > 6.0 && n < 9.0, "cores={n}");
    }
}

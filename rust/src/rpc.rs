//! RPC substrate: message framing, an in-process transport, and the
//! eRPC-style per-core throughput model of §6.
//!
//! Two halves:
//!
//! 1. **A real transport** ([`Endpoint`]) — length-prefixed messages over
//!    in-process channels with a server dispatch loop. The coordinator's
//!    leader/worker protocol (see [`crate::coordinator::protocol`]) runs
//!    on it, and `bench rpc` measures its per-core message rate and
//!    large-message goodput (the §6 experiment: "a single ARM core can
//!    sustain over 25 Gbps with large message RPCs"; eRPC's 10 M small
//!    RPCs/s/core and ~75 Gbps large-message numbers are the calibration
//!    points). Clients speak two verbs: [`Client::call`] (synchronous
//!    request/response) and [`Client::cast`] (one-way fire-and-forget —
//!    what the query protocol's state machines use so that two busy
//!    endpoints can never deadlock waiting on each other's replies).
//! 2. **An analytic model** ([`RpcModel`]) mapping per-message CPU cost and
//!    per-byte cost to achievable Gbps per core on a given platform —
//!    used to scale measured x86 numbers to smart-NIC ARM cores.
//!
//! Failures carry the crate-wide [`crate::error::Error`] (frame framing
//! errors, closed endpoints, handler errors), never bare strings.

use crate::error::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Method id reserved for error responses.
pub const METHOD_ERR: u32 = u32::MAX;

/// Retained buffers per [`BufPool`]: enough for every in-flight frame of
/// a busy endpoint plus slack; beyond it, returned buffers are dropped so
/// a burst cannot pin memory forever.
const POOL_MAX_BUFS: usize = 64;

/// Bounded free-list of wire buffers, shared by an [`Endpoint`]'s server
/// loop and every [`Client`] cloned from it. Frames are encoded into
/// recycled buffers on send and returned to the pool after dispatch, so
/// a steady message stream (the query service's map/exchange/reduce
/// loop) allocates no frame memory after the first few round trips.
/// Buffers keep their capacity across cycles; the pool converges on
/// buffers sized to the endpoint's largest frames.
#[derive(Default)]
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BufPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty buffer with at least `cap` capacity — recycled when the
    /// free list has one, freshly allocated otherwise.
    pub fn get(&self, cap: usize) -> Vec<u8> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                b.reserve(cap);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // lint: allow(hot-path-alloc) pool miss — cold start or burst beyond pool depth; steady state recycles
                Vec::with_capacity(cap)
            }
        }
    }

    /// Return a buffer for reuse (dropped if the pool is full or the
    /// buffer never allocated).
    pub fn put(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        let mut g = self.free.lock().unwrap();
        if g.len() < POOL_MAX_BUFS {
            b.clear();
            g.push(b);
        }
    }

    /// Buffers served from the free list (steady-state sends).
    pub fn recycled(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Buffers that had to be freshly allocated (cold starts, bursts).
    pub fn allocated(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Wire format: 16-byte header (method, len, id) + payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub method: u32,
    pub id: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(16 + self.payload.len());
        self.encode_into(&mut buf);
        buf
    }

    /// Append the wire encoding to `buf` (the pooled-buffer path).
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        buf.reserve(16 + self.payload.len());
        buf.extend_from_slice(&self.method.to_le_bytes());
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.payload);
    }

    /// Parse and validate the 16-byte header; returns (method, id,
    /// payload length).
    fn decode_header(buf: &[u8]) -> Result<(u32, u64, usize)> {
        crate::ensure!(buf.len() >= 16, "short frame: {} bytes", buf.len());
        let method = u32::from_le_bytes(buf[0..4].try_into()?);
        let len = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let id = u64::from_le_bytes(buf[8..16].try_into()?);
        crate::ensure!(
            buf.len() == 16 + len,
            "bad frame length: header says {len}, have {}",
            buf.len() - 16
        );
        Ok((method, id, len))
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let (method, id, _) = Self::decode_header(buf)?;
        Ok(Self { method, id, payload: buf[16..].to_vec() })
    }

    /// [`Message::decode`] with the payload copied into a pooled buffer
    /// — the server loop recycles it after dispatch.
    fn decode_pooled(buf: &[u8], pool: &BufPool) -> Result<Self> {
        let (method, id, len) = Self::decode_header(buf)?;
        let mut payload = pool.get(len);
        payload.extend_from_slice(&buf[16..]);
        Ok(Self { method, id, payload })
    }
}

/// Handler: method → response payload (or a protocol error, which the
/// server encodes as a [`METHOD_ERR`] frame for `call`ers and drops for
/// `cast`s — one-way senders must report failures with their own frames).
pub type Handler = Arc<dyn Fn(&Message) -> Result<Vec<u8>> + Send + Sync>;

/// Builder for an endpoint's method table — the typed-dispatch face of
/// [`Endpoint::serve`].
///
/// ```
/// use lovelock::rpc::Dispatch;
/// let ep = Dispatch::new()
///     .on(1, |m| Ok(m.payload.to_vec()))
///     .serve();
/// assert_eq!(ep.client().call(1, vec![9]).unwrap(), vec![9]);
/// ```
#[derive(Default)]
pub struct Dispatch {
    handlers: HashMap<u32, Handler>,
}

impl Dispatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the handler for `method` (last registration wins).
    pub fn on<F>(mut self, method: u32, f: F) -> Self
    where
        F: Fn(&Message) -> Result<Vec<u8>> + Send + Sync + 'static,
    {
        self.handlers.insert(method, Arc::new(f));
        self
    }

    /// Spawn the endpoint serving this method table.
    pub fn serve(self) -> Endpoint {
        Endpoint::serve(self.handlers)
    }

    /// Spawn the endpoint with a deterministic [`FaultPlan`] applied to
    /// every arriving frame (chaos testing).
    pub fn serve_with_faults(self, plan: FaultPlan) -> Endpoint {
        Endpoint::serve_with_faults(self.handlers, plan)
    }
}

// ---------------------------------------------------------- fault layer

/// What to do to the Nth frame of a given method arriving at an endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Discard the frame silently (a lost packet). `call`ers observe a
    /// closed reply channel; `cast`s simply vanish.
    Drop,
    /// Deliver the frame twice (a retransmitted packet). The duplicate is
    /// dispatched as a one-way frame so a `call` still gets one reply.
    Duplicate,
    /// Hold the frame until `k` more frames have arrived (reordering).
    /// Heartbeat traffic keeps the arrival sequence advancing, so a
    /// delayed frame is never starved forever on a live fabric.
    Delay(u64),
}

/// Kill trigger: the endpoint dies immediately *before* dispatching the
/// `nth` (1-based) frame of `method` — or the `nth` frame of any method
/// when `method` is `None`. After death the serve loop keeps draining its
/// queue but drops every frame: casts to a dead endpoint still "succeed"
/// at the sender (the bytes left), exactly like a dead NIC, so failure
/// detection must be lease-based rather than send-error-based.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub method: Option<u32>,
    pub nth: u64,
}

/// A deterministic schedule of faults for one endpoint, keyed by
/// `(method, per-method ordinal)`. Ordinals count frames of the *same*
/// method, not global arrivals, so background traffic (heartbeats) that
/// interleaves nondeterministically with the query protocol cannot change
/// which protocol frame a fault lands on — the same seed always faults
/// the same frame, which is what makes chaos runs replayable.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    actions: HashMap<(u32, u64), FaultAction>,
    kill: Option<KillSpec>,
}

impl FaultPlan {
    /// No faults: `serve_with_faults` with this plan behaves like `serve`.
    pub fn none() -> Self {
        Self::default()
    }

    /// Kill the endpoint just before the `nth` (1-based) frame of `method`.
    pub fn kill_on(method: u32, nth: u64) -> Self {
        Self { actions: HashMap::new(), kill: Some(KillSpec { method: Some(method), nth }) }
    }

    /// Add one action for the `nth` (1-based) frame of `method`.
    pub fn with_action(mut self, method: u32, nth: u64, action: FaultAction) -> Self {
        self.actions.insert((method, nth), action);
        self
    }

    /// Set (or clear) the kill trigger.
    pub fn with_kill(mut self, kill: Option<KillSpec>) -> Self {
        self.kill = kill;
        self
    }

    /// A random drop/duplicate/delay schedule over `methods`, fully
    /// determined by `seed`. Each method's first [`Self::SEED_HORIZON`]
    /// frames independently draw a fault with small probability, so the
    /// schedule is finite and every run with the same seed is identical.
    /// No kill is scheduled here — kills are an explicit, separately
    /// targeted decision (see [`FaultPlan::kill_on`]).
    pub fn from_seed(seed: u64, methods: &[u32]) -> Self {
        let mut rng = crate::prng::Pcg64::seed_from_u64(seed);
        let mut actions = HashMap::new();
        for &m in methods {
            for nth in 1..=Self::SEED_HORIZON {
                if rng.gen_bool(0.06) {
                    let action = match rng.gen_range_u64(5) {
                        0 | 1 => FaultAction::Drop,
                        2 | 3 => FaultAction::Duplicate,
                        _ => FaultAction::Delay(1 + rng.gen_range_u64(3)),
                    };
                    actions.insert((m, nth), action);
                }
            }
        }
        Self { actions, kill: None }
    }

    /// Per-method ordinal horizon considered by [`FaultPlan::from_seed`].
    pub const SEED_HORIZON: u64 = 24;

    /// True when the plan injects nothing (the zero-overhead fast path).
    pub fn is_empty(&self) -> bool {
        self.actions.is_empty() && self.kill.is_none()
    }

    /// The scheduled actions, sorted by (method, ordinal) — for tests and
    /// for printing a replayable chaos schedule.
    pub fn schedule(&self) -> Vec<(u32, u64, FaultAction)> {
        let mut v: Vec<_> =
            self.actions.iter().map(|(&(m, n), &a)| (m, n, a)).collect();
        v.sort_unstable_by_key(|&(m, n, _)| (m, n));
        v
    }
}

/// Serve-loop side of [`FaultPlan`]: per-method counters, the delayed
/// frame buffer, and the dead flag.
struct FaultState {
    plan: FaultPlan,
    live: bool, // plan has anything to do (fast-path gate)
    seq: u64,
    per_method: HashMap<u32, u64>,
    delayed: Vec<(u64, Vec<u8>, Option<Sender<Vec<u8>>>)>,
    dead: bool,
}

impl FaultState {
    fn new(plan: FaultPlan) -> Self {
        let live = !plan.is_empty();
        Self { plan, live, seq: 0, per_method: HashMap::new(), delayed: Vec::new(), dead: false }
    }

    /// Run one arriving frame through the plan. Returns the frames to
    /// dispatch now, in order (delayed frames whose release point was
    /// reached come before the new arrival).
    #[allow(clippy::type_complexity)]
    fn admit(
        &mut self,
        frame: Vec<u8>,
        reply: Option<Sender<Vec<u8>>>,
        pool: &BufPool,
    ) -> Vec<(Vec<u8>, Option<Sender<Vec<u8>>>)> {
        if !self.live && self.delayed.is_empty() && !self.dead {
            return vec![(frame, reply)];
        }
        self.seq += 1;
        let mut ready = Vec::new();
        // Release delayed frames that have waited long enough; they
        // arrived earlier, so they dispatch before the new arrival.
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= self.seq {
                let (_, f, r) = self.delayed.remove(i);
                ready.push((f, r));
            } else {
                i += 1;
            }
        }
        if self.dead {
            pool.put(frame);
            for (f, _) in ready.drain(..) {
                pool.put(f);
            }
            return ready;
        }
        let method = if frame.len() >= 4 {
            u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]])
        } else {
            METHOD_ERR
        };
        let nth = {
            let c = self.per_method.entry(method).or_insert(0);
            *c += 1;
            *c
        };
        if let Some(k) = self.plan.kill {
            let fire = match k.method {
                Some(m) => m == method && nth == k.nth,
                None => self.seq == k.nth,
            };
            if fire {
                self.dead = true;
                pool.put(frame);
                for (f, _) in ready.drain(..) {
                    pool.put(f);
                }
                for (_, f, _) in self.delayed.drain(..) {
                    pool.put(f);
                }
                return ready;
            }
        }
        match self.plan.actions.get(&(method, nth)) {
            None => ready.push((frame, reply)),
            Some(FaultAction::Drop) => pool.put(frame),
            Some(FaultAction::Duplicate) => {
                ready.push((frame.clone(), None));
                ready.push((frame, reply));
            }
            Some(FaultAction::Delay(k)) => {
                self.delayed.push((self.seq + (*k).max(1), frame, reply));
            }
        }
        ready
    }
}

/// One queued request: an encoded frame with an optional reply channel
/// (`None` marks a one-way `cast`), or the shutdown sentinel that
/// `Endpoint`'s `Drop` enqueues. The sentinel is what lets an endpoint
/// shut down even while other endpoints' handler state still holds
/// `Client` senders to it — without it, a mesh of endpoints whose
/// handlers hold clients to each other (the coordinator's topology)
/// could never disconnect and every drop would deadlock on the join.
enum Request {
    Frame(Vec<u8>, Option<Sender<Vec<u8>>>),
    Shutdown,
}

/// A served endpoint: spawn with handlers, then create [`Client`]s.
pub struct Endpoint {
    tx: Sender<Request>,
    pool: Arc<BufPool>,
    server: Option<std::thread::JoinHandle<()>>,
}

impl Endpoint {
    /// Start a single-threaded server (one dispatch core — deliberately,
    /// to measure per-core capacity like the paper's experiment).
    ///
    /// The endpoint owns a [`BufPool`] shared with every client: request
    /// frames are encoded into recycled buffers, and the server returns
    /// both the frame and the decoded payload buffer to the pool after
    /// dispatch. One-way casts skip building a response entirely.
    pub fn serve(handlers: HashMap<u32, Handler>) -> Self {
        Self::serve_with_faults(handlers, FaultPlan::none())
    }

    /// [`Endpoint::serve`] with a [`FaultPlan`] interposed between the
    /// receive queue and dispatch. An empty plan takes a zero-overhead
    /// fast path, so the faultless endpoint is unchanged.
    pub fn serve_with_faults(handlers: HashMap<u32, Handler>, plan: FaultPlan) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let pool = Arc::new(BufPool::new());
        let server_pool = Arc::clone(&pool);
        let server = std::thread::Builder::new()
            .name("rpc-server".into())
            .spawn(move || {
                let pool = server_pool;
                let mut faults = FaultState::new(plan);
                // Exits on the shutdown sentinel or full disconnect,
                // after draining everything queued before it.
                while let Ok(Request::Frame(frame, reply_tx)) = rx.recv() {
                    for (frame, reply_tx) in faults.admit(frame, reply_tx, &pool) {
                        Self::dispatch_one(&handlers, &pool, frame, reply_tx);
                    }
                }
            })
            .expect("spawn rpc server");
        Self { tx, pool, server: Some(server) }
    }

    /// Decode, dispatch, and (for calls) answer one frame, recycling the
    /// frame and payload buffers through the pool.
    fn dispatch_one(
        handlers: &HashMap<u32, Handler>,
        pool: &BufPool,
        frame: Vec<u8>,
        reply_tx: Option<Sender<Vec<u8>>>,
    ) {
        match reply_tx {
            None => {
                // One-way cast: dispatch, recycle, no response.
                if let Ok(msg) = Message::decode_pooled(&frame, pool) {
                    if let Some(h) = handlers.get(&msg.method) {
                        let _ = h(&msg);
                    }
                    pool.put(msg.payload);
                }
            }
            Some(reply_tx) => {
                let resp = match Message::decode_pooled(&frame, pool) {
                    Ok(msg) => {
                        let out = match handlers.get(&msg.method) {
                            Some(h) => match h(&msg) {
                                Ok(payload) => {
                                    Message { method: msg.method, id: msg.id, payload }
                                }
                                Err(e) => Message {
                                    method: METHOD_ERR,
                                    id: msg.id,
                                    payload: e.to_string().into_bytes(),
                                },
                            },
                            None => Message {
                                method: METHOD_ERR,
                                id: msg.id,
                                payload: b"no such method".to_vec(),
                            },
                        };
                        pool.put(msg.payload);
                        out
                    }
                    Err(e) => Message {
                        method: METHOD_ERR,
                        id: 0,
                        payload: e.to_string().into_bytes(),
                    },
                };
                let mut buf = pool.get(16 + resp.payload.len());
                resp.encode_into(&mut buf);
                let _ = reply_tx.send(buf);
            }
        }
        pool.put(frame);
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.tx.clone(),
            pool: Arc::clone(&self.pool),
            next_id: Arc::new(Mutex::new(0)),
        }
    }

    /// The endpoint's shared frame-buffer pool (telemetry, tests).
    pub fn buf_pool(&self) -> &BufPool {
        &self.pool
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        // Enqueue the shutdown sentinel, then join: the server drains
        // every frame queued before the sentinel and exits — even if
        // outstanding `Client` clones (possibly held by other endpoints'
        // handlers, possibly by this endpoint's own) never drop. Their
        // later sends fail with "endpoint closed".
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.server.take() {
            let _ = h.join();
        }
    }
}

/// Client handle (cheaply cloneable; shares the endpoint's [`BufPool`]).
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    pool: Arc<BufPool>,
    next_id: Arc<Mutex<u64>>,
}

impl Client {
    fn fresh_id(&self) -> u64 {
        let mut g = self.next_id.lock().unwrap();
        *g += 1;
        *g
    }

    /// Encode a frame header + `write`-produced payload into a pooled
    /// buffer; returns the sealed frame (length field patched) and the
    /// request id it carries.
    fn frame_with<F: FnOnce(&mut Vec<u8>)>(&self, method: u32, write: F) -> (Vec<u8>, u64) {
        let id = self.fresh_id();
        let mut buf = self.pool.get(64);
        buf.extend_from_slice(&method.to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]); // length, patched below
        buf.extend_from_slice(&id.to_le_bytes());
        write(&mut buf);
        let len = (buf.len() - 16) as u32;
        buf[4..8].copy_from_slice(&len.to_le_bytes());
        (buf, id)
    }

    /// Synchronous call; returns the response payload.
    pub fn call(&self, method: u32, payload: Vec<u8>) -> Result<Vec<u8>> {
        let (frame, id) = self.frame_with(method, |b| b.extend_from_slice(&payload));
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Frame(frame, Some(rtx)))
            .map_err(|_| crate::err!("endpoint closed"))?;
        let mut resp = rrx.recv().map_err(|_| crate::err!("endpoint closed"))?;
        // Parse the header in place; on success the pooled response
        // buffer itself, drained of its header, becomes the payload —
        // no copy. Error paths hand the buffer back to the pool.
        let (rmethod, rid) = match Message::decode_header(&resp) {
            Ok((m, rid, _len)) => (m, rid),
            Err(e) => {
                self.pool.put(resp);
                return Err(e);
            }
        };
        if rmethod == METHOD_ERR {
            let msg = String::from_utf8_lossy(&resp[16..]).into_owned();
            self.pool.put(resp);
            crate::bail!("{msg}");
        }
        if rid != id {
            self.pool.put(resp);
            crate::bail!("response id mismatch: {rid} vs {id}");
        }
        resp.drain(..16);
        Ok(resp)
    }

    /// One-way send: enqueue the frame and return immediately with the
    /// number of bytes that crossed the wire. The handler's return value
    /// is discarded; delivery is in-order per endpoint. This is the verb
    /// the coordinator's protocol state machines use — a handler may
    /// `cast` to a peer that is itself mid-handler without deadlock.
    pub fn cast(&self, method: u32, payload: Vec<u8>) -> Result<usize> {
        self.cast_frame(method, |b| b.extend_from_slice(&payload))
    }

    /// One-way send with the payload written in place by `write` into a
    /// pooled frame buffer — no intermediate payload vector. The query
    /// service's state machines encode every protocol frame through
    /// this, so a steady exchange stream allocates no frame memory.
    pub fn cast_frame<F: FnOnce(&mut Vec<u8>)>(&self, method: u32, write: F) -> Result<usize> {
        let (frame, _id) = self.frame_with(method, write);
        let bytes = frame.len();
        self.tx
            .send(Request::Frame(frame, None))
            .map_err(|_| crate::err!("endpoint closed"))?;
        Ok(bytes)
    }
}

// ------------------------------------------------------------- perf model

/// Analytic per-core RPC throughput model (eRPC-style).
///
/// A core spends `per_msg_us` microseconds of fixed work per RPC plus
/// `per_byte_ns` nanoseconds per payload byte (copy + checksum at the
/// modeled stack efficiency). Throughput at message size `s` is
/// `1 / (per_msg + per_byte·s)` messages/s.
#[derive(Clone, Copy, Debug)]
pub struct RpcModel {
    pub per_msg_us: f64,
    pub per_byte_ns: f64,
    /// Core speed relative to the x86 core the constants were calibrated
    /// on (ARM N1 ≈ 0.77 of the calibration core in the paper's setting).
    pub core_speed: f64,
}

impl RpcModel {
    /// eRPC's published numbers on x86: ~10 M small RPCs/s/core
    /// (per_msg = 0.1 µs) and ~75 Gbps large-message goodput
    /// (per_byte ≈ 0.1067 ns/B).
    pub fn erpc_x86() -> Self {
        Self { per_msg_us: 0.1, per_byte_ns: 0.1067, core_speed: 1.0 }
    }

    /// The same stack on one IPU E2000 ARM N1 core. Calibrated against the
    /// paper's measurement: "a single ARM core can sustain over 25 Gbps
    /// with large message RPCs" — i.e. ≈ 1/3 of the x86 large-message
    /// goodput (ARM core is slower and LPDDR copies are costlier).
    pub fn e2000_arm() -> Self {
        Self { per_msg_us: 0.22, per_byte_ns: 0.30, core_speed: 0.77 }
    }

    /// Messages per second at payload size `bytes`, one core.
    pub fn msgs_per_sec(&self, bytes: f64) -> f64 {
        let us = self.per_msg_us + self.per_byte_ns * bytes / 1000.0;
        1e6 / us
    }

    /// Goodput in Gbit/s at payload size `bytes`, one core.
    pub fn gbps(&self, bytes: f64) -> f64 {
        self.msgs_per_sec(bytes) * bytes * 8.0 / 1e9
    }

    /// Asymptotic large-message goodput, Gbit/s.
    pub fn peak_gbps(&self) -> f64 {
        8.0 / self.per_byte_ns
    }

    /// Cores needed to sustain `gbps` at message size `bytes`.
    pub fn cores_for(&self, gbps: f64, bytes: f64) -> f64 {
        gbps / self.gbps(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn message_roundtrip() {
        let m = Message { method: 7, id: 99, payload: vec![1, 2, 3, 4, 5] };
        let buf = m.encode();
        assert_eq!(Message::decode(&buf).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[1, 2, 3]).is_err());
        let mut buf = Message { method: 1, id: 1, payload: vec![0; 8] }.encode();
        buf.pop(); // truncate
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn endpoint_dispatches() {
        let ep = Dispatch::new()
            .on(1, |m: &Message| {
                let mut v = m.payload.clone();
                v.reverse();
                Ok(v)
            })
            .on(2, |_m: &Message| Ok(b"pong".to_vec()))
            .serve();
        let c = ep.client();
        assert_eq!(c.call(1, vec![1, 2, 3]).unwrap(), vec![3, 2, 1]);
        assert_eq!(c.call(2, vec![]).unwrap(), b"pong".to_vec());
    }

    #[test]
    fn unknown_method_errors() {
        let ep = Endpoint::serve(HashMap::new());
        let c = ep.client();
        let err = c.call(42, vec![]).unwrap_err();
        assert!(err.to_string().contains("no such method"));
    }

    #[test]
    fn handler_error_reaches_caller_as_error() {
        let ep = Dispatch::new()
            .on(3, |_m: &Message| Err(crate::err!("handler exploded")))
            .serve();
        let err = ep.client().call(3, vec![]).unwrap_err();
        assert!(err.to_string().contains("handler exploded"), "{err}");
    }

    #[test]
    fn cast_is_one_way_and_ordered() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let ep = Dispatch::new()
            .on(1, move |m: &Message| {
                log2.lock().unwrap().push(m.payload[0]);
                Ok(vec![])
            })
            .serve();
        let c = ep.client();
        for i in 0..10u8 {
            let bytes = c.cast(1, vec![i]).unwrap();
            assert_eq!(bytes, 17, "16B header + 1B payload");
        }
        // A closing call flushes the queue (the server is in-order).
        c.call(1, vec![99]).unwrap();
        let seen = log.lock().unwrap().clone();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 99]);
    }

    #[test]
    fn cast_errors_are_dropped_not_fatal() {
        let hits = Arc::new(AtomicUsize::new(0));
        let hits2 = hits.clone();
        let ep = Dispatch::new()
            .on(1, move |_m: &Message| {
                hits2.fetch_add(1, Ordering::SeqCst);
                Err(crate::err!("boom"))
            })
            .on(2, |_m| Ok(vec![]))
            .serve();
        let c = ep.client();
        c.cast(1, vec![]).unwrap(); // handler errors, nothing to report to
        c.call(2, vec![]).unwrap(); // endpoint still serves
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn buf_pool_recycles_and_bounds() {
        let pool = BufPool::new();
        let mut b = pool.get(100);
        assert!(b.capacity() >= 100);
        assert_eq!(pool.allocated(), 1);
        b.extend_from_slice(&[1, 2, 3]);
        pool.put(b);
        let b2 = pool.get(10);
        assert!(b2.is_empty(), "recycled buffer must come back cleared");
        assert!(b2.capacity() >= 100, "capacity survives the cycle");
        assert_eq!(pool.recycled(), 1);
        // Zero-capacity buffers are not worth keeping.
        pool.put(Vec::new());
        assert!(pool.free.lock().unwrap().is_empty());
        // The free list is bounded.
        for _ in 0..(super::POOL_MAX_BUFS + 10) {
            pool.put(Vec::with_capacity(8));
        }
        assert!(pool.free.lock().unwrap().len() <= super::POOL_MAX_BUFS);
    }

    #[test]
    fn steady_state_casts_reuse_pooled_frames() {
        let ep = Dispatch::new().on(1, |_m: &Message| Ok(vec![])).serve();
        let c = ep.client();
        // Warm up: the first frames allocate, then the server recycles
        // them and later casts draw from the free list.
        for _ in 0..50 {
            c.cast(1, vec![7; 32]).unwrap();
        }
        c.call(1, vec![]).unwrap(); // flush the in-order queue
        assert!(
            ep.buf_pool().recycled() > 0,
            "no frame buffer was ever recycled (allocated={})",
            ep.buf_pool().allocated()
        );
    }

    #[test]
    fn concurrent_clients() {
        let ep = Dispatch::new().on(1, |m: &Message| Ok(m.payload.clone())).serve();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let c = ep.client();
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        let payload = vec![(t * 100 + i) as u8; 16];
                        assert_eq!(c.call(1, payload.clone()).unwrap(), payload);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    fn logging_endpoint(plan: FaultPlan) -> (Endpoint, Arc<Mutex<Vec<u8>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let ep = Dispatch::new()
            .on(1, move |m: &Message| {
                log2.lock().unwrap().push(m.payload[0]);
                Ok(vec![])
            })
            .serve_with_faults(plan);
        (ep, log)
    }

    #[test]
    fn fault_drop_loses_exactly_the_nth_frame() {
        let plan = FaultPlan::none().with_action(1, 2, FaultAction::Drop);
        let (ep, log) = logging_endpoint(plan);
        let c = ep.client();
        for i in 10..14u8 {
            c.cast(1, vec![i]).unwrap(); // ordinals 1..=4
        }
        c.call(1, vec![99]).unwrap(); // ordinal 5 flushes the queue
        assert_eq!(*log.lock().unwrap(), vec![10, 12, 13, 99]);
    }

    #[test]
    fn fault_duplicate_delivers_twice_but_replies_once() {
        let plan = FaultPlan::none().with_action(1, 1, FaultAction::Duplicate);
        let (ep, log) = logging_endpoint(plan);
        let c = ep.client();
        c.cast(1, vec![7]).unwrap();
        c.call(1, vec![99]).unwrap();
        assert_eq!(*log.lock().unwrap(), vec![7, 7, 99]);
    }

    #[test]
    fn fault_delay_reorders_but_never_loses() {
        // Frame 1 is held for 2 arrivals: delivery order becomes 2, 1, 3.
        let plan = FaultPlan::none().with_action(1, 1, FaultAction::Delay(2));
        let (ep, log) = logging_endpoint(plan);
        let c = ep.client();
        for i in [1u8, 2, 3] {
            c.cast(1, vec![i]).unwrap();
        }
        c.call(1, vec![99]).unwrap();
        assert_eq!(*log.lock().unwrap(), vec![2, 1, 3, 99]);
    }

    #[test]
    fn killed_endpoint_drains_but_drops_everything() {
        let plan = FaultPlan::kill_on(1, 2);
        let (ep, log) = logging_endpoint(plan);
        let c = ep.client();
        c.cast(1, vec![1]).unwrap(); // survives
        c.cast(1, vec![2]).unwrap(); // the kill frame — never dispatched
        c.cast(1, vec![3]).unwrap(); // cast to the dead endpoint "succeeds"
        // A call to a dead endpoint observes a dropped reply channel.
        let err = c.call(1, vec![4]).unwrap_err();
        assert!(err.to_string().contains("endpoint closed"), "{err}");
        assert_eq!(*log.lock().unwrap(), vec![1]);
    }

    #[test]
    fn fault_plan_from_seed_is_deterministic_and_seed_sensitive() {
        let methods = [0x50u32, 0x51, 0x52, 0x54];
        let a = FaultPlan::from_seed(42, &methods);
        let b = FaultPlan::from_seed(42, &methods);
        assert_eq!(a.schedule(), b.schedule());
        // Across a handful of seeds, the schedules are not all identical
        // and at least one is non-empty (p(all-empty) < 1e-40).
        let schedules: Vec<_> =
            (0..16u64).map(|s| FaultPlan::from_seed(s, &methods).schedule()).collect();
        assert!(schedules.iter().any(|s| !s.is_empty()));
        assert!(schedules.iter().any(|s| *s != schedules[0]));
    }

    #[test]
    fn empty_fault_plan_is_transparent() {
        assert!(FaultPlan::none().is_empty());
        let (ep, log) = logging_endpoint(FaultPlan::none());
        let c = ep.client();
        for i in 0..5u8 {
            c.cast(1, vec![i]).unwrap();
        }
        c.call(1, vec![99]).unwrap();
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4, 99]);
    }

    /// eRPC calibration: ~10M msgs/s at tiny payloads, ~75 Gbps at 1 MB.
    #[test]
    fn erpc_calibration_points() {
        let m = RpcModel::erpc_x86();
        assert!(close(m.msgs_per_sec(0.0) / 1e6, 10.0, 0.01));
        assert!(m.gbps(1e6) > 70.0 && m.gbps(1e6) < 76.0, "gbps={}", m.gbps(1e6));
    }

    /// §6: one E2000 ARM core sustains > 25 Gbps with large messages.
    #[test]
    fn e2000_arm_exceeds_25gbps_large() {
        let m = RpcModel::e2000_arm();
        assert!(m.gbps(1e6) > 25.0, "gbps={}", m.gbps(1e6));
        assert!(m.peak_gbps() > 25.0);
        // But it should be well below the x86 core (slower core).
        assert!(m.gbps(1e6) < RpcModel::erpc_x86().gbps(1e6));
    }

    #[test]
    fn throughput_monotone_in_size() {
        let m = RpcModel::e2000_arm();
        let mut last = 0.0;
        for s in [64.0, 1024.0, 65536.0, 1e6] {
            let g = m.gbps(s);
            assert!(g > last);
            last = g;
        }
    }

    #[test]
    fn cores_for_line_rate() {
        // How many ARM cores to drive a 200 Gbps NIC with 1 MB messages?
        let m = RpcModel::e2000_arm();
        let n = m.cores_for(200.0, 1e6);
        assert!(n > 6.0 && n < 9.0, "cores={n}");
    }
}

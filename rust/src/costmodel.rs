//! The paper's §4 analytical cost and energy model, plus the §5.2/§6
//! fabric-cost extension.
//!
//! Notation (all relative to one smart NIC):
//! * `c_s`, `p_s` — capital cost / power of one server,
//! * `c_p`, `p_p` — capital cost / power of the PCIe devices attached to a
//!   server (or to the smart NIC in Lovelock),
//! * `φ` (phi) — Lovelock provisions φ smart NICs per replaced server,
//! * `μ` (mu) — application slowdown on Lovelock (μ>1 slower, μ<1 faster),
//! * `c_f` — fabric/ToR cost per server, for the extended model.
//!
//! Eq. 1:  cost ratio  = (c_s + c_p) / (φ + c_p)
//! Eq. 2:  power ratio = (p_s + p_p) / (μ · (φ + p_p))
//! Extended (§5.2): cost ratio = (c_s + c_f + c_p) / (φ·(1 + c_f) + c_p)

/// Relative cost/power parameters of one cluster comparison.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Server capital cost relative to a smart NIC (paper: ≈7, from [6]).
    pub c_s: f64,
    /// Server power relative to a smart NIC (paper: ≈11–11.2, from [6]).
    pub p_s: f64,
    /// PCIe-device capital cost relative to a smart NIC (0 if none).
    pub c_p: f64,
    /// PCIe-device power relative to a smart NIC (0 if none).
    pub p_p: f64,
}

impl CostModel {
    /// The paper's NVIDIA-white-paper baseline with no PCIe devices
    /// (`c_s = 7`, `p_s = 11`).
    pub fn bare_bluefield() -> Self {
        Self { c_s: 7.0, p_s: 11.0, c_p: 0.0, p_p: 0.0 }
    }

    /// Baseline with `p_s = 11.2` (the value §4/§5.3 use when PCIe devices
    /// are in play).
    pub fn host_only() -> Self {
        Self { c_s: 7.0, p_s: 11.2, c_p: 0.0, p_p: 0.0 }
    }

    /// Attach PCIe devices that account for fraction `share` of total
    /// system cost/power (paper: 0.75 for 4-GPU servers), deriving
    /// `c_p = c_s · share/(1-share)` and likewise for power.
    pub fn with_pcie_share(mut self, share: f64) -> Self {
        assert!((0.0..1.0).contains(&share));
        self.c_p = self.c_s * share / (1.0 - share);
        self.p_p = self.p_s * share / (1.0 - share);
        self
    }

    /// Eq. 1 — capital cost of a traditional cluster relative to Lovelock.
    /// Values > 1 mean Lovelock is cheaper.
    pub fn cost_ratio(&self, phi: f64) -> f64 {
        assert!(phi > 0.0);
        (self.c_s + self.c_p) / (phi + self.c_p)
    }

    /// Eq. 2 — power of a traditional cluster relative to Lovelock, for a
    /// run that takes μ× as long on Lovelock (energy = power × time).
    pub fn power_ratio(&self, phi: f64, mu: f64) -> f64 {
        assert!(phi > 0.0 && mu > 0.0);
        (self.p_s + self.p_p) / (mu * (phi + self.p_p))
    }

    /// §5.2 extension: include fabric cost `c_f` per server (scaling
    /// linearly with node count — the paper's *pessimistic* variant).
    pub fn cost_ratio_with_fabric(&self, phi: f64, c_f: f64) -> f64 {
        assert!(phi > 0.0 && c_f >= 0.0);
        (self.c_s + c_f + self.c_p) / (phi * (1.0 + c_f) + self.c_p)
    }

    /// §5.2's refinement: the fabric does not need φ× capacity — only
    /// enough to sustain the achieved execution rate. Returns the required
    /// fabric speed relative to the traditional cluster's fabric
    /// (`1/μ`): μ=1.22 → 0.82 (fabric may be ~18-19% *slower*);
    /// μ=0.81 → 1.23 (fabric must be ~23% faster).
    pub fn required_fabric_speed(&self, mu: f64) -> f64 {
        assert!(mu > 0.0);
        1.0 / mu
    }
}

// ------------------------------------------ plan cardinality estimates
//
// A second, unrelated-to-the-paper use of this module: coarse
// cardinality estimates over the analytics plan IR. The SQL binder
// orders join steps by estimated build size, and `explain` prints the
// numbers. Selectivities are fixed per leaf shape (no data statistics
// are consulted) — good enough to rank hash-build sides, useless for
// anything finer, and deliberately deterministic so plans never depend
// on the data they run over.

use crate::analytics::engine::plan::{LogicalPlan, PredExpr, StrMatch, TableRef};

/// TPC-H base cardinality of a table at scale factor 1.
pub fn table_base_rows(t: TableRef) -> f64 {
    match t {
        TableRef::Lineitem => 6_000_000.0,
        TableRef::Orders => 1_500_000.0,
        TableRef::Partsupp => 800_000.0,
        TableRef::Part => 200_000.0,
        TableRef::Customer => 150_000.0,
        TableRef::Supplier => 10_000.0,
    }
}

/// Fraction of rows a predicate tree is assumed to keep.
pub fn pred_selectivity(p: &PredExpr) -> f64 {
    match p {
        PredExpr::True => 1.0,
        PredExpr::I32Range { .. } | PredExpr::F64Range { .. } => 0.3,
        PredExpr::I32ColLt { .. } => 0.5,
        PredExpr::F64Lt { .. } => 0.4,
        PredExpr::I32InSet { values, .. } => (0.05 * values.len() as f64).min(0.6),
        PredExpr::Str { m, .. } => match m {
            StrMatch::Eq(_) => 0.1,
            StrMatch::Prefix(_) => 0.15,
            StrMatch::Contains(_) => 0.5,
            StrMatch::OneOf(vs) => (0.1 * vs.len() as f64).min(0.6),
        },
        PredExpr::And(ps) => ps.iter().map(|p| pred_selectivity(p)).product::<f64>().max(0.001),
        PredExpr::Or(ps) => ps.iter().map(|p| pred_selectivity(p)).sum::<f64>().min(1.0),
    }
}

/// Estimated build side of one join step.
#[derive(Clone, Copy, Debug)]
pub struct StepEstimate {
    pub table: TableRef,
    /// Dimension rows at this scale factor, before the filter.
    pub base_rows: f64,
    /// Assumed fraction surviving the step's dimension filter.
    pub selectivity: f64,
    /// `base_rows × selectivity` — what the hash build materializes.
    pub build_rows: f64,
}

/// Coarse cardinalities of a whole plan at scale factor `sf`.
#[derive(Clone, Debug)]
pub struct PlanEstimate {
    pub scan_rows: f64,
    pub scan_selectivity: f64,
    pub steps: Vec<StepEstimate>,
    /// Rows reaching the aggregate after scan pred, join filters, and
    /// compare conjuncts (each compare assumed to halve).
    pub agg_rows: f64,
}

/// Estimate a plan's cardinalities (see [`PlanEstimate`]).
pub fn estimate(plan: &LogicalPlan, sf: f64) -> PlanEstimate {
    let scan_rows = table_base_rows(plan.scan) * sf;
    let scan_selectivity = pred_selectivity(&plan.pred);
    let steps: Vec<StepEstimate> = plan
        .joins
        .iter()
        .map(|j| {
            let base_rows = table_base_rows(j.table) * sf;
            let selectivity = pred_selectivity(&j.filter);
            StepEstimate {
                table: j.table,
                base_rows,
                selectivity,
                build_rows: base_rows * selectivity,
            }
        })
        .collect();
    let mut agg_rows = scan_rows * scan_selectivity;
    for s in &steps {
        agg_rows *= s.selectivity;
    }
    agg_rows *= 0.5f64.powi(plan.cmps.len() as i32);
    PlanEstimate { scan_rows, scan_selectivity, steps, agg_rows }
}

/// A named (φ, μ) scenario for sweep tables.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub phi: f64,
    pub mu: f64,
}

/// Evaluate cost and power ratios across scenarios.
pub fn sweep(model: &CostModel, scenarios: &[Scenario]) -> Vec<(Scenario, f64, f64)> {
    scenarios
        .iter()
        .map(|s| (*s, model.cost_ratio(s.phi), model.power_ratio(s.phi, s.mu)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    /// §4: bare cluster, φ=3, μ=1.2 → "2.3× cheaper and 3.1× less energy".
    #[test]
    fn paper_bare_scenario() {
        let m = CostModel::bare_bluefield();
        assert!(close(m.cost_ratio(3.0), 2.33, 0.01));
        assert!(close(m.power_ratio(3.0, 1.2), 3.06, 0.05)); // paper rounds to 3.1
    }

    /// §4: PCIe devices at 75% of system cost → c_p=21, p_p=33.6.
    #[test]
    fn pcie_share_derivation() {
        let m = CostModel::host_only().with_pcie_share(0.75);
        assert!(close(m.c_p, 21.0, 1e-9));
        assert!(close(m.p_p, 33.6, 1e-9));
    }

    /// §4: φ=1 no slowdown → 1.27× cost, 1.3× energy.
    #[test]
    fn paper_pcie_phi1() {
        let m = CostModel::host_only().with_pcie_share(0.75);
        assert!(close(m.cost_ratio(1.0), 1.27, 0.005));
        assert!(close(m.power_ratio(1.0, 1.0), 1.295, 0.01));
    }

    /// §4: φ=2, 10% faster (μ=0.9) → 1.22× cost, 1.4× energy.
    #[test]
    fn paper_pcie_phi2() {
        let m = CostModel::host_only().with_pcie_share(0.75);
        assert!(close(m.cost_ratio(2.0), 1.22, 0.005));
        assert!(close(m.power_ratio(2.0, 0.9), 1.40, 0.01));
    }

    /// §5.2: lite-compute (no PCIe): φ=2 → 3.5×, φ=3 → 2.33×; energy 4.58×
    /// for both (μ = 1.22 and 0.81 respectively from the Fig. 4 analysis).
    #[test]
    fn paper_bigquery_costs() {
        let m = CostModel::host_only();
        assert!(close(m.cost_ratio(2.0), 3.5, 0.01));
        assert!(close(m.cost_ratio(3.0), 2.33, 0.01));
        assert!(close(m.power_ratio(2.0, 1.22), 4.59, 0.05));
        assert!(close(m.power_ratio(3.0, 0.81), 4.61, 0.05));
    }

    /// §5.2: fabric cost c_f = 0.7 → 2.26× (φ=2) and 1.51× (φ=3).
    #[test]
    fn paper_fabric_extension() {
        let m = CostModel::host_only();
        assert!(close(m.cost_ratio_with_fabric(2.0, 0.7), 2.26, 0.01));
        assert!(close(m.cost_ratio_with_fabric(3.0, 0.7), 1.51, 0.01));
    }

    /// §5.2: fabric speed requirement — ~19% slower at μ=1.22, ~23% faster
    /// at μ=0.81.
    #[test]
    fn paper_fabric_speed() {
        let m = CostModel::host_only();
        assert!(close(1.0 - m.required_fabric_speed(1.22), 0.18, 0.01));
        assert!(close(m.required_fabric_speed(0.81) - 1.0, 0.235, 0.01));
    }

    /// §5.3: LLM training, φ=1, μ=1 with 75% PCIe share → 1.27× / 1.30×.
    #[test]
    fn paper_llm_training_costs() {
        // The paper uses p_p = 33.2 in §5.3 (vs 33.6 in §4) — reproduce
        // with the §5.3 constants verbatim.
        let m = CostModel { c_s: 7.0, p_s: 11.2, c_p: 21.0, p_p: 33.2 };
        assert!(close(m.cost_ratio(1.0), 1.27, 0.005));
        assert!(close(m.power_ratio(1.0, 1.0), 1.30, 0.005));
    }

    /// §5.3: GNN / bandwidth-stalled accelerators: φ=2, 10% speedup →
    /// 1.22× cost and 1.4× power.
    #[test]
    fn paper_gnn_costs() {
        let m = CostModel::host_only().with_pcie_share(0.75);
        assert!(close(m.cost_ratio(2.0), 1.22, 0.005));
        assert!(close(m.power_ratio(2.0, 0.9), 1.40, 0.01));
    }

    #[test]
    fn cost_monotone_decreasing_in_phi() {
        let m = CostModel::host_only().with_pcie_share(0.5);
        let mut last = f64::INFINITY;
        for phi in [0.5, 1.0, 2.0, 3.0, 5.0, 10.0] {
            let c = m.cost_ratio(phi);
            assert!(c < last);
            last = c;
        }
    }

    #[test]
    fn power_scales_inverse_mu() {
        let m = CostModel::host_only();
        let a = m.power_ratio(2.0, 1.0);
        let b = m.power_ratio(2.0, 2.0);
        assert!(close(a / b, 2.0, 1e-9));
    }

    #[test]
    fn fabric_zero_reduces_to_eq1() {
        let m = CostModel::host_only().with_pcie_share(0.75);
        assert!(close(m.cost_ratio_with_fabric(2.0, 0.0), m.cost_ratio(2.0), 1e-12));
    }

    #[test]
    fn plan_estimates_rank_build_sides() {
        use crate::analytics::engine::plan::{i32_range, str_eq};
        // A filtered customer build must rank below an unfiltered
        // orders build, and And tightens selectivity multiplicatively.
        assert!(table_base_rows(TableRef::Orders) > table_base_rows(TableRef::Customer));
        let filtered = str_eq("c_mktsegment", "BUILDING");
        assert!(pred_selectivity(&filtered) < pred_selectivity(&PredExpr::True));
        let both = crate::analytics::engine::plan::pand(vec![
            str_eq("c_mktsegment", "BUILDING"),
            i32_range("c_nationkey", 0, 5),
        ]);
        assert!(pred_selectivity(&both) < pred_selectivity(&filtered));
        // Estimates scale linearly with sf and follow the plan shape.
        let q3 = crate::analytics::queries::build("q3", &Default::default()).unwrap();
        let e1 = estimate(&q3, 1.0);
        let e2 = estimate(&q3, 2.0);
        assert!(close(e2.scan_rows, 2.0 * e1.scan_rows, 1e-6));
        assert_eq!(e1.steps.len(), q3.joins.len());
        for s in &e1.steps {
            assert!(close(s.build_rows, s.base_rows * s.selectivity, 1e-9));
        }
    }

    #[test]
    fn sweep_covers_scenarios() {
        let m = CostModel::bare_bluefield();
        let rows = sweep(
            &m,
            &[Scenario { phi: 1.0, mu: 1.0 }, Scenario { phi: 3.0, mu: 1.2 }],
        );
        assert_eq!(rows.len(), 2);
        assert!(close(rows[1].1, 2.33, 0.01));
    }
}

//! GNN training input-pipeline model — §5.3 "Higher aggregate network
//! bandwidth".
//!
//! The paper cites BGL [30]: building one GNN mini-batch fetches ~200 MB
//! from remote machines; 8 V100s in one server can *compute* 400
//! mini-batches/s but a shared 100 Gbps NIC only *feeds* ~60/s, so the
//! accelerators stall. Lovelock fixes the feeding side: φ smart NICs per
//! replaced server each bring their own 200–400 Gbps port, multiplying
//! aggregate end-host bandwidth.
//!
//! This module models the pipeline as a two-stage rate match (fetch →
//! compute) with an optional feature cache that short-circuits part of the
//! fetch, and derives the stall fraction / achieved throughput the paper
//! argues about. It also covers the generic claim that removing a
//! stall fraction `s` by doubling bandwidth yields `1/(1-s/2)` speedup
//! (s = 20% → ~11%).

/// Configuration of one GNN training host (traditional or Lovelock node).
#[derive(Clone, Copy, Debug)]
pub struct GnnHost {
    /// Accelerators attached to this host.
    pub gpus: u32,
    /// Mini-batches/s one GPU can compute (BGL: 400/8 = 50 per V100).
    pub compute_mbps_per_gpu: f64,
    /// Host NIC bandwidth, Gbit/s.
    pub nic_gbps: f64,
    /// Remote bytes fetched per mini-batch (BGL: 200 MB).
    pub fetch_bytes_per_mb: f64,
    /// Fraction of fetches served by a local feature cache.
    pub cache_hit: f64,
}

impl GnnHost {
    /// The BGL server: 8× V100, 100 Gbps, 200 MB/mini-batch, no cache.
    pub fn bgl_server() -> Self {
        Self {
            gpus: 8,
            compute_mbps_per_gpu: 50.0,
            nic_gbps: 100.0,
            fetch_bytes_per_mb: 200e6,
            cache_hit: 0.0,
        }
    }

    /// Compute-side ceiling, mini-batches/s.
    pub fn compute_rate(&self) -> f64 {
        self.gpus as f64 * self.compute_mbps_per_gpu
    }

    /// Network-side ceiling, mini-batches/s.
    pub fn network_rate(&self) -> f64 {
        let bytes = self.fetch_bytes_per_mb * (1.0 - self.cache_hit);
        if bytes <= 0.0 {
            return f64::INFINITY;
        }
        (self.nic_gbps / 8.0) * 1e9 / bytes
    }

    /// Achieved throughput = min of the two stages.
    pub fn achieved_rate(&self) -> f64 {
        self.compute_rate().min(self.network_rate())
    }

    /// Fraction of accelerator time spent stalled on the network.
    pub fn stall_fraction(&self) -> f64 {
        (1.0 - self.achieved_rate() / self.compute_rate()).max(0.0)
    }

    /// GPU utilization (complement of stalls).
    pub fn gpu_utilization(&self) -> f64 {
        1.0 - self.stall_fraction()
    }
}

/// A Lovelock replacement for one traditional GNN host: the same total GPU
/// count spread over `phi` smart NICs, each with its own port.
#[derive(Clone, Copy, Debug)]
pub struct LovelockGnn {
    pub phi: u32,
    pub nic_gbps_each: f64,
    pub base: GnnHost,
}

impl LovelockGnn {
    /// Aggregate achieved mini-batch rate across the φ nodes.
    pub fn achieved_rate(&self) -> f64 {
        let gpus_per_node = self.base.gpus as f64 / self.phi as f64;
        let node = GnnHost {
            gpus: 1, // use fractional arithmetic below instead
            ..self.base
        };
        let compute = gpus_per_node * node.compute_mbps_per_gpu;
        let network = (self.nic_gbps_each / 8.0) * 1e9
            / (self.base.fetch_bytes_per_mb * (1.0 - self.base.cache_hit));
        self.phi as f64 * compute.min(network)
    }

    pub fn speedup_vs_server(&self) -> f64 {
        self.achieved_rate() / self.base.achieved_rate()
    }
}

/// Generic stall-amortization claim (§5.3): if a fraction `stall` of
/// execution is network stalls, scaling bandwidth by `bw_scale` yields
/// this overall speedup.
pub fn bandwidth_speedup(stall: f64, bw_scale: f64) -> f64 {
    assert!((0.0..=1.0).contains(&stall) && bw_scale > 0.0);
    1.0 / ((1.0 - stall) + stall / bw_scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    /// Paper/BGL: 8 V100s compute 400 mb/s; 100 Gbps feeds only ~60 mb/s.
    #[test]
    fn bgl_numbers() {
        let h = GnnHost::bgl_server();
        assert!(close(h.compute_rate(), 400.0, 1e-9));
        assert!(close(h.network_rate(), 62.5, 0.1)); // paper rounds to 60
        assert!(close(h.achieved_rate(), 62.5, 0.1));
        // GPUs are ~84% stalled — the under-utilization the paper cites.
        assert!(h.stall_fraction() > 0.8);
    }

    /// Lovelock with φ=4 E2000s (200 Gbps each) hosting 2 GPUs apiece
    /// feeds 8× the bandwidth → compute becomes visible again.
    #[test]
    fn lovelock_unstalls_gnn() {
        let l = LovelockGnn { phi: 4, nic_gbps_each: 200.0, base: GnnHost::bgl_server() };
        let rate = l.achieved_rate();
        assert!(rate > 4.0 * GnnHost::bgl_server().achieved_rate());
        // 4 nodes × min(100 compute, 125 network) = 400 → fully compute bound.
        assert!(close(rate, 400.0, 1.0), "rate={rate}");
        assert!(l.speedup_vs_server() > 6.0);
    }

    /// §5.3: "network stalls often account for over 20% of execution time,
    /// so providing 2x of bandwidth can easily bring 10% speedup".
    #[test]
    fn twenty_pct_stall_halved_gives_ten_pct() {
        let s = bandwidth_speedup(0.20, 2.0);
        assert!(s >= 1.10, "speedup={s}");
        assert!(close(s, 1.111, 0.005));
    }

    #[test]
    fn cache_reduces_network_pressure() {
        let mut h = GnnHost::bgl_server();
        h.cache_hit = 0.8;
        assert!(close(h.network_rate(), 312.5, 0.5));
        assert!(h.stall_fraction() < 0.25);
        h.cache_hit = 1.0;
        assert!(h.network_rate().is_infinite());
        assert!(close(h.achieved_rate(), 400.0, 1e-9));
    }

    #[test]
    fn speedup_monotone_in_bandwidth() {
        let mut last = 0.0;
        for bw in [1.0, 1.5, 2.0, 4.0, 8.0] {
            let s = bandwidth_speedup(0.3, bw);
            assert!(s > last);
            last = s;
        }
        assert!(close(bandwidth_speedup(0.3, 1.0), 1.0, 1e-12));
    }

    #[test]
    fn no_stall_no_speedup() {
        assert!(close(bandwidth_speedup(0.0, 8.0), 1.0, 1e-12));
    }

    #[test]
    fn phi1_matches_base_when_same_nic() {
        let l = LovelockGnn { phi: 1, nic_gbps_each: 100.0, base: GnnHost::bgl_server() };
        assert!(close(l.achieved_rate(), GnnHost::bgl_server().achieved_rate(), 0.1));
    }
}

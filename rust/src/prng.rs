//! Deterministic pseudo-random number generation.
//!
//! Every simulator and workload generator in Lovelock takes an explicit
//! seed so experiments are reproducible bit-for-bit. We implement PCG64
//! (O'Neill's PCG XSL RR 128/64) plus SplitMix64 for seeding, rather than
//! pulling in `rand` (unavailable in the offline registry). The statistical
//! quality of PCG64 is more than sufficient for workload synthesis.

/// SplitMix64: used to expand a single `u64` seed into PCG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL RR 128/64 — the main generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut pcg = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        // Burn a couple of outputs to decorrelate nearby seeds.
        pcg.next_u64();
        pcg.next_u64();
        pcg
    }

    /// Derive an independent stream for a named sub-component.
    ///
    /// Used so e.g. each TPC-H table generator gets its own stream from the
    /// top-level experiment seed without coupling their sequences.
    pub fn derive(&self, tag: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a
        for b in tag.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        let mut clone = self.clone();
        let mix = clone.next_u64();
        Self::seed_from_u64(h ^ mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range_u64(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.gen_range_u64(span) as i64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value; simple, adequate here).
    pub fn gen_normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        let u = self.gen_f64().max(1e-300);
        -u.ln() / lambda
    }

    /// Zipf-like rank sample over `[0, n)` with skew `s` via rejection
    /// inversion (adequate for workload skew synthesis).
    pub fn gen_zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.gen_range_u64(n);
        }
        // Inverse-CDF on the continuous approximation.
        let hmax = harmonic_approx(n as f64, s);
        let u = self.gen_f64() * hmax;
        let x = inv_harmonic_approx(u, s).floor() as u64;
        x.min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_u64(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range_u64(xs.len() as u64) as usize]
    }

    /// Sample an index from unnormalized weights.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= *w;
        }
        weights.len() - 1
    }
}

fn harmonic_approx(n: f64, s: f64) -> f64 {
    if (s - 1.0).abs() < 1e-9 {
        n.ln() + 0.5772156649
    } else {
        (n.powf(1.0 - s) - 1.0) / (1.0 - s) + 1.0
    }
}

fn inv_harmonic_approx(h: f64, s: f64) -> f64 {
    if (s - 1.0).abs() < 1e-9 {
        (h - 0.5772156649).exp()
    } else {
        ((h - 1.0) * (1.0 - s) + 1.0).powf(1.0 / (1.0 - s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn derive_streams_independent() {
        let root = Pcg64::seed_from_u64(7);
        let mut l = root.derive("lineitem");
        let mut o = root.derive("orders");
        let same = (0..64).filter(|_| l.next_u64() == o.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds_hold() {
        let mut r = Pcg64::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = r.gen_range_u64(17);
            assert!(v < 17);
            let w = r.gen_range_i64(-5, 5);
            assert!((-5..=5).contains(&w));
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Pcg64::seed_from_u64(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range_u64(10) as usize] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Pcg64::seed_from_u64(8);
        let mut counts = [0u32; 100];
        for _ in 0..50_000 {
            counts[r.gen_zipf(100, 1.1) as usize] += 1;
        }
        assert!(counts[0] > counts[50] * 5);
        assert!(counts.iter().enumerate().all(|(i, _)| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Pcg64::seed_from_u64(10);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn exp_mean() {
        let mut r = Pcg64::seed_from_u64(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}

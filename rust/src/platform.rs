//! Platform catalog — the hardware models behind Table 1 of the paper.
//!
//! Each [`Platform`] records the spec-sheet quantities the paper uses
//! (core/SMT count, NIC bandwidth, DRAM channel count and transfer rate)
//! plus the modeling parameters the contention simulator ([`crate::memsim`])
//! and cost model ([`crate::costmodel`]) need: single-thread speed relative
//! to one IPU E2000 ARM N1 core, SMT scaling, LLC size, and relative
//! cost/power. The derived per-core bandwidths reproduce Table 1's numbers
//! exactly (theoretical DDR bandwidths from channel count × transfer rate,
//! 8 bytes/transfer).

/// Whether a platform is a conventional server host or a smart NIC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Server,
    SmartNic,
}

/// One hardware platform (a row of Table 1).
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub kind: Kind,
    /// Hardware threads exposed (vCPUs; SMT siblings counted).
    pub vcpus: u32,
    /// Physical cores (vcpus / smt_ways).
    pub smt_ways: u32,
    /// NIC line rate in Gbit/s.
    pub nic_gbps: f64,
    /// DRAM channels and per-channel transfer rate (MT/s); 8 B per transfer.
    pub mem_channels: u32,
    pub mem_mtps: f64,
    /// Last-level cache in MiB (modeling input for contention).
    pub llc_mib: f64,
    /// Single-thread performance of one core relative to one E2000 ARM N1
    /// core, uncontended (modeling input; see DESIGN.md §6).
    pub st_speed: f64,
    /// Throughput retained per SMT thread when both siblings are busy
    /// (1.0 for non-SMT parts; ~0.65 for x86 SMT2).
    pub smt_efficiency: f64,
    /// Capital cost relative to one smart NIC (c_s in the paper's model).
    pub rel_cost: f64,
    /// Power draw relative to one smart NIC (p_s in the paper's model).
    pub rel_power: f64,
}

impl Platform {
    /// Theoretical DRAM bandwidth in GB/s: channels × MT/s × 8 B.
    pub fn dram_gbs(&self) -> f64 {
        self.mem_channels as f64 * self.mem_mtps * 8.0 / 1000.0
    }

    /// NIC bandwidth in GB/s.
    pub fn nic_gbs(&self) -> f64 {
        self.nic_gbps / 8.0
    }

    /// Table 1 column: NIC bandwidth per vCPU, GB/s.
    pub fn nic_gbs_per_core(&self) -> f64 {
        self.nic_gbs() / self.vcpus as f64
    }

    /// Table 1 column: DRAM bandwidth per vCPU, GB/s.
    pub fn dram_gbs_per_core(&self) -> f64 {
        self.dram_gbs() / self.vcpus as f64
    }

    /// Physical cores.
    pub fn cores(&self) -> u32 {
        self.vcpus / self.smt_ways
    }
}

/// Google Cloud N1 host: 2× Intel Skylake, DDR4-2666, 100 Gbps.
pub fn n1_skylake() -> Platform {
    Platform {
        name: "GCP N1 (2x Skylake)",
        kind: Kind::Server,
        vcpus: 96,
        smt_ways: 2,
        nic_gbps: 100.0,
        mem_channels: 12,
        mem_mtps: 2666.0,
        llc_mib: 2.0 * 38.5,
        st_speed: 1.30,
        smt_efficiency: 0.65,
        rel_cost: 7.0,
        rel_power: 11.2,
    }
}

/// The Skylake measurement box of Fig. 3: 112 SMTs (2× 28 cores).
pub fn skylake_fig3() -> Platform {
    Platform {
        vcpus: 112,
        ..n1_skylake()
    }
}

/// Google Cloud N2d host: 2× AMD Milan, DDR4-3200, 100 Gbps.
pub fn n2d_milan() -> Platform {
    Platform {
        name: "GCP N2d (2x Milan)",
        kind: Kind::Server,
        vcpus: 224,
        smt_ways: 2,
        nic_gbps: 100.0,
        mem_channels: 16,
        mem_mtps: 3200.0,
        llc_mib: 2.0 * 256.0,
        st_speed: 1.55,
        smt_efficiency: 0.65,
        rel_cost: 7.0,
        rel_power: 11.2,
    }
}

/// AWS M6in host: 2× Intel Ice Lake, DDR4-3200, 200 Gbps.
pub fn m6in_icelake() -> Platform {
    Platform {
        name: "AWS M6in (2x Ice Lake)",
        kind: Kind::Server,
        vcpus: 128,
        smt_ways: 2,
        nic_gbps: 200.0,
        mem_channels: 16,
        mem_mtps: 3200.0,
        llc_mib: 2.0 * 54.0,
        st_speed: 1.45,
        smt_efficiency: 0.65,
        rel_cost: 7.0,
        rel_power: 11.2,
    }
}

/// Google Cloud C3 host: 2× Sapphire Rapids, DDR5-4800, 200 Gbps.
pub fn c3_sapphire_rapids() -> Platform {
    Platform {
        name: "GCP C3 (2x SPR)",
        kind: Kind::Server,
        vcpus: 176,
        smt_ways: 2,
        nic_gbps: 200.0,
        mem_channels: 16,
        mem_mtps: 4800.0,
        llc_mib: 2.0 * 105.0,
        st_speed: 1.65,
        smt_efficiency: 0.65,
        rel_cost: 7.0,
        rel_power: 11.2,
    }
}

/// AMD Genoa (1× EPYC 9654) paired with a 200 Gbps NIC (paper footnote 1).
pub fn genoa() -> Platform {
    Platform {
        name: "AMD Genoa (EPYC 9654)",
        kind: Kind::Server,
        vcpus: 192,
        smt_ways: 2,
        nic_gbps: 200.0,
        mem_channels: 12,
        mem_mtps: 4800.0,
        llc_mib: 384.0,
        st_speed: 1.70,
        smt_efficiency: 0.65,
        rel_cost: 7.0,
        rel_power: 11.2,
    }
}

/// Intel IPU E2000 smart NIC: 16 ARM Neoverse N1 cores, 3-ch LPDDR4-4266,
/// 200 Gbps. The paper's reference smart NIC (cost/power baseline = 1).
pub fn ipu_e2000() -> Platform {
    Platform {
        name: "Intel IPU E2000",
        kind: Kind::SmartNic,
        vcpus: 16,
        smt_ways: 1,
        nic_gbps: 200.0,
        mem_channels: 3,
        mem_mtps: 4266.0,
        llc_mib: 32.0,
        st_speed: 1.0,
        smt_efficiency: 1.0,
        rel_cost: 1.0,
        rel_power: 1.0,
    }
}

/// NVIDIA BlueField-3 DPU: 16 ARM cores, 2-ch DDR5-5600, 400 Gbps.
pub fn bluefield_v3() -> Platform {
    Platform {
        name: "BlueField v3",
        kind: Kind::SmartNic,
        vcpus: 16,
        smt_ways: 1,
        nic_gbps: 400.0,
        mem_channels: 2,
        mem_mtps: 5600.0,
        llc_mib: 16.0,
        st_speed: 1.05,
        smt_efficiency: 1.0,
        rel_cost: 1.0,
        rel_power: 1.0,
    }
}

/// All Table 1 rows in paper order.
pub fn table1_platforms() -> Vec<Platform> {
    vec![
        n1_skylake(),
        n2d_milan(),
        m6in_icelake(),
        c3_sapphire_rapids(),
        genoa(),
        ipu_e2000(),
        bluefield_v3(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    /// Table 1's "NIC bw per core" column, GB/s (paper-reported values).
    #[test]
    fn table1_nic_bw_per_core_matches_paper() {
        assert!(close(n1_skylake().nic_gbs_per_core(), 0.13, 0.005));
        assert!(close(n2d_milan().nic_gbs_per_core(), 0.06, 0.005));
        assert!(close(m6in_icelake().nic_gbs_per_core(), 0.20, 0.005));
        assert!(close(c3_sapphire_rapids().nic_gbs_per_core(), 0.14, 0.005));
        assert!(close(genoa().nic_gbs_per_core(), 0.13, 0.005));
        assert!(close(ipu_e2000().nic_gbs_per_core(), 1.56, 0.005));
        assert!(close(bluefield_v3().nic_gbs_per_core(), 3.13, 0.005));
    }

    /// Table 1's "DRAM bw per core" column, GB/s (paper-reported values).
    #[test]
    fn table1_dram_bw_per_core_matches_paper() {
        assert!(close(n1_skylake().dram_gbs_per_core(), 2.67, 0.01));
        assert!(close(n2d_milan().dram_gbs_per_core(), 1.83, 0.01));
        assert!(close(m6in_icelake().dram_gbs_per_core(), 3.20, 0.01));
        assert!(close(c3_sapphire_rapids().dram_gbs_per_core(), 3.49, 0.01));
        assert!(close(genoa().dram_gbs_per_core(), 2.40, 0.01));
        assert!(close(ipu_e2000().dram_gbs_per_core(), 6.40, 0.01));
        assert!(close(bluefield_v3().dram_gbs_per_core(), 5.60, 0.01));
    }

    /// §6: BlueField v3's DRAM bandwidth is only ~1.8× its NIC bandwidth —
    /// the paper's "cannot process at line rate" observation.
    #[test]
    fn bluefield_mem_to_nic_ratio() {
        let bf = bluefield_v3();
        let ratio = bf.dram_gbs() / bf.nic_gbs();
        assert!(close(ratio, 1.8, 0.05), "ratio={ratio}");
        // E2000 doesn't exhibit the limitation (ratio > 4).
        let e = ipu_e2000();
        assert!(e.dram_gbs() / e.nic_gbs() > 4.0);
    }

    #[test]
    fn smartnics_have_bandwidth_advantage() {
        // The paper's headline: NICs have ~10x NIC-bw/core and ~2-3x
        // DRAM-bw/core vs server hosts.
        let e = ipu_e2000();
        for p in table1_platforms() {
            if p.kind == Kind::Server {
                assert!(e.nic_gbs_per_core() > 7.0 * p.nic_gbs_per_core(), "{}", p.name);
                assert!(e.dram_gbs_per_core() > 1.8 * p.dram_gbs_per_core(), "{}", p.name);
            }
        }
    }

    #[test]
    fn core_counts() {
        assert_eq!(n2d_milan().cores(), 112);
        assert_eq!(skylake_fig3().cores(), 56);
        assert_eq!(ipu_e2000().cores(), 16);
    }

    #[test]
    fn paper_core_ratio_7_to_11x() {
        // §5.1: smart NICs have 7-11x fewer cores than traditional systems.
        let e = ipu_e2000().vcpus as f64;
        let lo = table1_platforms()
            .iter()
            .filter(|p| p.kind == Kind::Server)
            .map(|p| p.vcpus as f64 / e)
            .fold(f64::INFINITY, f64::min);
        let hi = table1_platforms()
            .iter()
            .filter(|p| p.kind == Kind::Server)
            .map(|p| p.vcpus as f64 / e)
            .fold(0.0, f64::max);
        assert!(lo >= 6.0 && hi <= 14.5, "lo={lo} hi={hi}");
    }
}

//! The analytics engine: TPC-H data generation, columnar storage,
//! vectorized operators, the unified plan/kernel layer ([`engine`]),
//! morsel-driven parallel execution, the Figure-3 query set, and
//! workload profiling.
//!
//! This is the substrate for §5.1/§5.2 of the paper: a real (if compact)
//! analytics execution engine whose measured per-query behaviour — bytes
//! touched, hash-table footprints, CPU seconds — feeds the
//! memory-bandwidth contention model ([`crate::memsim`]) and the
//! distributed shuffle workloads ([`crate::coordinator`]).
//!
//! Queries run three ways, all producing the same rows: single-threaded
//! ([`run_query`]), morsel-parallel on a local thread pool
//! ([`morsel::run_query_morsel`]), and distributed across a simulated
//! NIC cluster ([`crate::coordinator::DistributedQuery`]).
//!
//! ```
//! use lovelock::analytics::{morsel, run_query, TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 42));
//! let serial = run_query(&db, "q1").unwrap();
//! let parallel = morsel::run_query_morsel(&db, "q1", 2, 1024).unwrap();
//! assert!(parallel.approx_eq_rows(&serial.rows));
//! ```

pub mod chunkstore;
pub mod column;
pub mod engine;
pub mod morsel;
pub mod ops;
pub mod profile;
pub mod queries;
pub mod sql;
pub mod tpch;

pub use chunkstore::{ZoneMap, CHUNK_ROWS};
pub use column::{Column, Table};
pub use morsel::run_query_morsel;
pub use profile::{profile_query, QueryProfile};
pub use queries::{run_query, QueryOutput, QUERY_NAMES};
pub use tpch::{TpchConfig, TpchDb};

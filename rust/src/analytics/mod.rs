//! The analytics engine: TPC-H data generation, columnar storage,
//! vectorized operators, the Figure-3 query set, and workload profiling.
//!
//! This is the substrate for §5.1/§5.2 of the paper: a real (if compact)
//! analytics execution engine whose measured per-query behaviour — bytes
//! touched, hash-table footprints, CPU seconds — feeds the
//! memory-bandwidth contention model ([`crate::memsim`]) and the
//! distributed shuffle workloads ([`crate::coordinator`]).

pub mod column;
pub mod ops;
pub mod profile;
pub mod queries;
pub mod tpch;

pub use column::{Column, Table};
pub use profile::{profile_query, QueryProfile};
pub use queries::{run_query, QueryOutput, QUERY_NAMES};
pub use tpch::{TpchConfig, TpchDb};

//! Morsel-driven parallel query execution.
//!
//! The engine's columns are split into fixed-size **morsels** (contiguous
//! row ranges of `lineitem`, the probe side of every query). Each morsel
//! is aggregated independently by a per-query kernel into a [`Partial`] —
//! a mergeable grouped aggregate — and the partials are merged in morsel
//! order, so results are deterministic regardless of how threads were
//! scheduled. The same [`Partial`] is the wire unit of the distributed
//! executor ([`crate::coordinator::shuffle::DistributedQuery`]): a worker
//! is simply a larger morsel range whose merged partial crosses the
//! simulated fabric to the leader.
//!
//! Every query in [`super::queries`] provides a [`MorselPlan`]:
//!
//! * `prepare` — runs once per executor over the *broadcast* tables
//!   (dimension hash maps, dictionary lookups) and returns the morsel
//!   kernel, a closure over the borrowed columns;
//! * `finalize` — turns the merged partial into result rows (sorts,
//!   top-k, dimension lookups on the leader).
//!
//! ```
//! use lovelock::analytics::morsel::run_query_morsel;
//! use lovelock::analytics::{run_query, TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 7));
//! let serial = run_query(&db, "q6").unwrap();
//! let parallel = run_query_morsel(&db, "q6", 4, 512).unwrap();
//! assert!(parallel.approx_eq_rows(&serial.rows));
//! ```

use super::ops::{ExecStats, GroupBy};
use super::queries::{self, QueryOutput, Row};
use super::tpch::TpchDb;
use crate::error::Result;
use crate::exec::parallel_map_chunks;
use std::collections::HashMap;

/// Default rows per morsel — big enough to amortize kernel dispatch,
/// small enough that a scale-factor-0.1 `lineitem` yields dozens of
/// independently schedulable units.
pub const DEFAULT_MORSEL_ROWS: usize = 16_384;

/// A mergeable partial aggregate: a flat table of groups, each a key,
/// `width` f64 accumulators, and a row count. All per-query accumulators
/// are sums (averages, percentages, and top-k are computed at finalize),
/// so merging is pure addition and associative.
#[derive(Clone, Debug, Default)]
pub struct Partial {
    /// Accumulators per group.
    pub width: usize,
    pub keys: Vec<i64>,
    /// Row-major `[len × width]` accumulator block.
    pub accs: Vec<f64>,
    pub counts: Vec<u64>,
    /// Engine statistics for the rows this partial covered (not encoded
    /// on the wire — the leader accounts them host-side).
    pub stats: ExecStats,
}

impl Partial {
    pub fn new(width: usize) -> Self {
        Self { width, ..Default::default() }
    }

    /// Flatten a [`GroupBy`] into a partial.
    pub fn from_groupby<const W: usize>(g: &GroupBy<W>, stats: ExecStats) -> Self {
        let mut p = Self {
            width: W,
            keys: Vec::with_capacity(g.groups.len()),
            accs: Vec::with_capacity(g.groups.len() * W),
            counts: Vec::with_capacity(g.groups.len()),
            stats,
        };
        for (k, a, c) in &g.groups {
            p.keys.push(*k);
            p.accs.extend_from_slice(a);
            p.counts.push(*c);
        }
        p
    }

    /// A single-group partial (scalar aggregates like Q6/Q14/Q19).
    pub fn single(key: i64, accs: &[f64], count: u64, stats: ExecStats) -> Self {
        Self {
            width: accs.len(),
            keys: vec![key],
            accs: accs.to_vec(),
            counts: vec![count],
            stats,
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Accumulator slice of group `i`.
    pub fn acc(&self, i: usize) -> &[f64] {
        &self.accs[i * self.width..(i + 1) * self.width]
    }

    /// Wire size of one encoded group.
    fn group_bytes(width: usize) -> usize {
        8 + 8 * width + 8
    }

    /// Encode for the shuffle wire: `u32 width, u32 len`, then per group
    /// `i64 key, width × f64 accs, u64 count`, all little-endian.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.len() * Self::group_bytes(self.width));
        out.extend_from_slice(&(self.width as u32).to_le_bytes());
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        for i in 0..self.len() {
            out.extend_from_slice(&self.keys[i].to_le_bytes());
            for a in self.acc(i) {
                out.extend_from_slice(&a.to_le_bytes());
            }
            out.extend_from_slice(&self.counts[i].to_le_bytes());
        }
        out
    }

    /// Inverse of [`Partial::encode`]. The decoded partial carries empty
    /// [`ExecStats`].
    pub fn decode(buf: &[u8]) -> Result<Self> {
        crate::ensure!(buf.len() >= 8, "short partial frame: {} bytes", buf.len());
        let width = u32::from_le_bytes(buf[0..4].try_into()?) as usize;
        let len = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        crate::ensure!(width <= 64, "implausible partial width {width}");
        let gb = Self::group_bytes(width);
        crate::ensure!(
            buf.len() == 8 + len * gb,
            "bad partial length: header says {len} groups of {gb} B, frame has {} B",
            buf.len() - 8
        );
        let mut p = Self {
            width,
            keys: Vec::with_capacity(len),
            accs: Vec::with_capacity(len * width),
            counts: Vec::with_capacity(len),
            stats: ExecStats::default(),
        };
        for g in 0..len {
            let base = 8 + g * gb;
            p.keys.push(i64::from_le_bytes(buf[base..base + 8].try_into()?));
            for w in 0..width {
                let o = base + 8 + w * 8;
                p.accs.push(f64::from_le_bytes(buf[o..o + 8].try_into()?));
            }
            let o = base + 8 + width * 8;
            p.counts.push(u64::from_le_bytes(buf[o..o + 8].try_into()?));
        }
        Ok(p)
    }
}

/// Order-preserving partial merger: groups appear in first-seen order
/// across absorbed partials, accumulators and counts are summed.
pub struct Merger {
    width: usize,
    index: HashMap<i64, usize>,
    partial: Partial,
}

impl Merger {
    pub fn new(width: usize) -> Self {
        Self { width, index: HashMap::new(), partial: Partial::new(width) }
    }

    /// Merge one partial in (errors on accumulator-width mismatch).
    pub fn absorb(&mut self, p: &Partial) -> Result<()> {
        crate::ensure!(
            p.width == self.width,
            "partial width {} != merger width {}",
            p.width,
            self.width
        );
        self.partial.stats.merge(&p.stats);
        for gi in 0..p.len() {
            let key = p.keys[gi];
            let idx = match self.index.get(&key) {
                Some(&i) => i,
                None => {
                    let i = self.partial.keys.len();
                    self.index.insert(key, i);
                    self.partial.keys.push(key);
                    self.partial.accs.resize(self.partial.accs.len() + self.width, 0.0);
                    self.partial.counts.push(0);
                    i
                }
            };
            let base = idx * self.width;
            for (w, v) in p.acc(gi).iter().enumerate() {
                self.partial.accs[base + w] += v;
            }
            self.partial.counts[idx] += p.counts[gi];
        }
        Ok(())
    }

    /// Mutable access to the merged statistics (for folding in one-time
    /// prepare-phase stats).
    pub fn stats_mut(&mut self) -> &mut ExecStats {
        &mut self.partial.stats
    }

    pub fn into_partial(self) -> Partial {
        self.partial
    }
}

/// The morsel kernel for one query: aggregates lineitem rows `[lo, hi)`
/// into a [`Partial`]. Borrows the database columns for `'a`.
pub type PartialFn<'a> = Box<dyn Fn(usize, usize) -> Partial + Send + Sync + 'a>;

/// A query's morsel-parallel execution plan.
pub struct MorselPlan {
    /// Accumulator count per group.
    pub width: usize,
    /// Build broadcast-side state (dimension hash maps etc.) and return
    /// the morsel kernel plus the one-time statistics of that build.
    pub prepare: for<'a> fn(&'a TpchDb) -> (PartialFn<'a>, ExecStats),
    /// Merged partial → final result rows (leader-side).
    pub finalize: fn(&TpchDb, &Partial) -> Vec<Row>,
}

/// Look up the morsel plan for a query. Every query in
/// [`super::queries::QUERY_NAMES`] has one.
pub fn plan(name: &str) -> Option<MorselPlan> {
    match name {
        "q1" => Some(queries::q1::morsel_plan()),
        "q3" => Some(queries::q3::morsel_plan()),
        "q5" => Some(queries::q5::morsel_plan()),
        "q6" => Some(queries::q6::morsel_plan()),
        "q9" => Some(queries::q9::morsel_plan()),
        "q12" => Some(queries::q12::morsel_plan()),
        "q14" => Some(queries::q14::morsel_plan()),
        "q18" => Some(queries::q18::morsel_plan()),
        "q19" => Some(queries::q19::morsel_plan()),
        _ => None,
    }
}

/// Run a query morsel-parallel on `threads` threads (0 = all cores),
/// `morsel_rows` rows per morsel. Produces the same rows as
/// [`super::queries::run_query`] (floating-point sums associate
/// differently, within `approx_eq_rows` tolerance).
pub fn run_query_morsel(
    db: &TpchDb,
    name: &str,
    threads: usize,
    morsel_rows: usize,
) -> Option<QueryOutput> {
    let plan = plan(name)?;
    let (kernel, prep_stats) = (plan.prepare)(db);
    let partials =
        parallel_map_chunks(db.lineitem.len(), morsel_rows, threads, |lo, hi| kernel(lo, hi));
    let mut merger = Merger::new(plan.width);
    *merger.stats_mut() = prep_stats;
    let mut morsel_ht_peak = 0u64;
    for p in &partials {
        morsel_ht_peak = morsel_ht_peak.max(p.stats.ht_bytes);
        merger.absorb(p).expect("kernel produced mismatched partial width");
    }
    let mut merged = merger.into_partial();
    // The merge summed every transient per-morsel hash table into
    // ht_bytes; the *live* peak is the prepare-side tables plus one
    // morsel table plus the merged-group state. Keep ht_bytes at its
    // documented "live at once" meaning.
    let group_bytes = (8 + 8 * plan.width + 8) as u64;
    merged.stats.ht_bytes =
        prep_stats.ht_bytes + morsel_ht_peak + merged.len() as u64 * group_bytes;
    let rows = (plan.finalize)(db, &merged);
    Some(QueryOutput { rows, stats: merged.stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::{run_query, QUERY_NAMES};
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn codec_roundtrip() {
        let mut g: GroupBy<3> = GroupBy::with_capacity(4);
        g.update(7, [1.0, 2.0, 3.0]);
        g.update(-9, [4.0, 5.0, 6.0]);
        g.update(7, [0.5, 0.5, 0.5]);
        let p = Partial::from_groupby(&g, ExecStats::default());
        let dec = Partial::decode(&p.encode()).unwrap();
        assert_eq!(dec.width, 3);
        assert_eq!(dec.keys, p.keys);
        assert_eq!(dec.accs, p.accs);
        assert_eq!(dec.counts, p.counts);
    }

    #[test]
    fn decode_rejects_bad_frames() {
        assert!(Partial::decode(&[1, 2, 3]).is_err());
        let p = Partial::single(1, &[2.0], 1, ExecStats::default());
        let enc = p.encode();
        assert!(Partial::decode(&enc[..enc.len() - 1]).is_err());
        // Implausible width.
        let mut bad = enc.clone();
        bad[0] = 200;
        assert!(Partial::decode(&bad).is_err());
    }

    #[test]
    fn merger_sums_groups_in_first_seen_order() {
        let a = Partial::single(5, &[1.0, 10.0], 2, ExecStats::default());
        let b = Partial::single(9, &[3.0, 30.0], 1, ExecStats::default());
        let c = Partial::single(5, &[0.5, 5.0], 4, ExecStats::default());
        let mut m = Merger::new(2);
        for p in [&a, &b, &c] {
            m.absorb(p).unwrap();
        }
        let out = m.into_partial();
        assert_eq!(out.keys, vec![5, 9]);
        assert_eq!(out.acc(0), &[1.5, 15.0]);
        assert_eq!(out.acc(1), &[3.0, 30.0]);
        assert_eq!(out.counts, vec![6, 1]);
    }

    #[test]
    fn merger_rejects_width_mismatch() {
        let p = Partial::single(1, &[1.0], 1, ExecStats::default());
        let mut m = Merger::new(2);
        assert!(m.absorb(&p).is_err());
    }

    #[test]
    fn every_query_has_a_plan() {
        for q in QUERY_NAMES {
            assert!(plan(q).is_some(), "{q} has no morsel plan");
        }
        assert!(plan("q99").is_none());
    }

    #[test]
    fn all_queries_match_serial_reference() {
        // The tentpole invariant: the morsel-parallel path produces the
        // same rows as the single-threaded engine for every query.
        let db = TpchDb::generate(TpchConfig::new(0.01, 2024));
        for q in QUERY_NAMES {
            let serial = run_query(&db, q).unwrap();
            let par = run_query_morsel(&db, q, 4, 1000).unwrap();
            assert!(
                par.approx_eq_rows(&serial.rows),
                "{q}: morsel path ({} rows) diverged from serial ({} rows)",
                par.rows.len(),
                serial.rows.len()
            );
            assert!(par.stats.bytes_scanned > 0, "{q} reported no scan bytes");
        }
    }

    #[test]
    fn result_invariant_to_morsel_size_and_threads() {
        let db = TpchDb::generate(TpchConfig::new(0.005, 31));
        let reference = run_query_morsel(&db, "q1", 1, 257).unwrap();
        for (threads, rows) in [(2, 64), (4, 8192), (8, 1 << 20)] {
            let out = run_query_morsel(&db, "q1", threads, rows).unwrap();
            assert!(
                out.approx_eq_rows(&reference.rows),
                "q1 diverged at threads={threads} morsel_rows={rows}"
            );
        }
    }

    #[test]
    fn unknown_query_is_none() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 3));
        assert!(run_query_morsel(&db, "nope", 2, 64).is_none());
    }
}

//! Morsel-driven parallel query execution — the parallel driver over the
//! unified engine ([`crate::analytics::engine`]).
//!
//! The engine's columns are split into fixed-size **morsels** (contiguous
//! row ranges of `lineitem`, the probe side of every query). The shared
//! engine kernel evaluates each query's
//! [`crate::analytics::engine::LogicalPlan`] predicate per morsel, and
//! the surviving rows are aggregated over
//! balanced selection slices into [`Partial`]s — mergeable grouped
//! aggregates combined in slice order, so results are deterministic
//! regardless of how threads were scheduled. The same [`Partial`] is the
//! wire unit of the distributed executor
//! ([`crate::coordinator::shuffle::DistributedQuery`]): a worker is
//! simply a larger morsel range whose hash-partitioned partials cross
//! the simulated fabric.
//!
//! ```
//! use lovelock::analytics::morsel::run_query_morsel;
//! use lovelock::analytics::{run_query, TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 7));
//! let serial = run_query(&db, "q6").unwrap();
//! let parallel = run_query_morsel(&db, "q6", 4, 512).unwrap();
//! assert!(parallel.approx_eq_rows(&serial.rows));
//! ```

use super::engine;
use super::queries::QueryOutput;
use super::tpch::TpchDb;

pub use super::engine::partial::{Merger, Partial};

/// Default rows per morsel — big enough to amortize kernel dispatch,
/// small enough that a scale-factor-0.1 `lineitem` yields dozens of
/// independently schedulable units.
pub const DEFAULT_MORSEL_ROWS: usize = 16_384;

/// Run a query morsel-parallel on `threads` threads (0 = all cores),
/// `morsel_rows` rows per morsel. Produces the same rows as
/// [`super::queries::run_query`] (floating-point sums associate
/// differently, within `approx_eq_rows` tolerance).
pub fn run_query_morsel(
    db: &TpchDb,
    name: &str,
    threads: usize,
    morsel_rows: usize,
) -> Option<QueryOutput> {
    let spec = engine::spec(name)?;
    Some(engine::run_parallel(db, &spec, threads, morsel_rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::{run_query, QUERY_NAMES};
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn every_query_has_a_plan() {
        for q in QUERY_NAMES {
            assert!(engine::spec(q).is_some(), "{q} has no plan");
        }
        assert!(engine::spec("q99").is_none());
    }

    #[test]
    fn all_queries_match_serial_reference() {
        // The tentpole invariant: the morsel-parallel path produces the
        // same rows as the single-threaded engine for every query.
        let db = TpchDb::generate(TpchConfig::new(0.01, 2024));
        for q in QUERY_NAMES {
            let serial = run_query(&db, q).unwrap();
            let par = run_query_morsel(&db, q, 4, 1000).unwrap();
            assert!(
                par.approx_eq_rows(&serial.rows),
                "{q}: morsel path ({} rows) diverged from serial ({} rows)",
                par.rows.len(),
                serial.rows.len()
            );
            assert!(par.stats.bytes_scanned > 0, "{q} reported no scan bytes");
        }
    }

    #[test]
    fn result_invariant_to_morsel_size_and_threads() {
        let db = TpchDb::generate(TpchConfig::new(0.005, 31));
        let reference = run_query_morsel(&db, "q1", 1, 257).unwrap();
        for (threads, rows) in [(2, 64), (4, 8192), (8, 1 << 20)] {
            let out = run_query_morsel(&db, "q1", threads, rows).unwrap();
            assert!(
                out.approx_eq_rows(&reference.rows),
                "q1 diverged at threads={threads} morsel_rows={rows}"
            );
        }
    }

    #[test]
    fn unknown_query_is_none() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 3));
        assert!(run_query_morsel(&db, "nope", 2, 64).is_none());
    }
}

//! Hash aggregation into mergeable [`Partial`]s — the group-by kernel
//! every query shares.
//!
//! [`HashAgg`] is an open-addressing table over `i64` keys with a
//! *runtime* accumulator width, accumulating directly into the flat
//! layout of [`Partial`] (groups in first-seen order), so a finished
//! aggregation is already in wire/merge form: `into_partial` is a move,
//! not a conversion. It replaces the old const-generic `ops::GroupBy`,
//! whose per-width monomorphizations the serial, morsel, and distributed
//! paths each wrapped differently.
//!
//! The hot entry point is the batched [`HashAgg::update_sel`]: one pass
//! resolves the group index of every selected row into a caller-reused
//! `gids` scratch (with a last-key memo — TPC-H keys arrive clustered,
//! so consecutive rows usually share a group), then each accumulator
//! column is gathered in its own tight loop over `gids`. Compared to the
//! row-at-a-time [`HashAgg::update`], that kills the per-row slice zip
//! and its bounds checks and leaves loops the optimizer can vectorize.

use super::expr::Sel;
use super::hash64;
use super::partial::Partial;

/// Grouped aggregation over i64 keys with `width` f64 accumulators per
/// group plus a count. Groups come out in insertion order.
pub struct HashAgg {
    width: usize,
    mask: usize,
    /// slot → group index + 1; 0 = empty.
    slots: Vec<u32>,
    /// Key per slot (valid where `slots` is non-zero).
    keys: Vec<i64>,
    partial: Partial,
}

impl HashAgg {
    /// A table expecting about `n` distinct groups of `width`
    /// accumulators (it grows past `n` transparently).
    pub fn with_capacity(width: usize, n: usize) -> Self {
        let cap = (n.max(16) * 2).next_power_of_two();
        Self {
            width,
            mask: cap - 1,
            slots: vec![0; cap],
            keys: vec![0; cap],
            partial: Partial::new(width),
        }
    }

    /// Fold one row into its group: accumulators += `values`, count += 1.
    #[inline]
    pub fn update(&mut self, key: i64, values: &[f64]) {
        debug_assert_eq!(values.len(), self.width);
        let gi = self.group_index(key);
        let base = gi * self.width;
        for (acc, v) in self.partial.accs[base..base + self.width].iter_mut().zip(values) {
            *acc += v;
        }
        self.partial.counts[gi] += 1;
    }

    /// Batched fold over a selection: `sel` names the indices into
    /// `keys` and each of the `cols` to fold (the compacted output of a
    /// batch evaluator uses `Sel::Range(0, n)`; a direct gather from
    /// full-length columns passes the surviving row ids). Pass exactly
    /// `width` columns. `gids` is caller scratch, reused across morsels —
    /// in steady state (no new groups, scratch at high-water capacity)
    /// this path performs zero allocations.
    pub fn update_sel(&mut self, keys: &[i64], sel: Sel<'_>, cols: &[&[f64]], gids: &mut Vec<u32>) {
        assert_eq!(cols.len(), self.width, "update_sel needs one column per accumulator");
        // Pass 1: resolve group indices, memoizing the previous key —
        // clustered keys (Q18's order keys, Q6/Q14/Q19's single group)
        // skip the probe entirely on repeat hits.
        gids.clear();
        gids.reserve(sel.len());
        let mut last_key = 0i64;
        let mut last_gid = u32::MAX;
        sel.for_each(|r| {
            let k = keys[r];
            if last_gid == u32::MAX || k != last_key {
                last_gid = self.group_index(k) as u32;
                last_key = k;
            }
            gids.push(last_gid);
        });
        // Pass 2: one tight gather loop per accumulator column.
        let w = self.width;
        for (c, col) in cols.iter().enumerate() {
            let accs = &mut self.partial.accs;
            match sel {
                Sel::Range(lo, hi) => {
                    for (&g, &v) in gids.iter().zip(&col[lo..hi]) {
                        accs[g as usize * w + c] += v;
                    }
                }
                Sel::Ids(ids) => {
                    for (&g, &i) in gids.iter().zip(ids) {
                        accs[g as usize * w + c] += col[i as usize];
                    }
                }
            }
        }
        // Pass 3: counts.
        let counts = &mut self.partial.counts;
        for &g in gids.iter() {
            counts[g as usize] += 1;
        }
    }

    /// Index of the group for `key`, creating it if new.
    #[inline]
    pub fn group_index(&mut self, key: i64) -> usize {
        if (self.partial.len() + 1) * 2 > self.slots.len() {
            self.grow();
        }
        let mut slot = (hash64(key) as usize) & self.mask;
        loop {
            let s = self.slots[slot];
            if s == 0 {
                self.keys[slot] = key;
                self.partial.keys.push(key);
                let new_len = self.partial.accs.len() + self.width;
                self.partial.accs.resize(new_len, 0.0);
                self.partial.counts.push(0);
                self.slots[slot] = self.partial.len() as u32;
                return self.partial.len() - 1;
            }
            if self.keys[slot] == key {
                return (s - 1) as usize;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        let cap = self.slots.len() * 2;
        self.mask = cap - 1;
        // lint: allow(hot-path-alloc) rehash is amortized to zero in steady state; alloc_regression gates the bench path
        self.slots = vec![0; cap];
        // lint: allow(hot-path-alloc) same amortized rehash — fresh table sized to the doubled capacity
        let mut keys = vec![0i64; cap];
        for (gi, &k) in self.partial.keys.iter().enumerate() {
            let mut slot = (hash64(k) as usize) & self.mask;
            while self.slots[slot] != 0 {
                slot = (slot + 1) & self.mask;
            }
            self.slots[slot] = gi as u32 + 1;
            keys[slot] = k;
        }
        self.keys = keys;
    }

    /// Number of groups so far.
    pub fn len(&self) -> usize {
        self.partial.len()
    }

    pub fn is_empty(&self) -> bool {
        self.partial.is_empty()
    }

    /// Byte footprint: slots + slot keys + group state (for ExecStats).
    pub fn bytes(&self) -> u64 {
        (self.slots.len() * 4
            + self.keys.len() * 8
            + self.partial.len() * Partial::group_bytes(self.width)) as u64
    }

    /// Finish: the accumulated groups as a mergeable [`Partial`]
    /// (carrying default stats — the caller attaches its own).
    pub fn into_partial(self) -> Partial {
        self.partial
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_and_counts() {
        let mut g = HashAgg::with_capacity(2, 4);
        g.update(7, &[1.0, 10.0]);
        g.update(8, &[2.0, 20.0]);
        g.update(7, &[3.0, 30.0]);
        assert_eq!(g.len(), 2);
        let p = g.into_partial();
        assert_eq!(p.keys, vec![7, 8]);
        assert_eq!(p.acc(0), &[4.0, 40.0]);
        assert_eq!(p.acc(1), &[2.0, 20.0]);
        assert_eq!(p.counts, vec![2, 1]);
    }

    #[test]
    fn grows_past_capacity() {
        let mut g = HashAgg::with_capacity(1, 2);
        for k in 0..10_000i64 {
            g.update(k % 997, &[1.0]);
        }
        assert_eq!(g.len(), 997);
        assert!(g.bytes() > 0);
        let p = g.into_partial();
        let total: f64 = p.accs.iter().sum();
        assert_eq!(total, 10_000.0);
        let count: u64 = p.counts.iter().sum();
        assert_eq!(count, 10_000);
    }

    #[test]
    fn insertion_order_preserved() {
        let mut g = HashAgg::with_capacity(1, 4);
        for k in [5i64, 3, 5, 9, 3] {
            g.update(k, &[1.0]);
        }
        assert_eq!(g.into_partial().keys, vec![5, 3, 9]);
    }

    #[test]
    fn empty_agg_yields_empty_partial() {
        let g = HashAgg::with_capacity(3, 0);
        assert!(g.is_empty());
        let p = g.into_partial();
        assert!(p.is_empty());
        assert_eq!(p.width, 3);
    }

    #[test]
    fn negative_and_extreme_keys() {
        let mut g = HashAgg::with_capacity(1, 4);
        for k in [-1i64, i64::MIN, i64::MAX, -1] {
            g.update(k, &[1.0]);
        }
        assert_eq!(g.len(), 3);
        let p = g.into_partial();
        assert_eq!(p.counts[0], 2);
    }

    #[test]
    fn update_sel_matches_row_at_a_time() {
        // Clustered keys (runs of repeats) exercise the last-key memo.
        let keys: Vec<i64> = (0..1000).map(|i| (i / 7) % 23).collect();
        let c0: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let c1: Vec<f64> = (0..1000).map(|i| (i % 5) as f64).collect();

        let mut rows = HashAgg::with_capacity(2, 23);
        for i in 0..keys.len() {
            rows.update(keys[i], &[c0[i], c1[i]]);
        }
        let want = rows.into_partial();

        let mut batched = HashAgg::with_capacity(2, 23);
        let mut gids = Vec::new();
        // Two morsels through the dense form, reusing the gids scratch.
        batched.update_sel(&keys[..500], Sel::Range(0, 500), &[&c0[..500], &c1[..500]], &mut gids);
        batched.update_sel(&keys[500..], Sel::Range(0, 500), &[&c0[500..], &c1[500..]], &mut gids);
        let got = batched.into_partial();
        assert_eq!(got.keys, want.keys);
        assert_eq!(got.accs, want.accs);
        assert_eq!(got.counts, want.counts);
    }

    #[test]
    fn update_sel_ids_gathers_full_columns() {
        // The Ids form gathers from full-length columns by row id.
        let keys = vec![9i64, 7, 9, 7, 9];
        let col = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut g = HashAgg::with_capacity(1, 4);
        let mut gids = Vec::new();
        g.update_sel(&keys, Sel::Ids(&[0, 2, 3]), &[&col], &mut gids);
        assert_eq!(gids, vec![0, 0, 1]);
        let p = g.into_partial();
        assert_eq!(p.keys, vec![9, 7]);
        assert_eq!(p.acc(0), &[1.0 + 3.0]);
        assert_eq!(p.acc(1), &[4.0]);
        assert_eq!(p.counts, vec![2, 1]);
    }

    #[test]
    fn update_sel_empty_selection_is_noop() {
        let mut g = HashAgg::with_capacity(1, 4);
        let mut gids = vec![99];
        let empty: &[f64] = &[];
        g.update_sel(&[], Sel::Range(0, 0), &[empty], &mut gids);
        g.update_sel(&[1, 2], Sel::Ids(&[]), &[&[0.0, 0.0][..]], &mut gids);
        assert!(g.is_empty());
        assert!(gids.is_empty(), "scratch must be cleared even on empty input");
    }
}

//! Predicate expressions over lineitem columns.
//!
//! A [`Predicate`] is a small expression tree the query plans build once
//! at compile time and the shared kernel evaluates per morsel into a
//! *selection vector* (`Vec<u32>` of surviving row ids). Conjunctions
//! evaluate left to right: the first conjunct scans the raw row range,
//! every later conjunct narrows the previous selection — exactly the
//! cascading-filter shape the hand-written query paths used to spell out
//! per query, with per-conjunct [`ExecStats`] accounting (each leaf
//! charges its column bytes on the rows it actually examined).

use crate::analytics::column::Column;
use crate::analytics::ops::{filter_f64_lt, filter_f64_range, filter_i32_range, ExecStats};

/// A predicate over lineitem rows, evaluated vectorized into selection
/// vectors. Leaves borrow the columns they test for `'a`.
pub enum Predicate<'a> {
    /// Every row passes (pure-scan queries: Q5, Q9, Q18).
    True,
    /// `lo <= col[i] < hi` over an i32 column (date windows).
    I32Range { col: &'a [i32], lo: i32, hi: i32 },
    /// `a[i] < b[i]` between two i32 columns (Q12 date consistency).
    I32ColLt { a: &'a [i32], b: &'a [i32] },
    /// `lo <= col[i] < hi` over an f64 column (discount bands).
    F64Range { col: &'a [f64], lo: f64, hi: f64 },
    /// `col[i] < x` over an f64 column (quantity caps).
    F64Lt { col: &'a [f64], x: f64 },
    /// `ok[codes[i]]` over a dictionary-encoded column: the per-code
    /// boolean is precomputed from the dictionary (IN-lists, equality).
    CodeSet { codes: &'a [u32], ok: Vec<bool> },
    /// Conjunction, evaluated left to right.
    And(Vec<Predicate<'a>>),
}

impl<'a> Predicate<'a> {
    pub fn i32_range(col: &'a [i32], lo: i32, hi: i32) -> Self {
        Predicate::I32Range { col, lo, hi }
    }

    /// `a[i] < b[i]`.
    pub fn i32_col_lt(a: &'a [i32], b: &'a [i32]) -> Self {
        Predicate::I32ColLt { a, b }
    }

    pub fn f64_range(col: &'a [f64], lo: f64, hi: f64) -> Self {
        Predicate::F64Range { col, lo, hi }
    }

    pub fn f64_lt(col: &'a [f64], x: f64) -> Self {
        Predicate::F64Lt { col, x }
    }

    /// Rows whose dictionary-encoded value satisfies `f` — the string
    /// test runs once per dictionary entry, not once per row.
    pub fn code_matches<F: Fn(&str) -> bool>(col: &'a Column, f: F) -> Self {
        let (dict, codes) = col.as_str_codes();
        Predicate::CodeSet { codes, ok: dict.iter().map(|s| f(s)).collect() }
    }

    pub fn and(preds: Vec<Predicate<'a>>) -> Self {
        Predicate::And(preds)
    }

    /// Column bytes per examined row a leaf charges to [`ExecStats`].
    fn leaf_bytes(&self) -> usize {
        match self {
            Predicate::True | Predicate::And(_) => 0,
            Predicate::I32Range { .. } | Predicate::CodeSet { .. } => 4,
            Predicate::I32ColLt { .. } => 8,
            Predicate::F64Range { .. } | Predicate::F64Lt { .. } => 8,
        }
    }

    /// Evaluate over the raw row range `[lo, hi)`, producing the ids of
    /// surviving rows in row order and charging per-conjunct scan stats.
    pub fn eval(&self, lo: usize, hi: usize, stats: &mut ExecStats) -> Vec<u32> {
        match self {
            Predicate::True => (lo as u32..hi as u32).collect(),
            Predicate::And(ps) => {
                let mut sel: Option<Vec<u32>> = None;
                for p in ps {
                    sel = Some(match sel {
                        None => p.eval(lo, hi, stats),
                        Some(s) => p.filter(&s, stats),
                    });
                }
                sel.unwrap_or_else(|| (lo as u32..hi as u32).collect())
            }
            leaf => {
                stats.scan(hi - lo, leaf.leaf_bytes());
                let mut out = Vec::with_capacity(hi - lo);
                match leaf {
                    Predicate::I32Range { col, lo: a, hi: b } => {
                        for i in lo..hi {
                            let v = col[i];
                            if v >= *a && v < *b {
                                out.push(i as u32);
                            }
                        }
                    }
                    Predicate::I32ColLt { a, b } => {
                        for i in lo..hi {
                            if a[i] < b[i] {
                                out.push(i as u32);
                            }
                        }
                    }
                    Predicate::F64Range { col, lo: a, hi: b } => {
                        for i in lo..hi {
                            let v = col[i];
                            if v >= *a && v < *b {
                                out.push(i as u32);
                            }
                        }
                    }
                    Predicate::F64Lt { col, x } => {
                        for i in lo..hi {
                            if col[i] < *x {
                                out.push(i as u32);
                            }
                        }
                    }
                    Predicate::CodeSet { codes, ok } => {
                        for i in lo..hi {
                            if ok[codes[i] as usize] {
                                out.push(i as u32);
                            }
                        }
                    }
                    Predicate::True | Predicate::And(_) => unreachable!(),
                }
                out
            }
        }
    }

    /// Narrow an existing selection vector (the cascaded-conjunct path),
    /// charging this predicate's bytes on the examined rows.
    pub fn filter(&self, sel: &[u32], stats: &mut ExecStats) -> Vec<u32> {
        match self {
            Predicate::True => sel.to_vec(),
            Predicate::And(ps) => {
                let mut cur = sel.to_vec();
                for p in ps {
                    cur = p.filter(&cur, stats);
                }
                cur
            }
            leaf => {
                stats.scan(sel.len(), leaf.leaf_bytes());
                match leaf {
                    Predicate::I32Range { col, lo, hi } => filter_i32_range(sel, col, *lo, *hi),
                    Predicate::I32ColLt { a, b } => sel
                        .iter()
                        .copied()
                        .filter(|&i| a[i as usize] < b[i as usize])
                        .collect(),
                    Predicate::F64Range { col, lo, hi } => filter_f64_range(sel, col, *lo, *hi),
                    Predicate::F64Lt { col, x } => filter_f64_lt(sel, col, *x),
                    Predicate::CodeSet { codes, ok } => {
                        let mut out = Vec::with_capacity(sel.len());
                        for &i in sel {
                            if ok[codes[i as usize] as usize] {
                                out.push(i);
                            }
                        }
                        out
                    }
                    Predicate::True | Predicate::And(_) => unreachable!(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_leaf_selects() {
        let col = vec![10, 25, 30, 15, 40];
        let p = Predicate::i32_range(&col, 15, 31);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 5, &mut st), vec![1, 2, 3]);
        // 5 rows × 4 B charged.
        assert_eq!(st.bytes_scanned, 20);
        assert_eq!(st.rows_in, 5);
    }

    #[test]
    fn conjunction_cascades_and_charges_per_conjunct() {
        let dates = vec![5, 15, 25, 35];
        let vals = vec![1.0, 2.0, 3.0, 4.0];
        let p = Predicate::and(vec![
            Predicate::i32_range(&dates, 10, 40), // rows 1,2,3
            Predicate::f64_lt(&vals, 3.5),        // rows 1,2
        ]);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 4, &mut st), vec![1, 2]);
        // First conjunct: 4 rows × 4 B; second: 3 rows × 8 B.
        assert_eq!(st.bytes_scanned, 16 + 24);
    }

    #[test]
    fn code_set_from_dictionary() {
        use crate::analytics::column::StrColumnBuilder;
        let mut b = StrColumnBuilder::new();
        for s in ["MAIL", "AIR", "SHIP", "MAIL", "RAIL"] {
            b.push(s);
        }
        let col = b.finish();
        let p = Predicate::code_matches(&col, |s| s == "MAIL" || s == "SHIP");
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 5, &mut st), vec![0, 2, 3]);
    }

    #[test]
    fn col_lt_col() {
        let a = vec![1, 5, 3];
        let b = vec![2, 4, 3];
        let p = Predicate::i32_col_lt(&a, &b);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 3, &mut st), vec![0]);
    }

    #[test]
    fn selection_edge_empty_range() {
        let col = vec![1, 2, 3];
        let p = Predicate::i32_range(&col, 0, 10);
        let mut st = ExecStats::default();
        assert!(p.eval(1, 1, &mut st).is_empty());
        assert!(Predicate::True.eval(2, 2, &mut st).is_empty());
        assert!(p.filter(&[], &mut st).is_empty());
    }

    #[test]
    fn selection_edge_all_pass() {
        let col = vec![1, 2, 3, 4];
        let p = Predicate::i32_range(&col, i32::MIN, i32::MAX);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 4, &mut st), vec![0, 1, 2, 3]);
        assert_eq!(Predicate::True.eval(0, 4, &mut st), vec![0, 1, 2, 3]);
    }

    #[test]
    fn selection_edge_single_row() {
        let col = vec![7.0];
        let hit = Predicate::f64_lt(&col, 8.0);
        let miss = Predicate::f64_lt(&col, 7.0);
        let mut st = ExecStats::default();
        assert_eq!(hit.eval(0, 1, &mut st), vec![0]);
        assert!(miss.eval(0, 1, &mut st).is_empty());
        // Sub-range of a larger column: only row 2 examined.
        let col3 = vec![1.0, 2.0, 3.0];
        let p = Predicate::f64_lt(&col3, 10.0);
        assert_eq!(p.eval(2, 3, &mut st), vec![2]);
    }

    #[test]
    fn empty_and_passes_everything() {
        let mut st = ExecStats::default();
        assert_eq!(Predicate::and(vec![]).eval(0, 3, &mut st), vec![0, 1, 2]);
    }

    #[test]
    fn filter_narrows_existing_selection() {
        let col = vec![1, 2, 3, 4, 5];
        let p = Predicate::i32_range(&col, 2, 5);
        let mut st = ExecStats::default();
        assert_eq!(p.filter(&[0, 2, 4], &mut st), vec![2]);
        assert_eq!(st.rows_in, 3);
    }
}

//! Predicate expressions over lineitem columns.
//!
//! A [`Predicate`] is a small expression tree the query plans build once
//! at compile time and the shared kernel evaluates per morsel into a
//! *selection* ([`Sel`]). Conjunctions evaluate left to right: the first
//! conjunct scans the raw row range, every later conjunct narrows the
//! previous selection — exactly the cascading-filter shape the
//! hand-written query paths used to spell out per query, with
//! per-conjunct [`ExecStats`] accounting (each leaf charges its column
//! bytes on the rows it actually examined).
//!
//! The hot entry point is [`Predicate::eval_into`]: it writes into the
//! caller's reusable [`SelScratch`] ping-pong buffers (zero allocations
//! in steady state), the leaves run branchless
//! ([`crate::analytics::ops::select_into`] /
//! [`crate::analytics::ops::refine_into`]), and an all-pass predicate
//! ([`Predicate::True`], empty conjunction) returns [`Sel::Range`]
//! without materializing a single row id — on *every* execution path,
//! serial, morsel, and distributed alike.

use crate::analytics::chunkstore::{ColZones, Zone};
use crate::analytics::column::Column;
use crate::analytics::ops::{self, ExecStats};

/// A set of surviving row ids: either a dense range (the all-pass fast
/// path — nothing materialized) or explicit ids in a scratch buffer.
#[derive(Clone, Copy, Debug)]
pub enum Sel<'a> {
    /// Every row in `[lo, hi)` passes.
    Range(usize, usize),
    /// Explicit surviving row ids, ascending.
    Ids(&'a [u32]),
}

impl<'a> Sel<'a> {
    /// Number of selected rows.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Sel::Range(lo, hi) => hi - lo,
            Sel::Ids(ids) => ids.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every selected row id, in order.
    #[inline]
    pub fn for_each<F: FnMut(usize)>(self, mut f: F) {
        match self {
            Sel::Range(lo, hi) => {
                for i in lo..hi {
                    f(i);
                }
            }
            Sel::Ids(ids) => {
                for &i in ids {
                    f(i as usize);
                }
            }
        }
    }

    /// Materialize an owned id vector (drivers off the hot path, tests).
    pub fn to_vec(self) -> Vec<u32> {
        match self {
            Sel::Range(lo, hi) => (lo as u32..hi as u32).collect(),
            Sel::Ids(ids) => ids.to_vec(),
        }
    }
}

/// Reusable ping-pong selection buffers for predicate cascades: the
/// first conjunct writes buffer `a`, every later conjunct narrows into
/// the other buffer and the roles swap. Buffers are held at their
/// high-water length (never truncated), so a task that evaluates
/// same-sized morsels forever allocates on the first morsel only and
/// never re-zeroes grown regions.
#[derive(Default)]
pub struct SelScratch {
    a: Vec<u32>,
    b: Vec<u32>,
}

impl SelScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently held (both buffers) — capacity telemetry.
    pub fn bytes(&self) -> usize {
        (self.a.capacity() + self.b.capacity()) * 4
    }

    fn ensure(buf: &mut Vec<u32>, n: usize) {
        if buf.len() < n {
            buf.resize(n, 0);
        }
    }

    /// Source slice + destination buffer for one narrowing step, with
    /// the destination grown to `need` first.
    fn pair(&mut self, src_is_a: bool, need: usize) -> (&[u32], &mut [u32]) {
        if src_is_a {
            Self::ensure(&mut self.b, need);
        } else {
            Self::ensure(&mut self.a, need);
        }
        let Self { a, b } = self;
        if src_is_a {
            (a.as_slice(), b.as_mut_slice())
        } else {
            (b.as_slice(), a.as_mut_slice())
        }
    }
}

/// A predicate over lineitem rows, evaluated vectorized into selections.
/// Leaves borrow the columns they test for `'a`.
pub enum Predicate<'a> {
    /// Every row passes (pure-scan queries: Q5, Q9, Q18).
    True,
    /// `lo <= col[i] < hi` over an i32 column (date windows).
    I32Range { col: &'a [i32], lo: i32, hi: i32 },
    /// `a[i] < b[i]` between two i32 columns (Q12 date consistency).
    I32ColLt { a: &'a [i32], b: &'a [i32] },
    /// `col[i] ∈ values` over an i32 column; `values` is sorted and
    /// deduplicated so each row costs one binary search (IN-lists over
    /// dates and small int domains).
    I32InSet { col: &'a [i32], values: Vec<i32> },
    /// `lo <= col[i] < hi` over an f64 column (discount bands).
    F64Range { col: &'a [f64], lo: f64, hi: f64 },
    /// `col[i] < x` over an f64 column (quantity caps).
    F64Lt { col: &'a [f64], x: f64 },
    /// `ok[codes[i]]` over a dictionary-encoded column: the per-code
    /// boolean is precomputed from the dictionary (IN-lists, equality).
    CodeSet { codes: &'a [u32], ok: Vec<bool> },
    /// Conjunction, evaluated left to right.
    And(Vec<Predicate<'a>>),
}

impl<'a> Predicate<'a> {
    pub fn i32_range(col: &'a [i32], lo: i32, hi: i32) -> Self {
        Predicate::I32Range { col, lo, hi }
    }

    /// `a[i] < b[i]`.
    pub fn i32_col_lt(a: &'a [i32], b: &'a [i32]) -> Self {
        Predicate::I32ColLt { a, b }
    }

    /// `col[i] ∈ values` — the set is sorted and deduplicated here so
    /// the per-row test is a binary search.
    pub fn i32_in_set(col: &'a [i32], mut values: Vec<i32>) -> Self {
        values.sort_unstable();
        values.dedup();
        Predicate::I32InSet { col, values }
    }

    pub fn f64_range(col: &'a [f64], lo: f64, hi: f64) -> Self {
        Predicate::F64Range { col, lo, hi }
    }

    pub fn f64_lt(col: &'a [f64], x: f64) -> Self {
        Predicate::F64Lt { col, x }
    }

    /// Rows whose dictionary-encoded value satisfies `f` — the string
    /// test runs once per dictionary entry, not once per row.
    pub fn code_matches<F: Fn(&str) -> bool>(col: &'a Column, f: F) -> Self {
        let (dict, codes) = col.as_str_codes();
        Predicate::CodeSet { codes, ok: dict.iter().map(|s| f(s)).collect() }
    }

    pub fn and(preds: Vec<Predicate<'a>>) -> Self {
        Predicate::And(preds)
    }

    /// True iff no row can be rejected (the dense-range fast path).
    pub fn is_all_pass(&self) -> bool {
        match self {
            Predicate::True => true,
            Predicate::And(ps) => ps.iter().all(|p| p.is_all_pass()),
            _ => false,
        }
    }

    /// Column bytes per examined row a leaf charges to [`ExecStats`].
    fn leaf_bytes(&self) -> usize {
        match self {
            Predicate::True | Predicate::And(_) => 0,
            Predicate::I32Range { .. } | Predicate::I32InSet { .. } | Predicate::CodeSet { .. } => {
                4
            }
            Predicate::I32ColLt { .. } => 8,
            Predicate::F64Range { .. } | Predicate::F64Lt { .. } => 8,
        }
    }

    /// Branchless dense-range evaluation of a leaf into `out[..hi - lo]`;
    /// ids are absolute. Returns the survivor count.
    fn select_range(&self, lo: usize, hi: usize, out: &mut [u32]) -> usize {
        match self {
            Predicate::I32Range { col, lo: a, hi: b } => ops::select_into(lo, hi, out, |i| {
                let v = col[i];
                v >= *a && v < *b
            }),
            Predicate::I32ColLt { a, b } => ops::select_into(lo, hi, out, |i| a[i] < b[i]),
            Predicate::I32InSet { col, values } => {
                ops::select_into(lo, hi, out, |i| values.binary_search(&col[i]).is_ok())
            }
            Predicate::F64Range { col, lo: a, hi: b } => ops::select_into(lo, hi, out, |i| {
                let v = col[i];
                v >= *a && v < *b
            }),
            Predicate::F64Lt { col, x } => ops::select_into(lo, hi, out, |i| col[i] < *x),
            Predicate::CodeSet { codes, ok } => {
                ops::select_into(lo, hi, out, |i| ok[codes[i] as usize])
            }
            Predicate::True | Predicate::And(_) => unreachable!("not a leaf"),
        }
    }

    /// Branchless narrowing of `sel` into `out[..sel.len()]`.
    fn refine(&self, sel: &[u32], out: &mut [u32]) -> usize {
        match self {
            Predicate::I32Range { col, lo: a, hi: b } => ops::refine_into(sel, out, |i| {
                let v = col[i];
                v >= *a && v < *b
            }),
            Predicate::I32ColLt { a, b } => ops::refine_into(sel, out, |i| a[i] < b[i]),
            Predicate::I32InSet { col, values } => {
                ops::refine_into(sel, out, |i| values.binary_search(&col[i]).is_ok())
            }
            Predicate::F64Range { col, lo: a, hi: b } => ops::refine_into(sel, out, |i| {
                let v = col[i];
                v >= *a && v < *b
            }),
            Predicate::F64Lt { col, x } => ops::refine_into(sel, out, |i| col[i] < *x),
            Predicate::CodeSet { codes, ok } => {
                ops::refine_into(sel, out, |i| ok[codes[i] as usize])
            }
            Predicate::True | Predicate::And(_) => unreachable!("not a leaf"),
        }
    }

    /// Evaluate over the raw row range `[lo, hi)` into the caller's
    /// ping-pong scratch, producing surviving rows in row order and
    /// charging per-conjunct scan stats. All-pass predicates return
    /// [`Sel::Range`] — no ids are materialized on any path. Allocates
    /// only while the scratch grows to its high-water morsel size.
    pub fn eval_into<'s>(
        &self,
        lo: usize,
        hi: usize,
        scr: &'s mut SelScratch,
        stats: &mut ExecStats,
    ) -> Sel<'s> {
        let mut cur: Option<(bool, usize)> = None; // (selection is in `a`, live length)
        self.apply_into(lo, hi, scr, &mut cur, stats);
        match cur {
            None => Sel::Range(lo, hi),
            Some((in_a, n)) => Sel::Ids(if in_a { &scr.a[..n] } else { &scr.b[..n] }),
        }
    }

    /// One cascade step: leaves evaluate (dense) or narrow (ping-pong);
    /// conjunctions recurse; `True` is a no-op.
    fn apply_into(
        &self,
        lo: usize,
        hi: usize,
        scr: &mut SelScratch,
        cur: &mut Option<(bool, usize)>,
        stats: &mut ExecStats,
    ) {
        match self {
            Predicate::True => {}
            Predicate::And(ps) => {
                for p in ps {
                    p.apply_into(lo, hi, scr, cur, stats);
                }
            }
            leaf => match *cur {
                None => {
                    stats.scan(hi - lo, leaf.leaf_bytes());
                    SelScratch::ensure(&mut scr.a, hi - lo);
                    let k = leaf.select_range(lo, hi, &mut scr.a);
                    *cur = Some((true, k));
                }
                Some((in_a, n)) => {
                    stats.scan(n, leaf.leaf_bytes());
                    let (src, dst) = scr.pair(in_a, n);
                    let k = leaf.refine(&src[..n], dst);
                    *cur = Some((!in_a, k));
                }
            },
        }
    }

    /// Evaluate over `[lo, hi)` into a fresh vector — the allocating
    /// convenience form of [`Predicate::eval_into`] (tests, one-shot
    /// callers off the hot path).
    pub fn eval(&self, lo: usize, hi: usize, stats: &mut ExecStats) -> Vec<u32> {
        let mut scr = SelScratch::new();
        self.eval_into(lo, hi, &mut scr, stats).to_vec()
    }

    /// Narrow an existing selection vector (the cascaded-conjunct path),
    /// charging this predicate's bytes on the examined rows.
    pub fn filter(&self, sel: &[u32], stats: &mut ExecStats) -> Vec<u32> {
        match self {
            Predicate::True => sel.to_vec(),
            Predicate::And(ps) => {
                let mut cur = sel.to_vec();
                for p in ps {
                    cur = p.filter(&cur, stats);
                }
                cur
            }
            leaf => {
                stats.scan(sel.len(), leaf.leaf_bytes());
                let mut out = vec![0u32; sel.len()];
                let n = leaf.refine(sel, &mut out);
                out.truncate(n);
                out
            }
        }
    }
}

// ---------------------------------------------------------- zone pruning

/// Borrowed per-chunk zones of one scan column.
enum ZoneCol<'a> {
    I32(&'a [Zone<i32>]),
    I64(&'a [Zone<i64>]),
    F64(&'a [Zone<f64>]),
}

/// One zone-map consultation: a scan column's per-chunk min-max zones
/// plus the closed interval `[lo, hi]` the predicate tree admits for
/// that column (±∞ for one-sided constraints). Bounds are `f64`; i32
/// zone values convert losslessly.
pub struct PruneCheck<'a> {
    zones: ZoneCol<'a>,
    lo: f64,
    hi: f64,
}

impl<'a> PruneCheck<'a> {
    pub fn new(zones: &'a ColZones, lo: f64, hi: f64) -> Self {
        let zones = match zones {
            ColZones::I32(v) => ZoneCol::I32(v),
            ColZones::I64(v) => ZoneCol::I64(v),
            ColZones::F64(v) => ZoneCol::F64(v),
        };
        Self { zones, lo, hi }
    }

    /// Could chunk `ci` hold a value inside `[lo, hi]`? A chunk index
    /// past the zone slice answers yes (conservative), and so does any
    /// NaN bound (comparisons with NaN are false).
    #[inline]
    fn may_contain(&self, ci: usize) -> bool {
        match &self.zones {
            ZoneCol::I32(z) => match z.get(ci) {
                Some(z) => !((z.max as f64) < self.lo || (z.min as f64) > self.hi),
                None => true,
            },
            // Generated keys stay far below 2^53, so the i64→f64
            // conversion is exact.
            ZoneCol::I64(z) => match z.get(ci) {
                Some(z) => !((z.max as f64) < self.lo || (z.min as f64) > self.hi),
                None => true,
            },
            ZoneCol::F64(z) => match z.get(ci) {
                Some(z) => !(z.max < self.lo || z.min > self.hi),
                None => true,
            },
        }
    }
}

/// Chunk-skipping plan built at compile time: the scan table's zone
/// maps crossed with the per-column intervals derived from the plan's
/// predicate tree. An inactive plan ([`PrunePlan::none`], or one with
/// no derivable checks) leaves every execution path byte-identical to
/// the pre-pruning engine.
pub struct PrunePlan<'a> {
    chunk_rows: usize,
    checks: Vec<PruneCheck<'a>>,
}

impl<'a> PrunePlan<'a> {
    /// Pruning disabled (no zone map, no derivable intervals, or the
    /// caller opted out).
    pub fn none() -> Self {
        Self { chunk_rows: 0, checks: Vec::new() }
    }

    pub fn new(chunk_rows: usize, checks: Vec<PruneCheck<'a>>) -> Self {
        assert!(chunk_rows > 0, "active prune plans need a chunk size");
        Self { chunk_rows, checks }
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        self.chunk_rows > 0 && !self.checks.is_empty()
    }

    #[inline]
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// True iff chunk `ci` provably cannot satisfy the predicate —
    /// some check's admitted interval misses the chunk's zone entirely.
    #[inline]
    pub fn chunk_pruned(&self, ci: usize) -> bool {
        self.checks.iter().any(|c| !c.may_contain(ci))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_leaf_selects() {
        let col = vec![10, 25, 30, 15, 40];
        let p = Predicate::i32_range(&col, 15, 31);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 5, &mut st), vec![1, 2, 3]);
        // 5 rows × 4 B charged.
        assert_eq!(st.bytes_scanned, 20);
        assert_eq!(st.rows_in, 5);
    }

    #[test]
    fn conjunction_cascades_and_charges_per_conjunct() {
        let dates = vec![5, 15, 25, 35];
        let vals = vec![1.0, 2.0, 3.0, 4.0];
        let p = Predicate::and(vec![
            Predicate::i32_range(&dates, 10, 40), // rows 1,2,3
            Predicate::f64_lt(&vals, 3.5),        // rows 1,2
        ]);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 4, &mut st), vec![1, 2]);
        // First conjunct: 4 rows × 4 B; second: 3 rows × 8 B.
        assert_eq!(st.bytes_scanned, 16 + 24);
    }

    #[test]
    fn code_set_from_dictionary() {
        use crate::analytics::column::StrColumnBuilder;
        let mut b = StrColumnBuilder::new();
        for s in ["MAIL", "AIR", "SHIP", "MAIL", "RAIL"] {
            b.push(s);
        }
        let col = b.finish();
        let p = Predicate::code_matches(&col, |s| s == "MAIL" || s == "SHIP");
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 5, &mut st), vec![0, 2, 3]);
    }

    #[test]
    fn in_set_leaf_selects_and_refines() {
        let col = vec![3, 7, 7, 12, 5, 9];
        // Unsorted with a duplicate: the constructor normalizes.
        let p = Predicate::i32_in_set(&col, vec![9, 7, 9, 3]);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 6, &mut st), vec![0, 1, 2, 5]);
        assert_eq!(st.bytes_scanned, 24); // 6 rows × 4 B
        assert_eq!(p.filter(&[1, 3, 4, 5], &mut st), vec![1, 5]);
        // Empty set admits nothing.
        let none = Predicate::i32_in_set(&col, vec![]);
        assert!(none.eval(0, 6, &mut st).is_empty());
        assert!(!none.is_all_pass());
    }

    #[test]
    fn col_lt_col() {
        let a = vec![1, 5, 3];
        let b = vec![2, 4, 3];
        let p = Predicate::i32_col_lt(&a, &b);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 3, &mut st), vec![0]);
    }

    #[test]
    fn selection_edge_empty_range() {
        let col = vec![1, 2, 3];
        let p = Predicate::i32_range(&col, 0, 10);
        let mut st = ExecStats::default();
        assert!(p.eval(1, 1, &mut st).is_empty());
        assert!(Predicate::True.eval(2, 2, &mut st).is_empty());
        assert!(p.filter(&[], &mut st).is_empty());
    }

    #[test]
    fn selection_edge_all_pass() {
        let col = vec![1, 2, 3, 4];
        let p = Predicate::i32_range(&col, i32::MIN, i32::MAX);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 4, &mut st), vec![0, 1, 2, 3]);
        assert_eq!(Predicate::True.eval(0, 4, &mut st), vec![0, 1, 2, 3]);
    }

    #[test]
    fn selection_edge_single_row() {
        let col = vec![7.0];
        let hit = Predicate::f64_lt(&col, 8.0);
        let miss = Predicate::f64_lt(&col, 7.0);
        let mut st = ExecStats::default();
        assert_eq!(hit.eval(0, 1, &mut st), vec![0]);
        assert!(miss.eval(0, 1, &mut st).is_empty());
        // Sub-range of a larger column: only row 2 examined.
        let col3 = vec![1.0, 2.0, 3.0];
        let p = Predicate::f64_lt(&col3, 10.0);
        assert_eq!(p.eval(2, 3, &mut st), vec![2]);
    }

    #[test]
    fn empty_and_passes_everything() {
        let mut st = ExecStats::default();
        assert_eq!(Predicate::and(vec![]).eval(0, 3, &mut st), vec![0, 1, 2]);
    }

    #[test]
    fn filter_narrows_existing_selection() {
        let col = vec![1, 2, 3, 4, 5];
        let p = Predicate::i32_range(&col, 2, 5);
        let mut st = ExecStats::default();
        assert_eq!(p.filter(&[0, 2, 4], &mut st), vec![2]);
        assert_eq!(st.rows_in, 3);
    }

    #[test]
    fn all_pass_predicates_stay_dense() {
        // The satellite fix: no path materializes `(lo..hi).collect()`
        // for an all-pass predicate — eval_into returns Sel::Range and
        // the scratch buffers are never touched.
        let mut scr = SelScratch::new();
        let mut st = ExecStats::default();
        for p in [Predicate::True, Predicate::and(vec![]), Predicate::and(vec![Predicate::True])] {
            assert!(p.is_all_pass());
            match p.eval_into(5, 905, &mut scr, &mut st) {
                Sel::Range(5, 905) => {}
                other => panic!("all-pass predicate materialized: {other:?}"),
            }
        }
        assert_eq!(scr.bytes(), 0, "dense path touched the scratch");
        assert_eq!(st.bytes_scanned, 0);
    }

    #[test]
    fn eval_into_reuses_scratch_across_morsels() {
        let col: Vec<i32> = (0..1000).map(|i| i % 100).collect();
        let vals: Vec<f64> = (0..1000).map(|i| (i % 7) as f64).collect();
        let p = Predicate::and(vec![
            Predicate::i32_range(&col, 10, 60),
            Predicate::f64_lt(&vals, 4.0),
        ]);
        let mut scr = SelScratch::new();
        let mut st = ExecStats::default();
        // Warm the scratch, note its footprint…
        let first = p.eval_into(0, 500, &mut scr, &mut st).to_vec();
        let high_water = scr.bytes();
        assert!(high_water > 0);
        // …then re-evaluate same-sized morsels: footprint must not move.
        for (lo, hi) in [(0, 500), (500, 1000), (250, 750)] {
            let got = p.eval_into(lo, hi, &mut scr, &mut st).to_vec();
            let want = p.eval(lo, hi, &mut ExecStats::default());
            assert_eq!(got, want, "morsel {lo}..{hi} diverged");
        }
        assert_eq!(scr.bytes(), high_water, "steady-state morsels grew the scratch");
        assert_eq!(first, p.eval(0, 500, &mut ExecStats::default()));
    }

    #[test]
    fn prune_plan_skips_only_disjoint_zones() {
        let z = ColZones::I32(vec![
            Zone { min: 0, max: 9 },
            Zone { min: 10, max: 19 },
            Zone { min: 20, max: 29 },
        ]);
        // Predicate admits [12, 15]: only the middle chunk may match.
        let p = PrunePlan::new(4, vec![PruneCheck::new(&z, 12.0, 15.0)]);
        assert!(p.is_active());
        assert_eq!(p.chunk_rows(), 4);
        assert!(p.chunk_pruned(0));
        assert!(!p.chunk_pruned(1));
        assert!(p.chunk_pruned(2));
        // Chunks beyond the zone slice are conservatively kept.
        assert!(!p.chunk_pruned(3));
        // Interval edges touching a zone boundary keep the chunk.
        let edge = PrunePlan::new(4, vec![PruneCheck::new(&z, 9.0, 9.5)]);
        assert!(!edge.chunk_pruned(0));
        assert!(edge.chunk_pruned(1));
    }

    #[test]
    fn prune_plan_f64_and_one_sided_bounds() {
        let z = ColZones::F64(vec![Zone { min: 0.0, max: 0.04 }, Zone { min: 0.05, max: 0.09 }]);
        let below = PrunePlan::new(2, vec![PruneCheck::new(&z, f64::NEG_INFINITY, 0.045)]);
        assert!(!below.chunk_pruned(0));
        assert!(below.chunk_pruned(1));
        let above = PrunePlan::new(2, vec![PruneCheck::new(&z, 0.05, f64::INFINITY)]);
        assert!(above.chunk_pruned(0));
        assert!(!above.chunk_pruned(1));
    }

    #[test]
    fn inactive_prune_plan_never_prunes() {
        let p = PrunePlan::none();
        assert!(!p.is_active());
        assert!(!p.chunk_pruned(0));
        // Active chunking but no checks: also inactive.
        let q = PrunePlan::new(8, Vec::new());
        assert!(!q.is_active());
        assert!(!q.chunk_pruned(5));
    }

    #[test]
    fn nested_and_with_true_skips_charges() {
        let col = vec![1, 5, 9, 13];
        let p = Predicate::and(vec![
            Predicate::True,
            Predicate::and(vec![Predicate::i32_range(&col, 4, 10), Predicate::True]),
        ]);
        let mut st = ExecStats::default();
        assert_eq!(p.eval(0, 4, &mut st), vec![1, 2]);
        // Only the one real leaf charges: 4 rows × 4 B.
        assert_eq!(st.bytes_scanned, 16);
    }
}

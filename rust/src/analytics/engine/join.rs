//! Build/probe hash-join table — the dimension-side kernel every join
//! query shares.
//!
//! Open addressing maps key → slot; build rows sharing a key are chained
//! through `next`; probing yields an iterator of build rows. Multiply-
//! shift hashing, linear probing, power-of-two capacity — measured ~3-4×
//! faster than `std::HashMap` for this workload and, equally important,
//! with a byte footprint the engine can report exactly.
//!
//! (Moved here from `analytics::ops` when the engine layer was unified;
//! `ops::JoinMap` remains as a re-export alias.)

use super::hash64;
use crate::analytics::ops::ExecStats;

/// Build-side hash index for joins: key → list of build-row ids.
pub struct HashJoinTable {
    mask: usize,
    keys: Vec<i64>,
    /// head[slot] = first build row + 1 (0 = empty).
    head: Vec<u32>,
    /// next[row] = next build row with same key + 1 (0 = end).
    next: Vec<u32>,
}

impl HashJoinTable {
    /// Build from `keys[sel[i]]` for each selected build row.
    pub fn build(keys: &[i64], sel: &[u32]) -> Self {
        let cap = (sel.len().max(1) * 2).next_power_of_two();
        let mut m = Self {
            mask: cap - 1,
            keys: vec![0; cap],
            head: vec![0; cap],
            next: vec![0; keys.len()],
        };
        for &row in sel {
            let k = keys[row as usize];
            let mut slot = (hash64(k) as usize) & m.mask;
            loop {
                if m.head[slot] == 0 {
                    m.keys[slot] = k;
                    m.head[slot] = row + 1;
                    break;
                }
                if m.keys[slot] == k {
                    // Prepend to the chain.
                    let old = m.head[slot];
                    m.head[slot] = row + 1;
                    m.next[row as usize] = old;
                    break;
                }
                slot = (slot + 1) & m.mask;
            }
        }
        m
    }

    /// [`HashJoinTable::build`] plus charging the table's byte footprint
    /// to `stats` — the one-liner every plan's dimension build uses.
    pub fn build_dim(keys: &[i64], sel: &[u32], stats: &mut ExecStats) -> Self {
        let t = Self::build(keys, sel);
        stats.ht_bytes += t.bytes();
        t
    }

    /// Iterate build rows matching `k`.
    pub fn probe(&self, k: i64) -> ProbeIter<'_> {
        let mut slot = (hash64(k) as usize) & self.mask;
        loop {
            if self.head[slot] == 0 {
                return ProbeIter { map: self, cur: 0 };
            }
            if self.keys[slot] == k {
                return ProbeIter { map: self, cur: self.head[slot] };
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// First matching build row, if any (fast path for unique keys).
    pub fn probe_first(&self, k: i64) -> Option<u32> {
        let mut slot = (hash64(k) as usize) & self.mask;
        loop {
            if self.head[slot] == 0 {
                return None;
            }
            if self.keys[slot] == k {
                return Some(self.head[slot] - 1);
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Approximate byte footprint (for ExecStats).
    pub fn bytes(&self) -> u64 {
        (self.keys.len() * 8 + self.head.len() * 4 + self.next.len() * 4) as u64
    }
}

/// Iterator over build rows matching one probe key.
pub struct ProbeIter<'a> {
    map: &'a HashJoinTable,
    cur: u32,
}

impl Iterator for ProbeIter<'_> {
    type Item = u32;
    fn next(&mut self) -> Option<u32> {
        if self.cur == 0 {
            return None;
        }
        let row = self.cur - 1;
        self.cur = self.map.next[row as usize];
        Some(row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::ops::all_rows;

    #[test]
    fn probe_chains() {
        let keys = vec![10, 20, 10, 30, 10];
        let m = HashJoinTable::build(&keys, &all_rows(5));
        let mut rows: Vec<u32> = m.probe(10).collect();
        rows.sort_unstable();
        assert_eq!(rows, vec![0, 2, 4]);
        assert_eq!(m.probe(99).count(), 0);
        assert!(m.probe_first(30).is_some());
        assert!(m.probe_first(31).is_none());
    }

    #[test]
    fn build_dim_charges_stats() {
        let keys = vec![1i64, 2, 3];
        let mut st = ExecStats::default();
        let m = HashJoinTable::build_dim(&keys, &all_rows(3), &mut st);
        assert_eq!(st.ht_bytes, m.bytes());
        assert!(st.ht_bytes > 0);
    }

    #[test]
    fn negative_keys_hash_fine() {
        let keys = vec![-5i64, -5, 0, i64::MIN, i64::MAX];
        let m = HashJoinTable::build(&keys, &all_rows(5));
        assert_eq!(m.probe(-5).count(), 2);
        assert_eq!(m.probe(i64::MIN).count(), 1);
        assert_eq!(m.probe(i64::MAX).count(), 1);
    }

    #[test]
    fn respects_selection_vector() {
        let keys = vec![1i64, 2, 3];
        let m = HashJoinTable::build(&keys, &[1]);
        assert!(m.probe_first(2).is_some());
        assert!(m.probe_first(1).is_none());
        assert!(m.probe_first(3).is_none());
    }
}

//! Plans as data: the serializable logical-plan IR.
//!
//! Lovelock workers are headless smart NICs — the control plane hands
//! them *computation over the fabric*. Before this module, a
//! [`crate::coordinator::protocol::PlanFragment`] shipped only a query
//! **name** and every worker had to contain the matching hand-written
//! Rust closures: a closed world of nine frozen programs. A
//! [`LogicalPlan`] is the open replacement — a declarative,
//! wire-serializable description of a query:
//!
//! * `scan` — the probe-side table (lineitem for the TPC-H set);
//! * `pred` — a [`PredExpr`] tree over scan columns, lowered onto the
//!   vectorized [`Predicate`] cascade (ping-pong selection buffers);
//! * `joins` — up to [`MAX_JOINS`] dimension [`JoinStep`]s: a build key,
//!   an optional dim-side filter, an optional [`LinkRef`] into an
//!   earlier step's build (Q3/Q5 chain orders→customer this way), and
//!   [`Payload`] extractions that flow dim values to the probe row;
//! * `cmps` — post-join [`CmpExpr`] conjuncts over scan columns and
//!   payloads (Q5's co-nationality test, Q19's per-branch quantity
//!   window);
//! * `key` / `slots` — the group-[`KeyExpr`] and one arithmetic
//!   [`ValExpr`] per aggregate accumulator;
//! * `finalize` — a [`FinalizeSpec`]: output columns, having, sort keys,
//!   top-k limit, and leader-side dimension decoration.
//!
//! [`compile`] lowers a plan onto the engine's hot path *unchanged*: it
//! builds the dimension hash tables and payload arrays once, generates
//! the plan's [`BatchEval`] closure, and returns the same [`Compiled`]
//! context the hand-written queries used to produce — the zero-alloc
//! [`crate::analytics::engine::fold_range`] kernel and
//! [`crate::analytics::engine::HashAgg`] never see the IR. What stays
//! closure-land is exactly the per-morsel inner loop; everything the
//! closure *captures* is now data.
//!
//! The codec ([`LogicalPlan::encode`]/[`LogicalPlan::decode`]) is an
//! exact inverse with truncation and trailing-garbage rejection, like
//! the protocol frames (property-tested in `rust/tests/properties.rs`;
//! wire-format stability is pinned by the golden fixture test
//! `rust/tests/plan_fixture.rs`).
//!
//! ```
//! use lovelock::analytics::engine::{self, plan};
//! use lovelock::analytics::{TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 42));
//! // An ad-hoc plan no registry has heard of: 1994 revenue by ship mode.
//! let adhoc = plan::LogicalPlan {
//!     name: "mode-revenue".into(),
//!     scan: plan::TableRef::Lineitem,
//!     pred: plan::i32_range("l_shipdate", 8766, 9131),
//!     joins: vec![],
//!     cmps: vec![],
//!     key: plan::kcol("l_shipmode"),
//!     slots: vec![plan::vmul(
//!         plan::vcol("l_extendedprice"),
//!         plan::vsub(plan::vconst(1.0), plan::vcol("l_discount")),
//!     )],
//!     groups_hint: plan::GroupsHint::Const(8),
//!     finalize: plan::FinalizeSpec {
//!         scalar: false,
//!         columns: vec![
//!             plan::OutCol::KeyDict { table: plan::TableRef::Lineitem, col: "l_shipmode".into() },
//!             plan::OutCol::Acc(0),
//!         ],
//!         having_gt: None,
//!         sort: vec![(0, plan::SortDir::Asc)],
//!         limit: 0,
//!     },
//! };
//! let decoded = plan::LogicalPlan::decode(&adhoc.encode()).unwrap();
//! assert_eq!(decoded, adhoc);
//! let out = engine::try_run_serial(&db, &decoded).unwrap();
//! assert!(!out.rows.is_empty() && out.rows.len() <= 7); // ≤ one row per mode
//! ```

use super::expr::{Predicate, PruneCheck, PrunePlan, Sel};
use super::join::HashJoinTable;
use super::partial::Partial;
use super::{BatchEval, Compiled, EvalBatch, MAX_ACCS};
use crate::analytics::column::{date_to_days, days_to_date, Column, Table};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{Row, Value};
use crate::analytics::tpch::{TpchDb, NATIONS};
use crate::error::Result;
use crate::wirefmt::{put_str, Reader};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};

/// Maximum dimension-join steps per plan.
pub const MAX_JOINS: usize = 4;
/// Maximum total payload slots across all probed join steps (the size of
/// the per-row payload environment, a stack array in the generated
/// evaluator).
pub const MAX_ENV: usize = 8;
/// Recursion cap for decoded expression trees (a hostile frame cannot
/// blow the stack).
const MAX_DEPTH: usize = 12;

// ------------------------------------------------------------- IR types

/// A table of the TPC-H catalog, by position in [`TpchDb`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableRef {
    Lineitem,
    Orders,
    Customer,
    Supplier,
    Part,
    Partsupp,
}

impl TableRef {
    fn tag(self) -> u8 {
        match self {
            TableRef::Lineitem => 0,
            TableRef::Orders => 1,
            TableRef::Customer => 2,
            TableRef::Supplier => 3,
            TableRef::Part => 4,
            TableRef::Partsupp => 5,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => TableRef::Lineitem,
            1 => TableRef::Orders,
            2 => TableRef::Customer,
            3 => TableRef::Supplier,
            4 => TableRef::Part,
            5 => TableRef::Partsupp,
            t => crate::bail!("unknown table tag {t}"),
        })
    }

    /// Catalog name of the table (matches [`Table::name`]).
    pub fn name(self) -> &'static str {
        match self {
            TableRef::Lineitem => "lineitem",
            TableRef::Orders => "orders",
            TableRef::Customer => "customer",
            TableRef::Supplier => "supplier",
            TableRef::Part => "part",
            TableRef::Partsupp => "partsupp",
        }
    }
}

/// Resolve a [`TableRef`] against the attached database.
pub fn table(db: &TpchDb, t: TableRef) -> &Table {
    match t {
        TableRef::Lineitem => &db.lineitem,
        TableRef::Orders => &db.orders,
        TableRef::Customer => &db.customer,
        TableRef::Supplier => &db.supplier,
        TableRef::Part => &db.part,
        TableRef::Partsupp => &db.partsupp,
    }
}

/// How a string (dictionary-encoded) column is matched. The test runs
/// once per dictionary entry at compile time, never per row.
#[derive(Clone, Debug, PartialEq)]
pub enum StrMatch {
    Eq(String),
    Prefix(String),
    Contains(String),
    OneOf(Vec<String>),
}

impl StrMatch {
    /// Does `s` satisfy this matcher?
    pub fn matches(&self, s: &str) -> bool {
        match self {
            StrMatch::Eq(v) => s == v,
            StrMatch::Prefix(v) => s.starts_with(v.as_str()),
            StrMatch::Contains(v) => s.contains(v.as_str()),
            StrMatch::OneOf(vs) => vs.iter().any(|v| v == s),
        }
    }
}

/// Declarative predicate tree over one table's columns.
///
/// In **scan** position ([`LogicalPlan::pred`]) only the conjunctive
/// subset lowers (no `Or`) — the vectorized cascade narrows a
/// selection conjunct by conjunct. Dimension-side filters
/// ([`JoinStep::filter`], [`Payload::CaseConst`]) accept the full tree.
#[derive(Clone, Debug, PartialEq)]
pub enum PredExpr {
    True,
    /// `lo <= col[i] < hi` over an i32 column (date windows).
    I32Range { col: String, lo: i32, hi: i32 },
    /// `a[i] < b[i]` between two i32 columns.
    I32ColLt { a: String, b: String },
    /// `col[i] ∈ values` over an i32 column.
    I32InSet { col: String, values: Vec<i32> },
    /// `lo <= col[i] < hi` over an f64 column.
    F64Range { col: String, lo: f64, hi: f64 },
    /// `col[i] < x` over an f64 column.
    F64Lt { col: String, x: f64 },
    /// String match against a dictionary-encoded column.
    Str { col: String, m: StrMatch },
    /// Conjunction.
    And(Vec<PredExpr>),
    /// Disjunction (dimension-side only).
    Or(Vec<PredExpr>),
}

/// Key columns on the build or probe side of a join: one integral
/// column, or two packed as `(a << shift) | b` (Q9's composite
/// partsupp key).
#[derive(Clone, Debug, PartialEq)]
pub enum KeyCols {
    Col(String),
    Packed { a: String, shift: u8, b: String },
}

/// A dim-side value extracted into the probe row's payload environment.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Numeric dim column as f64 (i64/i32/u8/f64; str columns yield
    /// their dictionary code).
    Col(String),
    /// 1.0/0.0 from a string match on a dim column (Q12's priority
    /// class, Q14's PROMO test).
    Flag { col: String, m: StrMatch },
    /// The constant of the first matching case; dim rows matching **no**
    /// case are excluded from the join build (Q19's per-branch quantity
    /// bounds).
    CaseConst { cases: Vec<(PredExpr, f64)> },
    /// Payload slot `k` of the step this step links to, resolved through
    /// the link match at build time (Q5 carries the customer's nation
    /// through the orders build this way).
    FromLink(u8),
}

/// A dim-side probe from one join step into an **earlier** step's build:
/// this dim's `via` column must match, or the row is excluded.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkRef {
    pub step: u8,
    pub via: String,
}

/// One dimension-join step (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct JoinStep {
    pub table: TableRef,
    /// Dense surrogate access: `probe key − 1` indexes the dim table
    /// directly, no hash table (orders/part have dense 1..=N keys).
    pub dense: bool,
    /// Build-side key (hash steps; must be `None` when `dense`).
    pub build_key: Option<KeyCols>,
    /// Probe key over scan columns; `None` = compile-time-only step that
    /// a later step links into (never probed per row).
    pub probe_key: Option<KeyCols>,
    /// Dim-side filter; rows failing it are excluded from the build.
    pub filter: PredExpr,
    /// Optional dim-side probe into an earlier step.
    pub link: Option<LinkRef>,
    /// Values extracted from the matched dim row.
    pub payloads: Vec<Payload>,
}

/// Arithmetic over the probe row: scan columns and join payloads.
#[derive(Clone, Debug, PartialEq)]
pub enum ValExpr {
    Const(f64),
    /// Numeric scan column as f64.
    Col(String),
    /// Payload `slot` of join step `step` (the step must be probed).
    Payload { step: u8, slot: u8 },
    Add(Box<ValExpr>, Box<ValExpr>),
    Sub(Box<ValExpr>, Box<ValExpr>),
    Mul(Box<ValExpr>, Box<ValExpr>),
}

/// Comparison operator of a [`CmpExpr`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Lt,
    Le,
    Ge,
    Gt,
}

/// One post-join conjunct: `lhs op rhs` over the probe row.
#[derive(Clone, Debug, PartialEq)]
pub struct CmpExpr {
    pub lhs: ValExpr,
    pub op: CmpOp,
    pub rhs: ValExpr,
}

/// Integral group-key expression over the probe row.
#[derive(Clone, Debug, PartialEq)]
pub enum KeyExpr {
    Const(i64),
    /// Integral scan column (str columns yield their dictionary code).
    Col(String),
    /// Payload value truncated to i64.
    Payload { step: u8, slot: u8 },
    /// Calendar year of a day-count expression.
    Year(Box<KeyExpr>),
    /// `(hi << shift) | lo`.
    Pack { hi: Box<KeyExpr>, shift: u8, lo: Box<KeyExpr> },
}

/// Expected distinct groups — the aggregation-table capacity hint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupsHint {
    Const(u32),
    /// One group per row of a dimension table (Q18 groups by order key).
    TableRows(TableRef),
}

/// Sort direction of one finalize sort key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SortDir {
    Asc,
    Desc,
}

/// One output column of the finalized result.
#[derive(Clone, Debug, PartialEq)]
pub enum OutCol {
    /// `Int((key >> shift) & mask(bits))`; `bits == 0` keeps all bits.
    KeyInt { shift: u8, bits: u8 },
    /// `Str` of the byte at `key >> shift` as a char (Q1's flag pair).
    KeyChar { shift: u8 },
    /// `Str(NATIONS[(key >> shift) & mask(bits)])`.
    KeyNation { shift: u8, bits: u8 },
    /// `Str(dict[key])` through a table's string column dictionary.
    KeyDict { table: TableRef, col: String },
    /// `Float(acc[k])`.
    Acc(u8),
    /// `Int(acc[k] as i64)` (Q12's counts ride f64 accumulators).
    AccInt(u8),
    /// `Int(count)`.
    Count,
    /// `Float(acc[k] / count)` (Q1's averages).
    AccOverCount(u8),
    /// `Float(100 · acc[a] / acc[b])`, 0 when the denominator is 0.
    AccRatioPct(u8, u8),
    /// Dense dimension decoration: `Int(table.col[key − 1])`.
    DimInt { table: TableRef, col: String },
    /// Dense dimension decoration: `Float(table.col[key − 1])`.
    DimFloat { table: TableRef, col: String },
}

/// Leader-side finalization: merged partial → result rows.
#[derive(Clone, Debug, PartialEq)]
pub struct FinalizeSpec {
    /// Emit exactly one row even from an empty partial (scalar
    /// aggregates: Q6/Q14/Q19).
    pub scalar: bool,
    pub columns: Vec<OutCol>,
    /// Keep groups whose `acc[i]` exceeds the threshold (Q18).
    pub having_gt: Option<(u8, f64)>,
    /// Lexicographic sort over output columns.
    pub sort: Vec<(u8, SortDir)>,
    /// Keep the first `limit` rows after sorting (0 = unlimited).
    pub limit: u32,
}

/// The serializable logical plan (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct LogicalPlan {
    /// Display name — carried on the wire for reports/traces only; no
    /// executor consults a registry with it.
    pub name: String,
    pub scan: TableRef,
    pub pred: PredExpr,
    pub joins: Vec<JoinStep>,
    pub cmps: Vec<CmpExpr>,
    pub key: KeyExpr,
    pub slots: Vec<ValExpr>,
    pub groups_hint: GroupsHint,
    pub finalize: FinalizeSpec,
}

impl LogicalPlan {
    /// Aggregate accumulator slots per group.
    pub fn width(&self) -> usize {
        self.slots.len()
    }
}

// ------------------------------------------------------ builder helpers

pub fn i32_range(col: &str, lo: i32, hi: i32) -> PredExpr {
    PredExpr::I32Range { col: col.into(), lo, hi }
}

pub fn i32_col_lt(a: &str, b: &str) -> PredExpr {
    PredExpr::I32ColLt { a: a.into(), b: b.into() }
}

pub fn i32_in(col: &str, values: Vec<i32>) -> PredExpr {
    PredExpr::I32InSet { col: col.into(), values }
}

pub fn f64_range(col: &str, lo: f64, hi: f64) -> PredExpr {
    PredExpr::F64Range { col: col.into(), lo, hi }
}

pub fn f64_lt(col: &str, x: f64) -> PredExpr {
    PredExpr::F64Lt { col: col.into(), x }
}

pub fn str_eq(col: &str, v: &str) -> PredExpr {
    PredExpr::Str { col: col.into(), m: StrMatch::Eq(v.into()) }
}

pub fn str_prefix(col: &str, v: &str) -> PredExpr {
    PredExpr::Str { col: col.into(), m: StrMatch::Prefix(v.into()) }
}

pub fn str_contains(col: &str, v: &str) -> PredExpr {
    PredExpr::Str { col: col.into(), m: StrMatch::Contains(v.into()) }
}

pub fn str_in(col: &str, vs: &[String]) -> PredExpr {
    PredExpr::Str { col: col.into(), m: StrMatch::OneOf(vs.to_vec()) }
}

pub fn pand(ps: Vec<PredExpr>) -> PredExpr {
    PredExpr::And(ps)
}

pub fn por(ps: Vec<PredExpr>) -> PredExpr {
    PredExpr::Or(ps)
}

pub fn vcol(n: &str) -> ValExpr {
    ValExpr::Col(n.into())
}

pub fn vconst(x: f64) -> ValExpr {
    ValExpr::Const(x)
}

pub fn vpay(step: u8, slot: u8) -> ValExpr {
    ValExpr::Payload { step, slot }
}

pub fn vadd(a: ValExpr, b: ValExpr) -> ValExpr {
    ValExpr::Add(Box::new(a), Box::new(b))
}

pub fn vsub(a: ValExpr, b: ValExpr) -> ValExpr {
    ValExpr::Sub(Box::new(a), Box::new(b))
}

pub fn vmul(a: ValExpr, b: ValExpr) -> ValExpr {
    ValExpr::Mul(Box::new(a), Box::new(b))
}

/// `price · (1 − discount)` — the revenue expression most queries share.
pub fn vrevenue() -> ValExpr {
    vmul(vcol("l_extendedprice"), vsub(vconst(1.0), vcol("l_discount")))
}

pub fn kconst(k: i64) -> KeyExpr {
    KeyExpr::Const(k)
}

pub fn kcol(n: &str) -> KeyExpr {
    KeyExpr::Col(n.into())
}

pub fn kpay(step: u8, slot: u8) -> KeyExpr {
    KeyExpr::Payload { step, slot }
}

pub fn kyear(e: KeyExpr) -> KeyExpr {
    KeyExpr::Year(Box::new(e))
}

pub fn kpack(hi: KeyExpr, shift: u8, lo: KeyExpr) -> KeyExpr {
    KeyExpr::Pack { hi: Box::new(hi), shift, lo: Box::new(lo) }
}

pub fn cmp(lhs: ValExpr, op: CmpOp, rhs: ValExpr) -> CmpExpr {
    CmpExpr { lhs, op, rhs }
}

// ------------------------------------------------------------ parameters

/// A typed parameter value parsed from `--param key=value`.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    I64(i64),
    F64(f64),
    Str(String),
}

/// The parameter bag the IR constructors read: `--param` overrides flow
/// leader → worker *through the plan* (the worker never sees the bag,
/// only the parameterized IR). Reads are tracked so
/// [`crate::analytics::queries::build`] can reject unknown keys.
#[derive(Clone, Debug, Default)]
pub struct PlanParams {
    vals: BTreeMap<String, ParamValue>,
    used: RefCell<BTreeSet<String>>,
}

impl PlanParams {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a raw `key=value` pair, inferring the type: i64, then f64,
    /// then string.
    pub fn set(&mut self, key: &str, raw: &str) {
        let v = if let Ok(i) = raw.parse::<i64>() {
            ParamValue::I64(i)
        } else if let Ok(f) = raw.parse::<f64>() {
            ParamValue::F64(f)
        } else {
            ParamValue::Str(raw.to_string())
        };
        self.vals.insert(key.to_string(), v);
    }

    pub fn set_value(&mut self, key: &str, v: ParamValue) {
        self.vals.insert(key.to_string(), v);
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    fn touch(&self, key: &str) -> Option<&ParamValue> {
        let v = self.vals.get(key);
        if v.is_some() {
            self.used.borrow_mut().insert(key.to_string());
        }
        v
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.touch(key) {
            None => Ok(default),
            Some(ParamValue::I64(i)) => Ok(*i),
            Some(v) => crate::bail!("param {key} expects an integer, got {v:?}"),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.touch(key) {
            None => Ok(default),
            Some(ParamValue::F64(f)) => Ok(*f),
            Some(ParamValue::I64(i)) => Ok(*i as f64),
            Some(v) => crate::bail!("param {key} expects a number, got {v:?}"),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.touch(key) {
            None => Ok(default.to_string()),
            Some(ParamValue::Str(s)) => Ok(s.clone()),
            Some(v) => crate::bail!("param {key} expects a string, got {v:?}"),
        }
    }

    /// A date parameter: `"YYYY-MM-DD"` or a raw day count. Raw counts
    /// are range-checked (±4M days ≈ years −9000..13000) so plan
    /// builders can safely do `date ± 1` arithmetic on the result — an
    /// unchecked `as i32` would silently wrap a fat-fingered value into
    /// a valid-looking window.
    pub fn get_date(&self, key: &str, default_days: i32) -> Result<i32> {
        match self.touch(key) {
            None => Ok(default_days),
            Some(ParamValue::I64(i)) => {
                crate::ensure!(
                    (-4_000_000..=4_000_000).contains(i),
                    "param {key}: day count {i} out of range"
                );
                Ok(*i as i32)
            }
            Some(ParamValue::Str(s)) => parse_date(s),
            Some(v) => crate::bail!("param {key} expects a date, got {v:?}"),
        }
    }

    /// A top-k limit parameter: non-negative and `u32`-ranged (the wire
    /// `FinalizeSpec.limit` is u32 and 0 means "unlimited", so an
    /// unchecked narrowing cast would turn 2^32 into no limit at all).
    pub fn get_limit(&self, key: &str, default: u32) -> Result<u32> {
        let v = self.get_i64(key, default as i64)?;
        crate::ensure!(
            (0..=u32::MAX as i64).contains(&v),
            "param {key} must be in 0..={}, got {v}",
            u32::MAX
        );
        Ok(v as u32)
    }

    /// A comma-separated string list parameter.
    pub fn get_list(&self, key: &str, default: &[&str]) -> Result<Vec<String>> {
        match self.touch(key) {
            None => Ok(default.iter().map(|s| s.to_string()).collect()),
            Some(ParamValue::Str(s)) => {
                Ok(s.split(',').map(|p| p.trim().to_string()).collect())
            }
            Some(v) => crate::bail!("param {key} expects a comma list, got {v:?}"),
        }
    }

    /// Keys that were set but never read by the plan builder.
    pub fn unused(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.vals.keys().filter(|k| !used.contains(*k)).cloned().collect()
    }

    /// Forget which keys have been read — called at the top of
    /// [`crate::analytics::queries::build`] so reusing one bag across
    /// plans cannot let a key read by an *earlier* build defeat the
    /// stray-key check of a later one.
    pub fn reset_used(&self) {
        self.used.borrow_mut().clear();
    }
}

/// Parse `"YYYY-MM-DD"` into days since the unix epoch.
pub fn parse_date(s: &str) -> Result<i32> {
    let parts: Vec<&str> = s.split('-').collect();
    crate::ensure!(parts.len() == 3, "bad date {s:?}: want YYYY-MM-DD");
    let bad = |_| crate::err!("bad date {s:?}: want YYYY-MM-DD");
    let y: i32 = parts[0].parse().map_err(bad)?;
    let m: u32 = parts[1].parse().map_err(bad)?;
    let d: u32 = parts[2].parse().map_err(bad)?;
    crate::ensure!(
        (0..=9999).contains(&y) && (1..=12).contains(&m) && (1..=31).contains(&d),
        "bad date {s:?}"
    );
    Ok(date_to_days(y, m, d))
}

// ---------------------------------------------------------------- codec
//
// Wire layout (little-endian; strings are u32-length-prefixed UTF-8):
//
//   Plan     := str name, u8 scan, Pred, u8 nj Join*, u8 nc Cmp*,
//               Key, u8 ns Val*, Hint, Fin
//   Pred     := u8 tag: 0 True | 1 I32Range(str,i32,i32)
//             | 2 I32ColLt(str,str) | 3 I32InSet(str, u16 n, i32*n)
//             | 4 F64Range(str,f64,f64) | 5 F64Lt(str,f64)
//             | 6 Str(str, Match) | 7 And(u8 n, Pred*n) | 8 Or(...)
//   Match    := u8 tag: 0 Eq(str) | 1 Prefix | 2 Contains
//             | 3 OneOf(u8 n, str*n)
//   KeyCols  := u8 tag: 0 Col(str) | 1 Packed(str, u8, str)
//   Join     := u8 table, u8 dense, Opt<KeyCols> build, Opt<KeyCols>
//               probe, Pred filter, Opt<(u8 step, str via)> link,
//               u8 np Payload*np
//   Payload  := u8 tag: 0 Col(str) | 1 Flag(str, Match)
//             | 2 CaseConst(u8 n, (Pred, f64)*n) | 3 FromLink(u8)
//   Val      := u8 tag: 0 Const(f64) | 1 Col(str) | 2 Payload(u8,u8)
//             | 3 Add(Val,Val) | 4 Sub | 5 Mul
//   Cmp      := Val, u8 op (0 Eq 1 Lt 2 Le 3 Ge 4 Gt), Val
//   Key      := u8 tag: 0 Const(i64) | 1 Col(str) | 2 Payload(u8,u8)
//             | 3 Year(Key) | 4 Pack(Key, u8, Key)
//   Hint     := u8 tag: 0 Const(u32) | 1 TableRows(u8)
//   Fin      := u8 scalar, u8 n OutCol*n, Opt<(u8, f64)> having,
//               u8 n (u8 col, u8 desc)*n, u32 limit
//   OutCol   := u8 tag: 0 KeyInt(u8,u8) | 1 KeyChar(u8)
//             | 2 KeyNation(u8,u8) | 3 KeyDict(u8, str) | 4 Acc(u8)
//             | 5 AccInt(u8) | 6 Count | 7 AccOverCount(u8)
//             | 8 AccRatioPct(u8,u8) | 9 DimInt(u8, str)
//             | 10 DimFloat(u8, str)
//   Opt<T>   := u8 0 | u8 1, T
//
// `rust/tests/fixtures/q6_plan.bin` pins this layout across PRs.

fn enc_pred(p: &PredExpr, out: &mut Vec<u8>) {
    match p {
        PredExpr::True => out.push(0),
        PredExpr::I32Range { col, lo, hi } => {
            out.push(1);
            put_str(out, col);
            out.extend_from_slice(&lo.to_le_bytes());
            out.extend_from_slice(&hi.to_le_bytes());
        }
        PredExpr::I32ColLt { a, b } => {
            out.push(2);
            put_str(out, a);
            put_str(out, b);
        }
        PredExpr::I32InSet { col, values } => {
            out.push(3);
            put_str(out, col);
            out.extend_from_slice(&(values.len() as u16).to_le_bytes());
            for v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        PredExpr::F64Range { col, lo, hi } => {
            out.push(4);
            put_str(out, col);
            out.extend_from_slice(&lo.to_le_bytes());
            out.extend_from_slice(&hi.to_le_bytes());
        }
        PredExpr::F64Lt { col, x } => {
            out.push(5);
            put_str(out, col);
            out.extend_from_slice(&x.to_le_bytes());
        }
        PredExpr::Str { col, m } => {
            out.push(6);
            put_str(out, col);
            enc_match(m, out);
        }
        PredExpr::And(ps) => {
            out.push(7);
            out.push(ps.len() as u8);
            for p in ps {
                enc_pred(p, out);
            }
        }
        PredExpr::Or(ps) => {
            out.push(8);
            out.push(ps.len() as u8);
            for p in ps {
                enc_pred(p, out);
            }
        }
    }
}

fn dec_pred(r: &mut Reader<'_>, depth: usize) -> Result<PredExpr> {
    crate::ensure!(depth < MAX_DEPTH, "predicate tree too deep");
    Ok(match r.u8()? {
        0 => PredExpr::True,
        1 => PredExpr::I32Range { col: r.str()?, lo: r.i32()?, hi: r.i32()? },
        2 => PredExpr::I32ColLt { a: r.str()?, b: r.str()? },
        3 => {
            let col = r.str()?;
            let n = r.u16()? as usize;
            let values = (0..n).map(|_| r.i32()).collect::<Result<_>>()?;
            PredExpr::I32InSet { col, values }
        }
        4 => PredExpr::F64Range { col: r.str()?, lo: r.f64()?, hi: r.f64()? },
        5 => PredExpr::F64Lt { col: r.str()?, x: r.f64()? },
        6 => PredExpr::Str { col: r.str()?, m: dec_match(r)? },
        7 => {
            let n = r.u8()? as usize;
            PredExpr::And((0..n).map(|_| dec_pred(r, depth + 1)).collect::<Result<_>>()?)
        }
        8 => {
            let n = r.u8()? as usize;
            PredExpr::Or((0..n).map(|_| dec_pred(r, depth + 1)).collect::<Result<_>>()?)
        }
        t => crate::bail!("unknown predicate tag {t}"),
    })
}

fn enc_match(m: &StrMatch, out: &mut Vec<u8>) {
    match m {
        StrMatch::Eq(v) => {
            out.push(0);
            put_str(out, v);
        }
        StrMatch::Prefix(v) => {
            out.push(1);
            put_str(out, v);
        }
        StrMatch::Contains(v) => {
            out.push(2);
            put_str(out, v);
        }
        StrMatch::OneOf(vs) => {
            out.push(3);
            out.push(vs.len() as u8);
            for v in vs {
                put_str(out, v);
            }
        }
    }
}

fn dec_match(r: &mut Reader<'_>) -> Result<StrMatch> {
    Ok(match r.u8()? {
        0 => StrMatch::Eq(r.str()?),
        1 => StrMatch::Prefix(r.str()?),
        2 => StrMatch::Contains(r.str()?),
        3 => {
            let n = r.u8()? as usize;
            StrMatch::OneOf((0..n).map(|_| r.str()).collect::<Result<_>>()?)
        }
        t => crate::bail!("unknown string-match tag {t}"),
    })
}

fn enc_keycols(k: &KeyCols, out: &mut Vec<u8>) {
    match k {
        KeyCols::Col(c) => {
            out.push(0);
            put_str(out, c);
        }
        KeyCols::Packed { a, shift, b } => {
            out.push(1);
            put_str(out, a);
            out.push(*shift);
            put_str(out, b);
        }
    }
}

fn dec_keycols(r: &mut Reader<'_>) -> Result<KeyCols> {
    Ok(match r.u8()? {
        0 => KeyCols::Col(r.str()?),
        1 => KeyCols::Packed { a: r.str()?, shift: r.u8()?, b: r.str()? },
        t => crate::bail!("unknown key-cols tag {t}"),
    })
}

fn enc_opt<T, F: Fn(&T, &mut Vec<u8>)>(o: &Option<T>, out: &mut Vec<u8>, f: F) {
    match o {
        None => out.push(0),
        Some(v) => {
            out.push(1);
            f(v, out);
        }
    }
}

fn dec_opt<T, F: FnMut(&mut Reader<'_>) -> Result<T>>(
    r: &mut Reader<'_>,
    mut f: F,
) -> Result<Option<T>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(f(r)?)),
        t => crate::bail!("bad option tag {t}"),
    }
}

fn enc_payload(p: &Payload, out: &mut Vec<u8>) {
    match p {
        Payload::Col(c) => {
            out.push(0);
            put_str(out, c);
        }
        Payload::Flag { col, m } => {
            out.push(1);
            put_str(out, col);
            enc_match(m, out);
        }
        Payload::CaseConst { cases } => {
            out.push(2);
            out.push(cases.len() as u8);
            for (p, v) in cases {
                enc_pred(p, out);
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Payload::FromLink(k) => {
            out.push(3);
            out.push(*k);
        }
    }
}

fn dec_payload(r: &mut Reader<'_>) -> Result<Payload> {
    Ok(match r.u8()? {
        0 => Payload::Col(r.str()?),
        1 => Payload::Flag { col: r.str()?, m: dec_match(r)? },
        2 => {
            let n = r.u8()? as usize;
            let cases = (0..n)
                .map(|_| Ok((dec_pred(r, 0)?, r.f64()?)))
                .collect::<Result<_>>()?;
            Payload::CaseConst { cases }
        }
        3 => Payload::FromLink(r.u8()?),
        t => crate::bail!("unknown payload tag {t}"),
    })
}

fn enc_join(j: &JoinStep, out: &mut Vec<u8>) {
    out.push(j.table.tag());
    out.push(j.dense as u8);
    enc_opt(&j.build_key, out, enc_keycols);
    enc_opt(&j.probe_key, out, enc_keycols);
    enc_pred(&j.filter, out);
    enc_opt(&j.link, out, |l, out| {
        out.push(l.step);
        put_str(out, &l.via);
    });
    out.push(j.payloads.len() as u8);
    for p in &j.payloads {
        enc_payload(p, out);
    }
}

fn dec_join(r: &mut Reader<'_>) -> Result<JoinStep> {
    let table = TableRef::from_tag(r.u8()?)?;
    let dense = match r.u8()? {
        0 => false,
        1 => true,
        t => crate::bail!("bad dense flag {t}"),
    };
    let build_key = dec_opt(r, dec_keycols)?;
    let probe_key = dec_opt(r, dec_keycols)?;
    let filter = dec_pred(r, 0)?;
    let link = dec_opt(r, |r| Ok(LinkRef { step: r.u8()?, via: r.str()? }))?;
    let n = r.u8()? as usize;
    let payloads = (0..n).map(|_| dec_payload(r)).collect::<Result<_>>()?;
    Ok(JoinStep { table, dense, build_key, probe_key, filter, link, payloads })
}

fn enc_val(v: &ValExpr, out: &mut Vec<u8>) {
    match v {
        ValExpr::Const(x) => {
            out.push(0);
            out.extend_from_slice(&x.to_le_bytes());
        }
        ValExpr::Col(c) => {
            out.push(1);
            put_str(out, c);
        }
        ValExpr::Payload { step, slot } => {
            out.push(2);
            out.push(*step);
            out.push(*slot);
        }
        ValExpr::Add(a, b) => {
            out.push(3);
            enc_val(a, out);
            enc_val(b, out);
        }
        ValExpr::Sub(a, b) => {
            out.push(4);
            enc_val(a, out);
            enc_val(b, out);
        }
        ValExpr::Mul(a, b) => {
            out.push(5);
            enc_val(a, out);
            enc_val(b, out);
        }
    }
}

fn dec_val(r: &mut Reader<'_>, depth: usize) -> Result<ValExpr> {
    crate::ensure!(depth < MAX_DEPTH, "value tree too deep");
    Ok(match r.u8()? {
        0 => ValExpr::Const(r.f64()?),
        1 => ValExpr::Col(r.str()?),
        2 => ValExpr::Payload { step: r.u8()?, slot: r.u8()? },
        3 => ValExpr::Add(
            Box::new(dec_val(r, depth + 1)?),
            Box::new(dec_val(r, depth + 1)?),
        ),
        4 => ValExpr::Sub(
            Box::new(dec_val(r, depth + 1)?),
            Box::new(dec_val(r, depth + 1)?),
        ),
        5 => ValExpr::Mul(
            Box::new(dec_val(r, depth + 1)?),
            Box::new(dec_val(r, depth + 1)?),
        ),
        t => crate::bail!("unknown value tag {t}"),
    })
}

fn enc_key(k: &KeyExpr, out: &mut Vec<u8>) {
    match k {
        KeyExpr::Const(v) => {
            out.push(0);
            out.extend_from_slice(&v.to_le_bytes());
        }
        KeyExpr::Col(c) => {
            out.push(1);
            put_str(out, c);
        }
        KeyExpr::Payload { step, slot } => {
            out.push(2);
            out.push(*step);
            out.push(*slot);
        }
        KeyExpr::Year(e) => {
            out.push(3);
            enc_key(e, out);
        }
        KeyExpr::Pack { hi, shift, lo } => {
            out.push(4);
            enc_key(hi, out);
            out.push(*shift);
            enc_key(lo, out);
        }
    }
}

fn dec_key(r: &mut Reader<'_>, depth: usize) -> Result<KeyExpr> {
    crate::ensure!(depth < MAX_DEPTH, "key tree too deep");
    Ok(match r.u8()? {
        0 => KeyExpr::Const(r.i64()?),
        1 => KeyExpr::Col(r.str()?),
        2 => KeyExpr::Payload { step: r.u8()?, slot: r.u8()? },
        3 => KeyExpr::Year(Box::new(dec_key(r, depth + 1)?)),
        4 => {
            let hi = Box::new(dec_key(r, depth + 1)?);
            let shift = r.u8()?;
            let lo = Box::new(dec_key(r, depth + 1)?);
            KeyExpr::Pack { hi, shift, lo }
        }
        t => crate::bail!("unknown key tag {t}"),
    })
}

fn enc_outcol(c: &OutCol, out: &mut Vec<u8>) {
    match c {
        OutCol::KeyInt { shift, bits } => {
            out.push(0);
            out.push(*shift);
            out.push(*bits);
        }
        OutCol::KeyChar { shift } => {
            out.push(1);
            out.push(*shift);
        }
        OutCol::KeyNation { shift, bits } => {
            out.push(2);
            out.push(*shift);
            out.push(*bits);
        }
        OutCol::KeyDict { table, col } => {
            out.push(3);
            out.push(table.tag());
            put_str(out, col);
        }
        OutCol::Acc(k) => {
            out.push(4);
            out.push(*k);
        }
        OutCol::AccInt(k) => {
            out.push(5);
            out.push(*k);
        }
        OutCol::Count => out.push(6),
        OutCol::AccOverCount(k) => {
            out.push(7);
            out.push(*k);
        }
        OutCol::AccRatioPct(a, b) => {
            out.push(8);
            out.push(*a);
            out.push(*b);
        }
        OutCol::DimInt { table, col } => {
            out.push(9);
            out.push(table.tag());
            put_str(out, col);
        }
        OutCol::DimFloat { table, col } => {
            out.push(10);
            out.push(table.tag());
            put_str(out, col);
        }
    }
}

fn dec_outcol(r: &mut Reader<'_>) -> Result<OutCol> {
    Ok(match r.u8()? {
        0 => OutCol::KeyInt { shift: r.u8()?, bits: r.u8()? },
        1 => OutCol::KeyChar { shift: r.u8()? },
        2 => OutCol::KeyNation { shift: r.u8()?, bits: r.u8()? },
        3 => OutCol::KeyDict { table: TableRef::from_tag(r.u8()?)?, col: r.str()? },
        4 => OutCol::Acc(r.u8()?),
        5 => OutCol::AccInt(r.u8()?),
        6 => OutCol::Count,
        7 => OutCol::AccOverCount(r.u8()?),
        8 => OutCol::AccRatioPct(r.u8()?, r.u8()?),
        9 => OutCol::DimInt { table: TableRef::from_tag(r.u8()?)?, col: r.str()? },
        10 => OutCol::DimFloat { table: TableRef::from_tag(r.u8()?)?, col: r.str()? },
        t => crate::bail!("unknown output-column tag {t}"),
    })
}

impl LogicalPlan {
    /// Encode for the wire — the exact inverse of [`LogicalPlan::decode`]
    /// **for plans within wire bounds** ([`LogicalPlan::check_wire_bounds`]):
    /// collection counts narrow to u8/u16 on the wire, so an
    /// out-of-bounds plan would truncate silently. Callers that accept
    /// untrusted plan structures must check first (the one fabric entry
    /// point, `QueryService::submit_plan`, does); debug builds assert it.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(128);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` (see [`LogicalPlan::encode`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        debug_assert!(
            self.check_wire_bounds().is_ok(),
            "encoding a plan outside wire bounds: {:?}",
            self.check_wire_bounds().err()
        );
        put_str(out, &self.name);
        out.push(self.scan.tag());
        enc_pred(&self.pred, out);
        out.push(self.joins.len() as u8);
        for j in &self.joins {
            enc_join(j, out);
        }
        out.push(self.cmps.len() as u8);
        for c in &self.cmps {
            enc_val(&c.lhs, out);
            out.push(match c.op {
                CmpOp::Eq => 0,
                CmpOp::Lt => 1,
                CmpOp::Le => 2,
                CmpOp::Ge => 3,
                CmpOp::Gt => 4,
            });
            enc_val(&c.rhs, out);
        }
        enc_key(&self.key, out);
        out.push(self.slots.len() as u8);
        for s in &self.slots {
            enc_val(s, out);
        }
        match self.groups_hint {
            GroupsHint::Const(n) => {
                out.push(0);
                out.extend_from_slice(&n.to_le_bytes());
            }
            GroupsHint::TableRows(t) => {
                out.push(1);
                out.push(t.tag());
            }
        }
        let f = &self.finalize;
        out.push(f.scalar as u8);
        out.push(f.columns.len() as u8);
        for c in &f.columns {
            enc_outcol(c, out);
        }
        enc_opt(&f.having_gt, out, |(a, x), out| {
            out.push(*a);
            out.extend_from_slice(&x.to_le_bytes());
        });
        out.push(f.sort.len() as u8);
        for (c, d) in &f.sort {
            out.push(*c);
            out.push(matches!(d, SortDir::Desc) as u8);
        }
        out.extend_from_slice(&f.limit.to_le_bytes());
    }

    /// Everything `encode` writes with a `u8`/`u16` count or
    /// depth-bounded recursion, checked **before** the bytes hit the
    /// wire: the encoder uses narrowing casts, so an out-of-bounds
    /// structure (258 IN-list entries, a 13-deep expression tree) would
    /// truncate silently and decode to a different — or undecodable —
    /// plan. [`crate::coordinator::service::QueryService::submit_plan`]
    /// rejects such plans up front instead.
    pub fn check_wire_bounds(&self) -> Result<()> {
        fn match_ok(m: &StrMatch) -> Result<()> {
            if let StrMatch::OneOf(vs) = m {
                crate::ensure!(
                    vs.len() <= u8::MAX as usize,
                    "string IN-list has {} entries (wire max {})",
                    vs.len(),
                    u8::MAX
                );
            }
            Ok(())
        }
        fn pred_ok(p: &PredExpr, depth: usize) -> Result<()> {
            crate::ensure!(depth < MAX_DEPTH, "predicate tree too deep to encode");
            match p {
                PredExpr::I32InSet { values, .. } => crate::ensure!(
                    values.len() <= u16::MAX as usize,
                    "i32 IN-set has {} entries (wire max {})",
                    values.len(),
                    u16::MAX
                ),
                PredExpr::Str { m, .. } => match_ok(m)?,
                PredExpr::And(ps) | PredExpr::Or(ps) => {
                    crate::ensure!(
                        ps.len() <= u8::MAX as usize,
                        "conjunct list has {} entries (wire max {})",
                        ps.len(),
                        u8::MAX
                    );
                    for p in ps {
                        pred_ok(p, depth + 1)?;
                    }
                }
                _ => {}
            }
            Ok(())
        }
        fn val_ok(v: &ValExpr, depth: usize) -> Result<()> {
            crate::ensure!(depth < MAX_DEPTH, "value tree too deep to encode");
            if let ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) = v {
                val_ok(a, depth + 1)?;
                val_ok(b, depth + 1)?;
            }
            Ok(())
        }
        fn key_ok(k: &KeyExpr, depth: usize) -> Result<()> {
            crate::ensure!(depth < MAX_DEPTH, "key tree too deep to encode");
            match k {
                KeyExpr::Year(e) => key_ok(e, depth + 1),
                KeyExpr::Pack { hi, lo, .. } => {
                    key_ok(hi, depth + 1)?;
                    key_ok(lo, depth + 1)
                }
                _ => Ok(()),
            }
        }
        crate::ensure!(
            self.joins.len() <= MAX_JOINS,
            "plan has {} joins (max {MAX_JOINS})",
            self.joins.len()
        );
        crate::ensure!(
            (1..=MAX_ACCS).contains(&self.slots.len()),
            "plan width {} outside 1..={MAX_ACCS}",
            self.slots.len()
        );
        crate::ensure!(
            self.cmps.len() <= u8::MAX as usize,
            "plan has {} compares (wire max {})",
            self.cmps.len(),
            u8::MAX
        );
        pred_ok(&self.pred, 0)?;
        for j in &self.joins {
            pred_ok(&j.filter, 0)?;
            crate::ensure!(
                j.payloads.len() <= MAX_ENV,
                "join step has {} payloads (max {MAX_ENV})",
                j.payloads.len()
            );
            for p in &j.payloads {
                match p {
                    Payload::Flag { m, .. } => match_ok(m)?,
                    Payload::CaseConst { cases } => {
                        crate::ensure!(
                            cases.len() <= u8::MAX as usize,
                            "payload has {} cases (wire max {})",
                            cases.len(),
                            u8::MAX
                        );
                        for (cp, _) in cases {
                            pred_ok(cp, 0)?;
                        }
                    }
                    Payload::Col(_) | Payload::FromLink(_) => {}
                }
            }
        }
        for c in &self.cmps {
            val_ok(&c.lhs, 0)?;
            val_ok(&c.rhs, 0)?;
        }
        key_ok(&self.key, 0)?;
        for s in &self.slots {
            val_ok(s, 0)?;
        }
        crate::ensure!(
            self.finalize.columns.len() <= u8::MAX as usize,
            "finalize has {} output columns (wire max {})",
            self.finalize.columns.len(),
            u8::MAX
        );
        crate::ensure!(
            self.finalize.sort.len() <= u8::MAX as usize,
            "finalize has {} sort keys (wire max {})",
            self.finalize.sort.len(),
            u8::MAX
        );
        Ok(())
    }

    /// Exact inverse of [`LogicalPlan::encode`]; rejects truncation,
    /// trailing garbage, unknown tags, and implausible shapes. Decoding
    /// validates *structure* only — name resolution against the attached
    /// database happens in [`compile`].
    pub fn decode(buf: &[u8]) -> Result<LogicalPlan> {
        let mut r = Reader::new(buf);
        let name = r.str()?;
        let scan = TableRef::from_tag(r.u8()?)?;
        let pred = dec_pred(&mut r, 0)?;
        let nj = r.u8()? as usize;
        crate::ensure!(nj <= MAX_JOINS, "implausible join count {nj}");
        let joins = (0..nj).map(|_| dec_join(&mut r)).collect::<Result<Vec<_>>>()?;
        let nc = r.u8()? as usize;
        let cmps = (0..nc)
            .map(|_| {
                let lhs = dec_val(&mut r, 0)?;
                let op = match r.u8()? {
                    0 => CmpOp::Eq,
                    1 => CmpOp::Lt,
                    2 => CmpOp::Le,
                    3 => CmpOp::Ge,
                    4 => CmpOp::Gt,
                    t => crate::bail!("unknown compare op {t}"),
                };
                Ok(CmpExpr { lhs, op, rhs: dec_val(&mut r, 0)? })
            })
            .collect::<Result<Vec<_>>>()?;
        let key = dec_key(&mut r, 0)?;
        let ns = r.u8()? as usize;
        crate::ensure!(
            (1..=MAX_ACCS).contains(&ns),
            "plan width {ns} outside 1..={MAX_ACCS}"
        );
        let slots = (0..ns).map(|_| dec_val(&mut r, 0)).collect::<Result<Vec<_>>>()?;
        let groups_hint = match r.u8()? {
            0 => GroupsHint::Const(r.u32()?),
            1 => GroupsHint::TableRows(TableRef::from_tag(r.u8()?)?),
            t => crate::bail!("unknown groups-hint tag {t}"),
        };
        let scalar = match r.u8()? {
            0 => false,
            1 => true,
            t => crate::bail!("bad scalar flag {t}"),
        };
        let ncols = r.u8()? as usize;
        let columns = (0..ncols).map(|_| dec_outcol(&mut r)).collect::<Result<Vec<_>>>()?;
        let having_gt = dec_opt(&mut r, |r| Ok((r.u8()?, r.f64()?)))?;
        let nsort = r.u8()? as usize;
        let sort = (0..nsort)
            .map(|_| {
                let c = r.u8()?;
                let d = if r.u8()? == 0 { SortDir::Asc } else { SortDir::Desc };
                Ok((c, d))
            })
            .collect::<Result<Vec<_>>>()?;
        let limit = r.u32()?;
        r.finish()?;
        Ok(LogicalPlan {
            name,
            scan,
            pred,
            joins,
            cmps,
            key,
            slots,
            groups_hint,
            finalize: FinalizeSpec { scalar, columns, having_gt, sort, limit },
        })
    }
}

// ------------------------------------------------------ column resolvers

fn column<'a>(t: &'a Table, name: &str) -> Result<&'a Column> {
    crate::ensure!(t.has_col(name), "no column {name} in table {}", t.name);
    Ok(t.col(name))
}

fn i32s<'a>(t: &'a Table, name: &str) -> Result<&'a [i32]> {
    match column(t, name)? {
        Column::I32(v) => Ok(v),
        _ => crate::bail!("column {name} in {} is not i32", t.name),
    }
}

fn f64s<'a>(t: &'a Table, name: &str) -> Result<&'a [f64]> {
    match column(t, name)? {
        Column::F64(v) => Ok(v),
        _ => crate::bail!("column {name} in {} is not f64", t.name),
    }
}

fn str_col<'a>(t: &'a Table, name: &str) -> Result<&'a Column> {
    let c = column(t, name)?;
    crate::ensure!(
        matches!(c, Column::Str { .. }),
        "column {name} in {} is not a string column",
        t.name
    );
    Ok(c)
}

/// Bytes per row one column charges to scan statistics.
fn col_width(c: &Column) -> usize {
    match c {
        Column::I64(_) | Column::F64(_) => 8,
        Column::I32(_) | Column::Str { .. } => 4,
        Column::U8(_) => 1,
    }
}

/// Per-row i64 view of an integral column (group/probe keys).
fn key_leaf<'a>(t: &'a Table, name: &str) -> Result<CKey<'a>> {
    Ok(match column(t, name)? {
        Column::I64(v) => CKey::I64(v),
        Column::I32(v) => CKey::I32(v),
        Column::U8(v) => CKey::U8(v),
        Column::Str { codes, .. } => CKey::Code(codes),
        Column::F64(_) => crate::bail!("column {name} is f64; keys must be integral"),
    })
}

/// Per-row f64 view of a numeric column (aggregate slots, payloads).
fn val_leaf<'a>(t: &'a Table, name: &str) -> Result<CVal<'a>> {
    Ok(match column(t, name)? {
        Column::F64(v) => CVal::F64(v),
        Column::I64(v) => CVal::I64(v),
        Column::I32(v) => CVal::I32(v),
        Column::U8(v) => CVal::U8(v),
        Column::Str { codes, .. } => CVal::Code(codes),
    })
}

/// Materialize an integral column as owned i64 values (hash-build keys;
/// compile-time only).
fn i64_values(t: &Table, name: &str) -> Result<Vec<i64>> {
    Ok(match column(t, name)? {
        Column::I64(v) => v.clone(),
        Column::I32(v) => v.iter().map(|&x| x as i64).collect(),
        Column::U8(v) => v.iter().map(|&x| x as i64).collect(),
        Column::Str { codes, .. } => codes.iter().map(|&x| x as i64).collect(),
        Column::F64(_) => crate::bail!("column {name} is f64; keys must be integral"),
    })
}

/// Materialized build-key values for a [`KeyCols`] over a dim table.
fn build_keys(t: &Table, k: &KeyCols) -> Result<Vec<i64>> {
    match k {
        KeyCols::Col(c) => i64_values(t, c),
        KeyCols::Packed { a, shift, b } => {
            let (av, bv) = (i64_values(t, a)?, i64_values(t, b)?);
            crate::ensure!(*shift < 63, "pack shift {shift} too large");
            Ok(av.iter().zip(&bv).map(|(x, y)| (x << shift) | y).collect())
        }
    }
}

/// Scan-side probe-key evaluator for a [`KeyCols`].
fn probe_key<'a>(t: &'a Table, k: &KeyCols) -> Result<CKey<'a>> {
    match k {
        KeyCols::Col(c) => key_leaf(t, c),
        KeyCols::Packed { a, shift, b } => {
            crate::ensure!(*shift < 63, "pack shift {shift} too large");
            Ok(CKey::Pack {
                hi: Box::new(key_leaf(t, a)?),
                shift: *shift,
                lo: Box::new(key_leaf(t, b)?),
            })
        }
    }
}

/// Column names a [`KeyCols`] reads.
fn keycols_names(k: &KeyCols, out: &mut BTreeSet<String>) {
    match k {
        KeyCols::Col(c) => {
            out.insert(c.clone());
        }
        KeyCols::Packed { a, b, .. } => {
            out.insert(a.clone());
            out.insert(b.clone());
        }
    }
}

fn val_names(v: &ValExpr, out: &mut BTreeSet<String>) {
    match v {
        ValExpr::Col(c) => {
            out.insert(c.clone());
        }
        ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
            val_names(a, out);
            val_names(b, out);
        }
        ValExpr::Const(_) | ValExpr::Payload { .. } => {}
    }
}

fn key_names(k: &KeyExpr, out: &mut BTreeSet<String>) {
    match k {
        KeyExpr::Col(c) => {
            out.insert(c.clone());
        }
        KeyExpr::Year(e) => key_names(e, out),
        KeyExpr::Pack { hi, lo, .. } => {
            key_names(hi, out);
            key_names(lo, out);
        }
        KeyExpr::Const(_) | KeyExpr::Payload { .. } => {}
    }
}

fn pred_names(p: &PredExpr, out: &mut BTreeSet<String>) {
    match p {
        PredExpr::True => {}
        PredExpr::I32Range { col, .. }
        | PredExpr::I32InSet { col, .. }
        | PredExpr::F64Range { col, .. }
        | PredExpr::F64Lt { col, .. }
        | PredExpr::Str { col, .. } => {
            out.insert(col.clone());
        }
        PredExpr::I32ColLt { a, b } => {
            out.insert(a.clone());
            out.insert(b.clone());
        }
        PredExpr::And(ps) | PredExpr::Or(ps) => {
            for p in ps {
                pred_names(p, out);
            }
        }
    }
}

// --------------------------------------------------- compiled evaluators

/// Compiled group/probe-key expression: column leaves resolved to typed
/// slices, payload leaves to environment indices.
enum CKey<'a> {
    Const(i64),
    I64(&'a [i64]),
    I32(&'a [i32]),
    U8(&'a [u8]),
    Code(&'a [u32]),
    Env(usize),
    Year(Box<CKey<'a>>),
    Pack { hi: Box<CKey<'a>>, shift: u8, lo: Box<CKey<'a>> },
}

impl CKey<'_> {
    fn eval(&self, i: usize, env: &[f64; MAX_ENV]) -> i64 {
        match self {
            CKey::Const(v) => *v,
            CKey::I64(s) => s[i],
            CKey::I32(s) => s[i] as i64,
            CKey::U8(s) => s[i] as i64,
            CKey::Code(s) => s[i] as i64,
            CKey::Env(k) => env[*k] as i64,
            CKey::Year(e) => days_to_date(e.eval(i, env) as i32).0 as i64,
            CKey::Pack { hi, shift, lo } => (hi.eval(i, env) << shift) | lo.eval(i, env),
        }
    }
}

/// Compiled arithmetic expression.
enum CVal<'a> {
    Const(f64),
    F64(&'a [f64]),
    I64(&'a [i64]),
    I32(&'a [i32]),
    U8(&'a [u8]),
    Code(&'a [u32]),
    Env(usize),
    Add(Box<CVal<'a>>, Box<CVal<'a>>),
    Sub(Box<CVal<'a>>, Box<CVal<'a>>),
    Mul(Box<CVal<'a>>, Box<CVal<'a>>),
    /// Peephole for `a · (1 − b)` — the revenue shape every query hits.
    MulOneMinus(&'a [f64], &'a [f64]),
}

impl CVal<'_> {
    fn eval(&self, i: usize, env: &[f64; MAX_ENV]) -> f64 {
        match self {
            CVal::Const(x) => *x,
            CVal::F64(s) => s[i],
            CVal::I64(s) => s[i] as f64,
            CVal::I32(s) => s[i] as f64,
            CVal::U8(s) => s[i] as f64,
            CVal::Code(s) => s[i] as f64,
            CVal::Env(k) => env[*k],
            CVal::Add(a, b) => a.eval(i, env) + b.eval(i, env),
            CVal::Sub(a, b) => a.eval(i, env) - b.eval(i, env),
            CVal::Mul(a, b) => a.eval(i, env) * b.eval(i, env),
            CVal::MulOneMinus(a, b) => a[i] * (1.0 - b[i]),
        }
    }
}

/// Compiled post-join conjunct.
struct CCmp<'a> {
    lhs: CVal<'a>,
    op: CmpOp,
    rhs: CVal<'a>,
}

impl CCmp<'_> {
    #[inline]
    fn pass(&self, i: usize, env: &[f64; MAX_ENV]) -> bool {
        let (a, b) = (self.lhs.eval(i, env), self.rhs.eval(i, env));
        match self.op {
            CmpOp::Eq => a == b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Ge => a >= b,
            CmpOp::Gt => a > b,
        }
    }
}

/// One probe-side join step after compilation: the per-row state the
/// generated [`BatchEval`] walks.
struct CStep<'a> {
    key: CKey<'a>,
    /// `Some` = hash probe; `None` = dense (`key − 1` indexes the dim).
    hash: Option<HashJoinTable>,
    /// Dense-side exclusion bitmap (rows failing filter/case/link).
    pass: Option<Vec<bool>>,
    /// Payload value arrays indexed by dim row.
    vals: Vec<Vec<f64>>,
    env_base: usize,
    dim_len: usize,
}

/// Per-step bookkeeping carried through the build phase.
struct Built {
    hash: Option<HashJoinTable>,
    pass: Option<Vec<bool>>,
    vals: Vec<Vec<f64>>,
    dim_len: usize,
    /// `Some(env_base)` when the step is probed per row.
    env_base: Option<usize>,
}

/// Dim-side per-row predicate, compiled once (columns resolved, string
/// matches precomputed per dictionary entry).
fn dim_pred<'a>(p: &PredExpr, t: &'a Table) -> Result<Box<dyn Fn(usize) -> bool + 'a>> {
    Ok(match p {
        PredExpr::True => Box::new(|_| true),
        PredExpr::I32Range { col, lo, hi } => {
            let c = i32s(t, col)?;
            let (lo, hi) = (*lo, *hi);
            Box::new(move |i| {
                let v = c[i];
                v >= lo && v < hi
            })
        }
        PredExpr::I32ColLt { a, b } => {
            let (a, b) = (i32s(t, a)?, i32s(t, b)?);
            Box::new(move |i| a[i] < b[i])
        }
        PredExpr::I32InSet { col, values } => {
            let c = i32s(t, col)?;
            let vs = values.clone();
            Box::new(move |i| vs.contains(&c[i]))
        }
        PredExpr::F64Range { col, lo, hi } => {
            let c = f64s(t, col)?;
            let (lo, hi) = (*lo, *hi);
            Box::new(move |i| {
                let v = c[i];
                v >= lo && v < hi
            })
        }
        PredExpr::F64Lt { col, x } => {
            let c = f64s(t, col)?;
            let x = *x;
            Box::new(move |i| c[i] < x)
        }
        PredExpr::Str { col, m } => {
            let (dict, codes) = str_col(t, col)?.as_str_codes();
            let ok: Vec<bool> = dict.iter().map(|s| m.matches(s)).collect();
            Box::new(move |i| ok[codes[i] as usize])
        }
        PredExpr::And(ps) => {
            let fs: Vec<_> = ps.iter().map(|p| dim_pred(p, t)).collect::<Result<_>>()?;
            Box::new(move |i| fs.iter().all(|f| f(i)))
        }
        PredExpr::Or(ps) => {
            let fs: Vec<_> = ps.iter().map(|p| dim_pred(p, t)).collect::<Result<_>>()?;
            Box::new(move |i| fs.iter().any(|f| f(i)))
        }
    })
}

/// Lower a scan predicate onto the engine's vectorized [`Predicate`]
/// cascade. Conjunctive subset only: `Or` is a dimension-side
/// construct.
fn lower_scan_pred<'a>(p: &PredExpr, t: &'a Table) -> Result<Predicate<'a>> {
    Ok(match p {
        PredExpr::True => Predicate::True,
        PredExpr::I32Range { col, lo, hi } => Predicate::i32_range(i32s(t, col)?, *lo, *hi),
        PredExpr::I32ColLt { a, b } => Predicate::i32_col_lt(i32s(t, a)?, i32s(t, b)?),
        PredExpr::I32InSet { col, values } => {
            Predicate::i32_in_set(i32s(t, col)?, values.clone())
        }
        PredExpr::F64Range { col, lo, hi } => Predicate::f64_range(f64s(t, col)?, *lo, *hi),
        PredExpr::F64Lt { col, x } => Predicate::f64_lt(f64s(t, col)?, *x),
        PredExpr::Str { col, m } => Predicate::code_matches(str_col(t, col)?, |s| m.matches(s)),
        PredExpr::And(ps) => Predicate::and(
            ps.iter().map(|p| lower_scan_pred(p, t)).collect::<Result<Vec<_>>>()?,
        ),
        PredExpr::Or(_) => {
            crate::bail!("OR predicates are dimension-side only (the scan cascade is conjunctive)")
        }
    })
}

/// A payload slot's per-dim-row source during the build loop.
enum PaySrc<'a> {
    Val(Box<dyn Fn(usize) -> f64 + 'a>),
    /// First matching case's constant; no match excludes the row.
    Case(Vec<(Box<dyn Fn(usize) -> bool + 'a>, f64)>),
    /// Payload slot of the linked step, read through the link match.
    Link(usize),
}

/// Payload environment layout across probed steps.
struct EnvMap {
    /// Per join step: `Some((env_base, n_payloads))` when probed.
    slots: Vec<Option<(usize, usize)>>,
}

impl EnvMap {
    fn index(&self, step: u8, slot: u8) -> Result<usize> {
        let (base, n) = self
            .slots
            .get(step as usize)
            .and_then(|s| *s)
            .ok_or_else(|| {
                crate::err!("payload reference to step {step}, which is not probed")
            })?;
        crate::ensure!(
            (slot as usize) < n,
            "payload slot {slot} out of range for step {step} ({n} payloads)"
        );
        Ok(base + slot as usize)
    }
}

fn compile_val<'a>(e: &ValExpr, scan: &'a Table, env: &EnvMap) -> Result<CVal<'a>> {
    // Peephole: Col(a) * (Const(1) - Col(b)) over f64 columns.
    if let ValExpr::Mul(a, b) = e {
        if let (ValExpr::Col(ca), ValExpr::Sub(s1, s2)) = (&**a, &**b) {
            if let (ValExpr::Const(one), ValExpr::Col(cb)) = (&**s1, &**s2) {
                if *one == 1.0 {
                    if let (Ok(av), Ok(bv)) = (f64s(scan, ca), f64s(scan, cb)) {
                        return Ok(CVal::MulOneMinus(av, bv));
                    }
                }
            }
        }
    }
    Ok(match e {
        ValExpr::Const(x) => CVal::Const(*x),
        ValExpr::Col(c) => val_leaf(scan, c)?,
        ValExpr::Payload { step, slot } => CVal::Env(env.index(*step, *slot)?),
        ValExpr::Add(a, b) => CVal::Add(
            Box::new(compile_val(a, scan, env)?),
            Box::new(compile_val(b, scan, env)?),
        ),
        ValExpr::Sub(a, b) => CVal::Sub(
            Box::new(compile_val(a, scan, env)?),
            Box::new(compile_val(b, scan, env)?),
        ),
        ValExpr::Mul(a, b) => CVal::Mul(
            Box::new(compile_val(a, scan, env)?),
            Box::new(compile_val(b, scan, env)?),
        ),
    })
}

fn compile_key<'a>(e: &KeyExpr, scan: &'a Table, env: &EnvMap) -> Result<CKey<'a>> {
    Ok(match e {
        KeyExpr::Const(v) => CKey::Const(*v),
        KeyExpr::Col(c) => key_leaf(scan, c)?,
        KeyExpr::Payload { step, slot } => CKey::Env(env.index(*step, *slot)?),
        KeyExpr::Year(e) => CKey::Year(Box::new(compile_key(e, scan, env)?)),
        KeyExpr::Pack { hi, shift, lo } => {
            crate::ensure!(*shift < 63, "pack shift {shift} too large");
            CKey::Pack {
                hi: Box::new(compile_key(hi, scan, env)?),
                shift: *shift,
                lo: Box::new(compile_key(lo, scan, env)?),
            }
        }
    })
}

/// Build one join step's dim-side state: filter + link + payload arrays,
/// and (for hash steps) the probe table over passing rows.
fn build_step(db: &TpchDb, j: &JoinStep, built: &[Built], stats: &mut ExecStats) -> Result<Built> {
    let t = table(db, j.table);
    let dim_len = t.len();
    // Per-step bound, checked BEFORE the build loop writes its MAX_ENV
    // scratch (the whole-plan env budget is re-checked across steps in
    // `compile`).
    crate::ensure!(
        j.payloads.len() <= MAX_ENV,
        "join step has {} payloads (max {MAX_ENV})",
        j.payloads.len()
    );
    if j.dense {
        crate::ensure!(j.build_key.is_none(), "dense steps take no build key");
        crate::ensure!(j.link.is_none(), "dense steps cannot link");
        crate::ensure!(j.probe_key.is_some(), "dense steps must be probed");
    } else {
        crate::ensure!(j.build_key.is_some(), "hash steps need a build key");
    }
    let filter = dim_pred(&j.filter, t)?;

    // Link resolution: the target must be an earlier hash step.
    let link = match &j.link {
        None => None,
        Some(l) => {
            let target = built.get(l.step as usize).ok_or_else(|| {
                crate::err!("link to step {}, which is not earlier in the chain", l.step)
            })?;
            let hash = target
                .hash
                .as_ref()
                .ok_or_else(|| crate::err!("link target step {} is dense", l.step))?;
            let via = i64_values(t, &l.via)?;
            Some((hash, &target.vals, via))
        }
    };

    // Payload sources.
    let mut srcs: Vec<PaySrc<'_>> = Vec::with_capacity(j.payloads.len());
    for p in &j.payloads {
        srcs.push(match p {
            Payload::Col(c) => {
                let leaf = val_leaf(t, c)?;
                PaySrc::Val(Box::new(move |i| leaf.eval(i, &[0.0; MAX_ENV])))
            }
            Payload::Flag { col, m } => {
                let (dict, codes) = str_col(t, col)?.as_str_codes();
                let ok: Vec<bool> = dict.iter().map(|s| m.matches(s)).collect();
                PaySrc::Val(Box::new(move |i| ok[codes[i] as usize] as u8 as f64))
            }
            Payload::CaseConst { cases } => {
                let compiled = cases
                    .iter()
                    .map(|(p, v)| Ok((dim_pred(p, t)?, *v)))
                    .collect::<Result<Vec<_>>>()?;
                PaySrc::Case(compiled)
            }
            Payload::FromLink(k) => {
                let (_, vals, _) = link
                    .as_ref()
                    .ok_or_else(|| crate::err!("FromLink payload without a link"))?;
                crate::ensure!(
                    (*k as usize) < vals.len(),
                    "FromLink slot {k} out of range ({} link payloads)",
                    vals.len()
                );
                PaySrc::Link(*k as usize)
            }
        });
    }

    // Charge the filter scan. CaseConst case predicates run for every
    // row that reaches them, so their columns are part of this pass
    // (the hand-written Q19 charged its brand/container/size read the
    // same way).
    let mut filter_cols = BTreeSet::new();
    pred_names(&j.filter, &mut filter_cols);
    for p in &j.payloads {
        if let Payload::CaseConst { cases } = p {
            for (cp, _) in cases {
                pred_names(cp, &mut filter_cols);
            }
        }
    }
    if let Some(l) = &j.link {
        filter_cols.insert(l.via.clone());
    }
    let filter_bytes: usize =
        filter_cols.iter().map(|c| column(t, c).map(col_width).unwrap_or(0)).sum();
    stats.scan(dim_len, filter_bytes);

    // The build loop: decide pass/exclusion per dim row, fill payloads.
    let mut vals: Vec<Vec<f64>> = (0..j.payloads.len()).map(|_| vec![0.0; dim_len]).collect();
    let mut pass = vec![false; dim_len];
    let mut sel: Vec<u32> = Vec::new();
    'rows: for r in 0..dim_len {
        if !filter(r) {
            continue;
        }
        let link_row = match &link {
            None => usize::MAX,
            Some((hash, _, via)) => match hash.probe_first(via[r]) {
                Some(r2) => r2 as usize,
                None => continue,
            },
        };
        // Compute payloads into a scratch first: a CaseConst miss must
        // exclude the row without partially writing it.
        let mut tmp = [0.0f64; MAX_ENV];
        for (k, s) in srcs.iter().enumerate() {
            tmp[k] = match s {
                PaySrc::Val(f) => f(r),
                PaySrc::Case(cases) => match cases.iter().find(|(p, _)| p(r)) {
                    Some((_, v)) => *v,
                    None => continue 'rows,
                },
                // lint: allow(no-panic-worker) compile_scan validated that every Link src has a link table
                PaySrc::Link(k2) => link.as_ref().expect("validated").1[*k2][link_row],
            };
        }
        pass[r] = true;
        sel.push(r as u32);
        for (k, v) in vals.iter_mut().enumerate() {
            v[r] = tmp[k];
        }
    }

    // Charge the build-side scan over passing rows: key + payload cols.
    let mut build_cols = BTreeSet::new();
    if let Some(k) = &j.build_key {
        keycols_names(k, &mut build_cols);
    }
    for p in &j.payloads {
        match p {
            Payload::Col(c) | Payload::Flag { col: c, .. } => {
                build_cols.insert(c.clone());
            }
            Payload::CaseConst { .. } | Payload::FromLink(_) => {}
        }
    }
    let build_bytes: usize =
        build_cols.iter().map(|c| column(t, c).map(col_width).unwrap_or(0)).sum();
    stats.scan(sel.len(), build_bytes);

    let excluded_any = sel.len() < dim_len;
    let hash = match &j.build_key {
        None => None,
        Some(k) => {
            let keys = build_keys(t, k)?;
            Some(HashJoinTable::build_dim(&keys, &sel, stats))
        }
    };
    Ok(Built {
        hash,
        pass: if j.dense && excluded_any { Some(pass) } else { None },
        vals,
        dim_len,
        env_base: None,
    })
}

/// Distinct scan columns the probe phase reads beyond the predicate:
/// probe keys, group key, aggregate slots, compare conjuncts — the
/// `payload_bytes` charged per selected row.
fn payload_bytes(plan: &LogicalPlan, scan: &Table) -> usize {
    let mut cols = BTreeSet::new();
    for j in &plan.joins {
        if let Some(k) = &j.probe_key {
            keycols_names(k, &mut cols);
        }
    }
    key_names(&plan.key, &mut cols);
    for s in &plan.slots {
        val_names(s, &mut cols);
    }
    for c in &plan.cmps {
        val_names(&c.lhs, &mut cols);
        val_names(&c.rhs, &mut cols);
    }
    let mut pred_cols = BTreeSet::new();
    pred_names(&plan.pred, &mut pred_cols);
    cols.iter()
        .filter(|c| !pred_cols.contains(*c))
        .map(|c| column(scan, c).map(col_width).unwrap_or(0))
        .sum()
}

/// Lower a [`LogicalPlan`] onto the engine's hot path: build the
/// dimension state once, generate the plan's [`BatchEval`], return the
/// same [`Compiled`] context hand-written queries used to produce. Fails
/// (never panics) on malformed plans — unknown columns, type mismatches,
/// dangling payload references — so a worker can reject a bad wire plan
/// with an error frame.
pub fn compile<'a>(db: &'a TpchDb, plan: &LogicalPlan) -> Result<(Compiled<'a>, ExecStats)> {
    compile_scan(db, plan, table(db, plan.scan), true)
}

/// [`compile`] with zone-map pruning disabled: the equality baseline for
/// the pruning property tests and a hatch for debugging a suspect map.
pub fn compile_unpruned<'a>(
    db: &'a TpchDb,
    plan: &LogicalPlan,
) -> Result<(Compiled<'a>, ExecStats)> {
    compile_scan(db, plan, table(db, plan.scan), false)
}

/// [`compile`] against an explicit scan table: distributed workers hand
/// in a locally *generated* lineitem shard here instead of a table
/// resolved from `db`, so the scan side never has to exist in `db` at
/// full size. Dimension builds still resolve against `db`. With `prune`
/// set, a zone map on `scan` becomes a [`PrunePlan`] over the intervals
/// the plan's predicate and compare conjuncts imply.
pub fn compile_scan<'a>(
    db: &'a TpchDb,
    plan: &LogicalPlan,
    scan: &'a Table,
    prune: bool,
) -> Result<(Compiled<'a>, ExecStats)> {
    let width = plan.slots.len();
    crate::ensure!(
        (1..=MAX_ACCS).contains(&width),
        "plan width {width} outside 1..={MAX_ACCS}"
    );
    crate::ensure!(
        plan.joins.len() <= MAX_JOINS,
        "plan has {} joins (max {MAX_JOINS})",
        plan.joins.len()
    );

    let mut stats = ExecStats::default();
    let pred = lower_scan_pred(&plan.pred, scan)?;

    // Build the dimension chain, assigning env space to probed steps.
    let mut built: Vec<Built> = Vec::with_capacity(plan.joins.len());
    let mut env_off = 0usize;
    for j in &plan.joins {
        let mut b = build_step(db, j, &built, &mut stats)?;
        if j.probe_key.is_some() {
            b.env_base = Some(env_off);
            env_off += j.payloads.len();
        }
        built.push(b);
    }
    crate::ensure!(
        env_off <= MAX_ENV,
        "plan needs {env_off} payload slots (max {MAX_ENV})"
    );
    let env = EnvMap {
        slots: built
            .iter()
            .map(|b| b.env_base.map(|base| (base, b.vals.len())))
            .collect(),
    };

    // Probe-side steps, in chain order.
    let mut steps: Vec<CStep<'a>> = Vec::new();
    for (j, b) in plan.joins.iter().zip(built) {
        let Some(pk) = &j.probe_key else { continue };
        steps.push(CStep {
            key: probe_key(scan, pk)?,
            hash: b.hash,
            pass: b.pass,
            vals: b.vals,
            // lint: allow(no-panic-worker) build() sets env_base for every join with a probe_key
            env_base: b.env_base.expect("probed step has env"),
            dim_len: b.dim_len,
        });
    }

    let cmps: Vec<CCmp<'a>> = plan
        .cmps
        .iter()
        .map(|c| {
            Ok(CCmp {
                lhs: compile_val(&c.lhs, scan, &env)?,
                op: c.op,
                rhs: compile_val(&c.rhs, scan, &env)?,
            })
        })
        .collect::<Result<_>>()?;
    let key = compile_key(&plan.key, scan, &env)?;
    let slots: Vec<CVal<'a>> = plan
        .slots
        .iter()
        .map(|s| compile_val(s, scan, &env))
        .collect::<Result<_>>()?;

    // Finalize references are leader-side, but validate accumulator
    // indexes here so a bad plan fails at compile, not mid-query — and
    // charge the dense decoration columns finalize will read (Q18's
    // custkey/date/totalprice gathers are real scans the contention
    // model must see).
    validate_finalize(&plan.finalize, width)?;
    for c in &plan.finalize.columns {
        if let OutCol::DimInt { table: tr, col } | OutCol::DimFloat { table: tr, col } = c {
            let t = table(db, *tr);
            stats.scan(t.len(), column(t, col).map(col_width).unwrap_or(0));
        }
    }

    let pb = payload_bytes(plan, scan);
    let groups_hint = match plan.groups_hint {
        GroupsHint::Const(n) => (n as usize).max(1),
        GroupsHint::TableRows(t) => table(db, t).len().max(1),
    };

    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let mut penv = [0.0f64; MAX_ENV];
            for s in &steps {
                let k = s.key.eval(i, &penv);
                let row = match &s.hash {
                    Some(t) => match t.probe_first(k) {
                        Some(r) => r as usize,
                        None => return,
                    },
                    None => {
                        if k < 1 || k as usize > s.dim_len {
                            return;
                        }
                        let r = (k - 1) as usize;
                        if let Some(p) = &s.pass {
                            if !p[r] {
                                return;
                            }
                        }
                        r
                    }
                };
                for (j, v) in s.vals.iter().enumerate() {
                    penv[s.env_base + j] = v[row];
                }
            }
            for c in &cmps {
                if !c.pass(i, &penv) {
                    return;
                }
            }
            out.keys.push(key.eval(i, &penv));
            for (w, slot) in slots.iter().enumerate() {
                out.cols[w].push(slot.eval(i, &penv));
            }
        });
    });

    let prune = if prune { prune_plan(plan, scan) } else { PrunePlan::none() };
    Ok((Compiled { pred, payload_bytes: pb, eval, groups_hint, prune }, stats))
}

// ------------------------------------------------- zone-map derivation

/// Intersect `[lo, hi]` into the interval recorded for `col`.
fn narrow(iv: &mut Vec<(String, f64, f64)>, col: &str, lo: f64, hi: f64) {
    match iv.iter_mut().find(|(c, _, _)| c == col) {
        Some((_, l, h)) => {
            *l = l.max(lo);
            *h = h.min(hi);
        }
        None => iv.push((col.to_string(), lo, hi)),
    }
}

/// Per-column closed intervals implied by a scan predicate tree.
/// Conservative: conjunctive range/less-than leaves contribute their
/// window, `I32InSet` its `[min, max]` hull (values between set members
/// keep a chunk alive — sound, merely not tight); `Or`, string matches
/// and column-column comparisons contribute nothing (never prune on
/// them).
fn pred_intervals(p: &PredExpr, iv: &mut Vec<(String, f64, f64)>) {
    match p {
        PredExpr::I32Range { col, lo, hi } => {
            // Half-open int window: the largest admissible value is hi-1.
            narrow(iv, col, *lo as f64, (*hi - 1) as f64);
        }
        PredExpr::I32InSet { col, values } => {
            // Hull of the set. An empty set admits no row at all, and
            // the inverted interval [∞, −∞] is disjoint from every
            // zone — all chunks prune, which is exactly right.
            let lo = values.iter().copied().min().map_or(f64::INFINITY, |v| v as f64);
            let hi = values.iter().copied().max().map_or(f64::NEG_INFINITY, |v| v as f64);
            narrow(iv, col, lo, hi);
        }
        PredExpr::F64Range { col, lo, hi } => narrow(iv, col, *lo, *hi),
        PredExpr::F64Lt { col, x } => narrow(iv, col, f64::NEG_INFINITY, *x),
        PredExpr::And(cs) => {
            for c in cs {
                pred_intervals(c, iv);
            }
        }
        PredExpr::True | PredExpr::I32ColLt { .. } | PredExpr::Str { .. } | PredExpr::Or(_) => {}
    }
}

/// Closed-interval hull of a [`ValExpr`]'s possible values, when it is
/// independent of the scan row: a constant, or a payload slot fed by a
/// [`Payload::CaseConst`] (whose value is always one of the case
/// constants — a no-match excludes the row entirely).
fn val_hull(v: &ValExpr, plan: &LogicalPlan) -> Option<(f64, f64)> {
    match v {
        ValExpr::Const(x) => Some((*x, *x)),
        ValExpr::Payload { step, slot } => {
            let j = plan.joins.get(*step as usize)?;
            match j.payloads.get(*slot as usize)? {
                Payload::CaseConst { cases } if !cases.is_empty() => {
                    let mut lo = f64::INFINITY;
                    let mut hi = f64::NEG_INFINITY;
                    for (_, x) in cases {
                        lo = lo.min(*x);
                        hi = hi.max(*x);
                    }
                    Some((lo, hi))
                }
                _ => None,
            }
        }
        _ => None,
    }
}

/// Interval a compare conjunct implies for a bare scan column on one
/// side, given the hull of the other side. `Lt`/`Gt` keep the closed
/// bound — sound (never prunes a satisfying chunk), merely not tight.
fn cmp_intervals(c: &CmpExpr, plan: &LogicalPlan, iv: &mut Vec<(String, f64, f64)>) {
    let (col, op, hull) = match (&c.lhs, &c.rhs) {
        (ValExpr::Col(col), _) => match val_hull(&c.rhs, plan) {
            Some(h) => (col, c.op, h),
            None => return,
        },
        (_, ValExpr::Col(col)) => match val_hull(&c.lhs, plan) {
            // Mirror: `hull op col` reads as `col op' hull`.
            Some(h) => {
                let op = match c.op {
                    CmpOp::Eq => CmpOp::Eq,
                    CmpOp::Lt => CmpOp::Gt,
                    CmpOp::Le => CmpOp::Ge,
                    CmpOp::Ge => CmpOp::Le,
                    CmpOp::Gt => CmpOp::Lt,
                };
                (col, op, h)
            }
            None => return,
        },
        _ => return,
    };
    let (rlo, rhi) = hull;
    match op {
        CmpOp::Eq => narrow(iv, col, rlo, rhi),
        CmpOp::Lt | CmpOp::Le => narrow(iv, col, f64::NEG_INFINITY, rhi),
        CmpOp::Ge | CmpOp::Gt => narrow(iv, col, rlo, f64::INFINITY),
    }
}

/// Build the scan's [`PrunePlan`]: derive column intervals from the
/// plan, keep the ones the table's zone map actually covers. Returns an
/// inactive plan when the table has no zone map or nothing derives.
fn prune_plan<'a>(plan: &LogicalPlan, scan: &'a Table) -> PrunePlan<'a> {
    let Some(zm) = scan.zones() else {
        return PrunePlan::none();
    };
    if zm.chunk_rows() == 0 {
        return PrunePlan::none();
    }
    let mut iv: Vec<(String, f64, f64)> = Vec::new();
    pred_intervals(&plan.pred, &mut iv);
    for c in &plan.cmps {
        cmp_intervals(c, plan, &mut iv);
    }
    let checks: Vec<PruneCheck<'a>> = iv
        .iter()
        .filter_map(|(col, lo, hi)| zm.col(col).map(|z| PruneCheck::new(z, *lo, *hi)))
        .collect();
    if checks.is_empty() {
        PrunePlan::none()
    } else {
        PrunePlan::new(zm.chunk_rows(), checks)
    }
}

// ------------------------------------------------- plan introspection

/// Closed per-column intervals the pruning derivation extracts from the
/// plan's scan predicate and compare conjuncts — exactly what
/// [`compile`] crosses with the scan table's zone map. Public so the
/// SQL front-end's `explain` can show which chunks a plan could skip.
pub fn derived_intervals(plan: &LogicalPlan) -> Vec<(String, f64, f64)> {
    let mut iv = Vec::new();
    pred_intervals(&plan.pred, &mut iv);
    for c in &plan.cmps {
        cmp_intervals(c, plan, &mut iv);
    }
    iv
}

/// Per-column closed intervals implied by one predicate tree in
/// isolation (a join step's dimension filter) — build-side prune
/// potential for `explain`, crossed against the dimension table's zone
/// map by the caller.
pub fn filter_intervals(filter: &PredExpr) -> Vec<(String, f64, f64)> {
    let mut iv = Vec::new();
    pred_intervals(filter, &mut iv);
    iv
}

fn fmt_strmatch(col: &str, m: &StrMatch) -> String {
    match m {
        StrMatch::Eq(v) => format!("{col} = '{v}'"),
        StrMatch::Prefix(v) => format!("{col} like '{v}%'"),
        StrMatch::Contains(v) => format!("{col} like '%{v}%'"),
        StrMatch::OneOf(vs) => {
            let vs: Vec<String> = vs.iter().map(|v| format!("'{v}'")).collect();
            format!("{col} in ({})", vs.join(", "))
        }
    }
}

/// Render a predicate tree as a compact SQL-ish string (`explain`).
pub fn fmt_pred(p: &PredExpr) -> String {
    match p {
        PredExpr::True => "true".into(),
        PredExpr::I32Range { col, lo, hi } => format!("{col} in [{lo}, {hi})"),
        PredExpr::I32ColLt { a, b } => format!("{a} < {b}"),
        PredExpr::I32InSet { col, values } => {
            let vs: Vec<String> = values.iter().map(|v| v.to_string()).collect();
            format!("{col} in ({})", vs.join(", "))
        }
        PredExpr::F64Range { col, lo, hi } => format!("{col} in [{lo}, {hi})"),
        PredExpr::F64Lt { col, x } => format!("{col} < {x}"),
        PredExpr::Str { col, m } => fmt_strmatch(col, m),
        PredExpr::And(ps) => {
            let ps: Vec<String> = ps.iter().map(fmt_pred).collect();
            format!("({})", ps.join(" and "))
        }
        PredExpr::Or(ps) => {
            let ps: Vec<String> = ps.iter().map(fmt_pred).collect();
            format!("({})", ps.join(" or "))
        }
    }
}

/// Render an arithmetic expression (`explain`).
pub fn fmt_val(v: &ValExpr) -> String {
    match v {
        ValExpr::Const(x) => format!("{x}"),
        ValExpr::Col(c) => c.clone(),
        ValExpr::Payload { step, slot } => format!("join{step}.p{slot}"),
        ValExpr::Add(a, b) => format!("({} + {})", fmt_val(a), fmt_val(b)),
        ValExpr::Sub(a, b) => format!("({} - {})", fmt_val(a), fmt_val(b)),
        ValExpr::Mul(a, b) => format!("({} * {})", fmt_val(a), fmt_val(b)),
    }
}

/// Render a group-key expression (`explain`).
pub fn fmt_key(k: &KeyExpr) -> String {
    match k {
        KeyExpr::Const(v) => format!("{v}"),
        KeyExpr::Col(c) => c.clone(),
        KeyExpr::Payload { step, slot } => format!("join{step}.p{slot}"),
        KeyExpr::Year(e) => format!("year({})", fmt_key(e)),
        KeyExpr::Pack { hi, shift, lo } => {
            format!("({} << {shift} | {})", fmt_key(hi), fmt_key(lo))
        }
    }
}

fn fmt_keycols(k: &KeyCols) -> String {
    match k {
        KeyCols::Col(c) => c.clone(),
        KeyCols::Packed { a, shift, b } => format!("({a} << {shift} | {b})"),
    }
}

impl LogicalPlan {
    /// Multi-line plan tree for `explain` — every operator the compiled
    /// evaluator will run, in execution order, one indented line each.
    pub fn pretty(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "plan {:?} ({} slots)", self.name, self.slots.len());
        let _ = writeln!(s, "  scan {}", self.scan.name());
        let _ = writeln!(s, "    pred {}", fmt_pred(&self.pred));
        for (i, j) in self.joins.iter().enumerate() {
            let kind = if j.dense { "dense" } else { "hash" };
            let probe = match (&j.probe_key, &j.link) {
                (Some(k), _) => format!(" probe {}", fmt_keycols(k)),
                (None, Some(_)) => String::new(),
                (None, None) => " probe ?".into(),
            };
            let build = j.build_key.as_ref().map(|k| format!(" build {}", fmt_keycols(k)));
            let _ = writeln!(
                s,
                "  join[{i}] {kind} {}{}{}",
                j.table.name(),
                probe,
                build.unwrap_or_default()
            );
            if j.filter != PredExpr::True {
                let _ = writeln!(s, "    filter {}", fmt_pred(&j.filter));
            }
            if let Some(l) = &j.link {
                let _ = writeln!(s, "    link join[{}] via {}", l.step, l.via);
            }
            for (k, p) in j.payloads.iter().enumerate() {
                let desc = match p {
                    Payload::Col(c) => c.clone(),
                    Payload::Flag { col, m } => format!("flag({})", fmt_strmatch(col, m)),
                    Payload::CaseConst { cases } => format!("case({} arms)", cases.len()),
                    Payload::FromLink(slot) => format!("link.p{slot}"),
                };
                let _ = writeln!(s, "    payload p{k} = {desc}");
            }
        }
        for c in &self.cmps {
            let op = match c.op {
                CmpOp::Eq => "=",
                CmpOp::Lt => "<",
                CmpOp::Le => "<=",
                CmpOp::Ge => ">=",
                CmpOp::Gt => ">",
            };
            let _ = writeln!(s, "  cmp {} {op} {}", fmt_val(&c.lhs), fmt_val(&c.rhs));
        }
        let _ = writeln!(s, "  group by {}", fmt_key(&self.key));
        for (i, v) in self.slots.iter().enumerate() {
            let _ = writeln!(s, "    acc[{i}] += {}", fmt_val(v));
        }
        let f = &self.finalize;
        let _ = writeln!(
            s,
            "  finalize {} cols{}{}{}{}",
            f.columns.len(),
            if f.scalar { ", scalar" } else { "" },
            match f.having_gt {
                Some((a, x)) => format!(", having acc[{a}] > {x}"),
                None => String::new(),
            },
            if f.sort.is_empty() { String::new() } else { format!(", sort {} keys", f.sort.len()) },
            if f.limit > 0 { format!(", limit {}", f.limit) } else { String::new() },
        );
        s
    }
}

/// Validate a finalize spec against the plan's accumulator width.
fn validate_finalize(f: &FinalizeSpec, width: usize) -> Result<()> {
    let acc_ok = |k: u8| -> Result<()> {
        crate::ensure!((k as usize) < width, "finalize references acc {k}, width is {width}");
        Ok(())
    };
    for c in &f.columns {
        match c {
            OutCol::Acc(k) | OutCol::AccInt(k) | OutCol::AccOverCount(k) => acc_ok(*k)?,
            OutCol::AccRatioPct(a, b) => {
                acc_ok(*a)?;
                acc_ok(*b)?;
            }
            _ => {}
        }
    }
    if let Some((a, _)) = f.having_gt {
        acc_ok(a)?;
    }
    for (c, _) in &f.sort {
        crate::ensure!(
            (*c as usize) < f.columns.len(),
            "sort key {c} out of range ({} output columns)",
            f.columns.len()
        );
    }
    Ok(())
}

// ------------------------------------------------------------- finalize

/// Interpret a [`FinalizeSpec`] over the merged partial: emit output
/// columns per group (with dense dimension decoration through the
/// leader's attached tables), apply having, sort, and top-k. Scalar
/// specs emit exactly one row even from an empty partial. Fails (never
/// panics) on malformed specs or out-of-range keys.
pub fn finalize(db: &TpchDb, f: &FinalizeSpec, p: &Partial) -> Result<Vec<Row>> {
    validate_finalize(f, p.width.max(1))?;
    let mut rows: Vec<Row> = Vec::new();
    if f.scalar {
        // One row, from the single group or zeros: Q6/Q14/Q19 report 0
        // revenue on an empty window rather than no rows. More than one
        // group means the plan's key expression was not scalar-shaped —
        // picking group 0 would return a merge-order-dependent answer,
        // so reject the plan instead.
        crate::ensure!(
            p.len() <= 1,
            "scalar finalize over {} groups (the group key is not constant)",
            p.len()
        );
        let zeros = [0.0; MAX_ACCS];
        let (key, accs, cnt) = if p.is_empty() {
            (0, zeros.as_slice(), 0)
        } else {
            (p.keys[0], p.acc(0), p.counts[0])
        };
        rows.push(emit_row(db, f, key, accs, cnt)?);
        return Ok(rows);
    }
    for gi in 0..p.len() {
        if let Some((a, x)) = f.having_gt {
            if p.acc(gi)[a as usize] <= x {
                continue;
            }
        }
        rows.push(emit_row(db, f, p.keys[gi], p.acc(gi), p.counts[gi])?);
    }
    sort_rows(&mut rows, &f.sort);
    if f.limit > 0 {
        rows.truncate(f.limit as usize);
    }
    Ok(rows)
}

/// `(key >> shift) & mask(bits)`; `bits == 0` keeps every bit.
fn key_field(key: i64, shift: u8, bits: u8) -> i64 {
    let s = key >> shift.min(63);
    if bits == 0 || bits >= 63 {
        s
    } else {
        s & ((1i64 << bits) - 1)
    }
}

fn emit_row(db: &TpchDb, f: &FinalizeSpec, key: i64, accs: &[f64], cnt: u64) -> Result<Row> {
    f.columns.iter().map(|c| out_cell(db, c, key, accs, cnt)).collect()
}

fn out_cell(db: &TpchDb, c: &OutCol, key: i64, accs: &[f64], cnt: u64) -> Result<Value> {
    Ok(match c {
        OutCol::KeyInt { shift, bits } => Value::Int(key_field(key, *shift, *bits)),
        OutCol::KeyChar { shift } => {
            Value::Str(((key_field(key, *shift, 8) as u8) as char).to_string())
        }
        OutCol::KeyNation { shift, bits } => {
            let idx = key_field(key, *shift, *bits);
            crate::ensure!(
                (0..NATIONS.len() as i64).contains(&idx),
                "nation index {idx} out of range"
            );
            Value::Str(NATIONS[idx as usize].0.to_string())
        }
        OutCol::KeyDict { table: tr, col } => {
            let (dict, _) = str_col(table(db, *tr), col)?.as_str_codes();
            crate::ensure!(
                (0..dict.len() as i64).contains(&key),
                "dictionary key {key} out of range for {col}"
            );
            Value::Str(dict[key as usize].clone())
        }
        OutCol::Acc(k) => Value::Float(accs[*k as usize]),
        OutCol::AccInt(k) => Value::Int(accs[*k as usize] as i64),
        OutCol::Count => Value::Int(cnt as i64),
        OutCol::AccOverCount(k) => Value::Float(if cnt == 0 {
            0.0
        } else {
            accs[*k as usize] / cnt as f64
        }),
        OutCol::AccRatioPct(a, b) => {
            let (x, y) = (accs[*a as usize], accs[*b as usize]);
            Value::Float(if y > 0.0 { 100.0 * x / y } else { 0.0 })
        }
        OutCol::DimInt { table: tr, col } => {
            let t = table(db, *tr);
            let row = dim_row(key, t.len())?;
            match column(t, col)? {
                Column::I64(v) => Value::Int(v[row]),
                Column::I32(v) => Value::Int(v[row] as i64),
                Column::U8(v) => Value::Int(v[row] as i64),
                _ => crate::bail!("column {col} is not integral"),
            }
        }
        OutCol::DimFloat { table: tr, col } => {
            let t = table(db, *tr);
            let row = dim_row(key, t.len())?;
            Value::Float(f64s(t, col)?[row])
        }
    })
}

/// Dense decoration row: `key − 1`, bounds-checked.
fn dim_row(key: i64, len: usize) -> Result<usize> {
    crate::ensure!(
        key >= 1 && (key as usize) <= len,
        "group key {key} outside dense table of {len} rows"
    );
    Ok((key - 1) as usize)
}

/// Lexicographic stable sort over output cells. Cells in one column
/// share a type by construction; mixed comparisons order arbitrarily
/// (but deterministically) rather than erroring.
fn sort_rows(rows: &mut [Row], sort: &[(u8, SortDir)]) {
    if sort.is_empty() {
        return;
    }
    rows.sort_by(|a, b| {
        for &(c, dir) in sort {
            let ord = cmp_cell(&a[c as usize], &b[c as usize]);
            let ord = if dir == SortDir::Desc { ord.reverse() } else { ord };
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        std::cmp::Ordering::Equal
    });
}

fn cmp_cell(a: &Value, b: &Value) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a, b) {
        (Value::Int(x), Value::Int(y)) => x.cmp(y),
        (Value::Str(x), Value::Str(y)) => x.cmp(y),
        (Value::Str(_), _) | (_, Value::Str(_)) => Ordering::Equal,
        (x, y) => x.as_f64().partial_cmp(&y.as_f64()).unwrap_or(Ordering::Equal),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    fn small_db() -> TpchDb {
        TpchDb::generate(TpchConfig::new(0.001, 7))
    }

    /// A plan exercising every IR construct at once: all predicate
    /// leaves, a hash join with link + payloads, a dense join, cases,
    /// compares, packed keys, and a decorated finalize.
    fn kitchen_sink() -> LogicalPlan {
        LogicalPlan {
            name: "sink".into(),
            scan: TableRef::Lineitem,
            pred: pand(vec![
                i32_range("l_shipdate", 8000, 10000),
                f64_range("l_discount", 0.0, 0.2),
                f64_lt("l_quantity", 60.0),
                i32_col_lt("l_shipdate", "l_receiptdate"),
                str_in("l_shipmode", &["MAIL".into(), "SHIP".into(), "AIR".into()]),
            ]),
            joins: vec![
                JoinStep {
                    table: TableRef::Customer,
                    dense: false,
                    build_key: Some(KeyCols::Col("c_custkey".into())),
                    probe_key: None,
                    filter: por(vec![
                        str_eq("c_mktsegment", "BUILDING"),
                        i32_in("c_nationkey", vec![1, 2, 3]),
                    ]),
                    link: None,
                    payloads: vec![Payload::Col("c_nationkey".into())],
                },
                JoinStep {
                    table: TableRef::Orders,
                    dense: false,
                    build_key: Some(KeyCols::Col("o_orderkey".into())),
                    probe_key: Some(KeyCols::Col("l_orderkey".into())),
                    filter: PredExpr::True,
                    link: Some(LinkRef { step: 0, via: "o_custkey".into() }),
                    payloads: vec![
                        Payload::FromLink(0),
                        Payload::Col("o_orderdate".into()),
                        Payload::Flag {
                            col: "o_orderpriority".into(),
                            m: StrMatch::Prefix("1".into()),
                        },
                    ],
                },
                JoinStep {
                    table: TableRef::Part,
                    dense: true,
                    build_key: None,
                    probe_key: Some(KeyCols::Col("l_partkey".into())),
                    filter: str_contains("p_name", "a"),
                    link: None,
                    payloads: vec![Payload::CaseConst {
                        cases: vec![
                            (i32_range("p_size", 1, 20), 5.0),
                            (i32_range("p_size", 20, 60), 9.0),
                        ],
                    }],
                },
            ],
            cmps: vec![cmp(vpay(2, 0), CmpOp::Ge, vconst(5.0))],
            key: kpack(kpay(1, 0), 16, kyear(kpay(1, 1))),
            slots: vec![vrevenue(), vadd(vpay(1, 2), vconst(0.0))],
            groups_hint: GroupsHint::Const(64),
            finalize: FinalizeSpec {
                scalar: false,
                columns: vec![
                    OutCol::KeyNation { shift: 16, bits: 0 },
                    OutCol::KeyInt { shift: 0, bits: 16 },
                    OutCol::Acc(0),
                    OutCol::AccInt(1),
                    OutCol::Count,
                ],
                having_gt: None,
                sort: vec![(0, SortDir::Asc), (2, SortDir::Desc)],
                limit: 20,
            },
        }
    }

    #[test]
    fn codec_roundtrip_kitchen_sink() {
        let p = kitchen_sink();
        let enc = p.encode();
        let dec = LogicalPlan::decode(&enc).unwrap();
        assert_eq!(dec, p);
    }

    #[test]
    fn codec_rejects_truncation_and_garbage() {
        let enc = kitchen_sink().encode();
        for cut in [1usize, 2, 7, enc.len() / 2, enc.len() - 1] {
            assert!(
                LogicalPlan::decode(&enc[..enc.len() - cut]).is_err(),
                "accepted {cut}-byte truncation"
            );
        }
        let mut padded = enc.clone();
        padded.push(0);
        assert!(LogicalPlan::decode(&padded).is_err(), "accepted trailing garbage");
        assert!(LogicalPlan::decode(&[]).is_err());
        assert!(LogicalPlan::decode(&[0xFF; 40]).is_err());
    }

    #[test]
    fn kitchen_sink_compiles_and_runs() {
        let db = small_db();
        let (c, stats) = compile(&db, &kitchen_sink()).unwrap();
        assert!(stats.ht_bytes > 0, "dimension builds must charge table bytes");
        let p = super::super::run_range(&c, 2, 0, db.lineitem.len());
        // The plan is selective but the data is generated; just demand
        // structural sanity and that finalize interprets it.
        let rows = finalize(&db, &kitchen_sink().finalize, &p).unwrap();
        assert!(rows.len() <= 20);
        for r in &rows {
            assert_eq!(r.len(), 5);
            assert!(matches!(r[0], Value::Str(_)));
            assert!(matches!(r[1], Value::Int(_)));
        }
    }

    #[test]
    fn compile_rejects_malformed_plans() {
        let db = small_db();
        let base = kitchen_sink();

        let mut bad = base.clone();
        bad.pred = por(vec![PredExpr::True]);
        assert!(compile(&db, &bad).is_err(), "OR in scan position");

        let mut bad = base.clone();
        bad.slots = vec![vcol("no_such_column")];
        assert!(compile(&db, &bad).is_err(), "unknown column");

        let mut bad = base.clone();
        bad.slots = vec![vcol("l_shipmode"); 1];
        assert!(compile(&db, &bad).is_ok(), "str code as value is allowed");

        let mut bad = base.clone();
        bad.key = kcol("l_extendedprice");
        assert!(compile(&db, &bad).is_err(), "f64 key column");

        let mut bad = base.clone();
        bad.cmps = vec![cmp(vpay(0, 0), CmpOp::Eq, vconst(0.0))];
        assert!(compile(&db, &bad).is_err(), "payload ref to unprobed step");

        let mut bad = base.clone();
        bad.cmps = vec![cmp(vpay(1, 9), CmpOp::Eq, vconst(0.0))];
        assert!(compile(&db, &bad).is_err(), "payload slot out of range");

        let mut bad = base.clone();
        bad.finalize.having_gt = Some((4, 0.0));
        assert!(compile(&db, &bad).is_err(), "having acc out of width");

        let mut bad = base.clone();
        bad.finalize.sort = vec![(9, SortDir::Asc)];
        assert!(compile(&db, &bad).is_err(), "sort key out of range");

        let mut bad = base.clone();
        bad.joins[1].link = Some(LinkRef { step: 2, via: "o_custkey".into() });
        assert!(compile(&db, &bad).is_err(), "link to a later step");
    }

    #[test]
    fn wire_bounds_catch_what_encode_would_truncate() {
        let base = kitchen_sink();
        base.check_wire_bounds().unwrap();

        // 258-entry IN-list: enc_match would write the count as 2.
        let mut bad = base.clone();
        let many: Vec<String> = (0..258).map(|i| format!("M{i}")).collect();
        bad.pred = str_in("l_shipmode", &many);
        assert!(bad.check_wire_bounds().is_err(), "oversized OneOf must be rejected");

        // Expression tree deeper than the decoder's recursion cap: it
        // would encode fine and then never decode.
        let mut bad = base.clone();
        let mut deep = vconst(1.0);
        for _ in 0..MAX_DEPTH + 1 {
            deep = vadd(deep, vconst(1.0));
        }
        bad.slots = vec![deep];
        assert!(bad.check_wire_bounds().is_err(), "too-deep tree must be rejected");

        // Every registry default is encodable by construction.
        for d in &crate::analytics::queries::REGISTRY {
            (d.logical)(&PlanParams::default()).unwrap().check_wire_bounds().unwrap();
        }
    }

    #[test]
    fn scalar_finalize_survives_empty_partial() {
        let db = small_db();
        let f = FinalizeSpec {
            scalar: true,
            columns: vec![OutCol::Acc(0), OutCol::AccRatioPct(0, 0), OutCol::Count],
            having_gt: None,
            sort: vec![],
            limit: 0,
        };
        let rows = finalize(&db, &f, &Partial::new(1)).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0][0].as_f64(), 0.0);
        assert_eq!(rows[0][1].as_f64(), 0.0);
    }

    #[test]
    fn finalize_having_sort_limit_and_decoration() {
        let db = small_db();
        // Build a partial keyed by order keys 1..=6 with rising sums.
        let mut p = Partial::new(1);
        for k in 1..=6i64 {
            p.keys.push(k);
            p.accs.push(k as f64 * 10.0);
            p.counts.push(1);
        }
        let f = FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::KeyInt { shift: 0, bits: 0 },
                OutCol::Acc(0),
                OutCol::DimInt { table: TableRef::Orders, col: "o_orderdate".into() },
                OutCol::DimFloat { table: TableRef::Orders, col: "o_totalprice".into() },
            ],
            having_gt: Some((0, 25.0)),
            sort: vec![(1, SortDir::Desc)],
            limit: 3,
        };
        let rows = finalize(&db, &f, &p).unwrap();
        // Groups 3..=6 pass having; top-3 by acc desc = keys 6, 5, 4.
        assert_eq!(rows.len(), 3);
        let keys: Vec<i64> = rows
            .iter()
            .map(|r| match r[0] {
                Value::Int(k) => k,
                _ => panic!(),
            })
            .collect();
        assert_eq!(keys, vec![6, 5, 4]);
        let odate = db.orders.col("o_orderdate").as_i32();
        assert!(rows[0][2].approx_eq(&Value::Int(odate[5] as i64)));
        // A key outside the dense table errors instead of panicking.
        let mut far = Partial::new(1);
        far.keys.push(10_000_000);
        far.accs.push(99.0);
        far.counts.push(1);
        assert!(finalize(&db, &f, &far).is_err());
    }

    #[test]
    fn dense_step_out_of_range_key_drops_row_not_panics() {
        let db = small_db();
        let plan = LogicalPlan {
            name: "dense-oob".into(),
            scan: TableRef::Lineitem,
            pred: PredExpr::True,
            joins: vec![JoinStep {
                // Probing part with *orderkey* runs off the part table
                // for most rows; those rows must be dropped silently.
                table: TableRef::Part,
                dense: true,
                build_key: None,
                probe_key: Some(KeyCols::Col("l_orderkey".into())),
                filter: PredExpr::True,
                link: None,
                payloads: vec![Payload::Col("p_size".into())],
            }],
            cmps: vec![],
            key: kconst(0),
            slots: vec![vpay(0, 0)],
            groups_hint: GroupsHint::Const(1),
            finalize: FinalizeSpec {
                scalar: true,
                columns: vec![OutCol::Acc(0)],
                having_gt: None,
                sort: vec![],
                limit: 0,
            },
        };
        let (c, _) = compile(&db, &plan).unwrap();
        let p = super::super::run_range(&c, 1, 0, db.lineitem.len());
        let _ = finalize(&db, &plan.finalize, &p).unwrap();
    }

    #[test]
    fn params_track_usage_and_types() {
        let mut p = PlanParams::new();
        p.set("days", "90");
        p.set("rate", "0.5");
        p.set("who", "BUILDING");
        p.set("when", "1994-03-01");
        p.set("stray", "1");
        assert_eq!(p.get_i64("days", 0).unwrap(), 90);
        assert_eq!(p.get_f64("rate", 0.0).unwrap(), 0.5);
        assert_eq!(p.get_f64("days", 0.0).unwrap(), 90.0);
        assert_eq!(p.get_str("who", "x").unwrap(), "BUILDING");
        assert_eq!(p.get_date("when", 0).unwrap(), date_to_days(1994, 3, 1));
        assert_eq!(p.get_date("absent", 123).unwrap(), 123);
        assert!(p.get_i64("who", 0).is_err(), "type mismatch must error");
        assert_eq!(p.unused(), vec!["stray".to_string()]);
        let mut lists = PlanParams::new();
        lists.set("modes", "MAIL, SHIP");
        assert_eq!(lists.get_list("modes", &[]).unwrap(), vec!["MAIL", "SHIP"]);
        assert_eq!(
            lists.get_list("other", &["AIR"]).unwrap(),
            vec!["AIR".to_string()]
        );
    }

    #[test]
    fn parse_date_rejects_junk() {
        assert!(parse_date("1994-1-1").is_ok());
        assert!(parse_date("not-a-date").is_err());
        assert!(parse_date("1994-13-01").is_err());
        assert!(parse_date("1994-01").is_err());
    }

    #[test]
    fn key_field_masks_and_shifts() {
        assert_eq!(key_field(0x1234_5678, 16, 0), 0x1234);
        assert_eq!(key_field(0x1234_5678, 0, 16), 0x5678);
        assert_eq!(key_field(-1, 0, 0), -1);
        assert_eq!(key_field(0xAB, 0, 8), 0xAB);
    }
}

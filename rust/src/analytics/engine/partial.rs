//! Mergeable partial aggregates: the unit of merge between morsels, the
//! wire unit of the distributed shuffle, and the unit of the
//! hash-partitioned partial exchange.
//!
//! A [`Partial`] is a flat table of groups, each a key, `width` f64
//! accumulators, and a row count. All per-query accumulators are sums
//! (averages, percentages, and top-k are computed at finalize), so
//! merging is pure addition and associative. [`Merger`] absorbs partials
//! in a deterministic first-seen order; [`Partial::partition_by_key`]
//! splits a partial into key-disjoint partitions for the distributed
//! exchange (merging every partition reproduces the original exactly).

use super::hash64;
use crate::analytics::ops::ExecStats;
use crate::error::Result;
use std::collections::HashMap;

/// A mergeable partial aggregate (see module docs).
#[derive(Clone, Debug, Default)]
pub struct Partial {
    /// Accumulators per group.
    pub width: usize,
    pub keys: Vec<i64>,
    /// Row-major `[len × width]` accumulator block.
    pub accs: Vec<f64>,
    pub counts: Vec<u64>,
    /// Engine statistics for the rows this partial covered (not encoded
    /// on the wire — the leader accounts them host-side).
    pub stats: ExecStats,
}

impl Partial {
    pub fn new(width: usize) -> Self {
        Self { width, ..Default::default() }
    }

    /// A single-group partial (scalar aggregates like Q6/Q14/Q19).
    pub fn single(key: i64, accs: &[f64], count: u64, stats: ExecStats) -> Self {
        Self {
            width: accs.len(),
            keys: vec![key],
            accs: accs.to_vec(),
            counts: vec![count],
            stats,
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Accumulator slice of group `i`.
    pub fn acc(&self, i: usize) -> &[f64] {
        &self.accs[i * self.width..(i + 1) * self.width]
    }

    /// Bytes one group occupies — on the wire and (approximately) in the
    /// merged in-memory state: `i64 key + width × f64 accs + u64 count`.
    pub fn group_bytes(width: usize) -> usize {
        8 + 8 * width + 8
    }

    /// Encode for the shuffle wire: `u32 width, u32 len`, then per group
    /// `i64 key, width × f64 accs, u64 count`, all little-endian.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.len() * Self::group_bytes(self.width));
        self.encode_into(&mut out);
        out
    }

    /// Append the wire encoding to `out` — the pooled-buffer path: the
    /// query service encodes every exchange body into a recycled
    /// [`crate::rpc::BufPool`] buffer instead of a fresh vector.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.reserve(8 + self.len() * Self::group_bytes(self.width));
        out.extend_from_slice(&(self.width as u32).to_le_bytes());
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        for i in 0..self.len() {
            out.extend_from_slice(&self.keys[i].to_le_bytes());
            for a in self.acc(i) {
                out.extend_from_slice(&a.to_le_bytes());
            }
            out.extend_from_slice(&self.counts[i].to_le_bytes());
        }
    }

    /// Inverse of [`Partial::encode`]. The decoded partial carries empty
    /// [`ExecStats`].
    pub fn decode(buf: &[u8]) -> Result<Self> {
        crate::ensure!(buf.len() >= 8, "short partial frame: {} bytes", buf.len());
        // bound: the ensure! above proves 8 <= buf.len()
        let width = u32::from_le_bytes(buf[0..4].try_into()?) as usize;
        // bound: same ensure! — header is 8 bytes
        let len = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        crate::ensure!(width <= 64, "implausible partial width {width}");
        let gb = Self::group_bytes(width);
        crate::ensure!(
            buf.len() == 8 + len * gb,
            "bad partial length: header says {len} groups of {gb} B, frame has {} B",
            buf.len() - 8
        );
        let mut p = Self {
            width,
            keys: Vec::with_capacity(len),
            accs: Vec::with_capacity(len * width),
            counts: Vec::with_capacity(len),
            stats: ExecStats::default(),
        };
        for g in 0..len {
            let base = 8 + g * gb;
            // bound: length ensure! pins buf.len() == 8 + len*gb; g < len so base + gb <= buf.len(), and 8 < gb
            p.keys.push(i64::from_le_bytes(buf[base..base + 8].try_into()?));
            for w in 0..width {
                let o = base + 8 + w * 8;
                // bound: w < width so o + 8 <= base + gb <= buf.len() per the length ensure!
                p.accs.push(f64::from_le_bytes(buf[o..o + 8].try_into()?));
            }
            let o = base + 8 + width * 8;
            // bound: o + 8 == base + gb <= buf.len() per the length ensure!
            p.counts.push(u64::from_le_bytes(buf[o..o + 8].try_into()?));
        }
        Ok(p)
    }

    /// Split into `parts` key-disjoint partitions by the shared key hash,
    /// preserving relative group order within each partition. Every group
    /// lands in exactly one partition, so merging all partitions (in any
    /// partition order) reproduces this partial's groups exactly — the
    /// conservation property the distributed exchange relies on.
    /// Partition stats are empty (stats stay host-side).
    pub fn partition_by_key(&self, parts: usize) -> Vec<Partial> {
        let parts = parts.max(1);
        let mut out: Vec<Partial> = (0..parts).map(|_| Partial::new(self.width)).collect();
        for g in 0..self.len() {
            let p = &mut out[(hash64(self.keys[g]) as usize) % parts];
            p.keys.push(self.keys[g]);
            p.accs.extend_from_slice(self.acc(g));
            p.counts.push(self.counts[g]);
        }
        out
    }
}

/// Order-preserving partial merger: groups appear in first-seen order
/// across absorbed partials, accumulators and counts are summed.
pub struct Merger {
    width: usize,
    index: HashMap<i64, usize>,
    partial: Partial,
}

impl Merger {
    pub fn new(width: usize) -> Self {
        Self { width, index: HashMap::new(), partial: Partial::new(width) }
    }

    /// Merge one partial in (errors on accumulator-width mismatch).
    pub fn absorb(&mut self, p: &Partial) -> Result<()> {
        crate::ensure!(
            p.width == self.width,
            "partial width {} != merger width {}",
            p.width,
            self.width
        );
        self.partial.stats.merge(&p.stats);
        for gi in 0..p.len() {
            let key = p.keys[gi];
            let idx = match self.index.get(&key) {
                Some(&i) => i,
                None => {
                    let i = self.partial.keys.len();
                    self.index.insert(key, i);
                    self.partial.keys.push(key);
                    self.partial.accs.resize(self.partial.accs.len() + self.width, 0.0);
                    self.partial.counts.push(0);
                    i
                }
            };
            let base = idx * self.width;
            for (w, v) in p.acc(gi).iter().enumerate() {
                self.partial.accs[base + w] += v;
            }
            self.partial.counts[idx] += p.counts[gi];
        }
        Ok(())
    }

    /// Mutable access to the merged statistics (for folding in one-time
    /// compile-phase stats).
    pub fn stats_mut(&mut self) -> &mut ExecStats {
        &mut self.partial.stats
    }

    pub fn into_partial(self) -> Partial {
        self.partial
    }
}

#[cfg(test)]
mod tests {
    use super::super::agg::HashAgg;
    use super::*;

    #[test]
    fn codec_roundtrip() {
        let mut g = HashAgg::with_capacity(3, 4);
        g.update(7, &[1.0, 2.0, 3.0]);
        g.update(-9, &[4.0, 5.0, 6.0]);
        g.update(7, &[0.5, 0.5, 0.5]);
        let p = g.into_partial();
        let dec = Partial::decode(&p.encode()).unwrap();
        assert_eq!(dec.width, 3);
        assert_eq!(dec.keys, p.keys);
        assert_eq!(dec.accs, p.accs);
        assert_eq!(dec.counts, p.counts);
    }

    #[test]
    fn decode_rejects_bad_frames() {
        assert!(Partial::decode(&[1, 2, 3]).is_err());
        let p = Partial::single(1, &[2.0], 1, ExecStats::default());
        let enc = p.encode();
        assert!(Partial::decode(&enc[..enc.len() - 1]).is_err());
        // Implausible width.
        let mut bad = enc.clone();
        bad[0] = 200;
        assert!(Partial::decode(&bad).is_err());
    }

    #[test]
    fn merger_sums_groups_in_first_seen_order() {
        let a = Partial::single(5, &[1.0, 10.0], 2, ExecStats::default());
        let b = Partial::single(9, &[3.0, 30.0], 1, ExecStats::default());
        let c = Partial::single(5, &[0.5, 5.0], 4, ExecStats::default());
        let mut m = Merger::new(2);
        for p in [&a, &b, &c] {
            m.absorb(p).unwrap();
        }
        let out = m.into_partial();
        assert_eq!(out.keys, vec![5, 9]);
        assert_eq!(out.acc(0), &[1.5, 15.0]);
        assert_eq!(out.acc(1), &[3.0, 30.0]);
        assert_eq!(out.counts, vec![6, 1]);
    }

    #[test]
    fn merger_rejects_width_mismatch() {
        let p = Partial::single(1, &[1.0], 1, ExecStats::default());
        let mut m = Merger::new(2);
        assert!(m.absorb(&p).is_err());
    }

    #[test]
    fn partition_conserves_groups() {
        let mut g = HashAgg::with_capacity(2, 8);
        for k in 0..100i64 {
            g.update(k % 37, &[k as f64, 1.0]);
        }
        let p = g.into_partial();
        for parts in [1usize, 2, 3, 7] {
            let split = p.partition_by_key(parts);
            assert_eq!(split.len(), parts);
            let total: usize = split.iter().map(|s| s.len()).sum();
            assert_eq!(total, p.len(), "parts={parts}: group lost or duplicated");
            // Merging every partition reproduces the original groups.
            let mut m = Merger::new(2);
            for s in &split {
                m.absorb(s).unwrap();
            }
            let merged = m.into_partial();
            let mut want: Vec<(i64, Vec<f64>, u64)> = (0..p.len())
                .map(|i| (p.keys[i], p.acc(i).to_vec(), p.counts[i]))
                .collect();
            let mut got: Vec<(i64, Vec<f64>, u64)> = (0..merged.len())
                .map(|i| (merged.keys[i], merged.acc(i).to_vec(), merged.counts[i]))
                .collect();
            want.sort_by_key(|(k, _, _)| *k);
            got.sort_by_key(|(k, _, _)| *k);
            assert_eq!(got, want, "parts={parts}");
        }
    }

    #[test]
    fn partition_of_empty_partial() {
        let p = Partial::new(4);
        let split = p.partition_by_key(3);
        assert_eq!(split.len(), 3);
        assert!(split.iter().all(|s| s.is_empty() && s.width == 4));
        // parts = 0 is clamped to 1.
        assert_eq!(p.partition_by_key(0).len(), 1);
    }
}

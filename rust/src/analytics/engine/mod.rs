//! The unified vectorized execution layer: one [`PlanSpec`] per query,
//! one kernel for every path.
//!
//! Before this layer existed, each TPC-H query carried three hand-written
//! implementations — a serial `run()`, a morsel `prepare`/kernel pair,
//! and the distributed worker fold — that duplicated every predicate and
//! dimension-join build (a drift risk the cross-path equality tests only
//! papered over). Now a query is a single [`PlanSpec`]:
//!
//! * `compile` — runs once per executor over the *broadcast* tables and
//!   returns a [`Compiled`] context: a [`Predicate`] expression over
//!   lineitem, the dimension [`HashJoinTable`]s captured by a per-row
//!   evaluator, and the aggregate slot layout;
//! * the shared kernel ([`run_range`]) evaluates the predicate into a
//!   selection vector and folds surviving rows through [`HashAgg`] into a
//!   mergeable [`Partial`];
//! * `finalize` — merged partial → result rows (sorts, top-k, dimension
//!   lookups on the leader).
//!
//! The three execution paths are thin drivers over those pieces:
//! [`run_serial`] is `compile` + one full-range kernel call;
//! [`run_parallel`] (behind [`crate::analytics::morsel::run_query_morsel`])
//! evaluates the predicate morsel-parallel and aggregates balanced
//! selection slices; the distributed executor
//! ([`crate::coordinator::shuffle::DistributedQuery`]) gives each worker
//! a row range, then exchanges hash-partitioned partials. All three
//! produce the same rows (floating-point sums associate differently,
//! within `approx_eq_rows` tolerance).
//!
//! ```
//! use lovelock::analytics::engine;
//! use lovelock::analytics::{TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 42));
//! let spec = engine::spec("q6").unwrap();
//! let serial = engine::run_serial(&db, &spec);
//! let parallel = engine::run_parallel(&db, &spec, 2, 512);
//! assert!(parallel.approx_eq_rows(&serial.rows));
//! ```

pub mod agg;
pub mod expr;
pub mod join;
pub mod partial;

pub use agg::HashAgg;
pub use expr::Predicate;
pub use join::{HashJoinTable, ProbeIter};
pub use partial::{Merger, Partial};

use super::ops::ExecStats;
use super::queries::{self, QueryOutput, Row};
use super::tpch::TpchDb;
use crate::exec::{parallel_map_chunks, parallel_map_sel_chunks};

/// Maximum aggregate slots per group across the query set (Q1 uses 5).
pub const MAX_ACCS: usize = 5;

/// Fixed-size accumulator block a row evaluator returns; only the first
/// `PlanSpec::width` slots are used.
pub type Accs = [f64; MAX_ACCS];

/// Per-row evaluator: row id → `Some((group key, accumulator values))`,
/// or `None` when a dimension probe misses. Borrows the database columns
/// and the compiled dimension tables for `'a`.
pub type RowEval<'a> = Box<dyn Fn(usize) -> Option<(i64, Accs)> + Send + Sync + 'a>;

/// Pad a single accumulator value to an [`Accs`] block.
#[inline]
pub fn acc1(a: f64) -> Accs {
    [a, 0.0, 0.0, 0.0, 0.0]
}

/// Pad two accumulator values to an [`Accs`] block.
#[inline]
pub fn acc2(a: f64, b: f64) -> Accs {
    [a, b, 0.0, 0.0, 0.0]
}

/// Fibonacci/multiply-xorshift hash over i64 keys: adequate spread for
/// dense keys. Shared by the join table, the aggregation table, and the
/// partial key-partitioner (the exchange relies on all executors
/// agreeing on it).
#[inline]
pub(crate) fn hash64(k: i64) -> u64 {
    let mut h = (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^ (h >> 32)
}

/// A query's execution plan — the one description all three paths drive.
pub struct PlanSpec {
    /// Query name ("q1" … "q19").
    pub name: &'static str,
    /// Aggregate accumulator slots per group (≤ [`MAX_ACCS`]).
    pub width: usize,
    /// Build the broadcast-side state (dimension hash tables, dictionary
    /// lookups, predicate) and return it with its one-time build stats.
    pub compile: for<'a> fn(&'a TpchDb) -> (Compiled<'a>, ExecStats),
    /// Merged partial → final result rows (leader-side).
    pub finalize: fn(&TpchDb, &Partial) -> Vec<Row>,
}

/// The compiled per-executor context [`PlanSpec::compile`] returns.
pub struct Compiled<'a> {
    /// Predicate over lineitem, evaluated per morsel into a selection
    /// vector (charges its own per-conjunct scan stats).
    pub pred: Predicate<'a>,
    /// Bytes per *selected* row charged for the payload columns the
    /// evaluator reads.
    pub payload_bytes: usize,
    /// Row → group key + accumulator values (dimension probes inside).
    pub eval: RowEval<'a>,
    /// Expected distinct groups (aggregation-table capacity hint).
    pub groups_hint: usize,
}

/// Look up the plan for a query. Every query in
/// [`super::queries::QUERY_NAMES`] has exactly one.
pub fn spec(name: &str) -> Option<PlanSpec> {
    match name {
        "q1" => Some(queries::q1::plan_spec()),
        "q3" => Some(queries::q3::plan_spec()),
        "q5" => Some(queries::q5::plan_spec()),
        "q6" => Some(queries::q6::plan_spec()),
        "q9" => Some(queries::q9::plan_spec()),
        "q12" => Some(queries::q12::plan_spec()),
        "q14" => Some(queries::q14::plan_spec()),
        "q18" => Some(queries::q18::plan_spec()),
        "q19" => Some(queries::q19::plan_spec()),
        _ => None,
    }
}

/// Shared aggregation loop over any row-id stream: charges payload
/// bytes, folds rows through the evaluator into a [`HashAgg`], and
/// stamps the table footprint + produced group count onto `stats`.
fn aggregate_rows<I: Iterator<Item = usize>>(
    c: &Compiled<'_>,
    width: usize,
    rows: I,
    n_rows: usize,
    mut stats: ExecStats,
) -> Partial {
    stats.scan(n_rows, c.payload_bytes);
    let mut agg = HashAgg::with_capacity(width, c.groups_hint.min(n_rows + 16));
    for i in rows {
        if let Some((key, accs)) = (c.eval)(i) {
            agg.update(key, &accs[..width]);
        }
    }
    stats.ht_bytes += agg.bytes();
    stats.rows_out += agg.len() as u64;
    let mut p = agg.into_partial();
    p.stats = stats;
    p
}

/// Aggregate an already-computed selection slice into a [`Partial`],
/// folding `stats` (typically the predicate-phase scan stats) into the
/// result and charging the payload bytes, aggregation-table footprint,
/// and produced group count on top.
pub fn aggregate_sel(c: &Compiled<'_>, width: usize, sel: &[u32], stats: ExecStats) -> Partial {
    aggregate_rows(c, width, sel.iter().map(|&i| i as usize), sel.len(), stats)
}

/// THE morsel kernel, shared by all three paths: evaluate the plan over
/// lineitem rows `[lo, hi)` into a mergeable [`Partial`]. An all-pass
/// predicate aggregates the row range directly — no materialized
/// identity selection vector (q5/q9/q18 take this path on every
/// executor).
pub fn run_range(c: &Compiled<'_>, width: usize, lo: usize, hi: usize) -> Partial {
    let mut stats = ExecStats::default();
    if matches!(c.pred, Predicate::True) {
        return aggregate_rows(c, width, lo..hi, hi - lo, stats);
    }
    let sel = c.pred.eval(lo, hi, &mut stats);
    aggregate_sel(c, width, &sel, stats)
}

/// Run a compiled plan single-threaded over the whole of lineitem —
/// the serial path as one full-range kernel call.
pub fn run_serial_compiled(
    db: &TpchDb,
    width: usize,
    c: &Compiled<'_>,
    prep: ExecStats,
    finalize: fn(&TpchDb, &Partial) -> Vec<Row>,
) -> QueryOutput {
    let p = run_range(c, width, 0, db.lineitem.len());
    let mut stats = prep;
    stats.merge(&p.stats);
    QueryOutput { rows: finalize(db, &p), stats }
}

/// Run a query single-threaded (the reference path behind
/// [`super::queries::run_query`]).
pub fn run_serial(db: &TpchDb, spec: &PlanSpec) -> QueryOutput {
    let (c, prep) = (spec.compile)(db);
    run_serial_compiled(db, spec.width, &c, prep, spec.finalize)
}

/// Run a query morsel-parallel on `threads` threads (0 = all cores),
/// `morsel_rows` rows per unit of scheduling.
///
/// Two phases, both selection-vector aware: the predicate is evaluated
/// over fixed-size *row* morsels in parallel and the surviving row ids
/// concatenated in row order; the aggregation then runs over fixed-size
/// slices of that *selection* (via
/// [`crate::exec::parallel_map_sel_chunks`]), so a selective predicate
/// whose survivors cluster in a few row ranges still spreads its
/// aggregation work evenly. Per-slice partials merge in slice order —
/// deterministic regardless of thread scheduling.
pub fn run_parallel(
    db: &TpchDb,
    spec: &PlanSpec,
    threads: usize,
    morsel_rows: usize,
) -> QueryOutput {
    let morsel_rows = morsel_rows.max(1);
    let (c, prep) = (spec.compile)(db);
    let n = db.lineitem.len();

    let (pre_stats, partials): (ExecStats, Vec<Partial>) = if matches!(c.pred, Predicate::True) {
        // Fast path: with an all-pass predicate every selection slice is
        // a row range, so aggregate row morsels directly — no
        // materialized n-element selection vector, no inter-phase
        // barrier (q5/q9/q18 take this path).
        let partials = parallel_map_chunks(n, morsel_rows, threads, |lo, hi| {
            run_range(&c, spec.width, lo, hi)
        });
        (prep, partials)
    } else {
        // Phase 1: predicate → per-morsel selection vectors, row order.
        let parts: Vec<(Vec<u32>, ExecStats)> =
            parallel_map_chunks(n, morsel_rows, threads, |lo, hi| {
                let mut st = ExecStats::default();
                (c.pred.eval(lo, hi, &mut st), st)
            });
        let mut pre_stats = prep;
        let mut sel = Vec::with_capacity(parts.iter().map(|(s, _)| s.len()).sum());
        for (s, st) in &parts {
            pre_stats.merge(st);
            sel.extend_from_slice(s);
        }

        // Phase 2: aggregate balanced selection slices in parallel.
        let partials = parallel_map_sel_chunks(&sel, morsel_rows, threads, |slice| {
            aggregate_sel(&c, spec.width, slice, ExecStats::default())
        });
        (pre_stats, partials)
    };

    // Merge in slice order; fold in the compile + predicate stats.
    let mut merger = Merger::new(spec.width);
    *merger.stats_mut() = pre_stats;
    let mut slice_ht_peak = 0u64;
    for p in &partials {
        slice_ht_peak = slice_ht_peak.max(p.stats.ht_bytes);
        merger.absorb(p).expect("plan produced mismatched partial width");
    }
    let mut merged = merger.into_partial();
    // The merge summed every transient per-slice hash table into
    // ht_bytes; the *live* peak is the compile-side tables plus one
    // slice table plus the merged-group state. Keep ht_bytes at its
    // documented "live at once" meaning.
    merged.stats.ht_bytes = pre_stats.ht_bytes
        + slice_ht_peak
        + merged.len() as u64 * Partial::group_bytes(spec.width) as u64;
    let rows = (spec.finalize)(db, &merged);
    QueryOutput { rows, stats: merged.stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::QUERY_NAMES;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn every_query_has_exactly_one_spec() {
        for q in QUERY_NAMES {
            let s = spec(q).unwrap_or_else(|| panic!("{q} has no PlanSpec"));
            assert_eq!(s.name, q);
            assert!(s.width >= 1 && s.width <= MAX_ACCS, "{q} width {}", s.width);
        }
        assert!(spec("q99").is_none());
    }

    #[test]
    fn serial_path_is_one_kernel_call() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 7));
        for q in ["q1", "q6", "q18"] {
            let s = spec(q).unwrap();
            let (c, prep) = (s.compile)(&db);
            let p = run_range(&c, s.width, 0, db.lineitem.len());
            let direct = (s.finalize)(&db, &p);
            let driver = run_serial(&db, &s);
            assert!(driver.approx_eq_rows(&direct), "{q}: driver != direct kernel");
            assert!(driver.stats.bytes_scanned >= p.stats.bytes_scanned);
            let _ = prep;
        }
    }

    #[test]
    fn kernel_splits_merge_to_full_range() {
        // Splitting the range and merging partials must equal one
        // full-range call, group for group (f64-exact within slices of
        // identical association is not guaranteed — compare via rows).
        let db = TpchDb::generate(TpchConfig::new(0.002, 11));
        let s = spec("q1").unwrap();
        let (c, _) = (s.compile)(&db);
        let n = db.lineitem.len();
        let full = run_range(&c, s.width, 0, n);
        let mut m = Merger::new(s.width);
        let mid = n / 3;
        for (lo, hi) in [(0, mid), (mid, n)] {
            m.absorb(&run_range(&c, s.width, lo, hi)).unwrap();
        }
        let merged = m.into_partial();
        let rows_full = (s.finalize)(&db, &full);
        let rows_merged = (s.finalize)(&db, &merged);
        let out = QueryOutput { rows: rows_merged, stats: ExecStats::default() };
        assert!(out.approx_eq_rows(&rows_full));
    }

    #[test]
    fn empty_range_yields_empty_partial() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 13));
        for q in QUERY_NAMES {
            let s = spec(q).unwrap();
            let (c, _) = (s.compile)(&db);
            let p = run_range(&c, s.width, 0, 0);
            assert!(p.is_empty(), "{q}: non-empty partial from empty range");
            assert_eq!(p.width, s.width, "{q}: width mismatch");
            // Finalize must tolerate an empty partial (scalar queries
            // return their zero row, grouped queries no rows).
            let _ = (s.finalize)(&db, &p);
        }
    }

    #[test]
    fn parallel_matches_serial_for_all() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        for q in QUERY_NAMES {
            let s = spec(q).unwrap();
            let serial = run_serial(&db, &s);
            let par = run_parallel(&db, &s, 3, 777);
            assert!(
                par.approx_eq_rows(&serial.rows),
                "{q}: parallel ({} rows) diverged from serial ({} rows)",
                par.rows.len(),
                serial.rows.len()
            );
        }
    }
}

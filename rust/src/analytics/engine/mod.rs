//! The unified vectorized execution layer: one [`LogicalPlan`] per
//! query, one kernel for every path.
//!
//! Before this layer existed, each TPC-H query carried three hand-written
//! implementations — a serial `run()`, a morsel `prepare`/kernel pair,
//! and the distributed worker fold — that duplicated every predicate and
//! dimension-join build (a drift risk the cross-path equality tests only
//! papered over). Now a query is a single declarative, wire-serializable
//! [`LogicalPlan`] (see [`plan`]):
//!
//! * [`plan::compile`] — runs once per executor over the *broadcast*
//!   tables and returns a [`Compiled`] context: a [`Predicate`]
//!   expression over lineitem, the dimension [`HashJoinTable`]s captured
//!   by a generated batched evaluator, and the aggregate slot layout;
//! * the shared kernel ([`fold_range`]) evaluates the predicate into the
//!   task's reusable [`SelScratch`] ping-pong buffers, runs the plan's
//!   [`BatchEval`] over the surviving rows into reusable key/value
//!   columns ([`EvalBatch`]), and folds them through one batched
//!   [`HashAgg::update_sel`] call — allocation-free in steady state;
//! * [`plan::finalize`] — merged partial → result rows, interpreting the
//!   plan's [`plan::FinalizeSpec`] (sorts, top-k, having, dimension
//!   decoration on the leader).
//!
//! The three execution paths are thin drivers over those pieces:
//! [`run_serial`] is `compile` + one full-range kernel call;
//! [`run_parallel`] (behind [`crate::analytics::morsel::run_query_morsel`])
//! evaluates the predicate morsel-parallel and aggregates balanced
//! selection slices; the distributed executor
//! ([`crate::coordinator::shuffle::DistributedQuery`]) gives each worker
//! a row range, then exchanges hash-partitioned partials. All three
//! produce the same rows (floating-point sums associate differently,
//! within `approx_eq_rows` tolerance).
//!
//! ```
//! use lovelock::analytics::engine;
//! use lovelock::analytics::{TpchConfig, TpchDb};
//!
//! let db = TpchDb::generate(TpchConfig::new(0.001, 42));
//! let spec = engine::spec("q6").unwrap();
//! let serial = engine::run_serial(&db, &spec);
//! let parallel = engine::run_parallel(&db, &spec, 2, 512);
//! assert!(parallel.approx_eq_rows(&serial.rows));
//! ```

pub mod agg;
pub mod expr;
pub mod join;
pub mod partial;
pub mod plan;

pub use agg::HashAgg;
pub use expr::{Predicate, PruneCheck, PrunePlan, Sel, SelScratch};
pub use join::{HashJoinTable, ProbeIter};
pub use partial::{Merger, Partial};
pub use plan::{LogicalPlan, PlanParams};

use super::ops::ExecStats;
use super::queries::{self, QueryOutput};
use super::tpch::TpchDb;
use crate::error::Result;
use crate::exec::{parallel_map_chunks_with, parallel_map_sel_chunks_with};

/// Maximum aggregate slots per group across the query set (Q1 uses 5).
pub const MAX_ACCS: usize = 5;

/// Batched row evaluator: visit the rows in `sel` and, for each row that
/// survives its dimension probes, append the row's group key to
/// `out.keys` and one value to each of the first `width` columns of
/// `out.cols` (probe misses append nothing — the output is compacted).
/// The engine then folds the batch through [`HashAgg::update_sel`].
/// Borrows the database columns and the compiled dimension tables for
/// `'a`.
pub type BatchEval<'a> = Box<dyn Fn(Sel<'_>, &mut EvalBatch) + Send + Sync + 'a>;

/// Reusable output of one [`BatchEval`] call: per-row group keys plus
/// one value column per accumulator slot (only the plan's first `width`
/// columns are used). Cleared-and-reserved per morsel, so capacity
/// sticks at the high-water morsel size and steady-state evaluation
/// allocates nothing.
pub struct EvalBatch {
    /// Group key per surviving row.
    pub keys: Vec<i64>,
    /// Accumulator value columns, index-aligned with `keys`.
    pub cols: [Vec<f64>; MAX_ACCS],
}

impl Default for EvalBatch {
    fn default() -> Self {
        Self { keys: Vec::new(), cols: std::array::from_fn(|_| Vec::new()) }
    }
}

impl EvalBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear and pre-size for a morsel of up to `n` rows at `width`.
    #[inline]
    fn begin(&mut self, width: usize, n: usize) {
        self.keys.clear();
        self.keys.reserve(n);
        for col in &mut self.cols[..] {
            col.clear();
        }
        for col in &mut self.cols[..width] {
            col.reserve(n);
        }
    }

    /// The columns as slices (for [`HashAgg::update_sel`]).
    #[inline]
    fn col_refs(&self) -> [&[f64]; MAX_ACCS] {
        std::array::from_fn(|i| self.cols[i].as_slice())
    }
}

/// Everything one executor task reuses across morsels: the predicate's
/// ping-pong selection buffers, the batch evaluator's key/value columns,
/// and the aggregation's group-index scratch. Create once per task (per
/// worker fold, per pool thread), fold forever — after the first few
/// morsels size the buffers, the kernel performs zero allocations per
/// morsel (asserted by the counting-allocator regression test).
#[derive(Default)]
pub struct TaskScratch {
    pub sel: SelScratch,
    pub batch: EvalBatch,
    pub gids: Vec<u32>,
}

impl TaskScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Fibonacci/multiply-xorshift hash over i64 keys: adequate spread for
/// dense keys. Shared by the join table, the aggregation table, and the
/// partial key-partitioner (the exchange relies on all executors
/// agreeing on it).
#[inline]
pub(crate) fn hash64(k: i64) -> u64 {
    let mut h = (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^ (h >> 32)
}

/// The compiled per-executor context [`plan::compile`] returns.
pub struct Compiled<'a> {
    /// Predicate over lineitem, evaluated per morsel into the task's
    /// selection scratch (charges its own per-conjunct scan stats).
    pub pred: Predicate<'a>,
    /// Bytes per *selected* row charged for the payload columns the
    /// evaluator reads.
    pub payload_bytes: usize,
    /// Batched selection → group keys + accumulator columns (dimension
    /// probes inside).
    pub eval: BatchEval<'a>,
    /// Expected distinct groups (aggregation-table capacity hint).
    pub groups_hint: usize,
    /// Zone-map pruning plan over the scan table's chunks. Inactive when
    /// the table carries no zone map or the plan derives no usable
    /// column intervals; then every path behaves exactly as before.
    pub prune: PrunePlan<'a>,
}

/// Look up the default-parameter plan for a registered query. Every
/// query in [`super::queries::QUERY_NAMES`] has exactly one entry in
/// [`super::queries::REGISTRY`] — this is a thin view over that one
/// table, not a second name list.
pub fn spec(name: &str) -> Option<LogicalPlan> {
    queries::REGISTRY
        .iter()
        .find(|d| d.name == name)
        .map(|d| (d.logical)(&PlanParams::default()).expect("default registry plan must build"))
}

/// A right-sized aggregation table for folding up to `n_rows` rows of a
/// compiled plan.
pub fn agg_for(c: &Compiled<'_>, width: usize, n_rows: usize) -> HashAgg {
    HashAgg::with_capacity(width, c.groups_hint.min(n_rows + 16))
}

/// Fold an already-selected row set into `agg`: charge payload bytes,
/// run the batch evaluator into the scratch columns, one batched
/// aggregation update. Zero allocations in steady state.
#[inline]
fn fold_sel(
    c: &Compiled<'_>,
    width: usize,
    rows: Sel<'_>,
    agg: &mut HashAgg,
    batch: &mut EvalBatch,
    gids: &mut Vec<u32>,
    stats: &mut ExecStats,
) {
    stats.scan(rows.len(), c.payload_bytes);
    batch.begin(width, rows.len());
    (c.eval)(rows, batch);
    let n = batch.keys.len();
    debug_assert!(
        batch.cols[..width].iter().all(|col| col.len() == n),
        "batch evaluator produced ragged columns"
    );
    let cols = batch.col_refs();
    agg.update_sel(&batch.keys, Sel::Range(0, n), &cols[..width], gids);
}

/// THE morsel kernel, shared by all three paths: evaluate the plan over
/// lineitem rows `[lo, hi)` into `agg`, reusing `scr` across calls. An
/// all-pass predicate folds the row range directly — no materialized
/// identity selection vector on any path (q5/q9/q18 take this on every
/// executor). When the compiled plan carries an active [`PrunePlan`],
/// zone-map-disjoint chunks are skipped wholesale: their rows are never
/// evaluated and charge no scan bytes, only a `morsels_pruned` tick. The
/// workers' map loop calls this once per morsel with one long-lived
/// `agg`; in steady state the call allocates nothing.
pub fn fold_range(
    c: &Compiled<'_>,
    width: usize,
    lo: usize,
    hi: usize,
    agg: &mut HashAgg,
    scr: &mut TaskScratch,
    stats: &mut ExecStats,
) {
    let TaskScratch { sel, batch, gids } = scr;
    if !c.prune.is_active() {
        let rows = c.pred.eval_into(lo, hi, sel, stats);
        fold_sel(c, width, rows, agg, batch, gids, stats);
        return;
    }
    // Chunk walk: fold maximal runs of unpruned chunks, skip the rest. A
    // pruned chunk ticks `morsels_pruned` only from the call covering
    // its first row, so morsel splits mid-chunk never double-count it.
    let cr = c.prune.chunk_rows();
    let mut run_lo = lo;
    let mut s = lo;
    while s < hi {
        let ci = s / cr;
        let ce = ((ci + 1) * cr).min(hi);
        if c.prune.chunk_pruned(ci) {
            if s == ci * cr {
                stats.morsels_pruned += 1;
            }
            if run_lo < s {
                let rows = c.pred.eval_into(run_lo, s, sel, stats);
                fold_sel(c, width, rows, agg, batch, gids, stats);
            }
            run_lo = ce;
        }
        s = ce;
    }
    if run_lo < hi {
        let rows = c.pred.eval_into(run_lo, hi, sel, stats);
        fold_sel(c, width, rows, agg, batch, gids, stats);
    }
}

/// Phase-1 selection with zone-map pruning: evaluate the predicate over
/// the unpruned runs of `[lo, hi)`, appending survivors to `out` in row
/// order. Mirrors [`fold_range`]'s chunk walk, including the
/// first-row-only `morsels_pruned` counting rule.
fn select_pruned(
    c: &Compiled<'_>,
    lo: usize,
    hi: usize,
    scr: &mut SelScratch,
    stats: &mut ExecStats,
    out: &mut Vec<u32>,
) {
    let cr = c.prune.chunk_rows();
    let mut run_lo = lo;
    let mut s = lo;
    while s < hi {
        let ci = s / cr;
        let ce = ((ci + 1) * cr).min(hi);
        if c.prune.chunk_pruned(ci) {
            if s == ci * cr {
                stats.morsels_pruned += 1;
            }
            if run_lo < s {
                append_sel(c.pred.eval_into(run_lo, s, scr, stats), out);
            }
            run_lo = ce;
        }
        s = ce;
    }
    if run_lo < hi {
        append_sel(c.pred.eval_into(run_lo, hi, scr, stats), out);
    }
}

#[inline]
fn append_sel(rows: Sel<'_>, out: &mut Vec<u32>) {
    match rows {
        Sel::Range(a, b) => out.extend(a as u32..b as u32),
        Sel::Ids(ids) => out.extend_from_slice(ids),
    }
}

/// Seal a fold: stamp the table footprint and produced group count onto
/// `stats`, and attach them to the finished [`Partial`].
pub fn finish_fold(agg: HashAgg, mut stats: ExecStats) -> Partial {
    stats.ht_bytes += agg.bytes();
    stats.rows_out += agg.len() as u64;
    let mut p = agg.into_partial();
    p.stats = stats;
    p
}

/// One-shot kernel call over `[lo, hi)` with caller-reused scratch.
pub fn run_range_scratch(
    c: &Compiled<'_>,
    width: usize,
    lo: usize,
    hi: usize,
    scr: &mut TaskScratch,
) -> Partial {
    let mut stats = ExecStats::default();
    let mut agg = agg_for(c, width, hi - lo);
    fold_range(c, width, lo, hi, &mut agg, scr, &mut stats);
    finish_fold(agg, stats)
}

/// One-shot kernel call over `[lo, hi)` (allocating convenience form).
pub fn run_range(c: &Compiled<'_>, width: usize, lo: usize, hi: usize) -> Partial {
    let mut scr = TaskScratch::new();
    run_range_scratch(c, width, lo, hi, &mut scr)
}

/// Aggregate an already-computed selection slice into a [`Partial`] with
/// caller-reused scratch, folding `stats` (typically the predicate-phase
/// scan stats) into the result.
pub fn aggregate_sel_scratch(
    c: &Compiled<'_>,
    width: usize,
    sel: &[u32],
    stats: ExecStats,
    scr: &mut TaskScratch,
) -> Partial {
    let mut stats = stats;
    let mut agg = agg_for(c, width, sel.len());
    let TaskScratch { batch, gids, .. } = scr;
    fold_sel(c, width, Sel::Ids(sel), &mut agg, batch, gids, &mut stats);
    finish_fold(agg, stats)
}

/// [`aggregate_sel_scratch`] with throwaway scratch.
pub fn aggregate_sel(c: &Compiled<'_>, width: usize, sel: &[u32], stats: ExecStats) -> Partial {
    let mut scr = TaskScratch::new();
    aggregate_sel_scratch(c, width, sel, stats, &mut scr)
}

/// Run a plan single-threaded over the whole of its scan table — the
/// serial path as one full-range kernel call. Fails (never panics) on a
/// malformed plan, so ad-hoc wire plans can be rejected gracefully.
pub fn try_run_serial(db: &TpchDb, p: &LogicalPlan) -> Result<QueryOutput> {
    let (c, prep) = plan::compile(db, p)?;
    let part = run_range(&c, p.width(), 0, plan::table(db, p.scan).len());
    let mut stats = prep;
    stats.merge(&part.stats);
    Ok(QueryOutput { rows: plan::finalize(db, &p.finalize, &part)?, stats })
}

/// Run a query single-threaded (the reference path behind
/// [`super::queries::run_query`]). Panics on a malformed plan — registry
/// plans always compile; use [`try_run_serial`] for ad-hoc IR.
pub fn run_serial(db: &TpchDb, p: &LogicalPlan) -> QueryOutput {
    try_run_serial(db, p).expect("logical plan failed to compile")
}

/// Run a query morsel-parallel on `threads` threads (0 = all cores),
/// `morsel_rows` rows per unit of scheduling.
///
/// Two phases, both selection-aware and both reusing per-thread scratch:
/// the predicate is evaluated over fixed-size *row* morsels in parallel
/// (ping-pong buffers per pool thread) and the surviving row ids
/// concatenated in row order; the aggregation then runs over fixed-size
/// slices of that *selection* (via
/// [`crate::exec::parallel_map_sel_chunks_with`]), so a selective
/// predicate whose survivors cluster in a few row ranges still spreads
/// its aggregation work evenly. Per-slice partials merge in slice order —
/// deterministic regardless of thread scheduling.
pub fn run_parallel(
    db: &TpchDb,
    plan: &LogicalPlan,
    threads: usize,
    morsel_rows: usize,
) -> QueryOutput {
    try_run_parallel(db, plan, threads, morsel_rows).expect("logical plan failed to compile")
}

/// Fallible form of [`run_parallel`] for ad-hoc wire plans.
pub fn try_run_parallel(
    db: &TpchDb,
    spec: &LogicalPlan,
    threads: usize,
    morsel_rows: usize,
) -> Result<QueryOutput> {
    let morsel_rows = morsel_rows.max(1);
    let (c, prep) = plan::compile(db, spec)?;
    let width = spec.width();
    let n = plan::table(db, spec.scan).len();

    let (pre_stats, partials): (ExecStats, Vec<Partial>) = if c.pred.is_all_pass()
        && !c.prune.is_active()
    {
        // Fast path: with an all-pass predicate every selection slice is
        // a row range, so fold row morsels directly — no materialized
        // n-element selection vector, no inter-phase barrier (q5/q9/q18
        // take this path).
        let partials =
            parallel_map_chunks_with(n, morsel_rows, threads, TaskScratch::new, |scr, lo, hi| {
                run_range_scratch(&c, width, lo, hi, scr)
            });
        (prep, partials)
    } else {
        // Phase 1: predicate → per-morsel selection vectors, row order
        // (zone-map pruning skips disjoint chunks before evaluation).
        let parts: Vec<(Vec<u32>, ExecStats)> =
            parallel_map_chunks_with(n, morsel_rows, threads, SelScratch::new, |scr, lo, hi| {
                let mut st = ExecStats::default();
                if c.prune.is_active() {
                    let mut out = Vec::new();
                    select_pruned(&c, lo, hi, scr, &mut st, &mut out);
                    (out, st)
                } else {
                    (c.pred.eval_into(lo, hi, scr, &mut st).to_vec(), st)
                }
            });
        let mut pre_stats = prep;
        let mut sel = Vec::with_capacity(parts.iter().map(|(s, _)| s.len()).sum());
        for (s, st) in &parts {
            pre_stats.merge(st);
            sel.extend_from_slice(s);
        }

        // Phase 2: aggregate balanced selection slices in parallel.
        let partials = parallel_map_sel_chunks_with(
            &sel,
            morsel_rows,
            threads,
            TaskScratch::new,
            |scr, slice| aggregate_sel_scratch(&c, width, slice, ExecStats::default(), scr),
        );
        (pre_stats, partials)
    };

    // Merge in slice order; fold in the compile + predicate stats.
    let mut merger = Merger::new(width);
    *merger.stats_mut() = pre_stats;
    let mut slice_ht_peak = 0u64;
    for p in &partials {
        slice_ht_peak = slice_ht_peak.max(p.stats.ht_bytes);
        merger.absorb(p).expect("plan produced mismatched partial width");
    }
    let mut merged = merger.into_partial();
    // The merge summed every transient per-slice hash table into
    // ht_bytes; the *live* peak is the compile-side tables plus one
    // slice table plus the merged-group state. Keep ht_bytes at its
    // documented "live at once" meaning.
    merged.stats.ht_bytes = pre_stats.ht_bytes
        + slice_ht_peak
        + merged.len() as u64 * Partial::group_bytes(width) as u64;
    let rows = plan::finalize(db, &spec.finalize, &merged)?;
    Ok(QueryOutput { rows, stats: merged.stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::queries::QUERY_NAMES;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn every_query_has_exactly_one_spec() {
        for q in QUERY_NAMES {
            let s = spec(q).unwrap_or_else(|| panic!("{q} has no LogicalPlan"));
            assert_eq!(s.name, q);
            let w = s.width();
            assert!(w >= 1 && w <= MAX_ACCS, "{q} width {w}");
        }
        assert!(spec("q99").is_none());
    }

    #[test]
    fn serial_path_is_one_kernel_call() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 7));
        for q in ["q1", "q6", "q18"] {
            let s = spec(q).unwrap();
            let (c, prep) = plan::compile(&db, &s).unwrap();
            let p = run_range(&c, s.width(), 0, db.lineitem.len());
            let direct = plan::finalize(&db, &s.finalize, &p).unwrap();
            let driver = run_serial(&db, &s);
            assert!(driver.approx_eq_rows(&direct), "{q}: driver != direct kernel");
            assert!(driver.stats.bytes_scanned >= p.stats.bytes_scanned);
            let _ = prep;
        }
    }

    #[test]
    fn kernel_splits_merge_to_full_range() {
        // Splitting the range and merging partials must equal one
        // full-range call, group for group (f64-exact within slices of
        // identical association is not guaranteed — compare via rows).
        let db = TpchDb::generate(TpchConfig::new(0.002, 11));
        let s = spec("q1").unwrap();
        let (c, _) = plan::compile(&db, &s).unwrap();
        let n = db.lineitem.len();
        let full = run_range(&c, s.width(), 0, n);
        let mut m = Merger::new(s.width());
        let mid = n / 3;
        for (lo, hi) in [(0, mid), (mid, n)] {
            m.absorb(&run_range(&c, s.width(), lo, hi)).unwrap();
        }
        let merged = m.into_partial();
        let rows_full = plan::finalize(&db, &s.finalize, &full).unwrap();
        let rows_merged = plan::finalize(&db, &s.finalize, &merged).unwrap();
        let out = QueryOutput { rows: rows_merged, stats: ExecStats::default() };
        assert!(out.approx_eq_rows(&rows_full));
    }

    #[test]
    fn fold_range_accumulates_like_one_call() {
        // The workers' shape: one long-lived agg + scratch folded morsel
        // by morsel must equal a single full-range kernel call exactly
        // (identical association — both fold rows in row order).
        let db = TpchDb::generate(TpchConfig::new(0.002, 19));
        for q in ["q1", "q6", "q12"] {
            let s = spec(q).unwrap();
            let (c, _) = plan::compile(&db, &s).unwrap();
            let n = db.lineitem.len();
            let full = run_range(&c, s.width(), 0, n);
            let mut agg = agg_for(&c, s.width(), n);
            let mut scr = TaskScratch::new();
            let mut stats = ExecStats::default();
            let mut lo = 0;
            while lo < n {
                let hi = (lo + 777).min(n);
                fold_range(&c, s.width(), lo, hi, &mut agg, &mut scr, &mut stats);
                lo = hi;
            }
            let folded = finish_fold(agg, stats);
            assert_eq!(folded.keys, full.keys, "{q}: group order diverged");
            assert_eq!(folded.counts, full.counts, "{q}: counts diverged");
            assert_eq!(folded.stats.rows_in, full.stats.rows_in, "{q}: rows_in diverged");
            let close = folded
                .accs
                .iter()
                .zip(&full.accs)
                .all(|(a, b)| (a - b).abs() <= 1e-6 * (1.0 + b.abs()));
            assert!(close, "{q}: accumulators diverged");
        }
    }

    #[test]
    fn empty_range_yields_empty_partial() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 13));
        for q in QUERY_NAMES {
            let s = spec(q).unwrap();
            let (c, _) = plan::compile(&db, &s).unwrap();
            let p = run_range(&c, s.width(), 0, 0);
            assert!(p.is_empty(), "{q}: non-empty partial from empty range");
            assert_eq!(p.width, s.width(), "{q}: width mismatch");
            // Finalize must tolerate an empty partial (scalar queries
            // return their zero row, grouped queries no rows).
            let _ = plan::finalize(&db, &s.finalize, &p).unwrap();
        }
    }

    #[test]
    fn parallel_matches_serial_for_all() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        for q in QUERY_NAMES {
            let s = spec(q).unwrap();
            let serial = run_serial(&db, &s);
            let par = run_parallel(&db, &s, 3, 777);
            assert!(
                par.approx_eq_rows(&serial.rows),
                "{q}: parallel ({} rows) diverged from serial ({} rows)",
                par.rows.len(),
                serial.rows.len()
            );
        }
    }
}

//! Vectorized primitive operators: branchless selection kernels, join
//! wrappers, top-k, execution statistics.
//!
//! Operators work over selection vectors (`Vec<u32>` of row ids) and
//! record an [`ExecStats`] so every query run yields the bytes-touched /
//! rows-processed profile the memory-contention model consumes. The
//! into-kernels here are the leaf shapes the engine's predicate
//! expressions ([`crate::analytics::engine::Predicate`]) compose; the
//! hash tables themselves live in the engine layer
//! ([`crate::analytics::engine`]) — [`JoinMap`] is a re-export alias
//! kept for the original name. (The one-shot owned-`Vec` filter
//! wrappers the early engine used were dropped once the `lovelock
//! lint` reachability walk showed nothing called them.)

pub use crate::analytics::engine::join::{HashJoinTable as JoinMap, ProbeIter};

/// Execution statistics accumulated across operators of one query run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Bytes of column data read (post-selection estimate).
    pub bytes_scanned: u64,
    /// Rows flowing into operators.
    pub rows_in: u64,
    /// Rows surviving operators.
    pub rows_out: u64,
    /// Peak bytes of hash tables live at once (approximated by sum).
    pub ht_bytes: u64,
    /// Scan chunks skipped wholesale by zone-map pruning (their rows are
    /// never touched and charge no `bytes_scanned`).
    pub morsels_pruned: u64,
}

impl ExecStats {
    pub fn merge(&mut self, o: &ExecStats) {
        self.bytes_scanned += o.bytes_scanned;
        self.rows_in += o.rows_in;
        self.rows_out += o.rows_out;
        self.ht_bytes += o.ht_bytes;
        self.morsels_pruned += o.morsels_pruned;
    }

    pub fn scan(&mut self, rows: usize, bytes_per_row: usize) {
        self.rows_in += rows as u64;
        self.bytes_scanned += (rows * bytes_per_row) as u64;
    }
}

/// Identity selection vector `[0, n)`.
pub fn all_rows(n: usize) -> Vec<u32> {
    (0..n as u32).collect()
}

// ------------------------------------------------ branchless into-kernels
//
// The engine's hot path evaluates predicates into *caller-provided*
// buffers (the ping-pong pair of
// [`crate::analytics::engine::expr::SelScratch`]), so the per-morsel
// steady state allocates nothing. The two primitives below are the leaf
// shape every filter compiles to: write the candidate id unconditionally,
// advance the cursor by the predicate cast to 0/1 — no per-row branch to
// mispredict at the 1-99% selectivities TPC-H predicates actually have.

/// Append the ids in `[lo, hi)` satisfying `pred` to `out[0..]`,
/// branchless; returns the number written. `out` must hold `hi - lo`.
#[inline]
pub fn select_into<F: Fn(usize) -> bool>(lo: usize, hi: usize, out: &mut [u32], pred: F) -> usize {
    debug_assert!(out.len() >= hi - lo);
    let mut k = 0;
    for i in lo..hi {
        out[k] = i as u32;
        k += pred(i) as usize;
    }
    k
}

/// Narrow an existing selection into `out[0..]`, branchless; returns the
/// number of survivors. `out` must hold `sel.len()` and may not alias it.
#[inline]
pub fn refine_into<F: Fn(usize) -> bool>(sel: &[u32], out: &mut [u32], pred: F) -> usize {
    debug_assert!(out.len() >= sel.len());
    let mut k = 0;
    for &i in sel {
        out[k] = i;
        k += pred(i as usize) as usize;
    }
    k
}

/// Morsel-parallel full-column variant of [`filter_i32_range`]: splits
/// the column into `morsel_rows`-sized chunks, filters each on the
/// scoped-thread pool, and concatenates the per-morsel selections in
/// row order (so output equals the serial filter exactly).
pub fn par_filter_i32_range(
    col: &[i32],
    lo: i32,
    hi: i32,
    threads: usize,
    morsel_rows: usize,
) -> Vec<u32> {
    crate::exec::parallel_map_chunks(col.len(), morsel_rows, threads, |s, e| {
        let mut v = vec![0u32; e - s];
        let n = select_into(s, e, &mut v, |i| {
            let x = col[i];
            x >= lo && x < hi
        });
        v.truncate(n);
        v
    })
    .concat()
}

/// `lo <= col[i] < hi` over i32 (date windows).
pub fn filter_i32_range(sel: &[u32], col: &[i32], lo: i32, hi: i32) -> Vec<u32> {
    let mut out = vec![0u32; sel.len()];
    let n = refine_into(sel, &mut out, |i| {
        let v = col[i];
        v >= lo && v < hi
    });
    out.truncate(n);
    out
}

/// Inner hash join: returns (probe_row, build_row) pairs for matches.
pub fn hash_join(
    build_keys: &[i64],
    build_sel: &[u32],
    probe_keys: &[i64],
    probe_sel: &[u32],
    stats: &mut ExecStats,
) -> Vec<(u32, u32)> {
    let map = JoinMap::build(build_keys, build_sel);
    stats.ht_bytes += map.bytes();
    stats.rows_in += (build_sel.len() + probe_sel.len()) as u64;
    let mut out = Vec::new();
    for &p in probe_sel {
        let k = probe_keys[p as usize];
        for b in map.probe(k) {
            out.push((p, b));
        }
    }
    stats.rows_out += out.len() as u64;
    out
}

/// Top-k by f64 score, descending; stable on ties by key ascending.
pub fn top_k_desc<K: Clone + Ord>(items: &mut Vec<(K, f64)>, k: usize) {
    items.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.0.cmp(&b.0))
    });
    items.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_basic() {
        let dates = vec![10, 20, 30, 40];
        assert_eq!(filter_i32_range(&all_rows(4), &dates, 20, 40), vec![1, 2]);
        assert!(filter_i32_range(&[], &dates, 20, 40).is_empty());
    }

    #[test]
    fn par_filter_matches_serial() {
        let col: Vec<i32> = (0..10_000).map(|i| (i * 7919) % 1000).collect();
        let serial = filter_i32_range(&all_rows(col.len()), &col, 100, 600);
        for (threads, morsel) in [(1, 64), (4, 64), (4, 1), (8, 4096), (4, 1 << 20)] {
            let par = par_filter_i32_range(&col, 100, 600, threads, morsel);
            assert_eq!(par, serial, "threads={threads} morsel={morsel}");
        }
        assert!(par_filter_i32_range(&[], 0, 1, 4, 64).is_empty());
    }

    #[test]
    fn filter_composes_on_selection() {
        let a = vec![10, 20, 30, 40, 50];
        let sel = filter_i32_range(&all_rows(5), &a, 0, 45); // 0..=3
        let sel2 = filter_i32_range(&sel, &a, 15, 100); // 1..=3
        assert_eq!(sel2, vec![1, 2, 3]);
    }

    #[test]
    fn hash_join_matches_nested_loop() {
        let build = vec![1i64, 2, 3, 2, 9];
        let probe = vec![2i64, 9, 4, 2];
        let mut stats = ExecStats::default();
        let mut got = hash_join(&build, &all_rows(5), &probe, &all_rows(4), &mut stats);
        got.sort_unstable();
        let mut expect = Vec::new();
        for (p, pk) in probe.iter().enumerate() {
            for (b, bk) in build.iter().enumerate() {
                if pk == bk {
                    expect.push((p as u32, b as u32));
                }
            }
        }
        expect.sort_unstable();
        assert_eq!(got, expect);
        assert!(stats.ht_bytes > 0);
        assert_eq!(stats.rows_out, expect.len() as u64);
    }

    #[test]
    fn join_with_selection_vectors() {
        let build = vec![1i64, 2, 3];
        let probe = vec![1i64, 2, 3];
        let mut stats = ExecStats::default();
        // Only build row 1 and probe rows {0,1} participate.
        let got = hash_join(&build, &[1], &probe, &[0, 1], &mut stats);
        assert_eq!(got, vec![(1, 1)]);
    }

    #[test]
    fn topk_orders_desc() {
        let mut items = vec![(1, 5.0), (2, 9.0), (3, 1.0), (4, 9.0)];
        top_k_desc(&mut items, 3);
        assert_eq!(items, vec![(2, 9.0), (4, 9.0), (1, 5.0)]);
    }

    #[test]
    fn select_into_is_branchless_select() {
        let col = [5, 1, 7, 3, 9];
        let mut out = [0u32; 5];
        let n = select_into(0, 5, &mut out, |i| col[i] >= 5);
        assert_eq!(&out[..n], &[0, 2, 4]);
        // Sub-range: ids stay absolute.
        let n = select_into(2, 5, &mut out, |i| col[i] >= 5);
        assert_eq!(&out[..n], &[2, 4]);
        assert_eq!(select_into(3, 3, &mut out, |_| true), 0);
    }

    #[test]
    fn refine_into_matches_filter() {
        let col = [1.0, 4.0, 2.0, 8.0];
        let sel = [0u32, 1, 3];
        let mut out = [0u32; 3];
        let n = refine_into(&sel, &mut out, |i| col[i] > 1.5);
        assert_eq!(&out[..n], &[1, 3]);
        assert_eq!(refine_into(&[], &mut out, |_| true), 0);
    }
}

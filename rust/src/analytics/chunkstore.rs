//! Chunked column storage: fixed-size row chunks with per-column
//! min-max *zone maps*.
//!
//! A [`ZoneMap`] summarises a table as consecutive chunks of
//! [`CHUNK_ROWS`] rows (the last chunk may be short). For every `i32`
//! and `f64` column it records the min and max value inside each chunk,
//! computed by the data *producer* (the TPC-H generator builds zones as
//! it appends chunks — no separate whole-table pass at query time).
//! Scans consult the map through [`crate::analytics::engine`]'s
//! `PrunePlan`: a chunk whose `[min, max]` interval cannot intersect the
//! predicate's derived interval is skipped without touching a byte.
//!
//! Zone maps are advisory: a table without one (or a column missing
//! from one) simply never prunes. Row-subset views ([`Table::take`])
//! drop the map, because selection breaks chunk alignment.
//!
//! [`Table::take`]: crate::analytics::column::Table::take

use crate::analytics::column::{Column, Table};

/// Rows per zone-map chunk. A divisor of the default morsel size
/// (16 384), so morsel boundaries land on chunk boundaries and a pruned
/// chunk is skipped by exactly one morsel.
pub const CHUNK_ROWS: usize = 4096;

/// Closed min-max interval of one chunk of one column.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Zone<T> {
    pub min: T,
    pub max: T,
}

/// Per-chunk zones for one column.
#[derive(Clone, Debug)]
pub enum ColZones {
    I32(Vec<Zone<i32>>),
    I64(Vec<Zone<i64>>),
    F64(Vec<Zone<f64>>),
}

impl ColZones {
    pub fn len(&self) -> usize {
        match self {
            ColZones::I32(v) => v.len(),
            ColZones::I64(v) => v.len(),
            ColZones::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Min-max zone map over a table's chunks.
#[derive(Clone, Debug, Default)]
pub struct ZoneMap {
    chunk_rows: usize,
    cols: Vec<(String, ColZones)>,
}

impl ZoneMap {
    pub fn new(chunk_rows: usize) -> Self {
        assert!(chunk_rows > 0, "zone map chunk size must be positive");
        Self { chunk_rows, cols: Vec::new() }
    }

    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Number of chunks summarised (0 for an empty map).
    pub fn chunks(&self) -> usize {
        self.cols.iter().map(|(_, z)| z.len()).max().unwrap_or(0)
    }

    pub fn add_col(&mut self, name: &str, zones: ColZones) {
        self.cols.push((name.to_string(), zones));
    }

    /// Zones for a column, if summarised.
    pub fn col(&self, name: &str) -> Option<&ColZones> {
        self.cols.iter().find(|(n, _)| n == name).map(|(_, z)| z)
    }

    /// Build a zone map by scanning every `i32`/`i64`/`f64` column of
    /// `t`.
    ///
    /// This is the path for tables whose producer did not build zones
    /// incrementally (dimension tables, test fixtures). `i64` coverage
    /// is what gives dimension tables zones on their join-key columns
    /// (`o_orderkey`, `p_partkey`, …), so the SQL planner's `explain`
    /// can report build-side prune potential. String/u8 columns carry
    /// no zones: no pruning interval can be derived for them.
    pub fn build_from(t: &Table, chunk_rows: usize) -> ZoneMap {
        let mut zm = ZoneMap::new(chunk_rows);
        for name in t.column_names() {
            match t.col(name) {
                Column::I32(v) => zm.add_col(name, ColZones::I32(zones_i32(v, chunk_rows))),
                Column::I64(v) => zm.add_col(name, ColZones::I64(zones_i64(v, chunk_rows))),
                Column::F64(v) => zm.add_col(name, ColZones::F64(zones_f64(v, chunk_rows))),
                _ => {}
            }
        }
        zm
    }
}

/// Per-chunk min/max over an `i32` slice. Chunk `c` covers rows
/// `[c * chunk_rows, (c + 1) * chunk_rows)` of `vals`; a chunk-aligned
/// slice of a larger column therefore yields exactly the global map's
/// entries for those chunks, which is what lets parallel generator
/// shards concatenate their zones.
pub fn zones_i32(vals: &[i32], chunk_rows: usize) -> Vec<Zone<i32>> {
    vals.chunks(chunk_rows)
        .map(|c| {
            let mut z = Zone { min: c[0], max: c[0] };
            for &v in &c[1..] {
                z.min = z.min.min(v);
                z.max = z.max.max(v);
            }
            z
        })
        .collect()
}

/// Per-chunk min/max over an `i64` slice (see [`zones_i32`]).
pub fn zones_i64(vals: &[i64], chunk_rows: usize) -> Vec<Zone<i64>> {
    vals.chunks(chunk_rows)
        .map(|c| {
            let mut z = Zone { min: c[0], max: c[0] };
            for &v in &c[1..] {
                z.min = z.min.min(v);
                z.max = z.max.max(v);
            }
            z
        })
        .collect()
}

/// Per-chunk min/max over an `f64` slice (see [`zones_i32`]). NaN never
/// occurs in generated data; if it did, min/max would absorb it and the
/// pruning comparisons (all strict, NaN-false) would simply never prune
/// that chunk — conservative, not wrong.
pub fn zones_f64(vals: &[f64], chunk_rows: usize) -> Vec<Zone<f64>> {
    vals.chunks(chunk_rows)
        .map(|c| {
            let mut z = Zone { min: c[0], max: c[0] };
            for &v in &c[1..] {
                z.min = z.min.min(v);
                z.max = z.max.max(v);
            }
            z
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zones_cover_chunks_including_short_tail() {
        let vals: Vec<i32> = (0..10).collect();
        let z = zones_i32(&vals, 4);
        assert_eq!(z.len(), 3);
        assert_eq!(z[0], Zone { min: 0, max: 3 });
        assert_eq!(z[1], Zone { min: 4, max: 7 });
        assert_eq!(z[2], Zone { min: 8, max: 9 });
    }

    #[test]
    fn f64_zones_track_min_and_max() {
        let z = zones_f64(&[1.5, -2.0, 0.0, 7.25, 3.0], 3);
        assert_eq!(z.len(), 2);
        assert_eq!(z[0], Zone { min: -2.0, max: 1.5 });
        assert_eq!(z[1], Zone { min: 3.0, max: 7.25 });
    }

    #[test]
    fn aligned_slices_concatenate_to_the_global_map() {
        let vals: Vec<i32> = (0..100).map(|i| (i * 37) % 91).collect();
        let whole = zones_i32(&vals, 8);
        let mut parts = zones_i32(&vals[..48], 8);
        parts.extend(zones_i32(&vals[48..], 8));
        assert_eq!(whole, parts);
    }

    #[test]
    fn build_from_covers_numeric_columns_only() {
        let mut t = Table::new("t");
        t.add("k", Column::I64(vec![1, 2, 3, 4, 5]));
        t.add("d", Column::I32(vec![10, 20, 30, 40, 50]));
        t.add("x", Column::F64(vec![0.1, 0.2, 0.3, 0.4, 0.5]));
        t.add("s", Column::U8(vec![b'a', b'b', b'c', b'd', b'e']));
        let zm = ZoneMap::build_from(&t, 2);
        assert_eq!(zm.chunk_rows(), 2);
        assert_eq!(zm.chunks(), 3);
        assert!(zm.col("s").is_none(), "u8 columns carry no zones");
        match zm.col("k").unwrap() {
            ColZones::I64(z) => {
                assert_eq!(z.len(), 3);
                assert_eq!(z[0], Zone { min: 1, max: 2 });
                assert_eq!(z[2], Zone { min: 5, max: 5 });
            }
            _ => panic!("k must be i64 zones"),
        }
        match zm.col("d").unwrap() {
            ColZones::I32(z) => {
                assert_eq!(z.len(), 3);
                assert_eq!(z[2], Zone { min: 50, max: 50 });
            }
            _ => panic!("d must be i32 zones"),
        }
        match zm.col("x").unwrap() {
            ColZones::F64(z) => assert_eq!(z[0], Zone { min: 0.1, max: 0.2 }),
            _ => panic!("x must be f64 zones"),
        }
        assert!(zm.col("missing").is_none());
    }

    #[test]
    fn empty_map_reports_zero_chunks() {
        let zm = ZoneMap::new(4096);
        assert_eq!(zm.chunks(), 0);
        assert_eq!(ZoneMap::default().chunk_rows(), 0);
    }
}

//! Query profiling: turn a real engine run into the demand profile the
//! memory-contention model consumes.
//!
//! Figure 3's methodology (DESIGN.md §6): run each query single-threaded
//! on *this* machine, measure wall time and the engine-reported bytes
//! moved, normalize CPU seconds to E2000 single-core units, and linearly
//! rescale to the paper's scale factor (SF 1). The contention simulation
//! is then a pure function of the profile and the platform.

use super::queries::run_query;
use super::tpch::TpchDb;
use crate::memsim::WorkloadProfile;
use std::time::Instant;

/// Calibration: single-core speed of this host relative to one E2000 ARM
/// N1 core. Only *ratios across platforms* matter downstream, so the
/// default (2.0 — a modern x86 dev core is roughly twice an N1) shifts
/// all bars identically. Override with LOVELOCK_HOST_SPEED.
pub fn host_speed() -> f64 {
    std::env::var("LOVELOCK_HOST_SPEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0)
}

/// Profile of one query at a reference scale factor.
#[derive(Clone, Debug)]
pub struct QueryProfile {
    pub name: String,
    /// Measured wall seconds on this host at the generated SF.
    pub host_secs: f64,
    /// E2000-normalized single-core CPU seconds at the target SF.
    pub cpu_secs: f64,
    /// DRAM bytes per execution at the target SF.
    pub dram_bytes: f64,
    /// Working set (hash tables + hot columns) at the target SF.
    pub working_set_bytes: f64,
}

impl QueryProfile {
    pub fn workload(&self) -> WorkloadProfile {
        WorkloadProfile {
            cpu_secs: self.cpu_secs,
            dram_bytes: self.dram_bytes,
            working_set_bytes: self.working_set_bytes,
        }
    }
}

/// Run `name` on `db` (generated at `db.config.sf`), scale the profile to
/// `target_sf`, and normalize CPU seconds to E2000 units.
pub fn profile_query(db: &TpchDb, name: &str, target_sf: f64) -> Option<QueryProfile> {
    let t0 = Instant::now();
    let out = run_query(db, name)?;
    let host_secs = t0.elapsed().as_secs_f64();
    let scale = target_sf / db.config.sf;
    // Cache-line inflation: the engine's logical byte counts understate
    // real DRAM traffic (64 B line granularity on strided/selective
    // access, write-allocate traffic, metadata). Factor calibrated
    // against STREAM-vs-logical ratios of columnar scans.
    const LINE_INFLATION: f64 = 1.5;
    // Hash tables are written once and probed ~once per probe row; count
    // them twice (write + read) in DRAM traffic.
    let dram =
        (out.stats.bytes_scanned + 2 * out.stats.ht_bytes) as f64 * LINE_INFLATION * scale;
    // Working set: the live hash tables; scans stream and do not occupy.
    let ws = (out.stats.ht_bytes as f64 * scale).max(4.0e6);
    Some(QueryProfile {
        name: name.to_string(),
        host_secs,
        cpu_secs: (host_secs * host_speed() * scale).max(1e-9),
        dram_bytes: dram.max(1.0),
        working_set_bytes: ws,
    })
}

/// Like [`profile_query`] but with warmup: runs the query `iters + 1`
/// times and keeps the fastest wall time, suppressing cold-allocation
/// noise at small scale factors.
pub fn profile_query_warm(
    db: &TpchDb,
    name: &str,
    target_sf: f64,
    iters: usize,
) -> Option<QueryProfile> {
    let mut best: Option<QueryProfile> = None;
    for _ in 0..=iters.max(1) {
        let p = profile_query(db, name, target_sf)?;
        let better = best.as_ref().map(|b| p.host_secs < b.host_secs).unwrap_or(true);
        if better {
            best = Some(p);
        }
    }
    best
}

/// Profile every Figure-3 query (with warmup).
pub fn profile_all(db: &TpchDb, target_sf: f64) -> Vec<QueryProfile> {
    super::queries::QUERY_NAMES
        .iter()
        .filter_map(|n| profile_query_warm(db, n, target_sf, 2))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn profiles_scale_linearly() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 7));
        let p1 = profile_query(&db, "q6", 0.002).unwrap();
        let p10 = profile_query(&db, "q6", 0.02).unwrap();
        // DRAM traffic scales exactly with the target SF (deterministic);
        // cpu_secs scales with both SF and a fresh wall measurement, so
        // it is only checked for positivity here.
        let ratio = p10.dram_bytes / p1.dram_bytes;
        assert!((ratio - 10.0).abs() < 0.01, "ratio={ratio}");
        assert!(p1.cpu_secs > 0.0 && p10.cpu_secs > 0.0);
    }

    #[test]
    fn q1_more_intense_than_q6() {
        // Q1 touches more bytes per cpu-second than Q6 relative to its
        // runtime? At minimum it must move more total bytes.
        let db = TpchDb::generate(TpchConfig::new(0.002, 7));
        let q1 = profile_query(&db, "q1", 1.0).unwrap();
        let q6 = profile_query(&db, "q6", 1.0).unwrap();
        assert!(q1.dram_bytes > q6.dram_bytes);
    }

    #[test]
    fn all_queries_profile() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 7));
        let ps = profile_all(&db, 1.0);
        assert_eq!(ps.len(), crate::analytics::queries::QUERY_NAMES.len());
        for p in &ps {
            assert!(p.cpu_secs > 0.0, "{}", p.name);
            assert!(p.dram_bytes > 0.0, "{}", p.name);
            assert!(p.working_set_bytes > 0.0, "{}", p.name);
        }
    }

    #[test]
    fn unknown_query_is_none() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 7));
        assert!(profile_query(&db, "q999", 1.0).is_none());
    }
}

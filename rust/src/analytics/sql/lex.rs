//! SQL lexer — hand-rolled, zero dependencies, never panics.
//!
//! Produces a flat token stream for the recursive-descent parser in
//! [`super::ast`]. Keywords are not distinguished here: the parser
//! matches identifiers case-insensitively, so `SELECT`, `select`, and
//! `Select` all work while column names stay verbatim. String literals
//! use single quotes with `''` as the escape for a literal quote
//! (standard SQL).

use crate::error::Result;

/// One lexical token.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Bare identifier *or* keyword (the parser decides, ignoring case).
    Ident(String),
    /// Integer literal (no sign — `-` is a token of its own).
    Int(i64),
    /// Float literal (`digits.digits`).
    Float(f64),
    /// `'single-quoted'` string, `''` unescaped to `'`.
    Str(String),
    LParen,
    RParen,
    Comma,
    Star,
    Plus,
    Minus,
    Slash,
    Eq,
    Lt,
    Le,
    Gt,
    Ge,
    /// `<>` (also accepted: `!=`).
    Ne,
}

impl Tok {
    /// Human-readable form for error messages.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("{s:?}"),
            Tok::Int(v) => format!("{v}"),
            Tok::Float(v) => format!("{v}"),
            Tok::Str(s) => format!("'{s}'"),
            Tok::LParen => "(".into(),
            Tok::RParen => ")".into(),
            Tok::Comma => ",".into(),
            Tok::Star => "*".into(),
            Tok::Plus => "+".into(),
            Tok::Minus => "-".into(),
            Tok::Slash => "/".into(),
            Tok::Eq => "=".into(),
            Tok::Lt => "<".into(),
            Tok::Le => "<=".into(),
            Tok::Gt => ">".into(),
            Tok::Ge => ">=".into(),
            Tok::Ne => "<>".into(),
        }
    }
}

/// Tokenize `input`. Errors name the offending byte offset; nothing
/// here recurses or indexes unchecked, so hostile input cannot panic.
pub fn lex(input: &str) -> Result<Vec<Tok>> {
    let b = input.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => i += 1,
            b'(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            b',' => {
                toks.push(Tok::Comma);
                i += 1;
            }
            b'*' => {
                toks.push(Tok::Star);
                i += 1;
            }
            b'+' => {
                toks.push(Tok::Plus);
                i += 1;
            }
            b'-' => {
                toks.push(Tok::Minus);
                i += 1;
            }
            b'/' => {
                toks.push(Tok::Slash);
                i += 1;
            }
            b'=' => {
                toks.push(Tok::Eq);
                i += 1;
            }
            b'!' => {
                crate::ensure!(b.get(i + 1) == Some(&b'='), "lone '!' at byte {i}");
                toks.push(Tok::Ne);
                i += 2;
            }
            b'<' => match b.get(i + 1) {
                Some(b'=') => {
                    toks.push(Tok::Le);
                    i += 2;
                }
                Some(b'>') => {
                    toks.push(Tok::Ne);
                    i += 2;
                }
                _ => {
                    toks.push(Tok::Lt);
                    i += 1;
                }
            },
            b'>' => {
                if b.get(i + 1) == Some(&b'=') {
                    toks.push(Tok::Ge);
                    i += 2;
                } else {
                    toks.push(Tok::Gt);
                    i += 1;
                }
            }
            b'\'' => {
                let mut s = String::new();
                let mut j = i + 1;
                loop {
                    match b.get(j) {
                        None => crate::bail!("unterminated string starting at byte {i}"),
                        Some(b'\'') if b.get(j + 1) == Some(&b'\'') => {
                            s.push('\'');
                            j += 2;
                        }
                        Some(b'\'') => {
                            j += 1;
                            break;
                        }
                        Some(&ch) => {
                            // Column data is ASCII throughout; keeping
                            // the lexer byte-oriented avoids UTF-8
                            // boundary bookkeeping.
                            crate::ensure!(ch.is_ascii(), "non-ASCII byte in string at {j}");
                            s.push(ch as char);
                            j += 1;
                        }
                    }
                }
                toks.push(Tok::Str(s));
                i = j;
            }
            b'0'..=b'9' => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let is_float =
                    i < b.len() && b[i] == b'.' && b.get(i + 1).is_some_and(|d| d.is_ascii_digit());
                if is_float {
                    i += 1;
                    while i < b.len() && b[i].is_ascii_digit() {
                        i += 1;
                    }
                    let text = &input[start..i];
                    let v = text
                        .parse::<f64>()
                        .map_err(|_| crate::err!("bad float literal {text:?}"))?;
                    toks.push(Tok::Float(v));
                } else {
                    let text = &input[start..i];
                    let v = text
                        .parse::<i64>()
                        .map_err(|_| crate::err!("integer literal {text:?} out of range"))?;
                    toks.push(Tok::Int(v));
                }
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                toks.push(Tok::Ident(input[start..i].to_string()));
            }
            _ => crate::bail!("unexpected byte {:?} at offset {i}", c as char),
        }
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_a_representative_query() {
        let toks = lex("SELECT sum(l_extendedprice * 0.5) FROM lineitem WHERE a >= 10").unwrap();
        assert_eq!(toks[0], Tok::Ident("SELECT".into()));
        assert!(toks.contains(&Tok::Float(0.5)));
        assert!(toks.contains(&Tok::Ge));
        assert!(toks.contains(&Tok::Int(10)));
    }

    #[test]
    fn string_escapes_and_operators() {
        assert_eq!(
            lex("'it''s' <> '' <=").unwrap(),
            vec![Tok::Str("it's".into()), Tok::Ne, Tok::Str(String::new()), Tok::Le]
        );
    }

    #[test]
    fn rejects_junk_without_panicking() {
        assert!(lex("select ; from").is_err());
        assert!(lex("'unterminated").is_err());
        assert!(lex("99999999999999999999").is_err());
        assert!(lex("a ! b").is_err());
    }
}

//! Rule-based optimizer over the [`LogicalPlan`] IR.
//!
//! Works on the IR, not on SQL — registry-built plans benefit exactly
//! as much as SQL-bound ones. Rules run in a fixed order:
//!
//! 1. **Constant folding** — `Add/Sub/Mul` over two constants collapse,
//!    recursively (the binder lowers `DATE '1994-01-01' + 90` to
//!    `Add(Const, Const)`; folding it is what makes rule 2 fire).
//! 2. **Predicate pushdown** — a post-join compare of a scan column
//!    against a constant becomes a scan-predicate leaf (`I32Range`,
//!    `F64Range`, `F64Lt`, `I32ColLt`); a compare of a plain `Col`
//!    payload against a constant moves into that join step's dim-side
//!    filter, excluding rows from the build instead of testing every
//!    probe. Pushed scan leaves feed the zone-map prune derivation, so
//!    this rule is what turns folded date arithmetic into skipped
//!    morsels.
//! 3. **Range merging** — `And` trees flatten, `True` leaves drop, and
//!    per-column intervals intersect into a single half-open leaf
//!    (anchored where the column first appeared, so registry predicates
//!    round-trip unchanged).
//! 4. **Join reordering** — steps sort by estimated build-side rows
//!    (see [`crate::costmodel::estimate`]), smallest build first; link
//!    targets stay ahead of their linkers; every step reference
//!    (payload values, key parts, link edges) is remapped.
//! 5. **Payload elision** — payloads nothing reads (often orphaned by
//!    rule 2) are removed and the surviving slots renumbered.
//!    `CaseConst` payloads always stay: their no-match case *excludes*
//!    build rows, which is a filter in disguise.
//!
//! Exactness notes: integer bounds convert with floor/ceil so
//! fractional constants tighten correctly (`x < 24.5` ⇒ `hi = 25`);
//! float `Le`/`Gt`/`Eq` bounds use the next representable double, which
//! is exact for the finite column data the generator produces (no NaN,
//! no infinities). A rule that cannot prove its rewrite safe leaves the
//! compare where it was.

use crate::analytics::engine::plan::{
    pand, CmpExpr, CmpOp, JoinStep, KeyExpr, LogicalPlan, Payload, PredExpr, TableRef, ValExpr,
};
use crate::costmodel;
use super::catalog::{self, ColType};

/// Run every rule, in order. Pure: the input plan is untouched.
pub fn optimize(plan: &LogicalPlan) -> LogicalPlan {
    let mut p = plan.clone();
    fold_plan(&mut p);
    push_down(&mut p);
    p.pred = merge_ranges(std::mem::replace(&mut p.pred, PredExpr::True));
    for j in &mut p.joins {
        j.filter = merge_ranges(std::mem::replace(&mut j.filter, PredExpr::True));
    }
    reorder_joins(&mut p);
    elide_payloads(&mut p);
    p
}

// ------------------------------------------------------ constant folding

fn fold_plan(p: &mut LogicalPlan) {
    for c in &mut p.cmps {
        fold_val(&mut c.lhs);
        fold_val(&mut c.rhs);
    }
    for s in &mut p.slots {
        fold_val(s);
    }
}

fn fold_val(v: &mut ValExpr) {
    match v {
        ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
            fold_val(a);
            fold_val(b);
            if let (ValExpr::Const(x), ValExpr::Const(y)) = (a.as_ref(), b.as_ref()) {
                *v = ValExpr::Const(match v {
                    ValExpr::Add(..) => x + y,
                    ValExpr::Sub(..) => x - y,
                    _ => x * y,
                });
            }
        }
        ValExpr::Const(_) | ValExpr::Col(_) | ValExpr::Payload { .. } => {}
    }
}

// ---------------------------------------------------- predicate pushdown

/// Where a pushed leaf lands.
enum Sink {
    Scan,
    Step(usize),
}

fn push_down(p: &mut LogicalPlan) {
    let mut kept = Vec::new();
    let mut scan_extra = Vec::new();
    let mut step_extra: Vec<Vec<PredExpr>> = vec![Vec::new(); p.joins.len()];
    for c in std::mem::take(&mut p.cmps) {
        match try_push(&c, &p.joins) {
            Some((Sink::Scan, leaf)) => scan_extra.push(leaf),
            Some((Sink::Step(s), leaf)) => step_extra[s].push(leaf),
            None => kept.push(c),
        }
    }
    p.cmps = kept;
    if !scan_extra.is_empty() {
        let mut all = vec![std::mem::replace(&mut p.pred, PredExpr::True)];
        all.extend(scan_extra);
        p.pred = pand(all);
    }
    for (j, extra) in p.joins.iter_mut().zip(step_extra) {
        if !extra.is_empty() {
            let mut all = vec![std::mem::replace(&mut j.filter, PredExpr::True)];
            all.extend(extra);
            j.filter = pand(all);
        }
    }
}

/// Try to convert one compare into a predicate leaf plus its sink.
fn try_push(c: &CmpExpr, joins: &[JoinStep]) -> Option<(Sink, PredExpr)> {
    // col-vs-col first: `a < b` over two scan date/int columns.
    if c.op == CmpOp::Lt {
        if let (ValExpr::Col(a), ValExpr::Col(b)) = (&c.lhs, &c.rhs) {
            if is_i32_scan(a) && is_i32_scan(b) {
                return Some((Sink::Scan, PredExpr::I32ColLt { a: a.clone(), b: b.clone() }));
            }
        }
    }
    if c.op == CmpOp::Gt {
        if let (ValExpr::Col(a), ValExpr::Col(b)) = (&c.lhs, &c.rhs) {
            if is_i32_scan(a) && is_i32_scan(b) {
                return Some((Sink::Scan, PredExpr::I32ColLt { a: b.clone(), b: a.clone() }));
            }
        }
    }
    // Normalize to (column-ish, op, constant).
    let (target, op, k) = match (&c.lhs, &c.rhs) {
        (lhs, ValExpr::Const(k)) => (lhs, c.op, *k),
        (ValExpr::Const(k), rhs) => (rhs, mirror(c.op), *k),
        _ => return None,
    };
    match target {
        ValExpr::Col(col) => {
            let (td, cd) = catalog_entry(col)?;
            if td != TableRef::Lineitem {
                return None;
            }
            Some((Sink::Scan, leaf_for(col, cd, op, k)?))
        }
        ValExpr::Payload { step, slot } => {
            let j = joins.get(*step as usize)?;
            // Only a plain column payload is a faithful copy of the dim
            // value; flags and case constants are computed, and
            // FromLink values belong to another step's build.
            let Payload::Col(col) = j.payloads.get(*slot as usize)? else {
                return None;
            };
            let (td, cd) = catalog_entry(col)?;
            if td != j.table {
                return None;
            }
            Some((Sink::Step(*step as usize), leaf_for(col, cd, op, k)?))
        }
        _ => None,
    }
}

fn catalog_entry(col: &str) -> Option<(TableRef, ColType)> {
    let (td, cd) = catalog::resolve(col).ok()?;
    Some((td.table, cd.ty))
}

fn is_i32_scan(col: &str) -> bool {
    matches!(
        catalog_entry(col),
        Some((TableRef::Lineitem, ColType::I32 | ColType::Date))
    )
}

fn mirror(op: CmpOp) -> CmpOp {
    match op {
        CmpOp::Eq => CmpOp::Eq,
        CmpOp::Lt => CmpOp::Gt,
        CmpOp::Le => CmpOp::Ge,
        CmpOp::Ge => CmpOp::Le,
        CmpOp::Gt => CmpOp::Lt,
    }
}

/// Lower `col op k` to a typed predicate leaf, or `None` when the
/// column type has no exact leaf form (strings, i64 keys).
fn leaf_for(col: &str, ty: ColType, op: CmpOp, k: f64) -> Option<PredExpr> {
    match ty {
        ColType::I32 | ColType::Date => int_leaf(col, op, k),
        ColType::F64 => f64_leaf(col, op, k),
        ColType::Key | ColType::Char | ColType::Str => None,
    }
}

fn int_leaf(col: &str, op: CmpOp, k: f64) -> Option<PredExpr> {
    if !k.is_finite() {
        return None;
    }
    let range = |lo: i64, hi: i64| -> Option<PredExpr> {
        let lo = i32::try_from(lo.max(i32::MIN as i64)).ok()?;
        let hi = i32::try_from(hi.min(i32::MAX as i64)).ok()?;
        Some(PredExpr::I32Range { col: col.to_string(), lo, hi })
    };
    let is_int = k.fract() == 0.0 && (i32::MIN as f64..=i32::MAX as f64).contains(&k);
    // `as` saturates, so out-of-range constants clamp — which is exact
    // here, because the column's values all fit in i32 anyway.
    let fl = k.floor() as i64;
    let ce = k.ceil() as i64;
    // All bounds are half-open [lo, hi).
    match op {
        CmpOp::Lt => range(i32::MIN as i64, if is_int { k as i64 } else { fl.saturating_add(1) }),
        CmpOp::Le => range(i32::MIN as i64, fl.saturating_add(1)),
        CmpOp::Ge => range(ce, i32::MAX as i64),
        CmpOp::Gt => range(fl.saturating_add(1), i32::MAX as i64),
        CmpOp::Eq => {
            if is_int {
                range(k as i64, k as i64 + 1)
            } else {
                // `int_col = 2.5` holds for no row.
                range(0, 0)
            }
        }
    }
}

fn f64_leaf(col: &str, op: CmpOp, k: f64) -> Option<PredExpr> {
    if !k.is_finite() {
        return None;
    }
    let col = col.to_string();
    Some(match op {
        CmpOp::Lt => PredExpr::F64Lt { col, x: k },
        CmpOp::Le => PredExpr::F64Lt { col, x: next_up(k) },
        CmpOp::Ge => PredExpr::F64Range { col, lo: k, hi: f64::INFINITY },
        CmpOp::Gt => PredExpr::F64Range { col, lo: next_up(k), hi: f64::INFINITY },
        CmpOp::Eq => PredExpr::F64Range { col, lo: k, hi: next_up(k) },
    })
}

/// Next representable double above `k` (finite `k` only).
fn next_up(k: f64) -> f64 {
    if k == 0.0 {
        return f64::from_bits(1); // covers -0.0 too
    }
    let bits = k.to_bits();
    if k > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

// -------------------------------------------------------- range merging

/// Flatten `And` trees, drop `True`, intersect per-column intervals.
/// Each merged leaf sits where its column first appeared, so an
/// already-minimal predicate comes back structurally identical.
fn merge_ranges(p: PredExpr) -> PredExpr {
    let mut flat = Vec::new();
    flatten_and(p, &mut flat);

    enum Slot {
        I32 { col: String, lo: i32, hi: i32 },
        F64 { col: String, lo: f64, hi: f64 }, // [lo, hi), ±inf sentinels
        Other(PredExpr),
    }
    let mut slots: Vec<Slot> = Vec::new();
    for leaf in flat {
        match leaf {
            PredExpr::True => {}
            PredExpr::I32Range { col, lo, hi } => {
                let hit = slots.iter_mut().find_map(|s| match s {
                    Slot::I32 { col: c, lo: l, hi: h } if *c == col => Some((l, h)),
                    _ => None,
                });
                match hit {
                    Some((l, h)) => {
                        *l = (*l).max(lo);
                        *h = (*h).min(hi);
                    }
                    None => slots.push(Slot::I32 { col, lo, hi }),
                }
            }
            PredExpr::F64Range { .. } | PredExpr::F64Lt { .. } => {
                let (col, lo, hi) = match leaf {
                    PredExpr::F64Range { col, lo, hi } => (col, lo, hi),
                    PredExpr::F64Lt { col, x } => (col, f64::NEG_INFINITY, x),
                    _ => unreachable!(),
                };
                let hit = slots.iter_mut().find_map(|s| match s {
                    Slot::F64 { col: c, lo: l, hi: h } if *c == col => Some((l, h)),
                    _ => None,
                });
                match hit {
                    Some((l, h)) => {
                        *l = (*l).max(lo);
                        *h = (*h).min(hi);
                    }
                    None => slots.push(Slot::F64 { col, lo, hi }),
                }
            }
            other => slots.push(Slot::Other(other)),
        }
    }
    let mut out = Vec::new();
    for s in slots {
        out.push(match s {
            Slot::I32 { col, lo, hi } => PredExpr::I32Range { col, lo, hi },
            Slot::F64 { col, lo, hi } => {
                if lo == f64::NEG_INFINITY {
                    PredExpr::F64Lt { col, x: hi }
                } else {
                    PredExpr::F64Range { col, lo, hi }
                }
            }
            Slot::Other(p) => p,
        });
    }
    match out.len() {
        0 => PredExpr::True,
        1 => out.remove(0),
        _ => PredExpr::And(out),
    }
}

fn flatten_and(p: PredExpr, out: &mut Vec<PredExpr>) {
    match p {
        PredExpr::And(parts) => {
            for part in parts {
                flatten_and(part, out);
            }
        }
        other => out.push(other),
    }
}

// ------------------------------------------------------ join reordering

/// Sort steps ascending by estimated build rows (selection sort for
/// stability), holding every link target ahead of its linker, then
/// remap all step references.
fn reorder_joins(p: &mut LogicalPlan) {
    if p.joins.len() < 2 {
        return;
    }
    let est = costmodel::estimate(p, 1.0);
    let n = p.joins.len();
    // order[new] = old
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut placed = vec![false; n];
    for _ in 0..n {
        let mut best: Option<usize> = None;
        for i in 0..n {
            if placed[i] {
                continue;
            }
            // A linker cannot move ahead of its unplaced target.
            if let Some(l) = &p.joins[i].link {
                if !placed[l.step as usize] {
                    continue;
                }
            }
            let better = match best {
                None => true,
                Some(b) => est.steps[i].build_rows < est.steps[b].build_rows,
            };
            if better {
                best = Some(i);
            }
        }
        let i = best.expect("link edges are acyclic (target index < linker index)");
        placed[i] = true;
        order.push(i);
    }
    if order.iter().enumerate().all(|(new, old)| new == *old) {
        return;
    }
    // remap[old] = new
    let mut remap = vec![0u8; n];
    for (new, old) in order.iter().enumerate() {
        remap[*old] = new as u8;
    }
    let mut steps: Vec<Option<JoinStep>> = p.joins.drain(..).map(Some).collect();
    p.joins = order.iter().map(|old| steps[*old].take().expect("each old index once")).collect();
    for j in &mut p.joins {
        if let Some(l) = &mut j.link {
            l.step = remap[l.step as usize];
        }
    }
    for c in &mut p.cmps {
        remap_val(&mut c.lhs, &remap);
        remap_val(&mut c.rhs, &remap);
    }
    for s in &mut p.slots {
        remap_val(s, &remap);
    }
    remap_key(&mut p.key, &remap);
}

fn remap_val(v: &mut ValExpr, remap: &[u8]) {
    match v {
        ValExpr::Payload { step, .. } => *step = remap[*step as usize],
        ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
            remap_val(a, remap);
            remap_val(b, remap);
        }
        ValExpr::Const(_) | ValExpr::Col(_) => {}
    }
}

fn remap_key(k: &mut KeyExpr, remap: &[u8]) {
    match k {
        KeyExpr::Payload { step, .. } => *step = remap[*step as usize],
        KeyExpr::Year(inner) => remap_key(inner, remap),
        KeyExpr::Pack { hi, lo, .. } => {
            remap_key(hi, remap);
            remap_key(lo, remap);
        }
        KeyExpr::Const(_) | KeyExpr::Col(_) => {}
    }
}

// ------------------------------------------------------ payload elision

/// Remove payloads nothing references and renumber the survivors.
/// `CaseConst` never goes: its no-match case excludes build rows.
/// Dropping a `FromLink` can orphan its target's column payload, so the
/// pass loops to a fixed point.
fn elide_payloads(p: &mut LogicalPlan) {
    loop {
        let mut used: Vec<Vec<bool>> =
            p.joins.iter().map(|j| vec![false; j.payloads.len()]).collect();
        for c in &p.cmps {
            mark_val(&c.lhs, &mut used);
            mark_val(&c.rhs, &mut used);
        }
        for s in &p.slots {
            mark_val(s, &mut used);
        }
        mark_key(&p.key, &mut used);
        for (i, j) in p.joins.iter().enumerate() {
            if let Some(l) = &j.link {
                let target = l.step as usize;
                for (slot, pay) in j.payloads.iter().enumerate() {
                    if let Payload::FromLink(k) = pay {
                        // The link-through read matters only if someone
                        // reads the FromLink slot itself.
                        if used[i][slot] {
                            if let Some(u) = used[target].get_mut(*k as usize) {
                                *u = true;
                            }
                        }
                    }
                }
            }
        }
        // Plan the removals first (renumbering touches the whole plan,
        // so it cannot run while iterating the joins mutably).
        let mut removals: Vec<(usize, Vec<Option<u8>>)> = Vec::new();
        for (i, j) in p.joins.iter().enumerate() {
            let mut newidx: Vec<Option<u8>> = Vec::with_capacity(j.payloads.len());
            let mut next = 0u8;
            let mut dropped = false;
            for (slot, pay) in j.payloads.iter().enumerate() {
                if used[i][slot] || matches!(pay, Payload::CaseConst { .. }) {
                    newidx.push(Some(next));
                    next += 1;
                } else {
                    newidx.push(None);
                    dropped = true;
                }
            }
            if dropped {
                removals.push((i, newidx));
            }
        }
        if removals.is_empty() {
            return;
        }
        for (i, newidx) in removals {
            let old = std::mem::take(&mut p.joins[i].payloads);
            p.joins[i].payloads = old
                .into_iter()
                .zip(&newidx)
                .filter_map(|(pay, keep)| keep.map(|_| pay))
                .collect();
            renumber_step_slots(p, i, &newidx);
        }
    }
}

/// Renumber every reference to `step`'s payload slots after an elision
/// (values, key parts, and linkers' `FromLink` arguments).
fn renumber_step_slots(p: &mut LogicalPlan, step: usize, newidx: &[Option<u8>]) {
    fn fix_val(v: &mut ValExpr, step: usize, newidx: &[Option<u8>]) {
        match v {
            ValExpr::Payload { step: s, slot } if *s as usize == step => {
                if let Some(Some(n)) = newidx.get(*slot as usize) {
                    *slot = *n;
                }
            }
            ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
                fix_val(a, step, newidx);
                fix_val(b, step, newidx);
            }
            _ => {}
        }
    }
    fn fix_key(k: &mut KeyExpr, step: usize, newidx: &[Option<u8>]) {
        match k {
            KeyExpr::Payload { step: s, slot } if *s as usize == step => {
                if let Some(Some(n)) = newidx.get(*slot as usize) {
                    *slot = *n;
                }
            }
            KeyExpr::Year(inner) => fix_key(inner, step, newidx),
            KeyExpr::Pack { hi, lo, .. } => {
                fix_key(hi, step, newidx);
                fix_key(lo, step, newidx);
            }
            _ => {}
        }
    }
    for c in &mut p.cmps {
        fix_val(&mut c.lhs, step, newidx);
        fix_val(&mut c.rhs, step, newidx);
    }
    for s in &mut p.slots {
        fix_val(s, step, newidx);
    }
    fix_key(&mut p.key, step, newidx);
    for j in &mut p.joins {
        if j.link.as_ref().is_some_and(|l| l.step as usize == step) {
            for pay in &mut j.payloads {
                if let Payload::FromLink(k) = pay {
                    if let Some(Some(n)) = newidx.get(*k as usize) {
                        *k = *n;
                    }
                }
            }
        }
    }
}

fn mark_val(v: &ValExpr, used: &mut [Vec<bool>]) {
    match v {
        ValExpr::Payload { step, slot } => {
            if let Some(u) = used.get_mut(*step as usize).and_then(|s| s.get_mut(*slot as usize)) {
                *u = true;
            }
        }
        ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
            mark_val(a, used);
            mark_val(b, used);
        }
        ValExpr::Const(_) | ValExpr::Col(_) => {}
    }
}

fn mark_key(k: &KeyExpr, used: &mut [Vec<bool>]) {
    match k {
        KeyExpr::Payload { step, slot } => {
            if let Some(u) = used.get_mut(*step as usize).and_then(|s| s.get_mut(*slot as usize)) {
                *u = true;
            }
        }
        KeyExpr::Year(inner) => mark_key(inner, used),
        KeyExpr::Pack { hi, lo, .. } => {
            mark_key(hi, used);
            mark_key(lo, used);
        }
        KeyExpr::Const(_) | KeyExpr::Col(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::column::date_to_days;
    use crate::analytics::engine::plan::{
        cmp, f64_lt, f64_range, i32_range, vcol, vconst, vmul, LinkRef,
    };
    use crate::analytics::queries::REGISTRY;
    use crate::analytics::sql::{ast, bind};

    fn sql_plan(text: &str) -> LogicalPlan {
        bind::bind(&ast::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn q6_pipeline_reaches_the_registry_predicate() {
        let p = optimize(&sql_plan(
            "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
             AND l_discount >= 0.045 AND l_discount < 0.075 AND l_quantity < 24",
        ));
        assert!(p.cmps.is_empty(), "every compare pushed into the scan");
        assert_eq!(
            p.pred,
            pand(vec![
                i32_range("l_shipdate", date_to_days(1994, 1, 1), date_to_days(1995, 1, 1)),
                f64_range("l_discount", 0.045, 0.075),
                f64_lt("l_quantity", 24.0),
            ])
        );
        assert_eq!(p.slots, vec![vmul(vcol("l_extendedprice"), vcol("l_discount"))]);
    }

    #[test]
    fn folded_date_arithmetic_becomes_a_range() {
        let p = optimize(&sql_plan(
            "SELECT SUM(l_quantity) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1994-01-01' + 90",
        ));
        let d = date_to_days(1994, 1, 1);
        assert_eq!(p.pred, i32_range("l_shipdate", d, d + 90));
        assert!(p.cmps.is_empty());
    }

    #[test]
    fn int_and_float_bound_conversions_are_exact() {
        // x < 24.5 over an int column keeps 24, excludes 25.
        assert_eq!(
            int_leaf("l_linenumber", CmpOp::Lt, 24.5),
            Some(i32_range("l_linenumber", i32::MIN, 25))
        );
        assert_eq!(
            int_leaf("l_linenumber", CmpOp::Le, 24.0),
            Some(i32_range("l_linenumber", i32::MIN, 25))
        );
        assert_eq!(
            int_leaf("l_linenumber", CmpOp::Gt, 24.5),
            Some(i32_range("l_linenumber", 25, i32::MAX))
        );
        assert_eq!(
            int_leaf("l_linenumber", CmpOp::Eq, 2.5),
            Some(i32_range("l_linenumber", 0, 0)),
            "fractional equality over ints is the empty range"
        );
        // x <= k over floats admits exactly k and nothing above it.
        let up = next_up(0.07);
        assert!(up > 0.07 && (up - 0.07) < 1e-15);
        assert_eq!(f64_leaf("l_tax", CmpOp::Le, 0.07), Some(f64_lt("l_tax", up)));
        // Ge keeps the bound itself.
        assert_eq!(
            f64_leaf("l_quantity", CmpOp::Ge, 10.0),
            Some(f64_range("l_quantity", 10.0, f64::INFINITY))
        );
    }

    #[test]
    fn payload_compares_push_into_dim_filters() {
        let p = optimize(&sql_plan(
            "SELECT SUM(l_extendedprice) FROM lineitem \
             JOIN part ON p_partkey = l_partkey WHERE p_size < 15",
        ));
        assert!(p.cmps.is_empty(), "the payload compare became a dim filter");
        assert_eq!(p.joins[0].filter, i32_range("p_size", i32::MIN, 15));
        assert!(p.joins[0].payloads.is_empty(), "the orphaned payload was elided");
        assert!(p.joins[0].dense, "filtered dense steps stay dense");
    }

    #[test]
    fn registry_plans_are_fixed_points_up_to_join_order() {
        use crate::analytics::engine::plan::PlanParams;
        for def in &REGISTRY {
            let plan = (def.logical)(&PlanParams::default()).unwrap();
            let opt = optimize(&plan);
            opt.check_wire_bounds()
                .unwrap_or_else(|e| panic!("{} broke wire bounds: {e}", def.name));
            if matches!(def.name, "q5" | "q9") {
                // Join order changes (smaller builds first); same tables.
                let mut a: Vec<_> = plan.joins.iter().map(|j| j.table).collect();
                let mut b: Vec<_> = opt.joins.iter().map(|j| j.table).collect();
                a.sort_by_key(|t| t.name());
                b.sort_by_key(|t| t.name());
                assert_eq!(a, b, "{} must keep its join set", def.name);
                assert_eq!(plan.pred, opt.pred, "{} scan predicate must round-trip", def.name);
            } else {
                assert_eq!(plan, opt, "{} must be a fixed point", def.name);
            }
        }
    }

    #[test]
    fn reordering_remaps_link_and_payload_references() {
        let p = sql_plan(
            "SELECT nation_name(s_nationkey), SUM(l_extendedprice * (1 - l_discount)) \
             FROM lineitem \
             JOIN customer ON c_custkey = o_custkey \
             JOIN orders ON o_orderkey = l_orderkey \
             JOIN supplier ON s_suppkey = l_suppkey \
             WHERE c_nationkey = s_nationkey \
               AND o_orderdate >= DATE '1994-01-01' AND o_orderdate < DATE '1995-01-01' \
               AND region_of(c_nationkey) = 'ASIA' \
             GROUP BY nation_name(s_nationkey) ORDER BY 2 DESC",
        );
        let opt = optimize(&p);
        // Supplier's build (10k rows) beats customer's (150k): it moves
        // first, and the customer←orders link stays target-before-linker.
        assert_eq!(opt.joins[0].table, TableRef::Supplier);
        let cust = opt.joins.iter().position(|j| j.table == TableRef::Customer).unwrap();
        let ord = opt.joins.iter().position(|j| j.table == TableRef::Orders).unwrap();
        assert!(cust < ord);
        assert_eq!(opt.joins[ord].link, Some(LinkRef { step: cust as u8, via: "o_custkey".into() }));
        // Every payload reference must resolve in-bounds post-remap.
        opt.check_wire_bounds().unwrap();
    }

    #[test]
    fn merge_anchors_at_first_occurrence_and_drops_true() {
        let merged = merge_ranges(pand(vec![
            PredExpr::True,
            i32_range("a", 0, 100),
            f64_lt("x", 5.0),
            i32_range("a", 10, 200),
            f64_range("x", 1.0, f64::INFINITY),
        ]));
        assert_eq!(
            merged,
            pand(vec![i32_range("a", 10, 100), f64_range("x", 1.0, 5.0)])
        );
        assert_eq!(merge_ranges(PredExpr::True), PredExpr::True);
        assert_eq!(
            merge_ranges(pand(vec![PredExpr::True, f64_lt("x", 2.0)])),
            f64_lt("x", 2.0),
            "single survivor unwraps"
        );
    }

    #[test]
    fn folding_only_touches_constant_pairs() {
        let mut v = vmul(vcol("l_quantity"), vconst(2.0));
        fold_val(&mut v);
        assert_eq!(v, vmul(vcol("l_quantity"), vconst(2.0)));
        let mut v = cmp(
            vcol("l_shipdate"),
            CmpOp::Lt,
            crate::analytics::engine::plan::vadd(vconst(100.0), vconst(28.0)),
        );
        fold_val(&mut v.rhs);
        assert_eq!(v.rhs, vconst(128.0));
    }
}

//! Binder: lowers a parsed [`Query`] against the TPC-H catalog into the
//! engine's [`LogicalPlan`] IR.
//!
//! Binding rules, in the order they run:
//!
//! 1. **Scan selection.** The FROM table must be `lineitem`; every
//!    other table joins to it (the engine's plans are star-shaped
//!    probes out of the fact scan).
//! 2. **Join shaping.** Each `JOIN dim ON ...` clause becomes one
//!    [`JoinStep`]. An ON pair against a scan column is a probe
//!    (single or packed, per the catalog's FK shapes); a pair against
//!    another dimension's column is the dim→dim *link* edge
//!    (`customer ← orders`), making the keyed side an unprobed link
//!    target. Link targets are hoisted before their linkers.
//! 3. **Predicate classification.** WHERE conjuncts that are pure
//!    single-table string matches, IN lists, integer BETWEENs, or
//!    `region_of(...)` tests lower directly into the scan predicate or
//!    the owning step's dim filter. Everything numeric becomes a
//!    post-join [`CmpExpr`] (dimension columns ride along as `Col`
//!    payloads) — the optimizer then folds and pushes those down. A
//!    disjunction of per-dimension branch predicates with scan-column
//!    bounds becomes `CaseConst` payloads plus range compares (the Q19
//!    shape); other disjunctions must confine themselves to one
//!    dimension table.
//! 4. **Value lowering.** Aggregate arguments lower to [`ValExpr`];
//!    `CASE WHEN <dim string match> THEN .. ELSE ..` becomes a
//!    `Flag` payload (optionally scaled by an expression). Slots are
//!    deduplicated structurally, so `SUM(x)` and `AVG(x)` share one
//!    accumulator.
//! 5. **Group keys.** Char columns pack in 8 bits, `year(...)` in 16;
//!    only the leftmost key part may be unbounded. Grouping by a scan
//!    FK column turns sibling group-by columns of that dimension into
//!    dense decorations, and a join step left with no work is elided.
//! 6. **Finalize.** SELECT items map onto key parts, decorations, and
//!    accumulator outputs (`AVG` → `AccOverCount`, `COUNT(*)` →
//!    `Count`, `100 * SUM(a) / SUM(b)` → `AccRatioPct`); HAVING takes
//!    the `SUM(..) > const` form; ORDER BY accepts 1-based positions,
//!    aliases, or expressions matched structurally against SELECT.
//!
//! Everything is fallible: unknown columns, unsupported shapes, and
//! capacity overruns (> 4 joins, > 5 accumulators, > 8 payloads per
//! step) return errors, never panic.

use super::ast::{AggKind, BinOp, CmpKind, Expr, OrderKey, Query};
use super::catalog::{self, ColType, FkShape};
use crate::analytics::engine::plan::{
    cmp, kconst, pand, por, vadd, vcol, vconst, vmul, vsub, CmpExpr, CmpOp, FinalizeSpec,
    GroupsHint, JoinStep, KeyCols, KeyExpr, LinkRef, LogicalPlan, OutCol, Payload, PredExpr,
    SortDir, StrMatch, TableRef, ValExpr,
};
use crate::error::Result;

const MAX_JOIN_STEPS: usize = 4;
const MAX_SLOTS: usize = 5;
const MAX_PAYLOADS_PER_STEP: usize = 8;

/// Lower a parsed query to an executable plan (named `"sql"`; callers
/// may rename).
pub fn bind(q: &Query) -> Result<LogicalPlan> {
    let scan = catalog::table(&q.from)?;
    crate::ensure!(
        scan.table == TableRef::Lineitem,
        "FROM must name lineitem (got {:?}); dimension tables join to it",
        q.from
    );
    let mut b = Binder { steps: build_steps(q)?, pred: Vec::new(), cmps: Vec::new(), slots: Vec::new() };
    if let Some(w) = &q.where_ {
        b.classify(w)?;
    }
    let groups = b.plan_groups(&q.group_by)?;
    let scalar = groups.parts.is_empty();
    let mut columns = Vec::new();
    for (item, _) in &q.select {
        if let Some(part) = groups.parts.iter().find(|p| &p.ast == item) {
            columns.push(part.out.clone());
        } else {
            columns.push(b.aggregate_out(item)?);
        }
    }
    if scalar {
        crate::ensure!(
            columns.iter().all(is_agg_out),
            "a query without GROUP BY may select only aggregates"
        );
    }
    let having_gt = match &q.having {
        None => None,
        Some(h) => Some(b.lower_having(h)?),
    };
    let mut sort = Vec::new();
    for o in &q.order_by {
        let idx = match &o.key {
            OrderKey::Pos(p) => {
                crate::ensure!(*p <= q.select.len(), "ORDER BY position {p} exceeds select list");
                p - 1
            }
            OrderKey::Expr(e) => select_index(q, e)?,
        };
        sort.push((idx as u8, if o.desc { SortDir::Desc } else { SortDir::Asc }));
    }
    if b.slots.is_empty() {
        // COUNT(*)-only queries still need one accumulator lane for the
        // wire format; a constant keeps the executor happy and cheap.
        b.slots.push(vconst(1.0));
    }
    crate::ensure!(b.slots.len() <= MAX_SLOTS, "more than {MAX_SLOTS} aggregate accumulators");
    b.elide_idle_steps();
    let hint = b.groups_hint(&groups, scalar);
    let Binder { steps, pred, cmps, slots } = b;
    Ok(LogicalPlan {
        name: "sql".into(),
        scan: TableRef::Lineitem,
        pred: conj(pred),
        joins: steps.into_iter().map(Step::into_join).collect(),
        cmps,
        key: if scalar { kconst(0) } else { groups.key.clone() },
        slots,
        groups_hint: hint,
        finalize: FinalizeSpec {
            scalar,
            columns,
            having_gt,
            sort,
            limit: q.limit.unwrap_or(0),
        },
    })
}

fn is_agg_out(o: &OutCol) -> bool {
    matches!(
        o,
        OutCol::Acc(_)
            | OutCol::AccInt(_)
            | OutCol::Count
            | OutCol::AccOverCount(_)
            | OutCol::AccRatioPct(_, _)
    )
}

/// Fold conjunct list to a predicate tree.
fn conj(mut ps: Vec<PredExpr>) -> PredExpr {
    match ps.len() {
        0 => PredExpr::True,
        1 => ps.remove(0),
        _ => pand(ps),
    }
}

/// Find the SELECT item an ORDER BY expression refers to: alias first,
/// then structural equality.
fn select_index(q: &Query, e: &Expr) -> Result<usize> {
    if let Expr::Col(name) = e {
        if let Some(i) = q
            .select
            .iter()
            .position(|(_, a)| a.as_deref().is_some_and(|al| al.eq_ignore_ascii_case(name)))
        {
            return Ok(i);
        }
    }
    q.select
        .iter()
        .position(|(s, _)| s == e)
        .ok_or_else(|| crate::err!("ORDER BY expression {e:?} is not in the select list"))
}

// ------------------------------------------------------------ join steps

struct Step {
    table: TableRef,
    dense_ok: bool,
    build_key: Option<KeyCols>,
    probe_key: Option<KeyCols>,
    filter: Vec<PredExpr>,
    link: Option<LinkRef>,
    is_target: bool,
    payloads: Vec<Payload>,
}

impl Step {
    fn into_join(self) -> JoinStep {
        let dense = self.dense_ok && self.probe_key.is_some() && self.link.is_none() && !self.is_target;
        JoinStep {
            table: self.table,
            dense,
            build_key: if dense { None } else { self.build_key },
            probe_key: self.probe_key,
            filter: conj(self.filter),
            link: self.link,
            payloads: self.payloads,
        }
    }
}

/// Resolve JOIN clauses into ordered steps: probe shapes from the
/// catalog, the customer←orders link edge, targets hoisted before
/// linkers.
fn build_steps(q: &Query) -> Result<Vec<Step>> {
    crate::ensure!(q.joins.len() <= MAX_JOIN_STEPS, "more than {MAX_JOIN_STEPS} joins");
    let mut tables = Vec::new();
    for j in &q.joins {
        let t = catalog::table(&j.table)?;
        crate::ensure!(t.table != TableRef::Lineitem, "lineitem cannot join to itself");
        crate::ensure!(
            tables.iter().all(|(tr, _)| *tr != t.table),
            "table {} joined twice",
            j.table
        );
        tables.push((t.table, j));
    }
    let find = |name: &str| -> Result<TableRef> {
        let (td, _) = catalog::resolve(name)?;
        crate::ensure!(
            td.table == TableRef::Lineitem || tables.iter().any(|(t, _)| *t == td.table),
            "column {name} belongs to {}, which is not in FROM/JOIN",
            td.table.name()
        );
        Ok(td.table)
    };
    // Pass 1: classify each clause's pairs into probe pairs and link
    // edges (target table, target key, linker table, via).
    struct Clause {
        table: TableRef,
        probe: Vec<(String, String)>, // (dim key col, scan col)
    }
    let mut clauses = Vec::new();
    let mut links: Vec<(TableRef, String, TableRef, String)> = Vec::new();
    for (t, j) in &tables {
        let mut probe = Vec::new();
        for (a, bcol) in &j.on {
            let ta = find(a)?;
            let tb = find(bcol)?;
            let (dim_col, other, other_t) = if ta == *t {
                (a.clone(), bcol.clone(), tb)
            } else if tb == *t {
                (bcol.clone(), a.clone(), ta)
            } else {
                crate::bail!("ON pair {a} = {bcol} does not involve {}", t.name());
            };
            if other_t == TableRef::Lineitem {
                probe.push((dim_col, other));
            } else {
                // Dim-dim pair: one orientation must be a known link
                // edge.
                if let Some(via) = catalog::link_via(*t, &dim_col, other_t, &other) {
                    links.push((*t, dim_col.clone(), other_t, via.to_string()));
                } else if let Some(via) = catalog::link_via(other_t, &other, *t, &dim_col) {
                    links.push((other_t, other.clone(), *t, via.to_string()));
                } else {
                    crate::bail!(
                        "no link edge joins {} and {} on {a} = {bcol}",
                        t.name(),
                        other_t.name()
                    );
                }
            }
        }
        clauses.push(Clause { table: *t, probe });
    }
    // Pass 2: build steps in declaration order, then hoist link targets
    // before their linkers.
    let mut steps = Vec::new();
    for c in &clauses {
        let is_target = links.iter().any(|(tgt, ..)| *tgt == c.table);
        let mut step = Step {
            table: c.table,
            dense_ok: false,
            build_key: None,
            probe_key: None,
            filter: Vec::new(),
            link: None,
            is_target,
            payloads: Vec::new(),
        };
        if c.probe.is_empty() {
            crate::ensure!(
                is_target,
                "{} has no join path to lineitem (no FK pair and no link edge)",
                c.table.name()
            );
            let (_, key, _, _) =
                links.iter().find(|(tgt, ..)| *tgt == c.table).expect("checked above");
            step.build_key = Some(KeyCols::Col(key.clone()));
        } else {
            crate::ensure!(
                !is_target,
                "{} cannot both probe the scan and be a link target",
                c.table.name()
            );
            let dim_keys: Vec<&str> = c.probe.iter().map(|(d, _)| d.as_str()).collect();
            let scan_cols: Vec<&str> = c.probe.iter().map(|(_, s)| s.as_str()).collect();
            match catalog::fk_shape(c.table, &dim_keys, &scan_cols)? {
                FkShape::Single { scan_col, dense_ok } => {
                    step.dense_ok = dense_ok;
                    step.build_key = Some(KeyCols::Col(dim_keys[0].to_string()));
                    step.probe_key = Some(KeyCols::Col(scan_col.to_string()));
                }
                FkShape::Packed { scan_a, scan_b, shift } => {
                    step.build_key = Some(KeyCols::Packed {
                        a: "ps_partkey".into(),
                        shift,
                        b: "ps_suppkey".into(),
                    });
                    step.probe_key = Some(KeyCols::Packed {
                        a: scan_a.to_string(),
                        shift,
                        b: scan_b.to_string(),
                    });
                }
            }
        }
        steps.push(step);
    }
    // Hoist: every link target must precede its linker.
    for (tgt, _, linker, _) in &links {
        let ti = steps.iter().position(|s| s.table == *tgt).expect("target built");
        let li = steps.iter().position(|s| s.table == *linker).expect("linker built");
        if ti > li {
            let s = steps.remove(ti);
            steps.insert(li, s);
        }
    }
    // Wire the link refs now that indices are final.
    for (tgt, _, linker, via) in &links {
        let ti = steps.iter().position(|s| s.table == *tgt).expect("target placed");
        let li = steps.iter().position(|s| s.table == *linker).expect("linker placed");
        crate::ensure!(ti < li, "link target {} must precede {}", tgt.name(), linker.name());
        let step = &mut steps[li];
        crate::ensure!(step.link.is_none(), "{} links twice", linker.name());
        step.link = Some(LinkRef { step: ti as u8, via: via.clone() });
    }
    Ok(steps)
}

// ------------------------------------------------------------- the binder

struct Binder {
    steps: Vec<Step>,
    pred: Vec<PredExpr>,
    cmps: Vec<CmpExpr>,
    slots: Vec<ValExpr>,
}

/// One GROUP BY item, resolved.
struct GroupPart {
    ast: Expr,
    out: OutCol,
}

struct Groups {
    parts: Vec<GroupPart>,
    key: KeyExpr,
    /// Scan FK column the single key part reads, when the whole key is
    /// one bare FK column (drives the `TableRows` hint + decorations).
    fk_dim: Option<TableRef>,
}

impl Binder {
    fn step_idx(&self, t: TableRef) -> Result<usize> {
        self.steps
            .iter()
            .position(|s| s.table == t)
            .ok_or_else(|| crate::err!("{} is referenced but not joined", t.name()))
    }

    fn ensure_payload(&mut self, step: usize, p: Payload) -> Result<u8> {
        if let Some(i) = self.steps[step].payloads.iter().position(|q| *q == p) {
            return Ok(i as u8);
        }
        crate::ensure!(
            self.steps[step].payloads.len() < MAX_PAYLOADS_PER_STEP,
            "more than {MAX_PAYLOADS_PER_STEP} payloads on the {} step",
            self.steps[step].table.name()
        );
        self.steps[step].payloads.push(p);
        Ok((self.steps[step].payloads.len() - 1) as u8)
    }

    /// Route a dim-side payload to a probed step: directly, or through
    /// the linker via `FromLink` when the owner is a link target.
    fn dim_payload(&mut self, t: TableRef, p: Payload) -> Result<(u8, u8)> {
        let s = self.step_idx(t)?;
        if self.steps[s].is_target {
            let k = self.ensure_payload(s, p)?;
            let linker = self
                .steps
                .iter()
                .position(|st| st.link.as_ref().is_some_and(|l| l.step as usize == s))
                .ok_or_else(|| crate::err!("{} is a link target with no linker", t.name()))?;
            let j = self.ensure_payload(linker, Payload::FromLink(k))?;
            Ok((linker as u8, j))
        } else {
            let k = self.ensure_payload(s, p)?;
            Ok((s as u8, k))
        }
    }

    fn ensure_slot(&mut self, v: ValExpr) -> Result<u8> {
        if let Some(i) = self.slots.iter().position(|s| *s == v) {
            return Ok(i as u8);
        }
        crate::ensure!(self.slots.len() < MAX_SLOTS, "more than {MAX_SLOTS} aggregate accumulators");
        self.slots.push(v);
        Ok((self.slots.len() - 1) as u8)
    }

    // -------------------------------------------------- WHERE lowering

    fn classify(&mut self, e: &Expr) -> Result<()> {
        match e {
            Expr::And(arms) => {
                for a in arms {
                    self.classify(a)?;
                }
                Ok(())
            }
            Expr::Or(arms) => self.classify_or(e, arms),
            _ => {
                if let Some((t, p)) = self.try_pred(e)? {
                    self.route_pred(t, p)
                } else {
                    match e {
                        Expr::Cmp(k, a, b) => self.classify_cmp(*k, a, b),
                        Expr::Between(x, lo, hi) => {
                            self.classify_cmp(CmpKind::Ge, x, lo)?;
                            self.classify_cmp(CmpKind::Le, x, hi)
                        }
                        Expr::Not(_) => Err(crate::err!("NOT has no plan form here: {e:?}")),
                        _ => Err(crate::err!("unsupported WHERE term {e:?}")),
                    }
                }
            }
        }
    }

    fn route_pred(&mut self, t: TableRef, p: PredExpr) -> Result<()> {
        if t == TableRef::Lineitem {
            crate::ensure!(
                !matches!(p, PredExpr::Or(_)),
                "OR over scan columns is not lowerable (the scan cascade is conjunctive); \
                 restrict each disjunct to one dimension table"
            );
            self.pred.push(p);
        } else {
            let s = self.step_idx(t)?;
            self.steps[s].filter.push(p);
        }
        Ok(())
    }

    /// Try to read `e` as a directly lowerable single-table predicate.
    /// `Ok(None)` means "not this shape, try the compare path"; `Err`
    /// means the shape is recognized but illegal.
    fn try_pred(&self, e: &Expr) -> Result<Option<(TableRef, PredExpr)>> {
        Ok(match e {
            Expr::InList(x, items) => {
                let Expr::Col(c) = x.as_ref() else {
                    crate::bail!("IN applies to a column, got {x:?}");
                };
                let (td, cd) = catalog::resolve(c)?;
                match cd.ty {
                    ColType::Str => {
                        let mut vs = Vec::new();
                        for it in items {
                            match it {
                                Expr::Str(s) => vs.push(s.clone()),
                                other => crate::bail!("IN list for {c} wants strings, got {other:?}"),
                            }
                        }
                        Some((td.table, PredExpr::Str { col: c.clone(), m: StrMatch::OneOf(vs) }))
                    }
                    ColType::I32 | ColType::Date => {
                        let mut vs = Vec::new();
                        for it in items {
                            vs.push(lit_i32(it).ok_or_else(|| {
                                crate::err!("IN list for {c} wants integers or dates, got {it:?}")
                            })?);
                        }
                        Some((td.table, PredExpr::I32InSet { col: c.clone(), values: vs }))
                    }
                    _ => crate::bail!("IN is not supported on {c} (type {:?})", cd.ty),
                }
            }
            Expr::Like(x, pat) => {
                let Expr::Col(c) = x.as_ref() else {
                    crate::bail!("LIKE applies to a column, got {x:?}");
                };
                let (td, cd) = catalog::resolve(c)?;
                crate::ensure!(cd.ty == ColType::Str, "LIKE needs a string column, {c} is {:?}", cd.ty);
                let m = like_match(pat)?;
                Some((td.table, PredExpr::Str { col: c.clone(), m }))
            }
            Expr::Cmp(CmpKind::Ne, _, _) => crate::bail!("'<>' has no plan form"),
            Expr::Cmp(CmpKind::Eq, a, b) => {
                let (x, y) = (a.as_ref(), b.as_ref());
                // col = 'str' (either orientation)
                let col_str = match (x, y) {
                    (Expr::Col(c), Expr::Str(v)) | (Expr::Str(v), Expr::Col(c)) => Some((c, v)),
                    _ => None,
                };
                if let Some((c, v)) = col_str {
                    let (td, cd) = catalog::resolve(c)?;
                    crate::ensure!(
                        cd.ty == ColType::Str,
                        "string equality needs a string column, {c} is {:?}",
                        cd.ty
                    );
                    return Ok(Some((
                        td.table,
                        PredExpr::Str { col: c.clone(), m: StrMatch::Eq(v.clone()) },
                    )));
                }
                // region_of(col) = 'REGION' (either orientation)
                let region = match (x, y) {
                    (Expr::Func(f, args), Expr::Str(v)) | (Expr::Str(v), Expr::Func(f, args))
                        if f == "region_of" =>
                    {
                        Some((args, v))
                    }
                    _ => None,
                };
                if let Some((args, v)) = region {
                    crate::ensure!(args.len() == 1, "region_of takes one column");
                    let Expr::Col(c) = &args[0] else {
                        crate::bail!("region_of applies to a column, got {:?}", args[0]);
                    };
                    let (td, cd) = catalog::resolve(c)?;
                    crate::ensure!(
                        cd.ty == ColType::I32,
                        "region_of needs a nation-key column, {c} is {:?}",
                        cd.ty
                    );
                    let nations = catalog::region_nations(v)?;
                    return Ok(Some((
                        td.table,
                        PredExpr::I32InSet { col: c.clone(), values: nations },
                    )));
                }
                None
            }
            Expr::Between(x, lo, hi) => {
                let Expr::Col(c) = x.as_ref() else { return Ok(None) };
                let (td, cd) = catalog::resolve(c)?;
                if !matches!(cd.ty, ColType::I32 | ColType::Date) {
                    return Ok(None); // f64 BETWEEN desugars to compares
                }
                let (Some(l), Some(h)) = (lit_i32(lo), lit_i32(hi)) else { return Ok(None) };
                crate::ensure!(h < i32::MAX, "BETWEEN upper bound too large on {c}");
                // SQL BETWEEN is closed; I32Range is half-open.
                Some((td.table, PredExpr::I32Range { col: c.clone(), lo: l, hi: h + 1 }))
            }
            Expr::Or(arms) => {
                let mut table = None;
                let mut ps = Vec::new();
                for a in arms {
                    match self.try_pred(a)? {
                        Some((t, p)) => {
                            if *table.get_or_insert(t) != t {
                                return Ok(None);
                            }
                            ps.push(p);
                        }
                        None => return Ok(None),
                    }
                }
                table.map(|t| (t, por(ps)))
            }
            Expr::And(arms) => {
                let mut table = None;
                let mut ps = Vec::new();
                for a in arms {
                    match self.try_pred(a)? {
                        Some((t, p)) => {
                            if *table.get_or_insert(t) != t {
                                return Ok(None);
                            }
                            ps.push(p);
                        }
                        None => return Ok(None),
                    }
                }
                table.map(|t| (t, pand(ps)))
            }
            _ => None,
        })
    }

    fn classify_cmp(&mut self, k: CmpKind, a: &Expr, b: &Expr) -> Result<()> {
        let op = match k {
            CmpKind::Eq => CmpOp::Eq,
            CmpKind::Lt => CmpOp::Lt,
            CmpKind::Le => CmpOp::Le,
            CmpKind::Ge => CmpOp::Ge,
            CmpKind::Gt => CmpOp::Gt,
            CmpKind::Ne => crate::bail!("'<>' has no plan form"),
        };
        let lhs = self.lower_val(a)?;
        let rhs = self.lower_val(b)?;
        self.cmps.push(cmp(lhs, op, rhs));
        Ok(())
    }

    /// The Q19 shape: `(dimpred AND scancol BETWEEN lo AND hi) OR ...`
    /// with provably disjoint branches (a shared dim string column
    /// equal to a different constant in every arm). Lowers to a pair of
    /// `CaseConst` payloads plus `Ge`/`Le` compares; falls back to a
    /// one-dimension OR filter otherwise.
    fn classify_or(&mut self, whole: &Expr, arms: &[Expr]) -> Result<()> {
        if self.try_case_bounds(arms)? {
            return Ok(());
        }
        if let Some((t, p)) = self.try_pred(whole)? {
            return self.route_pred(t, p);
        }
        Err(crate::err!(
            "OR must either confine itself to one dimension table or take the \
             branch-bounds form (dim predicates plus a shared scan-column range per arm)"
        ))
    }

    fn try_case_bounds(&mut self, arms: &[Expr]) -> Result<bool> {
        struct Arm {
            pred: PredExpr,
            eqs: Vec<(String, String)>,
            lo: f64,
            hi: f64,
        }
        let mut dim: Option<TableRef> = None;
        let mut bound_col: Option<String> = None;
        let mut parsed = Vec::new();
        for arm in arms {
            let Expr::And(cs) = arm else { return Ok(false) };
            let mut preds = Vec::new();
            let mut eqs = Vec::new();
            let mut lo = None;
            let mut hi = None;
            for c in cs {
                if let Some((t, p)) = self.try_pred(c).ok().flatten() {
                    if t == TableRef::Lineitem || *dim.get_or_insert(t) != t {
                        return Ok(false);
                    }
                    if let PredExpr::Str { col, m: StrMatch::Eq(v) } = &p {
                        eqs.push((col.clone(), v.clone()));
                    }
                    preds.push(p);
                    continue;
                }
                let (col, which, v) = match c {
                    Expr::Cmp(CmpKind::Ge, x, lit) => match (x.as_ref(), lit_f64(lit)) {
                        (Expr::Col(c), Some(v)) => (c, 0, v),
                        _ => return Ok(false),
                    },
                    Expr::Cmp(CmpKind::Le, x, lit) => match (x.as_ref(), lit_f64(lit)) {
                        (Expr::Col(c), Some(v)) => (c, 1, v),
                        _ => return Ok(false),
                    },
                    Expr::Between(x, l, h) => match (x.as_ref(), lit_f64(l), lit_f64(h)) {
                        (Expr::Col(c), Some(lv), Some(hv)) => {
                            let (td, _) = catalog::resolve(c)?;
                            if td.table != TableRef::Lineitem {
                                return Ok(false);
                            }
                            if *bound_col.get_or_insert(c.clone()) != *c {
                                return Ok(false);
                            }
                            lo = Some(lv);
                            hi = Some(hv);
                            continue;
                        }
                        _ => return Ok(false),
                    },
                    _ => return Ok(false),
                };
                let (td, _) = catalog::resolve(col)?;
                if td.table != TableRef::Lineitem || *bound_col.get_or_insert(col.clone()) != *col {
                    return Ok(false);
                }
                if which == 0 {
                    lo = Some(v);
                } else {
                    hi = Some(v);
                }
            }
            let (Some(lo), Some(hi)) = (lo, hi) else { return Ok(false) };
            if preds.is_empty() {
                return Ok(false);
            }
            parsed.push(Arm { pred: conj(preds), eqs, lo, hi });
        }
        let (Some(dim), Some(bound_col)) = (dim, bound_col) else { return Ok(false) };
        // Disjointness proof: some dim string column carries a distinct
        // Eq constant in every arm. Without it the branches could
        // overlap and the first-match CaseConst would drop rows.
        let disjoint = parsed[0].eqs.iter().any(|(col, _)| {
            let vals: Vec<&String> = parsed
                .iter()
                .filter_map(|a| a.eqs.iter().find(|(c, _)| c == col).map(|(_, v)| v))
                .collect();
            vals.len() == parsed.len()
                && (0..vals.len()).all(|i| (i + 1..vals.len()).all(|j| vals[i] != vals[j]))
        });
        crate::ensure!(
            disjoint,
            "OR branches must be provably disjoint (a shared dimension string column \
             equal to a distinct constant per branch)"
        );
        let lo_cases = Payload::CaseConst {
            cases: parsed.iter().map(|a| (a.pred.clone(), a.lo)).collect(),
        };
        let hi_cases = Payload::CaseConst {
            cases: parsed.iter().map(|a| (a.pred.clone(), a.hi)).collect(),
        };
        let (s1, lo_slot) = self.dim_payload(dim, lo_cases)?;
        let (s2, hi_slot) = self.dim_payload(dim, hi_cases)?;
        self.cmps.push(cmp(vcol(&bound_col), CmpOp::Ge, ValExpr::Payload { step: s1, slot: lo_slot }));
        self.cmps.push(cmp(vcol(&bound_col), CmpOp::Le, ValExpr::Payload { step: s2, slot: hi_slot }));
        Ok(true)
    }

    // -------------------------------------------------- value lowering

    fn lower_val(&mut self, e: &Expr) -> Result<ValExpr> {
        match e {
            Expr::Int(v) => Ok(vconst(*v as f64)),
            Expr::Float(v) => Ok(vconst(*v)),
            Expr::Date(d) => Ok(vconst(*d as f64)),
            Expr::Str(_) => Err(crate::err!("a string literal has no numeric value")),
            Expr::Col(c) => {
                let (td, _) = catalog::resolve(c)?;
                if td.table == TableRef::Lineitem {
                    Ok(vcol(c))
                } else {
                    let (s, k) = self.dim_payload(td.table, Payload::Col(c.clone()))?;
                    Ok(ValExpr::Payload { step: s, slot: k })
                }
            }
            Expr::Bin(op, a, b) => {
                let (x, y) = (self.lower_val(a)?, self.lower_val(b)?);
                Ok(match op {
                    BinOp::Add => vadd(x, y),
                    BinOp::Sub => vsub(x, y),
                    BinOp::Mul => vmul(x, y),
                    BinOp::Div => crate::bail!(
                        "division lowers only as 100 * SUM(a) / SUM(b) in the select list"
                    ),
                })
            }
            Expr::Case { whens, else_ } => self.lower_case(whens, else_.as_deref()),
            Expr::Agg(..) => Err(crate::err!(
                "aggregates cannot nest inside expressions (except the ratio form)"
            )),
            other => Err(crate::err!("expression has no value form: {other:?}")),
        }
    }

    /// CASE lowering: the condition must be a dimension string match
    /// (it becomes a `Flag` payload); the arms select among three
    /// shapes — 1/0, 0/1, and expr/0.
    fn lower_case(&mut self, whens: &[(Expr, Expr)], else_: Option<&Expr>) -> Result<ValExpr> {
        crate::ensure!(whens.len() == 1, "CASE lowers with exactly one WHEN arm");
        let (cond, then) = &whens[0];
        let else_ = else_.ok_or_else(|| crate::err!("CASE needs an ELSE arm"))?;
        let Some((t, p)) = self.try_pred(cond)? else {
            crate::bail!("CASE condition must be a single-table predicate, got {cond:?}");
        };
        crate::ensure!(
            t != TableRef::Lineitem,
            "CASE over scan columns is not supported; move the condition to WHERE"
        );
        let PredExpr::Str { col, m } = p else {
            crate::bail!("CASE condition must be a string match (it lowers to a flag payload)");
        };
        let (s, k) = self.dim_payload(t, Payload::Flag { col, m })?;
        let flag = ValExpr::Payload { step: s, slot: k };
        let is = |e: &Expr, v: i64| {
            matches!(e, Expr::Int(x) if *x == v)
                || matches!(e, Expr::Float(x) if *x == v as f64)
        };
        if is(then, 1) && is(else_, 0) {
            return Ok(flag);
        }
        if is(then, 0) && is(else_, 1) {
            return Ok(vsub(vconst(1.0), flag));
        }
        if is(else_, 0) {
            let scaled = self.lower_val(then)?;
            return Ok(vmul(flag, scaled));
        }
        Err(crate::err!("CASE arms must be 1/0, 0/1, or expr/0"))
    }

    /// True when the AST value is provably integral, which routes its
    /// accumulator to `AccInt` output.
    fn expr_is_int(e: &Expr) -> bool {
        match e {
            Expr::Int(_) => true,
            Expr::Case { whens, else_ } => {
                whens.iter().all(|(_, v)| Self::expr_is_int(v))
                    && else_.as_deref().is_some_and(Self::expr_is_int)
            }
            Expr::Bin(op, a, b) => {
                *op != BinOp::Div && Self::expr_is_int(a) && Self::expr_is_int(b)
            }
            _ => false,
        }
    }

    fn aggregate_out(&mut self, item: &Expr) -> Result<OutCol> {
        match item {
            Expr::Agg(AggKind::Count, None) => Ok(OutCol::Count),
            Expr::Agg(AggKind::Count, Some(_)) => {
                Err(crate::err!("COUNT(expr) is not supported; use COUNT(*)"))
            }
            Expr::Agg(AggKind::Sum, Some(e)) => {
                let v = self.lower_val(e)?;
                let s = self.ensure_slot(v)?;
                Ok(if Self::expr_is_int(e) { OutCol::AccInt(s) } else { OutCol::Acc(s) })
            }
            Expr::Agg(AggKind::Avg, Some(e)) => {
                let v = self.lower_val(e)?;
                Ok(OutCol::AccOverCount(self.ensure_slot(v)?))
            }
            Expr::Agg(_, None) => Err(crate::err!("SUM/AVG need an argument")),
            // 100 * SUM(a) / SUM(b), as the parser associates it.
            Expr::Bin(BinOp::Div, num, den) => {
                let Expr::Bin(BinOp::Mul, hundred, suma) = num.as_ref() else {
                    crate::bail!("division is only supported as 100 * SUM(a) / SUM(b)");
                };
                let is_hundred = matches!(hundred.as_ref(), Expr::Int(100))
                    || matches!(hundred.as_ref(), Expr::Float(x) if *x == 100.0);
                let (Expr::Agg(AggKind::Sum, Some(a)), Expr::Agg(AggKind::Sum, Some(b))) =
                    (suma.as_ref(), den.as_ref())
                else {
                    crate::bail!("division is only supported as 100 * SUM(a) / SUM(b)");
                };
                crate::ensure!(is_hundred, "the ratio form is 100 * SUM(a) / SUM(b)");
                let (va, vb) = (self.lower_val(a)?, self.lower_val(b)?);
                let sa = self.ensure_slot(va)?;
                let sb = self.ensure_slot(vb)?;
                Ok(OutCol::AccRatioPct(sa, sb))
            }
            other => Err(crate::err!(
                "select item is neither a GROUP BY key nor a supported aggregate: {other:?}"
            )),
        }
    }

    fn lower_having(&mut self, h: &Expr) -> Result<(u8, f64)> {
        let Expr::Cmp(CmpKind::Gt, lhs, rhs) = h else {
            crate::bail!("HAVING takes the form SUM(expr) > constant");
        };
        let Expr::Agg(AggKind::Sum, Some(e)) = lhs.as_ref() else {
            crate::bail!("HAVING takes the form SUM(expr) > constant");
        };
        let k = lit_f64(rhs).ok_or_else(|| crate::err!("HAVING threshold must be a constant"))?;
        let v = self.lower_val(e)?;
        Ok((self.ensure_slot(v)?, k))
    }

    // ---------------------------------------------------- group keys

    fn plan_groups(&mut self, group_by: &[Expr]) -> Result<Groups> {
        if group_by.is_empty() {
            return Ok(Groups { parts: Vec::new(), key: kconst(0), fk_dim: None });
        }
        enum Kind {
            Key { k: KeyExpr, width: Option<u8>, out: KeyOut },
            Decor { table: TableRef, col: String, float: bool },
        }
        enum KeyOut {
            Int,
            Char,
            Nation,
            Dict(TableRef, String),
        }
        // Pass 1: find the FK anchor, if any — a bare scan FK column
        // whose dense dimension can decorate.
        let fk: Option<(usize, TableRef)> = group_by.iter().enumerate().find_map(|(i, g)| {
            if let Expr::Col(c) = g {
                catalog::scan_fk_dim(c).map(|d| (i, d))
            } else {
                None
            }
        });
        // Pass 2: resolve every item.
        let mut kinds = Vec::new();
        for g in group_by {
            let kind = match g {
                Expr::Col(c) => {
                    let (td, cd) = catalog::resolve(c)?;
                    if td.table == TableRef::Lineitem {
                        match cd.ty {
                            ColType::Char => Kind::Key {
                                k: KeyExpr::Col(c.clone()),
                                width: Some(8),
                                out: KeyOut::Char,
                            },
                            ColType::Str => Kind::Key {
                                k: KeyExpr::Col(c.clone()),
                                width: None,
                                out: KeyOut::Dict(TableRef::Lineitem, c.clone()),
                            },
                            ColType::Key | ColType::I32 | ColType::Date => Kind::Key {
                                k: KeyExpr::Col(c.clone()),
                                width: None,
                                out: KeyOut::Int,
                            },
                            ColType::F64 => {
                                crate::bail!("cannot group by float column {c}")
                            }
                        }
                    } else if fk.is_some_and(|(_, d)| d == td.table) {
                        match cd.ty {
                            ColType::F64 => {
                                Kind::Decor { table: td.table, col: c.clone(), float: true }
                            }
                            ColType::Key | ColType::I32 | ColType::Date => {
                                Kind::Decor { table: td.table, col: c.clone(), float: false }
                            }
                            _ => crate::bail!("cannot decorate by string column {c}"),
                        }
                    } else {
                        crate::ensure!(
                            !matches!(cd.ty, ColType::Str | ColType::Char),
                            "grouping by dimension string column {c} is not supported \
                             (group by a key and decorate, or use nation_name)"
                        );
                        let (s, k) = self.dim_payload(td.table, Payload::Col(c.clone()))?;
                        Kind::Key {
                            k: KeyExpr::Payload { step: s, slot: k },
                            width: None,
                            out: KeyOut::Int,
                        }
                    }
                }
                Expr::Func(f, args) if f == "year" => {
                    crate::ensure!(args.len() == 1, "year takes one argument");
                    let Expr::Col(c) = &args[0] else {
                        crate::bail!("year applies to a date column, got {:?}", args[0]);
                    };
                    let (td, cd) = catalog::resolve(c)?;
                    crate::ensure!(cd.ty == ColType::Date, "year needs a date column, {c} is {:?}", cd.ty);
                    let inner = if td.table == TableRef::Lineitem {
                        KeyExpr::Col(c.clone())
                    } else {
                        let (s, k) = self.dim_payload(td.table, Payload::Col(c.clone()))?;
                        KeyExpr::Payload { step: s, slot: k }
                    };
                    Kind::Key {
                        k: KeyExpr::Year(Box::new(inner)),
                        width: Some(16),
                        out: KeyOut::Int,
                    }
                }
                Expr::Func(f, args) if f == "nation_name" => {
                    crate::ensure!(args.len() == 1, "nation_name takes one argument");
                    let Expr::Col(c) = &args[0] else {
                        crate::bail!("nation_name applies to a column, got {:?}", args[0]);
                    };
                    let (td, cd) = catalog::resolve(c)?;
                    crate::ensure!(
                        cd.ty == ColType::I32 && td.table != TableRef::Lineitem,
                        "nation_name needs a dimension nation-key column, got {c}"
                    );
                    let (s, k) = self.dim_payload(td.table, Payload::Col(c.clone()))?;
                    Kind::Key {
                        k: KeyExpr::Payload { step: s, slot: k },
                        width: None,
                        out: KeyOut::Nation,
                    }
                }
                other => crate::bail!("unsupported GROUP BY item {other:?}"),
            };
            kinds.push(kind);
        }
        // Decorations require the key to be exactly the bare FK column
        // (`key − 1` must index the dimension), so with decorations
        // present there may be only one key part.
        let n_keys = kinds.iter().filter(|k| matches!(k, Kind::Key { .. })).count();
        let has_decor = kinds.iter().any(|k| matches!(k, Kind::Decor { .. }));
        crate::ensure!(
            !has_decor || n_keys == 1,
            "grouping by dimension columns requires grouping by exactly one scan \
             foreign-key column alongside them"
        );
        // Widths: every part after the first must be bounded.
        let key_widths: Vec<Option<u8>> = kinds
            .iter()
            .filter_map(|k| match k {
                Kind::Key { width, .. } => Some(*width),
                Kind::Decor { .. } => None,
            })
            .collect();
        for (i, w) in key_widths.iter().enumerate() {
            crate::ensure!(
                i == 0 || w.is_some(),
                "only the leftmost GROUP BY key may be unbounded (char packs 8 bits, \
                 year() 16); reorder the keys"
            );
        }
        // Dict keys output through the whole key, so they must stand alone.
        let has_dict = kinds.iter().any(
            |k| matches!(k, Kind::Key { out: KeyOut::Dict(..), .. }),
        );
        crate::ensure!(
            !has_dict || n_keys == 1,
            "a dictionary-string group key cannot be packed with other keys"
        );
        // Assemble key (right-to-left pack) and per-part output shifts.
        let mut shifts = vec![0u8; key_widths.len()];
        for i in (0..key_widths.len()).rev() {
            if i + 1 < key_widths.len() {
                shifts[i] = shifts[i + 1]
                    + key_widths[i + 1].expect("non-leftmost widths checked above");
            }
        }
        let mut key: Option<KeyExpr> = None;
        for (i, kind) in kinds.iter().enumerate().rev() {
            if let Kind::Key { k, .. } = kind {
                key = Some(match key {
                    None => k.clone(),
                    Some(rest) => {
                        let shift = {
                            // Width of everything to the right of this
                            // key part = its output shift.
                            let ki = kinds[..i]
                                .iter()
                                .filter(|x| matches!(x, Kind::Key { .. }))
                                .count();
                            shifts[ki]
                        };
                        KeyExpr::Pack { hi: Box::new(k.clone()), shift, lo: Box::new(rest) }
                    }
                });
            }
        }
        let key = key.expect("group_by non-empty implies at least one key part");
        // Build parts with their out columns.
        let mut parts = Vec::new();
        let mut ki = 0;
        let mut fk_dim = None;
        for (g, kind) in group_by.iter().zip(&kinds) {
            let out = match kind {
                Kind::Decor { table, col, float } => {
                    if *float {
                        OutCol::DimFloat { table: *table, col: col.clone() }
                    } else {
                        OutCol::DimInt { table: *table, col: col.clone() }
                    }
                }
                Kind::Key { out, width, .. } => {
                    let shift = shifts[ki];
                    let bits = width.unwrap_or(0);
                    ki += 1;
                    match out {
                        KeyOut::Int => OutCol::KeyInt { shift, bits },
                        KeyOut::Char => OutCol::KeyChar { shift },
                        KeyOut::Nation => OutCol::KeyNation { shift, bits },
                        KeyOut::Dict(t, c) => OutCol::KeyDict { table: *t, col: c.clone() },
                    }
                }
            };
            parts.push(GroupPart { ast: g.clone(), out });
        }
        if n_keys == 1 {
            if let Some((i, d)) = fk {
                // The single key is the FK column only if the FK item
                // itself resolved as a key part.
                if matches!(kinds[i], Kind::Key { .. }) {
                    fk_dim = Some(d);
                }
            }
        }
        Ok(Groups { parts, key, fk_dim })
    }

    // ------------------------------------------------- plan finishing

    /// Drop probed dense steps that ended up with no filter, no
    /// payloads, and no link involvement: their probe is a guaranteed
    /// FK hit, so they contribute nothing (Q18's orders join after
    /// decoration). Later step indices shift down.
    fn elide_idle_steps(&mut self) {
        loop {
            let idle = self.steps.iter().position(|s| {
                s.dense_ok
                    && s.probe_key.is_some()
                    && s.link.is_none()
                    && !s.is_target
                    && s.filter.is_empty()
                    && s.payloads.is_empty()
            });
            let Some(r) = idle else { return };
            self.steps.remove(r);
            let shift = |step: &mut u8| {
                if *step as usize > r {
                    *step -= 1;
                }
            };
            for s in &mut self.steps {
                if let Some(l) = &mut s.link {
                    shift(&mut l.step);
                }
            }
            for c in &mut self.cmps {
                shift_val_steps(&mut c.lhs, r);
                shift_val_steps(&mut c.rhs, r);
            }
            for v in &mut self.slots {
                shift_val_steps(v, r);
            }
        }
    }

    fn groups_hint(&self, groups: &Groups, scalar: bool) -> GroupsHint {
        if scalar {
            return GroupsHint::Const(1);
        }
        let outs: Vec<&OutCol> = groups
            .parts
            .iter()
            .map(|p| &p.out)
            .filter(|o| !matches!(o, OutCol::DimInt { .. } | OutCol::DimFloat { .. }))
            .collect();
        if outs.iter().any(|o| matches!(o, OutCol::KeyDict { .. }))
            || outs.iter().all(|o| matches!(o, OutCol::KeyChar { .. }))
        {
            return GroupsHint::Const(8);
        }
        if outs.len() == 1 && matches!(outs[0], OutCol::KeyNation { .. }) {
            return GroupsHint::Const(32);
        }
        if let Some(d) = groups.fk_dim {
            if self.steps.is_empty() {
                return GroupsHint::TableRows(d);
            }
        }
        GroupsHint::Const(256)
    }
}

/// Decrement join-step references above a removed index inside a value
/// tree.
fn shift_val_steps(v: &mut ValExpr, removed: usize) {
    match v {
        ValExpr::Payload { step, .. } => {
            if *step as usize > removed {
                *step -= 1;
            }
        }
        ValExpr::Add(a, b) | ValExpr::Sub(a, b) | ValExpr::Mul(a, b) => {
            shift_val_steps(a, removed);
            shift_val_steps(b, removed);
        }
        ValExpr::Const(_) | ValExpr::Col(_) => {}
    }
}

fn lit_i32(e: &Expr) -> Option<i32> {
    match e {
        Expr::Int(v) => i32::try_from(*v).ok(),
        Expr::Date(d) => Some(*d),
        _ => None,
    }
}

fn lit_f64(e: &Expr) -> Option<f64> {
    match e {
        Expr::Int(v) => Some(*v as f64),
        Expr::Float(v) => Some(*v),
        Expr::Date(d) => Some(*d as f64),
        _ => None,
    }
}

/// LIKE patterns the dictionary matcher supports: `prefix%`,
/// `%infix%`, and wildcard-free equality.
fn like_match(pat: &str) -> Result<StrMatch> {
    let pct = pat.matches('%').count();
    if pct == 0 {
        return Ok(StrMatch::Eq(pat.to_string()));
    }
    if pct == 1 && pat.ends_with('%') {
        return Ok(StrMatch::Prefix(pat[..pat.len() - 1].to_string()));
    }
    if pct == 2 && pat.starts_with('%') && pat.ends_with('%') && pat.len() >= 2 {
        return Ok(StrMatch::Contains(pat[1..pat.len() - 1].to_string()));
    }
    Err(crate::err!(
        "LIKE pattern {pat:?} unsupported (use 'prefix%', '%infix%', or no wildcard)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::engine::plan::vrevenue;
    use crate::analytics::sql::ast::parse;

    fn bind_text(sql: &str) -> Result<LogicalPlan> {
        bind(&parse(sql)?)
    }

    #[test]
    fn q6_binds_to_cmps_before_optimization() {
        let p = bind_text(
            "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
             AND l_discount >= 0.045 AND l_discount < 0.075 AND l_quantity < 24",
        )
        .unwrap();
        assert_eq!(p.pred, PredExpr::True, "numeric conjuncts bind as compares");
        assert_eq!(p.cmps.len(), 5);
        assert_eq!(p.slots, vec![vmul(vcol("l_extendedprice"), vcol("l_discount"))]);
        assert!(p.finalize.scalar);
        assert_eq!(p.groups_hint, GroupsHint::Const(1));
    }

    #[test]
    fn link_target_is_hoisted_and_wired() {
        let p = bind_text(
            "SELECT l_orderkey, SUM(l_extendedprice * (1 - l_discount)) AS revenue, o_orderdate \
             FROM lineitem \
             JOIN orders ON o_orderkey = l_orderkey \
             JOIN customer ON c_custkey = o_custkey \
             WHERE c_mktsegment = 'BUILDING' \
             GROUP BY l_orderkey, o_orderdate \
             ORDER BY revenue DESC, l_orderkey LIMIT 10",
        )
        .unwrap();
        assert_eq!(p.joins.len(), 2);
        assert_eq!(p.joins[0].table, TableRef::Customer, "target hoisted before linker");
        assert!(p.joins[0].probe_key.is_none());
        assert_eq!(p.joins[0].filter, PredExpr::Str { col: "c_mktsegment".into(), m: StrMatch::Eq("BUILDING".into()) });
        assert_eq!(p.joins[1].link, Some(LinkRef { step: 0, via: "o_custkey".into() }));
        assert!(!p.joins[1].dense, "linked steps cannot be dense");
        assert_eq!(p.slots, vec![vrevenue()]);
        assert_eq!(
            p.finalize.columns,
            vec![
                OutCol::KeyInt { shift: 0, bits: 0 },
                OutCol::Acc(0),
                OutCol::DimInt { table: TableRef::Orders, col: "o_orderdate".into() },
            ]
        );
        assert_eq!(p.finalize.sort, vec![(1, SortDir::Desc), (0, SortDir::Asc)]);
        assert_eq!(p.finalize.limit, 10);
    }

    #[test]
    fn dense_fk_group_elides_the_join() {
        let p = bind_text(
            "SELECT o_custkey, l_orderkey, o_orderdate, o_totalprice, SUM(l_quantity) \
             FROM lineitem JOIN orders ON o_orderkey = l_orderkey \
             GROUP BY o_custkey, l_orderkey, o_orderdate, o_totalprice \
             HAVING SUM(l_quantity) > 300 \
             ORDER BY o_totalprice DESC, l_orderkey LIMIT 100",
        )
        .unwrap();
        assert!(p.joins.is_empty(), "idle dense join elided");
        assert_eq!(p.key, KeyExpr::Col("l_orderkey".into()));
        assert_eq!(p.groups_hint, GroupsHint::TableRows(TableRef::Orders));
        assert_eq!(p.finalize.having_gt, Some((0, 300.0)));
        assert_eq!(p.finalize.sort, vec![(3, SortDir::Desc), (1, SortDir::Asc)]);
    }

    #[test]
    fn char_keys_pack_and_averages_share_slots() {
        let p = bind_text(
            "SELECT l_returnflag, l_linestatus, SUM(l_quantity), AVG(l_quantity), COUNT(*) \
             FROM lineitem GROUP BY l_returnflag, l_linestatus ORDER BY 1, 2",
        )
        .unwrap();
        assert_eq!(
            p.key,
            KeyExpr::Pack {
                hi: Box::new(KeyExpr::Col("l_returnflag".into())),
                shift: 8,
                lo: Box::new(KeyExpr::Col("l_linestatus".into())),
            }
        );
        assert_eq!(p.slots.len(), 1, "SUM and AVG share the accumulator");
        assert_eq!(
            p.finalize.columns,
            vec![
                OutCol::KeyChar { shift: 8 },
                OutCol::KeyChar { shift: 0 },
                OutCol::Acc(0),
                OutCol::AccOverCount(0),
                OutCol::Count,
            ]
        );
        assert_eq!(p.groups_hint, GroupsHint::Const(8));
    }

    #[test]
    fn q19_branch_bounds_lower_to_case_payloads() {
        let p = bind_text(
            "SELECT SUM(l_extendedprice * (1 - l_discount)) FROM lineitem \
             JOIN part ON p_partkey = l_partkey \
             WHERE l_shipinstruct = 'DELIVER IN PERSON' AND \
             ((p_brand = 'Brand#12' AND p_size BETWEEN 1 AND 5 \
               AND l_quantity >= 1 AND l_quantity <= 11) \
              OR (p_brand = 'Brand#23' AND p_size BETWEEN 1 AND 10 \
               AND l_quantity >= 10 AND l_quantity <= 20))",
        )
        .unwrap();
        assert_eq!(p.joins.len(), 1);
        assert!(p.joins[0].dense);
        assert_eq!(p.joins[0].payloads.len(), 2, "lo and hi CaseConst payloads");
        match &p.joins[0].payloads[0] {
            Payload::CaseConst { cases } => {
                assert_eq!(cases.len(), 2);
                assert_eq!(cases[0].1, 1.0);
                assert_eq!(cases[1].1, 10.0);
            }
            other => panic!("expected CaseConst, got {other:?}"),
        }
        assert_eq!(p.cmps.len(), 2);
        assert_eq!(p.cmps[0].op, CmpOp::Ge);
        assert_eq!(p.cmps[1].op, CmpOp::Le);
    }

    #[test]
    fn hostile_queries_error_cleanly() {
        for bad in [
            "SELECT SUM(x) FROM orders",                     // scan must be lineitem
            "SELECT SUM(nope) FROM lineitem",                // unknown column
            "SELECT l_quantity FROM lineitem",               // bare column, no group
            "SELECT SUM(l_quantity) FROM lineitem WHERE l_shipmode <> 'AIR'",
            "SELECT SUM(l_quantity) FROM lineitem WHERE l_quantity < 1 OR l_tax < 1",
            "SELECT SUM(l_quantity) FROM lineitem GROUP BY l_quantity",
            "SELECT SUM(l_quantity) FROM lineitem JOIN customer ON c_custkey = l_orderkey",
            "SELECT SUM(s_acctbal) FROM lineitem",           // supplier not joined
            "SELECT SUM(l_quantity) FROM lineitem GROUP BY l_orderkey, l_partkey",
        ] {
            assert!(bind_text(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}

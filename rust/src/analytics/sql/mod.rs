//! SQL front-end: text → [`LogicalPlan`] IR.
//!
//! A zero-dependency pipeline in three stages, each its own module and
//! each fallible end to end (hostile input errors, never panics):
//!
//! - [`lex`] — byte-oriented tokenizer;
//! - [`ast`] — recursive-descent parser for the TPC-H-shaped subset
//!   (SELECT with aggregates/arithmetic/CASE, INNER JOINs on equi-keys,
//!   WHERE with AND/OR/IN/BETWEEN/LIKE, GROUP BY, HAVING, ORDER BY,
//!   LIMIT), depth-capped against stack bombs;
//! - [`bind`] — lowers the AST against the [`catalog`] into the same
//!   `LogicalPlan` IR the query registry builds, so everything
//!   downstream (serial, morsel, distributed, zone-map pruning, the
//!   wire format) works on SQL-born plans unchanged.
//!
//! [`optimize`] is deliberately *not* part of `plan_sql`'s signature —
//! it rewrites `LogicalPlan` → `LogicalPlan`, so registry plans can be
//! run through it too. [`plan_sql`] applies it; callers comparing
//! optimized against raw plans use [`plan_sql_unoptimized`].

pub mod ast;
pub mod bind;
pub mod catalog;
pub mod lex;
pub mod optimize;

use crate::analytics::engine::plan::{self, LogicalPlan};
use crate::costmodel;
use crate::error::Result;

/// Parse, bind, and optimize: the front door.
pub fn plan_sql(text: &str) -> Result<LogicalPlan> {
    Ok(optimize::optimize(&plan_sql_unoptimized(text)?))
}

/// Parse and bind only — what the binder emits before any rewrite.
pub fn plan_sql_unoptimized(text: &str) -> Result<LogicalPlan> {
    let q = ast::parse(text)?;
    let p = bind::bind(&q)?;
    p.check_wire_bounds()?;
    Ok(p)
}

/// Human-readable explain: the optimized plan tree, the scan prune
/// intervals the zone maps will see, each join's build-side prune
/// potential, and cost-model estimates. Pure planning — touches no
/// data.
pub fn explain_report(text: &str) -> Result<String> {
    let raw = plan_sql_unoptimized(text)?;
    let opt = optimize::optimize(&raw);
    opt.check_wire_bounds()?;
    let mut out = String::new();
    out.push_str(&opt.pretty());
    out.push_str("\nscan prune intervals (zone-mapped columns skip whole morsels):\n");
    let before = plan::derived_intervals(&raw);
    let after = plan::derived_intervals(&opt);
    if after.is_empty() {
        out.push_str("  (none derived)\n");
    }
    for (col, lo, hi) in &after {
        let zoned = catalog::resolve(col).map(|(_, c)| c.zoned).unwrap_or(false);
        let tag = if zoned { "zoned" } else { "no zone map" };
        out.push_str(&format!("  {col} in [{lo}, {hi}]  ({tag})\n"));
    }
    out.push_str(&format!(
        "  {} interval(s) before optimization, {} after\n",
        before.len(),
        after.len()
    ));
    let est = costmodel::estimate(&opt, 1.0);
    out.push_str(&format!(
        "cost estimate (SF 1): scan {:.0} rows, selectivity {:.3}\n",
        est.scan_rows, est.scan_selectivity
    ));
    for (j, s) in opt.joins.iter().zip(est.steps.iter()) {
        out.push_str(&format!(
            "  build {}: {:.0} of {:.0} rows (selectivity {:.3}){}\n",
            s.table.name(),
            s.build_rows,
            s.base_rows,
            s.selectivity,
            if j.dense { ", dense" } else { "" }
        ));
        for (col, lo, hi) in plan::filter_intervals(&j.filter) {
            let zoned = catalog::resolve(&col).map(|(_, c)| c.zoned).unwrap_or(false);
            if zoned {
                out.push_str(&format!(
                    "    build-side prunable: {col} in [{lo}, {hi}]\n"
                ));
            }
        }
    }
    out.push_str(&format!("  estimated groups: {:.0}\n", est.agg_rows));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_sql_round_trips_q6() {
        let p = plan_sql(
            "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
             AND l_discount >= 0.045 AND l_discount < 0.075 AND l_quantity < 24",
        )
        .unwrap();
        assert!(p.finalize.scalar);
        assert!(p.cmps.is_empty(), "q6's compares all push into the scan");
    }

    #[test]
    fn explain_names_pruning_and_costs() {
        let r = explain_report(
            "SELECT SUM(l_quantity) FROM lineitem \
             JOIN part ON p_partkey = l_partkey \
             WHERE l_shipdate < DATE '1995-01-01' + 30 AND p_size < 15",
        )
        .unwrap();
        assert!(r.contains("l_shipdate"), "derived scan interval listed:\n{r}");
        assert!(r.contains("(zoned)"), "l_shipdate is zone-mapped:\n{r}");
        assert!(r.contains("build part"), "join estimate listed:\n{r}");
        assert!(
            r.contains("build-side prunable: p_size"),
            "dim zone maps cover p_size:\n{r}"
        );
        assert!(r.contains("0 interval(s) before optimization"), "{r}");
    }

    #[test]
    fn hostile_text_errors_cleanly_through_the_front_door() {
        for bad in ["", "SELECT", "SELECT 1 FROM nowhere", "((((((("] {
            assert!(plan_sql(bad).is_err(), "{bad:?}");
            assert!(explain_report(bad).is_err(), "{bad:?}");
        }
    }
}

//! SQL AST and recursive-descent parser.
//!
//! The grammar is the TPC-H-shaped subset the binder can lower:
//!
//! ```text
//! query   := SELECT item (',' item)*
//!            FROM ident join*
//!            (WHERE expr)? (GROUP BY exprs)? (HAVING expr)?
//!            (ORDER BY orders)? (LIMIT int)?
//! item    := expr (AS ident)?
//! join    := INNER? JOIN ident ON col '=' col (AND col '=' col)*
//! expr    := or-expr; precedence OR < AND < NOT < comparison/IN/
//!            BETWEEN/LIKE < add/sub < mul/div < unary < primary
//! primary := literal | DATE 'y-m-d' | CASE WHEN..THEN.. [ELSE..] END
//!          | SUM(e) | AVG(e) | COUNT(*) | ident(args) | ident | (expr)
//! ```
//!
//! The parser is fallible end to end: hostile text produces `Err`,
//! never a panic. Nesting depth is capped (parenthesised expressions,
//! CASE arms, and function arguments all recurse through the same
//! guarded entry point), so a parenthesis bomb cannot overflow the
//! stack.

use super::lex::{lex, Tok};
use crate::analytics::column::date_to_days;
use crate::error::Result;

/// Maximum expression nesting depth the parser will follow.
pub const MAX_PARSE_DEPTH: u32 = 64;

/// Arithmetic operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// Comparison operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpKind {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Aggregate function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    Sum,
    Avg,
    Count,
}

/// Expression node. `PartialEq` is load-bearing: the binder dedups
/// aggregate slots and matches ORDER BY / SELECT items by structural
/// equality.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Col(String),
    Int(i64),
    Float(f64),
    Str(String),
    /// `DATE 'yyyy-mm-dd'`, already converted to a day count.
    Date(i32),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Cmp(CmpKind, Box<Expr>, Box<Expr>),
    /// N-ary conjunction (flattened at parse time).
    And(Vec<Expr>),
    /// N-ary disjunction (flattened at parse time).
    Or(Vec<Expr>),
    Not(Box<Expr>),
    /// `expr IN (lit, ...)` — members are literals only.
    InList(Box<Expr>, Vec<Expr>),
    /// `expr BETWEEN lo AND hi` (closed on both ends, per SQL).
    Between(Box<Expr>, Box<Expr>, Box<Expr>),
    /// `expr LIKE 'pattern'` — the binder restricts patterns to
    /// `prefix%`, `%infix%`, and literal (no wildcard) forms.
    Like(Box<Expr>, String),
    Case { whens: Vec<(Expr, Expr)>, else_: Option<Box<Expr>> },
    /// `SUM(e)` / `AVG(e)` / `COUNT(*)` (`None` operand = `*`).
    Agg(AggKind, Option<Box<Expr>>),
    /// Scalar function call: `year(e)`, `nation_name(e)`,
    /// `region_of(e)`.
    Func(String, Vec<Expr>),
}

/// One `INNER JOIN dim ON a = b [AND c = d]` clause. ON sides are bare
/// column names; the binder resolves which side is the dimension key.
#[derive(Clone, Debug, PartialEq)]
pub struct JoinClause {
    pub table: String,
    pub on: Vec<(String, String)>,
}

/// ORDER BY key: 1-based output position or an expression matched
/// against SELECT items (by alias or structural equality).
#[derive(Clone, Debug, PartialEq)]
pub enum OrderKey {
    Pos(usize),
    Expr(Expr),
}

#[derive(Clone, Debug, PartialEq)]
pub struct OrderItem {
    pub key: OrderKey,
    pub desc: bool,
}

/// A parsed SELECT statement.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Query {
    /// Output expressions with optional `AS` aliases.
    pub select: Vec<(Expr, Option<String>)>,
    pub from: String,
    pub joins: Vec<JoinClause>,
    pub where_: Option<Expr>,
    pub group_by: Vec<Expr>,
    pub having: Option<Expr>,
    pub order_by: Vec<OrderItem>,
    pub limit: Option<u32>,
}

/// Parse one SELECT statement; trailing tokens are an error.
pub fn parse(text: &str) -> Result<Query> {
    let toks = lex(text)?;
    let mut p = Parser { toks, pos: 0, depth: 0 };
    let q = p.query()?;
    match p.peek() {
        None => Ok(q),
        Some(t) => Err(crate::err!("unexpected trailing {}", t.describe())),
    }
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
    depth: u32,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self.toks.get(self.pos).cloned();
        self.pos += 1;
        t.ok_or_else(|| crate::err!("unexpected end of query"))
    }

    fn expect(&mut self, want: &Tok) -> Result<()> {
        let got = self.next()?;
        crate::ensure!(&got == want, "expected {}, got {}", want.describe(), got.describe());
        Ok(())
    }

    /// True (and consume) if the next token is the keyword `kw`
    /// (case-insensitive).
    fn eat_kw(&mut self, kw: &str) -> bool {
        if let Some(Tok::Ident(s)) = self.peek() {
            if s.eq_ignore_ascii_case(kw) {
                self.pos += 1;
                return true;
            }
        }
        false
    }

    fn expect_kw(&mut self, kw: &str) -> Result<()> {
        crate::ensure!(self.eat_kw(kw), "expected keyword {kw}");
        Ok(())
    }

    /// Peek: is the next token the keyword `kw`?
    fn at_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s.eq_ignore_ascii_case(kw))
    }

    fn ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            t => Err(crate::err!("expected identifier, got {}", t.describe())),
        }
    }

    fn query(&mut self) -> Result<Query> {
        self.expect_kw("SELECT")?;
        let mut select = Vec::new();
        loop {
            let e = self.expr()?;
            let alias = if self.eat_kw("AS") { Some(self.ident()?) } else { None };
            select.push((e, alias));
            if !matches!(self.peek(), Some(Tok::Comma)) {
                break;
            }
            self.pos += 1;
        }
        self.expect_kw("FROM")?;
        let from = self.ident()?;
        let mut joins = Vec::new();
        loop {
            let inner = self.at_kw("INNER");
            if inner {
                self.pos += 1;
                self.expect_kw("JOIN")?;
            } else if !self.eat_kw("JOIN") {
                break;
            }
            let table = self.ident()?;
            self.expect_kw("ON")?;
            let mut on = Vec::new();
            loop {
                let a = self.ident()?;
                self.expect(&Tok::Eq)?;
                let b = self.ident()?;
                on.push((a, b));
                // An AND here belongs to the ON clause only if another
                // `col = col` pair follows; WHERE comes via its own
                // keyword, so plain AND always extends the ON list.
                if !self.eat_kw("AND") {
                    break;
                }
            }
            joins.push(JoinClause { table, on });
        }
        let where_ = if self.eat_kw("WHERE") { Some(self.expr()?) } else { None };
        let mut group_by = Vec::new();
        if self.eat_kw("GROUP") {
            self.expect_kw("BY")?;
            loop {
                group_by.push(self.expr()?);
                if !matches!(self.peek(), Some(Tok::Comma)) {
                    break;
                }
                self.pos += 1;
            }
        }
        let having = if self.eat_kw("HAVING") { Some(self.expr()?) } else { None };
        let mut order_by = Vec::new();
        if self.eat_kw("ORDER") {
            self.expect_kw("BY")?;
            loop {
                let key = match self.peek() {
                    Some(Tok::Int(n)) => {
                        let n = *n;
                        self.pos += 1;
                        crate::ensure!(n >= 1, "ORDER BY position must be >= 1, got {n}");
                        OrderKey::Pos(n as usize)
                    }
                    _ => OrderKey::Expr(self.expr()?),
                };
                let desc = if self.eat_kw("DESC") {
                    true
                } else {
                    self.eat_kw("ASC");
                    false
                };
                order_by.push(OrderItem { key, desc });
                if !matches!(self.peek(), Some(Tok::Comma)) {
                    break;
                }
                self.pos += 1;
            }
        }
        let limit = if self.eat_kw("LIMIT") {
            match self.next()? {
                Tok::Int(n) if (0..=u32::MAX as i64).contains(&n) => Some(n as u32),
                t => crate::bail!("LIMIT wants a small integer, got {}", t.describe()),
            }
        } else {
            None
        };
        Ok(Query { select, from, joins, where_, group_by, having, order_by, limit })
    }

    /// Expression entry point; every recursion passes through here, so
    /// this is where depth is bounded.
    fn expr(&mut self) -> Result<Expr> {
        self.depth += 1;
        crate::ensure!(self.depth <= MAX_PARSE_DEPTH, "expression nested deeper than {MAX_PARSE_DEPTH}");
        let e = self.or_expr();
        self.depth -= 1;
        e
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let first = self.and_expr()?;
        if !self.at_kw("OR") {
            return Ok(first);
        }
        let mut arms = vec![first];
        while self.eat_kw("OR") {
            arms.push(self.and_expr()?);
        }
        Ok(Expr::Or(arms))
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let first = self.not_expr()?;
        if !self.at_kw("AND") {
            return Ok(first);
        }
        let mut arms = vec![first];
        while self.eat_kw("AND") {
            arms.push(self.not_expr()?);
        }
        Ok(Expr::And(arms))
    }

    fn not_expr(&mut self) -> Result<Expr> {
        if self.eat_kw("NOT") {
            self.depth += 1;
            crate::ensure!(self.depth <= MAX_PARSE_DEPTH, "NOT nested deeper than {MAX_PARSE_DEPTH}");
            let inner = self.not_expr()?;
            self.depth -= 1;
            return Ok(Expr::Not(Box::new(inner)));
        }
        self.cmp_expr()
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let negate = {
            let save = self.pos;
            if self.eat_kw("NOT") {
                if self.at_kw("IN") || self.at_kw("BETWEEN") || self.at_kw("LIKE") {
                    true
                } else {
                    self.pos = save;
                    return Ok(lhs);
                }
            } else {
                false
            }
        };
        let kind = match self.peek() {
            Some(Tok::Eq) => Some(CmpKind::Eq),
            Some(Tok::Ne) => Some(CmpKind::Ne),
            Some(Tok::Lt) => Some(CmpKind::Lt),
            Some(Tok::Le) => Some(CmpKind::Le),
            Some(Tok::Gt) => Some(CmpKind::Gt),
            Some(Tok::Ge) => Some(CmpKind::Ge),
            _ => None,
        };
        if let Some(k) = kind {
            self.pos += 1;
            let rhs = self.add_expr()?;
            return Ok(Expr::Cmp(k, Box::new(lhs), Box::new(rhs)));
        }
        if self.eat_kw("IN") {
            self.expect(&Tok::LParen)?;
            let mut items = Vec::new();
            loop {
                items.push(self.literal()?);
                match self.next()? {
                    Tok::Comma => {}
                    Tok::RParen => break,
                    t => crate::bail!("expected ',' or ')' in IN list, got {}", t.describe()),
                }
            }
            let e = Expr::InList(Box::new(lhs), items);
            return Ok(if negate { Expr::Not(Box::new(e)) } else { e });
        }
        if self.eat_kw("BETWEEN") {
            let lo = self.add_expr()?;
            self.expect_kw("AND")?;
            let hi = self.add_expr()?;
            let e = Expr::Between(Box::new(lhs), Box::new(lo), Box::new(hi));
            return Ok(if negate { Expr::Not(Box::new(e)) } else { e });
        }
        if self.eat_kw("LIKE") {
            let pat = match self.next()? {
                Tok::Str(s) => s,
                t => crate::bail!("LIKE wants a string pattern, got {}", t.describe()),
            };
            let e = Expr::Like(Box::new(lhs), pat);
            return Ok(if negate { Expr::Not(Box::new(e)) } else { e });
        }
        crate::ensure!(!negate, "dangling NOT before {:?}", self.peek().map(Tok::describe));
        Ok(lhs)
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut e = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Plus) => BinOp::Add,
                Some(Tok::Minus) => BinOp::Sub,
                _ => return Ok(e),
            };
            self.pos += 1;
            let rhs = self.mul_expr()?;
            e = Expr::Bin(op, Box::new(e), Box::new(rhs));
        }
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut e = self.unary()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Star) => BinOp::Mul,
                Some(Tok::Slash) => BinOp::Div,
                _ => return Ok(e),
            };
            self.pos += 1;
            let rhs = self.unary()?;
            e = Expr::Bin(op, Box::new(e), Box::new(rhs));
        }
    }

    fn unary(&mut self) -> Result<Expr> {
        if matches!(self.peek(), Some(Tok::Minus)) {
            self.pos += 1;
            // Unary minus folds into the literal; arbitrary negation
            // has no IR form, so anything else is rejected here.
            return match self.next()? {
                Tok::Int(v) => Ok(Expr::Int(-v)),
                Tok::Float(v) => Ok(Expr::Float(-v)),
                t => Err(crate::err!("unary '-' applies to literals only, got {}", t.describe())),
            };
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.next()? {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Float(v) => Ok(Expr::Float(v)),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => self.ident_led(name),
            t => Err(crate::err!("expected expression, got {}", t.describe())),
        }
    }

    /// Continue a primary that started with an identifier: keyword
    /// constructs (DATE, CASE, aggregates), function calls, or a bare
    /// column reference.
    fn ident_led(&mut self, name: String) -> Result<Expr> {
        if name.eq_ignore_ascii_case("DATE") {
            return match self.next()? {
                Tok::Str(s) => Ok(Expr::Date(parse_date(&s)?)),
                t => Err(crate::err!("DATE wants a 'yyyy-mm-dd' string, got {}", t.describe())),
            };
        }
        if name.eq_ignore_ascii_case("CASE") {
            let mut whens = Vec::new();
            while self.eat_kw("WHEN") {
                let cond = self.expr()?;
                self.expect_kw("THEN")?;
                let val = self.expr()?;
                whens.push((cond, val));
            }
            crate::ensure!(!whens.is_empty(), "CASE needs at least one WHEN arm");
            let else_ =
                if self.eat_kw("ELSE") { Some(Box::new(self.expr()?)) } else { None };
            self.expect_kw("END")?;
            return Ok(Expr::Case { whens, else_ });
        }
        for (kw, kind) in
            [("SUM", AggKind::Sum), ("AVG", AggKind::Avg), ("COUNT", AggKind::Count)]
        {
            if name.eq_ignore_ascii_case(kw) {
                self.expect(&Tok::LParen)?;
                if kind == AggKind::Count && matches!(self.peek(), Some(Tok::Star)) {
                    self.pos += 1;
                    self.expect(&Tok::RParen)?;
                    return Ok(Expr::Agg(AggKind::Count, None));
                }
                let arg = self.expr()?;
                self.expect(&Tok::RParen)?;
                return Ok(Expr::Agg(kind, Some(Box::new(arg))));
            }
        }
        if matches!(self.peek(), Some(Tok::LParen)) {
            self.pos += 1;
            let mut args = Vec::new();
            if !matches!(self.peek(), Some(Tok::RParen)) {
                loop {
                    args.push(self.expr()?);
                    if !matches!(self.peek(), Some(Tok::Comma)) {
                        break;
                    }
                    self.pos += 1;
                }
            }
            self.expect(&Tok::RParen)?;
            return Ok(Expr::Func(name.to_ascii_lowercase(), args));
        }
        Ok(Expr::Col(name))
    }

    /// A literal for IN lists: int, float, string, or DATE.
    fn literal(&mut self) -> Result<Expr> {
        match self.next()? {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Float(v) => Ok(Expr::Float(v)),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::Ident(name) if name.eq_ignore_ascii_case("DATE") => match self.next()? {
                Tok::Str(s) => Ok(Expr::Date(parse_date(&s)?)),
                t => Err(crate::err!("DATE wants a 'yyyy-mm-dd' string, got {}", t.describe())),
            },
            t => Err(crate::err!("IN list members must be literals, got {}", t.describe())),
        }
    }
}

/// Parse `yyyy-mm-dd` into a day count, validating ranges so
/// `date_to_days` never sees garbage.
fn parse_date(s: &str) -> Result<i32> {
    let parts: Vec<&str> = s.split('-').collect();
    crate::ensure!(parts.len() == 3, "date {s:?} is not yyyy-mm-dd");
    let y: i32 = parts[0].parse().map_err(|_| crate::err!("bad year in date {s:?}"))?;
    let m: u32 = parts[1].parse().map_err(|_| crate::err!("bad month in date {s:?}"))?;
    let d: u32 = parts[2].parse().map_err(|_| crate::err!("bad day in date {s:?}"))?;
    crate::ensure!((1000..=9999).contains(&y), "year {y} out of range in {s:?}");
    crate::ensure!((1..=12).contains(&m), "month {m} out of range in {s:?}");
    crate::ensure!((1..=31).contains(&d), "day {d} out of range in {s:?}");
    Ok(date_to_days(y, m, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_q6_shape() {
        let q = parse(
            "SELECT SUM(l_extendedprice * l_discount) FROM lineitem \
             WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01' \
             AND l_discount BETWEEN 0.05 - 0.01 AND 0.07 + 0.01 AND l_quantity < 24",
        )
        .unwrap();
        assert_eq!(q.from, "lineitem");
        assert_eq!(q.select.len(), 1);
        let w = q.where_.unwrap();
        match w {
            Expr::And(arms) => assert_eq!(arms.len(), 4),
            other => panic!("expected top-level AND, got {other:?}"),
        }
    }

    #[test]
    fn joins_group_order_limit() {
        let q = parse(
            "SELECT l_orderkey, SUM(l_extendedprice) AS rev FROM lineitem \
             JOIN orders ON o_orderkey = l_orderkey AND o_custkey = o_custkey \
             GROUP BY l_orderkey ORDER BY rev DESC, 1 ASC LIMIT 10",
        )
        .unwrap();
        assert_eq!(q.joins.len(), 1);
        assert_eq!(q.joins[0].on.len(), 2);
        assert_eq!(q.order_by.len(), 2);
        assert!(q.order_by[0].desc);
        assert_eq!(q.order_by[1].key, OrderKey::Pos(1));
        assert_eq!(q.limit, Some(10));
    }

    #[test]
    fn precedence_binds_mul_over_add_over_cmp_over_and() {
        let q = parse("SELECT COUNT(*) FROM lineitem WHERE a + b * 2 < 10 AND c = 1").unwrap();
        let Expr::And(arms) = q.where_.unwrap() else { panic!("AND expected") };
        let Expr::Cmp(CmpKind::Lt, lhs, _) = &arms[0] else { panic!("Lt expected") };
        let Expr::Bin(BinOp::Add, _, rhs) = lhs.as_ref() else { panic!("Add expected") };
        assert!(matches!(rhs.as_ref(), Expr::Bin(BinOp::Mul, _, _)));
    }

    #[test]
    fn case_in_between_like_and_not_variants() {
        let q = parse(
            "SELECT SUM(CASE WHEN p_type LIKE 'PROMO%' THEN 1 ELSE 0 END) FROM lineitem \
             WHERE l_shipmode IN ('MAIL', 'SHIP') AND l_quantity NOT BETWEEN 5 AND 10 \
             AND p_name NOT LIKE 'x%' AND NOT l_linenumber = 3",
        )
        .unwrap();
        let Expr::And(arms) = q.where_.unwrap() else { panic!("AND expected") };
        assert!(matches!(&arms[0], Expr::InList(_, items) if items.len() == 2));
        assert!(matches!(&arms[1], Expr::Not(b) if matches!(b.as_ref(), Expr::Between(..))));
        assert!(matches!(&arms[2], Expr::Not(b) if matches!(b.as_ref(), Expr::Like(..))));
        assert!(matches!(&arms[3], Expr::Not(b) if matches!(b.as_ref(), Expr::Cmp(..))));
    }

    #[test]
    fn hostile_inputs_error_not_panic() {
        for bad in [
            "",
            "SELECT",
            "SELECT FROM lineitem",
            "SELECT 1 FROM",
            "SELECT 1 FROM lineitem WHERE",
            "SELECT 1 FROM lineitem trailing junk",
            "SELECT a b FROM t",
            "SELECT 1 FROM t LIMIT -3",
            "SELECT CASE END FROM t",
            "SELECT COUNT(l) FROM t WHERE x IN (a)",
            "SELECT 1 FROM t WHERE DATE 'not-a-date' < x",
            "SELECT - FROM t",
        ] {
            assert!(parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn depth_bomb_is_rejected() {
        let mut q = String::from("SELECT 1 FROM t WHERE ");
        for _ in 0..200 {
            q.push('(');
        }
        q.push('1');
        for _ in 0..200 {
            q.push(')');
        }
        q.push_str(" = 1");
        assert!(parse(&q).is_err());
    }

    #[test]
    fn date_literals_convert_and_validate() {
        let q = parse("SELECT 1 FROM t WHERE d = DATE '1994-01-01'").unwrap();
        let Expr::Cmp(_, _, rhs) = q.where_.unwrap() else { panic!() };
        assert_eq!(*rhs, Expr::Date(date_to_days(1994, 1, 1)));
        assert!(parse("SELECT 1 FROM t WHERE d = DATE '1994-13-01'").is_err());
    }
}

//! Static TPC-H catalog for the SQL binder.
//!
//! The binder needs four things the plan IR does not carry: which table
//! a column name belongs to, its storage type, how a dimension table
//! joins back to the `lineitem` scan (foreign-key shape, dense-PK
//! eligibility), and which columns carry zone maps (so `explain` can
//! report prune potential without generating data). All of it is
//! compile-time constant — column naming follows the TPC-H prefix
//! convention, so resolution is a flat lookup.

use crate::analytics::engine::plan::TableRef;
use crate::analytics::tpch::{NATIONS, REGIONS};
use crate::error::Result;

/// Storage type of a catalog column, as the executor sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColType {
    /// `i64` join key (`l_orderkey`, `p_partkey`, …).
    Key,
    /// Plain `i32` (sizes, nation keys, line numbers).
    I32,
    /// `i32` day count — comparable against `DATE '...'` literals.
    Date,
    /// `f64` measure.
    F64,
    /// Single-byte code (`l_returnflag`, `o_orderstatus`).
    Char,
    /// Dictionary-encoded string.
    Str,
}

/// One column of one table.
#[derive(Clone, Copy, Debug)]
pub struct ColDef {
    pub name: &'static str,
    pub ty: ColType,
    /// Whether the generated table carries per-chunk zones for this
    /// column (lineitem zones its measures and dates at append time;
    /// dimension tables zone every numeric column via
    /// `ZoneMap::build_from`).
    pub zoned: bool,
}

const fn col(name: &'static str, ty: ColType, zoned: bool) -> ColDef {
    ColDef { name, ty, zoned }
}

/// One table: its IR tag, columns, and (for dimensions) the dense
/// primary key — consecutive `1..=n` keys that allow `dense: true`
/// join steps with direct indexing instead of a hash build.
#[derive(Clone, Copy, Debug)]
pub struct TableDef {
    pub table: TableRef,
    pub cols: &'static [ColDef],
    pub dense_pk: Option<&'static str>,
}

/// Bit width of the packed `(ps_partkey << PS_SHIFT) | ps_suppkey`
/// composite key — must match `queries::q9`.
pub const PS_SHIFT: u8 = 21;

static LINEITEM: TableDef = TableDef {
    table: TableRef::Lineitem,
    dense_pk: None,
    cols: &[
        col("l_orderkey", ColType::Key, false),
        col("l_partkey", ColType::Key, false),
        col("l_suppkey", ColType::Key, false),
        col("l_linenumber", ColType::I32, false),
        col("l_quantity", ColType::F64, true),
        col("l_extendedprice", ColType::F64, true),
        col("l_discount", ColType::F64, true),
        col("l_tax", ColType::F64, true),
        col("l_returnflag", ColType::Char, false),
        col("l_linestatus", ColType::Char, false),
        col("l_shipdate", ColType::Date, true),
        col("l_commitdate", ColType::Date, true),
        col("l_receiptdate", ColType::Date, true),
        col("l_shipmode", ColType::Str, false),
        col("l_shipinstruct", ColType::Str, false),
    ],
};

static ORDERS: TableDef = TableDef {
    table: TableRef::Orders,
    dense_pk: Some("o_orderkey"),
    cols: &[
        col("o_orderkey", ColType::Key, true),
        col("o_custkey", ColType::Key, true),
        col("o_orderdate", ColType::Date, true),
        col("o_totalprice", ColType::F64, true),
        col("o_orderpriority", ColType::Str, false),
        col("o_orderstatus", ColType::Char, false),
    ],
};

static CUSTOMER: TableDef = TableDef {
    table: TableRef::Customer,
    dense_pk: Some("c_custkey"),
    cols: &[
        col("c_custkey", ColType::Key, true),
        col("c_nationkey", ColType::I32, true),
        col("c_acctbal", ColType::F64, true),
        col("c_mktsegment", ColType::Str, false),
    ],
};

static SUPPLIER: TableDef = TableDef {
    table: TableRef::Supplier,
    dense_pk: Some("s_suppkey"),
    cols: &[
        col("s_suppkey", ColType::Key, true),
        col("s_nationkey", ColType::I32, true),
        col("s_acctbal", ColType::F64, true),
    ],
};

static PART: TableDef = TableDef {
    table: TableRef::Part,
    dense_pk: Some("p_partkey"),
    cols: &[
        col("p_partkey", ColType::Key, true),
        col("p_name", ColType::Str, false),
        col("p_brand", ColType::Str, false),
        col("p_type", ColType::Str, false),
        col("p_container", ColType::Str, false),
        col("p_size", ColType::I32, true),
        col("p_retailprice", ColType::F64, true),
    ],
};

static PARTSUPP: TableDef = TableDef {
    table: TableRef::Partsupp,
    dense_pk: None,
    cols: &[
        col("ps_partkey", ColType::Key, true),
        col("ps_suppkey", ColType::Key, true),
        col("ps_availqty", ColType::I32, true),
        col("ps_supplycost", ColType::F64, true),
    ],
};

static TABLES: [&TableDef; 6] = [&LINEITEM, &ORDERS, &CUSTOMER, &SUPPLIER, &PART, &PARTSUPP];

/// Look a table up by SQL name (case-insensitive).
pub fn table(name: &str) -> Result<&'static TableDef> {
    TABLES
        .iter()
        .find(|t| t.table.name().eq_ignore_ascii_case(name))
        .copied()
        .ok_or_else(|| crate::err!("unknown table {name:?}"))
}

/// Definition record for a `TableRef` (infallible: every tag is listed).
pub fn table_def(t: TableRef) -> &'static TableDef {
    TABLES.iter().find(|d| d.table == t).copied().unwrap_or(&LINEITEM)
}

/// Resolve a column name to its owning table and type. Column names are
/// globally unique in TPC-H (prefix convention), so no qualification is
/// needed.
pub fn resolve(col: &str) -> Result<(&'static TableDef, ColDef)> {
    for t in TABLES {
        if let Some(c) = t.cols.iter().find(|c| c.name == col) {
            return Ok((t, *c));
        }
    }
    Err(crate::err!("unknown column {col:?}"))
}

/// Type of a column, if it exists anywhere in the catalog.
pub fn col_type(col: &str) -> Option<ColType> {
    resolve(col).ok().map(|(_, c)| c.ty)
}

/// How a `JOIN <dim> ON <dim-key> = <scan-col>` equi-pair maps onto a
/// probe. `Single` joins probe one scan column; `Packed` is the
/// partsupp composite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FkShape {
    /// `dim_key = scan_col`, with `dense` legal iff `dim_key` is the
    /// dense PK.
    Single { scan_col: &'static str, dense_ok: bool },
    /// `(ps_partkey, ps_suppkey) = (l_partkey, l_suppkey)` packed with
    /// [`PS_SHIFT`].
    Packed { scan_a: &'static str, scan_b: &'static str, shift: u8 },
}

/// The scan-side probe shape for joining `dim` on `dim_key_cols` (the
/// dim-side columns named in the ON clause, in appearance order).
/// Returns an error for key pairings the engine cannot probe.
pub fn fk_shape(dim: TableRef, dim_keys: &[&str], scan_cols: &[&str]) -> Result<FkShape> {
    match (dim, dim_keys, scan_cols) {
        (TableRef::Orders, ["o_orderkey"], ["l_orderkey"]) => {
            Ok(FkShape::Single { scan_col: "l_orderkey", dense_ok: true })
        }
        (TableRef::Part, ["p_partkey"], ["l_partkey"]) => {
            Ok(FkShape::Single { scan_col: "l_partkey", dense_ok: true })
        }
        (TableRef::Supplier, ["s_suppkey"], ["l_suppkey"]) => {
            Ok(FkShape::Single { scan_col: "l_suppkey", dense_ok: true })
        }
        (TableRef::Partsupp, ["ps_partkey", "ps_suppkey"], ["l_partkey", "l_suppkey"])
        | (TableRef::Partsupp, ["ps_suppkey", "ps_partkey"], ["l_suppkey", "l_partkey"]) => {
            Ok(FkShape::Packed { scan_a: "l_partkey", scan_b: "l_suppkey", shift: PS_SHIFT })
        }
        _ => Err(crate::err!(
            "no foreign-key path joins {} on ({}) to lineitem ({})",
            dim.name(),
            dim_keys.join(", "),
            scan_cols.join(", ")
        )),
    }
}

/// The dense dimension a lineitem foreign-key column points at, if
/// any. Grouping by such a column lets sibling group-by columns of
/// that dimension become dense decorations (`DimInt`/`DimFloat`)
/// instead of key bits.
pub fn scan_fk_dim(col: &str) -> Option<TableRef> {
    match col {
        "l_orderkey" => Some(TableRef::Orders),
        "l_partkey" => Some(TableRef::Part),
        "l_suppkey" => Some(TableRef::Supplier),
        _ => None,
    }
}

/// The dim→dim link edge: `customer.c_custkey = orders.o_custkey`.
/// Returns the `via` column on the linking step if `(target, target_key,
/// linker, linker_col)` is that edge.
pub fn link_via(
    target: TableRef,
    target_key: &str,
    linker: TableRef,
    linker_col: &str,
) -> Option<&'static str> {
    if target == TableRef::Customer
        && target_key == "c_custkey"
        && linker == TableRef::Orders
        && linker_col == "o_custkey"
    {
        Some("o_custkey")
    } else {
        None
    }
}

/// Nation keys belonging to `region` (the `region_of(col) = '...'`
/// rewrite target, mirroring `queries::q5`).
pub fn region_nations(region: &str) -> Result<Vec<i32>> {
    let idx = REGIONS
        .iter()
        .position(|r| *r == region)
        .ok_or_else(|| crate::err!("unknown region {region:?}"))?
        as u32;
    Ok(NATIONS
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| *r == idx)
        .map(|(i, _)| i as i32)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_columns_to_their_tables() {
        let (t, c) = resolve("l_shipdate").unwrap();
        assert_eq!(t.table, TableRef::Lineitem);
        assert_eq!(c.ty, ColType::Date);
        assert!(c.zoned);
        let (t, c) = resolve("c_mktsegment").unwrap();
        assert_eq!(t.table, TableRef::Customer);
        assert_eq!(c.ty, ColType::Str);
        assert!(resolve("nonexistent").is_err());
    }

    #[test]
    fn catalog_matches_generated_tables() {
        use crate::analytics::column::Column;
        use crate::analytics::engine::plan;
        use crate::analytics::tpch::{TpchConfig, TpchDb};
        let db = TpchDb::generate(TpchConfig::new(0.001, 7));
        for def in TABLES {
            let t = plan::table(&db, def.table);
            for c in def.cols {
                let stored = t.col(c.name);
                let ty_ok = match (c.ty, stored) {
                    (ColType::Key, Column::I64(_)) => true,
                    (ColType::I32 | ColType::Date, Column::I32(_)) => true,
                    (ColType::F64, Column::F64(_)) => true,
                    (ColType::Char, Column::U8(_)) => true,
                    (ColType::Str, Column::Str { .. }) => true,
                    _ => false,
                };
                assert!(ty_ok, "{}.{} type mismatch", def.table.name(), c.name);
                let zm = t.zones().expect("all generated tables carry zone maps");
                assert_eq!(
                    zm.col(c.name).is_some(),
                    c.zoned,
                    "{}.{} zone coverage mismatch",
                    def.table.name(),
                    c.name
                );
            }
        }
    }

    #[test]
    fn fk_shapes_cover_the_star_schema() {
        assert_eq!(
            fk_shape(TableRef::Orders, &["o_orderkey"], &["l_orderkey"]).unwrap(),
            FkShape::Single { scan_col: "l_orderkey", dense_ok: true }
        );
        match fk_shape(
            TableRef::Partsupp,
            &["ps_suppkey", "ps_partkey"],
            &["l_suppkey", "l_partkey"],
        )
        .unwrap()
        {
            FkShape::Packed { scan_a, scan_b, shift } => {
                assert_eq!((scan_a, scan_b, shift), ("l_partkey", "l_suppkey", PS_SHIFT));
            }
            other => panic!("expected packed shape, got {other:?}"),
        }
        assert!(fk_shape(TableRef::Orders, &["o_custkey"], &["l_orderkey"]).is_err());
        assert_eq!(
            link_via(TableRef::Customer, "c_custkey", TableRef::Orders, "o_custkey"),
            Some("o_custkey")
        );
        assert!(link_via(TableRef::Supplier, "s_suppkey", TableRef::Orders, "o_custkey").is_none());
    }

    #[test]
    fn asia_nations_match_q5() {
        let asia = region_nations("ASIA").unwrap();
        assert!(!asia.is_empty());
        for n in &asia {
            assert_eq!(NATIONS[*n as usize].1, 2, "ASIA is region index 2");
        }
        assert!(region_nations("ATLANTIS").is_err());
    }
}

//! Columnar storage: typed columns, dictionary-encoded strings, tables.
//!
//! The engine is vectorized: operators produce *selection vectors*
//! (`Vec<u32>` of row indices) over immutable columns, the classic
//! MonetDB/X100 design. Column accessors are `#[inline]` and bounds-checked
//! only in debug builds on the hot paths that matter.

use crate::analytics::chunkstore::ZoneMap;
use std::collections::HashMap;

/// A typed column.
#[derive(Clone, Debug)]
pub enum Column {
    I64(Vec<i64>),
    I32(Vec<i32>),
    F64(Vec<f64>),
    U8(Vec<u8>),
    /// Dictionary-encoded string column: `codes[i]` indexes `dict`.
    Str { dict: Vec<String>, codes: Vec<u32> },
}

impl Column {
    pub fn len(&self) -> usize {
        match self {
            Column::I64(v) => v.len(),
            Column::I32(v) => v.len(),
            Column::F64(v) => v.len(),
            Column::U8(v) => v.len(),
            Column::Str { codes, .. } => codes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of storage this column occupies (drives the memsim profile).
    pub fn bytes(&self) -> u64 {
        match self {
            Column::I64(v) => (v.len() * 8) as u64,
            Column::I32(v) => (v.len() * 4) as u64,
            Column::F64(v) => (v.len() * 8) as u64,
            Column::U8(v) => v.len() as u64,
            Column::Str { dict, codes } => {
                (codes.len() * 4) as u64 + dict.iter().map(|s| s.len() as u64).sum::<u64>()
            }
        }
    }

    pub fn as_i64(&self) -> &[i64] {
        match self {
            Column::I64(v) => v,
            _ => panic!("column is not i64"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Column::I32(v) => v,
            _ => panic!("column is not i32"),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            Column::F64(v) => v,
            _ => panic!("column is not f64"),
        }
    }

    pub fn as_u8(&self) -> &[u8] {
        match self {
            Column::U8(v) => v,
            _ => panic!("column is not u8"),
        }
    }

    pub fn as_str_codes(&self) -> (&[String], &[u32]) {
        match self {
            Column::Str { dict, codes } => (dict, codes),
            _ => panic!("column is not str"),
        }
    }

    /// Resolve a string value at a row.
    pub fn str_at(&self, row: usize) -> &str {
        let (dict, codes) = self.as_str_codes();
        &dict[codes[row] as usize]
    }

    /// Dictionary code for `value`, if present.
    pub fn dict_code(&self, value: &str) -> Option<u32> {
        let (dict, _) = self.as_str_codes();
        dict.iter().position(|s| s == value).map(|i| i as u32)
    }
}

/// Builder for dictionary-encoded string columns.
#[derive(Default)]
pub struct StrColumnBuilder {
    dict: Vec<String>,
    index: HashMap<String, u32>,
    codes: Vec<u32>,
}

impl StrColumnBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, s: &str) {
        let code = match self.index.get(s) {
            Some(&c) => c,
            None => {
                let c = self.dict.len() as u32;
                self.dict.push(s.to_string());
                self.index.insert(s.to_string(), c);
                c
            }
        };
        self.codes.push(code);
    }

    pub fn finish(self) -> Column {
        Column::Str { dict: self.dict, codes: self.codes }
    }
}

/// A named table of equal-length columns, optionally summarised by a
/// min-max [`ZoneMap`] over fixed-size row chunks.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub name: String,
    columns: Vec<(String, Column)>,
    len: usize,
    zones: Option<ZoneMap>,
}

impl Table {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), columns: Vec::new(), len: 0, zones: None }
    }

    /// Attach a zone map (built by the producer or via
    /// [`ZoneMap::build_from`]). Scans use it to skip chunks; absence
    /// only disables pruning, never correctness.
    pub fn set_zones(&mut self, zones: ZoneMap) {
        self.zones = Some(zones);
    }

    pub fn zones(&self) -> Option<&ZoneMap> {
        self.zones.as_ref()
    }

    pub fn add(&mut self, name: &str, col: Column) -> &mut Self {
        if self.columns.is_empty() {
            self.len = col.len();
        } else {
            assert_eq!(col.len(), self.len, "column {name} length mismatch in {}", self.name);
        }
        self.columns.push((name.to_string(), col));
        self
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn col(&self, name: &str) -> &Column {
        self.columns
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c)
            .unwrap_or_else(|| panic!("no column {name} in table {}", self.name))
    }

    pub fn has_col(&self, name: &str) -> bool {
        self.columns.iter().any(|(n, _)| n == name)
    }

    pub fn column_names(&self) -> Vec<&str> {
        self.columns.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Total bytes across columns.
    pub fn bytes(&self) -> u64 {
        self.columns.iter().map(|(_, c)| c.bytes()).sum()
    }

    /// Extract the subset of rows in `sel` (used to partition tables for
    /// distributed execution). The result carries no zone map: an
    /// arbitrary row subset breaks chunk alignment.
    pub fn take(&self, sel: &[u32]) -> Table {
        let mut out = Table::new(&self.name);
        for (name, col) in &self.columns {
            let new_col = match col {
                Column::I64(v) => Column::I64(sel.iter().map(|&i| v[i as usize]).collect()),
                Column::I32(v) => Column::I32(sel.iter().map(|&i| v[i as usize]).collect()),
                Column::F64(v) => Column::F64(sel.iter().map(|&i| v[i as usize]).collect()),
                Column::U8(v) => Column::U8(sel.iter().map(|&i| v[i as usize]).collect()),
                Column::Str { dict, codes } => Column::Str {
                    dict: dict.clone(),
                    codes: sel.iter().map(|&i| codes[i as usize]).collect(),
                },
            };
            out.add(name, new_col);
        }
        out
    }
}

// ----------------------------------------------------------------- dates

/// Days since 1970-01-01 for a civil date (Howard Hinnant's algorithm).
pub fn date_to_days(y: i32, m: u32, d: u32) -> i32 {
    debug_assert!((1..=12).contains(&m) && (1..=31).contains(&d));
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = (y - era * 400) as i64;
    let mp = ((m + 9) % 12) as i64;
    let doy = (153 * mp + 2) / 5 + d as i64 - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    (era as i64 * 146_097 + doe - 719_468) as i32
}

/// Inverse of [`date_to_days`].
pub fn days_to_date(days: i32) -> (i32, u32, u32) {
    let z = days as i64 + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    ((if m <= 2 { y + 1 } else { y }) as i32, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_types_and_bytes() {
        let c = Column::I64(vec![1, 2, 3]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.bytes(), 24);
        assert_eq!(c.as_i64()[1], 2);
        let f = Column::F64(vec![1.5]);
        assert_eq!(f.bytes(), 8);
        let b = Column::U8(vec![0; 5]);
        assert_eq!(b.bytes(), 5);
    }

    #[test]
    fn str_dictionary_dedups() {
        let mut b = StrColumnBuilder::new();
        for s in ["AIR", "RAIL", "AIR", "SHIP", "AIR"] {
            b.push(s);
        }
        let c = b.finish();
        let (dict, codes) = c.as_str_codes();
        assert_eq!(dict.len(), 3);
        assert_eq!(codes, &[0, 1, 0, 2, 0]);
        assert_eq!(c.str_at(3), "SHIP");
        assert_eq!(c.dict_code("RAIL"), Some(1));
        assert_eq!(c.dict_code("TRUCK"), None);
    }

    #[test]
    fn table_accessors() {
        let mut t = Table::new("t");
        t.add("a", Column::I64(vec![1, 2, 3]));
        t.add("b", Column::F64(vec![0.1, 0.2, 0.3]));
        assert_eq!(t.len(), 3);
        assert!(t.has_col("a") && !t.has_col("z"));
        assert_eq!(t.col("b").as_f64()[2], 0.3);
        assert_eq!(t.column_names(), vec!["a", "b"]);
        assert_eq!(t.bytes(), 24 + 24);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut t = Table::new("t");
        t.add("a", Column::I64(vec![1, 2, 3]));
        t.add("b", Column::I64(vec![1]));
    }

    #[test]
    fn take_extracts_rows() {
        let mut t = Table::new("t");
        t.add("a", Column::I64(vec![10, 20, 30, 40]));
        let mut b = StrColumnBuilder::new();
        for s in ["x", "y", "x", "z"] {
            b.push(s);
        }
        t.add("s", b.finish());
        let sub = t.take(&[3, 1]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.col("a").as_i64(), &[40, 20]);
        assert_eq!(sub.col("s").str_at(0), "z");
        assert_eq!(sub.col("s").str_at(1), "y");
    }

    #[test]
    fn date_roundtrip() {
        for (y, m, d) in [(1992, 1, 1), (1995, 6, 17), (1998, 12, 1), (1970, 1, 1), (2000, 2, 29)] {
            let days = date_to_days(y, m, d);
            assert_eq!(days_to_date(days), (y, m, d), "date {y}-{m}-{d}");
        }
    }

    #[test]
    fn date_known_values() {
        assert_eq!(date_to_days(1970, 1, 1), 0);
        assert_eq!(date_to_days(1970, 1, 2), 1);
        // TPC-H epoch: 1992-01-01 = 8035 days after unix epoch.
        assert_eq!(date_to_days(1992, 1, 1), 8035);
        // Q1 cutoff: 1998-12-01.
        assert_eq!(date_to_days(1998, 12, 1) - date_to_days(1998, 9, 2), 90);
    }

    #[test]
    fn date_ordering() {
        assert!(date_to_days(1994, 1, 1) < date_to_days(1995, 1, 1));
        assert!(date_to_days(1994, 12, 31) < date_to_days(1995, 1, 1));
    }
}

//! TPC-H Q5 — local supplier volume: revenue per nation within a region
//! where the customer and supplier share the nation.
//!
//! Five-way join (region→nation→customer→orders→lineitem→supplier); the
//! co-nationality constraint — expressed in the IR as a post-join
//! payload equality — makes it the join-heaviest query in the set.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    cmp, i32_in, i32_range, kpay, vpay, vrevenue, CmpOp, FinalizeSpec, GroupsHint, JoinStep,
    KeyCols, LinkRef, LogicalPlan, OutCol, Payload, PredExpr, SortDir, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::{TpchDb, NATIONS, REGIONS};
use crate::error::Result;

const REGION: &str = "ASIA";

fn window() -> (i32, i32) {
    (date_to_days(1994, 1, 1), date_to_days(1995, 1, 1))
}

/// Nation keys belonging to `region`.
fn region_nations(region: &str) -> Result<Vec<i32>> {
    let idx = REGIONS
        .iter()
        .position(|r| *r == region)
        .ok_or_else(|| crate::err!("unknown region {region:?}"))?
        as u32;
    Ok(NATIONS
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| *r == idx)
        .map(|(i, _)| i as i32)
        .collect())
}

/// The one Q5 IR constructor: customers of the region carry their
/// nation; orders in the window link into them (FromLink flows the
/// nation through); suppliers carry theirs; a post-join equality keeps
/// co-national rows and revenue groups by that nation. Parameter keys:
/// `region`, `date-lo`, `date-hi`.
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let region = p.get_str("region", REGION)?;
    let (lo_d, hi_d) = window();
    let lo_d = p.get_date("date-lo", lo_d)?;
    let hi_d = p.get_date("date-hi", hi_d)?;
    let nations = region_nations(&region)?;
    Ok(LogicalPlan {
        name: "q5".into(),
        scan: TableRef::Lineitem,
        pred: PredExpr::True,
        joins: vec![
            JoinStep {
                table: TableRef::Customer,
                dense: false,
                build_key: Some(KeyCols::Col("c_custkey".into())),
                probe_key: None,
                filter: i32_in("c_nationkey", nations),
                link: None,
                payloads: vec![Payload::Col("c_nationkey".into())],
            },
            JoinStep {
                table: TableRef::Orders,
                dense: false,
                build_key: Some(KeyCols::Col("o_orderkey".into())),
                probe_key: Some(KeyCols::Col("l_orderkey".into())),
                filter: i32_range("o_orderdate", lo_d, hi_d),
                link: Some(LinkRef { step: 0, via: "o_custkey".into() }),
                payloads: vec![Payload::FromLink(0)],
            },
            JoinStep {
                table: TableRef::Supplier,
                dense: false,
                build_key: Some(KeyCols::Col("s_suppkey".into())),
                probe_key: Some(KeyCols::Col("l_suppkey".into())),
                filter: PredExpr::True,
                link: None,
                payloads: vec![Payload::Col("s_nationkey".into())],
            },
        ],
        // Customer nation == supplier nation.
        cmps: vec![cmp(vpay(1, 0), CmpOp::Eq, vpay(2, 0))],
        key: kpay(1, 0),
        slots: vec![vrevenue()],
        groups_hint: GroupsHint::Const(32),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![OutCol::KeyNation { shift: 0, bits: 0 }, OutCol::Acc(0)],
            having_gt: None,
            sort: vec![(1, SortDir::Desc)],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q5 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let (lo, hi) = window();
    let asia: Vec<i64> = region_nations(REGION).unwrap().iter().map(|&n| n as i64).collect();
    let cust = &db.customer;
    let mut cust_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..cust.len() {
        let nk = cust.col("c_nationkey").as_i32()[i] as i64;
        if asia.contains(&nk) {
            cust_nat.insert(cust.col("c_custkey").as_i64()[i], nk);
        }
    }
    let orders = &db.orders;
    let mut order_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..orders.len() {
        let d = orders.col("o_orderdate").as_i32()[i];
        if d >= lo && d < hi {
            if let Some(nk) = cust_nat.get(&orders.col("o_custkey").as_i64()[i]) {
                order_nat.insert(orders.col("o_orderkey").as_i64()[i], *nk);
            }
        }
    }
    let sup = &db.supplier;
    let mut sup_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..sup.len() {
        sup_nat.insert(sup.col("s_suppkey").as_i64()[i], sup.col("s_nationkey").as_i32()[i] as i64);
    }
    let li = &db.lineitem;
    let mut revenue: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        if let Some(cn) = order_nat.get(&li.col("l_orderkey").as_i64()[i]) {
            if let Some(sn) = sup_nat.get(&li.col("l_suppkey").as_i64()[i]) {
                if cn == sn {
                    *revenue.entry(*cn).or_insert(0.0) += li.col("l_extendedprice").as_f64()[i]
                        * (1.0 - li.col("l_discount").as_f64()[i]);
                }
            }
        }
    }
    let mut rows: Vec<Row> = revenue
        .into_iter()
        .map(|(nk, r)| vec![Value::Str(NATIONS[nk as usize].0.to_string()), Value::Float(r)])
        .collect();
    rows.sort_by(|a, b| b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 23));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:#?}\noracle:\n{:#?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn only_asia_nations_appear() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 29));
        let out = run(&db);
        let asia_names: Vec<&str> = region_nations(REGION)
            .unwrap()
            .iter()
            .map(|&nk| NATIONS[nk as usize].0)
            .collect();
        for r in &out.rows {
            match &r[0] {
                Value::Str(n) => assert!(asia_names.contains(&n.as_str()), "{n} not in ASIA"),
                _ => panic!(),
            }
        }
        assert!(out.rows.len() <= asia_names.len());
    }

    #[test]
    fn region_param_switches_the_build() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 29));
        let mut bag = PlanParams::new();
        bag.set("region", "EUROPE");
        let out = engine::run_serial(&db, &logical(&bag).unwrap());
        let europe: Vec<&str> = region_nations("EUROPE")
            .unwrap()
            .iter()
            .map(|&nk| NATIONS[nk as usize].0)
            .collect();
        for r in &out.rows {
            match &r[0] {
                Value::Str(n) => assert!(europe.contains(&n.as_str()), "{n} not in EUROPE"),
                _ => panic!(),
            }
        }
        let mut bad = PlanParams::new();
        bad.set("region", "ATLANTIS");
        assert!(logical(&bad).is_err());
    }

    #[test]
    fn sorted_by_revenue_desc() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 31));
        let out = run(&db);
        let revs: Vec<f64> = out.rows.iter().map(|r| r[1].as_f64()).collect();
        for w in revs.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}

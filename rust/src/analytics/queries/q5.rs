//! TPC-H Q5 — local supplier volume: revenue per nation within a region
//! where the customer and supplier share the nation.
//!
//! Five-way join (region→nation→customer→orders→lineitem→supplier); the
//! co-nationality constraint makes it the join-heaviest query in the set.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{
    self, BatchEval, Compiled, EvalBatch, HashJoinTable, PlanSpec, Predicate, Sel,
};
use crate::analytics::ops::{all_rows, filter_i32_range, ExecStats};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::{TpchDb, NATIONS, REGIONS};

const REGION: &str = "ASIA";

fn window() -> (i32, i32) {
    (date_to_days(1994, 1, 1), date_to_days(1995, 1, 1))
}

/// Nation keys belonging to the target region.
fn region_nations() -> Vec<i64> {
    let region_idx = REGIONS.iter().position(|r| *r == REGION).unwrap() as u32;
    NATIONS
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| *r == region_idx)
        .map(|(i, _)| i as i64)
        .collect()
}

/// The one Q5 plan: customer/order/supplier hash tables built once at
/// compile time; the kernel probes both sides per lineitem and sums
/// revenue per nation where customer and supplier nations agree.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q5", width: 1, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let (lo_d, hi_d) = window();
    let asia = region_nations();
    let in_asia = |nk: i64| asia.contains(&nk);

    // customer nation lookup (custkey → nationkey) for ASIA customers.
    let cust = &db.customer;
    let ckeys = cust.col("c_custkey").as_i64();
    let cnat = cust.col("c_nationkey").as_i32();
    stats.scan(cust.len(), 12);
    let cust_sel: Vec<u32> = all_rows(cust.len())
        .into_iter()
        .filter(|&i| in_asia(cnat[i as usize] as i64))
        .collect();
    let cust_map = HashJoinTable::build_dim(ckeys, &cust_sel, &mut stats);

    // orders in window with ASIA customers; record order row → nation.
    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    let ocust = orders.col("o_custkey").as_i64();
    let okeys = orders.col("o_orderkey").as_i64();
    stats.scan(orders.len(), 4);
    let ord_sel = filter_i32_range(&all_rows(orders.len()), odate, lo_d, hi_d);
    stats.scan(ord_sel.len(), 16);
    let mut ord_rows: Vec<u32> = Vec::new();
    let mut orow_nation = vec![-1i32; orders.len()];
    for &o in &ord_sel {
        if let Some(crow) = cust_map.probe_first(ocust[o as usize]) {
            ord_rows.push(o);
            orow_nation[o as usize] = cnat[crow as usize];
        }
    }
    let ord_map = HashJoinTable::build_dim(okeys, &ord_rows, &mut stats);

    // supplier nation lookup.
    let sup = &db.supplier;
    let skeys = sup.col("s_suppkey").as_i64();
    let snat = sup.col("s_nationkey").as_i32();
    stats.scan(sup.len(), 12);
    let sup_map = HashJoinTable::build_dim(skeys, &all_rows(sup.len()), &mut stats);

    // lineitem probe.
    let li = &db.lineitem;
    let lok = li.col("l_orderkey").as_i64();
    let lsk = li.col("l_suppkey").as_i64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let Some(orow) = ord_map.probe_first(lok[i]) else { return };
            let c_nat = orow_nation[orow as usize];
            let Some(srow) = sup_map.probe_first(lsk[i]) else { return };
            if snat[srow as usize] != c_nat {
                return;
            }
            out.keys.push(c_nat as i64);
            out.cols[0].push(price[i] * (1.0 - disc[i]));
        });
    });
    (Compiled { pred: Predicate::True, payload_bytes: 8 * 4, eval, groups_hint: 32 }, stats)
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let mut rows: Vec<Row> = (0..p.len())
        .map(|i| {
            vec![
                Value::Str(NATIONS[p.keys[i] as usize].0.to_string()),
                Value::Float(p.acc(i)[0]),
            ]
        })
        .collect();
    rows.sort_by(|a, b| b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap());
    rows
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let (lo, hi) = window();
    let asia = region_nations();
    let cust = &db.customer;
    let mut cust_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..cust.len() {
        let nk = cust.col("c_nationkey").as_i32()[i] as i64;
        if asia.contains(&nk) {
            cust_nat.insert(cust.col("c_custkey").as_i64()[i], nk);
        }
    }
    let orders = &db.orders;
    let mut order_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..orders.len() {
        let d = orders.col("o_orderdate").as_i32()[i];
        if d >= lo && d < hi {
            if let Some(nk) = cust_nat.get(&orders.col("o_custkey").as_i64()[i]) {
                order_nat.insert(orders.col("o_orderkey").as_i64()[i], *nk);
            }
        }
    }
    let sup = &db.supplier;
    let mut sup_nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..sup.len() {
        sup_nat.insert(sup.col("s_suppkey").as_i64()[i], sup.col("s_nationkey").as_i32()[i] as i64);
    }
    let li = &db.lineitem;
    let mut revenue: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        if let Some(cn) = order_nat.get(&li.col("l_orderkey").as_i64()[i]) {
            if let Some(sn) = sup_nat.get(&li.col("l_suppkey").as_i64()[i]) {
                if cn == sn {
                    *revenue.entry(*cn).or_insert(0.0) += li.col("l_extendedprice").as_f64()[i]
                        * (1.0 - li.col("l_discount").as_f64()[i]);
                }
            }
        }
    }
    let mut rows: Vec<Row> = revenue
        .into_iter()
        .map(|(nk, r)| vec![Value::Str(NATIONS[nk as usize].0.to_string()), Value::Float(r)])
        .collect();
    rows.sort_by(|a, b| b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 23));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:#?}\noracle:\n{:#?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn only_asia_nations_appear() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 29));
        let out = run(&db);
        let asia_names: Vec<&str> = region_nations()
            .iter()
            .map(|&nk| NATIONS[nk as usize].0)
            .collect();
        for r in &out.rows {
            match &r[0] {
                Value::Str(n) => assert!(asia_names.contains(&n.as_str()), "{n} not in ASIA"),
                _ => panic!(),
            }
        }
        assert!(out.rows.len() <= asia_names.len());
    }

    #[test]
    fn sorted_by_revenue_desc() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 31));
        let out = run(&db);
        let revs: Vec<f64> = out.rows.iter().map(|r| r[1].as_f64()).collect();
        for w in revs.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
